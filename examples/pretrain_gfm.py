"""End-to-end driver: pre-train a ~100M-parameter GFM for a few hundred steps.

The full-width paper model (4-layer EGNN x 866 hidden + 5 branches of
3x889 FC heads) on the 5 synthetic multi-fidelity sources with energy
alignment, early stopping, checkpointing — the paper's §5.1 protocol end to
end. ~100M-parameter class via wider heads; reduce --width for a quick run.

  PYTHONPATH=src python examples/pretrain_gfm.py --steps 300 --width 256
"""
import argparse
import json

import jax
import numpy as np

from repro.configs import get
from repro.core import MTPConfig, make_gfm_mtl, make_mtp_train_step
from repro.core.balancing import align_sources
from repro.data.loader import GroupBatcher
from repro.data.synthetic_atoms import N_SPECIES, SOURCES, generate_all
from repro.optim import adamw, warmup_cosine
from repro.train import checkpoint
from repro.train.loop import EarlyStopping, MetricLogger

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=300)
ap.add_argument("--width", type=int, default=866, help="EGNN hidden (paper: 866)")
ap.add_argument("--samples", type=int, default=512)
ap.add_argument("--batch", type=int, default=32)
ap.add_argument("--ckpt", default="results/gfm_pretrained.npz")
args = ap.parse_args()

cfg = get("hydragnn-gfm").replace(
    gnn_hidden=args.width, head_hidden=min(889, args.width + 23),
    max_atoms=24, max_edges=256, remat=False)
names = list(SOURCES)
model = make_gfm_mtl(cfg, n_tasks=len(names))
params = model.init(jax.random.PRNGKey(0))
n_params = sum(int(x.size) for x in jax.tree_util.tree_leaves(params))
print(f"# model: EGNN {cfg.gnn_layers}x{cfg.gnn_hidden} + "
      f"{len(names)} branches -> {n_params/1e6:.1f}M params")

data = generate_all(args.samples, max_atoms=cfg.max_atoms,
                    max_edges=cfg.max_edges)
# paper §4: align energy-per-atom across fidelities before pre-training
n_atoms = {k: np.maximum(s.node_mask.sum(1), 1) for k, s in data.items()}
aligned = align_sources(
    [{"species": s.species, "energy": s.energy * n_atoms[k]}
     for k, s in data.items()], N_SPECIES)
sources = []
for (k, s), al in zip(data.items(), aligned):
    sources.append(dict(species=s.species, pos=s.pos, edge_src=s.edge_src,
                        edge_dst=s.edge_dst, node_mask=s.node_mask,
                        edge_mask=s.edge_mask,
                        energy=al["energy"].astype(np.float32),
                        forces=s.forces))

opt = adamw(warmup_cosine(1e-3, 30, args.steps), grad_clip=1.0)
state = opt.init(params)
step = make_mtp_train_step(model, opt, MTPConfig(n_tasks=len(names)))
batcher = GroupBatcher(sources, args.batch)
log, stop = MetricLogger(), EarlyStopping(patience=25)

for i in range(args.steps):
    params, state, loss, m = step(params, state, batcher.next_batch())
    if i % 10 == 0 or i == args.steps - 1:
        row = log.log(i, loss=loss, **{names[t]: m["per_task_loss"][t]
                                       for t in range(len(names))})
        print(json.dumps({k: round(v, 4) for k, v in row.items()}))
        if stop.update(float(loss)):
            print("# early stopping (paper §5.1)")
            break

checkpoint.save(args.ckpt, {"params": params},
                metadata={"arch": cfg.name, "hidden": cfg.gnn_hidden,
                          "params_m": n_params / 1e6, "final_loss": float(loss)})
print(f"# checkpoint -> {args.ckpt}")

"""End-to-end driver: pre-train a ~100M-parameter GFM for a few hundred steps.

The full-width paper model (4-layer EGNN x 866 hidden + 5 branches of
3x889 FC heads) on the 5 synthetic multi-fidelity sources with energy
alignment, early stopping, checkpointing — the paper's §5.1 protocol end to
end, expressed as one engine ``Session``. ~100M-parameter class via wider
heads; reduce --width for a quick run.

  PYTHONPATH=src python examples/pretrain_gfm.py --steps 300 --width 256
"""
import argparse

import numpy as np

from repro.configs import get
from repro.core.balancing import align_sources
from repro.data.synthetic_atoms import N_SPECIES, SOURCES, generate_all
from repro.engine import Session, SessionConfig

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=300)
ap.add_argument("--width", type=int, default=866, help="EGNN hidden (paper: 866)")
ap.add_argument("--samples", type=int, default=512)
ap.add_argument("--batch", type=int, default=32)
ap.add_argument("--accum", type=int, default=1, help="grad-accum microbatches")
ap.add_argument("--ckpt", default="results/gfm_pretrained.npz")
args = ap.parse_args()

cfg = get("hydragnn-gfm").replace(
    gnn_hidden=args.width, head_hidden=min(889, args.width + 23),
    max_atoms=24, max_edges=256, remat=False)
names = list(SOURCES)

data = generate_all(args.samples, max_atoms=cfg.max_atoms,
                    max_edges=cfg.max_edges)
# paper §4: align energy-per-atom across fidelities before pre-training
n_atoms = {k: np.maximum(s.node_mask.sum(1), 1) for k, s in data.items()}
aligned = align_sources(
    [{"species": s.species, "energy": s.energy * n_atoms[k]}
     for k, s in data.items()], N_SPECIES)
sources = []
for (k, s), al in zip(data.items(), aligned):
    sources.append(dict(species=s.species, pos=s.pos, edge_src=s.edge_src,
                        edge_dst=s.edge_dst, node_mask=s.node_mask,
                        edge_mask=s.edge_mask,
                        energy=al["energy"].astype(np.float32),
                        forces=s.forces))

# paper §5.1: AdamW + warmup-cosine, early stopping, checkpoint at the end
session = Session.from_config(
    SessionConfig(model="gfm-mtl", arch=cfg, steps=args.steps,
                  batch_per_task=args.batch, lr=1e-3, warmup=30,
                  grad_clip=1.0, accum=args.accum, log_every=10,
                  eval_every=10, patience=25, ckpt_path=args.ckpt),
    sources=sources, task_names=names)
print(f"# model: EGNN {cfg.gnn_layers}x{cfg.gnn_hidden} + "
      f"{len(names)} branches -> {session.n_params()/1e6:.1f}M params")
result = session.run()
session.close()          # stop the background prefetcher
print(f"# final loss {result.final_loss:.4f} "
      f"(early stop: {result.stopped_early})")
print(f"# checkpoint -> {args.ckpt}")

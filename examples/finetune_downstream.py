"""Downstream fine-tuning — the paper's raison d'être for pre-training.

Pre-trains the two-level MTL GFM on 3 sources through an engine ``Session``,
then adapts to an UNSEEN high-fidelity downstream source (CCSD-like: same
ground truth, different offsets, little data) by attaching a FRESH branch to
the shared encoder — and compares against training an identical model from
scratch on the downstream data alone. The pre-trained encoder should
dominate in the low-data regime ("drastic reduction of data volume ... for
task-specific fine-tuning", paper §1).

  PYTHONPATH=src python examples/finetune_downstream.py
"""
import jax
import numpy as np

from repro.configs import get_smoke
from repro.core import gfm_eval_fn
from repro.core.mtl import gfm_loss_terms
from repro.data.synthetic_atoms import generate_all, generate_source, to_batch_dict
from repro.engine import (Session, SessionConfig, ShardingPlan,
                          SingleTaskModel, TrainState, make_step)
from repro.models import gnn, heads
from repro.optim import adamw

PRETRAIN_SOURCES = ["ani1x", "qm7x", "mptrj"]
N_DOWNSTREAM = 12          # low-data downstream regime
STEPS_PT, STEPS_FT = 400, 200

cfg = get_smoke("hydragnn-gfm").replace(gnn_hidden=64, head_hidden=48)

# ---- pre-train on 3 sources (one Session) ---------------------------------
data = generate_all(192, max_atoms=cfg.max_atoms, max_edges=cfg.max_edges,
                    sources=PRETRAIN_SOURCES)
train = [dict(species=s.species, pos=s.pos, edge_src=s.edge_src,
              edge_dst=s.edge_dst, node_mask=s.node_mask,
              edge_mask=s.edge_mask, energy=s.energy, forces=s.forces)
         for s in data.values()]
# the with-block stops the session's prefetcher thread before the
# fine-tuning phase below takes over the process
with Session.from_config(
        SessionConfig(model="gfm-mtl", arch=cfg, steps=STEPS_PT,
                      batch_per_task=16, lr=3e-3, log_every=100,
                      verbose=False),
        sources=train, task_names=PRETRAIN_SOURCES) as _sess:
    result = _sess.run()
print(f"pre-trained on {PRETRAIN_SOURCES}: final loss {result.final_loss:.4f}")

# ---- downstream source (unseen fidelity, tiny dataset) ---------------------
ds = generate_source("transition1x", N_DOWNSTREAM + 64,
                     max_atoms=cfg.max_atoms, max_edges=cfg.max_edges, seed=99)
ds_train = to_batch_dict(ds, np.arange(N_DOWNSTREAM))
ds_test = to_batch_dict(ds, np.arange(N_DOWNSTREAM, N_DOWNSTREAM + 64))
ev = gfm_eval_fn(cfg)


def finetune(shared, steps=STEPS_FT, lr=3e-3, seed=1):
    """Fresh branch + encoder tuning on a given encoder init, expressed as a
    SingleTaskModel through the same unified engine step."""
    def init(key):
        return {"branch": heads.branch_init(jax.random.PRNGKey(seed), cfg),
                "shared": shared}

    def loss_fn(fp, batch):
        feats = gnn.egnn_apply(fp["shared"], batch, cfg=cfg)
        e, f = heads.branch_apply(fp["branch"], feats, batch["node_mask"],
                                  cfg=cfg)
        l, _, _ = gfm_loss_terms(e, f, batch)
        return l

    model = SingleTaskModel(init=init, loss_fn=loss_fn, name="gfm-finetune")
    opt = adamw(lr)
    plan = ShardingPlan()
    step = plan.compile(make_step(model, opt, plan))
    state = TrainState.create(model.init(None), opt)
    for _ in range(steps):
        state, _ = step(state, ds_train)
    return ev(state.params["shared"], state.params["branch"], ds_test)


# both paths tune the encoder; the only difference is its initialization
e_ft, f_ft = finetune(result.params["shared"])
scratch = gnn.egnn_init(jax.random.PRNGKey(7), cfg)
e_sc, f_sc = finetune(scratch)                              # from scratch

print(f"\ndownstream ({N_DOWNSTREAM} samples), held-out MAE:")
print(f"  fine-tuned pre-trained encoder : E {float(e_ft):.4f}  F {float(f_ft):.4f}")
print(f"  trained from scratch           : E {float(e_sc):.4f}  F {float(f_sc):.4f}")
print(f"  energy-MAE improvement: {float(e_sc) / max(float(e_ft), 1e-9):.2f}x")

"""Downstream fine-tuning — the paper's raison d'être for pre-training.

Pre-trains the two-level MTL GFM on 3 sources, then adapts to an UNSEEN
high-fidelity downstream source (CCSD-like: same ground truth, different
offsets, little data) by attaching a FRESH branch to the frozen shared
encoder — and compares against training an identical model from scratch on
the downstream data alone. The pre-trained encoder should dominate in the
low-data regime ("drastic reduction of data volume ... for task-specific
fine-tuning", paper §1).

  PYTHONPATH=src python examples/finetune_downstream.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke
from repro.core import MTPConfig, gfm_eval_fn, make_gfm_mtl, make_mtp_train_step
from repro.data.loader import GroupBatcher
from repro.data.synthetic_atoms import generate_all, generate_source, to_batch_dict
from repro.models import gnn, heads
from repro.optim import adamw

PRETRAIN_SOURCES = ["ani1x", "qm7x", "mptrj"]
N_DOWNSTREAM = 12          # low-data downstream regime
STEPS_PT, STEPS_FT = 400, 200

cfg = get_smoke("hydragnn-gfm").replace(gnn_hidden=64, head_hidden=48)

# ---- pre-train on 3 sources ------------------------------------------------
model = make_gfm_mtl(cfg, len(PRETRAIN_SOURCES))
data = generate_all(192, max_atoms=cfg.max_atoms, max_edges=cfg.max_edges,
                    sources=PRETRAIN_SOURCES)
train = [dict(species=s.species, pos=s.pos, edge_src=s.edge_src,
              edge_dst=s.edge_dst, node_mask=s.node_mask,
              edge_mask=s.edge_mask, energy=s.energy, forces=s.forces)
         for s in data.values()]
params = model.init(jax.random.PRNGKey(0))
opt = adamw(3e-3)
st = opt.init(params)
step = make_mtp_train_step(model, opt, MTPConfig(n_tasks=3))
gb = GroupBatcher(train, 16)
for i in range(STEPS_PT):
    params, st, loss, _ = step(params, st, gb.next_batch())
print(f"pre-trained on {PRETRAIN_SOURCES}: final loss {float(loss):.4f}")

# ---- downstream source (unseen fidelity, tiny dataset) ---------------------
ds = generate_source("transition1x", N_DOWNSTREAM + 64,
                     max_atoms=cfg.max_atoms, max_edges=cfg.max_edges, seed=99)
ds_train = to_batch_dict(ds, np.arange(N_DOWNSTREAM))
ds_test = to_batch_dict(ds, np.arange(N_DOWNSTREAM, N_DOWNSTREAM + 64))
ev = gfm_eval_fn(cfg)


def finetune(shared, steps=STEPS_FT, lr=3e-3, train_encoder=False, seed=1):
    """Fresh branch on a given encoder; optionally tune the encoder too."""
    branch = heads.branch_init(jax.random.PRNGKey(seed), cfg)
    fopt = adamw(lr)
    fparams = {"branch": branch} | ({"shared": shared} if train_encoder else {})
    fst = fopt.init(fparams)

    def loss_fn(fp):
        sh = fp.get("shared", shared)
        feats = gnn.egnn_apply(sh, ds_train, cfg=cfg)
        e, f = heads.branch_apply(fp["branch"], feats, ds_train["node_mask"],
                                  cfg=cfg)
        from repro.core.mtl import gfm_loss_terms
        l, _, _ = gfm_loss_terms(e, f, ds_train)
        return l

    stp = jax.jit(lambda fp, fs: (lambda g: fopt.update(g, fs, fp))(
        jax.grad(loss_fn)(fp)))
    for _ in range(steps):
        fparams, fst = stp(fparams, fst)
    sh = fparams.get("shared", shared)
    return ev(sh, fparams["branch"], ds_test)


# both paths tune the encoder; the only difference is its initialization
e_ft, f_ft = finetune(params["shared"], train_encoder=True)
scratch = gnn.egnn_init(jax.random.PRNGKey(7), cfg)
e_sc, f_sc = finetune(scratch, train_encoder=True)          # from scratch

print(f"\ndownstream ({N_DOWNSTREAM} samples), held-out MAE:")
print(f"  fine-tuned pre-trained encoder : E {float(e_ft):.4f}  F {float(f_ft):.4f}")
print(f"  trained from scratch           : E {float(e_sc):.4f}  F {float(f_sc):.4f}")
print(f"  energy-MAE improvement: {float(e_sc) / max(float(e_ft), 1e-9):.2f}x")

"""The paper's technique carried onto an LLM: shared transformer trunk +
per-source LM heads (task-shardable), trained on 4 synthetic corpora with
different token statistics.

Demonstrates that per-source heads absorb per-corpus distribution shifts the
same way the GFM's per-dataset branches absorb fidelity offsets: per-task
losses converge together even though the corpora conflict.

  PYTHONPATH=src python examples/multitask_lm.py --arch xlstm-125m
"""
import argparse
import json

import jax
import numpy as np

from repro.configs import get_smoke
from repro.core import MTPConfig, make_lm_multitask, make_mtp_train_step
from repro.data.lm_data import make_lm_sources
from repro.data.loader import GroupBatcher
from repro.optim import adamw

ap = argparse.ArgumentParser()
ap.add_argument("--arch", default="qwen1.5-0.5b")
ap.add_argument("--tasks", type=int, default=4)
ap.add_argument("--steps", type=int, default=120)
ap.add_argument("--seq", type=int, default=64)
ap.add_argument("--batch", type=int, default=8)
args = ap.parse_args()

cfg = get_smoke(args.arch).replace(n_tasks=args.tasks)
model = make_lm_multitask(cfg)
sources = make_lm_sources(args.tasks, n_seqs=128, seq_len=args.seq,
                          vocab=cfg.vocab)
batcher = GroupBatcher(sources, args.batch)

params = model.init(jax.random.PRNGKey(0))
opt = adamw(2e-3)
state = opt.init(params)
step = make_mtp_train_step(model, opt, MTPConfig(n_tasks=args.tasks))

for i in range(args.steps):
    params, state, loss, m = step(params, state, batcher.next_batch())
    if i % 20 == 0 or i == args.steps - 1:
        print(json.dumps({
            "step": i, "loss": round(float(loss), 4),
            "per_task": [round(float(x), 3) for x in m["per_task_loss"]]}))

pt = np.asarray(m["per_task_loss"])
print(f"# spread across {args.tasks} conflicting corpora: "
      f"max/min = {pt.max() / pt.min():.2f} (heads absorb per-source shift)")

"""The paper's technique carried onto an LLM: shared transformer trunk +
per-source LM heads (task-shardable), trained on 4 synthetic corpora with
different token statistics — one engine ``Session``.

Demonstrates that per-source heads absorb per-corpus distribution shifts the
same way the GFM's per-dataset branches absorb fidelity offsets: per-task
losses converge together even though the corpora conflict.

  PYTHONPATH=src python examples/multitask_lm.py --arch xlstm-125m
"""
import argparse

import numpy as np

from repro.configs import get_smoke
from repro.data.lm_data import make_lm_sources
from repro.engine import Session, SessionConfig

ap = argparse.ArgumentParser()
ap.add_argument("--arch", default="qwen1.5-0.5b")
ap.add_argument("--tasks", type=int, default=4)
ap.add_argument("--steps", type=int, default=120)
ap.add_argument("--seq", type=int, default=64)
ap.add_argument("--batch", type=int, default=8)
args = ap.parse_args()

cfg = get_smoke(args.arch).replace(n_tasks=args.tasks)
sources = make_lm_sources(args.tasks, n_seqs=128, seq_len=args.seq,
                          vocab=cfg.vocab)

session = Session.from_config(
    SessionConfig(model="lm-mtl", arch=cfg, steps=args.steps,
                  batch_per_task=args.batch, lr=2e-3, log_every=20),
    sources=sources,
    task_names=[f"corpus{t}" for t in range(args.tasks)])
result = session.run()
session.close()          # stop the background prefetcher

pt = np.asarray(result.last_metrics["per_task_loss"])
print(f"# spread across {args.tasks} conflicting corpora: "
      f"max/min = {pt.max() / pt.min():.2f} (heads absorb per-source shift)")

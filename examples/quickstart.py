"""Quickstart: the paper's two-level MTL GFM through the engine API.

One declarative ``Session`` builds the HydraGNN-style EGNN + per-source
{energy, force} branches, trains on 3 synthetic multi-fidelity sources, and
prints per-source MAEs — a miniature of the paper's Tables 1-2 protocol.

  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import numpy as np

from repro.configs import get_smoke
from repro.core import gfm_eval_fn
from repro.data.synthetic_atoms import generate_all, to_batch_dict
from repro.engine import Session, SessionConfig

SOURCES = ["ani1x", "qm7x", "mptrj"]

cfg = get_smoke("hydragnn-gfm")
data = generate_all(256, max_atoms=cfg.max_atoms, max_edges=cfg.max_edges,
                    sources=SOURCES)
train = [dict(species=s.species[:192], pos=s.pos[:192],
              edge_src=s.edge_src[:192], edge_dst=s.edge_dst[:192],
              node_mask=s.node_mask[:192], edge_mask=s.edge_mask[:192],
              energy=s.energy[:192], forces=s.forces[:192])
         for s in data.values()]

# paper: AdamW (lr 1e-3 at full scale; 3e-3 at this smoke scale)
session = Session.from_config(
    SessionConfig(model="gfm-mtl", arch=cfg, steps=200, batch_per_task=16,
                  lr=3e-3, log_every=25),
    sources=train, task_names=SOURCES)
result = session.run()
session.close()          # stop the background prefetcher
params = result.params

ev = gfm_eval_fn(cfg)
print("\nheld-out per-source MAE (energy/atom, force):")
for t, name in enumerate(SOURCES):
    tb = to_batch_dict(data[name], np.arange(192, 256))
    head_t = jax.tree_util.tree_map(lambda x: x[t], params["heads"])
    e_mae, f_mae = ev(params["shared"], head_t, tb)
    print(f"  {name:14s} E {float(e_mae):.4f}   F {float(f_mae):.4f}")

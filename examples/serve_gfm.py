"""Serving example: a pre-trained GFM behind continuous size-binned batching.

End-to-end request lifecycle at smoke scale on CPU: save a checkpoint,
restore it into a ``ServeSession``, stream mixed-source property requests
(each asking its own source's head) through the async queue, and read the
engine's latency/occupancy report. See docs/serving.md for the design.

  PYTHONPATH=src python examples/serve_gfm.py --requests 40
"""
import argparse
import json
import os
import tempfile
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.core.mtl import make_gfm_mtl
from repro.data.bucketing import BucketSpec
from repro.data.synthetic_atoms import generate_mixture, source_dicts
from repro.serve import ServeSession
from repro.train import checkpoint

ap = argparse.ArgumentParser()
ap.add_argument("--requests", type=int, default=40)
ap.add_argument("--max-batch", type=int, default=8)
ap.add_argument("--max-wait-ms", type=float, default=3.0)
args = ap.parse_args()

# a tiny five-source GFM standing in for a trained checkpoint
data = generate_mixture(80, max_atoms=16, max_edges=96, seed=0)
sources, names = source_dicts(data), list(data.keys())
arch = ArchConfig(name="serve-example", family="gnn", gnn_hidden=32,
                  gnn_layers=2, n_species=64, head_hidden=16, head_layers=2,
                  remat=False, compute_dtype=jnp.float32)
model = make_gfm_mtl(arch, len(sources))
ckpt_dir = os.path.join(tempfile.mkdtemp(prefix="serve_gfm_"), "ck")
checkpoint.save(ckpt_dir, {"params": model.init(jax.random.PRNGKey(0))})

# restore into a serving session; the bucket grid doubles as the admission
# rule AND the compiled-shape universe
spec = BucketSpec.from_sources(sources, n_atom_buckets=2, n_edge_buckets=2)
srv = ServeSession.from_checkpoint(ckpt_dir, arch, n_heads=len(sources),
                                   spec=spec, max_batch=args.max_batch,
                                   max_wait_ms=args.max_wait_ms)
print(f"grid atoms={list(spec.atom_buckets)} edges={list(spec.edge_buckets)}"
      f" -> recompile budget {spec.n_shapes} shapes "
      f"({spec.n_shapes * len(sources)} cache entries)")

with srv:
    srv.warmup()
    rng = np.random.default_rng(0)
    keys = ("species", "pos", "edge_src", "edge_dst", "node_mask",
            "edge_mask")
    t0 = time.perf_counter()
    futs = []
    for _ in range(args.requests):
        t = int(rng.integers(len(sources)))
        i = int(rng.integers(sources[t]["species"].shape[0]))
        sample = {k: sources[t][k][i] for k in keys}
        futs.append((names[t], srv.submit(sample, head=t)))
    for name, fut in futs[:4]:
        out = fut.result(timeout=60)
        print(f"  {name:>10}: energy={out['energy']:+.4f}  "
              f"forces {out['forces'].shape}")
    for _, fut in futs:
        fut.result(timeout=60)
    wall = time.perf_counter() - t0
    stats = srv.stats()

c, lat = stats["counters"], stats["latency"]["e2e"]
print(f"{c['completed']}/{c['submitted']} requests in {wall:.2f}s "
      f"({c['completed'] / wall:.0f} req/s) over {c['batches']} batches, "
      f"occupancy {stats['batch_occupancy']:.2f}")
print(f"e2e latency p50={lat['p50_ms']:.1f}ms p99={lat['p99_ms']:.1f}ms; "
      f"{c['compilations']} compilations "
      f"(budget {stats['executable_cache']['budget']})")
print(json.dumps(stats["counters"]))

"""Serving example: batched prefill + greedy decode on an assigned arch.

Exercises the production serve path (prefill -> cache extension -> rolling /
full decode) at smoke scale on CPU.

  PYTHONPATH=src python examples/serve_lm.py --arch h2o-danube-1.8b --new 24
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_smoke
from repro.models import transformer
from repro.train.serve import greedy_generate

ap = argparse.ArgumentParser()
ap.add_argument("--arch", default="h2o-danube-1.8b")
ap.add_argument("--batch", type=int, default=4)
ap.add_argument("--prompt-len", type=int, default=32)
ap.add_argument("--new", type=int, default=16)
args = ap.parse_args()

cfg = get_smoke(args.arch)
if not cfg.supports_decode:
    raise SystemExit(f"{args.arch} has no decode path")
params = transformer.lm_init(jax.random.PRNGKey(0), cfg)
prompt = jax.random.randint(jax.random.PRNGKey(1),
                            (args.batch, args.prompt_len), 0, cfg.vocab)
memory = (jnp.zeros((args.batch, 32, cfg.d_model), cfg.compute_dtype)
          if cfg.n_enc_layers else None)

t0 = time.perf_counter()
out = greedy_generate(params, cfg, prompt, args.new, memory=memory)
dt = time.perf_counter() - t0
print(f"arch={cfg.name} batch={args.batch} prompt={args.prompt_len} "
      f"new={args.new}  wall={dt:.2f}s "
      f"({args.batch * args.new / dt:.1f} tok/s on CPU)")
print("sampled continuations (token ids):")
for row in out[:2]:
    print(" ", row.tolist())

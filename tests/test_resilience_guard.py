"""repro.resilience unit layer: retry backoff, guarded stepping, StepGuard
bookkeeping, batch sanitization.

The load-bearing guarantees: a tripped step leaves params/optimizer/step
BITWISE unchanged (the accept/reject select lives inside the jitted step),
a guarded clean run is bitwise-identical to an unguarded one (guarding is
free when nothing trips), and trip attribution charges the right source."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ArchConfig
from repro.core import MTPConfig, make_gfm_mtl
from repro.data.loader import GroupBatcher
from repro.data.synthetic_atoms import generate_all
from repro.engine import ShardingPlan, TrainState, make_step
from repro.engine.state import StepOutput
from repro.optim import adamw
from repro.resilience import (
    GuardConfig,
    GuardState,
    RetryError,
    StepGuard,
    make_guarded_step,
    poison_nan,
    with_retry,
    zero_task_slices,
)

CFG = ArchConfig(name="g", family="gnn", gnn_hidden=16, gnn_layers=2,
                 n_species=64, head_hidden=8, head_layers=2,
                 remat=False, compute_dtype=jnp.float32)


def _sources(n=16, n_tasks=2):
    data = generate_all(n, max_atoms=8, max_edges=24,
                        sources=["ani1x", "qm7x"][:n_tasks])
    return [dict(species=s.species, pos=s.pos, edge_src=s.edge_src,
                 edge_dst=s.edge_dst, node_mask=s.node_mask,
                 edge_mask=s.edge_mask, energy=s.energy, forces=s.forces)
            for s in data.values()]


def _guarded_setup(gcfg=None, n_tasks=2):
    model = make_gfm_mtl(CFG, n_tasks)
    opt = adamw(1e-3)
    plan = ShardingPlan(mtp=MTPConfig(n_tasks=n_tasks), donate=False)
    step = plan.compile(make_guarded_step(
        model, opt, plan, guard=gcfg or GuardConfig()))
    params = model.init(jax.random.PRNGKey(0))
    state = TrainState.create(params, opt, guard=GuardState.init())
    batcher = GroupBatcher(_sources(n_tasks=n_tasks), 4, seed=0)
    return step, state, batcher


def _tree_equal(a, b) -> bool:
    return all(np.array_equal(np.asarray(x), np.asarray(y))
               for x, y in zip(jax.tree_util.tree_leaves(a),
                               jax.tree_util.tree_leaves(b)))


# ---------------------------------------------------------------------------
# retry
# ---------------------------------------------------------------------------

def test_retry_succeeds_after_transient_failures():
    calls, delays = [], []

    def flaky():
        calls.append(1)
        if len(calls) < 3:
            raise OSError("transient")
        return "ok"

    assert with_retry(flaky, attempts=4, base_delay=0.1,
                      sleep=delays.append)() == "ok"
    assert len(calls) == 3
    # deterministic exponential backoff, no jitter
    assert delays == [0.1, 0.2]


def test_retry_exhaustion_raises_retry_error_with_cause():
    def broken():
        raise OSError("disk on fire")

    with pytest.raises(RetryError) as ei:
        with_retry(broken, attempts=3, base_delay=0.0, sleep=lambda _: None)()
    assert ei.value.attempts == 3
    assert isinstance(ei.value.__cause__, OSError)


def test_retry_does_not_catch_non_transient_exceptions():
    def bad_arg():
        raise ValueError("not IO")

    slept = []
    with pytest.raises(ValueError):
        with_retry(bad_arg, attempts=5, sleep=slept.append)()
    assert slept == []   # failed immediately, no backoff


def test_retry_decorator_form_and_on_retry_observer():
    seen = []

    @with_retry(attempts=2, base_delay=0.0, sleep=lambda _: None,
                on_retry=lambda i, e: seen.append((i, type(e).__name__)))
    def once():
        if not seen:
            raise OSError("first")
        return 7

    assert once() == 7
    assert seen == [(0, "OSError")]


# ---------------------------------------------------------------------------
# guarded step
# ---------------------------------------------------------------------------

def test_guarded_step_accepts_clean_batch():
    step, state, batcher = _guarded_setup()
    new, out = step(state, batcher.next_batch())
    assert float(out.metrics["guard_ok"]) == 1.0
    assert int(new.step) == 1 and int(new.guard.good) == 1
    assert int(new.guard.trips) == 0
    assert not _tree_equal(new.params, state.params)   # update applied


def test_guarded_step_nan_batch_is_bitwise_noop():
    """A NaN batch must leave params, optimizer moments AND the step counter
    bitwise unchanged — the whole point of the in-step select."""
    step, state, batcher = _guarded_setup()
    clean = batcher.next_batch()
    state, _ = step(state, clean)          # one accepted step first
    before = jax.device_get(state)
    new, out = step(state, poison_nan(batcher.next_batch()))
    assert float(out.metrics["guard_ok"]) == 0.0
    assert not np.isfinite(float(out.loss))
    assert _tree_equal(new.params, before.params)
    assert _tree_equal(new.opt_state, before.opt_state)
    assert int(new.step) == int(before.step)
    assert int(new.guard.trips) == 1


def test_guarded_step_spike_trips_after_warmup_only():
    gcfg = GuardConfig(spike_factor=1e-6, spike_slack=0.0, warmup_steps=2,
                       ema_decay=0.5)
    step, state, batcher = _guarded_setup(gcfg)
    # warmup: finiteness only, the absurd spike_factor must not trip yet
    for _ in range(2):
        state, out = step(state, batcher.next_batch())
        assert float(out.metrics["guard_ok"]) == 1.0
    # armed: any loss > 1e-6 * ema trips
    state, out = step(state, batcher.next_batch())
    assert float(out.metrics["guard_ok"]) == 0.0
    assert np.isfinite(float(out.loss))    # a spike trip, not a NaN trip


def test_tripped_loss_never_updates_ema():
    step, state, batcher = _guarded_setup()
    state, _ = step(state, batcher.next_batch())
    ema_before = float(state.guard.ema)
    state, out = step(state, poison_nan(batcher.next_batch()))
    assert float(out.metrics["guard_ok"]) == 0.0
    assert float(state.guard.ema) == ema_before


def test_guarded_clean_run_matches_unguarded_and_is_deterministic():
    """With no trips the guard selects the exact update, but guarded and
    unguarded steps are DIFFERENT XLA programs, so fusion may differ by a
    few ULPs — the honest contract is (a) tight numerical agreement with
    the plain step and (b) BITWISE determinism across guarded replays
    (that's what rollback/resume identity rests on)."""
    model = make_gfm_mtl(CFG, 2)
    opt = adamw(1e-3)
    plan = ShardingPlan(mtp=MTPConfig(n_tasks=2), donate=False)
    guarded = plan.compile(make_guarded_step(model, opt, plan,
                                             guard=GuardConfig()))
    plain = plan.compile(make_step(model, opt, plan))
    params = model.init(jax.random.PRNGKey(0))
    ps = TrainState.create(params, opt)
    b2 = GroupBatcher(_sources(), 4, seed=0)

    def guarded_run():
        gs = TrainState.create(params, opt, guard=GuardState.init())
        b = GroupBatcher(_sources(), 4, seed=0)
        for _ in range(4):
            gs, out = guarded(gs, b.next_batch())
            assert float(out.metrics["guard_ok"]) == 1.0
        return gs

    gs = guarded_run()
    for _ in range(4):
        ps, _ = plain(ps, b2.next_batch())
    for x, y in zip(jax.tree_util.tree_leaves(gs.params),
                    jax.tree_util.tree_leaves(ps.params)):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                   rtol=1e-5, atol=1e-7)
    gs2 = guarded_run()                    # bitwise-deterministic replay
    assert _tree_equal(gs.params, gs2.params)
    assert _tree_equal(gs.opt_state, gs2.opt_state)


# ---------------------------------------------------------------------------
# StepGuard (host side)
# ---------------------------------------------------------------------------

def _out(ok: float, per_task=None, loss=1.0):
    m = {"guard_ok": np.float32(ok)}
    if per_task is not None:
        m["per_task_loss"] = np.asarray(per_task, np.float32)
    return StepOutput(loss=jnp.asarray(loss), metrics=m)


def test_step_guard_counts_consecutive_trips_and_rollback():
    g = StepGuard(GuardConfig(max_consecutive_trips=2), n_sources=0)
    assert g.observe(_out(1.0)) and not g.should_rollback()
    assert not g.observe(_out(0.0)) and not g.should_rollback()
    assert not g.observe(_out(0.0)) and g.should_rollback()
    g.on_rollback()
    assert g.consecutive == 0 and g.rollbacks == 1
    assert g.observe(_out(1.0))            # streak is over
    assert g.report()["trips"] == 2


def test_step_guard_attributes_nonfinite_sources_directly():
    g = StepGuard(GuardConfig(quarantine_after=2), n_sources=3)
    g.observe(_out(0.0, per_task=[1.0, np.nan, 2.0]))
    g.observe(_out(0.0, per_task=[1.0, np.inf, 2.0]))
    assert g.source_trips.tolist() == [0, 2, 0]
    assert g.quarantine_candidates() == [1]
    g.mark_quarantined([1])
    assert g.quarantine_candidates() == []   # not re-proposed


def test_step_guard_finite_spike_charges_argmax():
    g = StepGuard(GuardConfig(), n_sources=3)
    g.observe(_out(0.0, per_task=[1.0, 2.0, 50.0]))
    assert g.source_trips.tolist() == [0, 0, 1]


def test_quarantine_candidates_off_by_default():
    g = StepGuard(GuardConfig(), n_sources=2)   # quarantine_after=0
    for _ in range(10):
        g.observe(_out(0.0, per_task=[np.nan, 1.0]))
    assert g.quarantine_candidates() == []


# ---------------------------------------------------------------------------
# batch sanitization
# ---------------------------------------------------------------------------

def test_zero_task_slices_scrubs_only_given_tasks():
    batch = {"pos": np.full((3, 4, 3), 7.0, np.float32),
             "species": np.full((3, 4), 5, np.int32),
             "node_mask": np.ones((3, 4), bool)}
    out = zero_task_slices(batch, [1])
    for k in batch:
        arr = np.asarray(out[k])
        assert not arr[1].any()                       # scrubbed slice inert
        np.testing.assert_array_equal(arr[0], batch[k][0])
        np.testing.assert_array_equal(arr[2], batch[k][2])
    assert zero_task_slices(batch, []) is batch       # no-op passthrough

"""Deterministic fault injection (repro.resilience.faults): schedules are
seeded and replayable, each fault fires exactly once, and the batch
injectors corrupt exactly what they claim (and nothing else)."""
import numpy as np
import pytest

from repro.resilience import (
    KINDS,
    Fault,
    FaultSchedule,
    corrupt_batch,
    poison_nan,
    scale_floats,
)


def test_fault_validation():
    with pytest.raises(AssertionError):
        Fault(tick=1, kind="meteor_strike")
    with pytest.raises(AssertionError):
        Fault(tick=0, kind="nan_grad")     # ticks are 1-based


def test_schedule_take_fires_each_fault_exactly_once():
    s = FaultSchedule([Fault(tick=2, kind="nan_grad"),
                       Fault(tick=2, kind="preempt"),
                       Fault(tick=5, kind="kill_producer")])
    assert len(s) == 3 and s.pending() == 3
    assert s.take(1) == []
    got = s.take(2)
    assert [f.kind for f in got] == ["nan_grad", "preempt"]
    assert s.take(2) == []                 # popped: a rollback revisiting
    assert s.pending() == 1                # tick 2 cannot re-fire
    s.take(5)
    assert s.pending() == 0 and len(s.fired) == 3


def test_schedule_from_dict_shorthand():
    s = FaultSchedule.from_dict({3: "nan_grad", 7: "preempt"})
    assert [f.kind for f in s.take(3)] == ["nan_grad"]
    assert [f.kind for f in s.take(7)] == ["preempt"]


def test_random_schedule_is_seed_deterministic():
    a = FaultSchedule.random(seed=7, n_ticks=200, rates={"nan_grad": 0.1})
    b = FaultSchedule.random(seed=7, n_ticks=200, rates={"nan_grad": 0.1})
    c = FaultSchedule.random(seed=8, n_ticks=200, rates={"nan_grad": 0.1})
    key = lambda s: [(f.tick, f.kind) for t in range(1, 201)  # noqa: E731
                     for f in s.take(t)]
    ka = key(a)
    assert ka == key(b)
    assert ka != key(c)
    assert len(ka) > 0


# ---------------------------------------------------------------------------
# injectors
# ---------------------------------------------------------------------------

def _batch():
    return {"pos": np.ones((3, 4, 3), np.float32),
            "energy": np.full((3, 4), 2.0, np.float32),
            "species": np.full((3, 4), 5, np.int32),
            "node_mask": np.ones((3, 4), bool)}


def test_poison_nan_whole_batch_floats_only():
    out = poison_nan(_batch())
    assert np.isnan(np.asarray(out["pos"])).all()
    assert np.isnan(np.asarray(out["energy"])).all()
    np.testing.assert_array_equal(np.asarray(out["species"]),
                                  _batch()["species"])   # ints untouched
    np.testing.assert_array_equal(np.asarray(out["node_mask"]),
                                  _batch()["node_mask"])  # bools untouched


def test_poison_nan_source_targeted_slice_only():
    out = poison_nan(_batch(), source=1)
    pos = np.asarray(out["pos"])
    assert np.isnan(pos[1]).all()
    assert np.isfinite(pos[0]).all() and np.isfinite(pos[2]).all()


def test_scale_floats_magnitude():
    out = scale_floats(_batch(), 1e3, source=2)
    e = np.asarray(out["energy"])
    assert (e[2] == 2e3).all() and (e[0] == 2.0).all()


def test_corrupt_batch_dispatch():
    nan = corrupt_batch(_batch(), Fault(tick=1, kind="nan_grad"))
    assert np.isnan(np.asarray(nan["pos"])).all()
    big = corrupt_batch(_batch(), Fault(tick=1, kind="corrupt_batch",
                                        magnitude=10.0))
    assert (np.asarray(big["energy"]) == 20.0).all()
    with pytest.raises(ValueError):
        corrupt_batch(_batch(), Fault(tick=1, kind="kill_producer"))


def test_kinds_cover_the_issue_contract():
    """The harness must span >= 5 distinct fault classes (ISSUE-7)."""
    assert set(KINDS) == {"nan_grad", "corrupt_batch", "kill_producer",
                          "ckpt_write_fail", "preempt"}

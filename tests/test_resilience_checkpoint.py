"""Preemption-safe checkpointing: atomic .npz publishing, CheckpointManager
retention + retried IO, PreemptionHandler signal plumbing, and the
None-leaf TrainState roundtrip the resilient runner depends on."""
import json
import os
import signal

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.engine import TrainState
from repro.optim import adamw
from repro.resilience import (
    CheckpointManager,
    CheckpointPolicy,
    CheckpointWriteError,
    GuardState,
    PreemptionHandler,
    RetryError,
)
from repro.train import checkpoint
from repro.train.loop import train_loop


def _state(seed=0, guard=False):
    opt = adamw(1e-3)
    params = {"w": jnp.arange(6, dtype=jnp.float32).reshape(2, 3) + seed,
              "b": jnp.ones((3,), jnp.float32) * seed}
    return TrainState.create(params, opt,
                             guard=GuardState.init() if guard else None)


def _tree_equal(a, b) -> bool:
    return all(np.array_equal(np.asarray(x), np.asarray(y))
               for x, y in zip(jax.tree_util.tree_leaves(a),
                               jax.tree_util.tree_leaves(b)))


# ---------------------------------------------------------------------------
# atomic npz write (ISSUE-7 satellite: train/checkpoint.py)
# ---------------------------------------------------------------------------

def test_npz_write_is_atomic_under_kill_mid_write(tmp_path, monkeypatch):
    """A writer killed mid-.npz-write must leave the PREVIOUS checkpoint
    intact and loadable — tmp + os.replace, like the JSON sidecars."""
    path = str(tmp_path / "ck")
    tree = {"w": np.arange(4.0, dtype=np.float32)}
    checkpoint.save(path, tree, metadata={"step": 1})

    real_savez = np.savez

    def dying_savez(f, **arrs):
        f.write(b"PK\x03\x04 truncated")   # partial bytes, then the "kill"
        raise KeyboardInterrupt("simulated SIGKILL mid-write")

    monkeypatch.setattr(np, "savez", dying_savez)
    with pytest.raises(KeyboardInterrupt):
        checkpoint.save(path, {"w": np.full(4, 9.0, np.float32)},
                        metadata={"step": 2})
    monkeypatch.setattr(np, "savez", real_savez)

    restored = checkpoint.restore(path, {"w": np.zeros(4, np.float32)})
    np.testing.assert_array_equal(restored["w"], tree["w"])   # old survives
    # no stray temp files published into the directory listing
    assert not [f for f in os.listdir(tmp_path) if f.endswith(".tmp")]


def test_none_leaves_roundtrip_through_npz():
    """TrainState.rng/guard = None must survive save/restore — npz cannot
    hold None, so _flatten drops them and the template restores them."""
    st = _state()
    assert st.rng is None and st.guard is None
    flat = checkpoint._flatten({"state": st})
    assert not any(v is None for v in flat.values())
    rebuilt = checkpoint._unflatten_like({"state": st}, flat, "")["state"]
    assert rebuilt.rng is None and rebuilt.guard is None
    assert _tree_equal(rebuilt.params, st.params)


def test_guarded_state_roundtrips_bitwise(tmp_path):
    st = _state(seed=3, guard=True)
    path = str(tmp_path / "ck")
    checkpoint.save(path, {"state": st}, metadata={"step": 0})
    back = checkpoint.restore(path, {"state": st})["state"]
    assert _tree_equal(back, st)
    assert isinstance(back.guard, GuardState)


# ---------------------------------------------------------------------------
# CheckpointPolicy / CheckpointManager
# ---------------------------------------------------------------------------

def test_policy_cadence():
    p = CheckpointPolicy(every_steps=5)
    assert [s for s in range(12) if p.should_save(s)] == [5, 10]
    assert not CheckpointPolicy(every_steps=0).should_save(100)


def test_manager_save_load_latest_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path), CheckpointPolicy())
    st = _state(seed=2, guard=True)
    mgr.save(st, metric=1.5, datapipe={"kind": "X", "pos": 3})
    path, back = mgr.load_latest(template=st)
    assert _tree_equal(back, st)
    assert checkpoint.load_metadata(path)["metric"] == 1.5
    assert checkpoint.load_datapipe(path) == {"kind": "X", "pos": 3}
    assert mgr.latest_step() == 0


def test_manager_retention_keeps_last_k_plus_best(tmp_path):
    mgr = CheckpointManager(str(tmp_path),
                            CheckpointPolicy(keep_last=2, keep_best=True))
    opt = adamw(1e-3)
    # best metric at step 1 (0.1), then worse ones — step 1 must survive
    # pruning even after falling out of the trailing window
    for step, metric in [(1, 0.1), (2, 5.0), (3, 4.0), (4, 3.0)]:
        st = TrainState.create({"w": jnp.ones(2) * step}, opt)
        st = st._replace(step=jnp.asarray(step, jnp.int32))
        mgr.save(st, metric=metric)
    steps = [s for s, _ in mgr.checkpoints()]
    assert steps == [1, 3, 4]
    assert mgr.best() == mgr.path_for(1)


def test_manager_retries_armed_failures_then_succeeds(tmp_path):
    slept = []
    mgr = CheckpointManager(str(tmp_path), attempts=3, base_delay=0.01,
                            sleep=slept.append)
    mgr.arm_failures(2)
    mgr.save(_state())
    assert mgr.io_retries == 2
    assert slept == [0.01, 0.02]           # deterministic backoff
    assert mgr.latest_step() == 0          # the third attempt landed


def test_manager_exhausted_retries_raise(tmp_path):
    mgr = CheckpointManager(str(tmp_path), attempts=2, base_delay=0.0,
                            sleep=lambda _: None)
    mgr.arm_failures(5)
    with pytest.raises(RetryError) as ei:
        mgr.save(_state())
    assert isinstance(ei.value.__cause__, CheckpointWriteError)
    assert mgr.checkpoints() == []         # nothing half-published


def test_manager_directory_is_the_index(tmp_path):
    """checkpoints() trusts the listing (atomic writes guarantee complete
    files) and ignores foreign files."""
    mgr = CheckpointManager(str(tmp_path))
    (tmp_path / "ckpt-garbage.npz").write_bytes(b"")
    (tmp_path / "notes.txt").write_text("hi")
    mgr.save(_state())
    assert [s for s, _ in mgr.checkpoints()] == [0]


# ---------------------------------------------------------------------------
# PreemptionHandler
# ---------------------------------------------------------------------------

def test_preemption_trigger_without_signal():
    h = PreemptionHandler()
    assert not h.triggered and not h.installed
    h.trigger()
    assert h.triggered
    h.clear()
    assert not h.triggered


def test_preemption_real_signal_sets_flag_and_uninstall_restores():
    prev = signal.getsignal(signal.SIGUSR1)
    with PreemptionHandler(install=True, signals=(signal.SIGUSR1,)) as h:
        assert h.installed
        os.kill(os.getpid(), signal.SIGUSR1)
        # the python-level handler runs on the main thread's next bytecode
        for _ in range(1000):
            if h.triggered:
                break
        assert h.triggered
        assert h.received == signal.SIGUSR1
    assert signal.getsignal(signal.SIGUSR1) is prev   # restored on exit


def test_train_loop_should_stop_hook():
    """The generic loop's cooperative stop: a PreemptionHandler plugged into
    should_stop ends the loop cleanly mid-schedule."""
    h = PreemptionHandler()
    seen = []

    def step(state, batch):
        seen.append(batch)
        if len(seen) == 3:
            h.trigger()
        from repro.engine.state import StepOutput
        return state + 1, StepOutput(loss=jnp.asarray(0.0), metrics={})

    state, _, _ = train_loop(step, 0, lambda: len(seen), steps=10,
                             eval_every=100, should_stop=lambda: h.triggered)
    assert state == 3                      # stopped after the trigger

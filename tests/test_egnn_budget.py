"""Unit tests for the fused-kernel VMEM budget model
(``repro.kernels.egnn_edge.budget``).

The model is the single source of truth for what the H-blocked kernels may
hold resident: the planner must never emit an over-budget
``(block_e, block_h)`` — at the paper widths H ∈ {256, 512, 866} and
A ∈ {64, 128} in particular — and over-budget explicit overrides must
raise instead of silently compiling.
"""
import pytest

from repro.kernels.egnn_edge import budget, ops as edge_ops
from repro.kernels.egnn_edge.budget import (VMEM_BUDGET, VmemBudgetError,
                                            bwd_vmem_items, check_blocks,
                                            fwd_vmem_items, plan_blocks,
                                            vmem_bytes)

PAPER_E = 768


@pytest.mark.parametrize("H", [256, 512, 866])
@pytest.mark.parametrize("A", [64, 128])
def test_planned_blocks_always_within_budget(H, A):
    """The acceptance grid: every planned config fits the documented
    budget, blocks are positive and problem-clamped."""
    be, bh = plan_blocks(A, PAPER_E, H)
    assert 8 <= be <= PAPER_E and 8 <= bh <= H
    assert vmem_bytes(A, be, bh, H) <= VMEM_BUDGET


def test_paper_width_requires_h_split():
    """At the paper width the whole-H config is over budget (the ROADMAP
    gap this PR closes) and the planner responds by splitting H."""
    A, H = 128, 866
    assert vmem_bytes(A, 256, H, H) > VMEM_BUDGET    # whole-H does NOT fit
    be, bh = plan_blocks(A, PAPER_E, H)
    assert bh < H
    assert vmem_bytes(A, be, bh, H) <= VMEM_BUDGET


def test_model_is_monotone_in_blocks_and_h():
    """Sanity on the byte model itself: more block, more bytes."""
    base = vmem_bytes(128, 128, 128, 512)
    assert vmem_bytes(128, 256, 128, 512) > base
    assert vmem_bytes(128, 128, 256, 512) > base
    assert vmem_bytes(128, 128, 128, 866) > base
    # bf16 compute shrinks the compute-dtype tiles
    assert vmem_bytes(128, 128, 128, 512, itemsize=2) < base


def test_itemized_model_covers_both_directions():
    """The backward resident set dominates (it is what vmem_bytes budgets),
    and every item is a positive byte count."""
    fwd = fwd_vmem_items(128, 128, 128, 866)
    bwd = bwd_vmem_items(128, 128, 128, 866)
    assert all(v > 0 for v in fwd.values())
    assert all(v > 0 for v in bwd.values())
    assert sum(bwd.values()) > sum(fwd.values())
    assert vmem_bytes(128, 128, 128, 866) == sum(bwd.values())


def test_over_budget_override_raises_with_guidance():
    """Explicit whole-H blocks at the paper width must raise a clear
    error naming the shape, the overage, and a fitting plan — not compile."""
    with pytest.raises(VmemBudgetError, match="block_e=256, block_h=866"):
        check_blocks(128, PAPER_E, 866, 256, 866)
    with pytest.raises(VmemBudgetError, match="plan_blocks"):
        check_blocks(128, PAPER_E, 866, 256, 866)
    # within budget: no raise
    check_blocks(128, PAPER_E, 866, *plan_blocks(128, PAPER_E, 866))


def test_over_budget_override_raises_through_public_entry():
    """The validation is wired into egnn_edge_agg itself — an over-budget
    cfg override fails fast at call time, before any pallas_call."""
    import jax, jax.numpy as jnp
    from repro.models.mlp import mlp_init
    B, E, A, H = 1, 16, 8, 866
    h = jnp.zeros((B, A, H))
    pos = jnp.zeros((B, A, 3))
    src = dst = jnp.zeros((B, E), jnp.int32)
    em = jnp.ones((B, E), bool)
    phi_e = mlp_init(jax.random.PRNGKey(0), 2 * H + 1, H, H, 1, jnp.float32)
    with pytest.raises(VmemBudgetError):
        edge_ops.egnn_edge_agg(h, pos, src, dst, em, phi_e,
                               block_e=16, block_h=866)


def test_partial_override_is_validated_as_a_mix():
    """Overriding only one knob re-validates the (override, planned) pair."""
    be, bh = edge_ops._resolve_blocks(None, 64, 128, PAPER_E, 866)
    assert bh == 64 and vmem_bytes(128, be, bh, 866) <= VMEM_BUDGET


def test_planner_raises_when_nothing_fits():
    """A node state too large for any (block_e, block_h) raises instead of
    looping or emitting a bogus config."""
    with pytest.raises(VmemBudgetError, match="node-dimension"):
        plan_blocks(4096, PAPER_E, 8192, vmem_limit=1 << 20)


def test_segment_sum_autotune_never_over_budget():
    """The shared segment-sum heuristic also respects its budget at wide F
    (it used to stall at block_e=8 and sail past): the emitted config's
    resident set fits the limit it was given."""
    from repro.kernels.segment_sum.kernel import autotune_blocks
    for F in (256, 512, 866, 4096):
        for A in (64, 128, 1024):
            limit = 2 << 20
            bn, be = autotune_blocks(A, PAPER_E, F, vmem_limit=limit)
            assert 8 <= bn and 8 <= be
            assert 4 * (bn * F + be * F + be * bn) <= limit, (A, F, bn, be)

import os

# Tests run on the single real CPU device — the 512-device XLA flag is
# confined to launch/dryrun.py (and subprocesses spawned by tests that need
# a multi-device mesh set their own env).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax  # noqa: E402

jax.config.update("jax_enable_x64", False)

"""Unit tests for the repro.engine training API: unified TrainStep protocol,
gradient accumulation as a universal wrapper, ShardingPlan spec builders
(incl. the ndim<2 batch-sharding regression), Session end to end."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.core import MTPConfig, batch_shardings, make_gfm_mtl
from repro.data.lm_data import make_lm_sources
from repro.data.loader import GroupBatcher
from repro.data.synthetic_atoms import generate_all, to_batch_dict
from repro.engine import (Session, SessionConfig, ShardingPlan, StepOutput,
                          TrainState, available_models, build_model,
                          make_step, with_grad_accum)
from repro.optim import adamw
from repro.train.loop import EarlyStopping, MetricLogger, train_loop


def _lm_cfg(**kw):
    base = dict(name="lm", n_layers=2, d_model=32, n_heads=2, n_kv_heads=2,
                d_ff=64, vocab=64, remat=False, compute_dtype=jnp.float32)
    base.update(kw)
    return ArchConfig(**base)


def _gfm_cfg():
    return ArchConfig(name="g", family="gnn", gnn_hidden=24, gnn_layers=2,
                      n_species=64, head_hidden=12, head_layers=2,
                      remat=False, compute_dtype=jnp.float32)


def _gfm_sources(n=24, n_tasks=3):
    names = ["ani1x", "qm7x", "mptrj"][:n_tasks]
    data = generate_all(n, max_atoms=10, max_edges=40, sources=names)
    return [dict(species=s.species, pos=s.pos, edge_src=s.edge_src,
                 edge_dst=s.edge_dst, node_mask=s.node_mask,
                 edge_mask=s.edge_mask, energy=s.energy, forces=s.forces)
            for s in data.values()]


def _max_err(a, b):
    e = jax.tree_util.tree_map(lambda x, y: float(jnp.abs(x - y).max()), a, b)
    return max(jax.tree_util.tree_leaves(e))


# ---------------------------------------------------------------------------
# unified step protocol
# ---------------------------------------------------------------------------

def test_unified_signature_lm_and_multitask():
    """One signature — step(state, batch) -> (state, StepOutput) — for both
    the single-task LM and the multi-task paths."""
    opt = adamw(1e-3)
    # LM
    cfg = _lm_cfg()
    model = build_model("lm", cfg)
    batch = {k: jnp.asarray(v) for k, v in
             make_lm_sources(1, 8, 16, cfg.vocab)[0].items()}
    plan = ShardingPlan(donate=False)
    state = TrainState.create(model.init(jax.random.PRNGKey(0)), opt)
    state2, out = plan.compile(make_step(model, opt, plan))(state, batch)
    assert isinstance(out, StepOutput) and np.isfinite(float(out.loss))
    assert int(state2.step) == int(state.step) + 1
    # multi-task GFM
    model2 = make_gfm_mtl(_gfm_cfg(), 3)
    gb = GroupBatcher(_gfm_sources(), 8)
    plan2 = ShardingPlan(mtp=MTPConfig(n_tasks=3), donate=False)
    st = TrainState.create(model2.init(jax.random.PRNGKey(0)), opt)
    st2, out2 = plan2.compile(make_step(model2, opt, plan2))(st, gb.next_batch())
    assert isinstance(out2, StepOutput)
    assert out2.metrics["per_task_loss"].shape == (3,)
    assert int(st2.step) == 1


def test_registry_names():
    assert set(available_models()) >= {"gfm-mtl", "gfm-baseline", "lm",
                                       "lm-mtl"}
    with pytest.raises(KeyError):
        build_model("nope", _lm_cfg())


# ---------------------------------------------------------------------------
# gradient accumulation — the one wrapper, both paths
# ---------------------------------------------------------------------------

def test_grad_accum_lm_matches_full_batch():
    cfg = _lm_cfg()
    model = build_model("lm", cfg)
    batch = {k: jnp.asarray(v) for k, v in
             make_lm_sources(1, 8, 16, cfg.vocab)[0].items()}
    opt = adamw(1e-2)
    params = model.init(jax.random.PRNGKey(0))
    plan = ShardingPlan(donate=False)
    results = {}
    for accum in (1, 4):
        step = plan.compile(make_step(model, opt, plan, accum=accum))
        s2, out = step(TrainState.create(params, opt), batch)
        results[accum] = (float(out.loss), s2.params)
    np.testing.assert_allclose(results[1][0], results[4][0], rtol=1e-6)
    assert _max_err(results[1][1], results[4][1]) < 1e-4


def test_grad_accum_multitask_matches_full_batch():
    """Accumulation slices task-major batches along dim 1 (per-task batch),
    never the task dim — exact parity for the multi-task LM."""
    cfg = _lm_cfg(name="lmmt", n_tasks=3)
    model = build_model("lm-mtl", cfg)
    gb = GroupBatcher(make_lm_sources(3, 16, 16, cfg.vocab), 8)
    batch = gb.next_batch()
    opt = adamw(1e-2)
    params = model.init(jax.random.PRNGKey(0))
    plan = ShardingPlan(mtp=MTPConfig(n_tasks=3), donate=False)
    results = {}
    for accum in (1, 2):
        step = plan.compile(make_step(model, opt, plan, accum=accum))
        s2, out = step(TrainState.create(params, opt), batch)
        results[accum] = (float(out.loss), s2.params)
    np.testing.assert_allclose(results[1][0], results[2][0], rtol=1e-6)
    assert _max_err(results[1][1], results[2][1]) < 1e-4


def test_grad_accum_passes_low_rank_leaves_through():
    """Task-major batches may carry leaves with no per-task batch dim (e.g.
    stacked task weights (n_tasks,)); accumulation broadcasts them to every
    microbatch instead of crashing on the missing axis."""
    cfg = _lm_cfg(name="lmmt2", n_tasks=3)
    model = build_model("lm-mtl", cfg)
    gb = GroupBatcher(make_lm_sources(3, 16, 16, cfg.vocab), 8)
    batch = dict(gb.next_batch(), task_w=jnp.ones((3,)))
    opt = adamw(1e-2)
    plan = ShardingPlan(mtp=MTPConfig(n_tasks=3), donate=False)
    step = plan.compile(make_step(model, opt, plan, accum=2))
    _, out = step(TrainState.create(model.init(jax.random.PRNGKey(0)), opt),
                  batch)
    assert np.isfinite(float(out.loss))


def test_grad_accum_rejects_indivisible_batch():
    def grad_fn(params, batch):
        return jnp.zeros(()), {}, params
    fn = with_grad_accum(grad_fn, 3)
    with pytest.raises(AssertionError):
        fn(jnp.zeros((2,)), {"x": jnp.zeros((8, 4))})


# ---------------------------------------------------------------------------
# batch_shardings ndim<2 regression (satellite)
# ---------------------------------------------------------------------------

def test_batch_shardings_low_rank_leaves():
    """1-D per-task leaves (e.g. stacked task weights (n_tasks,)) and 0-D
    scalars get rank-truncated specs instead of over-long ones."""
    from repro.launch.mesh import make_host_mesh
    mesh = make_host_mesh(1, 1)
    batch = {"tokens": jnp.zeros((4, 8, 16), jnp.int32),
             "energy": jnp.zeros((4, 8)),
             "task_w": jnp.zeros((4,)),
             "scalar": jnp.zeros(())}
    for mode in ("par", "base"):
        sh = batch_shardings(mesh, batch, MTPConfig(n_tasks=4, mode=mode))
        for k, leaf in batch.items():
            spec = sh[k].spec
            assert len(spec) <= leaf.ndim, f"{mode}/{k}: spec {spec}"
        assert sh["tokens"].spec == (
            P("model", ("data",), None) if mode == "par"
            else P(None, ("data", "model"), None))
        assert sh["task_w"].spec == (P("model") if mode == "par" else P(None))
        assert sh["scalar"].spec == P()
        # the shardings must actually be usable for placement
        jax.device_put(batch, sh)


# ---------------------------------------------------------------------------
# train_loop + early stopping on the validation metric (satellite)
# ---------------------------------------------------------------------------

def test_train_loop_early_stops_on_validation_metric():
    """train_loop must feed the VALIDATION metric to EarlyStopping when
    eval_fn provides one (paper §5.1), not the training loss."""
    calls = []

    def fake_step(state, batch):
        # training loss keeps improving; validation plateaus immediately
        return state._replace(step=state.step + 1), StepOutput(
            loss=jnp.asarray(100.0 / (int(state.step) + 1)), metrics={})

    def eval_fn(params):
        calls.append(1)
        return {"val_loss": 1.0}

    state = TrainState(params={}, opt_state=None,
                       step=jnp.zeros((), jnp.int32))
    early = EarlyStopping(patience=3)
    _, logger, _ = train_loop(fake_step, state, lambda: {}, steps=100,
                              eval_fn=eval_fn, eval_every=1,
                              early_stop=early, val_metric="val_loss")
    # stopped by the flat val metric despite the improving train loss:
    # first row sets best, then `patience` flat rows trigger the stop
    assert len(logger.history) == early.patience + 1
    assert early.bad >= early.patience


def test_train_loop_falls_back_to_train_loss():
    def fake_step(state, batch):
        return state._replace(step=state.step + 1), StepOutput(
            loss=jnp.asarray(1.0), metrics={})

    state = TrainState(params={}, opt_state=None,
                       step=jnp.zeros((), jnp.int32))
    early = EarlyStopping(patience=2)
    _, logger, _ = train_loop(fake_step, state, lambda: {}, steps=50,
                              eval_every=1, early_stop=early)
    assert len(logger.history) == early.patience + 1


# ---------------------------------------------------------------------------
# Session end to end
# ---------------------------------------------------------------------------

def test_session_gfm_end_to_end(tmp_path):
    cfg = _gfm_cfg()
    ckpt = str(tmp_path / "s.npz")
    scfg = SessionConfig(model="gfm-mtl", arch=cfg, steps=6, batch_per_task=8,
                         lr=3e-3, log_every=2, verbose=False, ckpt_path=ckpt,
                         accum=2)
    sess = Session.from_config(scfg, sources=_gfm_sources(),
                               task_names=["a", "b", "c"])
    res = sess.run()
    assert np.isfinite(res.final_loss)
    assert int(res.state.step) == 6
    assert {"a", "b", "c"} <= set(res.logger.history[-1])
    assert res.last_metrics["per_task_loss"].shape == (3,)
    import os
    assert os.path.exists(ckpt)
    from repro.train import checkpoint
    meta = checkpoint.load_metadata(ckpt)
    assert meta["model"] == "gfm-mtl" and meta["step"] == 6


def test_session_single_task_lm():
    cfg = _lm_cfg()
    scfg = SessionConfig(model="lm", arch=cfg, steps=3, batch_per_task=4,
                         lr=1e-3, verbose=False)
    res = Session.from_config(
        scfg, sources=make_lm_sources(1, 16, 16, cfg.vocab)[0]).run()
    assert np.isfinite(res.final_loss)
    assert int(res.state.step) == 3


def test_session_early_stops_on_eval(tmp_path):
    cfg = _gfm_cfg()
    scfg = SessionConfig(model="gfm-mtl", arch=cfg, steps=200,
                         batch_per_task=8, lr=3e-3, eval_every=1,
                         patience=2, verbose=False)
    res = Session.from_config(scfg, sources=_gfm_sources(),
                              eval_fn=lambda p: {"val_loss": 1.0}).run()
    assert res.stopped_early
    assert int(res.state.step) < 200


# ---------------------------------------------------------------------------
# config-driven kernel selection (satellite)
# ---------------------------------------------------------------------------

def test_segment_sum_impl_from_config():
    """cfg.segment_sum_impl routes egnn_apply to the selected aggregation
    kernel without call-site edits; every impl agrees numerically with the
    one-hot reference."""
    from repro.models import gnn
    cfg = _gfm_cfg()
    assert cfg.segment_sum_impl == "scatter"   # scatter-add is the default
    data = generate_all(4, max_atoms=8, max_edges=24, sources=["ani1x"])
    batch = to_batch_dict(data["ani1x"], np.arange(4))
    params = gnn.egnn_init(jax.random.PRNGKey(0), cfg)
    h_ref = gnn.egnn_apply(params, batch, cfg=cfg, impl="jnp")
    for impl in ("scatter", "pallas", "fused"):
        h = gnn.egnn_apply(params, batch,
                           cfg=cfg.replace(segment_sum_impl=impl))
        np.testing.assert_allclose(np.asarray(h), np.asarray(h_ref),
                                   atol=2e-5, rtol=2e-5, err_msg=impl)

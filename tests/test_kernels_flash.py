"""Pallas flash-attention kernel vs pure-jnp oracle: shape/dtype sweeps."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_ref


def _mk(B, S, H, K, D, dtype, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (B, S, H, D), dtype)
    k = jax.random.normal(ks[1], (B, S, K, D), dtype)
    v = jax.random.normal(ks[2], (B, S, K, D), dtype)
    return q, k, v


def _ref(q, k, v, pos, **kw):
    o = attention_ref(q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
                      v.transpose(0, 2, 1, 3), pos, pos, **kw)
    return o.transpose(0, 2, 1, 3)


@pytest.mark.parametrize("B,S,H,K,D", [
    (1, 128, 4, 4, 64),      # MHA, block-aligned
    (2, 200, 8, 2, 64),      # GQA, ragged seq (padding path)
    (1, 96, 6, 3, 128),      # odd head group
    (2, 256, 4, 1, 32),      # MQA
])
@pytest.mark.parametrize("window", [0, 37])
def test_flash_matches_ref(B, S, H, K, D, window):
    q, k, v = _mk(B, S, H, K, D, jnp.float32)
    pos = jnp.arange(S)
    o = flash_attention(q, k, v, q_pos=pos, k_pos=pos, causal=True,
                        window=window, block_q=64, block_k=64)
    r = _ref(q, k, v, pos, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(o), np.asarray(r), atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("dtype,atol", [(jnp.float32, 2e-5), (jnp.bfloat16, 3e-2)])
def test_flash_dtypes(dtype, atol):
    q, k, v = _mk(1, 160, 4, 2, 64, dtype)
    pos = jnp.arange(160)
    o = flash_attention(q, k, v, q_pos=pos, k_pos=pos, causal=True)
    r = _ref(q, k, v, pos, causal=True)
    np.testing.assert_allclose(np.asarray(o, np.float32), np.asarray(r, np.float32),
                               atol=atol, rtol=atol)


def test_flash_noncausal():
    q, k, v = _mk(1, 128, 4, 4, 64, jnp.float32)
    pos = jnp.arange(128)
    o = flash_attention(q, k, v, q_pos=pos, k_pos=pos, causal=False)
    r = _ref(q, k, v, pos, causal=False)
    np.testing.assert_allclose(np.asarray(o), np.asarray(r), atol=2e-5, rtol=2e-5)


def test_flash_rolling_cache_positions():
    """Rolling-window cache layout: non-monotonic k positions mask right."""
    B, S, H, D, W = 1, 64, 2, 32, 32
    q, k, v = _mk(B, S, H, H, D, jnp.float32)
    # emulate a rolling cache: absolute positions shuffled by wraparound
    k_pos = jnp.concatenate([jnp.arange(32, 64), jnp.arange(0, 32)])
    kk = jnp.concatenate([k[:, 32:], k[:, :32]], axis=1)
    vv = jnp.concatenate([v[:, 32:], v[:, :32]], axis=1)
    q_pos = jnp.arange(S)
    o = flash_attention(q, kk, vv, q_pos=q_pos, k_pos=k_pos, causal=True,
                        window=W, block_q=32, block_k=32)
    r = _ref(q, k, v, jnp.arange(S), causal=True, window=W)
    np.testing.assert_allclose(np.asarray(o), np.asarray(r), atol=2e-5, rtol=2e-5)

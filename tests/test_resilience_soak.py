"""The ISSUE-7 soak: a run hit by every fault class (NaN gradients, corrupt
batch, producer kill, checkpoint-write failure, simulated preemption) must
finish — across a rollback, an in-place pipeline recovery, retried IO and a
preempt/resume cycle — with final params BITWISE-IDENTICAL to a run that
was never faulted.

Why bitwise identity is the right bar: rollback restores params + optimizer
moments + guard EMA + the byte-identical datapipe position together, the
replayed compute is deterministic on CPU, and the npz roundtrip is
bit-exact for f32 — so any single bit of drift means some piece of state
escaped the recovery path."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ArchConfig
from repro.data.synthetic_atoms import generate_all
from repro.engine import Session, SessionConfig
from repro.resilience import (
    CheckpointPolicy,
    Fault,
    FaultSchedule,
    GuardConfig,
    ResilienceConfig,
)

CFG = ArchConfig(name="g", family="gnn", gnn_hidden=16, gnn_layers=2,
                 n_species=64, head_hidden=8, head_layers=2,
                 remat=False, compute_dtype=jnp.float32)
STEPS = 14


def _sources():
    data = generate_all(18, max_atoms=8, max_edges=24,
                        sources=["ani1x", "qm7x", "mptrj"])
    return [dict(species=s.species, pos=s.pos, edge_src=s.edge_src,
                 edge_dst=s.edge_dst, node_mask=s.node_mask,
                 edge_mask=s.edge_mask, energy=s.energy, forces=s.forces)
            for s in data.values()]


def _res(ckpt_dir, faults=None, **guard_kw):
    gk = dict(warmup_steps=3, spike_factor=50.0, max_consecutive_trips=1)
    gk.update(guard_kw)
    return ResilienceConfig(
        ckpt_dir=str(ckpt_dir),
        guard=GuardConfig(**gk),
        policy=CheckpointPolicy(every_steps=5, keep_last=2),
        faults=faults, retry_base_delay=0.0)


def _cfg(res):
    return SessionConfig(model="gfm-mtl", arch=CFG, steps=STEPS,
                         batch_per_task=6, eval_every=100, log_every=100,
                         verbose=False, resilience=res)


def _run(res, resume=False):
    sess = Session.from_config(_cfg(res), sources=_sources())
    try:
        if resume:
            sess.resume()
        return sess.run()
    finally:
        sess.close()


def _params_equal(a, b) -> bool:
    return all(np.array_equal(np.asarray(x), np.asarray(y))
               for x, y in zip(jax.tree_util.tree_leaves(a.state.params),
                               jax.tree_util.tree_leaves(b.state.params)))


def test_soak_five_fault_classes_bitwise_identical_finish(tmp_path):
    """One run, all five fault classes, ticks chosen so every recovery path
    fires: NaN rollback, spike rollback, pipeline recovery, IO retry, and a
    preemption flush + resume. Final params must match the clean run bit
    for bit."""
    faults = FaultSchedule([
        Fault(tick=5, kind="nan_grad"),
        Fault(tick=9, kind="corrupt_batch", magnitude=1e6),
        Fault(tick=12, kind="kill_producer"),
        Fault(tick=15, kind="ckpt_write_fail"),
        Fault(tick=18, kind="preempt"),
    ])
    assert len({f.kind for fs in faults._by_tick.values()
                for f in fs}) == 5

    faulted = _run(_res(tmp_path / "faulted", faults))
    assert faulted.preempted
    rep = faulted.resilience
    assert rep["faults_fired"] == 5 and rep["faults_pending"] == 0
    assert rep["rollbacks"] >= 2            # nan + spike both rolled back
    assert rep["pipeline_recoveries"] >= 1  # producer kill recovered
    assert rep["io_retries"] >= 1           # ckpt write retried
    kinds = {e["kind"] for e in rep["events"]}
    assert {"rollback", "pipeline_recovery", "preempt_flush"} <= kinds

    resumed = _run(_res(tmp_path / "faulted"), resume=True)
    assert not resumed.preempted
    assert int(resumed.state.step) == STEPS

    clean = _run(_res(tmp_path / "clean"))
    assert clean.resilience["trips"] == 0
    assert int(clean.state.step) == STEPS
    assert _params_equal(resumed, clean)


def test_nan_at_step_k_rolls_back_and_matches_unfaulted(tmp_path):
    """ISSUE-7 satellite: NaN gradient injected at a known step -> guard
    trips -> rollback restores params AND the datapipe byte-identically ->
    final params match the unfaulted run bitwise."""
    k = 7
    faulted = _run(_res(tmp_path / "f",
                        FaultSchedule([Fault(tick=k, kind="nan_grad")])))
    rep = faulted.resilience
    assert rep["trips"] == 1 and rep["rollbacks"] == 1
    [rb] = [e for e in rep["events"] if e["kind"] == "rollback"]
    assert rb["tick"] == k and rb["to_step"] == 5   # last policy save
    assert int(faulted.state.step) == STEPS

    clean = _run(_res(tmp_path / "c"))
    assert _params_equal(faulted, clean)


def test_rollback_without_prefetch_is_also_bitwise(tmp_path):
    """The synchronous (prefetch=False) path shares the rollback contract —
    datapipe restore goes straight to the batcher."""
    faults = FaultSchedule([Fault(tick=6, kind="nan_grad")])

    def run(res):
        sess = Session.from_config(
            _cfg(res).replace(prefetch=False), sources=_sources())
        try:
            return sess.run()
        finally:
            sess.close()

    faulted = run(_res(tmp_path / "f", faults))
    clean = run(_res(tmp_path / "c"))
    assert faulted.resilience["rollbacks"] == 1
    assert _params_equal(faulted, clean)


def test_persistent_bad_source_gets_quarantined_and_run_survives(tmp_path):
    """A source that keeps emitting NaNs is quarantined (loss weight zeroed
    + batch slice sanitized) instead of killing the run: the run completes
    its full schedule with a finite loss even though the source's faults
    keep firing after quarantine."""
    faults = FaultSchedule([Fault(tick=t, kind="nan_grad", source=1)
                            for t in (4, 6, 8)])
    out = _run(_res(tmp_path / "q", faults, quarantine_after=2))
    rep = out.resilience
    assert 1 in rep["quarantined"]
    assert rep["source_trips"][1] >= 2
    assert int(out.state.step) == STEPS
    assert np.isfinite(out.final_loss)


def test_quarantine_zeroes_loss_weight_and_keeps_guard_quiet(tmp_path):
    """After quarantine the session's task weights reflect it, and faults
    from the quarantined source that keep firing no longer reach the
    parameters: the run finishes its schedule despite a post-quarantine
    NaN fault (sanitized slice -> finite loss and gradients)."""
    faults = FaultSchedule([Fault(tick=t, kind="nan_grad", source=2)
                            for t in (4, 6)])
    sess = Session.from_config(
        _cfg(_res(tmp_path / "q", faults, quarantine_after=2)),
        sources=_sources())
    try:
        out = sess.run()
        assert 2 in out.resilience["quarantined"]
        assert sess.task_weights[2] == 0.0
        assert sess._quarantined == {2}
        assert int(out.state.step) == STEPS
    finally:
        sess.close()


def test_preempt_flush_writes_resumable_checkpoint(tmp_path):
    """A preemption mid-run flushes a checkpoint at the CURRENT step with
    the datapipe sidecar, and resume() picks it up exactly."""
    res = _res(tmp_path / "p",
               FaultSchedule([Fault(tick=8, kind="preempt")]))
    out = _run(res)
    assert out.preempted and int(out.state.step) == 7   # 7 steps before tick 8
    d = str(tmp_path / "p")
    names = sorted(f for f in os.listdir(d) if f.endswith(".npz"))
    assert f"ckpt-{7:08d}.npz" in names
    sess = Session.from_config(_cfg(res.replace(faults=None)),
                               sources=_sources())
    try:
        assert sess.resume() == 7
    finally:
        sess.close()


def test_unrecoverable_ckpt_failure_raises_cleanly(tmp_path):
    """ckpt_write_fail with repeats >= the retry budget is a FATAL fault:
    the run raises RetryError instead of silently skipping the save."""
    from repro.resilience import RetryError
    res = _res(tmp_path / "x",
               FaultSchedule([Fault(tick=1, kind="ckpt_write_fail",
                                    repeats=10)]))
    res = res.replace(retry_attempts=2,
                      policy=CheckpointPolicy(every_steps=2, keep_last=2))
    with pytest.raises(RetryError):
        _run(res)

"""GroupBatcher epoch semantics — the DDStore contract the task-sharded
train step relies on: row t of every batch is drawn only from source t,
per-source shuffled cyclic iteration with independent wraparound
reshuffling, and full determinism under a fixed seed."""
import numpy as np

from repro.data.loader import GroupBatcher, SingleBatcher


def _sources(sizes, feature_offset=1000):
    """Source t has samples whose value encodes (t, sample index)."""
    return [{"x": (feature_offset * t + np.arange(n)).astype(np.int64),
             "y": np.full((n, 2), t, np.int64)} for t, n in enumerate(sizes)]


def test_rows_come_only_from_their_source():
    gb = GroupBatcher(_sources([10, 7, 13]), batch_per_task=4, seed=0)
    for _ in range(20):
        b = gb.next_batch()
        assert b["x"].shape == (3, 4)
        for t in range(3):
            vals = np.asarray(b["x"][t])
            assert ((vals >= 1000 * t) & (vals < 1000 * t + 100)).all(), \
                f"row {t} leaked samples from another source"
            assert (np.asarray(b["y"][t]) == t).all()


def test_deterministic_under_fixed_seed():
    a = GroupBatcher(_sources([9, 5]), 4, seed=123)
    b = GroupBatcher(_sources([9, 5]), 4, seed=123)
    for _ in range(10):
        ba, bb = a.next_batch(), b.next_batch()
        np.testing.assert_array_equal(np.asarray(ba["x"]), np.asarray(bb["x"]))
    d = GroupBatcher(_sources([9, 5]), 4, seed=123)
    c = GroupBatcher(_sources([9, 5]), 4, seed=124)
    stream_d = np.concatenate([np.asarray(d.next_batch()["x"][0])
                               for _ in range(3)])
    stream_c = np.concatenate([np.asarray(c.next_batch()["x"][0])
                               for _ in range(3)])
    assert not np.array_equal(stream_d, stream_c), "seed has no effect"


def test_epoch_wraparound_reshuffles():
    """Each consecutive n-sample block of the per-source stream is a full
    permutation of the source (no repeats within an epoch, every sample
    visited), and successive epochs use different orders."""
    n = 16
    gb = GroupBatcher(_sources([n]), batch_per_task=4, seed=7)
    stream = np.concatenate(
        [np.asarray(gb.next_batch()["x"][0]) for _ in range(3 * n // 4)])
    epochs = stream.reshape(3, n)
    for e in range(3):
        assert sorted(epochs[e]) == list(range(n)), \
            f"epoch {e} is not a permutation of the source"
    assert not np.array_equal(epochs[0], epochs[1]), \
        "wraparound did not reshuffle"


def test_uneven_sources_wrap_independently():
    """Sources of different sizes wrap independently (paper weak-scaling:
    all heads stay busy every step) — batch shape never changes."""
    sizes = [6, 17]
    gb = GroupBatcher(_sources(sizes), batch_per_task=5, seed=3)
    counts = [np.zeros(n, np.int64) for n in sizes]
    for _ in range(12):
        b = gb.next_batch()
        assert b["x"].shape == (2, 5)
        for t, n in enumerate(sizes):
            counts[t][np.asarray(b["x"][t]) - 1000 * t] += 1
    # 60 draws: the small source completed 10 epochs, the big one 3.5 —
    # cyclic iteration keeps per-sample counts within 1 of each other
    for t in range(2):
        assert counts[t].max() - counts[t].min() <= 1, \
            f"source {t} not cyclic: {counts[t]}"


def test_single_batcher_shapes_and_determinism():
    src = {"x": np.arange(20), "y": np.zeros((20, 3))}
    a = SingleBatcher(src, 8, seed=1)
    b = SingleBatcher(src, 8, seed=1)
    ba, bb = a.next_batch(), b.next_batch()
    assert ba["x"].shape == (8,) and ba["y"].shape == (8, 3)
    np.testing.assert_array_equal(np.asarray(ba["x"]), np.asarray(bb["x"]))

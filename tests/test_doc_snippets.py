"""Docs stay true: every ``python`` fence in docs/*.md and README.md is
extracted and EXECUTED. A snippet that drifts from the real API fails CI
(the non-gating ``docs`` job gives docs-only changes a dedicated signal;
the tier-1 gate runs this file too).

Conventions for doc authors:
  * ``` ```python ``` fences must be self-contained, fast (CI-sized
    shapes), and runnable with PYTHONPATH=src on a CPU-only host;
  * use ``` ```text ``` (or plain ``` ``` ```) for schematics, shell
    commands, and pseudo-code — only ``python`` fences are executed;
  * snippets run in a temp cwd, so relative paths they write are scratch.
"""
import pathlib
import re

import pytest

REPO = pathlib.Path(__file__).resolve().parents[1]
FENCE = re.compile(r"```python\n(.*?)```", re.DOTALL)


def _doc_files():
    files = sorted((REPO / "docs").glob("*.md")) if (REPO / "docs").is_dir() \
        else []
    readme = REPO / "README.md"
    if readme.exists():
        files.append(readme)
    return files


def _snippets():
    params = []
    for f in _doc_files():
        for i, code in enumerate(FENCE.findall(f.read_text())):
            params.append(pytest.param(
                code, id=f"{f.relative_to(REPO)}[{i}]"))
    return params


SNIPPETS = _snippets()


def test_docs_exist_and_have_executable_snippets():
    names = {f.name for f in _doc_files()}
    assert {"architecture.md", "kernels.md", "data.md", "benchmarks.md",
            "migration.md", "static_analysis.md", "parallelism.md",
            "README.md"} <= names, names
    assert len(SNIPPETS) >= 6, "docs lost their executable examples"


@pytest.mark.parametrize("code", SNIPPETS)
def test_doc_snippet_executes(code, tmp_path, monkeypatch):
    monkeypatch.chdir(tmp_path)      # file-writing snippets land in scratch
    exec(compile(code, "<doc-snippet>", "exec"), {"__name__": "__main__"})

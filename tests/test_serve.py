"""Serving correctness: prefill+decode must reproduce teacher-forced logits."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ArchConfig
from repro.models import transformer
from repro.train.serve import extend_caches, greedy_generate


def _cfg(**kw):
    base = dict(name="t", n_layers=3, d_model=64, n_heads=4, n_kv_heads=2,
                d_ff=128, vocab=97, head_dim=16, remat=False,
                compute_dtype=jnp.float32)
    base.update(kw)
    return ArchConfig(**base)


@pytest.mark.parametrize("kw", [
    {},                                                    # full attention
    {"block_pattern": ("swa",), "window": 8},              # sliding window
    {"block_pattern": ("mla",), "kv_lora": 24, "q_lora": 32,
     "rope_dims": 8, "head_dim": 16, "v_head_dim": 16, "n_kv_heads": 4},
    {"block_pattern": ("mamba2", "attn"), "ssm_state": 8, "ssm_heads": 4,
     "ssm_chunk": 8, "n_kv_heads": 4},
    {"block_pattern": ("mlstm", "slstm"), "d_ff": 0, "n_kv_heads": 4,
     "n_layers": 2},
])
def test_decode_matches_teacher_forcing(kw):
    cfg = _cfg(**kw)
    key = jax.random.PRNGKey(0)
    params = transformer.lm_init(key, cfg)
    S, T = 16, 5
    toks = jax.random.randint(key, (2, S + T), 0, cfg.vocab)

    # teacher-forced full forward
    full_logits, _, _ = transformer.lm_apply(params, toks, cfg=cfg)

    # prefill on S, then decode T steps feeding the TRUE next token
    logits, caches = transformer.lm_apply(params, toks[:, :S], cfg=cfg,
                                          mode="prefill")[:2]
    caches = extend_caches(caches, cfg, S + T)
    np.testing.assert_allclose(np.asarray(logits[:, -1]),
                               np.asarray(full_logits[:, S - 1]),
                               atol=2e-4, rtol=2e-3)
    for t in range(T):
        tok = toks[:, S + t: S + t + 1]
        logits, caches, _ = transformer.lm_apply(
            params, tok, cfg=cfg, mode="decode", caches=caches,
            positions=jnp.array([S + t]))
        np.testing.assert_allclose(np.asarray(logits[:, 0]),
                                   np.asarray(full_logits[:, S + t]),
                                   atol=2e-4, rtol=2e-3,
                                   err_msg=f"decode step {t}")


def test_rolling_window_cache_decode():
    """SWA decode with a cache SMALLER than the generated length: the rolling
    cache must still match teacher forcing (window-bounded attention)."""
    cfg = _cfg(block_pattern=("swa",), window=8)
    key = jax.random.PRNGKey(1)
    params = transformer.lm_init(key, cfg)
    S = 24
    toks = jax.random.randint(key, (1, S), 0, cfg.vocab)
    full_logits, _, _ = transformer.lm_apply(params, toks, cfg=cfg)

    caches = transformer.lm_cache_init(params, cfg, 1, cfg.window)
    for t in range(S):
        logits, caches, _ = transformer.lm_apply(
            params, toks[:, t: t + 1], cfg=cfg, mode="decode", caches=caches,
            positions=jnp.array([t]))
        np.testing.assert_allclose(np.asarray(logits[:, 0]),
                                   np.asarray(full_logits[:, t]),
                                   atol=2e-4, rtol=2e-3, err_msg=f"t={t}")


def test_greedy_generate_runs():
    cfg = _cfg()
    params = transformer.lm_init(jax.random.PRNGKey(2), cfg)
    prompt = jax.random.randint(jax.random.PRNGKey(3), (2, 8), 0, cfg.vocab)
    out = greedy_generate(params, cfg, prompt, 6)
    assert out.shape == (2, 6)
    assert bool((out >= 0).all()) and bool((out < cfg.padded_vocab).all())


def test_greedy_generate_single_token_skips_decode(monkeypatch):
    """n_new=1 is answered entirely from the prefill logits: shape (B, 1)
    and the decode step is never invoked."""
    from repro.train import serve

    cfg = _cfg()
    params = transformer.lm_init(jax.random.PRNGKey(4), cfg)
    prompt = jax.random.randint(jax.random.PRNGKey(5), (3, 8), 0, cfg.vocab)

    def forbidden_decode_step(cfg, impl="chunked", task=None):
        def decode(*a, **kw):
            raise AssertionError("decode loop entered for n_new=1")
        return decode

    monkeypatch.setattr(serve, "make_decode_step", forbidden_decode_step)
    out = serve.greedy_generate(params, cfg, prompt, 1)
    assert out.shape == (3, 1)

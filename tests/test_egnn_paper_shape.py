"""Paper-shape parity suite for the H-blocked fused EGNN kernels.

The paper's HydraGNN trunk runs hidden width H=866; the fused egnn_edge
forward/backward kernels only fit that width because a ``block_h`` grid
dimension bounds VMEM residency by ``block_h·H`` (see
``repro.kernels.egnn_edge.budget``). This file is what makes the
paper-shape claim honest: fwd + grad parity against the pure-jnp reference
at the TRUE paper shape (B=4, E=768, A=128, H=866), fp32 at 1e-5 and bf16
relaxed, with masked AND sentinel-padded edges, plus ragged
``E % block_e != 0`` / ``H % block_h != 0`` tiling.

The H=866 tests carry the ``paper_shape`` marker (registered in pytest.ini,
deselected from the default run so tier-1 stays quick; the non-gating CI
``paper-shape`` job runs ``pytest -m paper_shape``). A small-H variant of
the same checks runs un-marked on every tier-1 pass.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ArchConfig
from repro.kernels.egnn_edge import ops as edge_ops
from repro.kernels.egnn_edge.budget import VMEM_BUDGET, plan_blocks, vmem_bytes
from repro.kernels.egnn_edge.ref import egnn_edge_agg_ref
from repro.models import gnn
from repro.models.mlp import mlp_init

PAPER = dict(B=4, E=768, A=128, H=866)     # the HydraGNN GFM trunk shape


def _case(B, E, A, H, dtype=jnp.float32, seed=0):
    """Kernel inputs with masked AND sentinel-padded (dst == A) edges plus
    a fixed cotangent probe for grad parity."""
    ks = jax.random.split(jax.random.PRNGKey(seed), 7)
    h = jax.random.normal(ks[0], (B, A, H), dtype)
    pos = jax.random.normal(ks[1], (B, A, 3), jnp.float32) * 2.0
    src = jax.random.randint(ks[2], (B, E), 0, A)
    dst = jax.random.randint(ks[3], (B, E), 0, A + 1)      # A = pad sentinel
    em = jax.random.bernoulli(ks[4], 0.85, (B, E)) & (dst < A)
    phi_e = mlp_init(ks[5], 2 * H + 1, H, H, 1, jnp.float32)
    gw = jax.random.normal(ks[6], (B, A, H), jnp.float32)  # cotangent probe
    return h, pos, src, dst, em, phi_e, gw


def _assert_close_scaled(got, ref, tol, name=""):
    got = np.asarray(got, np.float32)
    ref = np.asarray(ref, np.float32)
    scale = max(1.0, float(np.abs(ref).max()))
    np.testing.assert_allclose(got, ref, atol=tol * scale, rtol=tol,
                               err_msg=name)


def _check_fwd_and_grads(B, E, A, H, dtype, tol, *, block_e=None,
                         block_h=None, seed=0):
    """Shared harness: fused fwd + all cotangents vs the jnp oracle."""
    h, pos, src, dst, em, phi_e, gw = _case(B, E, A, H, dtype, seed)
    kw = dict(compute_dtype=dtype, block_e=block_e, block_h=block_h)

    out = edge_ops.egnn_edge_agg(h, pos, src, dst, em, phi_e, **kw)
    ref = egnn_edge_agg_ref(h, pos, src, dst, em, phi_e, compute_dtype=dtype)
    assert out.dtype == ref.dtype
    _assert_close_scaled(out, ref, tol, "forward")

    def loss(fn, hh, pp, ww, **lkw):
        o = fn(hh, pp, src, dst, em, ww, **lkw)
        return jnp.sum(o.astype(jnp.float32) * gw)

    g_fused = jax.grad(lambda *a: loss(edge_ops.egnn_edge_agg, *a, **kw),
                       argnums=(0, 1, 2))(h, pos, phi_e)
    g_ref = jax.grad(lambda *a: loss(egnn_edge_agg_ref, *a,
                                     compute_dtype=dtype),
                     argnums=(0, 1, 2))(h, pos, phi_e)
    for name, a, b in zip(("d_h", "d_pos", "d_phi_e"), g_fused, g_ref):
        jax.tree_util.tree_map(
            lambda x, y, n=name: _assert_close_scaled(x, y, tol, n), a, b)
        jax.tree_util.tree_map(
            lambda x, y: (x.dtype == y.dtype) or pytest.fail(
                f"cotangent dtype {x.dtype} != primal-grad {y.dtype}"), a, b)


# ---------------------------------------------------------------------------
# the true paper shape, H=866 (marked: non-gating CI job, not tier-1)
# ---------------------------------------------------------------------------

@pytest.mark.paper_shape
@pytest.mark.parametrize("dtype,tol", [
    (jnp.float32, 1e-5),       # acceptance: fp32 atol ≲ 1e-5
    (jnp.bfloat16, 4e-2),      # relaxed: bf16 forward-recompute rounding
])
def test_paper_width_fwd_and_grad_parity(dtype, tol):
    """H=866 fwd + every cotangent vs the jnp reference, with the
    (block_e, block_h) the VMEM budget model plans."""
    _check_fwd_and_grads(**PAPER, dtype=dtype, tol=tol)


@pytest.mark.paper_shape
def test_paper_width_planned_blocks_within_budget():
    """The blocks the H=866 run above actually used are provably within
    the documented VMEM budget — an H-block smaller than H (the whole
    point of the grid split)."""
    A, E, H = PAPER["A"], PAPER["E"], PAPER["H"]
    be, bh = plan_blocks(A, E, H)
    assert bh < H, f"paper width must be H-split, planned block_h={bh}"
    assert vmem_bytes(A, be, bh, H) <= VMEM_BUDGET


@pytest.mark.paper_shape
def test_paper_width_ragged_blocks():
    """Explicit block sizes that divide NEITHER E (768 % 160 != 0) nor H
    (866 % 100 != 0): the sentinel edge padding and the zero weight-column
    padding must contribute exactly nothing."""
    _check_fwd_and_grads(**PAPER, dtype=jnp.float32, tol=1e-5,
                         block_e=160, block_h=100)


@pytest.mark.paper_shape
def test_paper_width_through_egnn_apply():
    """The whole fused layer path at paper width through egnn_apply with
    the config-driven kernel_block_h knob."""
    cfg = ArchConfig(name="paper", family="gnn", gnn_hidden=PAPER["H"],
                     gnn_layers=1, n_species=64, max_atoms=PAPER["A"],
                     max_edges=PAPER["E"], remat=False,
                     compute_dtype=jnp.float32, segment_sum_impl="fused",
                     kernel_block_h=128)
    h, pos, src, dst, em, phi_e, _ = _case(**PAPER)
    batch = dict(species=jnp.ones((PAPER["B"], PAPER["A"]), jnp.int32),
                 pos=pos, edge_src=src, edge_dst=dst,
                 node_mask=jnp.ones((PAPER["B"], PAPER["A"]), bool),
                 edge_mask=em)
    params = gnn.egnn_init(jax.random.PRNGKey(0), cfg)
    got = gnn.egnn_apply(params, batch, cfg=cfg)           # fused via cfg
    ref = gnn.egnn_apply(params, batch, cfg=cfg, impl="jnp")
    _assert_close_scaled(got, ref, 1e-5, "egnn_apply fused@H=866")


# ---------------------------------------------------------------------------
# small-H fast variant — identical checks, runs un-marked on every tier-1
# pass (ragged E and H blocks, sentinel pads, fp32 + bf16)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype,tol", [
    (jnp.float32, 1e-5),
    (jnp.bfloat16, 4e-2),
])
def test_small_h_fwd_and_grad_parity_ragged_blocks(dtype, tol):
    """The same harness at tier-1 speed: H=96 with block_h=40 (ragged),
    E=100 with block_e=48 (ragged), masked + sentinel-padded edges."""
    _check_fwd_and_grads(B=2, E=100, A=16, H=96, dtype=dtype, tol=tol,
                         block_e=48, block_h=40)


def test_small_h_block_h_invariance():
    """block_h is a tiling knob, not a numeric one: every split of H gives
    the same fwd output and the same d_h cotangent (fp32, tight tol)."""
    h, pos, src, dst, em, phi_e, gw = _case(B=2, E=64, A=12, H=48)

    def run(block_h):
        def f(hh):
            o = edge_ops.egnn_edge_agg(hh, pos, src, dst, em, phi_e,
                                       block_h=block_h)
            return jnp.sum(o * gw)
        return jax.value_and_grad(f)(h)

    v_ref, g_ref = run(48)                      # whole-H (single block)
    for bh in (7, 16, 48, 64):                  # ragged, even, oversized
        v, g = run(bh)
        np.testing.assert_allclose(float(v), float(v_ref), rtol=1e-6)
        np.testing.assert_allclose(np.asarray(g), np.asarray(g_ref),
                                   atol=1e-6, rtol=1e-6,
                                   err_msg=f"block_h={bh}")


def test_small_h_kernel_block_h_knob_threads_through():
    """cfg.kernel_block_h reaches the fused kernels through egnn_apply —
    forward and gradients — without changing numerics."""
    cfg = ArchConfig(name="g", family="gnn", gnn_hidden=24, gnn_layers=2,
                     n_species=64, head_hidden=12, head_layers=2,
                     max_atoms=10, max_edges=40, remat=False,
                     compute_dtype=jnp.float32)
    from repro.data.synthetic_atoms import generate_all, to_batch_dict
    data = generate_all(4, max_atoms=10, max_edges=40, sources=["ani1x"])
    batch = to_batch_dict(data["ani1x"], np.arange(4))
    params = gnn.egnn_init(jax.random.PRNGKey(1), cfg)
    tuned = cfg.replace(kernel_block_h=8)
    ref = gnn.egnn_apply(params, batch, cfg=cfg, impl="jnp")
    got = gnn.egnn_apply(params, batch, cfg=tuned, impl="fused")
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               atol=2e-5, rtol=2e-5)

    def loss(p, c):
        return jnp.mean(gnn.egnn_apply(p, batch, cfg=c, impl="fused") ** 2)
    g_t = jax.grad(lambda p: loss(p, tuned))(params)
    g_d = jax.grad(lambda p: loss(p, cfg))(params)
    jax.tree_util.tree_map(
        lambda a, b: np.testing.assert_allclose(
            np.asarray(a), np.asarray(b), atol=1e-5, rtol=1e-4), g_t, g_d)

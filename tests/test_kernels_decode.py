"""Flash-decode Pallas kernel (split-KV partial-softmax) vs oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_decode.ops import flash_decode
from repro.kernels.flash_decode.ref import decode_ref


def _mk(B, S, H, K, D, dtype=jnp.float32, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (B, 1, H, D), dtype)
    k = jax.random.normal(ks[1], (B, S, K, D), dtype)
    v = jax.random.normal(ks[2], (B, S, K, D), dtype)
    return q, k, v


@pytest.mark.parametrize("B,S,H,K,D", [
    (1, 256, 4, 4, 64), (2, 300, 8, 2, 64), (1, 100, 6, 1, 32),
])
@pytest.mark.parametrize("n_splits,bk", [(1, 128), (4, 64), (8, 32)])
def test_flash_decode_matches_ref(B, S, H, K, D, n_splits, bk):
    q, k, v = _mk(B, S, H, K, D)
    kp = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    qp = jnp.full((B,), S - 1)
    o = flash_decode(q, k, v, q_pos=qp, k_pos=kp, n_splits=n_splits, block_k=bk)
    r = decode_ref(q, k, v, q_pos=qp, k_pos=kp)
    np.testing.assert_allclose(np.asarray(o), np.asarray(r), atol=2e-5,
                               rtol=2e-5)


def test_flash_decode_partial_cache_and_window():
    """Mid-generation: only pos<=q_pos valid; sliding window bounds reach."""
    B, S, H, K, D = 2, 192, 4, 2, 32
    q, k, v = _mk(B, S, H, K, D, seed=3)
    kp = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    for qpos, win in [(40, 0), (S - 1, 31), (5, 16)]:
        qp = jnp.full((B,), qpos)
        o = flash_decode(q, k, v, q_pos=qp, k_pos=kp, window=win,
                         n_splits=4, block_k=32)
        r = decode_ref(q, k, v, q_pos=qp, k_pos=kp, window=win)
        np.testing.assert_allclose(np.asarray(o), np.asarray(r), atol=2e-5,
                                   rtol=2e-5, err_msg=f"pos={qpos} win={win}")


def test_flash_decode_rolling_cache_layout():
    """Rolling-window cache: slots hold non-monotonic absolute positions."""
    B, S, H, K, D = 1, 64, 2, 2, 32
    q, k, v = _mk(B, S, H, K, D, seed=5)
    # rotate the cache by 20 slots, positions travel with the data
    kp = jnp.broadcast_to(jnp.roll(jnp.arange(S), 20)[None], (B, S))
    kk = jnp.roll(k, 20, axis=1)
    vv = jnp.roll(v, 20, axis=1)
    qp = jnp.full((B,), S - 1)
    o = flash_decode(q, kk, vv, q_pos=qp, k_pos=kp, n_splits=2, block_k=32)
    r = decode_ref(q, k, v, q_pos=qp,
                   k_pos=jnp.broadcast_to(jnp.arange(S)[None], (B, S)))
    np.testing.assert_allclose(np.asarray(o), np.asarray(r), atol=2e-5,
                               rtol=2e-5)


def test_flash_decode_bf16():
    q, k, v = _mk(1, 128, 4, 2, 64, jnp.bfloat16, seed=7)
    kp = jnp.broadcast_to(jnp.arange(128)[None], (1, 128))
    qp = jnp.full((1,), 127)
    o = flash_decode(q, k, v, q_pos=qp, k_pos=kp, n_splits=4, block_k=32)
    r = decode_ref(q, k, v, q_pos=qp, k_pos=kp)
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(r, np.float32), atol=3e-2, rtol=3e-2)
"""Property tests for the imbalance-aware head-placement solver
(repro.core.balancing.solve_placement) and the HeadPlacement vocabulary.

Seeded sweeps over (devices, heads, mix weights) pin the solver contract:
every head placed exactly once, group device counts partition the mesh,
determinism for a fixed seed, and modeled max-group load never worse than
round-robin's. The paper's 5-source mix on 8 devices is pinned exactly —
it is the configuration the bench sweep and parity suite run.
"""
import numpy as np
import pytest

from repro.core import HeadPlacement, round_robin_placement, solve_placement
from repro.data.synthetic_atoms import PAPER_REL_SIZES


def _sweep_cases():
    rng = np.random.default_rng(1234)
    cases = []
    for n_dev in (1, 2, 3, 5, 8, 13, 16):
        for n_heads in (1, 2, 3, 5, 8, 11):
            w = rng.gamma(shape=1.0, scale=1.0, size=n_heads) + 1e-3
            cases.append(pytest.param(n_dev, n_heads, tuple(w),
                                      id=f"d{n_dev}h{n_heads}"))
    return cases


SWEEP = _sweep_cases()


@pytest.mark.parametrize("n_dev,n_heads,w", SWEEP)
def test_every_head_placed_exactly_once(n_dev, n_heads, w):
    p = solve_placement(n_dev, w)
    flat = sorted(h for g in p.groups for h in g)
    assert flat == list(range(n_heads))
    assert all(len(g) >= 1 for g in p.groups)


@pytest.mark.parametrize("n_dev,n_heads,w", SWEEP)
def test_group_sizes_partition_the_mesh(n_dev, n_heads, w):
    p = solve_placement(n_dev, w)
    assert sum(p.device_counts) == n_dev
    assert all(c >= 1 for c in p.device_counts)
    assert p.n_devices == n_dev and p.n_heads == n_heads


@pytest.mark.parametrize("n_dev,n_heads,w", SWEEP)
def test_deterministic_for_fixed_seed(n_dev, n_heads, w):
    a = solve_placement(n_dev, w, seed=7)
    b = solve_placement(n_dev, w, seed=7)
    assert a == b


@pytest.mark.parametrize("n_dev,n_heads,w", SWEEP)
def test_never_worse_than_round_robin(n_dev, n_heads, w):
    wn = tuple(float(x) / sum(w) for x in w)
    p = solve_placement(n_dev, w)
    rr = round_robin_placement(n_heads, n_dev)
    assert p.max_group_load() <= rr.max_group_load(wn) + 1e-12


def test_paper_mix_on_8_devices_pinned():
    """The bench-sweep configuration: 5 paper-proportioned sources on 8
    host devices. The solver gives transition1x (the heaviest source) 3
    devices and STRICTLY beats round-robin's even split."""
    mix = list(PAPER_REL_SIZES.values())
    p = solve_placement(8, mix)
    rr = round_robin_placement(5, 8)
    assert p.groups == ((0,), (1,), (2,), (3,), (4,))
    assert p.device_counts == (2, 1, 3, 1, 1)
    assert p.max_group_load() < rr.max_group_load(p.loads)
    np.testing.assert_allclose(p.max_group_load(), 0.17872, atol=1e-4)
    np.testing.assert_allclose(rr.max_group_load(p.loads), 0.20638, atol=1e-4)


def test_more_heads_than_devices_packs_all_devices():
    p = solve_placement(3, [5, 1, 1, 1, 1, 1, 5, 5])
    assert p.n_groups == 3 and p.device_counts == (1, 1, 1)
    rr = round_robin_placement(8, 3)
    assert p.max_group_load() <= rr.max_group_load(p.loads)


def test_zero_load_heads_never_strand_a_device():
    # ties on zero-load heads must still leave every device owning >=1 head
    p = solve_placement(3, [1.0, 0.0, 0.0, 0.0])
    assert all(len(g) >= 1 for g in p.groups)
    assert sum(p.device_counts) == 3


def test_single_device_degenerate():
    p = solve_placement(1, [1, 2, 3])
    assert p.groups == ((0, 1, 2),) and p.device_counts == (1,)


def test_loads_recorded_and_group_loads_model():
    p = solve_placement(4, [1, 1, 2])
    assert p.loads is not None and len(p.loads) == 3
    np.testing.assert_allclose(sum(p.loads), 1.0)
    gl = p.group_loads()
    assert len(gl) == p.n_groups
    assert max(gl) == p.max_group_load()


def test_round_robin_shape():
    rr = round_robin_placement(5, 8)
    assert rr.groups == ((0,), (1,), (2,), (3,), (4,))
    assert rr.device_counts == (2, 2, 2, 1, 1)
    rr2 = round_robin_placement(7, 3)   # heads dealt cyclically
    assert rr2.groups == ((0, 3, 6), (1, 4), (2, 5))
    assert rr2.device_counts == (1, 1, 1)


def test_head_placement_validation():
    with pytest.raises(AssertionError):      # head 1 missing
        HeadPlacement(groups=((0,), (2,)), device_counts=(1, 1))
    with pytest.raises(AssertionError):      # duplicate head
        HeadPlacement(groups=((0, 1), (1,)), device_counts=(1, 1))
    with pytest.raises(AssertionError):      # zero-device group
        HeadPlacement(groups=((0,), (1,)), device_counts=(2, 0))
    with pytest.raises(AssertionError):      # headless group
        HeadPlacement(groups=((0, 1), ()), device_counts=(1, 1))
    with pytest.raises(AssertionError):      # loads length mismatch
        HeadPlacement(groups=((0, 1),), device_counts=(2,), loads=(1.0,))


def test_group_of():
    p = HeadPlacement(groups=((0, 2), (1,)), device_counts=(1, 3))
    assert p.group_of(0) == 0 and p.group_of(2) == 0 and p.group_of(1) == 1
    with pytest.raises(KeyError):
        p.group_of(3)


def test_bad_loads_rejected():
    with pytest.raises(AssertionError):
        solve_placement(4, [])
    with pytest.raises(AssertionError):
        solve_placement(4, [0.0, 0.0])
    with pytest.raises(AssertionError):
        solve_placement(4, [1.0, -0.5])
    with pytest.raises(AssertionError):
        solve_placement(0, [1.0])

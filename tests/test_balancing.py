"""Energy alignment: least-squares reference energies recover planted shifts."""
import numpy as np
import jax.numpy as jnp

from repro.core.balancing import (align_sources, composition_matrix,
                                  fit_reference_energies,
                                  uncertainty_weighted_loss,
                                  uncertainty_weights_init)


def test_fit_recovers_planted_shifts():
    rng = np.random.default_rng(0)
    n, A, Z = 400, 12, 16
    species = rng.integers(0, Z, (n, A))
    shift = rng.normal(0, 2.0, Z)
    shift[0] = 0.0  # pad element
    comp = composition_matrix(species, Z)
    base = rng.normal(0, 0.05, n)
    energy = comp @ shift + base
    e_ref = fit_reference_energies(species, energy, Z)
    aligned = energy - comp @ e_ref
    # aligned energies have (much) smaller variance than raw
    assert aligned.std() < 0.3 * energy.std()


def test_align_sources_reduces_cross_source_offset():
    rng = np.random.default_rng(1)
    Z, A, n = 8, 6, 300
    out = []
    for s in range(2):
        species = rng.integers(1, Z, (n, A))
        comp = composition_matrix(species, Z)
        shift = rng.normal(0, 3.0, Z)
        energy = comp @ shift + rng.normal(0, 0.01, n)
        out.append({"species": species, "energy": energy})
    aligned = align_sources(out, Z)
    for src in aligned:
        assert np.abs(src["energy"]).mean() < 1.0  # per-atom residual small


def test_uncertainty_weighting():
    p = uncertainty_weights_init(2)
    l = uncertainty_weighted_loss(p, jnp.array([1.0, 2.0]))
    np.testing.assert_allclose(float(l), 3.0, rtol=1e-6)  # sigma=1 -> sum

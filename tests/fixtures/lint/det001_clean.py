"""Clean twin of DET001: a held, seeded Generator (the repo convention)."""
import numpy as np


def shuffled_indices(n, seed):
    rng = np.random.default_rng(seed)
    return rng.permutation(n)

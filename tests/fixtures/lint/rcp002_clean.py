"""Clean twin of RCP002: the array is an argument, not a baked constant."""
import functools

import jax
import jax.numpy as jnp


def make_step(n):
    scale = jnp.ones((n,))

    @jax.jit
    def step(x, scale):
        return x * scale

    return functools.partial(step, scale=scale)

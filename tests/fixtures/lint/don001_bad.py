"""Seeded DON001: reading a buffer after passing it at a donated position."""
import jax


def run(step_fn, state, batches):
    step = jax.jit(step_fn, donate_argnums=(0,))
    for batch in batches:
        new_state, out = step(state, batch)
        print(state.params)
    return new_state, out

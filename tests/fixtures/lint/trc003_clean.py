"""Clean twin of TRC003: accumulate on device, read back once after."""
import jax
import jax.numpy as jnp


def train(step, state, batches):
    losses = []
    for batch in batches:
        state, out = step(state, batch)
        losses.append(out.loss)
    return state, jax.device_get(jnp.stack(losses))

"""Seeded DET001: the legacy process-global numpy RNG."""
import numpy as np


def shuffled_indices(n):
    return np.random.permutation(n)

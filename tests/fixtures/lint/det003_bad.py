"""Seeded DET003: durations computed from the non-monotonic wall clock."""
import time


def timed(f):
    t0 = time.time()
    f()
    return time.time() - t0

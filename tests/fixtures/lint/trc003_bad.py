"""Seeded TRC003: a device->host sync every loop iteration."""


def train(step, state, batches):
    losses = []
    for batch in batches:
        state, out = step(state, batch)
        losses.append(out.loss.item())
    return state, losses

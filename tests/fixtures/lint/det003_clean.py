"""Clean twin of DET003: perf_counter for durations."""
import time


def timed(f):
    t0 = time.perf_counter()
    f()
    return time.perf_counter() - t0

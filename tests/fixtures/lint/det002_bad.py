"""Seeded DET002: the stdlib global RNG."""
import random


def pick(xs):
    return random.choice(xs)

"""Clean twin of DET002: a held, seeded Random instance."""
import random


def pick(xs, seed):
    rng = random.Random(seed)
    return rng.choice(xs)

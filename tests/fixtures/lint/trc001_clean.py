"""Clean twin of TRC001: the branch stays inside the compiled program."""
import jax
import jax.numpy as jnp


@jax.jit
def step(x):
    return jnp.where(jnp.any(x > 0), x + 1, x - 1)

"""Seeded TRC002: host-sync coercions inside a jit-reachable function."""
import jax
import jax.numpy as jnp


@jax.jit
def loss_scalar(x):
    total = jnp.sum(x)
    return float(jnp.mean(x)) + total.item()

"""Clean twin of DON001: the donating call rebinds the donated name."""
import jax


def run(step_fn, state, batches):
    step = jax.jit(step_fn, donate_argnums=(0,))
    for batch in batches:
        state, out = step(state, batch)
    return state, out

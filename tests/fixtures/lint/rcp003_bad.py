"""Seeded RCP003: an array expression passed for a declared static arg."""
import jax
import jax.numpy as jnp


def build(g):
    f = jax.jit(g, static_argnames=("mask",))
    return f(jnp.ones((4,)), mask=jnp.ones((4,), bool))

"""Clean twin of RCP003: statics are hashable scalars/tuples."""
import jax
import jax.numpy as jnp


def build(g):
    f = jax.jit(g, static_argnames=("mask",))
    return f(jnp.ones((4,)), mask=(True, True, False, True))

"""Clean twin of PAL001: unit dims via pl.dslice(0, 1), squeezed after."""
from jax.experimental import pallas as pl


def kernel(x_ref, o_ref):
    row = pl.load(x_ref, (pl.dslice(0, 1), pl.dslice(0, 8)))[0]
    pl.store(o_ref, (pl.dslice(0, 1), pl.dslice(0, 8)), row[None])

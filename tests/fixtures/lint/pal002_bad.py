"""Seeded PAL002: pallas_call with no VMEM planning anywhere in the module."""
import jax
from jax.experimental import pallas as pl


def double(x, tile=128):
    def kern(x_ref, o_ref):
        o_ref[...] = x_ref[...] * 2

    return pl.pallas_call(
        kern,
        grid=(x.shape[0] // tile,),
        in_specs=[pl.BlockSpec((tile,), lambda i: (i,))],
        out_specs=pl.BlockSpec((tile,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
    )(x)

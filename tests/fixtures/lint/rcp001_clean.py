"""Clean twin of RCP001: jit once, call many times."""
import jax


def sweep(f, xs):
    jf = jax.jit(f)
    outs = []
    for x in xs:
        outs.append(jf(x))
    return outs

"""Seeded PAL003: a low-precision VMEM scratch used as an accumulator.

The module routes its tile through check_blocks so only the scratch-dtype
contract (PAL003) is violated here.
"""
import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.egnn_edge.budget import check_blocks


def reduce_rows(x, tile=128):
    check_blocks(x.shape[0], x.shape[0], x.shape[1], tile, tile)

    def kern(x_ref, o_ref, acc):
        acc[...] += x_ref[...].astype(acc.dtype)
        o_ref[...] = acc[...]

    return pl.pallas_call(
        kern,
        grid=(x.shape[0] // tile,),
        in_specs=[pl.BlockSpec((tile, x.shape[1]), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((tile, x.shape[1]), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        scratch_shapes=[pltpu.VMEM((tile, x.shape[1]), jnp.bfloat16)],
    )(x)

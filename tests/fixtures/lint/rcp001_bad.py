"""Seeded RCP001: a fresh jit wrapper (and compile) every iteration."""
import jax


def sweep(f, xs):
    outs = []
    for x in xs:
        outs.append(jax.jit(f)(x))
    return outs

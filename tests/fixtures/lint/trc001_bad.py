"""Seeded TRC001: Python `if` on a traced value inside a jitted function."""
import jax
import jax.numpy as jnp


@jax.jit
def step(x):
    if jnp.any(x > 0):
        return x + 1
    return x - 1

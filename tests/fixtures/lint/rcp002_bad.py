"""Seeded RCP002: jitted inner function closes over a factory-built array."""
import jax
import jax.numpy as jnp


def make_step(n):
    scale = jnp.ones((n,))

    @jax.jit
    def step(x):
        return x * scale

    return step

"""Seeded PAL001: bare int indices in pl.load/pl.store (the PR 3 bug)."""
from jax.experimental import pallas as pl


def kernel(x_ref, o_ref):
    row = pl.load(x_ref, (0, pl.dslice(0, 8)))
    pl.store(o_ref, (0, pl.dslice(0, 8)), row)

"""Clean twin of TRC002: scalars stay on device inside the jitted region."""
import jax
import jax.numpy as jnp


@jax.jit
def loss_scalar(x):
    return jnp.mean(x) + jnp.sum(x)


def read_out(x):
    return float(loss_scalar(x))

"""Clean twin of PAL003: f32 accumulator scratch, cast on the final flush."""
import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.egnn_edge.budget import check_blocks


def reduce_rows(x, tile=128):
    check_blocks(x.shape[0], x.shape[0], x.shape[1], tile, tile)

    def kern(x_ref, o_ref, acc):
        acc[...] += x_ref[...].astype(jnp.float32)
        o_ref[...] = acc[...].astype(o_ref.dtype)

    return pl.pallas_call(
        kern,
        grid=(x.shape[0] // tile,),
        in_specs=[pl.BlockSpec((tile, x.shape[1]), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((tile, x.shape[1]), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        scratch_shapes=[pltpu.VMEM((tile, x.shape[1]), jnp.float32)],
    )(x)

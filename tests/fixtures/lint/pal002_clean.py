"""Clean twin of PAL002: tile sizes validated against a VMEM budget model."""
import jax
from jax.experimental import pallas as pl

from repro.kernels.egnn_edge.budget import VMEM_BUDGET


def check_blocks(tile, itemsize, vmem_limit=VMEM_BUDGET):
    if 2 * 2 * tile * itemsize > vmem_limit:
        raise ValueError(f"tile {tile} over the VMEM budget")


def double(x, tile=128):
    check_blocks(tile, x.dtype.itemsize)

    def kern(x_ref, o_ref):
        o_ref[...] = x_ref[...] * 2

    return pl.pallas_call(
        kern,
        grid=(x.shape[0] // tile,),
        in_specs=[pl.BlockSpec((tile,), lambda i: (i,))],
        out_specs=pl.BlockSpec((tile,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
    )(x)

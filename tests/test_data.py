"""Data substrate: synthetic physics consistency + group-aware batcher."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.data.loader import GroupBatcher
from repro.data.synthetic_atoms import (SOURCES, generate_source, true_energy,
                                        true_forces)


def test_forces_are_negative_gradient():
    sd = generate_source("ani1x", 4, max_atoms=12, max_edges=64, seed=3)
    s = jnp.array(sd.species[:2])
    p = jnp.array(sd.pos[:2])
    f = np.asarray(true_forces(s, p))
    # finite-difference check (fp32: central differences of O(1) energies
    # carry ~1e-7/eps relative noise — eps and tolerance sized accordingly)
    eps = 2e-3
    for (i, a, c) in [(0, 0, 0), (1, 2, 1)]:
        p2 = p.at[i, a, c].add(eps)
        p3 = p.at[i, a, c].add(-eps)
        fd = -(true_energy(s[i], p2[i]) - true_energy(s[i], p3[i])) / (2 * eps)
        np.testing.assert_allclose(f[i, a, c], float(fd), atol=5e-3, rtol=5e-2)


def test_sources_have_distinct_chemistry():
    a = generate_source("ani1x", 16, seed=0)
    m = generate_source("mptrj", 16, seed=0)
    za = set(np.unique(a.species)) - {0}
    zm = set(np.unique(m.species)) - {0}
    assert za <= set(SOURCES["ani1x"]["elements"])
    assert zm <= set(SOURCES["mptrj"]["elements"])
    assert za != zm


def test_fidelity_offsets_conflict():
    """Same ground truth, different observed labels across sources."""
    a = generate_source("ani1x", 64, seed=0)
    q = generate_source("qm7x", 64, seed=0)
    # within each source, observed != true by a composition-dependent shift
    assert np.abs(q.energy - q.e_true).mean() > 5 * np.abs(
        a.energy - a.e_true).mean()


def test_group_batcher_task_purity_and_epoch():
    srcs = [{"x": np.full((5, 2), t, np.float32), "y": np.arange(5) + 10 * t}
            for t in range(3)]
    gb = GroupBatcher(srcs, batch_per_task=4, seed=0)
    seen = [set(), set(), set()]
    for _ in range(6):
        b = gb.next_batch()
        assert b["x"].shape == (3, 4, 2)
        for t in range(3):
            assert bool((b["x"][t] == t).all()), "cross-source contamination"
            seen[t].update(np.asarray(b["y"][t]).tolist())
    for t in range(3):  # cyclic epochs cover every sample
        assert seen[t] == set(range(10 * t, 10 * t + 5))

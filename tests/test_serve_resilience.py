"""Serve hardening (ISSUE-7): worker-crash propagation and per-request
deadlines.

The contracts: a dead engine worker fails EVERY pending future immediately
(queued, in-flight, and binned — nothing hangs), subsequent submits raise
``ServeClosedError``, and ``restart_worker()`` recovers without
recompiling; requests that age past ``max_queue_wait`` are shed with
``DeadlineExceededError`` instead of computed; ``submit()`` under
backpressure gives up after ``admission_timeout`` in the caller's thread."""
import threading
from concurrent.futures import Future

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.core.mtl import make_gfm_mtl
from repro.data.bucketing import BucketSpec
from repro.data.synthetic_atoms import generate_mixture, source_dicts
from repro.serve import (
    DeadlineExceededError,
    ServeClosedError,
    ServeMetrics,
    ServeSession,
)
from repro.serve.queue import Request, RequestQueue

CFG = ArchConfig(name="serve-res", family="gnn", gnn_hidden=16,
                 gnn_layers=2, n_species=64, head_hidden=8, head_layers=2,
                 remat=False, compute_dtype=jnp.float32)
SPEC = BucketSpec((8, 16), (32, 64))


@pytest.fixture(scope="module")
def served():
    sources = source_dicts(generate_mixture(24, max_atoms=16, max_edges=64))
    model = make_gfm_mtl(CFG, len(sources))
    params = model.init(jax.random.PRNGKey(0))
    return params, sources


def _sample(sources, t=0, i=0):
    s = sources[t]
    i = i % s["species"].shape[0]
    return {k: s[k][i] for k in ("species", "pos", "edge_src", "edge_dst",
                                 "node_mask", "edge_mask")}


# ---------------------------------------------------------------------------
# worker-crash propagation + restart
# ---------------------------------------------------------------------------

def test_worker_crash_fails_all_pending_then_restart_recovers(served):
    """Kill the worker mid-backlog (batcher.add raises): every pending
    future — including the request the worker had already dequeued — must
    fail with the crash error, new submits must raise ServeClosedError,
    and restart_worker() must bring the session back with the compiled
    executables intact."""
    params, sources = served
    srv = ServeSession(params, CFG, spec=SPEC, max_batch=4,
                       max_wait_ms=2.0)
    try:
        release = threading.Event()

        def dying_add(req):
            # hold the worker here so the test can queue more requests
            # behind the one being filed, then detonate
            release.wait(timeout=10)
            raise RuntimeError("batcher exploded")

        srv.batcher.add = dying_add
        f1 = srv.submit(_sample(sources, 0), head=0)
        f2 = srv.submit(_sample(sources, 1), head=1)
        release.set()
        for f in (f1, f2):                     # nothing hangs
            with pytest.raises(RuntimeError, match="batcher exploded"):
                f.result(timeout=30)
        srv._worker.join(timeout=10)
        assert not srv._worker.is_alive()

        with pytest.raises(ServeClosedError):
            srv.submit(_sample(sources, 0))
        # back-compat: ServeClosedError IS a RuntimeError matching "closed"
        with pytest.raises(RuntimeError, match="closed"):
            srv.submit(_sample(sources, 0))

        compiled_before = len(srv._shapes_compiled)
        assert srv.restart_worker() is True
        got = srv.submit(_sample(sources, 2), head=2).result(timeout=60)
        ref = srv.predict_one(_sample(sources, 2), head=2)
        assert got["energy"] == ref["energy"]
        np.testing.assert_array_equal(got["forces"], ref["forces"])
        assert len(srv._shapes_compiled) >= compiled_before

        c = srv.stats()["counters"]
        assert c["worker_failures"] == 1
        assert c["worker_restarts"] == 1
        assert c["failed"] >= 2
    finally:
        srv.close()


def test_restart_worker_is_noop_when_healthy_and_raises_when_closed(served):
    params, _ = served
    srv = ServeSession(params, CFG, spec=SPEC, max_batch=2)
    assert srv.restart_worker() is False
    assert srv.stats()["counters"]["worker_restarts"] == 0
    srv.close()
    with pytest.raises(ServeClosedError):
        srv.restart_worker()
    with pytest.raises(ServeClosedError):
        srv.submit({"species": np.zeros(2, np.int32),
                    "pos": np.zeros((2, 3), np.float32)})


# ---------------------------------------------------------------------------
# deadlines: queue-wait shedding + admission timeout
# ---------------------------------------------------------------------------

def test_submit_stamps_queue_wait_deadline(served):
    _, sources = served
    q = RequestQueue(SPEC, depth=4, n_heads=3, max_queue_wait=0.05)
    q.submit(_sample(sources, 0), head=0)
    req = q.get(timeout=1.0)
    assert req is not None
    assert req.deadline == pytest.approx(req.t_submit + 0.05)


def test_worker_sheds_requests_past_their_deadline(served):
    """Drive the shed branch deterministically: hand _file a request whose
    deadline is already in the past (engine clock is monotonic, so any
    negative deadline is expired). The future must fail with
    DeadlineExceededError and the shed must be counted — the request never
    reaches the batcher."""
    params, sources = served
    srv = ServeSession(params, CFG, spec=SPEC, max_batch=4,
                       max_queue_wait_ms=50.0)
    srv.close()                                # worker quiesced; _file is ours
    sm = _sample(sources, 0)
    from repro.serve.queue import _as_sample
    canon, n_atoms, n_edges = _as_sample(sm)
    req = Request(sample=canon, head=0, bucket=SPEC.bucket_for(n_atoms,
                                                               n_edges),
                  n_atoms=n_atoms, n_edges=n_edges, future=Future(),
                  t_submit=0.0, deadline=-1.0)
    assert srv._file(req) is None
    with pytest.raises(DeadlineExceededError):
        req.future.result(timeout=0)
    assert srv.stats()["counters"]["shed_deadline"] == 1
    assert srv.batcher.pending_requests() == []


def test_admission_timeout_sheds_in_caller_thread(served):
    """depth=1 and no consumer: the first submit takes the only slot, the
    second must give up after admission_timeout in the CALLER's thread."""
    _, sources = served
    m = ServeMetrics()
    q = RequestQueue(SPEC, depth=1, n_heads=3, admission_timeout=0.05,
                     metrics=m)
    q.submit(_sample(sources, 0), head=0)
    with pytest.raises(DeadlineExceededError, match="saturated"):
        q.submit(_sample(sources, 1), head=1)
    assert m.counters["shed_admission"] == 1
    assert m.counters["submitted"] == 1        # the shed one never counted


def test_closed_queue_rejects_submits_with_closed_error(served):
    _, sources = served
    q = RequestQueue(SPEC, depth=2, n_heads=3)
    q.close()
    with pytest.raises(ServeClosedError):
        q.submit(_sample(sources, 0))
    with pytest.raises(RuntimeError, match="closed"):   # back-compat
        q.submit(_sample(sources, 0))
    q.close()                                  # idempotent re-entry

"""Per-architecture smoke tests: reduced variant of the same family
(2 layers, d_model <= 512, <= 4 experts) — one forward/train step on CPU,
asserting output shapes and no NaNs; plus a decode step where applicable."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.configs import ARCHS, SHAPES
from repro.configs.specs import input_specs
from repro.models import transformer
from repro.engine import ShardingPlan, TrainState, build_model, make_step
from repro.models.frontends import AUDIO_EMBED_DIM, VISION_EMBED_DIM
from repro.optim import adamw

LM_ARCHS = [a for a in ARCHS if a != "hydragnn-gfm"]


def _materialize(spec_tree, seed=0):
    rng = np.random.default_rng(seed)

    def mk(s):
        if jnp.issubdtype(s.dtype, jnp.integer):
            return jnp.asarray(rng.integers(0, 16, s.shape), s.dtype)
        return jnp.asarray(rng.normal(0, 1, s.shape), s.dtype)

    return jax.tree_util.tree_map(mk, spec_tree)


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_reduced_limits(arch):
    cfg = configs.get_smoke(arch)
    assert cfg.n_layers <= 8 and cfg.d_model <= 512
    assert cfg.n_experts <= 4


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_forward_and_train_step(arch):
    cfg = configs.get_smoke(arch)
    shape = SHAPES["train_4k"]
    batch = _materialize(input_specs(cfg, shape, mesh=None, reduced=True))
    params = transformer.lm_init(jax.random.PRNGKey(0), cfg)

    # forward: logits shape + finite
    memory = None
    if cfg.n_enc_layers:
        memory = transformer.encode(params, batch["src_embed"], cfg)
        assert memory.shape == (batch["src_embed"].shape[0], 32, cfg.d_model)
    logits, _, aux = transformer.lm_apply(params, batch["tokens"], cfg=cfg,
                                          media=batch.get("media"),
                                          memory=memory)
    B, S = batch["tokens"].shape
    n_media = batch["media"].shape[1] if "media" in batch else 0
    assert logits.shape == (B, S + n_media, cfg.padded_vocab)
    assert bool(jnp.isfinite(logits).all()), "NaN/Inf in logits"

    # one train step through the engine's unified API
    opt = adamw(1e-3)
    plan = ShardingPlan(donate=False)
    model = build_model("lm", cfg)
    step = plan.compile(make_step(model, opt, plan))
    state = TrainState.create(params, opt)
    state2, out = step(state, batch)
    assert bool(jnp.isfinite(out.loss)), "NaN loss"
    assert int(state2.step) == 1
    # params actually changed
    d = jax.tree_util.tree_map(lambda a, b: float(jnp.abs(a - b).max()),
                               params, state2.params)
    assert max(jax.tree_util.tree_leaves(d)) > 0


@pytest.mark.parametrize("arch", [a for a in LM_ARCHS
                                  if configs.get(a).supports_decode])
def test_decode_step(arch):
    cfg = configs.get_smoke(arch)
    B, C = 2, 64
    params = transformer.lm_init(jax.random.PRNGKey(0), cfg)
    caches = transformer.lm_cache_init(params, cfg, B, C)
    memory = (jnp.zeros((B, 32, cfg.d_model), cfg.compute_dtype)
              if cfg.n_enc_layers else None)
    tok = jnp.zeros((B, 1), jnp.int32)
    logits, caches2, _ = transformer.lm_apply(
        params, tok, cfg=cfg, mode="decode", caches=caches,
        positions=jnp.array([0]), memory=memory)
    assert logits.shape == (B, 1, cfg.padded_vocab)
    assert bool(jnp.isfinite(logits).all())


def test_gfm_smoke():
    from repro.core import make_gfm_mtl
    from repro.data.synthetic_atoms import generate_all, to_batch_dict
    cfg = configs.get_smoke("hydragnn-gfm")
    model = make_gfm_mtl(cfg, cfg.n_tasks)
    params = model.init(jax.random.PRNGKey(0))
    data = generate_all(8, max_atoms=cfg.max_atoms, max_edges=cfg.max_edges,
                        sources=["ani1x", "qm7x", "mptrj"])
    bs = [to_batch_dict(sd, np.arange(4)) for sd in data.values()]
    batch = {k: jnp.stack([b[k] for b in bs]) for k in bs[0]}
    per_task, metrics = model.loss_fn(params["shared"], params["heads"], batch)
    assert per_task.shape == (cfg.n_tasks,)
    assert bool(jnp.isfinite(per_task).all())


def test_exact_assigned_configs():
    """The full configs carry the exact assigned hyperparameters."""
    c = configs.get("granite-moe-3b-a800m")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.vocab,
            c.n_experts, c.top_k) == (32, 1536, 24, 8, 49155, 40, 8)
    c = configs.get("deepseek-v2-236b")
    assert (c.n_layers, c.d_model, c.n_heads, c.kv_lora, c.n_experts,
            c.top_k, c.vocab) == (60, 5120, 128, 512, 160, 6, 102400)
    c = configs.get("gemma3-12b")
    assert (c.n_layers, c.d_model, c.vocab, c.block_pattern.count("swa")) == \
        (48, 3840, 262144, 5)
    c = configs.get("zamba2-1.2b")
    assert (c.n_layers, c.d_model, c.ssm_state) == (38, 2048, 64)
    c = configs.get("xlstm-125m")
    assert (c.n_layers, c.d_model, c.n_heads, c.d_ff) == (12, 768, 4, 0)
    c = configs.get("hydragnn-gfm")
    assert (c.gnn_layers, c.gnn_hidden, c.head_hidden, c.head_layers,
            c.n_tasks) == (4, 866, 889, 3, 5)

"""Multi-source mixing (repro.data.mixing): weight math, deterministic
error-diffusion schedule, epoch semantics, and Session wiring."""
import numpy as np
import pytest

from repro.data.mixing import MixingBatcher, MixingConfig, mix_weights


def _sources(sizes, feature_offset=1000):
    """Source s has samples whose value encodes (s, sample index)."""
    return [{"x": (feature_offset * s + np.arange(n)).astype(np.int64),
             "y": np.full((n, 2), s, np.int64)} for s, n in enumerate(sizes)]


# ---------------------------------------------------------------------------
# mix_weights
# ---------------------------------------------------------------------------

def test_weights_proportional_uniform_and_flattened():
    np.testing.assert_allclose(mix_weights([100, 400]), [0.2, 0.8])
    np.testing.assert_allclose(mix_weights([100, 400], temperature=1e12),
                               [0.5, 0.5], atol=1e-6)
    w = mix_weights([100, 400], temperature=2.0)   # sqrt flattening
    assert 0.2 < w[0] < 0.5 and w[1] == pytest.approx(1 - w[0])
    np.testing.assert_allclose(mix_weights([10, 10], weights=(3, 1)),
                               [0.75, 0.25])      # explicit weights win


def test_weights_validation():
    with pytest.raises(AssertionError):
        mix_weights([100, 400], temperature=0.0)
    with pytest.raises(AssertionError):
        mix_weights([10, 10], weights=(1, -1))


# ---------------------------------------------------------------------------
# MixingBatcher
# ---------------------------------------------------------------------------

def test_schedule_tracks_weights_exactly():
    """Error diffusion: realized per-source counts track k*B*w_s to within
    the number of sources — not just in expectation."""
    sizes = [97, 31, 9]
    mb = MixingBatcher(_sources(sizes), 16,
                       mixing=MixingConfig(emit_source=True), seed=0)
    counts = np.zeros(3)
    for k in range(1, 40):
        counts += np.bincount(mb.next_batch()["source_id"], minlength=3)
        assert np.abs(counts - k * 16 * mb.weights).max() <= len(sizes), \
            f"schedule drifted at batch {k}"


def test_extreme_weights_never_crash_the_schedule():
    """Regression: the old error-diffusion top-up could drive a source's
    credit negative and emit a negative count (np.full(-1, ...) crash).
    Smooth weighted round-robin keeps every count >= 0 by construction."""
    mb = MixingBatcher(_sources([50, 5, 5, 5, 5]), 1,
                       mixing=MixingConfig(weights=(100, 1, 1, 1, 1),
                                           emit_source=True), seed=0)
    counts = np.zeros(5)
    for _ in range(300):
        b = mb.next_batch()
        assert b["x"].shape == (1,)
        counts += np.bincount(b["source_id"], minlength=5)
    emp = counts / counts.sum()
    assert np.abs(emp - mb.weights).max() < 0.02, (emp, mb.weights)


def test_state_is_small_and_never_serializes_permutations():
    """Checkpoint state is O(n_sources): the prefetch producer snapshots it
    per batch, so it must not carry the per-source permutations."""
    import json
    mb = MixingBatcher(_sources([50_000, 30_000]), 8, seed=0)
    mb.next_batch()
    assert len(json.dumps(mb.state())) < 4096
    from repro.data.loader import GroupBatcher
    gb = GroupBatcher(_sources([50_000, 30_000]), 8, seed=0)
    gb.next_batch()
    assert len(json.dumps(gb.state())) < 4096


def test_restore_rejects_source_count_mismatch():
    mb = MixingBatcher(_sources([10, 10, 10]), 4, seed=0)
    snap = mb.state()
    with pytest.raises(AssertionError, match="sources"):
        MixingBatcher(_sources([10, 10]), 4, seed=0).restore(snap)


def test_samples_match_their_source_and_batch_is_flat():
    mb = MixingBatcher(_sources([20, 30]), 8,
                       mixing=MixingConfig(emit_source=True), seed=1)
    for _ in range(10):
        b = mb.next_batch()
        assert b["x"].shape == (8,) and b["y"].shape == (8, 2)
        # the value encoding proves each sample came from its claimed source
        np.testing.assert_array_equal(b["x"] // 1000, b["source_id"])
        np.testing.assert_array_equal(b["y"][:, 0], b["source_id"])


def test_deterministic_under_seed_and_seed_matters():
    a = MixingBatcher(_sources([20, 30]), 8, seed=5)
    b = MixingBatcher(_sources([20, 30]), 8, seed=5)
    for _ in range(6):
        np.testing.assert_array_equal(a.next_batch()["x"],
                                      b.next_batch()["x"])
    c = MixingBatcher(_sources([20, 30]), 8, seed=6)
    stream_a = np.concatenate([a.next_batch()["x"] for _ in range(4)])
    stream_c = np.concatenate([c.next_batch()["x"] for _ in range(4)])
    assert not np.array_equal(stream_a, stream_c)


def test_per_source_epoch_semantics():
    """Within one source, every sample is visited once per local epoch
    (shuffled-cyclic, like GroupBatcher) under proportional mixing."""
    n = 12
    mb = MixingBatcher(_sources([n]), 4, seed=2)
    stream = np.concatenate([mb.next_batch()["x"] for _ in range(3 * n // 4)])
    epochs = stream.reshape(3, n)
    for e in range(3):
        assert sorted(epochs[e]) == list(range(n)), f"epoch {e}"
    assert not np.array_equal(epochs[0], epochs[1]), "no reshuffle"


def test_task_major_emits_leading_unit_dim():
    mb = MixingBatcher(_sources([20, 30]), 8, seed=0, task_major=True)
    b = mb.next_batch()
    assert b["x"].shape == (1, 8) and b["y"].shape == (1, 8, 2)


def test_state_restore_resumes_byte_identical():
    mb = MixingBatcher(_sources([17, 5, 23]), 8, seed=9)
    for _ in range(7):
        mb.next_batch()
    snap = mb.state()
    ref = [mb.next_batch() for _ in range(9)]
    fresh = MixingBatcher(_sources([17, 5, 23]), 8, seed=0)  # wrong seed
    fresh.restore(snap)                                      # ...rewound
    for a in ref:
        b = fresh.next_batch()
        for k in a:
            np.testing.assert_array_equal(a[k], b[k])


def test_gather_style_sources(tmp_path):
    """MixingBatcher accepts ShardedSource readers (gather contract)."""
    from repro.data.store import ShardedSource, write_store
    paths = []
    for s, n in enumerate([40, 20]):
        p = str(tmp_path / f"s{s}")
        write_store(p, {"x": 1000 * s + np.arange(n)}, shard_size=16)
        paths.append(p)
    mb = MixingBatcher([ShardedSource(p) for p in paths], 8,
                       mixing=MixingConfig(emit_source=True), seed=0)
    for _ in range(5):
        b = mb.next_batch()
        np.testing.assert_array_equal(b["x"] // 1000, b["source_id"])


# ---------------------------------------------------------------------------
# Session wiring
# ---------------------------------------------------------------------------

def _gnn_setup(n=40):
    import jax.numpy as jnp
    from repro.configs.base import ArchConfig
    from repro.data.synthetic_atoms import generate_mixture, source_dicts
    cfg = ArchConfig(name="g", family="gnn", gnn_hidden=8, gnn_layers=1,
                     n_species=64, head_hidden=8, head_layers=2,
                     remat=False, compute_dtype=jnp.float32)
    return cfg, source_dicts(generate_mixture(n, max_atoms=12, max_edges=48))


def test_session_multitask_mixing_becomes_task_weights():
    from repro.engine import Session, SessionConfig
    cfg, sources = _gnn_setup()
    with Session.from_config(
            SessionConfig(model="gfm-mtl", arch=cfg, steps=1,
                          batch_per_task=2, verbose=False,
                          mixing=MixingConfig(temperature=2.0)),
            sources=sources) as s:
        sizes = [len(src["energy"]) for src in sources]
        np.testing.assert_allclose(
            s.task_weights, mix_weights(sizes, temperature=2.0), rtol=1e-6)
        s.run()


def test_session_baseline_over_mixture():
    """gfm-baseline (ONE branch) + cfg.mixing trains on the weighted
    mixture of all five sources — the paper's GFM-Baseline-All setup."""
    from repro.engine import Session, SessionConfig
    cfg, sources = _gnn_setup()
    with Session.from_config(
            SessionConfig(model="gfm-baseline", arch=cfg, steps=2,
                          batch_per_task=4, verbose=False, mixing=1.0),
            sources=sources) as s:
        res = s.run()
        assert np.isfinite(res.final_loss)
        # one branch: head leaves carry a leading task dim of 1
        heads = res.params["heads"]
        import jax
        assert all(x.shape[0] == 1 for x in jax.tree_util.tree_leaves(heads))


def test_session_baseline_many_sources_without_mixing_raises():
    from repro.engine import Session, SessionConfig
    cfg, sources = _gnn_setup()
    with pytest.raises(AssertionError, match="mixing"):
        Session.from_config(
            SessionConfig(model="gfm-baseline", arch=cfg, steps=1,
                          verbose=False), sources=sources)


def test_session_mixing_shorthands():
    from repro.engine.session import _as_bucket_spec, _as_mixing
    assert _as_mixing(None) is None
    assert _as_mixing(2.0).temperature == 2.0
    assert _as_mixing((1, 3)).weights == (1, 3)
    mc = MixingConfig(temperature=3.0)
    assert _as_mixing(mc) is mc
    with pytest.raises(TypeError):
        _as_mixing("proportional")
    # bool IS int in Python — a likely typo (prefetch-style flag), rejected
    with pytest.raises(TypeError, match="ambiguous"):
        _as_mixing(True)
    with pytest.raises(TypeError, match="ambiguous"):
        _as_bucket_spec(True, None, None)


def test_reenabled_source_restarts_with_zero_credit():
    """ISSUE 10 bugfix: a source coming back from quarantine (weight 0 ->
    positive) must NOT burst-win early slots off its stale pre-quarantine
    credit — cumulative counts from the re-enable on must re-track the new
    ``k*B*w_s`` schedule immediately."""
    sizes = [60, 60, 60]
    B = 12
    mb = MixingBatcher(_sources(sizes), B,
                       mixing=MixingConfig(emit_source=True), seed=0)
    for _ in range(5):
        mb.next_batch()
    # quarantine source 1: its credit freezes at whatever it had accrued
    mb.set_weights((1.0, 0.0, 1.0))
    for _ in range(7):
        assert 1 not in mb.next_batch()["source_id"]
    frozen_credit = mb.credit[1]
    # re-enable: stale credit must be zeroed on the 0 -> positive flip
    mb.set_weights((1.0, 1.0, 1.0))
    assert mb.credit[1] == 0.0, \
        f"stale credit {frozen_credit} survived re-enable"
    counts = np.zeros(3)
    for k in range(1, 30):
        counts += np.bincount(mb.next_batch()["source_id"], minlength=3)
        # the smooth-round-robin bound, measured from the re-enable only:
        # a stale-credit burst would blow it in the first few batches
        assert np.abs(counts - k * B * mb.weights).max() <= len(sizes), \
            f"post-re-enable schedule drifted at batch {k}: {counts}"


def test_set_weights_does_not_touch_live_source_credit():
    """Only the 0 -> positive transition resets credit: reweighting LIVE
    sources keeps their diffusion error, so the schedule stays smooth
    across an ordinary reweight."""
    mb = MixingBatcher(_sources([40, 40]), 8,
                       mixing=MixingConfig(emit_source=True), seed=0)
    for _ in range(3):
        mb.next_batch()
    credit_before = mb.credit.copy()
    mb.set_weights((0.7, 0.3))           # both stay positive
    np.testing.assert_array_equal(mb.credit, credit_before)

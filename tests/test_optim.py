"""AdamW + schedules against closed-form references."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.optim import adamw, global_norm, warmup_cosine


def test_adamw_matches_reference():
    """One Adam step on a known gradient matches the textbook update."""
    opt = adamw(0.1, b1=0.9, b2=0.999, eps=1e-8, weight_decay=0.0)
    p = {"w": jnp.array([1.0, -2.0])}
    g = {"w": jnp.array([0.5, 0.5])}
    st = opt.init(p)
    p2, st2 = opt.update(g, st, p)
    # step 1: m=0.1*g/(1-0.9)=g ; v=0.001*g^2/(1-0.999)=g^2 ; upd = m/(sqrt(v)+eps)
    expect = np.array([1.0, -2.0]) - 0.1 * (0.5 / (0.5 + 1e-8))
    np.testing.assert_allclose(np.asarray(p2["w"]), expect, rtol=1e-6)


def test_weight_decay_decoupled():
    opt = adamw(0.1, weight_decay=0.1)
    p = {"w": jnp.array([2.0])}
    g = {"w": jnp.array([0.0])}
    st = opt.init(p)
    p2, _ = opt.update(g, st, p)
    np.testing.assert_allclose(np.asarray(p2["w"]), [2.0 - 0.1 * 0.1 * 2.0],
                               rtol=1e-6)


def test_grad_clip():
    opt = adamw(0.0, grad_clip=1.0, weight_decay=0.0)
    g = {"w": jnp.array([30.0, 40.0])}  # norm 50 -> scaled by 1/50
    assert abs(float(global_norm(g)) - 50.0) < 1e-4
    p = {"w": jnp.zeros(2)}
    st = opt.init(p)
    _, st2 = opt.update(g, st, p)
    np.testing.assert_allclose(np.asarray(st2.m["w"]),
                               0.1 * np.array([0.6, 0.8]), rtol=1e-5)


def test_adamw_converges_quadratic():
    opt = adamw(0.05)
    target = jnp.array([3.0, -1.0, 0.5])
    p = {"w": jnp.zeros(3)}
    st = opt.init(p)
    loss = lambda p: jnp.sum((p["w"] - target) ** 2)
    step = jax.jit(lambda p, st: (lambda g: opt.update(g, st, p))(jax.grad(loss)(p)))
    for _ in range(500):
        p, st = step(p, st)
    assert float(loss(p)) < 1e-2


def test_warmup_cosine_shape():
    s = warmup_cosine(1.0, 10, 100)
    assert float(s(jnp.array(0))) == 0.0
    np.testing.assert_allclose(float(s(jnp.array(10))), 1.0, rtol=1e-5)
    assert float(s(jnp.array(55))) < 1.0
    np.testing.assert_allclose(float(s(jnp.array(100))), 0.0, atol=1e-6)

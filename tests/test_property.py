"""Hypothesis property tests on system invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
hypothesis = pytest.importorskip("hypothesis")  # not in every container
from hypothesis import given, settings, strategies as st

from repro.configs.base import ArchConfig
from repro.models import transformer
from repro.models.attention import sdpa_chunked, sdpa_naive
from repro.models.common import apply_rope, rmsnorm, rmsnorm_init


@settings(max_examples=15, deadline=None)
@given(S=st.integers(4, 48), hd=st.sampled_from([8, 16, 32]),
       seed=st.integers(0, 999))
def test_rope_preserves_norm_and_relativity(S, hd, seed):
    """RoPE is an orthogonal transform; scores depend on relative offset."""
    key = jax.random.PRNGKey(seed)
    x = jax.random.normal(key, (1, S, 2, hd))
    pos = jnp.arange(S)
    y = apply_rope(x, pos)
    np.testing.assert_allclose(np.linalg.norm(np.asarray(y), axis=-1),
                               np.linalg.norm(np.asarray(x), axis=-1),
                               rtol=1e-4)
    # shifting all positions by a constant leaves q.k scores unchanged
    q = jax.random.normal(key, (1, 1, 2, hd))
    k = jax.random.normal(jax.random.PRNGKey(seed + 1), (1, 1, 2, hd))
    def score(off):
        qq = apply_rope(q, pos[:1] + off)
        kk = apply_rope(k, pos[:1] + off)
        return np.asarray(jnp.einsum("bshd,bshd->bsh", qq, kk))
    np.testing.assert_allclose(score(0), score(17), rtol=1e-3, atol=1e-4)


@settings(max_examples=10, deadline=None)
@given(S=st.integers(8, 64), seed=st.integers(0, 999))
def test_attention_causality(S, seed):
    """Perturbing future tokens never changes past outputs."""
    key = jax.random.PRNGKey(seed)
    q = jax.random.normal(key, (1, S, 2, 16))
    k = jax.random.normal(jax.random.PRNGKey(seed + 1), (1, S, 2, 16))
    v = jax.random.normal(jax.random.PRNGKey(seed + 2), (1, S, 2, 16))
    pos = jnp.arange(S)
    o1 = sdpa_naive(q, k, v, q_pos=pos, k_pos=pos, causal=True)
    t = S // 2
    k2 = k.at[:, t:].add(100.0)
    v2 = v.at[:, t:].add(-50.0)
    o2 = sdpa_naive(q, k2, v2, q_pos=pos, k_pos=pos, causal=True)
    np.testing.assert_allclose(np.asarray(o1[:, :t]), np.asarray(o2[:, :t]),
                               atol=1e-5)


@settings(max_examples=10, deadline=None)
@given(S=st.integers(5, 80), qc=st.sampled_from([4, 8, 16]),
       kc=st.sampled_from([4, 8, 16]), seed=st.integers(0, 999))
def test_chunked_equals_naive(S, qc, kc, seed):
    """Blocked online-softmax == full softmax for any chunking."""
    key = jax.random.PRNGKey(seed)
    q = jax.random.normal(key, (1, S, 4, 8))
    k = jax.random.normal(jax.random.PRNGKey(seed + 1), (1, S, 2, 8))
    v = jax.random.normal(jax.random.PRNGKey(seed + 2), (1, S, 2, 8))
    pos = jnp.arange(S)
    o1 = sdpa_naive(q, k, v, q_pos=pos, k_pos=pos, causal=True)
    o2 = sdpa_chunked(q, k, v, q_pos=pos, k_pos=pos, causal=True,
                      q_chunk=qc, k_chunk=kc)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=2e-5,
                               rtol=2e-5)


@settings(max_examples=15, deadline=None)
@given(d=st.sampled_from([8, 32, 128]), scale=st.floats(0.1, 100.0),
       seed=st.integers(0, 999))
def test_rmsnorm_scale_invariance(d, scale, seed):
    x = jax.random.normal(jax.random.PRNGKey(seed), (3, d))
    p = rmsnorm_init(d)
    y1 = rmsnorm(p, x)
    y2 = rmsnorm(p, x * scale)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-3,
                               rtol=1e-3)


@settings(max_examples=5, deadline=None)
@given(seed=st.integers(0, 99))
def test_lm_causality_end_to_end(seed):
    """Changing token t only affects logits at positions >= t."""
    cfg = ArchConfig(name="t", n_layers=2, d_model=32, n_heads=2, n_kv_heads=2,
                     d_ff=64, vocab=50, remat=False,
                     compute_dtype=jnp.float32)
    params = transformer.lm_init(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(seed), (1, 12), 0, 50)
    l1, _, _ = transformer.lm_apply(params, toks, cfg=cfg)
    toks2 = toks.at[0, 6].set((toks[0, 6] + 1) % 50)
    l2, _, _ = transformer.lm_apply(params, toks2, cfg=cfg)
    np.testing.assert_allclose(np.asarray(l1[:, :6]), np.asarray(l2[:, :6]),
                               atol=1e-4)
    assert float(jnp.abs(l1[:, 6:] - l2[:, 6:]).max()) > 1e-4

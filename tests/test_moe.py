"""MoE: dispatch/combine vs dense per-token reference; aux loss; capacity."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ArchConfig
from repro.models.moe import moe_apply, moe_init, _capacity


def _cfg(**kw):
    base = dict(name="m", n_layers=2, d_model=16, n_heads=2, n_kv_heads=2,
                d_ff=32, vocab=64, n_experts=4, top_k=2, d_ff_expert=24,
                capacity_factor=8.0,  # ample: no drops
                compute_dtype=jnp.float32, remat=False)
    base.update(kw)
    return ArchConfig(**base)


def dense_moe_reference(params, x, cfg):
    """Per-token dense reference: y_t = sum_k gate * FFN_{e_k}(x_t)."""
    B, S, d = x.shape
    xf = x.reshape(-1, d)
    logits = xf @ params["router"]
    probs = jax.nn.softmax(logits, -1)
    gate, choice = jax.lax.top_k(probs, cfg.top_k)
    gate = gate / gate.sum(-1, keepdims=True)
    wg, wu, wd = params["w_gate"], params["w_up"], params["w_down"]
    # all experts on all tokens, then select
    h = jax.nn.silu(jnp.einsum("td,edf->tef", xf, wg)) * jnp.einsum(
        "td,edf->tef", xf, wu)
    y_all = jnp.einsum("tef,efd->ted", h, wd)          # (T,E,d)
    oh = jax.nn.one_hot(choice, cfg.n_experts)          # (T,k,E)
    y = jnp.einsum("tke,ted,tk->td", oh, y_all, gate)
    return y.reshape(B, S, d)


def test_moe_matches_dense_reference():
    cfg = _cfg()
    key = jax.random.PRNGKey(0)
    p = moe_init(key, cfg)
    x = jax.random.normal(key, (2, 16, cfg.d_model))
    y, aux = moe_apply(p, x, cfg=cfg, group_size=8)
    y_ref = dense_moe_reference(p, x, cfg)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), atol=1e-4,
                               rtol=1e-4)
    assert float(aux) > 0


def test_moe_capacity_drops_are_bounded():
    """With tight capacity some tokens drop; output stays finite and within
    the convex hull scale of expert outputs."""
    cfg = _cfg(capacity_factor=0.5)
    key = jax.random.PRNGKey(1)
    p = moe_init(key, cfg)
    x = jax.random.normal(key, (2, 32, cfg.d_model))
    y, aux = moe_apply(p, x, cfg=cfg, group_size=16)
    assert bool(jnp.isfinite(y).all())
    y_full, _ = moe_apply(p, x, cfg=_cfg(), group_size=16)
    # dropped-token output is a (gated) subset: norm can only shrink
    assert float(jnp.linalg.norm(y)) <= float(jnp.linalg.norm(y_full)) * 1.5


def test_shared_experts_added():
    cfg = _cfg(n_shared_experts=1)
    key = jax.random.PRNGKey(2)
    p = moe_init(key, cfg)
    assert "shared" in p
    x = jax.random.normal(key, (1, 8, cfg.d_model))
    y, _ = moe_apply(p, x, cfg=cfg, group_size=8)
    assert bool(jnp.isfinite(y).all())


def test_capacity_formula():
    assert _capacity(512, 8, 40, 1.25) % 8 == 0
    assert _capacity(512, 8, 40, 1.25) >= 512 * 8 / 40

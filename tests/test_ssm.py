"""SSM correctness: chunked SSD vs naive recurrence; step vs full-sequence."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ArchConfig
from repro.models import ssm


def naive_ssd(xh, dtv, A, Bm, Cm):
    """Sequential reference recurrence for SSD."""
    B_, S, H, P = xh.shape
    N = Bm.shape[-1]
    h = np.zeros((B_, H, P, N))
    ys = []
    x = np.asarray(xh, np.float64)
    dt = np.asarray(dtv, np.float64)
    A = np.asarray(A, np.float64)
    Bn = np.asarray(Bm, np.float64)
    Cn = np.asarray(Cm, np.float64)
    for t in range(S):
        dA = np.exp(dt[:, t] * A)                       # (B,H)
        h = h * dA[..., None, None] + np.einsum(
            "bh,bhp,bn->bhpn", dt[:, t], x[:, t], Bn[:, t])
        ys.append(np.einsum("bhpn,bn->bhp", h, Cn[:, t]))
    return np.stack(ys, 1), h


@pytest.mark.parametrize("S,chunk", [(32, 8), (64, 16), (48, 16)])
def test_ssd_chunked_vs_naive(S, chunk):
    key = jax.random.PRNGKey(0)
    B_, H, P, N = 2, 3, 8, 5
    ks = jax.random.split(key, 4)
    xh = jax.random.normal(ks[0], (B_, S, H, P))
    dtv = jax.nn.softplus(jax.random.normal(ks[1], (B_, S, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
    Bm = jax.random.normal(ks[3], (B_, S, N)) * 0.5
    Cm = jax.random.normal(ks[0], (B_, S, N)) * 0.5
    y, hT = ssm.ssd_chunked(xh, dtv, A, Bm, Cm, chunk)
    y_ref, h_ref = naive_ssd(xh, dtv, A, Bm, Cm)
    np.testing.assert_allclose(np.asarray(y), y_ref, atol=2e-4, rtol=2e-4)
    np.testing.assert_allclose(np.asarray(hT), h_ref, atol=2e-4, rtol=2e-4)


def _tiny_cfg(**kw):
    base = dict(name="t", n_layers=1, d_model=32, n_heads=4, n_kv_heads=4,
                d_ff=0, vocab=64, ssm_state=8, ssm_heads=4, ssm_chunk=8,
                remat=False, compute_dtype=jnp.float32)
    base.update(kw)
    return ArchConfig(**base)


@pytest.mark.parametrize("block", ["mamba2", "mlstm", "slstm"])
def test_step_matches_full_sequence(block):
    """Prefill S tokens then decode 1 == full apply on S+1 tokens."""
    cfg = _tiny_cfg()
    key = jax.random.PRNGKey(1)
    S = 16
    x = jax.random.normal(key, (2, S + 1, cfg.d_model)) * 0.5
    init = {"mamba2": ssm.mamba2_init, "mlstm": ssm.mlstm_init,
            "slstm": ssm.slstm_init}[block]
    apply = {"mamba2": ssm.mamba2_apply, "mlstm": ssm.mlstm_apply,
             "slstm": ssm.slstm_apply}[block]
    step = {"mamba2": ssm.mamba2_step, "mlstm": ssm.mlstm_step,
            "slstm": ssm.slstm_step}[block]
    p = init(key, cfg)
    y_full = apply(p, x, cfg=cfg)
    _, state = apply(p, x[:, :S], cfg=cfg, return_state=True)
    y_step, _ = step(p, x[:, S:], state, cfg=cfg)
    np.testing.assert_allclose(np.asarray(y_step[:, 0]),
                               np.asarray(y_full[:, S]), atol=1e-4, rtol=1e-3)


def test_mamba2_chunk_invariance():
    """Output must not depend on the chunk size."""
    cfg8 = _tiny_cfg(ssm_chunk=8)
    cfg4 = _tiny_cfg(ssm_chunk=4)
    key = jax.random.PRNGKey(2)
    x = jax.random.normal(key, (1, 32, cfg8.d_model)) * 0.5
    p = ssm.mamba2_init(key, cfg8)
    y8 = ssm.mamba2_apply(p, x, cfg=cfg8)
    y4 = ssm.mamba2_apply(p, x, cfg=cfg4)
    np.testing.assert_allclose(np.asarray(y8), np.asarray(y4), atol=2e-5,
                               rtol=2e-5)


def test_mlstm_chunkwise_vs_scan():
    """Chunkwise-parallel mLSTM (§Perf-1) is algebraically exact vs the
    step cell, including the carried (C, n, m) state, for ragged chunks."""
    import numpy as np
    from repro.models.ssm import mlstm_chunkwise, _mlstm_cell
    key = jax.random.PRNGKey(0)
    B, S, H, dk = 2, 37, 3, 8
    ks = jax.random.split(key, 5)
    q = jax.random.normal(ks[0], (B, S, H, dk))
    k = jax.random.normal(ks[1], (B, S, H, dk)) / np.sqrt(dk)
    v = jax.random.normal(ks[2], (B, S, H, dk))
    ig = jax.random.normal(ks[3], (B, S, H)) * 2
    fg = jax.random.normal(ks[4], (B, S, H)) * 2
    st = (jnp.zeros((B, H, dk, dk)), jnp.zeros((B, H, dk)),
          jnp.full((B, H), -1e30))
    ys = []
    for t in range(S):
        y, st = _mlstm_cell(q[:, t], k[:, t], v[:, t], ig[:, t], fg[:, t], st)
        ys.append(y)
    y_ref = jnp.stack(ys, 1)
    for chunk in (5, 16, 64):
        y, (C, n, m) = mlstm_chunkwise(q, k, v, ig, fg, chunk)
        np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                                   atol=2e-5, rtol=2e-5)
        np.testing.assert_allclose(np.asarray(C), np.asarray(st[0]),
                                   atol=2e-5, rtol=2e-5)
        np.testing.assert_allclose(np.asarray(m), np.asarray(st[2]),
                                   atol=2e-5, rtol=2e-5)


def test_mlstm_apply_chunked_equals_scan_path():
    cfg = _tiny_cfg(ssm_chunk=8)
    key = jax.random.PRNGKey(3)
    x = jax.random.normal(key, (2, 20, cfg.d_model)) * 0.5
    p = ssm.mlstm_init(key, cfg)
    y_c = ssm.mlstm_apply(p, x, cfg=cfg, use_chunked=True)
    y_s = ssm.mlstm_apply(p, x, cfg=cfg, use_chunked=False)
    np.testing.assert_allclose(np.asarray(y_c), np.asarray(y_s), atol=2e-5,
                               rtol=2e-4)

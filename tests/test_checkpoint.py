"""Checkpoint roundtrip incl. NamedTuple optimizer state."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.optim import adamw
from repro.train import checkpoint


def test_roundtrip(tmp_path):
    params = {"a": {"w": jnp.arange(6.0).reshape(2, 3)},
              "b": (jnp.ones(4), jnp.zeros((2, 2), jnp.int32))}
    opt = adamw(1e-3)
    st = opt.init({"a": params["a"]})
    path = str(tmp_path / "ck.npz")
    checkpoint.save(path, {"params": params, "opt": st}, metadata={"step": 7})
    template = jax.tree_util.tree_map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
        {"params": params, "opt": st})
    out = checkpoint.restore(path, template)
    np.testing.assert_array_equal(np.asarray(out["params"]["a"]["w"]),
                                  np.arange(6.0).reshape(2, 3))
    assert out["params"]["b"][1].dtype == jnp.int32
    assert type(out["opt"]).__name__ == "AdamWState"
    assert checkpoint.load_metadata(path)["step"] == 7


def test_trainstate_roundtrip_with_npz_midstring_path(tmp_path):
    """NamedTuple TrainState tree survives save→restore, including through a
    directory whose name contains ``.npz`` mid-string (the sidecar path used
    to be derived with ``str.replace`` and corrupted such paths)."""
    from repro.engine import TrainState

    params = {"w": jnp.arange(8.0).reshape(2, 4), "b": jnp.zeros(4)}
    opt = adamw(1e-3)
    st = TrainState.create(params, opt, rng=jax.random.PRNGKey(3))
    d = tmp_path / "run.npz.bak"
    d.mkdir()
    path = str(d / "ck.npz")
    checkpoint.save(path, st._asdict(), metadata={"step": 11})
    # the sidecar must land NEXT to the .npz, not at a mangled path
    assert (d / "ck.meta.json").exists()
    assert checkpoint.load_metadata(path)["step"] == 11

    template = jax.tree_util.tree_map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), st._asdict())
    out = checkpoint.restore(path, template)
    restored = TrainState(**out)
    np.testing.assert_array_equal(np.asarray(restored.params["w"]),
                                  np.arange(8.0).reshape(2, 4))
    assert type(restored.opt_state).__name__ == "AdamWState"
    assert int(restored.step) == 0
    np.testing.assert_array_equal(np.asarray(restored.rng),
                                  np.asarray(jax.random.PRNGKey(3)))


def test_sharded_save_restores_to_host(tmp_path):
    """A tree saved from mesh-sharded arrays restores onto a host template
    (no .sharding) as plain host-resident arrays with identical values."""
    from jax.sharding import Mesh, NamedSharding, PartitionSpec

    mesh = Mesh(np.array(jax.devices()[:1]), ("data",))
    sharding = NamedSharding(mesh, PartitionSpec())
    tree = {"w": jax.device_put(jnp.arange(12.0).reshape(3, 4), sharding)}
    path = str(tmp_path / "sharded.npz")
    checkpoint.save(path, tree)
    template = {"w": jax.ShapeDtypeStruct((3, 4), jnp.float32)}
    out = checkpoint.restore(path, template)
    np.testing.assert_array_equal(np.asarray(out["w"]),
                                  np.arange(12.0).reshape(3, 4))


def test_restore_into_different_dtype_fails_loudly(tmp_path):
    path = str(tmp_path / "ck.npz")
    checkpoint.save(path, {"w": jnp.ones((2, 2))})
    bad = {"w": jax.ShapeDtypeStruct((3, 3), jnp.float32)}
    try:
        checkpoint.restore(path, bad)
        raise RuntimeError("should have failed")
    except AssertionError:
        pass

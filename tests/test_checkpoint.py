"""Checkpoint roundtrip incl. NamedTuple optimizer state."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.optim import adamw
from repro.train import checkpoint


def test_roundtrip(tmp_path):
    params = {"a": {"w": jnp.arange(6.0).reshape(2, 3)},
              "b": (jnp.ones(4), jnp.zeros((2, 2), jnp.int32))}
    opt = adamw(1e-3)
    st = opt.init({"a": params["a"]})
    path = str(tmp_path / "ck.npz")
    checkpoint.save(path, {"params": params, "opt": st}, metadata={"step": 7})
    template = jax.tree_util.tree_map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
        {"params": params, "opt": st})
    out = checkpoint.restore(path, template)
    np.testing.assert_array_equal(np.asarray(out["params"]["a"]["w"]),
                                  np.arange(6.0).reshape(2, 3))
    assert out["params"]["b"][1].dtype == jnp.int32
    assert type(out["opt"]).__name__ == "AdamWState"
    assert checkpoint.load_metadata(path)["step"] == 7


def test_restore_into_different_dtype_fails_loudly(tmp_path):
    path = str(tmp_path / "ck.npz")
    checkpoint.save(path, {"w": jnp.ones((2, 2))})
    bad = {"w": jax.ShapeDtypeStruct((3, 3), jnp.float32)}
    try:
        checkpoint.restore(path, bad)
        raise RuntimeError("should have failed")
    except AssertionError:
        pass

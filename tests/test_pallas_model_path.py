"""End-to-end model forward/decode through the Pallas kernels (interpret
mode) must match the jnp lowering path — the kernels are drop-in."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models import transformer


def _cfg(**kw):
    base = dict(name="t", n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                d_ff=128, vocab=97, head_dim=16, remat=False,
                compute_dtype=jnp.float32)
    base.update(kw)
    return ArchConfig(**base)


def test_train_forward_pallas_matches_chunked():
    cfg = _cfg()
    params = transformer.lm_init(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 48), 0, cfg.vocab)
    l_ref, _, _ = transformer.lm_apply(params, toks, cfg=cfg, impl="naive")
    l_pal, _, _ = transformer.lm_apply(params, toks, cfg=cfg, impl="pallas")
    np.testing.assert_allclose(np.asarray(l_pal), np.asarray(l_ref),
                               atol=5e-4, rtol=5e-4)


def test_decode_pallas_matches_naive():
    cfg = _cfg(block_pattern=("swa",), window=16)
    params = transformer.lm_init(jax.random.PRNGKey(2), cfg)
    S = 24
    toks = jax.random.randint(jax.random.PRNGKey(3), (2, S), 0, cfg.vocab)
    caches_a = transformer.lm_cache_init(params, cfg, 2, 32)
    caches_b = transformer.lm_cache_init(params, cfg, 2, 32)
    for t in range(S):
        la, caches_a, _ = transformer.lm_apply(
            params, toks[:, t:t + 1], cfg=cfg, mode="decode", caches=caches_a,
            positions=jnp.array([t]), impl="naive")
        lb, caches_b, _ = transformer.lm_apply(
            params, toks[:, t:t + 1], cfg=cfg, mode="decode", caches=caches_b,
            positions=jnp.array([t]), impl="pallas")
        np.testing.assert_allclose(np.asarray(lb), np.asarray(la),
                                   atol=5e-4, rtol=5e-4, err_msg=f"t={t}")

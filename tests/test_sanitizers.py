"""RecompileSanitizer: declared XLA-compilation budgets, unit + end-to-end.

The end-to-end case is the compile-count regression the ISSUE asks for: a
20-step Session run must compile its train step EXACTLY once — a second
compilation means a shape/dtype leaked into the traced signature.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import RecompileBudgetError, RecompileSanitizer
from repro.configs.base import ArchConfig
from repro.data.synthetic_atoms import generate_all
from repro.engine import Session, SessionConfig, ShardingPlan, make_step
from repro.engine import TrainState, build_model
from repro.optim import adamw


class FakeJit:
    """A cache-size seam without jax — the ``CompiledStep.cache_size``
    duck type."""

    def __init__(self, n=0):
        self.n = n

    def cache_size(self):
        return self.n


def _gfm_cfg():
    return ArchConfig(name="g", family="gnn", gnn_hidden=24, gnn_layers=2,
                      n_species=64, head_hidden=12, head_layers=2,
                      remat=False, compute_dtype=jnp.float32)


def _gfm_sources(n=24, n_tasks=3):
    data = generate_all(n, max_atoms=10, max_edges=40,
                        sources=["ani1x", "qm7x", "mptrj"][:n_tasks])
    return [dict(species=s.species, pos=s.pos, edge_src=s.edge_src,
                 edge_dst=s.edge_dst, node_mask=s.node_mask,
                 edge_mask=s.edge_mask, energy=s.energy, forces=s.forces)
            for s in data.values()]


# ---------------------------------------------------------------------------
# unit: the probe + budget accounting
# ---------------------------------------------------------------------------

def test_counts_cache_growth_since_tracking():
    fn = FakeJit(n=3)                       # warmed up before tracking
    san = RecompileSanitizer(budget=1)
    assert san.track(fn, "step")
    assert san.compilations() == 0          # pre-existing compiles don't count
    fn.n = 4
    assert san.compilations() == 1
    san.check()                             # at budget: fine
    fn.n = 5
    with pytest.raises(RecompileBudgetError, match="step=2"):
        san.check()


def test_untracked_objects_are_reported():
    san = RecompileSanitizer(budget=0)
    assert not san.track(object())          # no seam -> not tracked
    assert san.report() == {}


def test_context_manager_checks_on_clean_exit():
    fn = FakeJit()
    with pytest.raises(RecompileBudgetError):
        with RecompileSanitizer(budget=0, label="unit") as san:
            san.track(fn)
            fn.n = 1
    # an in-flight exception wins over the budget check
    with pytest.raises(KeyError):
        with RecompileSanitizer(budget=0) as san:
            san.track(fn)
            fn.n = 2
            raise KeyError("boom")


def test_tracks_raw_jax_jit_cache():
    @jax.jit
    def f(x):
        return x * 2

    san = RecompileSanitizer(budget=1)
    assert san.track(f, "f")
    f(jnp.ones((4,)))
    f(jnp.ones((4,)))                       # cache hit
    assert san.compilations() == 1
    san.check()
    f(jnp.ones((5,)))                       # shape churn -> second compile
    with pytest.raises(RecompileBudgetError, match="f=2"):
        san.check()


def test_tracks_compiled_step_seam():
    cfg = _gfm_cfg()
    from repro.core import MTPConfig, make_gfm_mtl
    from repro.data.loader import GroupBatcher
    model = make_gfm_mtl(cfg, 3)
    plan = ShardingPlan(mtp=MTPConfig(n_tasks=3), donate=False)
    step = plan.compile(make_step(model, adamw(1e-3), plan))
    assert step.cache_size() == 0           # lazy: nothing compiled yet
    san = RecompileSanitizer(budget=1)
    assert san.track(step, "step")
    gb = GroupBatcher(_gfm_sources(), 8)
    state = TrainState.create(model.init(jax.random.PRNGKey(0)), adamw(1e-3))
    state, _ = step(state, gb.next_batch())
    state, _ = step(state, gb.next_batch())
    assert san.compilations() == 1
    san.check()


# ---------------------------------------------------------------------------
# end to end: the 20-step Session compile-count regression
# ---------------------------------------------------------------------------

def test_session_20_steps_compile_once():
    """Fixed-shape GroupBatcher batches must hit one executable: 20 steps,
    budget 1 (the single lazy-jit build). More means a recompile leak."""
    scfg = SessionConfig(model="gfm-mtl", arch=_gfm_cfg(), steps=20,
                         batch_per_task=8, lr=3e-3, verbose=False)
    sess = Session.from_config(scfg, sources=_gfm_sources(),
                               task_names=["a", "b", "c"])
    with RecompileSanitizer(budget=1, label="20-step session") as san:
        san.track_session(sess)
        res = sess.run()
    assert np.isfinite(res.final_loss) and int(res.state.step) == 20
    assert san.compilations() == 1, san.report()


def test_track_session_sees_rebuilt_step():
    """The live probe must count compiles of a step REBUILT mid-run (the
    quarantine path swaps Session.compiled_step for a new object)."""
    scfg = SessionConfig(model="gfm-mtl", arch=_gfm_cfg(), steps=2,
                         batch_per_task=8, lr=3e-3, verbose=False)
    sess = Session.from_config(scfg, sources=_gfm_sources(),
                               task_names=["a", "b", "c"])
    san = RecompileSanitizer(budget=1)
    san.track_session(sess)
    sess.run()
    assert san.compilations() == 1
    sess.quarantine_tasks([2])              # rebuilds + recompiles the step
    sess.run()
    assert san.compilations() == 2
    with pytest.raises(RecompileBudgetError):
        san.check()

"""RecompileSanitizer: declared XLA-compilation budgets, unit + end-to-end.

The end-to-end case is the compile-count regression the ISSUE asks for: a
20-step Session run must compile its train step EXACTLY once — a second
compilation means a shape/dtype leaked into the traced signature. The
hierarchical backend gets the same treatment: a placement change must
rebuild EXACTLY the group executables whose device sets changed.
"""
import json
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import RecompileBudgetError, RecompileSanitizer
from repro.configs.base import ArchConfig
from repro.data.synthetic_atoms import generate_all
from repro.engine import Session, SessionConfig, ShardingPlan, make_step
from repro.engine import TrainState, build_model
from repro.optim import adamw


class FakeJit:
    """A cache-size seam without jax — the ``CompiledStep.cache_size``
    duck type."""

    def __init__(self, n=0):
        self.n = n

    def cache_size(self):
        return self.n


def _gfm_cfg():
    return ArchConfig(name="g", family="gnn", gnn_hidden=24, gnn_layers=2,
                      n_species=64, head_hidden=12, head_layers=2,
                      remat=False, compute_dtype=jnp.float32)


def _gfm_sources(n=24, n_tasks=3):
    data = generate_all(n, max_atoms=10, max_edges=40,
                        sources=["ani1x", "qm7x", "mptrj"][:n_tasks])
    return [dict(species=s.species, pos=s.pos, edge_src=s.edge_src,
                 edge_dst=s.edge_dst, node_mask=s.node_mask,
                 edge_mask=s.edge_mask, energy=s.energy, forces=s.forces)
            for s in data.values()]


# ---------------------------------------------------------------------------
# unit: the probe + budget accounting
# ---------------------------------------------------------------------------

def test_counts_cache_growth_since_tracking():
    fn = FakeJit(n=3)                       # warmed up before tracking
    san = RecompileSanitizer(budget=1)
    assert san.track(fn, "step")
    assert san.compilations() == 0          # pre-existing compiles don't count
    fn.n = 4
    assert san.compilations() == 1
    san.check()                             # at budget: fine
    fn.n = 5
    with pytest.raises(RecompileBudgetError, match="step=2"):
        san.check()


def test_untracked_objects_are_reported():
    san = RecompileSanitizer(budget=0)
    assert not san.track(object())          # no seam -> not tracked
    assert san.report() == {}


def test_context_manager_checks_on_clean_exit():
    fn = FakeJit()
    with pytest.raises(RecompileBudgetError):
        with RecompileSanitizer(budget=0, label="unit") as san:
            san.track(fn)
            fn.n = 1
    # an in-flight exception wins over the budget check
    with pytest.raises(KeyError):
        with RecompileSanitizer(budget=0) as san:
            san.track(fn)
            fn.n = 2
            raise KeyError("boom")


def test_tracks_raw_jax_jit_cache():
    @jax.jit
    def f(x):
        return x * 2

    san = RecompileSanitizer(budget=1)
    assert san.track(f, "f")
    f(jnp.ones((4,)))
    f(jnp.ones((4,)))                       # cache hit
    assert san.compilations() == 1
    san.check()
    f(jnp.ones((5,)))                       # shape churn -> second compile
    with pytest.raises(RecompileBudgetError, match="f=2"):
        san.check()


def test_tracks_compiled_step_seam():
    cfg = _gfm_cfg()
    from repro.core import MTPConfig, make_gfm_mtl
    from repro.data.loader import GroupBatcher
    model = make_gfm_mtl(cfg, 3)
    plan = ShardingPlan(mtp=MTPConfig(n_tasks=3), donate=False)
    step = plan.compile(make_step(model, adamw(1e-3), plan))
    assert step.cache_size() == 0           # lazy: nothing compiled yet
    san = RecompileSanitizer(budget=1)
    assert san.track(step, "step")
    gb = GroupBatcher(_gfm_sources(), 8)
    state = TrainState.create(model.init(jax.random.PRNGKey(0)), adamw(1e-3))
    state, _ = step(state, gb.next_batch())
    state, _ = step(state, gb.next_batch())
    assert san.compilations() == 1
    san.check()


# ---------------------------------------------------------------------------
# end to end: the 20-step Session compile-count regression
# ---------------------------------------------------------------------------

def test_session_20_steps_compile_once():
    """Fixed-shape GroupBatcher batches must hit one executable: 20 steps,
    budget 1 (the single lazy-jit build). More means a recompile leak."""
    scfg = SessionConfig(model="gfm-mtl", arch=_gfm_cfg(), steps=20,
                         batch_per_task=8, lr=3e-3, verbose=False)
    sess = Session.from_config(scfg, sources=_gfm_sources(),
                               task_names=["a", "b", "c"])
    with RecompileSanitizer(budget=1, label="20-step session") as san:
        san.track_session(sess)
        res = sess.run()
    assert np.isfinite(res.final_loss) and int(res.state.step) == 20
    assert san.compilations() == 1, san.report()


def test_track_session_sees_rebuilt_step():
    """The live probe must count compiles of a step REBUILT mid-run (the
    quarantine path swaps Session.compiled_step for a new object)."""
    scfg = SessionConfig(model="gfm-mtl", arch=_gfm_cfg(), steps=2,
                         batch_per_task=8, lr=3e-3, verbose=False)
    sess = Session.from_config(scfg, sources=_gfm_sources(),
                               task_names=["a", "b", "c"])
    san = RecompileSanitizer(budget=1)
    san.track_session(sess)
    sess.run()
    assert san.compilations() == 1
    sess.quarantine_tasks([2])              # rebuilds + recompiles the step
    sess.run()
    assert san.compilations() == 2
    with pytest.raises(RecompileBudgetError):
        san.check()


# ---------------------------------------------------------------------------
# hierarchical backend: per-group executables under the same budget contract
# ---------------------------------------------------------------------------

def test_hier_session_single_device_functions_and_budget():
    """A 1-device hierarchical Session degenerates to one group: Session.
    compiled_functions() must surface the per-group step + the update jit,
    and 5 steps must stay within a 2-compile budget (one per function)."""
    scfg = SessionConfig(model="gfm-mtl", arch=_gfm_cfg(), steps=5,
                         batch_per_task=8, lr=3e-3, verbose=False,
                         placement=1)
    sess = Session.from_config(scfg, sources=_gfm_sources(),
                               task_names=["a", "b", "c"])
    with RecompileSanitizer(budget=2, label="hier 1-device") as san:
        san.track_session(sess)
        res = sess.run()
    assert len(sess.compiled_functions()) == 2   # one group fn + the update
    assert san.compilations() == 2
    assert np.isfinite(res.final_loss) and int(res.state.step) == 5


_HIER_SWAP_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import json
    import jax, jax.numpy as jnp
    from repro.analysis import RecompileSanitizer
    from repro.configs.base import ArchConfig
    from repro.core import HeadPlacement
    from repro.data.synthetic_atoms import generate_all
    from repro.engine import Session, SessionConfig

    assert jax.device_count() == 4
    cfg = ArchConfig(name="g", family="gnn", gnn_hidden=24, gnn_layers=2,
                     n_species=64, head_hidden=12, head_layers=2, remat=False,
                     compute_dtype=jnp.float32)
    data = generate_all(24, max_atoms=10, max_edges=40,
                        sources=["ani1x", "qm7x", "mptrj"])
    sources = [dict(species=s.species, pos=s.pos, edge_src=s.edge_src,
                    edge_dst=s.edge_dst, node_mask=s.node_mask,
                    edge_mask=s.edge_mask, energy=s.energy, forces=s.forces)
               for s in data.values()]
    # same head grouping, shifted device split: only head 2 keeps its
    # device set ({3}), so exactly two group executables must rebuild.
    p1 = HeadPlacement(groups=((0,), (1,), (2,)), device_counts=(2, 1, 1))
    p2 = HeadPlacement(groups=((0,), (1,), (2,)), device_counts=(1, 2, 1))
    scfg = SessionConfig(model="gfm-mtl", arch=cfg, steps=3, batch_per_task=8,
                         lr=3e-3, verbose=False, placement=p1)
    sess = Session.from_config(scfg, sources=sources,
                               task_names=["a", "b", "c"])
    san = RecompileSanitizer(budget=6, label="hier placement swap")
    san.track_session(sess)
    sess.run()
    out = {"compiles_first_run": san.compilations(),
           "n_functions_first": len(sess.compiled_functions())}
    sess.set_placement(p2)
    sess.run()
    out["compiles_after_swap"] = san.compilations()
    out["n_functions_after"] = len(sess.compiled_functions())
    san.check()
    print("RESULT " + json.dumps(out))
""")


def test_hier_placement_change_rebuilds_exactly_affected():
    """Placement (2,1,1) -> (1,2,1) over 4 devices keeps head 2 on device
    {3}: its executable must be REUSED while heads 0/1 rebuild — 4 compiles
    after the first run (3 groups + update), exactly 6 after the swap."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run([sys.executable, "-c", _HIER_SWAP_SCRIPT], env=env,
                          capture_output=True, text=True, timeout=600)
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [l for l in proc.stdout.splitlines()
            if l.startswith("RESULT ")][0]
    out = json.loads(line[len("RESULT "):])
    assert out["compiles_first_run"] == 4
    assert out["n_functions_first"] == 4
    assert out["compiles_after_swap"] == 6      # NOT 7: head 2 reused
    assert out["n_functions_after"] == 6        # old entries kept for reuse

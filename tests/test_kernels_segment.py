"""Pallas segment-sum kernel vs jax.ops.segment_sum oracle + properties."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
hypothesis = pytest.importorskip("hypothesis")  # not in every container
from hypothesis import given, settings, strategies as st

from repro.kernels.segment_sum.ops import segment_sum
from repro.kernels.segment_sum.ref import segment_sum_ref


@pytest.mark.parametrize("E,F,N,bn,be", [
    (64, 16, 10, 8, 16),
    (300, 48, 33, 16, 64),
    (128, 128, 128, 128, 128),
    (7, 5, 3, 8, 8),
])
def test_segment_sum_matches_ref(E, F, N, bn, be):
    key = jax.random.PRNGKey(0)
    msg = jax.random.normal(key, (2, E, F))
    dst = jax.random.randint(key, (2, E), 0, N)
    mask = jax.random.bernoulli(key, 0.7, (2, E))
    o = segment_sum(msg, dst, N, edge_mask=mask, block_n=bn, block_e=be)
    r = jnp.stack([segment_sum_ref(jnp.where(mask[i][:, None], msg[i], 0),
                                   dst[i], N) for i in range(2)])
    np.testing.assert_allclose(np.asarray(o), np.asarray(r), atol=1e-5, rtol=1e-5)


@pytest.mark.parametrize("dtype,tol", [(jnp.float32, 1e-5), (jnp.bfloat16, 5e-2)])
def test_segment_sum_dtypes(dtype, tol):
    key = jax.random.PRNGKey(1)
    msg = jax.random.normal(key, (1, 96, 24), dtype)
    dst = jax.random.randint(key, (1, 96), 0, 17)
    o = segment_sum(msg, dst, 17, block_n=8, block_e=32)
    r = segment_sum_ref(msg[0], dst[0], 17)
    np.testing.assert_allclose(np.asarray(o[0], np.float32),
                               np.asarray(r, np.float32), atol=tol, rtol=tol)


@settings(max_examples=20, deadline=None)
@given(E=st.integers(4, 80), N=st.integers(2, 40), seed=st.integers(0, 2 ** 16))
def test_segment_sum_property(E, N, seed):
    """Linearity + mass conservation: summing the output over nodes equals
    summing the (unmasked) messages over edges."""
    key = jax.random.PRNGKey(seed)
    msg = jax.random.normal(key, (1, E, 4))
    dst = jax.random.randint(key, (1, E), 0, N)
    o = segment_sum(msg, dst, N, block_n=8, block_e=16)
    np.testing.assert_allclose(np.asarray(o.sum(1)), np.asarray(msg.sum(1)),
                               atol=1e-4, rtol=1e-4)
    # linearity
    o2 = segment_sum(2.0 * msg, dst, N, block_n=8, block_e=16)
    np.testing.assert_allclose(np.asarray(o2), 2 * np.asarray(o), atol=1e-4,
                               rtol=1e-4)

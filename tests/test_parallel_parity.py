"""Cross-plan numerical parity: the hierarchical backend vs the flat
(data, task) pjit mesh vs single-device jit, all built through the ONE
public path (``engine.make_step`` + ``ShardingPlan.compile``).

Per-task losses must agree within fp32 tolerance for 3 optimizer steps on
8 host devices, for BOTH an even 4-heads split and the ragged
5-heads-on-8-devices paper configuration (the hierarchical plan's whole
point — a flat mesh can't express it). Needs >1 device, so runs in a
subprocess with ``--xla_force_host_platform_device_count=8`` (the main
pytest process keeps 1 device).
"""
import json
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import jax, jax.numpy as jnp
    import numpy as np
    from repro.configs.base import ArchConfig
    from repro.core import MTPConfig, make_gfm_mtl, solve_placement
    from repro.data.synthetic_atoms import (PAPER_REL_SIZES, generate_all,
                                            to_batch_dict)
    from repro.engine import ShardingPlan, TrainState, make_step
    from repro.launch.mesh import make_host_mesh
    from repro.optim import adamw

    assert jax.device_count() == 8
    cfg = ArchConfig(name="g", family="gnn", gnn_hidden=24, gnn_layers=2,
                     n_species=64, head_hidden=12, head_layers=2, remat=False,
                     compute_dtype=jnp.float32)

    def run_case(sources, mesh_shape):
        T = len(sources)
        model = make_gfm_mtl(cfg, T)
        params = model.init(jax.random.PRNGKey(0))
        data = generate_all(16, max_atoms=10, max_edges=40, sources=sources)
        rng = np.random.default_rng(0)
        batches = []
        for _ in range(3):                      # 3 steps, 3 distinct batches
            idx = rng.integers(0, 16, size=8)
            bs = [to_batch_dict(sd, idx) for sd in data.values()]
            batches.append({k: jnp.stack([b[k] for b in bs]) for k in bs[0]})
        tw = tuple(PAPER_REL_SIZES[s] for s in sources)
        opt = adamw(1e-3)
        mtp = MTPConfig(n_tasks=T, mode="par")
        plans = {
            "jit": ShardingPlan(mtp=mtp, donate=False),
            "pjit": ShardingPlan(mesh=make_host_mesh(*mesh_shape), mtp=mtp,
                                 backend="pjit", donate=False),
            "hier": ShardingPlan(placement=solve_placement(8, tw),
                                 donate=False),
        }
        out = {}
        for name, plan in plans.items():
            step = plan.compile(make_step(model, opt, plan, task_weights=tw))
            state = plan.shard_state(TrainState.create(params, opt))
            losses, per_task = [], []
            for b in batches:
                state, o = step(state, plan.shard_batch(b))
                losses.append(float(o.loss))
                per_task.append(np.asarray(o.metrics["per_task_loss"],
                                           np.float64).tolist())
            row = {"loss": losses, "per_task": per_task}
            if plan.placement is not None:
                row["groups"] = [list(g) for g in plan.placement.groups]
                row["device_counts"] = list(plan.placement.device_counts)
            out[name] = row
        return out

    res = {
        "even4": run_case(["ani1x", "qm7x", "mptrj", "alexandria"], (2, 4)),
        "ragged5": run_case(list(PAPER_REL_SIZES), (1, 5)),
    }
    print("RESULT " + json.dumps(res))
""")


@pytest.fixture(scope="module")
def result():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                          capture_output=True, text=True, timeout=600)
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [l for l in proc.stdout.splitlines() if l.startswith("RESULT ")][0]
    return json.loads(line[len("RESULT "):])


# fp32 tolerance over 3 steps: summation order is the only difference
RTOL, ATOL = 5e-5, 1e-6


@pytest.mark.parametrize("case", ["even4", "ragged5"])
@pytest.mark.parametrize("backend", ["pjit", "hier"])
def test_per_task_losses_match_single_device(result, case, backend):
    ref = np.asarray(result[case]["jit"]["per_task"])
    got = np.asarray(result[case][backend]["per_task"])
    assert got.shape == ref.shape
    np.testing.assert_allclose(got, ref, rtol=RTOL, atol=ATOL)


@pytest.mark.parametrize("case", ["even4", "ragged5"])
@pytest.mark.parametrize("backend", ["pjit", "hier"])
def test_total_losses_match_single_device(result, case, backend):
    np.testing.assert_allclose(result[case][backend]["loss"],
                               result[case]["jit"]["loss"],
                               rtol=RTOL, atol=ATOL)


def test_hier_vs_pjit_directly(result):
    for case in ("even4", "ragged5"):
        np.testing.assert_allclose(
            np.asarray(result[case]["hier"]["per_task"]),
            np.asarray(result[case]["pjit"]["per_task"]),
            rtol=RTOL, atol=ATOL)


def test_ragged_split_really_is_ragged(result):
    """5 heads over 8 devices: the solver's uneven split (no flat mesh can
    express it) — transition1x gets 3 devices, and the groups cover all 8."""
    row = result["ragged5"]["hier"]
    assert row["device_counts"] == [2, 1, 3, 1, 1]
    assert sum(row["device_counts"]) == 8
    assert sorted(h for g in row["groups"] for h in g) == [0, 1, 2, 3, 4]
    assert len(set(row["device_counts"])) > 1   # genuinely uneven


def test_losses_evolve_over_steps(result):
    """3 steps actually train (losses change), so parity is not vacuous."""
    for case in ("even4", "ragged5"):
        losses = result[case]["jit"]["loss"]
        assert len(losses) == 3
        assert len({round(l, 8) for l in losses}) == 3

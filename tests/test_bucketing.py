"""Size-bucketed dynamic batching (repro.data.bucketing): grid planning,
content-exact trimming, sentinel contract, model-path parity, and the pad
reduction the subsystem exists for."""
import numpy as np
import pytest

from repro.data.bucketing import (ATOM_KEYS, EDGE_KEYS, BucketingBatcher,
                                  BucketOverflowError, BucketSpec,
                                  pad_fraction)
from repro.data.loader import GroupBatcher
from repro.data.synthetic_atoms import generate_mixture, source_dicts


def _mixture(total=50, max_atoms=48, max_edges=512):
    """Paper-shaped regime: stored pad shape larger than any content."""
    return source_dicts(generate_mixture(total, max_atoms=max_atoms,
                                         max_edges=max_edges, seed=0))


# ---------------------------------------------------------------------------
# BucketSpec
# ---------------------------------------------------------------------------

def test_spec_validation_and_ceil():
    spec = BucketSpec((8, 16, 32), (64, 256))
    assert spec.n_shapes == 6
    assert spec.ceil(1, 1) == (8, 64)
    assert spec.ceil(8, 64) == (8, 64)       # inclusive ceilings
    assert spec.ceil(9, 65) == (16, 256)
    with pytest.raises(BucketOverflowError):
        spec.ceil(33, 1)                      # beyond the grid
    with pytest.raises(AssertionError):
        BucketSpec((16, 8), (64,))            # not ascending


def test_bucket_for_boundaries_and_overflow():
    """The public single-sample lookup (serve admission + BucketingBatcher
    both route through it): inclusive ceilings at every grid boundary, and
    a clear BucketOverflowError naming the offending axis beyond the cap."""
    spec = BucketSpec((8, 16), (64, 128))
    # exact boundary on each axis stays in the smaller bucket
    assert spec.bucket_for(8, 128) == (8, 128)
    assert spec.bucket_for(16, 64) == (16, 64)
    assert spec.bucket_for(0, 0) == (8, 64)   # empty structure still binned
    assert spec.bucket_for(16, 128) == (16, 128)   # grid cap itself fits
    with pytest.raises(BucketOverflowError, match="17 atoms"):
        spec.bucket_for(17, 1)
    with pytest.raises(BucketOverflowError, match="129 edges"):
        spec.bucket_for(1, 129)
    # BucketOverflowError is a ValueError: callers without the serve
    # admission path in mind still fail loudly, not with a bare assert
    with pytest.raises(ValueError):
        spec.bucket_for(99, 99)
    with pytest.raises(ValueError, match="negative"):
        spec.bucket_for(-1, 0)


def test_spec_from_sources_covers_data_and_is_capped():
    sources = _mixture()
    spec = BucketSpec.from_sources(sources)
    a_cap = sources[0]["node_mask"].shape[-1]
    e_cap = sources[0]["edge_mask"].shape[-1]
    assert spec.atom_buckets[-1] == a_cap
    assert spec.edge_buckets[-1] == e_cap
    # every sample of every source has a bucket
    for s in sources:
        for nm, em in zip(s["node_mask"], s["edge_mask"]):
            spec.ceil(int(nm.sum()), int(em.sum()))


# ---------------------------------------------------------------------------
# BucketingBatcher
# ---------------------------------------------------------------------------

def test_trim_preserves_all_content_task_major():
    sources = _mixture()
    spec = BucketSpec.from_sources(sources)
    full = GroupBatcher(sources, 4, seed=0)
    trim = BucketingBatcher(GroupBatcher(sources, 4, seed=0), spec)
    for _ in range(8):
        a, b = full.next_batch(), trim.next_batch()
        A_t, E_t = b["node_mask"].shape[-1], b["edge_mask"].shape[-1]
        assert (A_t, E_t) in {(x, y) for x in spec.atom_buckets
                              for y in spec.edge_buckets}
        # identical real content: the trimmed batch is the full batch minus
        # trailing pad
        for k in ATOM_KEYS:
            np.testing.assert_array_equal(np.asarray(a[k])[:, :, :A_t], b[k])
        assert a["node_mask"].sum() == b["node_mask"].sum()
        assert a["edge_mask"].sum() == b["edge_mask"].sum()
        # real edges untouched, masked edges re-pointed at the trimmed
        # sentinel A_t (the >= n_nodes kernel contract)
        em = b["edge_mask"]
        for k in ("edge_src", "edge_dst"):
            np.testing.assert_array_equal(
                np.asarray(a[k])[:, :, :E_t][em], b[k][em])
            assert (b[k][~em] == A_t).all()
        assert b["energy"].shape == a["energy"].shape   # pass-through keys


def test_trim_flat_batches_and_passthrough_keys():
    from repro.data.mixing import MixingBatcher, MixingConfig
    sources = _mixture()
    spec = BucketSpec.from_sources(sources)
    bb = BucketingBatcher(
        MixingBatcher(sources, 8, mixing=MixingConfig(emit_source=True),
                      seed=0), spec)
    b = bb.next_batch()
    assert b["species"].ndim == 2 and b["source_id"].shape == (8,)
    assert b["species"].shape[1] in spec.atom_buckets


def test_strict_mode_catches_non_front_packed_masks():
    sources = _mixture()
    spec = BucketSpec.from_sources(sources)

    class Scrambler:
        """Puts a real atom BEYOND the bucket ceiling (pad not trailing)."""
        def __init__(self):
            self.gb = GroupBatcher(sources, 4, seed=0)

        def next_batch(self):
            b = dict(self.gb.next_batch())
            nm = b["node_mask"].copy()
            nm[..., 0] = False
            nm[..., -1] = True     # real atom in the last stored slot
            b["node_mask"] = nm
            return b

    with pytest.raises(AssertionError, match="front-packed"):
        BucketingBatcher(Scrambler(), spec).next_batch()


def test_bucketed_stream_cuts_pad_fraction():
    """The acceptance metric: mean pad fraction drops vs the single-shape
    pipeline on paper-shaped five-source data."""
    sources = _mixture()
    spec = BucketSpec.from_sources(sources)
    full = GroupBatcher(sources, 4, seed=0)
    trim = BucketingBatcher(GroupBatcher(sources, 4, seed=0), spec)
    f_mean = t_mean = 0.0
    for _ in range(10):
        pf, pt = pad_fraction(full.next_batch()), pad_fraction(trim.next_batch())
        f_mean += (pf["atoms"] + pf["edges"]) / 20
        t_mean += (pt["atoms"] + pt["edges"]) / 20
    assert t_mean < f_mean, (t_mean, f_mean)
    # and the emitted shapes stay within the declared grid (recompile bound)
    assert len(trim.shapes_seen) <= spec.n_shapes


def test_model_loss_parity_full_vs_bucketed():
    """egnn/branch losses are pad-invariant, so the same samples at a
    trimmed shape give the same per-task loss (fp32)."""
    import jax
    import jax.numpy as jnp
    from repro.configs.base import ArchConfig
    from repro.core.mtl import make_gfm_mtl
    cfg = ArchConfig(name="g", family="gnn", gnn_hidden=8, gnn_layers=2,
                     n_species=64, head_hidden=8, head_layers=2,
                     remat=False, compute_dtype=jnp.float32)
    sources = _mixture(total=30)
    model = make_gfm_mtl(cfg, len(sources))
    params = model.init(jax.random.PRNGKey(0))
    spec = BucketSpec.from_sources(sources)
    full = GroupBatcher(sources, 2, seed=0)
    trim = BucketingBatcher(GroupBatcher(sources, 2, seed=0), spec)
    for _ in range(3):
        a = {k: jnp.asarray(v) for k, v in full.next_batch().items()}
        b = {k: jnp.asarray(v) for k, v in trim.next_batch().items()}
        la, _ = model.loss_fn(params["shared"], params["heads"], a)
        lb, _ = model.loss_fn(params["shared"], params["heads"], b)
        np.testing.assert_allclose(np.asarray(la), np.asarray(lb),
                                   rtol=1e-5, atol=1e-6)


def test_state_restore_delegates_through_wrapper():
    sources = _mixture()
    spec = BucketSpec.from_sources(sources)
    bb = BucketingBatcher(GroupBatcher(sources, 4, seed=3), spec)
    for _ in range(5):
        bb.next_batch()
    snap = bb.state()
    ref = [bb.next_batch() for _ in range(4)]
    bb2 = BucketingBatcher(GroupBatcher(sources, 4, seed=0), spec)
    bb2.restore(snap)
    for a in ref:
        b = bb2.next_batch()
        for k in a:
            np.testing.assert_array_equal(a[k], b[k])


def test_spec_from_gather_style_sources(tmp_path):
    """Planning works over ShardedSource readers, not just dicts."""
    from repro.data.store import ShardedSource, write_store
    sources = _mixture(total=20)
    paths = []
    for t, s in enumerate(sources[:2]):
        p = str(tmp_path / f"s{t}")
        write_store(p, s, shard_size=8)
        paths.append(p)
    readers = [ShardedSource(p) for p in paths]
    spec = BucketSpec.from_sources(readers)
    assert spec == BucketSpec.from_sources(sources[:2])


def test_keys_constants_cover_graph_batch():
    batch = GroupBatcher(_mixture(total=10), 2, seed=0).next_batch()
    graph_keys = set(ATOM_KEYS) | set(EDGE_KEYS)
    assert graph_keys <= set(batch) | {"source_id"} | graph_keys
    assert "energy" not in graph_keys    # per-graph labels pass through


def test_state_roundtrip_preserves_shapes_seen():
    """ISSUE 10 bugfix: ``shapes_seen`` is the compiled-shape surface the
    RecompileSanitizer budget checks audit — a resumed run must report the
    same surface as the uninterrupted one, not rediscover it batch by
    batch."""
    sources = _mixture()
    spec = BucketSpec.from_sources(sources)
    bb = BucketingBatcher(GroupBatcher(sources, 4, seed=3), spec)
    for _ in range(6):
        bb.next_batch()
    assert bb.shapes_seen, "test needs at least one emitted shape"
    snap = bb.state()
    assert snap["kind"] == "BucketingBatcher"
    bb2 = BucketingBatcher(GroupBatcher(sources, 4, seed=0), spec)
    assert bb2.shapes_seen == set()
    bb2.restore(snap)
    assert bb2.shapes_seen == bb.shapes_seen
    # and the stream itself still resumes byte-identically
    for a, b in zip([bb.next_batch() for _ in range(3)],
                    [bb2.next_batch() for _ in range(3)]):
        for k in a:
            np.testing.assert_array_equal(a[k], b[k])
    # JSON-safe: the checkpoint sidecar serializes this dict verbatim
    import json
    json.loads(json.dumps(snap))


def test_restore_accepts_pre_scaleout_bare_inner_state():
    """Back-compat: snapshots written before shapes_seen was persisted are
    the bare inner-batcher state — restore must still resume the stream."""
    sources = _mixture()
    spec = BucketSpec.from_sources(sources)
    inner = GroupBatcher(sources, 4, seed=3)
    bb = BucketingBatcher(inner, spec)
    for _ in range(4):
        bb.next_batch()
    legacy = inner.state()               # the old format: inner state only
    bb2 = BucketingBatcher(GroupBatcher(sources, 4, seed=0), spec)
    bb2.restore(legacy)
    for a, b in zip([bb.next_batch() for _ in range(3)],
                    [bb2.next_batch() for _ in range(3)]):
        for k in a:
            np.testing.assert_array_equal(a[k], b[k])

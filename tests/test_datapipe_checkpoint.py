"""Input-pipeline checkpointing: an interrupted-and-resumed run must draw
the EXACT batch stream an uninterrupted run would have drawn — byte for
byte — through every layer of the pipeline (batcher, mixer, bucketing,
prefetcher, checkpoint sidecar, Session)."""
import os

import numpy as np

from repro.data.bucketing import BucketingBatcher, BucketSpec
from repro.data.loader import GroupBatcher, SingleBatcher
from repro.data.mixing import MixingBatcher, MixingConfig
from repro.data.prefetch import Prefetcher
from repro.data.synthetic_atoms import generate_mixture, source_dicts


def _sources(sizes, feature_offset=1000):
    return [{"x": (feature_offset * t + np.arange(n)).astype(np.int64)}
            for t, n in enumerate(sizes)]


def _assert_streams_equal(ref, got):
    for a, b in zip(ref, got):
        assert set(a) == set(b)
        for k in a:
            np.testing.assert_array_equal(np.asarray(a[k]), np.asarray(b[k]))


def _roundtrip(make_batcher, *, consume=7, compare=9):
    """Interrupted run (snapshot mid-stream, rebuild, restore) vs
    uninterrupted run: identical continuation. State must survive JSON."""
    import json
    uninterrupted = make_batcher()
    for _ in range(consume):
        uninterrupted.next_batch()
    ref = [uninterrupted.next_batch() for _ in range(compare)]

    interrupted = make_batcher()
    for _ in range(consume):
        interrupted.next_batch()
    snap = json.loads(json.dumps(interrupted.state()))   # full JSON cycle
    resumed = make_batcher()                             # fresh process sim
    resumed.restore(snap)
    got = [resumed.next_batch() for _ in range(compare)]
    _assert_streams_equal(ref, got)


def test_group_batcher_roundtrip():
    _roundtrip(lambda: GroupBatcher(_sources([17, 5, 23]), 4, seed=11))


def test_single_batcher_roundtrip():
    _roundtrip(lambda: SingleBatcher({"x": np.arange(31)}, 6, seed=4))


def test_mixing_batcher_roundtrip():
    _roundtrip(lambda: MixingBatcher(
        _sources([40, 9, 21]), 8,
        mixing=MixingConfig(temperature=2.0, emit_source=True), seed=2))


def test_bucketed_mixed_stream_roundtrip():
    """The full ISSUE-4 stack: mixture -> bucketing, resumed mid-epoch."""
    sources = source_dicts(generate_mixture(40, max_atoms=24, max_edges=96,
                                            seed=0))
    spec = BucketSpec.from_sources(sources)
    _roundtrip(lambda: BucketingBatcher(
        MixingBatcher(sources, 6, seed=3), spec), consume=5, compare=6)


def test_prefetcher_state_ignores_readahead():
    """state() credits only CONSUMED batches: whatever the producer drew
    ahead must be re-drawn after restore."""
    ref_b = GroupBatcher(_sources([13, 7]), 4, seed=0)
    ref = [ref_b.next_batch() for _ in range(10)]

    with Prefetcher(GroupBatcher(_sources([13, 7]), 4, seed=0),
                    depth=2) as pf:
        got = [pf.next_batch() for _ in range(3)]
        snap = pf.state()          # producer is ~2 batches ahead by now
    with Prefetcher(GroupBatcher(_sources([13, 7]), 4, seed=99),
                    depth=2) as pf2:
        pf2.restore(snap)
        got += [pf2.next_batch() for _ in range(7)]
    _assert_streams_equal(ref, got)


def test_prefetcher_restore_revives_closed():
    pf = Prefetcher(SingleBatcher({"x": np.arange(16)}, 4, seed=0), depth=1)
    pf.next_batch()
    snap = pf.state()
    pf.close()
    pf.restore(snap)
    assert pf.next_batch()["x"].shape == (4,)
    pf.close()


def test_prefetcher_untrackable_batcher_raises():
    import pytest

    class Plain:
        def next_batch(self):
            return {"x": np.zeros(2)}

    with Prefetcher(Plain(), depth=1) as pf:
        pf.next_batch()
        with pytest.raises(TypeError, match="state"):
            pf.state()


def test_prefetcher_over_bucketed_untrackable_batcher_works():
    """Regression: BucketingBatcher always HAS a state() method (it
    delegates), so trackability must be probed by calling it — a hasattr
    check crashed Prefetcher.__init__ on this composition."""
    import pytest

    class Plain:
        """Stateless batcher emitting tiny graph batches."""
        def next_batch(self):
            return {"node_mask": np.ones((2, 4), bool),
                    "edge_mask": np.ones((2, 8), bool),
                    "species": np.ones((2, 4), np.int32),
                    "pos": np.zeros((2, 4, 3), np.float32),
                    "forces": np.zeros((2, 4, 3), np.float32),
                    "edge_src": np.zeros((2, 8), np.int32),
                    "edge_dst": np.zeros((2, 8), np.int32)}

    spec = BucketSpec((4,), (8,))
    with Prefetcher(BucketingBatcher(Plain(), spec), depth=1) as pf:
        assert pf.next_batch()["species"].shape == (2, 4)
        with pytest.raises(TypeError, match="state"):
            pf.state()


# ---------------------------------------------------------------------------
# checkpoint sidecar + Session
# ---------------------------------------------------------------------------

def test_datapipe_sidecar_write_is_atomic(tmp_path, monkeypatch):
    """A crash mid-save must never leave a truncated .datapipe.json —
    the resume path has to survive the interruptions it exists for."""
    import pytest
    from repro.train import checkpoint
    gb = GroupBatcher(_sources([9, 14]), 4, seed=7)
    path = str(tmp_path / "ck")
    checkpoint.save(path, {"w": np.zeros(3)}, datapipe=gb.state())
    good = checkpoint.load_datapipe(path)
    gb.next_batch()

    monkeypatch.setattr(os, "replace",
                        lambda *a: (_ for _ in ()).throw(OSError("crash")))
    with pytest.raises(OSError, match="crash"):
        checkpoint.save(path, {"w": np.zeros(3)}, datapipe=gb.state())
    monkeypatch.undo()
    assert checkpoint.load_datapipe(path) == good   # old sidecar intact


def test_restore_datapipe_detects_params_stream_desync(tmp_path):
    """The npz and the sidecar are two files; a crash between their writes
    leaves them at different steps. The step stamp makes that detectable:
    restore_datapipe(path) refuses to pair mismatched params and stream."""
    import pytest
    from repro.engine import Session
    from repro.train import checkpoint
    sources = source_dicts(generate_mixture(24, max_atoms=12, max_edges=48,
                                            seed=0))
    path = str(tmp_path / "ck")
    with Session.from_config(_session_cfg(), sources=sources) as s:
        # simulate the crash window: params advanced to step 3, but the
        # sidecar still carries the step-2 stamp
        checkpoint.save(path, {"w": np.zeros(2)}, metadata={"step": 2},
                        datapipe=s.datapipe_state())
        checkpoint.save(path, {"w": np.ones(2)}, metadata={"step": 3})
        with pytest.raises(RuntimeError, match="desync"):
            s.restore_datapipe(path)
        # matched stamps restore fine
        checkpoint.save(path, {"w": np.ones(2)}, metadata={"step": 3},
                        datapipe=s.datapipe_state())
        s.restore_datapipe(path)


def test_restore_datapipe_invalidates_close_snapshot():
    """Regression: restore_datapipe must drop the close-time snapshot —
    a datapipe_state() after restore describes the RESTORED position."""
    from repro.engine import Session
    sources = source_dicts(generate_mixture(24, max_atoms=12, max_edges=48,
                                            seed=0))
    cfg = _session_cfg()
    s = Session.from_config(cfg, sources=sources)
    early = s.datapipe_state()                  # position 0
    s.run()
    s.close()                                   # snapshots post-run position
    s.restore_datapipe(early)                   # rewind to position 0
    assert s.datapipe_state() == early, \
        "stale close-time snapshot leaked through after restore"


def test_checkpoint_datapipe_sidecar_roundtrip(tmp_path):
    from repro.train import checkpoint
    gb = GroupBatcher(_sources([9, 14]), 4, seed=7)
    for _ in range(3):
        gb.next_batch()
    path = str(tmp_path / "ck")
    checkpoint.save(path, {"w": np.zeros(3)}, metadata={"step": 3},
                    datapipe=gb.state())
    assert checkpoint.has_datapipe(path)
    ref = [gb.next_batch() for _ in range(5)]
    gb2 = GroupBatcher(_sources([9, 14]), 4, seed=7)
    gb2.restore(checkpoint.load_datapipe(path))
    _assert_streams_equal(ref, [gb2.next_batch() for _ in range(5)])


def _session_cfg(**kw):
    import jax.numpy as jnp
    from repro.configs.base import ArchConfig
    from repro.engine import SessionConfig
    cfg = ArchConfig(name="g", family="gnn", gnn_hidden=8, gnn_layers=1,
                     n_species=64, head_hidden=8, head_layers=2,
                     remat=False, compute_dtype=jnp.float32)
    return SessionConfig(model="gfm-mtl", arch=cfg, steps=3, batch_per_task=3,
                         verbose=False, **kw)


def test_session_resume_reproduces_uninterrupted_stream(tmp_path):
    """The acceptance-criteria round trip: a Session that checkpoints after
    run() and a fresh Session that restores the sidecar draw the same
    continuation stream as one uninterrupted Session — with mixing AND
    bucketing on, prefetch on (default)."""
    from repro.data.mixing import MixingConfig
    from repro.engine import Session
    sources = source_dicts(generate_mixture(36, max_atoms=16, max_edges=64,
                                            seed=0))
    ck = str(tmp_path / "run")
    cfg = _session_cfg(mixing=MixingConfig(temperature=2.0), bucketing=3)

    # uninterrupted: run, then keep drawing from the live pipeline
    with Session.from_config(cfg, sources=sources) as s:
        s.run()
        ref = [s._prefetcher.next_batch() for _ in range(5)]

    # interrupted: identical run that saves a checkpoint, then a FRESH
    # session restores the sidecar and continues
    with Session.from_config(cfg.replace(ckpt_path=ck), sources=sources) as s:
        s.run()
    assert os.path.exists(ck + ".datapipe.json")
    with Session.from_config(cfg, sources=sources) as s2:
        s2.run()                      # same steps; advances its own pipeline
        s2.restore_datapipe(ck)       # ...then rewinds to the snapshot
        got = [s2._prefetcher.next_batch() for _ in range(5)]
    _assert_streams_equal(ref, got)


def test_session_datapipe_state_after_close_credits_only_consumed(tmp_path):
    """Regression: after close() the underlying batcher sits PAST what the
    loop consumed (discarded read-ahead); datapipe_state() must return the
    snapshot taken at close time, and a resume from it must match an
    uninterrupted stream."""
    from repro.engine import Session
    sources = source_dicts(generate_mixture(24, max_atoms=12, max_edges=48,
                                            seed=0))
    cfg = _session_cfg()
    with Session.from_config(cfg, sources=sources) as s:
        s.run()
    post_close = s.datapipe_state()          # taken AFTER the with-block
    assert post_close is not None

    # uninterrupted twin: same run, stream read live (no close)
    s2 = Session.from_config(cfg, sources=sources)
    s2.run()
    ref = [s2._prefetcher.next_batch() for _ in range(4)]
    s2.close()

    s3 = Session.from_config(cfg, sources=sources)
    s3.run()
    s3.restore_datapipe(post_close)
    got = [s3._prefetcher.next_batch() for _ in range(4)]
    s3.close()
    _assert_streams_equal(ref, got)


def test_session_datapipe_state_none_for_untrackable_batcher():
    from repro.engine import Session

    class Plain:
        def next_batch(self):
            b = GroupBatcher(
                source_dicts(generate_mixture(10, max_atoms=12, max_edges=48,
                                              seed=0)), 2).next_batch()
            return b

    sources = source_dicts(generate_mixture(10, max_atoms=12, max_edges=48,
                                            seed=0))
    with Session(_session_cfg(prefetch=False), sources=sources,
                 batcher=GroupBatcher(sources, 2)) as s:
        assert s.datapipe_state() is not None
    with Session(_session_cfg(prefetch=False), sources=sources,
                 batcher=Plain()) as s:
        assert s.datapipe_state() is None

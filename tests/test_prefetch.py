"""Async input pipeline (repro.data.prefetch.Prefetcher): the prefetched
batch stream must be BYTE-IDENTICAL to the synchronous path (prefetching
changes when batches are built, never which), exceptions must propagate,
and a Session runs the same loss trajectory with prefetch on or off."""
import numpy as np
import pytest

from repro.data.loader import GroupBatcher, SingleBatcher
from repro.data.prefetch import Prefetcher


def _sources(sizes, feature_offset=1000):
    return [{"x": (feature_offset * t + np.arange(n)).astype(np.int64),
             "y": np.full((n, 2), t, np.int64)} for t, n in enumerate(sizes)]


def test_stream_identical_to_synchronous_path():
    sync = GroupBatcher(_sources([10, 7]), 4, seed=42)
    with Prefetcher(GroupBatcher(_sources([10, 7]), 4, seed=42)) as pf:
        for _ in range(12):
            a, b = sync.next_batch(), pf.next_batch()
            assert a.keys() == b.keys()
            for k in a:
                np.testing.assert_array_equal(a[k], b[k])


def test_transform_runs_on_producer():
    src = {"x": np.arange(20), "y": np.zeros((20, 3))}
    with Prefetcher(SingleBatcher(src, 8, seed=1),
                    transform=lambda b: {k: v + 1 for k, v in b.items()}) as pf:
        ref = SingleBatcher(src, 8, seed=1).next_batch()
        got = pf.next_batch()
        np.testing.assert_array_equal(got["x"], ref["x"] + 1)


def test_iterator_protocol():
    with Prefetcher(SingleBatcher({"x": np.arange(8)}, 2, seed=0)) as pf:
        it = iter(pf)
        assert next(it)["x"].shape == (2,)


def test_producer_exception_propagates_and_does_not_hang():
    class Boom:
        def __init__(self):
            self.n = 0

        def next_batch(self):
            self.n += 1
            if self.n > 2:
                raise RuntimeError("boom")
            return {"x": np.arange(self.n)}

    pf = Prefetcher(Boom(), depth=1)
    try:
        with pytest.raises(RuntimeError, match="boom"):
            for _ in range(10):
                pf.next_batch()
        # a second call must re-raise immediately, not block forever
        with pytest.raises(RuntimeError, match="boom"):
            pf.next_batch()
    finally:
        pf.close()


def test_producer_stop_iteration_is_wrapped_not_swallowed():
    """Regression (ISSUE-7): next_batch() doubles as __next__, so a bare
    StopIteration from a broken/exhausted source would SILENTLY end any
    for-loop over the Prefetcher. It must surface as a RuntimeError with
    the original StopIteration preserved as __cause__."""
    class Exhausted:
        def __init__(self):
            self.n = 0

        def next_batch(self):
            self.n += 1
            if self.n > 2:
                raise StopIteration("source ran dry")
            return {"x": np.arange(self.n)}

    pf = Prefetcher(Exhausted(), depth=1)
    try:
        with pytest.raises(RuntimeError,
                           match="StopIteration") as ei:
            for _ in range(10):
                pf.next_batch()
        assert isinstance(ei.value.__cause__, StopIteration)
        # a for-loop over the prefetcher must ALSO blow up, not end cleanly
        pf2 = Prefetcher(Exhausted(), depth=1)
        try:
            with pytest.raises(RuntimeError, match="StopIteration"):
                for _ in pf2:
                    pass
        finally:
            pf2.close()
    finally:
        pf.close()


def test_injected_producer_fault_surfaces_after_queued_batches_drain():
    """The chaos hook: inject_producer_fault kills the producer before its
    NEXT draw; batches it already queued are still handed out first (the
    consumer observes the fault at a later position than the injection —
    exactly like a real producer crash with read-ahead in flight)."""
    from repro.data.prefetch import Prefetcher as PF

    class Killed(RuntimeError):
        pass

    pf = PF(GroupBatcher(_sources([10, 7]), 4, seed=3), depth=2)
    try:
        got = [pf.next_batch()]
        pf.inject_producer_fault(Killed("producer shot"))
        with pytest.raises(Killed):
            for _ in range(10):
                got.append(pf.next_batch())
        assert len(got) >= 1
        # recovery in place: rewind to the consumed position and the stream
        # continues byte-identically vs a synchronous reference
        pf.restore(pf.state())
        ref = GroupBatcher(_sources([10, 7]), 4, seed=3)
        for _ in range(len(got)):
            ref.next_batch()                   # skip what was consumed
        for _ in range(4):
            a, b = ref.next_batch(), pf.next_batch()
            for k in a:
                np.testing.assert_array_equal(a[k], b[k])
        # a second injected fault after recovery propagates again
        pf.inject_producer_fault(Killed("again"))
        with pytest.raises(Killed):
            for _ in range(10):
                pf.next_batch()
    finally:
        pf.close()


def test_restore_then_stop_iteration_still_wrapped():
    """The restore-then-crash path (ISSUE-7 satellite): restore() re-arms
    the producer through the same wrapping logic, so a source that runs
    dry AFTER a restore must still surface a RuntimeError with the
    original StopIteration (and its traceback) as __cause__ on the next
    __next__ — never a bare StopIteration that would end a for-loop."""
    class DryingTrackable:
        def __init__(self):
            self.n = 0

        def next_batch(self):
            self.n += 1
            if self.n > 2:
                raise StopIteration("dry")
            return {"x": np.arange(self.n)}

        def state(self):
            return {"n": self.n}

        def restore(self, st):
            self.n = st["n"]

    pf = Prefetcher(DryingTrackable(), depth=1)
    try:
        got = [pf.next_batch()]
        pf.restore(pf.state())         # rewind to the consumed position
        with pytest.raises(RuntimeError, match="StopIteration") as ei:
            for b in pf:               # __next__, the dangerous path
                got.append(b)
        assert isinstance(ei.value.__cause__, StopIteration)
        assert ei.value.__cause__.__traceback__ is not None
        assert len(got) == 2           # batch 2 replayed after the rewind
    finally:
        pf.close()


def test_close_is_idempotent_and_next_batch_after_close_raises():
    pf = Prefetcher(SingleBatcher({"x": np.arange(8)}, 2, seed=0))
    pf.next_batch()
    pf.close()
    pf.close()
    with pytest.raises(RuntimeError, match="closed"):   # raise, never hang
        pf.next_batch()


def test_repeated_shutdown_is_a_no_op(monkeypatch):
    """Regression: re-entrant shutdown (double close(), or close() followed
    by context-manager __exit__) must not re-run the halt machinery — with a
    producer stuck past the join timeout, every extra close() used to block
    for the full drain+join again. Only the FIRST close may halt."""
    pf = Prefetcher(SingleBatcher({"x": np.arange(8)}, 2, seed=0))
    halts = {"n": 0}
    real_halt = pf._halt

    def counting_halt():
        halts["n"] += 1
        real_halt()

    monkeypatch.setattr(pf, "_halt", counting_halt)
    with pf:                # __exit__ is the second shutdown entry
        pf.close()
        pf.close()
    pf.close()
    assert halts["n"] == 1, "re-entrant close() must be a strict no-op"
    with pytest.raises(RuntimeError, match="closed"):
        pf.next_batch()


def test_restore_revives_and_rearms_close():
    """restore() on a closed Prefetcher restarts the producer AND re-arms
    the shutdown path, so the close -> restore -> close lifecycle works."""
    pf = Prefetcher(SingleBatcher({"x": np.arange(8)}, 2, seed=0))
    first = pf.next_batch()
    snap = pf.state()
    pf.close()
    pf.close()                      # no-op
    pf.restore(snap)
    assert pf.next_batch()["x"].shape == first["x"].shape
    thread = pf._thread
    assert thread.is_alive()
    pf.close()                      # must actually halt the NEW producer
    assert not thread.is_alive()


def test_exception_inside_transform_propagates():
    """transform runs on the producer thread; its exceptions must surface
    from next_batch() like batcher exceptions do."""
    calls = {"n": 0}

    def bad_transform(b):
        calls["n"] += 1
        if calls["n"] > 1:
            raise ValueError("transform boom")
        return b

    pf = Prefetcher(SingleBatcher({"x": np.arange(8)}, 2, seed=0),
                    transform=bad_transform, depth=1)
    try:
        with pytest.raises(ValueError, match="transform boom"):
            for _ in range(10):
                pf.next_batch()
    finally:
        pf.close()


def _tiny_session(**kw):
    import jax.numpy as jnp
    from repro.configs.base import ArchConfig
    from repro.data.synthetic_atoms import generate_all
    from repro.engine import Session, SessionConfig

    cfg = ArchConfig(name="g", family="gnn", gnn_hidden=8, gnn_layers=1,
                     n_species=64, head_hidden=8, head_layers=2,
                     remat=False, compute_dtype=jnp.float32)
    data = generate_all(8, max_atoms=8, max_edges=24, sources=["ani1x"])
    s = data["ani1x"]
    sources = [dict(species=s.species, pos=s.pos, edge_src=s.edge_src,
                    edge_dst=s.edge_dst, node_mask=s.node_mask,
                    edge_mask=s.edge_mask, energy=s.energy, forces=s.forces)]
    return Session.from_config(
        SessionConfig(model="gfm-mtl", arch=cfg, steps=2, batch_per_task=2,
                      verbose=False, **kw), sources=sources)


def test_session_close_stops_producer_thread():
    s = _tiny_session()
    s.run()
    thread = s._prefetcher._thread
    assert thread.is_alive(), "prefetcher should be live after run()"
    s.close()
    assert not thread.is_alive(), "close() must stop the producer thread"
    assert s._prefetcher is None
    s.close()                         # idempotent
    s.run()                           # session stays usable: new prefetcher
    assert s._prefetcher._thread.is_alive()
    s.close()


def test_session_context_manager_shuts_down():
    with _tiny_session() as s:
        s.run()
        thread = s._prefetcher._thread
        assert thread.is_alive()
    assert not thread.is_alive(), "__exit__ must stop the producer"


def test_session_prefetch_off_never_starts_a_thread():
    with _tiny_session(prefetch=False) as s:
        s.run()
        assert s._prefetcher is None


def test_session_prefetch_on_off_same_trajectory():
    """End to end: SessionConfig.prefetch only changes scheduling, so the
    loss trajectory is identical with it on or off."""
    import jax.numpy as jnp
    from repro.configs.base import ArchConfig
    from repro.data.synthetic_atoms import generate_all
    from repro.engine import Session, SessionConfig

    cfg = ArchConfig(name="g", family="gnn", gnn_hidden=16, gnn_layers=1,
                     n_species=64, head_hidden=8, head_layers=2,
                     remat=False, compute_dtype=jnp.float32)
    data = generate_all(16, max_atoms=8, max_edges=24,
                        sources=["ani1x", "qm7x"])
    sources = [dict(species=s.species, pos=s.pos, edge_src=s.edge_src,
                    edge_dst=s.edge_dst, node_mask=s.node_mask,
                    edge_mask=s.edge_mask, energy=s.energy, forces=s.forces)
               for s in data.values()]
    base = SessionConfig(model="gfm-mtl", arch=cfg, steps=4, batch_per_task=4,
                         log_every=1, verbose=False)
    losses = {}
    for on in (True, False):
        with Session.from_config(base.replace(prefetch=on),
                                 sources=sources) as sess:
            # TWO sequential runs: the session must keep one prefetcher
            # alive across them — closing between runs would discard drawn
            # batches and shift the stream vs the synchronous path
            traj = [row["loss"] for row in sess.run().logger.history]
            traj += [row["loss"] for row in sess.run().logger.history]
        losses[on] = traj
    np.testing.assert_allclose(losses[True], losses[False], rtol=1e-6)

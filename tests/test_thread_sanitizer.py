"""ThreadSanitizer-style contract tests for the threaded layers.

Marked ``sanitizer`` (deselected from tier-1, run by the CI chaos-soak
job): the instrumentation patches bound methods and swaps ``__class__``,
which is test-only overhead. The contracts under test are the ones the
docstrings promise but no numeric test can see breaking:

  * ``data.prefetch.Prefetcher`` — exactly one producer draws from the
    wrapped batcher at a time, across restore() generations (the bitwise
    batch-replay guarantee);
  * ``serve.queue.RequestQueue`` — one engine worker drains the queue;
  * lock-guarded shared state is only touched while holding the lock.
"""
import threading

import pytest

from repro.analysis import (ThreadContractViolation, ThreadSanitizer,
                            TrackedLock)
from repro.data.bucketing import BucketSpec
from repro.data.prefetch import Prefetcher
from repro.serve.queue import RequestQueue

pytestmark = pytest.mark.sanitizer


# ---------------------------------------------------------------------------
# primitives
# ---------------------------------------------------------------------------

def test_tracked_lock_ownership():
    lock = TrackedLock()
    assert not lock.held()
    with lock:
        assert lock.held()
        with lock:                       # reentrant bookkeeping
            assert lock.held()
        assert lock.held()
        seen = []
        t = threading.Thread(target=lambda: seen.append(lock.held()))
        t.start()
        t.join()
        assert seen == [False]           # held() means held by THIS thread
    assert not lock.held()


class Counter:
    def __init__(self):
        self.n = 0

    def bump(self):
        self.n += 1


def test_guard_attrs_seeded_violation_and_clean():
    lock = TrackedLock()
    san = ThreadSanitizer()
    c = san.guard_attrs(Counter(), ("n",), lock)
    with lock:
        c.bump()                         # guarded access: fine
    san.check()
    c.bump()                             # unguarded read+write of n
    with pytest.raises(ThreadContractViolation) as ei:
        san.check()
    kinds = {v.kind for v in ei.value.violations}
    assert kinds == {"unguarded-read", "unguarded-write"}
    assert all(v.target == "Counter.n" for v in ei.value.violations)


class SlowWorker:
    """work() holds both callers inside simultaneously via the barrier —
    deterministic overlap, no sleeps."""

    def __init__(self, barrier):
        self.barrier = barrier

    def work(self):
        self.barrier.wait(timeout=5)


def test_mutual_exclusion_detects_concurrent_entry():
    san = ThreadSanitizer()
    w = san.wrap_mutual_exclusion(SlowWorker(threading.Barrier(2)), ("work",))
    ts = [threading.Thread(target=w.work) for _ in range(2)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    with pytest.raises(ThreadContractViolation, match="concurrent-entry"):
        san.check()


def test_mutual_exclusion_allows_sequential_and_reentrant():
    san = ThreadSanitizer()

    class W:
        def a(self):
            self.b()                     # same-thread re-entry into the group

        def b(self):
            pass

    w = san.wrap_mutual_exclusion(W(), ("a", "b"))
    w.a()                                # reentrant
    t = threading.Thread(target=w.a)     # a LATER thread (new generation)
    t.start()
    t.join()
    san.check()


# ---------------------------------------------------------------------------
# Prefetcher: single-producer contract across restore generations
# ---------------------------------------------------------------------------

class CountBatcher:
    def __init__(self):
        self.i = 0

    def next_batch(self):
        self.i += 1
        return {"i": self.i}

    def state(self):
        return {"i": self.i}

    def restore(self, st):
        self.i = st["i"]


def test_prefetcher_single_producer_through_restore():
    san = ThreadSanitizer()
    batcher = san.wrap_mutual_exclusion(CountBatcher(), ("next_batch",),
                                        group="prefetch-producer")
    with Prefetcher(batcher, depth=2) as pf:
        first = [pf.next_batch()["i"] for _ in range(3)]
        snap = pf.state()
        more = [pf.next_batch()["i"] for _ in range(2)]
        pf.restore(snap)                 # halts producer, starts generation 2
        replay = [pf.next_batch()["i"] for _ in range(2)]
        assert replay == more            # bitwise replay of the stream
        assert first == [1, 2, 3]
        assert pf.generation == 2        # restore started producer gen 2
    san.check()                          # draws never overlapped


def test_prefetcher_contract_catches_second_producer():
    """A rogue second thread drawing from the SAME batcher while the
    prefetcher's producer runs is exactly what the contract forbids."""
    san = ThreadSanitizer()
    barrier = threading.Barrier(2)

    class BlockingBatcher(CountBatcher):
        def next_batch(self):
            if self.i < 2:               # pin the FIRST two drawers inside
                try:
                    barrier.wait(timeout=5)
                except threading.BrokenBarrierError:
                    pass
            return super().next_batch()

    batcher = san.wrap_mutual_exclusion(BlockingBatcher(), ("next_batch",),
                                        group="prefetch-producer")
    with Prefetcher(batcher, depth=1) as pf:
        rogue = threading.Thread(target=batcher.next_batch)
        rogue.start()                    # overlaps the producer's draw
        rogue.join()
        pf.next_batch()
    with pytest.raises(ThreadContractViolation, match="prefetch-producer"):
        san.check()


# ---------------------------------------------------------------------------
# RequestQueue: single-worker drain contract
# ---------------------------------------------------------------------------

def _sample(n=3):
    import numpy as np
    return {"species": np.ones(n, np.int32),
            "pos": np.zeros((n, 3), np.float32)}


def _queue(**kw):
    return RequestQueue(BucketSpec((8,), (16,)), depth=8, **kw)


def test_request_queue_single_worker_drain_clean():
    san = ThreadSanitizer()
    q = _queue()
    futures = [q.submit(_sample()) for _ in range(4)]
    san.wrap_mutual_exclusion(q, ("get", "drain"), group="engine-worker")

    def worker():
        while (req := q.get(timeout=0.05)) is not None:
            req.future.set_result({"ok": True})

    t = threading.Thread(target=worker)
    t.start()
    t.join()
    assert all(f.result(timeout=1)["ok"] for f in futures)
    san.check()                          # one worker: no overlap


def test_request_queue_two_workers_draining_violate():
    san = ThreadSanitizer()
    q = _queue()
    san.wrap_mutual_exclusion(q, ("get", "drain"), group="engine-worker")
    start = threading.Barrier(2)

    def worker():
        start.wait(timeout=5)
        q.get(timeout=0.5)               # empty queue: both block inside get

    ts = [threading.Thread(target=worker) for _ in range(2)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    with pytest.raises(ThreadContractViolation, match="engine-worker"):
        san.check()


def test_request_queue_concurrent_submit_is_allowed():
    """submit() is the thread-safe side — many submitters is NOT a
    violation; only the drain side is single-worker."""
    san = ThreadSanitizer()
    q = _queue()
    san.wrap_mutual_exclusion(q, ("get", "drain"), group="engine-worker")
    ts = [threading.Thread(target=q.submit, args=(_sample(),))
          for _ in range(4)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    assert len(q.drain()) == 4           # main thread drains, sequentially
    san.check()

"""Sharded store (ADIOS/DDStore analogue): roundtrip, caching, prefetch."""
import numpy as np

from repro.data.store import PrefetchingBatcher, ShardedSource, write_store


def _write(tmp_path, n=100, tag=0):
    arrays = {"x": np.arange(n * 3, dtype=np.float32).reshape(n, 3) + 1000 * tag,
              "y": np.arange(n, dtype=np.int32) + 1000 * tag}
    path = str(tmp_path / f"src{tag}")
    write_store(path, arrays, shard_size=16)
    return path, arrays


def test_roundtrip_and_routing(tmp_path):
    path, arrays = _write(tmp_path)
    src = ShardedSource(path)
    assert len(src) == 100
    idx = np.array([3, 97, 17, 16, 15, 0, 55])
    out = src.gather(idx)
    np.testing.assert_array_equal(out["y"], arrays["y"][idx])
    np.testing.assert_array_equal(out["x"], arrays["x"][idx])


def test_cache_plateaus(tmp_path):
    """Steady-state serves come from memory, not the filesystem (DDStore)."""
    path, _ = _write(tmp_path)
    src = ShardedSource(path)
    rng = np.random.default_rng(0)
    for _ in range(20):
        src.gather(rng.integers(0, 100, 8))
    fetches_after_warmup = src.fetches
    for _ in range(50):
        src.gather(rng.integers(0, 100, 8))
    assert src.fetches == fetches_after_warmup  # no new filesystem reads
    assert src.fetches <= 7                      # at most one per shard
    assert src.hits > 0


def test_prefetching_batcher_task_purity(tmp_path):
    paths = [_write(tmp_path, tag=t)[0] for t in range(3)]
    gb = PrefetchingBatcher([ShardedSource(p) for p in paths],
                            batch_per_task=8, seed=1)
    try:
        for _ in range(5):
            b = gb.next_batch()
            assert b["y"].shape == (3, 8)
            for t in range(3):
                assert ((b["y"][t] >= 1000 * t) &
                        (b["y"][t] < 1000 * t + 100)).all()
    finally:
        gb.close()

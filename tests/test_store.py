"""Sharded store (ADIOS/DDStore analogue): roundtrip, caching, prefetch,
atomic manifest publish."""
import json
import os

import numpy as np
import pytest

from repro.data.store import PrefetchingBatcher, ShardedSource, write_store


def _write(tmp_path, n=100, tag=0):
    arrays = {"x": np.arange(n * 3, dtype=np.float32).reshape(n, 3) + 1000 * tag,
              "y": np.arange(n, dtype=np.int32) + 1000 * tag}
    path = str(tmp_path / f"src{tag}")
    write_store(path, arrays, shard_size=16)
    return path, arrays


def test_roundtrip_and_routing(tmp_path):
    path, arrays = _write(tmp_path)
    src = ShardedSource(path)
    assert len(src) == 100
    idx = np.array([3, 97, 17, 16, 15, 0, 55])
    out = src.gather(idx)
    np.testing.assert_array_equal(out["y"], arrays["y"][idx])
    np.testing.assert_array_equal(out["x"], arrays["x"][idx])


def test_cache_plateaus(tmp_path):
    """Steady-state serves come from memory, not the filesystem (DDStore)."""
    path, _ = _write(tmp_path)
    src = ShardedSource(path)
    rng = np.random.default_rng(0)
    for _ in range(20):
        src.gather(rng.integers(0, 100, 8))
    fetches_after_warmup = src.fetches
    for _ in range(50):
        src.gather(rng.integers(0, 100, 8))
    assert src.fetches == fetches_after_warmup  # no new filesystem reads
    assert src.fetches <= 7                      # at most one per shard
    assert src.hits > 0


def test_cache_hit_never_touches_filesystem(tmp_path, monkeypatch):
    """DDStore steady state, asserted at the syscall boundary: a SECOND
    read of a "remote" shard is served from memory — np.load is never
    called again, not merely called cheaply."""
    path, arrays = _write(tmp_path)
    src = ShardedSource(path)
    idx = np.array([0, 17, 33])           # three distinct shards
    first = src.gather(idx)

    def forbidden(*a, **kw):
        raise AssertionError("cache hit re-touched the filesystem")

    monkeypatch.setattr(np, "load", forbidden)
    second = src.gather(idx)               # same shards again: pure memory
    np.testing.assert_array_equal(first["x"], second["x"])
    np.testing.assert_array_equal(second["y"], arrays["y"][idx])


def test_manifest_write_is_atomic(tmp_path, monkeypatch):
    """An interrupted write_store leaves either the previous manifest or
    none — never a truncated JSON that ShardedSource crashes parsing.
    Scope: MANIFEST atomicity only — shard .npz files are not
    transactional (asserted below with distinguishable values)."""
    arrays = {"x": np.arange(32, dtype=np.float32)}
    path = str(tmp_path / "store")
    write_store(path, arrays, shard_size=8)
    good = json.load(open(os.path.join(path, "manifest.json")))

    # crash at publish time: os.replace never runs. The second write uses
    # DISTINGUISHABLE values so shard overwrites can't hide behind a value
    # coincidence.
    def crash(src, dst):
        raise OSError("simulated crash before publish")

    monkeypatch.setattr(os, "replace", crash)
    with pytest.raises(OSError, match="simulated crash"):
        write_store(path, {"x": np.arange(64, dtype=np.float32) + 100},
                    shard_size=8)
    monkeypatch.undo()
    # the OLD manifest is intact and parseable — readers never see a
    # truncated JSON
    assert json.load(open(os.path.join(path, "manifest.json"))) == good
    src = ShardedSource(path)
    assert len(src) == 32
    # documented scope limit: the crashed rewrite already replaced shard
    # bytes, so the old manifest now fronts NEW shard data — manifest
    # atomicity does not make in-place store rewrites transactional
    assert src.gather(np.array([0]))["x"][0] == 100.0
    # no half-written manifest.json left behind under the final name
    assert os.path.exists(os.path.join(path, "manifest.json.tmp"))


def test_prefetching_batcher_task_purity(tmp_path):
    paths = [_write(tmp_path, tag=t)[0] for t in range(3)]
    gb = PrefetchingBatcher([ShardedSource(p) for p in paths],
                            batch_per_task=8, seed=1)
    try:
        for _ in range(5):
            b = gb.next_batch()
            assert b["y"].shape == (3, 8)
            for t in range(3):
                assert ((b["y"][t] >= 1000 * t) &
                        (b["y"][t] < 1000 * t + 100)).all()
    finally:
        gb.close()

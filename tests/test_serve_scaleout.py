"""Multi-device serving scale-out (ISSUE 10).

Two layers:

  * in-process (1 device): ReplicaScheduler routing/failover invariants,
    AdaptivePolicy knee movement, serve_batch_spec, and the full
    ReplicaServeSession lifecycle (parity, failover, shed, close) with
    mesh-less replicas sharing the host device;
  * subprocess (8 forced host devices, the test_parallel_parity pattern):
    per-replica BITWISE row parity vs the plain single-device
    ``predict_one``, sharded-forward parity, compile-budget assertions
    (``shapes x plans``), per-replica param placement, and close/drain
    semantics under the replica workers.
"""
import json
import os
import subprocess
import sys
import textwrap
import time
from concurrent.futures import Future

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.core.mtl import make_gfm_mtl
from repro.data.bucketing import BucketSpec
from repro.data.synthetic_atoms import generate_mixture, source_dicts
from repro.serve import (AdaptivePolicy, ReplicaScheduler,
                         ReplicaServeSession, ServeClosedError,
                         SizeBinnedBatcher)
from repro.serve.queue import DeadlineExceededError, Request, _as_sample

CFG = ArchConfig(name="scaleout-test", family="gnn", gnn_hidden=16,
                 gnn_layers=2, n_species=64, head_hidden=8, head_layers=2,
                 remat=False, compute_dtype=jnp.float32)
SPEC = BucketSpec((8, 16), (32, 64))


class FakeClock:
    """Deterministic injectable clock (same base for every component)."""

    def __init__(self, t0: float = 1e6):
        self.t = float(t0)

    def __call__(self) -> float:
        return self.t

    def advance(self, dt: float):
        self.t += dt


@pytest.fixture(scope="module")
def served():
    sources = source_dicts(generate_mixture(40, max_atoms=16, max_edges=64))
    model = make_gfm_mtl(CFG, len(sources))
    params = model.init(jax.random.PRNGKey(0))
    return params, sources


def _sample(sources, t, i=0):
    s = sources[t]
    i = i % s["species"].shape[0]
    return {k: s[k][i] for k in ("species", "pos", "edge_src", "edge_dst",
                                 "node_mask", "edge_mask")}


def _request(sources, t=0, i=0, t_submit=0.0, head=0):
    canon, n_atoms, n_edges = _as_sample(_sample(sources, t, i))
    return Request(sample=canon, head=head,
                   bucket=SPEC.bucket_for(n_atoms, n_edges),
                   n_atoms=n_atoms, n_edges=n_edges, future=Future(),
                   t_submit=t_submit)


# ---------------------------------------------------------------------------
# ReplicaScheduler: sticky least-loaded routing
# ---------------------------------------------------------------------------

def test_scheduler_sticks_to_one_replica_while_a_bin_fills():
    s = ReplicaScheduler(4, max_batch=3)
    key = ((8, 32), 0)
    first = [s.route(key) for _ in range(3)]
    assert len(set(first)) == 1            # one bin, one replica
    # bin full: the 4th route re-picks least-loaded — a DIFFERENT replica,
    # since the first still holds 3 outstanding
    assert s.route(key) != first[0]


def test_scheduler_routes_to_least_loaded():
    s = ReplicaScheduler(3, max_batch=8)
    r0 = s.route(((8, 32), 0))
    r1 = s.route(((8, 32), 1))             # fresh key: avoids loaded r0
    assert r1 != r0
    s.complete(r0)                         # r0's request resolved
    assert s.outstanding[r0] == 0
    r2 = s.route(((16, 64), 2))
    assert r2 == r0                        # back to the now-idle replica


def test_scheduler_failover_and_all_dead():
    s = ReplicaScheduler(2, max_batch=4)
    key = ((8, 32), 0)
    r = s.route(key)
    s.fail(r)                              # put() failed: dead + released
    assert s.outstanding[r] == 0 and r in s.dead
    r2 = s.route(key)                      # sticky entry dropped, re-routed
    assert r2 != r
    s.fail(r2)
    with pytest.raises(ServeClosedError, match="dead"):
        s.route(key)
    s.revive(r)
    assert s.route(key) == r


# ---------------------------------------------------------------------------
# AdaptivePolicy: the knee moves with the measured rate
# ---------------------------------------------------------------------------

def test_adaptive_policy_moves_the_knee():
    p = AdaptivePolicy(max_batch=8, max_wait=0.005, min_wait=2e-4)
    key = ((8, 32), 0)
    # no estimate yet: fixed knobs
    assert p.target_rows(key) == 8 and p.wait(key) == 0.005
    # saturating arrivals (0.5 ms apart): wait for a fillable bin
    for k in range(20):
        p.observe_arrival(key, t=k * 5e-4)
    assert p.target_rows(key) == 8
    assert 0 < p.wait(key) <= 0.005
    # starved arrivals (50 ms apart): nothing else is coming — release fast
    slow = ((16, 64), 1)
    for k in range(20):
        p.observe_arrival(slow, t=k * 0.05)
    assert p.target_rows(slow) == 1
    assert p.wait(slow) == 2e-4
    snap = p.snapshot()
    assert snap[repr(slow)]["target_rows"] == 1


def test_adaptive_batcher_releases_lone_requests_early(served):
    """Once the policy has measured a starved key, a lone request releases
    on add() (target 1) instead of burning the full max_wait."""
    _, sources = served
    fc = FakeClock()
    pol = AdaptivePolicy(max_batch=8, max_wait=0.005)
    b = SizeBinnedBatcher(max_batch=8, max_wait=0.005, clock=fc, policy=pol)
    # prime the rate estimate: two arrivals 50 ms apart fill + release
    for k in range(2):
        ab = b.add(_request(sources, t_submit=fc()))
        if ab is None:
            fc.advance(1.0)
            released = b.expired()
            assert len(released) == 1
        fc.advance(0.05)
    ab = b.add(_request(sources, t_submit=fc()))
    assert ab is not None and ab.n_real == 1   # released immediately
    # the padded shape is still the STATIC max_batch (compile budget safe)
    assert ab.batch["species"].shape[0] == 8


# ---------------------------------------------------------------------------
# sharding rule + replica meshes on a 1-device host
# ---------------------------------------------------------------------------

def test_serve_batch_spec_rows_or_replicate():
    from jax.sharding import PartitionSpec as P

    from repro.configs.sharding import serve_batch_spec
    leaf = np.zeros((8, 4, 3))
    assert serve_batch_spec(leaf, 4) == P("data", None, None)
    assert serve_batch_spec(leaf, 3) == P(None, None, None)  # uneven: replicate
    assert serve_batch_spec(np.zeros(()), 2) == P()


def test_make_replica_meshes_partitions_the_pool():
    from repro.launch.mesh import make_replica_meshes
    meshes = make_replica_meshes(1)
    assert len(meshes) == 1 and meshes[0].shape == {"data": 1}
    if jax.device_count() < 2:
        with pytest.raises(AssertionError, match="devices"):
            make_replica_meshes(2)


def test_session_on_a_one_device_mesh_serves(served):
    """mesh= with a single device degenerates to device pinning — the
    replica building block. (The uneven-max_batch rejection needs >1
    device; the subprocess suite asserts it.)"""
    params, sources = served
    from repro.launch.mesh import make_replica_meshes
    from repro.serve import ServeSession
    mesh = make_replica_meshes(1)[0]
    with ServeSession(params, CFG, spec=SPEC, max_batch=3,
                      mesh=mesh) as srv:
        sm = _sample(sources, 0)
        got = srv.submit(sm, head=0).result(timeout=60)
        ref = srv.predict_one(sm, head=0)
        assert got["energy"] == ref["energy"]
        np.testing.assert_array_equal(got["forces"], ref["forces"])
        assert srv.stats()["plan"] == {"mode": "single", "devices": 1}


# ---------------------------------------------------------------------------
# ReplicaServeSession lifecycle (mesh-less replicas, one host device)
# ---------------------------------------------------------------------------

def test_replica_session_parity_and_routing(served):
    params, sources = served
    with ReplicaServeSession(params, CFG, meshes=[None, None], spec=SPEC,
                             max_batch=4, max_wait_ms=2.0) as srv:
        jobs = [(t, _sample(sources, t, i))
                for t in range(3) for i in range(3)]
        futs = [(t, sm, srv.submit(sm, head=t)) for t, sm in jobs]
        for t, sm, fut in futs:
            got = fut.result(timeout=60)
            ref = srv.predict_one(sm, head=t)
            assert got["energy"] == ref["energy"]
            np.testing.assert_array_equal(got["forces"], ref["forces"])
        st = srv.stats()
        assert st["counters"]["routed"] == len(jobs)
        assert st["plan"]["mode"] == "replica"
        assert st["executable_cache"]["compiled_shapes"] <= \
            st["executable_cache"]["compile_budget"] \
            == SPEC.n_shapes * 2


def _crash_replica(srv, r, sm):
    """Crash replica ``r`` deterministically (the resilience-test pattern):
    its next batcher.add raises, the worker fail-fast handler closes its
    queue. Blocks until the queue is observably closed."""
    def boom(req):
        raise RuntimeError("injected replica fault")
    srv.replicas[r].batcher.add = boom
    # route one trigger request at the doomed replica: it is the sticky /
    # least-loaded pick for a fresh key, and its future must FAIL (the
    # crash handler resolves everything the dead worker held)
    fut = srv.submit(sm, head=r % srv.n_heads)
    assert isinstance(fut.exception(timeout=60), RuntimeError)
    deadline = time.monotonic() + 10.0
    while not srv.replicas[r].queue.closed:
        assert time.monotonic() < deadline, "crashed queue never closed"
        time.sleep(0.005)


def test_replica_failover_then_all_dead_then_restart(served):
    params, sources = served
    srv = ReplicaServeSession(params, CFG, meshes=[None, None], spec=SPEC,
                              max_batch=8, max_wait_ms=1.0)
    try:
        sm = _sample(sources, 0)
        _crash_replica(srv, 0, sm)
        # the scheduler's sticky pick still points at replica 0: the next
        # submit's put fails, replica 0 is marked dead, and the request
        # fails over to replica 1 — and still serves correctly
        got = srv.submit(sm, head=0).result(timeout=60)
        assert got["energy"] == srv.predict_one(sm, head=0)["energy"]
        assert 0 in srv.scheduler.dead
        assert srv.metrics.counters["failovers"] >= 1
        # kill the last replica too -> no live replica to route to
        _crash_replica(srv, 1, sm)
        with pytest.raises(ServeClosedError, match="dead"):
            srv.submit(sm, head=0)
        # recovery: restart_workers rebuilds queue+batcher+worker per dead
        # replica (fresh batcher: the crash patch dies with the old one)
        assert srv.restart_workers() == 2
        assert srv.scheduler.dead == set()
        got = srv.submit(sm, head=0).result(timeout=60)
        assert got["energy"] == srv.predict_one(sm, head=0)["energy"]
    finally:
        srv.close()


def test_replica_shed_and_close_semantics(served):
    params, sources = served
    fc = FakeClock()
    srv = ReplicaServeSession(params, CFG, meshes=[None, None], spec=SPEC,
                              max_batch=4, max_queue_wait_ms=50.0, clock=fc)
    # quiesce replica 0's worker so _file is ours, then shed a stale request
    srv.replicas[0].close()
    req = srv._admission.make_request(_sample(sources, 0), 0)
    assert req.deadline == pytest.approx(fc() + 0.05)
    fc.advance(0.1)                        # aged past the deadline
    assert srv.replicas[0]._file(req) is None
    with pytest.raises(DeadlineExceededError):
        req.future.result(timeout=0)
    assert srv.metrics.counters["shed_deadline"] == 1
    srv.close()
    with pytest.raises(ServeClosedError):
        srv.submit(_sample(sources, 0), head=0)
    srv.close()                            # idempotent re-entry


# ---------------------------------------------------------------------------
# 8 forced host devices: parity + budgets + drain, in a subprocess
# ---------------------------------------------------------------------------

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import jax, jax.numpy as jnp
    import numpy as np
    from repro.configs.base import ArchConfig
    from repro.core.mtl import make_gfm_mtl
    from repro.data.bucketing import BucketSpec
    from repro.data.synthetic_atoms import generate_mixture, source_dicts
    from repro.launch.mesh import make_replica_meshes
    from repro.serve import ReplicaServeSession, ServeSession

    assert jax.device_count() == 8
    cfg = ArchConfig(name="scaleout-sub", family="gnn", gnn_hidden=16,
                     gnn_layers=2, n_species=64, head_hidden=8,
                     head_layers=2, remat=False, compute_dtype=jnp.float32)
    spec = BucketSpec((8, 16), (32, 64))
    sources = source_dicts(generate_mixture(40, max_atoms=16, max_edges=64))
    model = make_gfm_mtl(cfg, len(sources))
    params = model.init(jax.random.PRNGKey(0))
    KEYS = ("species", "pos", "edge_src", "edge_dst", "node_mask",
            "edge_mask")
    def sample(t, i):
        s = sources[t]
        return {k: s[k][i % s["species"].shape[0]] for k in KEYS}
    jobs = [(t, sample(t, i)) for t in range(len(sources))
            for i in range(4)]

    def match(out, ref):
        return out["energy"] == ref["energy"] and \\
            np.array_equal(out["forces"], ref["forces"])

    res = {}
    # plain single-device session = the parity reference for everything
    ref_srv = ServeSession(params, cfg, spec=spec, max_batch=4)
    refs = [ref_srv.predict_one(sm, head=t) for t, sm in jobs]

    # --- replica mode: 8 engines, one per device ---------------------------
    rep = ReplicaServeSession(params, cfg,
                              meshes=make_replica_meshes(8), spec=spec,
                              max_batch=4, max_wait_ms=2.0)
    outs = [f.result(timeout=300)
            for f in [rep.submit(sm, head=t) for t, sm in jobs]]
    st = rep.stats()
    placements = set()
    for s in rep.replicas:
        leaf = jax.tree_util.tree_leaves(s._shared)[0]
        placements.add(tuple(str(d) for d in sorted(
            leaf.devices(), key=str)))
    res["replica"] = {
        "parity": all(match(o, r) for o, r in zip(outs, refs)),
        "routed": st["counters"]["routed"],
        "n_jobs": len(jobs),
        "compilations": st["counters"]["compilations"],
        "compile_budget": st["executable_cache"]["compile_budget"],
        "budget": st["executable_cache"]["budget"],
        "entries": st["executable_cache"]["entries"],
        "plan": st["plan"],
        "distinct_param_placements": len(placements),
        "outstanding_after": st["scheduler"]["outstanding"],
    }
    # close/drain under the replica workers: a burst submitted then closed
    # immediately must still fully resolve (no dropped futures)
    rep2 = ReplicaServeSession(params, cfg,
                               meshes=make_replica_meshes(4), spec=spec,
                               max_batch=4, max_wait_ms=100.0)
    futs2 = [rep2.submit(sm, head=t) for t, sm in jobs]
    rep2.close()
    res["close"] = {
        "all_done": all(f.done() for f in futs2),
        "all_ok": all(f.exception() is None for f in futs2),
    }
    try:
        rep2.submit(jobs[0][1], head=0)
        res["close"]["after_close"] = "accepted"
    except Exception as e:
        res["close"]["after_close"] = type(e).__name__
    rep.close()

    # --- sharded-forward mode: rows data-parallel over one 8-device mesh ---
    mesh8 = make_replica_meshes(1, devices_per_replica=8)[0]
    sh = ServeSession(params, cfg, spec=spec, max_batch=8, mesh=mesh8,
                      max_wait_ms=2.0)
    outs3 = [f.result(timeout=300)
             for f in [sh.submit(sm, head=t) for t, sm in jobs]]
    st3 = sh.stats()
    res["sharded"] = {
        "parity": all(match(o, r) for o, r in zip(outs3, refs)),
        "compilations": st3["counters"]["compilations"],
        "compiled_shapes": st3["executable_cache"]["compiled_shapes"],
        "n_shapes": spec.n_shapes,
        "plan": st3["plan"],
    }
    try:
        ServeSession(params, cfg, spec=spec, max_batch=6, mesh=mesh8)
        res["sharded"]["uneven_raises"] = False
    except ValueError:
        res["sharded"]["uneven_raises"] = True
    sh.close()
    ref_srv.close()
    print("RESULT " + json.dumps(res))
""")


@pytest.fixture(scope="module")
def result():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                          capture_output=True, text=True, timeout=600)
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [l for l in proc.stdout.splitlines()
            if l.startswith("RESULT ")][0]
    return json.loads(line[len("RESULT "):])


def test_replica_rows_bitwise_match_single_device(result):
    """Every replica-served row equals the plain single-device predict_one
    BITWISE: sharding/routing moves rows, it must not change a bit."""
    assert result["replica"]["parity"] is True
    assert result["replica"]["routed"] == result["replica"]["n_jobs"]


def test_replica_compile_budget_is_shapes_times_plans(result):
    rep = result["replica"]
    assert rep["compilations"] <= rep["compile_budget"] == SPEC.n_shapes * 8
    assert rep["entries"] <= rep["budget"]
    assert rep["plan"] == {"mode": "replica", "n_replicas": 8, "devices": 8}


def test_each_replica_owns_its_own_device(result):
    assert result["replica"]["distinct_param_placements"] == 8
    assert result["replica"]["outstanding_after"] == [0] * 8


def test_replica_close_drains_everything(result):
    assert result["close"] == {"all_done": True, "all_ok": True,
                               "after_close": "ServeClosedError"}


def test_sharded_rows_bitwise_match_single_device(result):
    assert result["sharded"]["parity"] is True
    assert result["sharded"]["plan"] == {"mode": "sharded", "devices": 8}


def test_sharded_compile_budget_is_the_bucket_grid(result):
    sh = result["sharded"]
    assert sh["compilations"] <= sh["n_shapes"] == SPEC.n_shapes
    assert sh["compiled_shapes"] <= sh["n_shapes"]
    assert sh["uneven_raises"] is True

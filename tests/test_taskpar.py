"""Multi-task parallelism through the unified engine API: the pjit sharding
backend == explicit shard_map psum backend == single-device jit, all built
via the ONE public path (``engine.make_step`` + ``ShardingPlan.compile``).
Needs >1 device, so runs in a subprocess with 8 host devices (the main
pytest process keeps 1 device)."""
import json
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import jax, jax.numpy as jnp
    from repro.configs.base import ArchConfig
    from repro.core import (MTPConfig, make_gfm_mtl, param_shardings,
                            memory_per_device)
    from repro.data.synthetic_atoms import generate_all, to_batch_dict
    from repro.engine import ShardingPlan, TrainState, make_grad_fn, make_step
    from repro.launch.mesh import make_host_mesh
    from repro.optim import adamw
    import numpy as np

    cfg = ArchConfig(name="g", family="gnn", gnn_hidden=24, gnn_layers=2,
                     n_species=64, head_hidden=12, head_layers=2, remat=False,
                     compute_dtype=jnp.float32)
    T = 4
    model = make_gfm_mtl(cfg, T)
    params = model.init(jax.random.PRNGKey(0))
    data = generate_all(8, max_atoms=10, max_edges=40,
                        sources=["ani1x", "qm7x", "mptrj", "alexandria"])
    bs = [to_batch_dict(sd, np.arange(8)) for sd in data.values()]
    batch = {k: jnp.stack([b[k] for b in bs]) for k in bs[0]}

    def ref_loss(p):
        pt, _ = model.loss_fn(p["shared"], p["heads"], batch)
        return jnp.mean(pt)

    l_ref, g_ref = jax.value_and_grad(ref_loss)(params)

    # exact single-device replica of the shard_map DDP estimator: each data
    # shard normalizes its force MSE by its OWN atom count, then losses
    # average across shards — distinguishes that benign estimator spread
    # from a real backend error
    DP = 2
    half = 8 // DP

    def ddp_ref_loss(p):
        ls = []
        for d in range(DP):
            sub = {k: v[:, d * half:(d + 1) * half] for k, v in batch.items()}
            pt, _ = model.loss_fn(p["shared"], p["heads"], sub)
            ls.append(jnp.mean(pt))
        return sum(ls) / DP

    l_ddp, g_ddp = jax.value_and_grad(ddp_ref_loss)(params)

    mesh = make_host_mesh(2, 4)
    mtp = MTPConfig(n_tasks=T, mode="par")
    plan_pj = ShardingPlan(mesh=mesh, mtp=mtp, backend="pjit", donate=False)
    plan_sm = ShardingPlan(mesh=mesh, mtp=mtp, backend="shard_map",
                           donate=False)
    plan_1 = ShardingPlan(mtp=mtp, donate=False)  # single-device jit

    # grads through the new API (same make_grad_fn call, backend from plan)
    params_pj = jax.device_put(params, plan_pj.params_shardings(params))
    l_pj, _, g_pj = jax.jit(make_grad_fn(model, plan_pj))(
        params_pj, plan_pj.shard_batch(batch))
    l_sm, _, g_sm = jax.jit(make_grad_fn(model, plan_sm))(params, batch)

    # full train-step parity through ShardingPlan.compile — the one public
    # way to build a compiled step, same signature on every backend
    opt = adamw(1e-3)
    def one_step(plan):
        step = plan.compile(make_step(model, opt, plan))
        state = plan.shard_state(TrainState.create(params, opt))
        s2, out = step(state, plan.shard_batch(batch))
        return float(out.loss), jax.device_get(s2.params)

    sl_pj, p_pj = one_step(plan_pj)
    sl_sm, p_sm = one_step(plan_sm)
    sl_1, p_1 = one_step(plan_1)

    def maxerr(a, b):
        e = jax.tree_util.tree_map(lambda x, y: float(jnp.abs(x - y).max()), a, b)
        return max(jax.tree_util.tree_leaves(e))

    # head sharding really is task-sharded on the model axis
    hshard = jax.tree_util.tree_leaves(plan_pj.params_shardings(params)["heads"])[0]
    out = dict(
        l_ref=float(l_ref), l_sm=float(l_sm), l_pj=float(l_pj),
        l_ddp=float(l_ddp),
        g_err_sm=maxerr(g_ref, g_sm), g_err_pj=maxerr(g_ref, g_pj),
        g_err_sm_vs_ddp=maxerr(g_ddp, g_sm),
        sl_pj=sl_pj, sl_sm=sl_sm, sl_1=sl_1,
        p_err_pj_vs_1=maxerr(p_pj, p_1), p_err_pj_vs_sm=maxerr(p_pj, p_sm),
        head_spec=str(hshard.spec),
        mem_par=memory_per_device(100, 10, T, "par"),
        mem_base=memory_per_device(100, 10, T, "base"),
    )
    print("RESULT " + json.dumps(out))
""")


@pytest.fixture(scope="module")
def result():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                          capture_output=True, text=True, timeout=300)
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [l for l in proc.stdout.splitlines() if l.startswith("RESULT ")][0]
    return json.loads(line[len("RESULT "):])


def test_losses_agree(result):
    # shard_map reproduces the paper's per-process DDP loss averaging: the
    # force-MSE normalizes by each shard's OWN atom count, so the mean of
    # per-shard ratios differs from the global ratio by O(batch variance) —
    # a property of real DDP, not an error. Against the exact DDP-estimator
    # replica the shard_map loss must match TIGHTLY (next assert); against
    # the global estimator only loosely.
    np.testing.assert_allclose(result["l_sm"], result["l_ddp"], rtol=1e-5)
    np.testing.assert_allclose(result["l_sm"], result["l_ref"], rtol=0.15)
    np.testing.assert_allclose(result["l_pj"], result["l_ref"], rtol=1e-5)


def test_grads_agree(result):
    assert result["g_err_pj"] < 1e-5, "pjit grads != reference"
    # the tight gate: shard_map must be numerically identical to the exact
    # single-device replica of its own per-shard-normalized estimator
    assert result["g_err_sm_vs_ddp"] < 1e-4, "shard_map grads != DDP replica"
    # and within the benign estimator spread of the global-estimator grads
    assert result["g_err_sm"] < 2e-2, "shard_map grads != reference"


def test_compiled_step_parity(result):
    """pjit / shard_map / single-device through the SAME ShardingPlan.compile
    API produce matching losses and updated params."""
    np.testing.assert_allclose(result["sl_pj"], result["sl_1"], rtol=1e-5)
    np.testing.assert_allclose(result["sl_sm"], result["sl_1"], rtol=0.15)
    assert result["p_err_pj_vs_1"] < 1e-4, "pjit step != single-device step"
    # AdamW's m/sqrt(v) normalization amplifies the DDP-style grad spread
    # on near-zero grads; 2e-2 bounds one update's divergence
    assert result["p_err_pj_vs_sm"] < 2e-2, "shard_map step != pjit step"


def test_heads_sharded_on_task_axis(result):
    assert "model" in result["head_spec"]


def test_memory_model(result):
    # paper section 4.3: P_s + P_h vs P_s + N_h * P_h
    assert result["mem_par"] == 110
    assert result["mem_base"] == 140

"""Multi-task parallelism: pjit sharding path == explicit shard_map psum path
== single-device reference. Needs >1 device, so runs in a subprocess with
8 host devices (the main pytest process keeps 1 device)."""
import json
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import jax, jax.numpy as jnp
    from repro.configs.base import ArchConfig
    from repro.core import (MTPConfig, make_gfm_mtl, mtp_value_and_grad_shardmap,
                            param_shardings, batch_shardings, memory_per_device)
    from repro.data.synthetic_atoms import generate_all, to_batch_dict
    import numpy as np

    cfg = ArchConfig(name="g", family="gnn", gnn_hidden=24, gnn_layers=2,
                     n_species=64, head_hidden=12, head_layers=2, remat=False,
                     compute_dtype=jnp.float32)
    T = 4
    model = make_gfm_mtl(cfg, T)
    params = model.init(jax.random.PRNGKey(0))
    data = generate_all(8, max_atoms=10, max_edges=40,
                        sources=["ani1x", "qm7x", "mptrj", "alexandria"])
    bs = [to_batch_dict(sd, np.arange(8)) for sd in data.values()]
    batch = {k: jnp.stack([b[k] for b in bs]) for k in bs[0]}

    def ref_loss(p):
        pt, _ = model.loss_fn(p["shared"], p["heads"], batch)
        return jnp.mean(pt)

    l_ref, g_ref = jax.value_and_grad(ref_loss)(params)

    mesh = jax.make_mesh((2, 4), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
    mtp = MTPConfig(n_tasks=T, mode="par")

    # shard_map explicit-collective path
    f = mtp_value_and_grad_shardmap(model, mesh, mtp)
    l_sm, g_sm = jax.jit(f)(params, batch)

    # pjit path
    ps = param_shardings(mesh, params, mtp)
    bsh = batch_shardings(mesh, batch, mtp)
    params_s = jax.device_put(params, ps)
    batch_s = jax.device_put(batch, bsh)
    l_pj, g_pj = jax.jit(jax.value_and_grad(ref_loss))(params_s)

    def maxerr(a, b):
        e = jax.tree_util.tree_map(lambda x, y: float(jnp.abs(x - y).max()), a, b)
        return max(jax.tree_util.tree_leaves(e))

    # head sharding really is task-sharded on the model axis
    hshard = jax.tree_util.tree_leaves(ps["heads"])[0]
    out = dict(
        l_ref=float(l_ref), l_sm=float(l_sm), l_pj=float(l_pj),
        g_err_sm=maxerr(g_ref, g_sm), g_err_pj=maxerr(g_ref, g_pj),
        head_spec=str(hshard.spec),
        mem_par=memory_per_device(100, 10, T, "par"),
        mem_base=memory_per_device(100, 10, T, "base"),
    )
    print("RESULT " + json.dumps(out))
""")


@pytest.fixture(scope="module")
def result():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                          capture_output=True, text=True, timeout=300)
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [l for l in proc.stdout.splitlines() if l.startswith("RESULT ")][0]
    return json.loads(line[len("RESULT "):])


def test_losses_agree(result):
    # shard_map reproduces the paper's per-process DDP loss averaging: the
    # force-MSE normalizes by each shard's OWN atom count, so the mean of
    # per-shard ratios differs from the global ratio by O(batch variance) —
    # a property of real DDP, not an error. Grads agree to 5e-3 below.
    # O(10%) spread between the two estimators at local batch 8 is expected;
    # the GRADIENTS are the contract and match to 5e-3 (next test).
    np.testing.assert_allclose(result["l_sm"], result["l_ref"], rtol=0.15)
    np.testing.assert_allclose(result["l_pj"], result["l_ref"], rtol=1e-5)


def test_grads_agree(result):
    assert result["g_err_pj"] < 1e-5, "pjit grads != reference"
    assert result["g_err_sm"] < 5e-3, "shard_map grads != reference"


def test_heads_sharded_on_task_axis(result):
    assert "model" in result["head_spec"]


def test_memory_model(result):
    # paper section 4.3: P_s + P_h vs P_s + N_h * P_h
    assert result["mem_par"] == 110
    assert result["mem_base"] == 140

"""Seeded-sweep property tests for the data pipeline.

This container has no ``hypothesis`` (jax 0.4.37 host), so these sweeps
draw their own randomized configurations from seeded NumPy generators —
deterministic, ≥ 50 drawn configurations per property — and assert the
subsystem invariants the docs promise:

  * ``BucketingBatcher`` never drops content: trimming only removes
    trailing pad, every real atom/edge value survives bit-identical, and
    the trimmed batch keeps the ``>= A_pad`` edge-sentinel contract the
    kernels rely on (``docs/kernels.md``).
  * ``MixingBatcher``'s deterministic schedule tracks the target weights
    within the documented bound: after k batches every source's cumulative
    count is within ``len(sources)`` of ``k·B·w_s`` — not just in
    expectation.
"""
import numpy as np

from repro.data.bucketing import (ATOM_KEYS, EDGE_KEYS, BucketingBatcher,
                                  BucketSpec)
from repro.data.mixing import MixingBatcher, MixingConfig

N_CONFIGS = 60      # ≥ 50 drawn configurations per property


# ---------------------------------------------------------------------------
# BucketingBatcher: trimming is content-exact and sentinel-valid
# ---------------------------------------------------------------------------

class _RandomFrontPackedBatcher:
    """Emits flat (B, A, ...) batches with front-packed masks and random
    per-sample content sizes — the contract every store in this repo
    satisfies, with full control over the drawn shapes."""

    def __init__(self, rng, B, A, E):
        self.rng, self.B, self.A, self.E = rng, B, A, E

    def next_batch(self):
        rng, B, A, E = self.rng, self.B, self.A, self.E
        na = rng.integers(1, A + 1, size=B)            # content atoms
        ne = rng.integers(0, E + 1, size=B)            # content edges
        nm = np.arange(A)[None, :] < na[:, None]
        em = np.arange(E)[None, :] < ne[:, None]
        src = rng.integers(0, np.maximum(na, 1)[:, None], (B, E))
        dst = rng.integers(0, np.maximum(na, 1)[:, None], (B, E))
        batch = {
            "species": rng.integers(1, 9, (B, A)) * nm,
            "pos": rng.normal(size=(B, A, 3)).astype(np.float32) * nm[..., None],
            "forces": rng.normal(size=(B, A, 3)).astype(np.float32) * nm[..., None],
            "node_mask": nm,
            "edge_src": np.where(em, src, A).astype(np.int32),
            "edge_dst": np.where(em, dst, A).astype(np.int32),
            "edge_mask": em,
            "energy": rng.normal(size=(B,)).astype(np.float32),
        }
        return batch


def _draw_spec(rng, A, E):
    a_cuts = np.unique(rng.integers(1, A, size=rng.integers(1, 4)))
    e_cuts = np.unique(rng.integers(1, E, size=rng.integers(1, 4)))
    return BucketSpec(tuple(int(c) for c in a_cuts) + (A,),
                      tuple(int(c) for c in e_cuts) + (E,))


def test_bucketing_never_drops_content_sweep():
    """≥ 50 random (B, A, E, bucket-grid) configurations: every batch the
    trimmer emits is the wrapped batch minus trailing pad, nothing else."""
    for seed in range(N_CONFIGS):
        rng = np.random.default_rng(1000 + seed)       # config draws only
        B = int(rng.integers(1, 7))
        A = int(rng.integers(4, 40))
        E = int(rng.integers(4, 90))
        spec = _draw_spec(rng, A, E)
        # two identical content streams: one trimmed, one raw mirror
        inner = _RandomFrontPackedBatcher(
            np.random.default_rng((1000 + seed, 1)), B, A, E)
        mirror = _RandomFrontPackedBatcher(
            np.random.default_rng((1000 + seed, 1)), B, A, E)
        bb = BucketingBatcher(inner, spec)
        for _ in range(3):
            raw = mirror.next_batch()
            out = bb.next_batch()
            A_t = out["node_mask"].shape[-1]
            E_t = out["edge_mask"].shape[-1]
            # the emitted shape is a grid shape, the SMALLEST one that holds
            # the content
            assert (A_t, E_t) == spec.ceil(int(raw["node_mask"].sum(-1).max()),
                                           int(raw["edge_mask"].sum(-1).max()))
            # no content dropped: mask mass conserved ...
            assert out["node_mask"].sum() == raw["node_mask"].sum()
            assert out["edge_mask"].sum() == raw["edge_mask"].sum()
            # ... and every surviving value is bit-identical to the source
            for k in ATOM_KEYS:
                if k in raw:
                    np.testing.assert_array_equal(out[k], raw[k][:, :A_t],
                                                  err_msg=k)
            for k in ("edge_mask",):
                np.testing.assert_array_equal(out[k], raw[k][:, :E_t])
            # untouched passthrough keys
            np.testing.assert_array_equal(out["energy"], raw["energy"])


def test_bucketing_trimmed_edges_stay_sentinel_valid_sweep():
    """≥ 50 random configurations: in every trimmed batch, masked edges
    point at the TRIMMED pad sentinel (>= A_t) and real edges keep their
    original in-range endpoints — the kernels' ``>= n_nodes`` contract."""
    for seed in range(N_CONFIGS):
        rng = np.random.default_rng(7000 + seed)       # config draws only
        B = int(rng.integers(1, 6))
        A = int(rng.integers(4, 32))
        E = int(rng.integers(4, 70))
        spec = _draw_spec(rng, A, E)
        inner = _RandomFrontPackedBatcher(
            np.random.default_rng((7000 + seed, 1)), B, A, E)
        mirror = _RandomFrontPackedBatcher(
            np.random.default_rng((7000 + seed, 1)), B, A, E)
        bb = BucketingBatcher(inner, spec)
        for _ in range(3):
            raw = mirror.next_batch()
            out = bb.next_batch()
            A_t = out["node_mask"].shape[-1]
            E_t = out["edge_mask"].shape[-1]
            em = out["edge_mask"]
            for k in ("edge_src", "edge_dst"):
                assert (out[k][~em] >= A_t).all(), \
                    f"masked {k} below the trimmed sentinel"
                assert (out[k][em] < A_t).all(), f"real {k} out of range"
                np.testing.assert_array_equal(out[k][em], raw[k][:, :E_t][em],
                                              err_msg=k)
            # real edges only reference real (unmasked) nodes
            per_row_atoms = out["node_mask"].sum(-1)
            assert (out["edge_src"][em]
                    < np.broadcast_to(per_row_atoms[:, None], em.shape)[em]).all()


# ---------------------------------------------------------------------------
# MixingBatcher: realized counts track the target weights
# ---------------------------------------------------------------------------

def _mix_sources(rng, n_sources):
    sizes = rng.integers(3, 60, size=n_sources)
    return [{"x": (1000 * s + np.arange(n)).astype(np.int64)}
            for s, n in enumerate(sizes)], sizes


def test_mixing_counts_track_weights_sweep():
    """≥ 50 random (sources, B, temperature/explicit-weights, seed)
    configurations: cumulative per-source counts stay within the documented
    bound (len(sources)) of k·B·w_s at EVERY k."""
    for seed in range(N_CONFIGS):
        rng = np.random.default_rng(3000 + seed)
        n_sources = int(rng.integers(1, 6))
        sources, sizes = _mix_sources(rng, n_sources)
        if rng.random() < 0.5:
            mix = MixingConfig(temperature=float(rng.uniform(0.5, 4.0)),
                               emit_source=True)
        else:
            mix = MixingConfig(weights=tuple(rng.uniform(0.2, 3.0,
                                                         n_sources)),
                               emit_source=True)
        B = int(rng.integers(1, 18))
        mb = MixingBatcher(sources, B, mixing=mix, seed=seed)
        counts = np.zeros(n_sources)
        for k in range(1, 13):
            batch = mb.next_batch()
            assert batch["x"].shape[0] == B          # exact batch size
            counts += np.bincount(batch["source_id"], minlength=n_sources)
            dev = np.abs(counts - k * B * mb.weights).max()
            assert dev <= n_sources, \
                f"seed={seed}: drift {dev:.2f} > {n_sources} at batch {k}"


def test_mixing_stream_is_lossless_per_source_sweep():
    """≥ 50 configurations: within any window, the samples drawn from a
    source are distinct until its local epoch wraps (shuffled-cyclic — the
    mixture never repeats a sample before exhausting its source)."""
    for seed in range(N_CONFIGS):
        rng = np.random.default_rng(5000 + seed)
        n_sources = int(rng.integers(1, 5))
        sources, sizes = _mix_sources(rng, n_sources)
        B = int(rng.integers(2, 12))
        mb = MixingBatcher(sources, B,
                           mixing=MixingConfig(emit_source=True), seed=seed)
        drawn = [[] for _ in range(n_sources)]
        for _ in range(6):
            b = mb.next_batch()
            for s in range(n_sources):
                drawn[s].extend(b["x"][b["source_id"] == s].tolist())
        for s, n in enumerate(sizes):
            vals = np.asarray(drawn[s], np.int64)
            assert ((vals >= 1000 * s) & (vals < 1000 * s + n)).all()
            # shuffled-cyclic: over f = len//n full epochs every sample is
            # drawn f or f+1 times, and exactly len%n samples got the extra
            # draw (order-independent — batch composition shuffles draws)
            full, rest = divmod(len(vals), n)
            hist = np.bincount(vals - 1000 * s, minlength=n)
            assert hist.min() >= full and hist.max() <= full + 1, \
                f"seed={seed}, source {s}: non-cyclic draw"
            assert int((hist == full + 1).sum()) == rest

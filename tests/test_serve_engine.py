"""repro.serve — the property-prediction serving engine.

Covers the ISSUE-6 contracts: batched-and-scattered predictions bitwise-
match the single-request forward for every head; a lone request flushes at
the max_wait deadline instead of waiting for a full bucket; shutdown drains
everything in flight; metrics counters reconcile with what was submitted;
and the compiled-executable cache stays within the bucket-grid budget."""
import threading
import time

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.core.mtl import make_gfm_mtl
from repro.data.bucketing import BucketOverflowError, BucketSpec
from repro.data.synthetic_atoms import generate_mixture, source_dicts
from repro.serve import (Reservoir, ServeMetrics, ServeSession,
                         SizeBinnedBatcher, assemble)
from repro.serve.queue import RequestQueue

CFG = ArchConfig(name="serve-test", family="gnn", gnn_hidden=16,
                 gnn_layers=2, n_species=64, head_hidden=8, head_layers=2,
                 remat=False, compute_dtype=jnp.float32)
SPEC = BucketSpec((8, 16), (32, 64))


@pytest.fixture(scope="module")
def served():
    """(params, sources): one tiny trained-shape model + five-source data,
    shared across tests (init dominates test time otherwise)."""
    sources = source_dicts(generate_mixture(40, max_atoms=16, max_edges=64))
    model = make_gfm_mtl(CFG, len(sources))
    params = model.init(jax.random.PRNGKey(0))
    return params, sources


def _sample(sources, t, i):
    s = sources[t]
    i = i % s["species"].shape[0]        # small sources wrap around
    return {k: s[k][i] for k in ("species", "pos", "edge_src", "edge_dst",
                                 "node_mask", "edge_mask")}


# ---------------------------------------------------------------------------
# correctness: batched == single-request, per head
# ---------------------------------------------------------------------------

def test_batched_predictions_bitwise_match_single_request(served):
    """Every head, mixed bucket sizes, submitted together so the binner
    coalesces them — each scattered row must BITWISE match the same request
    run alone through predict_one (one real row + inert pad rows, same
    executable). Rows are independent through the whole forward, so
    coalescing must not change a single bit."""
    params, sources = served
    with ServeSession(params, CFG, spec=SPEC, max_batch=4,
                      max_wait_ms=2.0) as srv:
        jobs = [(t, _sample(sources, t, i))
                for t in range(len(sources)) for i in range(3)]
        futs = [(t, sm, srv.submit(sm, head=t)) for t, sm in jobs]
        for t, sm, fut in futs:
            got = fut.result(timeout=60)
            ref = srv.predict_one(sm, head=t)
            assert got["energy"] == ref["energy"], (t, got, ref)
            np.testing.assert_array_equal(got["forces"], ref["forces"])
            n_atoms = int(np.asarray(sm["node_mask"]).sum())
            assert got["forces"].shape == (n_atoms, 3)


def test_prediction_matches_plain_jnp_forward(served):
    """predict_one itself is honest: it equals the un-served egnn +
    branch forward on the padded batch (so the whole serve path is the
    model, not an approximation of it)."""
    from repro.models import gnn, heads
    params, sources = served
    with ServeSession(params, CFG, spec=SPEC, max_batch=4) as srv:
        t, sm = 2, _sample(sources, 2, 0)
        got = srv.predict_one(sm, head=t)
        a_pad, e_pad = SPEC.bucket_for(int(sm["node_mask"].sum()),
                                       int(sm["edge_mask"].sum()))
        batch = {
            "species": np.where(sm["node_mask"], sm["species"],
                                0)[None, :a_pad],
            "pos": (sm["pos"] * sm["node_mask"][:, None])[None, :a_pad],
            "edge_src": np.where(sm["edge_mask"], sm["edge_src"],
                                 a_pad)[None, :e_pad].astype(np.int32),
            "edge_dst": np.where(sm["edge_mask"], sm["edge_dst"],
                                 a_pad)[None, :e_pad].astype(np.int32),
            "node_mask": sm["node_mask"][None, :a_pad],
            "edge_mask": sm["edge_mask"][None, :e_pad],
        }
        feats = gnn.egnn_apply(params["shared"],
                               {k: jnp.asarray(v) for k, v in batch.items()},
                               cfg=CFG)
        hp = jax.tree_util.tree_map(lambda v: v[t], params["heads"])
        e, f = heads.branch_apply(hp, feats,
                                  jnp.asarray(batch["node_mask"]), cfg=CFG)
        n = int(sm["node_mask"].sum())
        np.testing.assert_allclose(got["energy"], float(np.asarray(e)[0]),
                                   rtol=1e-6)
        np.testing.assert_allclose(got["forces"], np.asarray(f)[0, :n],
                                   rtol=1e-5, atol=1e-6)


# ---------------------------------------------------------------------------
# bounded latency: partial flush
# ---------------------------------------------------------------------------

def test_lone_request_flushes_at_deadline_not_full_batch(served):
    """A single request against a huge max_batch must resolve on the
    max_wait deadline — bounded p99 under low arrival rates."""
    params, sources = served
    with ServeSession(params, CFG, spec=SPEC, max_batch=64,
                      max_wait_ms=20.0) as srv:
        srv.warmup()                     # exclude compile from the bound
        fut = srv.submit(_sample(sources, 0, 0), head=0)
        t0 = time.monotonic()
        out = fut.result(timeout=10)     # would deadlock if it waited for 64
        waited = time.monotonic() - t0
        assert np.isfinite(out["energy"])
        assert waited < 5.0, f"partial flush took {waited:.2f}s"
        snap = srv.stats()
        assert snap["counters"]["batches"] >= 1
        assert snap["counters"]["batch_real"] < snap["counters"]["batch_slots"]


def test_full_bin_releases_before_deadline(served):
    """max_batch requests of one bucket+head release immediately — the
    deadline is a bound, not a schedule."""
    params, sources = served
    sm = _sample(sources, 0, 0)
    with ServeSession(params, CFG, spec=SPEC, max_batch=2,
                      max_wait_ms=10_000.0) as srv:    # absurd deadline
        srv.warmup()
        futs = [srv.submit(sm, head=0) for _ in range(2)]
        for f in futs:
            f.result(timeout=10)         # would time out if deadline-bound


# ---------------------------------------------------------------------------
# shutdown drains
# ---------------------------------------------------------------------------

def test_close_drains_in_flight_requests(served):
    """Everything admitted before close() resolves — queued AND partially
    binned requests run through the compiled path on shutdown."""
    params, sources = served
    srv = ServeSession(params, CFG, spec=SPEC, max_batch=8,
                       max_wait_ms=10_000.0)   # nothing flushes on its own
    futs = [srv.submit(_sample(sources, t, i), head=t)
            for t in range(3) for i in range(3)]
    srv.close()
    for f in futs:
        assert np.isfinite(f.result(timeout=1)["energy"])
    srv.close()                          # idempotent no-op
    with pytest.raises(RuntimeError, match="closed"):
        srv.submit(_sample(sources, 0, 0), head=0)
    snap = srv.stats()
    assert snap["counters"]["completed"] == len(futs)


def test_close_is_reentrant_from_context_manager(served):
    params, sources = served
    with ServeSession(params, CFG, spec=SPEC) as srv:
        srv.submit(_sample(sources, 0, 0))
        srv.close()                      # explicit close, then __exit__
    assert not srv._worker.is_alive()


# ---------------------------------------------------------------------------
# metrics reconcile
# ---------------------------------------------------------------------------

def test_metrics_counters_reconcile(served):
    params, sources = served
    with ServeSession(params, CFG, spec=SPEC, max_batch=4,
                      max_wait_ms=1.0) as srv:
        n_ok = 0
        for t in range(len(sources)):
            for i in range(4):
                srv.submit(_sample(sources, t, i), head=t)
                n_ok += 1
        with pytest.raises(ValueError):
            srv.submit(_sample(sources, 0, 0), head=99)   # unknown head
        big = {"species": np.ones(40, np.int32),
               "pos": np.zeros((40, 3), np.float32)}
        with pytest.raises(BucketOverflowError):
            srv.submit(big, head=0)                       # over the grid cap
        srv.close()
        snap = srv.stats()
    c = snap["counters"]
    assert c["submitted"] == n_ok
    assert c["completed"] == n_ok and c["failed"] == 0
    assert c["rejected"] == 2
    assert c["batch_real"] == n_ok
    assert c["batch_slots"] == c["batches"] * 4
    lat = snap["latency"]
    assert lat["e2e"]["count"] == n_ok
    assert lat["queue_wait"]["count"] == n_ok
    assert lat["e2e"]["p99_ms"] >= lat["e2e"]["p50_ms"] >= 0.0


def test_reservoir_is_deterministic_and_bounded():
    xs = (np.sin(np.arange(10_000)) + 2.0).tolist()
    a, b = Reservoir(capacity=64, seed=3), Reservoir(capacity=64, seed=3)
    for x in xs:
        a.add(x)
        b.add(x)
    assert a.percentiles() == b.percentiles()
    assert len(a._buf) == 64 and a.count == 10_000
    # exact below capacity
    c = Reservoir(capacity=64, seed=0)
    for x in range(11):
        c.add(float(x))
    assert c.percentiles((50,))["p50"] == 5.0


# ---------------------------------------------------------------------------
# executable-cache / recompile budget
# ---------------------------------------------------------------------------

def test_compilations_within_bucket_grid_budget(served):
    """The acceptance bound: total compilations <= len(atom_buckets) x
    len(edge_buckets) x n_heads. The engine does strictly better — one
    shared jitted forward means compilations == distinct bucket shapes —
    but the asserted budget is the ISSUE's."""
    params, sources = served
    n_heads = len(sources)
    with ServeSession(params, CFG, spec=SPEC, max_batch=2,
                      max_wait_ms=1.0) as srv:
        futs = []
        for t in range(n_heads):
            for i in range(6):           # sizes spread over the 2x2 grid
                futs.append(srv.submit(_sample(sources, t, i), head=t))
        for f in futs:
            f.result(timeout=60)
        snap = srv.stats()
    budget = SPEC.n_shapes * n_heads     # 2 x 2 x 5
    assert snap["counters"]["compilations"] <= budget, snap
    assert snap["executable_cache"]["compiled_shapes"] <= SPEC.n_shapes
    assert snap["executable_cache"]["entries"] <= budget
    # cross-check the counter against jax's own jit cache when exposed
    cache_size = getattr(srv._predict, "_cache_size", None)
    if callable(cache_size):
        assert cache_size() <= budget, \
            "jit compiled more variants than the bucket-grid budget"


def test_warmup_precompiles_full_grid(served):
    params, sources = served
    with ServeSession(params, CFG, spec=SPEC, max_batch=2) as srv:
        n = srv.warmup()
        assert n == SPEC.n_shapes
        assert srv.stats()["counters"]["compilations"] == SPEC.n_shapes


# ---------------------------------------------------------------------------
# admission + queue behaviour
# ---------------------------------------------------------------------------

def test_admission_rejects_in_caller_thread(served):
    params, sources = served
    with ServeSession(params, CFG, spec=SPEC) as srv:
        with pytest.raises(ValueError, match="front-packed"):
            bad = dict(_sample(sources, 0, 0))
            nm = bad["node_mask"].copy()
            nm[:] = False
            nm[-1] = True                # real atom in the last slot
            bad["node_mask"] = nm
            srv.submit(bad)
        with pytest.raises(ValueError, match="SINGLE structure"):
            srv.submit({"species": np.ones((2, 8), np.int32),
                        "pos": np.zeros((2, 8, 3), np.float32)})


def test_masks_derived_when_absent(served):
    """species+pos(+edges) alone are a valid request — masks default to
    species>0 / in-range endpoints (the ASE-calculator-style entry)."""
    params, sources = served
    sm = _sample(sources, 1, 0)
    n = int(sm["node_mask"].sum())
    bare = {"species": sm["species"][:n], "pos": sm["pos"][:n],
            "edge_src": sm["edge_src"], "edge_dst": sm["edge_dst"]}
    with ServeSession(params, CFG, spec=SPEC, max_wait_ms=1.0) as srv:
        out = srv.submit(bare, head=1).result(timeout=30)
        ref = srv.predict_one(sm, head=1)
        assert out["energy"] == ref["energy"]


def test_queue_backpressure_and_close():
    q = RequestQueue(SPEC, depth=1, n_heads=1)
    sm = {"species": np.ones(4, np.int32), "pos": np.zeros((4, 3),
                                                           np.float32)}
    q.submit(sm)                         # fills the single slot
    blocked = threading.Event()

    def second():
        blocked.set()
        with pytest.raises(RuntimeError, match="closed"):
            q.submit(sm)                 # blocks, then unblocked by close

    th = threading.Thread(target=second, daemon=True)
    th.start()
    blocked.wait(2.0)
    time.sleep(0.1)
    q.close()
    th.join(timeout=5.0)
    assert not th.is_alive(), "close() must unblock a waiting submit()"
    q.close()                            # idempotent
    assert len(q.drain()) == 1


# ---------------------------------------------------------------------------
# binner unit behaviour
# ---------------------------------------------------------------------------

def _req(n_atoms, head=0, t=0.0, bucket=(8, 32)):
    from repro.serve.queue import Request, _as_sample
    sm, na, ne = _as_sample({"species": np.ones(n_atoms, np.int32),
                             "pos": np.zeros((n_atoms, 3), np.float32)})
    return Request(sample=sm, head=head, bucket=bucket, n_atoms=na,
                   n_edges=ne, future=None, t_submit=t)


def test_binner_separates_buckets_and_heads():
    bb = SizeBinnedBatcher(max_batch=2, max_wait=1.0)
    assert bb.add(_req(4, head=0)) is None
    assert bb.add(_req(4, head=1)) is None       # other head: other bin
    assert bb.add(_req(4, head=0, bucket=(16, 32))) is None   # other bucket
    ab = bb.add(_req(4, head=0))                 # fills the first bin
    assert ab is not None and ab.n_real == 2 and ab.head == 0
    assert bb.n_pending == 2
    assert len(bb.flush()) == 2 and bb.n_pending == 0


def test_binner_deadline_and_static_shape():
    bb = SizeBinnedBatcher(max_batch=4, max_wait=0.5)
    bb.add(_req(4, t=0.0))
    assert bb.expired(now=0.4) == []
    assert round(bb.next_deadline(now=0.4), 6) == round(0.1, 6)
    [ab] = bb.expired(now=0.6)
    assert ab.n_real == 1
    # partial flush still pads to the STATIC (max_batch, A_pad, E_pad)
    assert ab.batch["species"].shape == (4, 8)
    assert ab.batch["edge_src"].shape == (4, 32)
    assert not ab.batch["node_mask"][1:].any()   # inert pad rows
    assert (ab.batch["edge_src"][1:] == 8).all()  # sentinel == A_pad
    assert bb.next_deadline(now=0.7) is None


def test_assemble_repoints_masked_edges_at_trimmed_sentinel():
    sm = {"species": np.array([1, 2, 0, 0], np.int32),
          "pos": np.zeros((4, 3), np.float32),
          "edge_src": np.array([0, 1, 4, 4], np.int32),
          "edge_dst": np.array([1, 0, 4, 4], np.int32),
          "node_mask": np.array([True, True, False, False]),
          "edge_mask": np.array([True, True, False, False])}
    from repro.serve.queue import Request, _as_sample
    canon, na, ne = _as_sample(sm)
    req = Request(sample=canon, head=0, bucket=(8, 32), n_atoms=na,
                  n_edges=ne, future=None, t_submit=0.0)
    ab = assemble([req], (8, 32), 2)
    assert (ab.batch["edge_src"][0, 2:] == 8).all()   # re-pointed to A_pad=8
    assert (ab.batch["edge_src"][0, :2] == [0, 1]).all()


# ---------------------------------------------------------------------------
# checkpoint round trip
# ---------------------------------------------------------------------------

def test_from_checkpoint_serves_saved_params(served, tmp_path):
    from repro.train import checkpoint
    params, sources = served
    path = str(tmp_path / "ck")
    checkpoint.save(path, {"params": params})
    srv = ServeSession.from_checkpoint(
        path, CFG, n_heads=len(sources), spec=SPEC, max_wait_ms=1.0)
    with srv, ServeSession(params, CFG, spec=SPEC,
                           max_wait_ms=1.0) as direct:
        sm = _sample(sources, 3, 1)
        a = srv.submit(sm, head=3).result(timeout=30)
        b = direct.submit(sm, head=3).result(timeout=30)
        assert a["energy"] == b["energy"]
        np.testing.assert_array_equal(a["forces"], b["forces"])


# ---------------------------------------------------------------------------
# ONE clock base (ISSUE 10): queue + batcher + metrics share an injected clock
# ---------------------------------------------------------------------------

class FakeClock:
    """Injectable clock offset ~1e6 s from every real clock base."""

    def __init__(self, t0: float = 1e6):
        self.t = float(t0)

    def __call__(self) -> float:
        return self.t

    def advance(self, dt: float):
        self.t += dt


def test_one_injected_clock_threads_through_queue_batcher_metrics(served):
    """Regression for cross-base skew (monotonic deadlines vs perf_counter
    stamps): ONE fake clock, offset ~1e6 s from both real bases, drives the
    queue, the batcher, and the metrics. If any of them secretly read a
    real clock, deadlines/expiry/elapsed would be off by ~1e6 s — bins
    would expire instantly (or never) and the assertions below would
    explode rather than drift."""
    _, sources = served
    fc = FakeClock(1e6)
    m = ServeMetrics(clock=fc)
    q = RequestQueue(SPEC, depth=8, n_heads=5, clock=fc, metrics=m,
                     max_queue_wait=0.05)
    b = SizeBinnedBatcher(max_batch=8, max_wait=0.005, clock=fc)
    q.submit(_sample(sources, 0, 0), head=0)
    req = q.get(timeout=1.0)
    assert req.t_submit == 1e6
    assert req.deadline == pytest.approx(1e6 + 0.05)
    assert b.add(req) is None
    # no `now` passed: the batcher must consult the SAME injected clock
    assert b.expired() == []
    assert b.next_deadline() == pytest.approx(0.005)
    fc.advance(0.004)
    assert b.expired() == []
    fc.advance(0.002)
    assert len(b.expired()) == 1
    fc.advance(10.0)
    snap = m.snapshot()
    assert snap["rates"]["elapsed_s"] == pytest.approx(10.006)
    assert snap["rates"]["submitted_per_s"] == pytest.approx(1 / 10.006)


def test_session_deadlines_follow_the_injected_clock_not_wall_time(served):
    """A frozen fake clock freezes bin expiry: the partial bin flushes only
    when the INJECTED clock passes max_wait, however much wall time elapses
    (the worker's poll sleeps on wall time; its deadline math must not)."""
    params, sources = served
    fc = FakeClock(5e5)
    with ServeSession(params, CFG, spec=SPEC, max_batch=4, max_wait_ms=5.0,
                      clock=fc) as srv:
        sm = _sample(sources, 0, 0)
        fut = srv.submit(sm, head=0)
        time.sleep(0.3)        # 60x max_wait in wall time; fake clock frozen
        assert not fut.done()
        fc.advance(0.006)      # past max_wait on the one true clock
        got = fut.result(timeout=10)
        ref = srv.predict_one(sm, head=0)
        assert got["energy"] == ref["energy"]
        np.testing.assert_array_equal(got["forces"], ref["forces"])


def test_shed_decision_uses_the_injected_clock(served):
    """Two requests stamped at the same fake instant: filed fresh -> binned;
    filed after the fake clock jumps past their deadline -> shed. Wall time
    is identical for both, so any divergence is purely the injected base."""
    from concurrent.futures import Future

    from repro.serve.queue import (DeadlineExceededError, Request,
                                   _as_sample)
    params, sources = served
    fc = FakeClock()
    srv = ServeSession(params, CFG, spec=SPEC, max_batch=4,
                       max_queue_wait_ms=50.0, clock=fc)
    srv.close()                            # worker quiesced; _file is ours
    canon, n_atoms, n_edges = _as_sample(_sample(sources, 0, 0))
    bucket = SPEC.bucket_for(n_atoms, n_edges)

    def stamped():
        return Request(sample=canon, head=0, bucket=bucket,
                       n_atoms=n_atoms, n_edges=n_edges, future=Future(),
                       t_submit=fc(), deadline=fc() + 0.05)

    r1, r2 = stamped(), stamped()
    assert srv._file(r1) is None           # fresh: binned, NOT shed
    assert srv.batcher.n_pending == 1
    fc.advance(0.1)                        # both deadlines now in the past
    assert srv._file(r2) is None           # stale: shed, never binned
    assert srv.batcher.n_pending == 1
    with pytest.raises(DeadlineExceededError):
        r2.future.result(timeout=0)
    assert srv.stats()["counters"]["shed_deadline"] == 1

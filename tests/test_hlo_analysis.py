"""The loop-aware HLO analyzer: exact on known programs, and strictly more
complete than XLA's cost_analysis on loops."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.launch.hlo_analysis import analyze_hlo, xla_cost_analysis


def _compile(f, *specs):
    return jax.jit(f).lower(*specs).compile()


def test_flat_matmul():
    M = K = N = 128
    c = _compile(lambda a, b: a @ b,
                 jax.ShapeDtypeStruct((M, K), jnp.float32),
                 jax.ShapeDtypeStruct((K, N), jnp.float32))
    r = analyze_hlo(c.as_text())
    assert r["flops"] == 2 * M * N * K


def test_scan_multiplies_trip_count():
    M = K = 64
    def g(a, ws):
        def body(x, w):
            return jnp.tanh(x @ w), ()
        y, _ = jax.lax.scan(body, a, ws)
        return y
    c = _compile(g, jax.ShapeDtypeStruct((M, K), jnp.float32),
                 jax.ShapeDtypeStruct((10, K, K), jnp.float32))
    r = analyze_hlo(c.as_text())
    assert r["flops"] == 10 * 2 * M * K * K
    assert float(xla_cost_analysis(c)["flops"]) < r["flops"]  # XLA undercounts


def test_nested_scan():
    M = K = 32
    def h(a, ws):
        def outer(x, w):
            def inner(y, _):
                return jnp.tanh(y @ w), ()
            y, _ = jax.lax.scan(inner, x, None, length=5)
            return y, ()
        y, _ = jax.lax.scan(outer, a, ws)
        return y
    c = _compile(h, jax.ShapeDtypeStruct((M, K), jnp.float32),
                 jax.ShapeDtypeStruct((4, K, K), jnp.float32))
    r = analyze_hlo(c.as_text())
    assert r["flops"] == 4 * 5 * 2 * M * K * K


def test_traffic_scales_with_trip_count():
    K = 64
    def g(a, ws):
        def body(x, w):
            return jnp.tanh(x @ w), ()
        y, _ = jax.lax.scan(body, a, ws)
        return y
    specs = lambda n: (jax.ShapeDtypeStruct((K, K), jnp.float32),
                       jax.ShapeDtypeStruct((n, K, K), jnp.float32))
    t2 = analyze_hlo(_compile(g, *specs(2)).as_text())["traffic_bytes"]
    t8 = analyze_hlo(_compile(g, *specs(8)).as_text())["traffic_bytes"]
    assert 2.5 < t8 / t2 < 4.5  # ~4x body traffic, constant overhead


def test_remat_recompute_is_counted():
    K = 64
    def f(a, w):
        return jnp.sum(jax.checkpoint(lambda x: jnp.tanh(x @ w) @ w)(a))
    g = jax.grad(f)
    c = _compile(g, jax.ShapeDtypeStruct((K, K), jnp.float32),
                 jax.ShapeDtypeStruct((K, K), jnp.float32))
    r = analyze_hlo(c.as_text())
    # XLA CSEs the checkpoint recompute at this scale; the invariant that
    # matters is that backward dots are counted and the analyzer is at least
    # as complete as XLA's own accounting.
    assert r["flops"] >= 3 * 2 * K ** 3
    # within ~2% of XLA's own count on a loop-free graph (XLA additionally
    # counts a few elementwise transcendental fusions as flops)
    assert r["flops"] >= float(xla_cost_analysis(c)["flops"]) * 0.95

"""The loop-aware HLO analyzer: exact on known programs, and strictly more
complete than XLA's cost_analysis on loops."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.launch.hlo_analysis import analyze_hlo, xla_cost_analysis


def _compile(f, *specs):
    return jax.jit(f).lower(*specs).compile()


def test_flat_matmul():
    M = K = N = 128
    c = _compile(lambda a, b: a @ b,
                 jax.ShapeDtypeStruct((M, K), jnp.float32),
                 jax.ShapeDtypeStruct((K, N), jnp.float32))
    r = analyze_hlo(c.as_text())
    assert r["flops"] == 2 * M * N * K


def test_scan_multiplies_trip_count():
    M = K = 64
    def g(a, ws):
        def body(x, w):
            return jnp.tanh(x @ w), ()
        y, _ = jax.lax.scan(body, a, ws)
        return y
    c = _compile(g, jax.ShapeDtypeStruct((M, K), jnp.float32),
                 jax.ShapeDtypeStruct((10, K, K), jnp.float32))
    r = analyze_hlo(c.as_text())
    assert r["flops"] == 10 * 2 * M * K * K
    assert float(xla_cost_analysis(c)["flops"]) < r["flops"]  # XLA undercounts


def test_nested_scan():
    M = K = 32
    def h(a, ws):
        def outer(x, w):
            def inner(y, _):
                return jnp.tanh(y @ w), ()
            y, _ = jax.lax.scan(inner, x, None, length=5)
            return y, ()
        y, _ = jax.lax.scan(outer, a, ws)
        return y
    c = _compile(h, jax.ShapeDtypeStruct((M, K), jnp.float32),
                 jax.ShapeDtypeStruct((4, K, K), jnp.float32))
    r = analyze_hlo(c.as_text())
    assert r["flops"] == 4 * 5 * 2 * M * K * K


def test_traffic_scales_with_trip_count():
    K = 64
    def g(a, ws):
        def body(x, w):
            return jnp.tanh(x @ w), ()
        y, _ = jax.lax.scan(body, a, ws)
        return y
    specs = lambda n: (jax.ShapeDtypeStruct((K, K), jnp.float32),
                       jax.ShapeDtypeStruct((n, K, K), jnp.float32))
    t2 = analyze_hlo(_compile(g, *specs(2)).as_text())["traffic_bytes"]
    t8 = analyze_hlo(_compile(g, *specs(8)).as_text())["traffic_bytes"]
    assert 2.5 < t8 / t2 < 4.5  # ~4x body traffic, constant overhead


def test_remat_recompute_is_counted():
    K = 64
    def f(a, w):
        return jnp.sum(jax.checkpoint(lambda x: jnp.tanh(x @ w) @ w)(a))
    g = jax.grad(f)
    c = _compile(g, jax.ShapeDtypeStruct((K, K), jnp.float32),
                 jax.ShapeDtypeStruct((K, K), jnp.float32))
    r = analyze_hlo(c.as_text())
    # XLA CSEs the checkpoint recompute at this scale; the invariant that
    # matters is that backward dots are counted and the analyzer is at least
    # as complete as XLA's own accounting.
    assert r["flops"] >= 3 * 2 * K ** 3
    # within ~2% of XLA's own count on a loop-free graph (XLA additionally
    # counts a few elementwise transcendental fusions as flops)
    assert r["flops"] >= float(xla_cost_analysis(c)["flops"]) * 0.95


# ---------------------------------------------------------------------------
# hierarchical-mesh memory model (launch/hlo_stats.py)
# ---------------------------------------------------------------------------

def test_hier_group_memory_pinned():
    """Per-group HBM: trunk replicated into every group, a head's params
    resident only in its group — exact bytes pinned on a known placement."""
    from repro.core import HeadPlacement
    from repro.launch.hlo_stats import hier_group_memory

    p = HeadPlacement(groups=((0,), (1, 2)), device_counts=(3, 1))
    mem = hier_group_memory(p, shared_bytes=100, head_bytes=[10, 20, 30])
    assert [g["param_bytes"] for g in mem] == [110, 150]
    assert [g["hbm_bytes"] for g in mem] == [330, 450]   # 3x: params + m + v
    assert mem[0]["heads"] == [0] and mem[0]["devices"] == 3
    assert mem[1]["heads"] == [1, 2] and mem[1]["devices"] == 1
    # uniform-head shorthand
    mem2 = hier_group_memory(p, shared_bytes=100, head_bytes=10,
                             opt_factor=1.0)
    assert [g["param_bytes"] for g in mem2] == [110, 120]
    assert [g["hbm_bytes"] for g in mem2] == [110, 120]


def test_param_bytes_per_device_mesh_rank_agnostic():
    """The per-device residency estimate must honor whatever mesh axes a
    leaf's PartitionSpec names — 2-axis flat, 1-axis group, and replicated
    leaves — instead of hard-coding the (data, model) pair."""
    from types import SimpleNamespace

    from jax.sharding import PartitionSpec as P

    from repro.launch.hlo_stats import param_bytes_per_device

    def leaf(shape, spec, mesh_shape):
        sh = SimpleNamespace(spec=spec,
                             mesh=SimpleNamespace(shape=mesh_shape))
        return SimpleNamespace(shape=shape, dtype=np.dtype(np.float32),
                               sharding=sh)

    flat = {"shape": {"data": 4, "model": 2}}
    # f32[8,16] sharded over model(2) on dim0 -> 8*16*4/2 = 256
    assert param_bytes_per_device(
        [leaf((8, 16), P("model", None), flat["shape"])]) == 256
    # sharded over BOTH axes -> /8
    assert param_bytes_per_device(
        [leaf((8, 16), P("data", "model"), flat["shape"])]) == 64
    # 1-axis hierarchical group mesh: only "data" exists
    assert param_bytes_per_device(
        [leaf((8, 16), P("data"), {"data": 4})]) == 128
    # replicated spec -> full bytes; no sharding attr at all -> full bytes
    assert param_bytes_per_device(
        [leaf((8, 16), P(None, None), flat["shape"])]) == 512
    assert param_bytes_per_device(
        [SimpleNamespace(shape=(8, 16), dtype=np.dtype(np.float32))]) == 512
    # ragged tile rounds UP (XLA pads): f32[5] over 2 devices -> ceil(20/2)
    assert param_bytes_per_device([leaf((5,), P("data"), {"data": 2})]) == 10
    assert param_bytes_per_device([leaf((5,), P("data"), {"data": 3})]) == 7


def test_param_bytes_per_device_on_real_jax_arrays():
    """The same estimator on genuine single-device jax arrays (replicated
    semantics): exact byte totals."""
    from repro.launch.hlo_stats import param_bytes_per_device

    tree = {"w": jnp.zeros((4, 8), jnp.float32),
            "b": jnp.zeros((8,), jnp.bfloat16)}
    assert param_bytes_per_device(tree) == 4 * 8 * 4 + 8 * 2

"""End-to-end behaviour tests for the paper's system.

The paper's central empirical claims (Tables 1-2, at reduced scale):
  1. GFM-MTL (per-source heads) trains stably on conflicting multi-fidelity
     labels and reaches low error on EVERY source;
  2. GFM-Baseline (one shared head on mixed data) plateaus higher — it cannot
     fit per-source label offsets;
  3. training runs end-to-end through the MTP train step + group batcher.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ArchConfig
from repro.core import MTPConfig, make_gfm_mtl
from repro.data.loader import GroupBatcher
from repro.data.synthetic_atoms import generate_all
from repro.engine import ShardingPlan, TrainState, make_step
from repro.optim import adamw

SOURCES3 = ["ani1x", "qm7x", "mptrj"]


def _cfg():
    return ArchConfig(name="gfm-e2e", family="gnn", gnn_hidden=48,
                      gnn_layers=2, n_species=64, head_hidden=32,
                      head_layers=2, remat=False, compute_dtype=jnp.float32)


def _sources(n=96, seed=0):
    data = generate_all(n, max_atoms=12, max_edges=64, seed=seed,
                        sources=SOURCES3)
    out = []
    for sd in data.values():
        # paper SS4: align energies before pre-training (here: per-source
        # standardisation — removes the large fidelity offsets that would
        # otherwise dominate the early loss and make short CPU runs flaky
        # under XLA reduction-order nondeterminism)
        e = (sd.energy - sd.energy.mean()) / max(sd.energy.std(), 1e-6)
        f = sd.forces / max(np.abs(sd.forces).std(), 1e-6)
        out.append(dict(species=sd.species, pos=sd.pos, edge_src=sd.edge_src,
                        edge_dst=sd.edge_dst, node_mask=sd.node_mask,
                        edge_mask=sd.edge_mask, energy=e.astype(np.float32),
                        forces=f.astype(np.float32)))
    return out


def _train(model, n_tasks, sources, steps=300, batch=16, seed=0):
    opt = adamw(3e-3, grad_clip=1.0)
    plan = ShardingPlan(mtp=MTPConfig(n_tasks=n_tasks))
    step = plan.compile(make_step(model, opt, plan))
    state = TrainState.create(model.init(jax.random.PRNGKey(seed)), opt)
    gb = GroupBatcher(sources, batch, seed=seed)
    losses = []
    for _ in range(steps):
        state, out = step(state, gb.next_batch())
        losses.append(float(out.loss))
    return state.params, losses


def _probe_batch(sources):
    return {k: jnp.stack([jnp.asarray(s[k][:32]) for s in sources])
            for k in sources[0]}


@pytest.fixture(scope="module")
def mtl_run():
    cfg = _cfg()
    model = make_gfm_mtl(cfg, 3)
    sources = _sources()
    probe = _probe_batch(sources)
    p0 = model.init(jax.random.PRNGKey(0))
    loss0 = float(jnp.mean(model.loss_fn(p0["shared"], p0["heads"], probe)[0]))
    params, losses = _train(model, 3, sources)
    return cfg, model, sources, params, losses, loss0


def test_training_is_stable(mtl_run):
    cfg, model, sources, params, losses, loss0 = mtl_run
    assert all(np.isfinite(losses)), "training diverged"
    # fixed probe batch (per-batch losses are noisy across heterogeneous
    # structures; the paper's convergence claim is about the trend)
    probe = _probe_batch(sources)
    loss1 = float(jnp.mean(model.loss_fn(params["shared"], params["heads"],
                                         probe)[0]))
    assert loss1 < 0.5 * loss0, f"probe loss {loss0:.3f} -> {loss1:.3f}"


def test_mtl_fits_all_sources(mtl_run):
    cfg, model, sources, params, _, _ = mtl_run
    per_task, _ = model.loss_fn(
        params["shared"], params["heads"],
        {k: jnp.stack([jnp.asarray(s[k][:32]) for s in sources])
         for k in sources[0]})
    assert bool((per_task < np.inf).all())
    # every head reaches a comparable (low) loss despite conflicting labels
    pt = np.asarray(per_task)
    assert pt.max() < 10 * max(pt.min(), 1e-3)


def test_mtl_beats_single_head_baseline(mtl_run):
    """Paper Tables 1-2 phenomenology: per-source heads beat one shared head
    on the same mixed multi-fidelity data."""
    cfg, _, sources, mtl_params, mtl_losses, _ = mtl_run
    # baseline: one head processes all sources mixed together (n_tasks=1)
    mixed = {k: np.concatenate([s[k] for s in sources]) for k in sources[0]}
    base_model = make_gfm_mtl(cfg, 1)
    _, base_losses = _train(base_model, 1, [mixed])
    # compare energy fit quality at convergence
    assert np.mean(mtl_losses[-10:]) < np.mean(base_losses[-10:]), (
        f"MTL {np.mean(mtl_losses[-10:]):.4f} !< "
        f"baseline {np.mean(base_losses[-10:]):.4f}")


def test_lm_multitask_end_to_end():
    """The paper's technique on an LLM trunk: shared transformer + per-source
    LM heads, one train step, finite loss, head grads flow."""
    from repro.core import make_lm_multitask
    from repro.data.lm_data import make_lm_sources
    cfg = ArchConfig(name="lm-mt", n_layers=2, d_model=32, n_heads=2,
                     n_kv_heads=2, d_ff=64, vocab=128, n_tasks=3,
                     remat=False, compute_dtype=jnp.float32)
    model = make_lm_multitask(cfg)
    sources = make_lm_sources(3, n_seqs=8, seq_len=16, vocab=128)
    gb = GroupBatcher(sources, 4)
    params = model.init(jax.random.PRNGKey(0))
    opt = adamw(1e-3)
    plan = ShardingPlan(mtp=MTPConfig(n_tasks=3))
    step = plan.compile(make_step(model, opt, plan))
    p0 = jax.tree_util.tree_map(lambda x: x.copy(), params)
    state = TrainState.create(params, opt)
    for _ in range(3):
        state, out = step(state, gb.next_batch())
        assert np.isfinite(float(out.loss))
    dh = jax.tree_util.tree_map(lambda a, b: float(jnp.abs(a - b).max()),
                                p0["heads"], state.params["heads"])
    assert max(jax.tree_util.tree_leaves(dh)) > 0, "head params unchanged"


def test_uncertainty_weighted_mtl_trains():
    """Kendall uncertainty weighting: log-sigma2 leaves live with the heads
    (task-shardable) and adapt during training."""
    cfg = _cfg()
    model = make_gfm_mtl(cfg, 3, uncertainty=True)
    sources = _sources(n=48)
    params, losses = _train(model, 3, sources, steps=40)
    assert "log_sigma2" in params["heads"]
    assert params["heads"]["log_sigma2"].shape == (3, 2)
    s = np.asarray(params["heads"]["log_sigma2"])
    assert np.isfinite(losses[-1]) and (np.abs(s) > 1e-4).any(), \
        "uncertainty weights did not adapt"

"""The lint-rule suite: every rule catches its seeded fixture and passes
the clean twin; the baseline round-trips; the repo itself lints clean.

Stdlib-only (the linter never imports jax), so this file runs in tier-1.
Fixtures live in ``tests/fixtures/lint/`` — one ``<rule>_bad.py`` +
``<rule>_clean.py`` pair per rule; the ``fixtures`` path segment is
excluded from normal lint collection because the bad halves violate on
purpose.
"""
import pathlib
import subprocess
import sys

import pytest

from repro.analysis import (Baseline, Finding, apply_baseline, rule_ids)
from repro.analysis.baseline import BaselinePolicyError
from repro.analysis.findings import assign_occurrences
from repro.analysis.lint import collect_files, lint_paths, main
from repro.analysis.rules import run_rules

REPO = pathlib.Path(__file__).resolve().parent.parent
FIXDIR = REPO / "tests" / "fixtures" / "lint"
ALL_RULES = rule_ids()


def _lint_file(path: pathlib.Path):
    return run_rules(path.as_posix(), path.read_text())


# ---------------------------------------------------------------------------
# per-rule golden fixtures
# ---------------------------------------------------------------------------

def test_every_rule_has_a_fixture_pair():
    assert len(ALL_RULES) >= 8          # the ISSUE's floor
    for rule in ALL_RULES:
        stem = rule.lower()
        assert (FIXDIR / f"{stem}_bad.py").exists(), rule
        assert (FIXDIR / f"{stem}_clean.py").exists(), rule


@pytest.mark.parametrize("rule", ALL_RULES)
def test_rule_fires_on_seeded_violation(rule):
    findings = _lint_file(FIXDIR / f"{rule.lower()}_bad.py")
    fired = {f.rule for f in findings}
    assert rule in fired, f"{rule} missed its seeded fixture"
    # precision: a bad fixture trips ONLY its own rule
    assert fired == {rule}, f"{rule} fixture also tripped {fired - {rule}}"


@pytest.mark.parametrize("rule", ALL_RULES)
def test_rule_passes_clean_twin(rule):
    findings = _lint_file(FIXDIR / f"{rule.lower()}_clean.py")
    assert findings == [], [f.format() for f in findings]


def test_findings_carry_location_and_hint():
    for f in _lint_file(FIXDIR / "trc001_bad.py"):
        assert f.path.endswith("trc001_bad.py")
        assert f.line > 0 and f.message and f.hint
        assert f"{f.path}:{f.line}" in f.format()


# ---------------------------------------------------------------------------
# alias resolution + inline pragmas
# ---------------------------------------------------------------------------

def test_import_alias_does_not_dodge_rules():
    src = ("import numpy as xyz\n"
           "def f(n):\n"
           "    return xyz.random.permutation(n)\n")
    assert {f.rule for f in run_rules("x.py", src)} == {"DET001"}


def test_inline_allow_suppresses_named_rule():
    src = ("import jax\n"
           "def sweep(f, xs):\n"
           "    out = []\n"
           "    for x in xs:\n"
           "        # lint: allow(RCP001): one jit per swept config\n"
           "        out.append(jax.jit(f)(x))\n"
           "    return out\n")
    assert run_rules("x.py", src) == []


def test_inline_allow_cannot_suppress_det_or_pal():
    src = ("import time\n"
           "def f():\n"
           "    return time.time()  # lint: allow(DET003)\n")
    assert {f.rule for f in run_rules("x.py", src)} == {"DET003"}


# ---------------------------------------------------------------------------
# baseline round-trip
# ---------------------------------------------------------------------------

def test_baseline_round_trip(tmp_path):
    bad = FIXDIR / "rcp001_bad.py"
    findings = assign_occurrences(_lint_file(bad))
    bl = Baseline.from_findings(findings)
    p = tmp_path / "lint_baseline.json"
    bl.save(p)

    # baselined findings are suppressed...
    new, suppressed, stale = apply_baseline(findings, Baseline.load(p))
    assert new == [] and len(suppressed) == len(findings) and stale == []

    # ...but a NEW violation still gates
    extra = Finding(rule="RCP001", path=findings[0].path, line=99, col=0,
                    message="m", hint="h", snippet="jax.jit(g)(x)")
    new, suppressed, _ = apply_baseline(
        assign_occurrences(findings + [extra]), Baseline.load(p))
    assert [f.snippet for f in new] == ["jax.jit(g)(x)"]

    # fixing the finding leaves a stale entry (baseline shrinks, never grows)
    _, _, stale = apply_baseline([], Baseline.load(p))
    assert len(stale) == len(findings)


def test_baseline_fingerprint_survives_line_drift():
    src = ("import time\n"
           "def f():\n"
           "    return time.time()\n")
    drifted = "# a new header comment\n" + src
    f0 = assign_occurrences(run_rules("x.py", src))[0]
    f1 = assign_occurrences(run_rules("x.py", drifted))[0]
    assert f0.line != f1.line and f0.fingerprint == f1.fingerprint


def test_baseline_refuses_det_and_pal():
    det = _lint_file(FIXDIR / "det003_bad.py")
    with pytest.raises(BaselinePolicyError):
        Baseline.from_findings(det)
    pal = _lint_file(FIXDIR / "pal002_bad.py")
    with pytest.raises(BaselinePolicyError):
        Baseline.from_findings(pal)
    # explicit override still possible (for forks with different policy)
    assert len(Baseline.from_findings(det, allow_all=True).entries) == 2


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------

def test_cli_exit_codes(tmp_path):
    bad = str(FIXDIR / "rcp001_bad.py")
    clean = str(FIXDIR / "rcp001_clean.py")
    assert main([clean, "--no-baseline"]) == 0
    assert main([bad, "--no-baseline"]) == 1
    assert main(["--list-rules", "."]) == 0
    assert main([str(tmp_path / "missing_dir")]) == 2


def test_cli_write_baseline_then_pass(tmp_path, capsys):
    bad = str(FIXDIR / "rcp001_bad.py")
    bl = str(tmp_path / "bl.json")
    assert main([bad, "--write-baseline", "--baseline", bl]) == 0
    assert main([bad, "--baseline", bl]) == 0
    out = capsys.readouterr().out
    assert "baselined" in out


def test_cli_runs_as_module():
    proc = subprocess.run(
        [sys.executable, "-m", "repro.analysis.lint", "--list-rules", "."],
        capture_output=True, text=True, cwd=REPO,
        env={"PYTHONPATH": str(REPO / "src"), "PATH": "/usr/bin:/bin"})
    assert proc.returncode == 0
    for rule in ALL_RULES:
        assert rule in proc.stdout


# ---------------------------------------------------------------------------
# the repo itself
# ---------------------------------------------------------------------------

def test_fixture_dir_excluded_from_collection():
    files = collect_files([str(REPO / "tests")])
    assert not any("fixtures" in f.parts for f in files)


def test_repo_lints_clean_without_baseline():
    """src/benchmarks/examples carry ZERO findings — in particular no
    DET/PAL debt (the acceptance bar: fixed, not suppressed)."""
    findings, errors = lint_paths(
        [str(REPO / "src"), str(REPO / "benchmarks"), str(REPO / "examples")],
        root=REPO)
    assert errors == []
    assert findings == [], "\n".join(f.format() for f in findings)


def test_committed_baseline_is_empty():
    bl = Baseline.load(REPO / "lint_baseline.json")
    assert bl.entries == []

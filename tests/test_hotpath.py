"""ISSUE-2 hot-path parity suite (deterministic — no hypothesis in this
container, so this is the always-on coverage for the aggregation kernels):

  * one-hot ("jnp") vs scatter-add vs batched Pallas segment-sum agree to
    fp32 tolerance on batched shapes with pad edges AND pad nodes;
  * the batched Pallas entry point matches per-graph ``segment_sum_2d``;
  * the fused EGNN edge kernel matches its pure-jnp ``ref.py`` and, through
    ``egnn_apply``, the unfused model path — forward and gradients.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ArchConfig
from repro.data.synthetic_atoms import generate_all, to_batch_dict
from repro.kernels.egnn_edge import ops as edge_ops
from repro.kernels.egnn_edge.ref import egnn_edge_agg_ref
from repro.kernels.segment_sum import ops as ss_ops
from repro.kernels.segment_sum.kernel import segment_sum_2d, segment_sum_batched
from repro.models import gnn


def _case(B, E, A, F, seed=0, mask_p=0.7):
    """Random batched segment-sum inputs with pad edges (dst == A sentinel)
    and masked edges."""
    k0, k1, k2 = jax.random.split(jax.random.PRNGKey(seed), 3)
    msg = jax.random.normal(k0, (B, E, F), jnp.float32)
    dst = jax.random.randint(k1, (B, E), 0, A + 1)     # A = pad sentinel
    em = jax.random.bernoulli(k2, mask_p, (B, E)) & (dst < A)
    return msg, dst, em


@pytest.mark.parametrize("B,E,A,F,bn,be", [
    (2, 64, 16, 8, 8, 16),
    (3, 300, 33, 48, 16, 64),     # ragged E and A vs blocks
    (1, 128, 128, 128, 128, 128),
    (2, 7, 3, 5, 8, 8),           # blocks larger than the problem
])
def test_segment_sum_impl_parity(B, E, A, F, bn, be):
    msg, dst, em = _case(B, E, A, F)
    ref = gnn.segment_sum_nodes(msg, dst, A, edge_mask=em, impl="jnp")
    sc = gnn.segment_sum_nodes(msg, dst, A, edge_mask=em, impl="scatter")
    pl = ss_ops.segment_sum(msg, dst, A, edge_mask=em, block_n=bn, block_e=be)
    np.testing.assert_allclose(np.asarray(sc), np.asarray(ref),
                               atol=1e-5, rtol=1e-5)
    np.testing.assert_allclose(np.asarray(pl), np.asarray(ref),
                               atol=1e-5, rtol=1e-5)


def test_segment_sum_batched_matches_2d():
    msg, dst, em = _case(3, 100, 17, 12, seed=1)
    d = jnp.where(em, dst, 17)
    got = segment_sum_batched(msg, d, 17, block_n=8, block_e=32)
    per_graph = jnp.stack([
        segment_sum_2d(msg[i], d[i], 17, block_n=8, block_e=32)
        for i in range(3)])
    np.testing.assert_allclose(np.asarray(got), np.asarray(per_graph),
                               atol=1e-6, rtol=1e-6)


def test_segment_sum_rejects_bad_rank_and_blocks():
    msg, dst, em = _case(2, 16, 4, 4)
    with pytest.raises(ValueError, match="ndim"):
        ss_ops.segment_sum(msg[:, :, :, None], dst, 4, edge_mask=em)
    with pytest.raises(ValueError, match="block"):
        segment_sum_batched(msg, dst, 4, block_n=0)
    with pytest.raises(ValueError, match="impl"):
        gnn.segment_sum_nodes(msg, dst, 4, edge_mask=em, impl="nope")


def test_scatter_drops_all_pad_contributions():
    """Every masked/pad edge contributes exactly nothing (mass check)."""
    msg, dst, em = _case(2, 50, 9, 6, seed=2, mask_p=0.5)
    out = gnn.segment_sum_nodes(msg, dst, 9, edge_mask=em, impl="scatter")
    expect = jnp.where(em[..., None], msg, 0.0).sum(1)
    np.testing.assert_allclose(np.asarray(out.sum(1)), np.asarray(expect),
                               atol=1e-5, rtol=1e-5)


# ---------------------------------------------------------------------------
# fused edge kernel
# ---------------------------------------------------------------------------

def _gfm_cfg(**kw):
    base = dict(name="g", family="gnn", gnn_hidden=24, gnn_layers=2,
                n_species=64, head_hidden=12, head_layers=2, max_atoms=10,
                max_edges=40, remat=False, compute_dtype=jnp.float32)
    base.update(kw)
    return ArchConfig(**base)


def _gfm_batch(cfg, n=4, seed=0):
    data = generate_all(n, max_atoms=cfg.max_atoms, max_edges=cfg.max_edges,
                        seed=seed, sources=["ani1x"])
    return to_batch_dict(data["ani1x"], np.arange(n))


@pytest.mark.parametrize("block_e", [16, 40, 64])   # ragged/oversized blocks
def test_fused_edge_kernel_matches_ref(block_e):
    cfg = _gfm_cfg()
    batch = _gfm_batch(cfg)
    params = gnn.egnn_init(jax.random.PRNGKey(0), cfg)
    phi_e = params["layer0"]["phi_e"]
    h = gnn.embed(params["embed"], batch["species"], jnp.float32) \
        * batch["node_mask"][..., None]
    pos = batch["pos"]
    ref = egnn_edge_agg_ref(h, pos, batch["edge_src"], batch["edge_dst"],
                            batch["edge_mask"], phi_e)
    got = edge_ops.egnn_edge_agg(h, pos, batch["edge_src"],
                                 batch["edge_dst"], batch["edge_mask"],
                                 phi_e, block_e=block_e)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               atol=1e-5, rtol=1e-5)


def test_egnn_apply_all_impls_agree():
    cfg = _gfm_cfg()
    batch = _gfm_batch(cfg)
    params = gnn.egnn_init(jax.random.PRNGKey(1), cfg)
    ref = gnn.egnn_apply(params, batch, cfg=cfg, impl="jnp")
    for impl in ("scatter", "pallas", "fused"):
        got = gnn.egnn_apply(params, batch, cfg=cfg, impl=impl)
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                   atol=2e-5, rtol=2e-5, err_msg=impl)


@pytest.mark.parametrize("impl", ["scatter", "fused"])
def test_egnn_apply_grads_match_reference(impl):
    """The new default and the fused custom_vjp both differentiate like the
    one-hot reference — the train step is safe on every impl."""
    cfg = _gfm_cfg(gnn_layers=1)
    batch = _gfm_batch(cfg, seed=3)
    params = gnn.egnn_init(jax.random.PRNGKey(2), cfg)

    def loss(p, which):
        return jnp.mean(gnn.egnn_apply(p, batch, cfg=cfg, impl=which) ** 2)

    g_ref = jax.grad(lambda p: loss(p, "jnp"))(params)
    g_new = jax.grad(lambda p: loss(p, impl))(params)
    jax.tree_util.tree_map(
        lambda a, b: np.testing.assert_allclose(
            np.asarray(a), np.asarray(b), atol=1e-5, rtol=1e-4), g_new, g_ref)

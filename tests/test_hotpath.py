"""ISSUE-2 hot-path parity suite (deterministic — no hypothesis in this
container, so this is the always-on coverage for the aggregation kernels):

  * one-hot ("jnp") vs scatter-add vs batched Pallas segment-sum agree to
    fp32 tolerance on batched shapes with pad edges AND pad nodes;
  * the batched Pallas entry point matches per-graph ``segment_sum_2d``;
  * the fused EGNN edge kernel matches its pure-jnp ``ref.py`` and, through
    ``egnn_apply``, the unfused model path — forward and gradients.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ArchConfig
from repro.data.synthetic_atoms import generate_all, to_batch_dict
from repro.kernels.egnn_edge import ops as edge_ops
from repro.kernels.egnn_edge.ref import egnn_edge_agg_ref
from repro.kernels.segment_sum import ops as ss_ops
from repro.kernels.segment_sum.kernel import segment_sum_2d, segment_sum_batched
from repro.models import gnn


def _case(B, E, A, F, seed=0, mask_p=0.7):
    """Random batched segment-sum inputs with pad edges (dst == A sentinel)
    and masked edges."""
    k0, k1, k2 = jax.random.split(jax.random.PRNGKey(seed), 3)
    msg = jax.random.normal(k0, (B, E, F), jnp.float32)
    dst = jax.random.randint(k1, (B, E), 0, A + 1)     # A = pad sentinel
    em = jax.random.bernoulli(k2, mask_p, (B, E)) & (dst < A)
    return msg, dst, em


@pytest.mark.parametrize("B,E,A,F,bn,be", [
    (2, 64, 16, 8, 8, 16),
    (3, 300, 33, 48, 16, 64),     # ragged E and A vs blocks
    (1, 128, 128, 128, 128, 128),
    (2, 7, 3, 5, 8, 8),           # blocks larger than the problem
])
def test_segment_sum_impl_parity(B, E, A, F, bn, be):
    msg, dst, em = _case(B, E, A, F)
    ref = gnn.segment_sum_nodes(msg, dst, A, edge_mask=em, impl="jnp")
    sc = gnn.segment_sum_nodes(msg, dst, A, edge_mask=em, impl="scatter")
    pl = ss_ops.segment_sum(msg, dst, A, edge_mask=em, block_n=bn, block_e=be)
    np.testing.assert_allclose(np.asarray(sc), np.asarray(ref),
                               atol=1e-5, rtol=1e-5)
    np.testing.assert_allclose(np.asarray(pl), np.asarray(ref),
                               atol=1e-5, rtol=1e-5)


def test_segment_sum_batched_matches_2d():
    msg, dst, em = _case(3, 100, 17, 12, seed=1)
    d = jnp.where(em, dst, 17)
    got = segment_sum_batched(msg, d, 17, block_n=8, block_e=32)
    per_graph = jnp.stack([
        segment_sum_2d(msg[i], d[i], 17, block_n=8, block_e=32)
        for i in range(3)])
    np.testing.assert_allclose(np.asarray(got), np.asarray(per_graph),
                               atol=1e-6, rtol=1e-6)


def test_segment_sum_rejects_bad_rank_and_blocks():
    msg, dst, em = _case(2, 16, 4, 4)
    with pytest.raises(ValueError, match="ndim"):
        ss_ops.segment_sum(msg[:, :, :, None], dst, 4, edge_mask=em)
    with pytest.raises(ValueError, match="block"):
        segment_sum_batched(msg, dst, 4, block_n=0)
    with pytest.raises(ValueError, match="impl"):
        gnn.segment_sum_nodes(msg, dst, 4, edge_mask=em, impl="nope")


def test_scatter_drops_all_pad_contributions():
    """Every masked/pad edge contributes exactly nothing (mass check)."""
    msg, dst, em = _case(2, 50, 9, 6, seed=2, mask_p=0.5)
    out = gnn.segment_sum_nodes(msg, dst, 9, edge_mask=em, impl="scatter")
    expect = jnp.where(em[..., None], msg, 0.0).sum(1)
    np.testing.assert_allclose(np.asarray(out.sum(1)), np.asarray(expect),
                               atol=1e-5, rtol=1e-5)


# ---------------------------------------------------------------------------
# fused edge kernel
# ---------------------------------------------------------------------------

def _gfm_cfg(**kw):
    base = dict(name="g", family="gnn", gnn_hidden=24, gnn_layers=2,
                n_species=64, head_hidden=12, head_layers=2, max_atoms=10,
                max_edges=40, remat=False, compute_dtype=jnp.float32)
    base.update(kw)
    return ArchConfig(**base)


def _gfm_batch(cfg, n=4, seed=0):
    data = generate_all(n, max_atoms=cfg.max_atoms, max_edges=cfg.max_edges,
                        seed=seed, sources=["ani1x"])
    return to_batch_dict(data["ani1x"], np.arange(n))


@pytest.mark.parametrize("block_e", [16, 40, 64])   # ragged/oversized blocks
def test_fused_edge_kernel_matches_ref(block_e):
    cfg = _gfm_cfg()
    batch = _gfm_batch(cfg)
    params = gnn.egnn_init(jax.random.PRNGKey(0), cfg)
    phi_e = params["layer0"]["phi_e"]
    h = gnn.embed(params["embed"], batch["species"], jnp.float32) \
        * batch["node_mask"][..., None]
    pos = batch["pos"]
    ref = egnn_edge_agg_ref(h, pos, batch["edge_src"], batch["edge_dst"],
                            batch["edge_mask"], phi_e)
    got = edge_ops.egnn_edge_agg(h, pos, batch["edge_src"],
                                 batch["edge_dst"], batch["edge_mask"],
                                 phi_e, block_e=block_e)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               atol=1e-5, rtol=1e-5)


def test_egnn_apply_all_impls_agree():
    cfg = _gfm_cfg()
    batch = _gfm_batch(cfg)
    params = gnn.egnn_init(jax.random.PRNGKey(1), cfg)
    ref = gnn.egnn_apply(params, batch, cfg=cfg, impl="jnp")
    for impl in ("scatter", "pallas", "fused"):
        got = gnn.egnn_apply(params, batch, cfg=cfg, impl=impl)
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                   atol=2e-5, rtol=2e-5, err_msg=impl)


def _paper_case(B=4, E=768, A=128, H=256, dtype=jnp.float32, seed=0):
    """Paper-shaped kernel inputs (ISSUE-3 acceptance: B=4, E=768, A=128,
    F=256) with masked AND sentinel-padded (dst == A) edges."""
    from repro.models.mlp import mlp_init
    ks = jax.random.split(jax.random.PRNGKey(seed), 7)
    h = jax.random.normal(ks[0], (B, A, H), dtype)
    pos = jax.random.normal(ks[1], (B, A, 3), jnp.float32) * 2.0
    src = jax.random.randint(ks[2], (B, E), 0, A)
    dst = jax.random.randint(ks[3], (B, E), 0, A + 1)      # A = pad sentinel
    em = jax.random.bernoulli(ks[4], 0.85, (B, E)) & (dst < A)
    phi_e = mlp_init(ks[5], 2 * H + 1, H, H, 1, jnp.float32)
    gw = jax.random.normal(ks[6], (B, A, H), jnp.float32)  # cotangent probe
    return h, pos, src, dst, em, phi_e, gw


def _assert_close_scaled(got, ref, tol, name=""):
    got = np.asarray(got, np.float32)
    ref = np.asarray(ref, np.float32)
    scale = max(1.0, float(np.abs(ref).max()))
    np.testing.assert_allclose(got, ref, atol=tol * scale, rtol=tol,
                               err_msg=name)


@pytest.mark.parametrize("dtype,tol", [
    (jnp.float32, 1e-5),       # ISSUE-3 acceptance: fp32 atol ≲ 1e-5
    (jnp.bfloat16, 4e-2),      # relaxed: bf16 forward-recompute rounding
])
def test_fused_bwd_matches_ref_at_paper_shapes(dtype, tol):
    """The fused backward kernel (d_h, d_x, φ_e weight grads) agrees with
    jax.grad through the pure-jnp reference at paper shapes, including
    masked and sentinel-padded edges."""
    h, pos, src, dst, em, phi_e, gw = _paper_case(dtype=dtype)

    def loss(fn, hh, pp, ww):
        out = fn(hh, pp, src, dst, em, ww, compute_dtype=dtype)
        return jnp.sum(out.astype(jnp.float32) * gw)

    g_fused = jax.grad(lambda *a: loss(edge_ops.egnn_edge_agg, *a),
                       argnums=(0, 1, 2))(h, pos, phi_e)
    g_ref = jax.grad(lambda *a: loss(egnn_edge_agg_ref, *a),
                     argnums=(0, 1, 2))(h, pos, phi_e)
    names = ("d_h", "d_pos", "d_phi_e")
    for n, a, b in zip(names, g_fused, g_ref):
        jax.tree_util.tree_map(
            lambda x, y, n=n: _assert_close_scaled(x, y, tol, n), a, b)
        # dtypes of the cotangents must match the primals exactly
        jax.tree_util.tree_map(
            lambda x, y: (x.dtype == y.dtype) or pytest.fail(
                f"cotangent dtype {x.dtype} != primal-grad {y.dtype}"), a, b)


def test_fused_bwd_ragged_edge_block():
    """block_e that does not divide E: the wrapper's sentinel padding must
    contribute exactly nothing to any cotangent."""
    h, pos, src, dst, em, phi_e, gw = _paper_case(B=2, E=100, A=16, H=32)

    def loss(block_e):
        def f(hh):
            out = edge_ops.egnn_edge_agg(hh, pos, src, dst, em, phi_e,
                                         block_e=block_e)
            return jnp.sum(out * gw)
        return jax.grad(f)(h)

    np.testing.assert_allclose(np.asarray(loss(64)), np.asarray(loss(128)),
                               atol=1e-6, rtol=1e-6)


def test_kernel_block_config_knob_threads_through():
    """cfg.kernel_block_e / kernel_block_n override the autotune heuristic
    for both the pallas segment-sum and the fused edge path without
    changing numerics."""
    cfg = _gfm_cfg()
    batch = _gfm_batch(cfg)
    params = gnn.egnn_init(jax.random.PRNGKey(4), cfg)
    ref = gnn.egnn_apply(params, batch, cfg=cfg, impl="jnp")
    tuned = cfg.replace(kernel_block_e=16, kernel_block_n=8)
    for impl in ("pallas", "fused"):
        got = gnn.egnn_apply(params, batch, cfg=tuned, impl=impl)
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                   atol=2e-5, rtol=2e-5, err_msg=impl)
    # and gradients still flow through the fused override path
    def loss(p, c):
        return jnp.mean(gnn.egnn_apply(p, batch, cfg=c, impl="fused") ** 2)
    g_t = jax.grad(lambda p: loss(p, tuned))(params)
    g_d = jax.grad(lambda p: loss(p, cfg))(params)
    jax.tree_util.tree_map(
        lambda a, b: np.testing.assert_allclose(
            np.asarray(a), np.asarray(b), atol=1e-5, rtol=1e-4), g_t, g_d)


@pytest.mark.parametrize("impl", ["scatter", "fused"])
def test_egnn_apply_grads_match_reference(impl):
    """The new default and the fused custom_vjp both differentiate like the
    one-hot reference — the train step is safe on every impl."""
    cfg = _gfm_cfg(gnn_layers=1)
    batch = _gfm_batch(cfg, seed=3)
    params = gnn.egnn_init(jax.random.PRNGKey(2), cfg)

    def loss(p, which):
        return jnp.mean(gnn.egnn_apply(p, batch, cfg=cfg, impl=which) ** 2)

    g_ref = jax.grad(lambda p: loss(p, "jnp"))(params)
    g_new = jax.grad(lambda p: loss(p, impl))(params)
    jax.tree_util.tree_map(
        lambda a, b: np.testing.assert_allclose(
            np.asarray(a), np.asarray(b), atol=1e-5, rtol=1e-4), g_new, g_ref)

from . import checkpoint, loop, serve  # noqa: F401
from .loop import EarlyStopping, MetricLogger, make_lm_loss, train_loop  # noqa: F401
from .serve import greedy_generate, make_decode_step, make_prefill_step  # noqa: F401

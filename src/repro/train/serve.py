"""Serving: prefill / decode step factories and batched generation.

Decode shapes in the assignment (decode_32k, long_500k) are exactly one
``decode_step`` with a full-length cache; ``generate`` chains
prefill -> extend -> decode for the runnable serving example.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.models import transformer


def extend_caches(caches, cfg, capacity: int):
    """Pad prefill-produced attention caches (length S) to ``capacity``.
    SSM/xLSTM state caches are fixed-size and pass through unchanged."""
    def fix(tree):
        if isinstance(tree, dict):
            out = {}
            for k, v in tree.items():
                if k in ("k", "v", "ckv", "krope") and hasattr(v, "shape"):
                    # leading (reps,) stack possible: pad the seq axis
                    seq_ax = v.ndim - 3 if k in ("k", "v") else v.ndim - 2
                    cur = v.shape[seq_ax]
                    cap = capacity
                    if k in ("k", "v") and cfg.window and cur >= cfg.window:
                        cap = cur  # rolling window cache already at capacity
                    if cap > cur:
                        padw = [(0, 0)] * v.ndim
                        padw[seq_ax] = (0, cap - cur)
                        v = jnp.pad(v, padw)
                    out[k] = v
                elif isinstance(v, (dict, tuple)):
                    out[k] = fix(v)
                else:
                    out[k] = v
            return out
        if isinstance(tree, tuple):
            return tuple(fix(t) for t in tree)
        return tree

    return fix(caches)


def make_prefill_step(cfg, impl="chunked"):
    def prefill(params, tokens, media=None, memory=None):
        logits, caches, _ = transformer.lm_apply(
            params, tokens, cfg=cfg, media=media, memory=memory,
            mode="prefill", impl=impl)
        return logits, caches
    return prefill


def make_decode_step(cfg, impl="chunked", task=None):
    def decode(params, token, caches, pos, memory=None):
        """token: (B,1) int; pos: scalar absolute position."""
        logits, caches, _ = transformer.lm_apply(
            params, token, cfg=cfg, mode="decode", caches=caches,
            positions=jnp.reshape(pos, (1,)), memory=memory, impl=impl,
            task=task)
        return logits, caches
    return decode


def greedy_generate(params, cfg, prompt_tokens, n_new: int, *, impl="chunked",
                    capacity: int | None = None, memory=None):
    """prompt_tokens: (B, S). Returns (B, n_new) greedy continuation."""
    B, S = prompt_tokens.shape
    capacity = capacity or (S + n_new)
    prefill = jax.jit(make_prefill_step(cfg, impl))
    decode = jax.jit(make_decode_step(cfg, impl))
    logits, caches = prefill(params, prompt_tokens, memory=memory)
    caches = extend_caches(caches, cfg, capacity)
    tok = jnp.argmax(logits[:, -1:], -1).astype(jnp.int32)
    out = [tok]
    pos = S
    for _ in range(n_new - 1):
        logits, caches = decode(params, tok, caches, jnp.asarray(pos), memory=memory)
        tok = jnp.argmax(logits[:, -1:], -1).astype(jnp.int32)
        out.append(tok)
        pos += 1
    return jnp.concatenate(out, axis=1)

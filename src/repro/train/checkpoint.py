"""Sharding-aware checkpointing (pure numpy .npz + JSON metadata).

Save: gather every leaf to host (works for sharded arrays — jax.device_get
assembles the global view) and write one .npz with '/'-joined tree paths.
Restore: load arrays and ``jax.device_put`` each leaf to the sharding of a
template tree (so a checkpoint written on one mesh restores onto another —
e.g. single-pod -> multi-pod elasticity).
"""
from __future__ import annotations

import json
import os
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np


def _npz_path(path: str) -> str:
    return path if path.endswith(".npz") else path + ".npz"


def _meta_path(path: str) -> str:
    """Metadata sidecar next to the .npz. Only a trailing ``.npz`` is
    stripped — ``path.replace(".npz", "")`` would corrupt paths with the
    substring mid-string (e.g. ``run.npz.bak/ck``)."""
    base = path[:-len(".npz")] if path.endswith(".npz") else path
    return base + ".meta.json"


def _datapipe_path(path: str) -> str:
    """Input-pipeline state sidecar (batcher/mixer/prefetcher ``state()``)
    next to the .npz — same trailing-suffix-only strip as ``_meta_path``."""
    base = path[:-len(".npz")] if path.endswith(".npz") else path
    return base + ".datapipe.json"


def _flatten(tree, prefix=""):
    out = {}
    if isinstance(tree, dict):
        for k, v in tree.items():
            out.update(_flatten(v, f"{prefix}{k}/"))
    elif hasattr(tree, "_fields"):  # NamedTuple (before generic tuple!)
        for k in tree._fields:
            out.update(_flatten(getattr(tree, k), f"{prefix}{k}/"))
    elif isinstance(tree, (tuple, list)):
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}__{i}/"))
    elif tree is not None:
        # None leaves (e.g. TrainState.rng/guard when unused) are dropped:
        # npz cannot hold them without object-array pickling, and
        # _unflatten_like restores them from the template
        out[prefix[:-1]] = tree
    return out


def _write_json_atomic(path: str, obj, **dump_kw):
    """Same-directory temp file + os.replace: an interrupted writer leaves
    the previous sidecar (or none), never a truncated JSON — the same
    publish discipline as ``repro.data.store.write_store``."""
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(obj, f, **dump_kw)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, path)


def save(path: str, tree: Any, metadata: dict | None = None,
         datapipe: dict | None = None):
    """datapipe: a batcher/prefetcher ``state()`` dict (JSON-serializable)
    written to a ``.datapipe.json`` sidecar, so a resumed run can restore
    the exact batch-stream position alongside the params (see
    ``repro.engine.Session.restore_datapipe``). The sidecar is stamped
    with ``metadata["step"]`` when present: the npz and the sidecar are
    two files, so a crash between their writes CAN desynchronize them —
    the stamp lets ``restore_datapipe`` detect (not prevent) that."""
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    flat = _flatten(tree)
    arrs = {k: np.asarray(jax.device_get(v)) for k, v in flat.items()}
    # same-directory temp + os.replace, like the JSON sidecars: a writer
    # killed mid-write (preemption, OOM kill) leaves the previous .npz (or
    # none) on disk, never a truncated archive that would fail to restore.
    # np.savez is handed an OPEN file object — with a string path it would
    # append ".npz" to the temp name and os.replace would miss it.
    npz = _npz_path(path)
    tmp = npz + ".tmp"
    try:
        with open(tmp, "wb") as f:
            np.savez(f, **arrs)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, npz)
    except BaseException:
        # a hard kill can't reach this, but exception paths (full disk,
        # injected IO faults under retry) shouldn't litter the directory
        try:
            os.unlink(tmp)
        except OSError:
            pass
        raise
    if metadata is not None:
        _write_json_atomic(_meta_path(path), metadata, indent=2)
    if datapipe is not None:
        step = (metadata or {}).get("step")
        _write_json_atomic(_datapipe_path(path),
                           {"step": step, "state": datapipe})


def restore(path: str, template: Any) -> Any:
    """template: a pytree of arrays OR ShapeDtypeStructs (possibly with
    .sharding) with the target structure."""
    data = np.load(_npz_path(path))
    flat_t = _flatten(template)   # None template leaves restore as None

    def put(k, t):
        arr = jnp.asarray(data[k], dtype=t.dtype)
        assert arr.shape == tuple(t.shape), f"{k}: {arr.shape} vs {t.shape}"
        sh = getattr(t, "sharding", None)
        if sh is not None and not isinstance(sh, jax.sharding.SingleDeviceSharding):
            return jax.device_put(arr, sh)
        return arr

    new_flat = {k: put(k, t) for k, t in flat_t.items()}
    return _unflatten_like(template, new_flat, "")


def _unflatten_like(tree, flat, prefix):
    if isinstance(tree, dict):
        return {k: _unflatten_like(v, flat, f"{prefix}{k}/") for k, v in tree.items()}
    if isinstance(tree, tuple) and hasattr(tree, "_fields"):
        return type(tree)(**{k: _unflatten_like(getattr(tree, k), flat, f"{prefix}{k}/")
                             for k in tree._fields})
    if isinstance(tree, (tuple, list)):
        vals = [_unflatten_like(v, flat, f"{prefix}__{i}/") for i, v in enumerate(tree)]
        return type(tree)(vals) if isinstance(tree, list) else tuple(vals)
    if tree is None:   # dropped by _flatten on save — stays None
        return None
    return flat[prefix[:-1]]


def load_metadata(path: str) -> dict:
    with open(_meta_path(path)) as f:
        return json.load(f)


def load_datapipe(path: str) -> dict:
    """The pipeline state from the ``.datapipe.json`` sidecar written by
    ``save(..., datapipe=...)``. Feed it to the matching batcher/prefetcher
    ``restore()`` (or ``Session.restore_datapipe``) to resume the exact
    batch stream."""
    with open(_datapipe_path(path)) as f:
        payload = json.load(f)
    # stamped envelope {"step", "state"} vs a raw state dict (hand-written)
    if isinstance(payload, dict) and set(payload) == {"step", "state"}:
        return payload["state"]
    return payload


def load_datapipe_step(path: str):
    """The ``metadata["step"]`` stamp the sidecar was written with (None if
    unstamped). Compare against ``load_metadata(path)["step"]`` to detect a
    params/stream desync from a crash between the two writes."""
    with open(_datapipe_path(path)) as f:
        payload = json.load(f)
    if isinstance(payload, dict) and set(payload) == {"step", "state"}:
        return payload["step"]
    return None


def has_datapipe(path: str) -> bool:
    return os.path.exists(_datapipe_path(path))

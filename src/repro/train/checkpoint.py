"""Sharding-aware checkpointing (pure numpy .npz + JSON metadata).

Save: gather every leaf to host (works for sharded arrays — jax.device_get
assembles the global view) and write one .npz with '/'-joined tree paths.
Restore: load arrays and ``jax.device_put`` each leaf to the sharding of a
template tree (so a checkpoint written on one mesh restores onto another —
e.g. single-pod -> multi-pod elasticity).
"""
from __future__ import annotations

import json
import os
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np


def _npz_path(path: str) -> str:
    return path if path.endswith(".npz") else path + ".npz"


def _meta_path(path: str) -> str:
    """Metadata sidecar next to the .npz. Only a trailing ``.npz`` is
    stripped — ``path.replace(".npz", "")`` would corrupt paths with the
    substring mid-string (e.g. ``run.npz.bak/ck``)."""
    base = path[:-len(".npz")] if path.endswith(".npz") else path
    return base + ".meta.json"


def _flatten(tree, prefix=""):
    out = {}
    if isinstance(tree, dict):
        for k, v in tree.items():
            out.update(_flatten(v, f"{prefix}{k}/"))
    elif hasattr(tree, "_fields"):  # NamedTuple (before generic tuple!)
        for k in tree._fields:
            out.update(_flatten(getattr(tree, k), f"{prefix}{k}/"))
    elif isinstance(tree, (tuple, list)):
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}__{i}/"))
    else:
        out[prefix[:-1]] = tree
    return out


def save(path: str, tree: Any, metadata: dict | None = None):
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    flat = _flatten(tree)
    arrs = {k: np.asarray(jax.device_get(v)) for k, v in flat.items()}
    np.savez(_npz_path(path), **arrs)
    if metadata is not None:
        with open(_meta_path(path), "w") as f:
            json.dump(metadata, f, indent=2)


def restore(path: str, template: Any) -> Any:
    """template: a pytree of arrays OR ShapeDtypeStructs (possibly with
    .sharding) with the target structure."""
    data = np.load(_npz_path(path))
    flat_t = _flatten(template)

    def put(k, t):
        arr = jnp.asarray(data[k], dtype=t.dtype)
        assert arr.shape == tuple(t.shape), f"{k}: {arr.shape} vs {t.shape}"
        sh = getattr(t, "sharding", None)
        if sh is not None and not isinstance(sh, jax.sharding.SingleDeviceSharding):
            return jax.device_put(arr, sh)
        return arr

    new_flat = {k: put(k, t) for k, t in flat_t.items()}
    return _unflatten_like(template, new_flat, "")


def _unflatten_like(tree, flat, prefix):
    if isinstance(tree, dict):
        return {k: _unflatten_like(v, flat, f"{prefix}{k}/") for k, v in tree.items()}
    if isinstance(tree, tuple) and hasattr(tree, "_fields"):
        return type(tree)(**{k: _unflatten_like(getattr(tree, k), flat, f"{prefix}{k}/")
                             for k in tree._fields})
    if isinstance(tree, (tuple, list)):
        vals = [_unflatten_like(v, flat, f"{prefix}__{i}/") for i, v in enumerate(tree)]
        return type(tree)(vals) if isinstance(tree, list) else tuple(vals)
    return flat[prefix[:-1]]


def load_metadata(path: str) -> dict:
    with open(_meta_path(path)) as f:
        return json.load(f)

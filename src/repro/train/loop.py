"""Training-loop utilities for the ``repro.engine`` session API.

Step construction lives in ``repro.engine``: ``make_step(model, optimizer,
plan)`` builds the unified ``step(state, batch) -> (state, StepOutput)`` and
``ShardingPlan.compile(step)`` is the single public way to compile it
(single-device jit, pjit shardings, or the shard_map backend). This module
keeps the pieces the engine composes around a compiled step:

  * ``make_lm_loss`` — the single-task LM loss consumed by the engine's
    ``"lm"`` registry model;
  * ``EarlyStopping`` — paper §5.1 stopping criterion. It watches the
    VALIDATION metric when an eval_fn provides one (``val_metric`` row key)
    and falls back to the training loss otherwise;
  * ``MetricLogger`` — wall-clock-stamped metric rows;
  * ``train_loop`` — the generic loop over a unified TrainStep, used by
    ``engine.Session.run`` and usable standalone.
"""
from __future__ import annotations

import json
import time
from dataclasses import dataclass, field

from repro.core.mtl import softmax_xent
from repro.models import transformer


def make_lm_loss(cfg, impl="chunked"):
    def loss_fn(params, batch):
        memory = batch.get("memory")
        if cfg.n_enc_layers and memory is None:
            memory = transformer.encode(params, batch["src_embed"], cfg, impl)
        logits, _, aux = transformer.lm_apply(
            params, batch["tokens"], cfg=cfg, media=batch.get("media"),
            memory=memory, mode="train", impl=impl)
        # media tokens prepended: align logits to text labels
        if batch.get("media") is not None:
            logits = logits[:, batch["media"].shape[1]:]
        l = softmax_xent(logits, batch["labels"])
        if cfg.n_experts:
            l = l + cfg.router_aux_coef * aux
        return l
    return loss_fn


@dataclass
class EarlyStopping:
    """Paper §5.1: early stopping to avoid redundant computation."""
    patience: int = 10
    min_delta: float = 1e-4
    best: float = float("inf")
    bad: int = 0

    def update(self, val: float) -> bool:
        """Returns True if training should stop."""
        if val < self.best - self.min_delta:
            self.best, self.bad = val, 0
        else:
            self.bad += 1
        return self.bad >= self.patience


@dataclass
class MetricLogger:
    history: list = field(default_factory=list)
    t0: float = field(default_factory=time.perf_counter)

    def log(self, step: int, **metrics):
        row = {"step": step, "wall": time.perf_counter() - self.t0}
        row.update({k: float(v) for k, v in metrics.items()})
        self.history.append(row)
        return row


def train_loop(step_fn, state, batches, *, steps: int, eval_fn=None,
               eval_every: int = 50, log_every: int | None = None,
               early_stop: EarlyStopping | None = None,
               logger: MetricLogger | None = None,
               val_metric: str = "val_loss", metric_fn=None,
               should_stop=None, verbose: bool = False):
    """Run a unified TrainStep for ``steps`` iterations.

    step_fn: ``step(state, batch) -> (state, StepOutput)`` (compiled via
    ``ShardingPlan.compile`` or any callable with that signature).
    batches: zero-arg callable or iterator yielding batches.
    eval_fn: ``eval_fn(params) -> dict`` merged into eval rows; if the dict
    contains ``val_metric``, EarlyStopping watches THAT (paper §5.1 stops on
    validation), otherwise it falls back to the training loss.
    metric_fn: ``metric_fn(out: StepOutput) -> dict`` of extra scalars to
    log (e.g. named per-task losses).
    should_stop: zero-arg cooperative stop hook polled before every step —
    return True to end the loop cleanly with the state as-is (e.g. a
    ``repro.resilience.PreemptionHandler``'s ``triggered``).

    Returns (state, logger, last StepOutput).
    """
    logger = logger or MetricLogger()
    log_every = log_every or eval_every
    out = None
    for i in range(steps):
        if should_stop is not None and should_stop():
            break
        batch = batches() if callable(batches) else next(batches)
        state, out = step_fn(state, batch)
        is_eval = (i + 1) % eval_every == 0 or i == 0 or i == steps - 1
        is_log = (i + 1) % log_every == 0 or i == 0 or i == steps - 1
        if not (is_eval or is_log):
            continue
        extras = metric_fn(out) if metric_fn is not None else {}
        row = logger.log(i, loss=out.loss, **extras)
        if eval_fn is not None and is_eval:
            row.update({k: float(v) for k, v in eval_fn(state.params).items()})
        if verbose:
            print(json.dumps({k: round(v, 5) if isinstance(v, float) else v
                              for k, v in row.items()}))
        if early_stop is not None and is_eval:
            criterion = row.get(val_metric, row["loss"])
            if early_stop.update(float(criterion)):
                if verbose:
                    print(f"# early stopping (paper §5.1) at step {i}: "
                          f"best {val_metric if val_metric in row else 'loss'}"
                          f"={early_stop.best:.5f}")
                break
    return state, logger, out

"""Training loop utilities: step factories, metrics, early stopping.

``make_lm_train_step`` is the single-task (standard) LM step used by the
assigned-architecture configs; the multi-task step lives in
``repro.core.taskpar`` (the paper's technique). Both support gradient
accumulation (microbatching) — the memory knob for the big dry-run configs.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.core.mtl import softmax_xent
from repro.models import transformer


def make_lm_loss(cfg, impl="chunked"):
    def loss_fn(params, batch):
        memory = batch.get("memory")
        if cfg.n_enc_layers and memory is None:
            memory = transformer.encode(params, batch["src_embed"], cfg, impl)
        logits, _, aux = transformer.lm_apply(
            params, batch["tokens"], cfg=cfg, media=batch.get("media"),
            memory=memory, mode="train", impl=impl)
        # media tokens prepended: align logits to text labels
        if batch.get("media") is not None:
            logits = logits[:, batch["media"].shape[1]:]
        l = softmax_xent(logits, batch["labels"])
        if cfg.n_experts:
            l = l + cfg.router_aux_coef * aux
        return l
    return loss_fn


def make_lm_train_step(cfg, optimizer, impl="chunked", accum: int = 1):
    loss_fn = make_lm_loss(cfg, impl)

    def step(params, opt_state, batch):
        if accum == 1:
            l, grads = jax.value_and_grad(loss_fn)(params, batch)
        else:
            def micro(carry, mb):
                acc_l, acc_g = carry
                l, g = jax.value_and_grad(loss_fn)(params, mb)
                return (acc_l + l, jax.tree_util.tree_map(jnp.add, acc_g, g)), None
            micro_batches = jax.tree_util.tree_map(
                lambda x: x.reshape((accum, x.shape[0] // accum) + x.shape[1:]),
                batch)
            zeros = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (l, grads), _ = jax.lax.scan(micro, (jnp.zeros(()), zeros),
                                         micro_batches)
            l = l / accum
            grads = jax.tree_util.tree_map(lambda g: g / accum, grads)
        new_params, new_state = optimizer.update(grads, opt_state, params)
        return new_params, new_state, l
    return step


@dataclass
class EarlyStopping:
    """Paper §5.1: early stopping to avoid redundant computation."""
    patience: int = 10
    min_delta: float = 1e-4
    best: float = float("inf")
    bad: int = 0

    def update(self, val: float) -> bool:
        """Returns True if training should stop."""
        if val < self.best - self.min_delta:
            self.best, self.bad = val, 0
        else:
            self.bad += 1
        return self.bad >= self.patience


@dataclass
class MetricLogger:
    history: list = field(default_factory=list)
    t0: float = field(default_factory=time.time)

    def log(self, step: int, **metrics):
        row = {"step": step, "wall": time.time() - self.t0}
        row.update({k: float(v) for k, v in metrics.items()})
        self.history.append(row)
        return row


def train_loop(step_fn, params, opt_state, batches, *, epochs_or_steps: int,
               eval_fn=None, eval_every: int = 50, early_stop: EarlyStopping | None = None,
               logger: MetricLogger | None = None, verbose: bool = False):
    logger = logger or MetricLogger()
    for i in range(epochs_or_steps):
        batch = batches() if callable(batches) else next(batches)
        out = step_fn(params, opt_state, batch)
        params, opt_state, loss = out[0], out[1], out[2]
        if (i + 1) % eval_every == 0 or i == 0:
            row = logger.log(i, loss=loss)
            if eval_fn is not None:
                row.update(eval_fn(params))
            if verbose:
                print(row)
            if early_stop is not None and early_stop.update(float(loss)):
                break
    return params, opt_state, logger

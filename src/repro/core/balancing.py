"""Multi-source loss balancing + cross-fidelity energy alignment.

The paper "consistently aligned the energy per atom values across all the
datasets" (§4) before pre-training. Different DFT settings shift total
energies by per-element offsets; the standard alignment (cf. Shiota et al.'s
AEC) fits per-source reference atomic energies by least squares on element
composition and subtracts them:

    E_source(s) ≈ Σ_z n_z(s) · e_ref[source, z]  ->  E_aligned = E - Σ n_z e_ref

Loss balancing offers static task weights and learnable homoscedastic
uncertainty weights (Kendall et al.) for the energy/force pair.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def composition_matrix(species: np.ndarray, n_species: int) -> np.ndarray:
    """species: (n_samples, A) int (0 = pad) -> (n_samples, n_species) counts."""
    out = np.zeros((species.shape[0], n_species), np.float64)
    for z in range(1, n_species):
        out[:, z] = (species == z).sum(axis=1)
    return out


def fit_reference_energies(species: np.ndarray, total_energy: np.ndarray,
                           n_species: int, ridge: float = 1e-6) -> np.ndarray:
    """Least-squares per-element reference energies for ONE source.
    total_energy: (n_samples,) TOTAL (not per-atom) energies."""
    X = composition_matrix(species, n_species)
    A = X.T @ X + ridge * np.eye(n_species)
    b = X.T @ total_energy
    return np.linalg.solve(A, b)


def align_energies(species: np.ndarray, total_energy: np.ndarray,
                   e_ref: np.ndarray) -> np.ndarray:
    """Subtract composition-weighted reference energies -> aligned totals."""
    X = composition_matrix(species, e_ref.shape[0]).astype(total_energy.dtype)
    return total_energy - X @ e_ref


def align_sources(per_source: list[dict], n_species: int) -> list[dict]:
    """For each source {'species': (N,A), 'energy': (N,)} fit + subtract its
    own reference energies; returns new dicts with aligned per-atom energy."""
    out = []
    for src in per_source:
        e_ref = fit_reference_energies(src["species"], src["energy"], n_species)
        aligned = align_energies(src["species"], src["energy"], e_ref)
        n_atoms = np.maximum((src["species"] > 0).sum(axis=1), 1)
        out.append(dict(src, energy=aligned / n_atoms, e_ref=e_ref))
    return out


# ---------------------------------------------------------------------------
# Imbalance-aware head placement (hierarchical multi-task parallelism)
# ---------------------------------------------------------------------------

def solve_placement(n_devices: int, loads, *, seed: int = 0,
                    refine_iters: int = 64):
    """Assign heads to device groups so the bottleneck device is as idle as
    possible: minimize ``max_g Σ_{t∈g} load_t / n_g`` — the modeled per-
    device load of the busiest group, which IS the step time on hardware
    where groups run concurrently.

    ``loads`` is the per-head load model — use the measured per-source
    batch mix (``repro.data.mixing.mix_weights`` over source sizes): under
    proportional sampling a head's per-step sample count is its source's
    mixture share, so mix weights are per-head work.

    Two regimes, both deterministic for a fixed ``seed``:

      * ``n_devices >= n_heads`` — one group per head; devices are dealt by
        greedy water-filling (each spare device goes to the currently
        busiest group), then a seeded local search tries single-device
        moves between groups.
      * ``n_heads > n_devices`` — one single-device group per device; heads
        are packed LPT-style (heaviest first onto the least-loaded group),
        then the local search tries single-head moves and pairwise swaps.

    The result is guaranteed no worse than ``round_robin_placement`` on the
    modeled max-group load: the solver evaluates the round-robin baseline
    and keeps whichever wins (ties go to the solver's own layout).
    """
    from .taskpar import HeadPlacement, round_robin_placement

    w = np.asarray([float(x) for x in loads], np.float64)
    assert w.ndim == 1 and w.size >= 1, f"bad loads {loads!r}"
    assert (w >= 0).all() and w.sum() > 0, \
        f"loads must be non-negative with a positive sum, got {w}"
    w = w / w.sum()
    n_heads = w.size
    assert n_devices >= 1, f"n_devices must be >= 1, got {n_devices}"
    rng = np.random.default_rng(seed)

    if n_devices >= n_heads:
        groups = [(t,) for t in range(n_heads)]
        counts = np.ones(n_heads, np.int64)
        for _ in range(n_devices - n_heads):      # greedy water-filling
            counts[int(np.argmax(w / counts))] += 1
        # local search: move one device from a donor to the bottleneck
        for _ in range(refine_iters):
            per_dev = w / counts
            hot = int(np.argmax(per_dev))
            donors = [g for g in range(n_heads)
                      if counts[g] > 1 and g != hot
                      and w[g] / (counts[g] - 1) < per_dev[hot]]
            if not donors:
                break
            donor = donors[int(rng.integers(len(donors)))]
            counts[donor] -= 1
            counts[hot] += 1
        placed = HeadPlacement(groups=tuple(groups),
                               device_counts=tuple(int(c) for c in counts),
                               loads=tuple(w))
    else:
        # more heads than devices: every group is one device; pack heads
        # LPT — heaviest head onto the least-loaded group
        group_heads = [[] for _ in range(n_devices)]
        gload = np.zeros(n_devices, np.float64)
        order = np.argsort(-w, kind="stable")
        for t in order:
            # ties (e.g. zero-load heads) break toward the emptiest group so
            # every device ends up owning at least one head
            g = min(range(n_devices),
                    key=lambda i: (gload[i], len(group_heads[i]), i))
            group_heads[g].append(int(t))
            gload[g] += w[t]
        # local search: single-head moves + pairwise swaps
        for _ in range(refine_iters):
            hot = int(np.argmax(gload))
            best = None   # (new_max, kind, payload)
            cur = gload[hot]
            for t in group_heads[hot]:
                if len(group_heads[hot]) > 1:     # never strand a device
                    for g in range(n_devices):
                        if g == hot:
                            continue
                        new_max = max(cur - w[t], gload[g] + w[t])
                        if new_max < cur and (best is None
                                              or new_max < best[0]):
                            best = (new_max, "move", (t, g))
                for g in range(n_devices):
                    if g == hot:
                        continue
                    for u in group_heads[g]:
                        if w[t] <= w[u]:
                            continue
                        new_max = max(cur - w[t] + w[u],
                                      gload[g] + w[t] - w[u])
                        if new_max < cur and (best is None or
                                              new_max < best[0]):
                            best = (new_max, "swap", (t, hot, u, g))
            if best is None:
                break
            if best[1] == "move":
                t, g = best[2]
                group_heads[hot].remove(t)
                group_heads[g].append(t)
                gload[hot] -= w[t]
                gload[g] += w[t]
            else:
                t, gh, u, g = best[2]
                group_heads[gh].remove(t)
                group_heads[g].remove(u)
                group_heads[gh].append(u)
                group_heads[g].append(t)
                gload[gh] += w[u] - w[t]
                gload[g] += w[t] - w[u]
        assert all(group_heads), "internal: a device group lost all heads"
        group_heads = [sorted(g) for g in group_heads]
        placed = HeadPlacement(groups=tuple(tuple(g) for g in group_heads),
                               device_counts=(1,) * len(group_heads),
                               loads=tuple(w))

    rr = round_robin_placement(n_heads, n_devices)
    if rr.max_group_load(tuple(w)) < placed.max_group_load():
        placed = HeadPlacement(groups=rr.groups,
                               device_counts=rr.device_counts,
                               loads=tuple(w))
    return placed


# ---------------------------------------------------------------------------
# Loss weighting
# ---------------------------------------------------------------------------

def uncertainty_weights_init(n_terms: int):
    return {"log_sigma2": jnp.zeros((n_terms,), jnp.float32)}


def uncertainty_weighted_loss(params, losses):
    """Kendall homoscedastic-uncertainty MTL weighting:
    Σ_i [ exp(-s_i)·L_i + s_i ] with s_i = log σ_i²."""
    s = params["log_sigma2"]
    return jnp.sum(jnp.exp(-s) * losses + s)

"""Multi-source loss balancing + cross-fidelity energy alignment.

The paper "consistently aligned the energy per atom values across all the
datasets" (§4) before pre-training. Different DFT settings shift total
energies by per-element offsets; the standard alignment (cf. Shiota et al.'s
AEC) fits per-source reference atomic energies by least squares on element
composition and subtracts them:

    E_source(s) ≈ Σ_z n_z(s) · e_ref[source, z]  ->  E_aligned = E - Σ n_z e_ref

Loss balancing offers static task weights and learnable homoscedastic
uncertainty weights (Kendall et al.) for the energy/force pair.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def composition_matrix(species: np.ndarray, n_species: int) -> np.ndarray:
    """species: (n_samples, A) int (0 = pad) -> (n_samples, n_species) counts."""
    out = np.zeros((species.shape[0], n_species), np.float64)
    for z in range(1, n_species):
        out[:, z] = (species == z).sum(axis=1)
    return out


def fit_reference_energies(species: np.ndarray, total_energy: np.ndarray,
                           n_species: int, ridge: float = 1e-6) -> np.ndarray:
    """Least-squares per-element reference energies for ONE source.
    total_energy: (n_samples,) TOTAL (not per-atom) energies."""
    X = composition_matrix(species, n_species)
    A = X.T @ X + ridge * np.eye(n_species)
    b = X.T @ total_energy
    return np.linalg.solve(A, b)


def align_energies(species: np.ndarray, total_energy: np.ndarray,
                   e_ref: np.ndarray) -> np.ndarray:
    """Subtract composition-weighted reference energies -> aligned totals."""
    X = composition_matrix(species, e_ref.shape[0]).astype(total_energy.dtype)
    return total_energy - X @ e_ref


def align_sources(per_source: list[dict], n_species: int) -> list[dict]:
    """For each source {'species': (N,A), 'energy': (N,)} fit + subtract its
    own reference energies; returns new dicts with aligned per-atom energy."""
    out = []
    for src in per_source:
        e_ref = fit_reference_energies(src["species"], src["energy"], n_species)
        aligned = align_energies(src["species"], src["energy"], e_ref)
        n_atoms = np.maximum((src["species"] > 0).sum(axis=1), 1)
        out.append(dict(src, energy=aligned / n_atoms, e_ref=e_ref))
    return out


# ---------------------------------------------------------------------------
# Loss weighting
# ---------------------------------------------------------------------------

def uncertainty_weights_init(n_terms: int):
    return {"log_sigma2": jnp.zeros((n_terms,), jnp.float32)}


def uncertainty_weighted_loss(params, losses):
    """Kendall homoscedastic-uncertainty MTL weighting:
    Σ_i [ exp(-s_i)·L_i + s_i ] with s_i = log σ_i²."""
    s = params["log_sigma2"]
    return jnp.sum(jnp.exp(-s) * losses + s)

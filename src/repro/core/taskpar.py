"""Multi-task parallelism (the paper's contribution), in JAX SPMD.

The paper (§4.3–4.4) distributes the per-dataset MTL decoding heads across
process sub-groups: every process holds the shared trunk plus exactly ONE
head; head gradients all-reduce only inside the head's sub-group (local DDP)
while trunk gradients all-reduce globally. Memory per device falls from
``P_s + N_h·P_h`` to ``P_s + P_h``.

JAX mapping — the mesh's ``model`` axis doubles as the **task axis**:

  * heads are stacked ``(n_tasks, …)`` arrays; dim 0 sharded over ``model``
    (mode="par") or replicated (mode="base", the paper's MTL-base baseline);
  * the batch is task-major ``(n_tasks, per_task_batch, …)``: dim 0 follows
    the heads' sharding, dim 1 shards over the data axes;
  * trunk params replicated (or FSDP/TP-sharded via ``shared_spec_fn``).

With those shardings, XLA's SPMD partitioner emits exactly the paper's two
collective scopes for the backward pass: a global all-reduce for trunk grads
and a sub-group (data-axes-only) reduce for head grads. A ``shard_map``
variant makes the two ``psum`` scopes explicit and is used to cross-validate
the pjit path (tests/test_taskpar.py).

This module owns the *sharding vocabulary* only: ``MTPConfig``, the
``MultiTaskModel`` contract, the param/batch sharding builders and the
explicit-collective ``mtp_value_and_grad_shardmap``. Train-step construction
and compilation live in ``repro.engine``: build a step with
``engine.make_step(model, optimizer, plan)`` and compile it with
``ShardingPlan(mesh=..., mtp=..., backend=...).compile(step)`` — the single
public path covering single-device jit, the pjit sharding formulation
(mode="par"/"base") and the shard_map backend behind one signature.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Params = Any


@dataclasses.dataclass(frozen=True)
class MTPConfig:
    n_tasks: int
    mode: str = "par"              # "par" (task-sharded heads) | "base" (replicated)
    task_axis: str = "model"
    data_axes: tuple = ("data",)   # may include "pod"

    @property
    def all_axes(self) -> tuple:
        return tuple(self.data_axes) + (self.task_axis,)


class MultiTaskModel(NamedTuple):
    """init -> {"shared": ..., "heads": stacked-leading-task-dim}.
    loss_fn(shared, heads, batch) -> (per_task_loss: (n_tasks,), metrics).
    n_tasks: number of heads/branches (0 = unknown, for hand-built bundles;
    the repo's builders always set it — Session uses it to pair data sources
    with heads)."""
    init: Callable
    loss_fn: Callable
    name: str = "mtl"
    n_tasks: int = 0


# ---------------------------------------------------------------------------
# Sharding builders
# ---------------------------------------------------------------------------

def head_pspec(mtp: MTPConfig, leaf_ndim: int) -> P:
    if mtp.mode == "par":
        return P(mtp.task_axis, *([None] * (leaf_ndim - 1)))
    return P(*([None] * leaf_ndim))


def param_shardings(mesh: Mesh, params: Params, mtp: MTPConfig,
                    shared_spec_fn: Callable | None = None):
    """NamedSharding tree for {"shared", "heads"} params."""
    def shared_spec(path, leaf):
        if shared_spec_fn is not None:
            return shared_spec_fn(path, leaf)
        return P()

    def build(tree, fn):
        flat = jax.tree_util.tree_flatten_with_path(tree)
        specs = [fn(p, l) for p, l in flat[0]]
        return jax.tree_util.tree_unflatten(flat[1], [
            NamedSharding(mesh, s) for s in specs])

    out = {}
    out["shared"] = build(params["shared"], shared_spec)
    out["heads"] = build(params["heads"], lambda p, l: head_pspec(mtp, l.ndim))
    return out


def batch_shardings(mesh: Mesh, batch: Params, mtp: MTPConfig):
    """Task-major batch (n_tasks, B, ...). par: tasks over task_axis, B over
    data axes. base: tasks replicated, B over ALL axes (pure DDP). Leaves
    with fewer than 2 dims (e.g. stacked per-task weights (n_tasks,)) get
    the spec truncated to their rank."""
    def spec(leaf):
        nd = leaf.ndim
        if mtp.mode == "par":
            entries = (mtp.task_axis, tuple(mtp.data_axes))
        else:
            entries = (None, mtp.all_axes)
        s = P(*(entries[:nd] + tuple([None] * (nd - 2))))
        return NamedSharding(mesh, s)

    return jax.tree_util.tree_map(spec, batch)


def memory_per_device(p_shared: int, p_head: int, n_heads: int, mode: str) -> int:
    """Paper §4.3: parameter count resident per device."""
    return p_shared + (p_head if mode == "par" else n_heads * p_head)


# ---------------------------------------------------------------------------
# Hierarchical placement vocabulary (data-parallel replicas x per-head
# model shards): heads -> device groups, possibly UNEVEN — the Exascale
# follow-up's point is that imbalanced multi-fidelity batch mixes make
# uneven head-to-device assignment the thing that matters at scale.
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class HeadPlacement:
    """Head -> device-group assignment for the hierarchical backend.

    ``groups[g]`` is the tuple of head indices owned by group g;
    ``device_counts[g]`` is how many devices group g gets. Groups partition
    BOTH the heads (every head in exactly one group) and the device pool
    (counts sum to ``n_devices``). Within a group the batch is data-parallel
    over the group's devices and the group's head slice is resident only
    there — memory per device is ``P_s + Σ_{t∈g} P_h(t)``, the paper's
    §4.3 number when groups hold one head each.

    ``loads`` optionally records the per-head load model the placement was
    solved against (``repro.data.mixing`` weights); it is bookkeeping only.
    """
    groups: tuple                  # ((head, ...), ...) — disjoint, exhaustive
    device_counts: tuple           # devices per group, all >= 1
    loads: tuple | None = None     # per-head load model used by the solver

    def __post_init__(self):
        groups = tuple(tuple(int(h) for h in g) for g in self.groups)
        counts = tuple(int(c) for c in self.device_counts)
        object.__setattr__(self, "groups", groups)
        object.__setattr__(self, "device_counts", counts)
        assert len(groups) == len(counts), \
            f"{len(groups)} groups vs {len(counts)} device counts"
        assert all(c >= 1 for c in counts), f"empty device group: {counts}"
        assert all(len(g) >= 1 for g in groups), f"headless group: {groups}"
        flat = [h for g in groups for h in g]
        assert sorted(flat) == list(range(len(flat))), \
            f"groups must partition heads 0..{len(flat) - 1}, got {groups}"
        if self.loads is not None:
            loads = tuple(float(x) for x in self.loads)
            object.__setattr__(self, "loads", loads)
            assert len(loads) == len(flat), \
                f"{len(loads)} loads for {len(flat)} heads"

    @property
    def n_heads(self) -> int:
        return sum(len(g) for g in self.groups)

    @property
    def n_groups(self) -> int:
        return len(self.groups)

    @property
    def n_devices(self) -> int:
        return sum(self.device_counts)

    def group_of(self, head: int) -> int:
        for g, heads in enumerate(self.groups):
            if head in heads:
                return g
        raise KeyError(head)

    def group_loads(self, loads=None) -> tuple:
        """Modeled per-DEVICE load of each group: Σ_{t∈g} load_t / n_g.
        ``loads`` defaults to the solver's recorded load model (uniform if
        none was recorded)."""
        w = self.loads if loads is None else tuple(float(x) for x in loads)
        if w is None:
            w = (1.0,) * self.n_heads
        assert len(w) == self.n_heads, f"{len(w)} loads for {self.n_heads} heads"
        return tuple(sum(w[t] for t in g) / c
                     for g, c in zip(self.groups, self.device_counts))

    def max_group_load(self, loads=None) -> float:
        """The placement's modeled bottleneck: max per-device group load —
        the quantity the solver minimizes and the step-time model on real
        (non-oversubscribed) hardware."""
        return max(self.group_loads(loads))


def round_robin_placement(n_heads: int, n_devices: int) -> HeadPlacement:
    """The load-blind baseline: heads dealt cyclically over
    ``min(n_heads, n_devices)`` groups, devices dealt cyclically over the
    same groups — even-as-possible sizes, no regard for per-head load."""
    assert n_heads >= 1 and n_devices >= 1
    n_groups = min(n_heads, n_devices)
    groups = [[] for _ in range(n_groups)]
    for t in range(n_heads):
        groups[t % n_groups].append(t)
    counts = [n_devices // n_groups + (1 if g < n_devices % n_groups else 0)
              for g in range(n_groups)]
    return HeadPlacement(groups=tuple(tuple(g) for g in groups),
                         device_counts=tuple(counts))


# ---------------------------------------------------------------------------
# shard_map explicit-collective formulation (paper-verbatim psum scopes)
# ---------------------------------------------------------------------------

def mtp_value_and_grad_shardmap(model: MultiTaskModel, mesh: Mesh,
                                mtp: MTPConfig):
    """Explicit two-scope gradient sync. Requires n_tasks == task-axis size.
    Returns f(params, batch) -> (loss, per_task_loss, grads) numerically
    identical to the pjit path (head grads carry the 1/n_tasks factor of the
    mean-over-tasks loss); per_task_loss is (n_tasks,), each entry averaged
    over that task's data sub-group."""
    from jax.experimental.shard_map import shard_map

    ax_t = mtp.task_axis
    ax_d = tuple(mtp.data_axes)
    n_t = mtp.n_tasks
    assert mesh.shape[ax_t] == n_t, (
        f"shard_map path needs n_tasks == mesh['{ax_t}'] "
        f"({n_t} vs {mesh.shape[ax_t]})")

    def local(shared, heads_local, batch_local):
        # heads_local / batch_local have a leading task dim of size 1
        def loss(sh, hd):
            per_task, _ = model.loss_fn(sh, hd, batch_local)
            return per_task[0]

        l, (gs, gh) = jax.value_and_grad(loss, argnums=(0, 1))(
            shared, heads_local)
        # paper: trunk grads -> global group; head grads -> sub-group only.
        # The global pmean includes the 1/n_tasks of the mean-over-tasks loss;
        # head grads live in a single sub-group, so they carry it explicitly.
        gs = jax.lax.pmean(gs, ax_d + (ax_t,))
        gh = jax.lax.pmean(gh, ax_d)
        gh = jax.tree_util.tree_map(lambda g: g / n_t, gh)
        l_task = jax.lax.pmean(l, ax_d)              # this task's loss
        per_task = jax.lax.all_gather(l_task, ax_t)  # (n_tasks,), replicated
        l = jax.lax.pmean(l_task, ax_t)
        return l, per_task, gs, gh

    def shead(leaf_ndim):
        return P(ax_t, *([None] * (leaf_ndim - 1)))

    def f(params, batch):
        shared, heads = params["shared"], params["heads"]
        in_specs = (
            jax.tree_util.tree_map(lambda l: P(), shared),
            jax.tree_util.tree_map(lambda l: shead(l.ndim), heads),
            jax.tree_util.tree_map(
                lambda l: P(ax_t, ax_d, *([None] * (l.ndim - 2))), batch),
        )
        out_specs = (
            P(),
            P(),
            jax.tree_util.tree_map(lambda l: P(), shared),
            jax.tree_util.tree_map(lambda l: shead(l.ndim), heads),
        )
        fn = shard_map(local, mesh=mesh, in_specs=in_specs,
                       out_specs=out_specs, check_rep=False)
        l, per_task, gs, gh = fn(shared, heads, batch)
        return l, per_task, {"shared": gs, "heads": gh}

    return f

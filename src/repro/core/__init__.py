from .taskpar import (MTPConfig, MultiTaskModel, batch_shardings,  # noqa: F401
                      HeadPlacement, head_pspec, memory_per_device,
                      mtp_value_and_grad_shardmap, param_shardings,
                      round_robin_placement)
from .balancing import solve_placement  # noqa: F401
from .mtl import make_gfm_mtl, make_lm_multitask, gfm_eval_fn, softmax_xent  # noqa: F401
from . import balancing  # noqa: F401

"""Two-level hierarchical MTL models (paper §4.2), as MultiTaskModel bundles.

Level 1: one branch per data source. Level 2: each branch = {energy head,
force head}. Three model variants reproduce the paper's Tables 1–2 setup:

  * ``make_gfm_mtl``       — GFM-MTL-All: shared EGNN + per-source branches
  * ``make_gfm_baseline``  — GFM-Baseline-All: shared EGNN + ONE branch for
                              all sources (n_tasks=1 over mixed data)
  * single-source models are just ``make_gfm_mtl`` with n_tasks=1 on one
    source's data.

Also ``make_lm_multitask`` — the paper's technique carried onto the assigned
LLM architectures: shared transformer trunk + per-source LM heads.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.models import gnn, heads, transformer
from repro.models.common import KeyGen
from .taskpar import MultiTaskModel


# ---------------------------------------------------------------------------
# GFM (HydraGNN): EGNN trunk + stacked {energy, force} branches
# ---------------------------------------------------------------------------

def gfm_loss_terms(e_pred, f_pred, batch_t, force_weight=1.0):
    """Masked MSE on energy-per-atom + forces for one task's sub-batch."""
    nm = batch_t["node_mask"]
    e_err = jnp.mean(jnp.square(e_pred - batch_t["energy"]))
    f_err = jnp.sum(jnp.square(f_pred - batch_t["forces"]) * nm[..., None]) / \
        jnp.maximum(jnp.sum(nm) * 3.0, 1.0)
    return e_err + force_weight * f_err, e_err, f_err


def make_gfm_mtl(cfg, n_tasks: int, force_weight: float = 1.0,
                 uncertainty: bool = False) -> MultiTaskModel:
    """uncertainty=True adds Kendall homoscedastic weighting: each branch
    owns learnable log sigma^2 for its (energy, force) pair — the weights
    live with the branch, so they shard over the task axis like any other
    head parameter."""
    def init(key):
        kg = KeyGen(key)
        hp = heads.stacked_branches_init(kg(), cfg, n_tasks)
        if uncertainty:
            hp["log_sigma2"] = jnp.zeros((n_tasks, 2), jnp.float32)
        return {"shared": gnn.egnn_init(kg(), cfg), "heads": hp}

    def loss_fn(shared, hp, batch):
        # batch leaves are task-major: (T, B, ...)
        def per_task(hp_t, batch_t):
            feats = gnn.egnn_apply(shared, batch_t, cfg=cfg)
            e, f = heads.branch_apply(
                {k: v for k, v in hp_t.items() if k != "log_sigma2"},
                feats, batch_t["node_mask"], cfg=cfg)
            _, e_err, f_err = gfm_loss_terms(e, f, batch_t, force_weight)
            if uncertainty:
                s = hp_t["log_sigma2"]
                l = (jnp.exp(-s[0]) * e_err + s[0]
                     + jnp.exp(-s[1]) * force_weight * f_err + s[1])
            else:
                l = e_err + force_weight * f_err
            return l, (e_err, f_err)

        ls, (e_errs, f_errs) = jax.vmap(per_task)(hp, batch)
        return ls, {"energy_mse": e_errs, "force_mse": f_errs}

    return MultiTaskModel(init=init, loss_fn=loss_fn,
                          name=f"gfm-mtl-{n_tasks}", n_tasks=n_tasks)


def gfm_eval_fn(cfg):
    """Returns eval(shared, head_t, batch_single_task) -> (energy MAE, force MAE)."""
    def ev(shared, hp_t, batch_t):
        feats = gnn.egnn_apply(shared, batch_t, cfg=cfg)
        e, f = heads.branch_apply(hp_t, feats, batch_t["node_mask"], cfg=cfg)
        nm = batch_t["node_mask"]
        e_mae = jnp.mean(jnp.abs(e - batch_t["energy"]))
        f_mae = jnp.sum(jnp.abs(f - batch_t["forces"]) * nm[..., None]) / \
            jnp.maximum(jnp.sum(nm) * 3.0, 1.0)
        return e_mae, f_mae
    return jax.jit(ev)


# ---------------------------------------------------------------------------
# LM multi-task: shared transformer trunk + per-source vocab heads
# ---------------------------------------------------------------------------

def softmax_xent(logits, labels):
    """logits: (..., V) f32; labels: (...) int. Mean over all positions."""
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(logz - gold)


def make_lm_multitask(cfg, impl="chunked") -> MultiTaskModel:
    assert cfg.n_tasks > 1

    def init(key):
        kg = KeyGen(key)
        p = transformer.lm_init(kg(), cfg)
        hp = {"w": p.pop("task_heads")["w"]}
        return {"shared": p, "heads": hp}

    def loss_fn(shared, hp, batch):
        # batch: {"tokens": (T,B,S), "labels": (T,B,S)}
        def per_task(hw, toks, labels):
            x = transformer.embed_inputs(shared, toks, cfg)
            h, _, aux = transformer.run_trunk(
                shared, x, cfg=cfg, positions=jnp.arange(toks.shape[-1]),
                mode="train", impl=impl)
            logits = jnp.einsum("bsd,dv->bsv", h, hw.astype(h.dtype),
                                preferred_element_type=jnp.float32)
            return softmax_xent(logits, labels) + cfg.router_aux_coef * aux

        ls = jax.vmap(per_task)(hp["w"], batch["tokens"], batch["labels"])
        return ls, {}

    return MultiTaskModel(init=init, loss_fn=loss_fn,
                          name=f"lm-mtl-{cfg.name}", n_tasks=cfg.n_tasks)

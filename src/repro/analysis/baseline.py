"""Baseline file: accepted findings that pass while new ones gate.

Workflow (docs/static_analysis.md):

  * first adoption — ``python -m repro.analysis.lint src --write-baseline``
    records every current finding in ``lint_baseline.json``; commit it;
  * from then on the linter exits non-zero only for findings NOT in the
    baseline (new code must be clean; legacy debt is inventoried, not
    blocking);
  * fixing a baselined finding leaves a *stale* entry — the linter reports
    it so the baseline can be re-written and shrinks monotonically.

Determinism (DET*) and Pallas-contract (PAL*) findings are repo policy
NEVER to baseline (they break bitwise replay / VMEM budgets silently);
``Baseline.add`` refuses them unless ``allow_all=True``.
"""
from __future__ import annotations

import dataclasses
import json
import pathlib

from .findings import Finding

DEFAULT_NAME = "lint_baseline.json"
# rule-id prefixes whose findings must be FIXED, not suppressed
NEVER_BASELINE = ("DET", "PAL")


class BaselinePolicyError(ValueError):
    """Tried to baseline a finding from a fix-only rule family."""


@dataclasses.dataclass
class Baseline:
    """In-memory view of the accepted-findings file."""
    entries: list[dict] = dataclasses.field(default_factory=list)

    # -- construction -------------------------------------------------------

    @classmethod
    def load(cls, path) -> "Baseline":
        data = json.loads(pathlib.Path(path).read_text())
        if data.get("version") != 1:
            raise ValueError(f"unknown baseline version in {path}: "
                             f"{data.get('version')!r}")
        return cls(entries=list(data.get("entries", [])))

    @classmethod
    def from_findings(cls, findings: list[Finding], *,
                      allow_all: bool = False) -> "Baseline":
        b = cls()
        for f in findings:
            b.add(f, allow_all=allow_all)
        return b

    def add(self, f: Finding, *, allow_all: bool = False):
        if not allow_all and f.rule.startswith(NEVER_BASELINE):
            raise BaselinePolicyError(
                f"{f.rule} findings must be fixed, not baselined "
                f"({f.path}:{f.line}) — determinism and Pallas-contract "
                "violations break replay/VMEM guarantees silently")
        self.entries.append({
            "rule": f.rule, "path": f.path, "fingerprint": f.fingerprint,
            "line": f.line, "snippet": f.snippet,
        })

    # -- persistence --------------------------------------------------------

    def save(self, path):
        entries = sorted(self.entries,
                         key=lambda e: (e["path"], e["line"], e["rule"]))
        payload = {"version": 1, "entries": entries}
        pathlib.Path(path).write_text(json.dumps(payload, indent=2) + "\n")

    # -- matching -----------------------------------------------------------

    def _keys(self) -> set[tuple]:
        return {(e["rule"], e["path"], e["fingerprint"])
                for e in self.entries}


def apply_baseline(findings: list[Finding], baseline: Baseline | None):
    """Split findings against the baseline.

    Returns ``(new, suppressed, stale)``: findings not in the baseline
    (these gate), findings matched by it, and baseline entries whose
    finding no longer exists (fixed or moved — rewrite the baseline)."""
    if baseline is None:
        return list(findings), [], []
    keys = baseline._keys()
    new = [f for f in findings
           if (f.rule, f.path, f.fingerprint) not in keys]
    suppressed = [f for f in findings
                  if (f.rule, f.path, f.fingerprint) in keys]
    live = {(f.rule, f.path, f.fingerprint) for f in findings}
    stale = [e for e in baseline.entries
             if (e["rule"], e["path"], e["fingerprint"]) not in live]
    return new, suppressed, stale

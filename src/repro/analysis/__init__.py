"""repro.analysis — static analysis + runtime sanitizers for the repo's
compiled-path contracts.

The paper's "robust pre-training" claim rests on invariants that ordinary
tests can't see failing: a recompile storm wastes node-hours without a
single assertion tripping, an unseeded RNG in the data path silently breaks
the bitwise-replay guarantee the resilience layer depends on, and a Pallas
kernel whose block sizes bypass the VMEM budget model compiles fine on the
CPU interpreter and OOMs on the first TPU run. This package makes those
contracts machine-checkable:

  * ``repro.analysis.lint`` — an AST linter with repo-specific rules
    (``python -m repro.analysis.lint src benchmarks examples``). Rule
    catalog: ``rules.RULES`` / ``docs/static_analysis.md``.
  * ``repro.analysis.baseline`` — accepted-findings file so pre-existing
    findings pass while NEW ones gate CI.
  * ``repro.analysis.recompile`` — ``RecompileSanitizer``: declared
    XLA-compilation budgets over jitted callables (the serve-side
    ``_cache_size`` check, generalized to ``Session`` training and
    ``bench_*`` loops).
  * ``repro.analysis.tsan`` — ``ThreadSanitizer``: lock-ownership and
    mutual-exclusion contract checking for the threaded pieces
    (``data/prefetch.py``, ``serve/queue.py``); instrumented in tests only.

Everything here is stdlib-only (no jax import), so the CI lint job runs
without installing the accelerator stack.
"""
from .baseline import Baseline, apply_baseline
from .findings import Finding
from .recompile import RecompileBudgetError, RecompileSanitizer
from .rules import RULES, rule_ids
from .tsan import ThreadContractViolation, ThreadSanitizer, TrackedLock

__all__ = [
    "Finding", "Baseline", "apply_baseline", "RULES", "rule_ids",
    "RecompileSanitizer", "RecompileBudgetError",
    "ThreadSanitizer", "ThreadContractViolation", "TrackedLock",
]

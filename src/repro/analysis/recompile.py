"""RecompileSanitizer — declared XLA-compilation budgets, enforced.

The serve engine already gates its jit cache (``tests/test_serve_engine.py``
asserts ``_predict._cache_size()`` against the grid×heads budget); this
generalizes that check to anything that compiles: ``Session`` training
runs, ``bench_*`` loops, ad-hoc jitted functions. A recompile storm — a
shape leaking into a traced argument, a factory re-jitting per call — never
fails a numeric test; it just multiplies step time by the compile latency
and burns the allocation. Declaring the budget turns it into a crash.

Usage::

    from repro.analysis import RecompileSanitizer

    with RecompileSanitizer(budget=2, label="20-step session") as san:
        san.track_session(session)      # engine.Session seam
        session.run()
    # exit raises RecompileBudgetError if compilations exceeded the budget

Counting is by cache-size *delta* since ``track()``: functions already
warmed up before tracking start from zero. Stdlib-only: the probe duck-
types on ``_cache_size`` (jax's jit/pjit wrapper) or ``cache_size``
(``repro.engine.plan.CompiledStep`` seam) — no jax import here.
"""
from __future__ import annotations

import threading


class RecompileBudgetError(RuntimeError):
    """Tracked functions compiled more than the declared budget allows."""


def _probe_for(fn):
    """A zero-arg callable returning ``fn``'s current compile count, or
    None if ``fn`` exposes no cache-size seam."""
    probe = getattr(fn, "cache_size", None)           # CompiledStep seam
    if callable(probe):
        return probe
    raw = getattr(fn, "_cache_size", None)            # jax jit/pjit wrapper
    if callable(raw):
        return raw
    return None


class RecompileSanitizer:
    """Fail when tracked callables exceed a declared compilation budget.

    budget: max NEW compilations across all tracked functions (cache-size
    growth since each was tracked). ``check()`` raises
    ``RecompileBudgetError``; as a context manager, ``__exit__`` checks
    automatically (only on a clean exit — an in-flight exception wins).
    """

    def __init__(self, budget: int, *, label: str = ""):
        assert budget >= 0, f"budget must be >= 0, got {budget}"
        self.budget = int(budget)
        self.label = label
        self._mx = threading.Lock()
        self._tracked: list[tuple[str, object, int]] = []  # (name, probe, base)

    # -- registration -------------------------------------------------------

    def track(self, fn, name: str | None = None) -> bool:
        """Track one jitted callable. Returns False (and skips it) when the
        object exposes no cache-size seam — callers that require tracking
        can assert on the return value."""
        probe = _probe_for(fn)
        if probe is None:
            return False
        with self._mx:
            self._tracked.append(
                (name or getattr(fn, "__name__", type(fn).__name__),
                 probe, int(probe())))
        return True

    def track_session(self, session, name: str = "session"):
        """Track an ``engine.Session`` LIVE: the probe re-reads
        ``session.compiled_functions()`` at every check, so a step rebuilt
        mid-run (e.g. quarantine recompiles) still counts against the
        budget instead of silently escaping the tracker. Every callable ever
        seen stays in the sum (holding a reference, so ids are stable) —
        swapping in a fresh step must not erase the old one's compiles."""
        seen: dict[int, tuple] = {}   # id(fn) -> (fn ref, probe)

        def probe():
            for f in session.compiled_functions():
                p = _probe_for(f)
                if p is not None:
                    seen[id(f)] = (f, p)
            return sum(int(p()) for _f, p in seen.values())
        with self._mx:
            self._tracked.append((name, probe, int(probe())))

    # -- accounting ---------------------------------------------------------

    def compilations(self) -> int:
        """NEW compilations across all tracked functions since tracking."""
        with self._mx:
            return sum(max(0, int(probe()) - base)
                       for _, probe, base in self._tracked)

    def report(self) -> dict:
        """Per-function compile counts, for test assertions and logs."""
        with self._mx:
            return {name: max(0, int(probe()) - base)
                    for name, probe, base in self._tracked}

    def check(self):
        n = self.compilations()
        if n > self.budget:
            detail = ", ".join(f"{k}={v}" for k, v in self.report().items()
                               if v) or "untracked"
            label = f" [{self.label}]" if self.label else ""
            raise RecompileBudgetError(
                f"recompile budget exceeded{label}: {n} compilation(s) > "
                f"budget {self.budget} ({detail}) — a shape/dtype is "
                "leaking into a traced signature, or a factory re-jits "
                "per call (rules RCP001-003)")

    # -- context manager ----------------------------------------------------

    def __enter__(self):
        return self

    def __exit__(self, exc_type, exc, tb):
        if exc_type is None:
            self.check()
        return False

"""ThreadSanitizer-style contract checking for the threaded layers.

``data/prefetch.py`` and ``serve/queue.py`` make concurrency promises their
docstrings state but no test can see breaking: exactly one producer thread
draws from the wrapped batcher at a time (the bitwise-replay guarantee),
exactly one engine worker drains the request queue, and shared state that a
lock is supposed to guard is only touched while holding it. A violation is
a *benign-looking race* — the run usually still passes, just no longer
bitwise-replayably. This module makes the contracts executable:

  * ``TrackedLock`` — a lock wrapper that knows its owner thread;
  * ``ThreadSanitizer.wrap_mutual_exclusion(obj, methods)`` — records a
    violation when two threads are inside the named methods concurrently
    (re-entry by the SAME thread is fine; sequential generations of
    producer threads are fine — this checks overlap, not identity);
  * ``ThreadSanitizer.guard_attrs(obj, attrs, lock)`` — instruments the
    instance (class swap) so touching a guarded attribute without holding
    the lock records a violation;
  * ``check()`` raises ``ThreadContractViolation`` listing every recorded
    violation with thread names and call sites.

Instrumented in tests only (the ``sanitizer`` pytest marker): the
``__getattribute__`` hook costs real overhead, so production objects are
never wrapped. Stdlib-only.
"""
from __future__ import annotations

import dataclasses
import threading
import traceback


class ThreadContractViolation(AssertionError):
    """One or more recorded thread-contract violations (see .violations)."""

    def __init__(self, violations):
        self.violations = list(violations)
        lines = "\n".join(f"  - {v}" for v in self.violations)
        super().__init__(
            f"{len(self.violations)} thread-contract violation(s):\n{lines}")


@dataclasses.dataclass(frozen=True)
class Violation:
    kind: str       # "concurrent-entry" | "unguarded-read" | "unguarded-write"
    target: str     # "Type.method" / "Type.attr"
    thread: str
    detail: str
    site: str       # "file.py:123"

    def __str__(self):
        return (f"[{self.kind}] {self.target} from thread {self.thread} "
                f"at {self.site}: {self.detail}")


def _call_site() -> str:
    """First stack frame outside this module — where the access happened."""
    for frame in reversed(traceback.extract_stack()):
        if not frame.filename.endswith("tsan.py"):
            return f"{frame.filename.rsplit('/', 1)[-1]}:{frame.lineno}"
    return "?"


class TrackedLock:
    """``threading.Lock`` with ownership tracking (supports same-thread
    re-entry bookkeeping so ``held()`` answers 'does THIS thread hold
    it')."""

    def __init__(self):
        self._lock = threading.RLock()
        self._owner: int | None = None
        self._depth = 0

    def acquire(self, *a, **kw) -> bool:
        got = self._lock.acquire(*a, **kw)
        if got:
            self._owner = threading.get_ident()
            self._depth += 1
        return got

    def release(self):
        self._depth -= 1
        if self._depth == 0:
            self._owner = None
        self._lock.release()

    def held(self) -> bool:
        return self._owner == threading.get_ident()

    def __enter__(self):
        self.acquire()
        return self

    def __exit__(self, *exc):
        self.release()
        return False


class ThreadSanitizer:
    """Collects thread-contract violations; raise them all via check()."""

    def __init__(self):
        self.violations: list[Violation] = []
        self._mx = threading.Lock()

    def _record(self, kind, target, detail):
        v = Violation(kind=kind, target=target,
                      thread=threading.current_thread().name,
                      detail=detail, site=_call_site())
        with self._mx:
            self.violations.append(v)

    # -- mutual exclusion ---------------------------------------------------

    def wrap_mutual_exclusion(self, obj, methods, *, group: str | None = None):
        """Patch the named bound methods so that concurrent entry by two
        threads records a violation. All listed methods share one exclusion
        group (``Prefetcher``'s contract: batcher draws never overlap, no
        matter which producer generation makes them)."""
        label = group or f"{type(obj).__name__}.{{{','.join(methods)}}}"
        state = {"owner": None, "depth": 0}
        state_mx = threading.Lock()
        san = self

        def _wrap(name, orig):
            def wrapped(*a, **kw):
                me = threading.get_ident()
                with state_mx:
                    if state["owner"] not in (None, me):
                        san._record(
                            "concurrent-entry",
                            f"{type(obj).__name__}.{name}",
                            f"entered while thread id {state['owner']} is "
                            f"inside exclusion group {label}")
                    else:
                        state["owner"] = me
                    state["depth"] += 1
                try:
                    return orig(*a, **kw)
                finally:
                    with state_mx:
                        state["depth"] -= 1
                        if state["depth"] == 0:
                            state["owner"] = None
            wrapped.__name__ = name
            return wrapped

        for name in methods:
            orig = getattr(obj, name)
            setattr(obj, name, _wrap(name, orig))
        return obj

    # -- lock-guarded attributes --------------------------------------------

    def guard_attrs(self, obj, attrs, lock: TrackedLock):
        """Swap ``obj``'s class for an instrumented subclass: any read or
        write of a guarded attribute while ``lock`` is NOT held by the
        current thread records a violation. Test-only instrumentation —
        never wrap production instances."""
        attrs = frozenset(attrs)
        san = self
        cls = type(obj)

        class Instrumented(cls):
            def __getattribute__(self, name):
                if name in attrs and not lock.held():
                    san._record("unguarded-read", f"{cls.__name__}.{name}",
                                "read without holding the guarding lock")
                return super().__getattribute__(name)

            def __setattr__(self, name, value):
                if name in attrs and not lock.held():
                    san._record("unguarded-write", f"{cls.__name__}.{name}",
                                "written without holding the guarding lock")
                super().__setattr__(name, value)

        Instrumented.__name__ = f"Instrumented{cls.__name__}"
        obj.__class__ = Instrumented
        return obj

    # -- reporting ----------------------------------------------------------

    def check(self):
        """Raise ThreadContractViolation if any violation was recorded."""
        with self._mx:
            if self.violations:
                raise ThreadContractViolation(self.violations)

    def __enter__(self):
        return self

    def __exit__(self, exc_type, exc, tb):
        if exc_type is None:
            self.check()
        return False

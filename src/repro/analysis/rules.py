"""The rule catalog: repo-specific AST checks over jax/Pallas code.

Every rule is a pure function ``(ModuleInfo) -> list[Finding]`` registered
in ``RULES``. Rules resolve names through the module's import aliases
(``jnp.any`` -> ``jax.numpy.any`` whatever the local alias), so renaming an
import does not dodge a rule. The rule ids are grouped by contract:

  TRC — trace-safety (Python control flow / host syncs on traced values)
  RCP — recompile hazards (per-call jit, array constants baked into jaxprs,
        array-valued static args)
  DET — determinism (unseeded global RNGs, wall-clock time in replayable
        or measured paths)
  DON — buffer-donation discipline (use-after-donate)
  PAL — Pallas kernel contracts (bare int indices, unplanned block sizes,
        non-f32 accumulator scratch)

Heuristics err toward precision: a rule that cries wolf gets baselined into
silence, which is worse than a narrow rule that always means it. The
fixtures in ``tests/fixtures/lint/`` pin each rule's seeded violation AND
its clean twin.
"""
from __future__ import annotations

import ast
import dataclasses
import re

from .findings import Finding

# canonical prefixes after alias resolution
_JNP = "jax.numpy"
_NP = "numpy"
_PL = "jax.experimental.pallas"
_PLTPU = "jax.experimental.pallas.tpu"

# determinism-critical packages: their bitwise-replay guarantees are what
# PR 7's rollback soak and the serve parity tests depend on
REPLAY_SCOPED = ("repro/data/", "repro/serve/", "repro/resilience/")

# module-level references that count as "block sizes are planned" for PAL002
_PLANNING_RE = re.compile(
    r"plan_blocks|check_blocks|autotune_blocks|block_geometry|vmem_bytes"
    r"|resolve_blocks|fits_vmem")
_EXPLICIT_BLOCKS_PRAGMA = "pallas: explicit-blocks"

# numpy.random constructors that are seeded/deterministic by design
_NP_RANDOM_OK = {"default_rng", "Generator", "SeedSequence", "PCG64",
                 "Philox", "MT19937", "SFC64", "BitGenerator"}


# ---------------------------------------------------------------------------
# module model
# ---------------------------------------------------------------------------

class ModuleInfo:
    """Parsed module + alias table + jit-reachability, shared by all rules."""

    def __init__(self, path: str, src: str):
        self.path = path
        self.src = src
        self.lines = src.splitlines()
        self.tree = ast.parse(src)
        self.aliases: dict[str, str] = {}       # local name -> dotted module
        self.from_imports: dict[str, str] = {}  # local name -> qualified name
        self._collect_imports()
        self._parents: dict[ast.AST, ast.AST] = {}
        for parent in ast.walk(self.tree):
            for child in ast.iter_child_nodes(parent):
                self._parents[child] = parent
        self.jit_reachable = self._jit_reachable()

    # -- imports ------------------------------------------------------------

    def _collect_imports(self):
        for node in ast.walk(self.tree):
            if isinstance(node, ast.Import):
                for a in node.names:
                    self.aliases[a.asname or a.name.split(".")[0]] = \
                        a.name if a.asname else a.name.split(".")[0]
                    if a.asname:
                        self.aliases[a.asname] = a.name
            elif isinstance(node, ast.ImportFrom) and node.module:
                for a in node.names:
                    self.from_imports[a.asname or a.name] = \
                        f"{node.module}.{a.name}"

    def qualname(self, node) -> str | None:
        """Resolve a Name/Attribute chain to its canonical dotted path, or
        None if the root is not an imported module / from-import."""
        parts = []
        while isinstance(node, ast.Attribute):
            parts.append(node.attr)
            node = node.value
        if not isinstance(node, ast.Name):
            return None
        root = node.id
        if root in self.aliases:
            base = self.aliases[root]
        elif root in self.from_imports:
            base = self.from_imports[root]
        elif not parts and root in ("bool", "float", "int"):
            base = root
        else:
            return None
        return ".".join([base] + list(reversed(parts)))

    # -- findings helpers ---------------------------------------------------

    def snippet(self, lineno: int) -> str:
        if 1 <= lineno <= len(self.lines):
            return self.lines[lineno - 1].strip()
        return ""

    def finding(self, rule: str, node: ast.AST, message: str,
                hint: str) -> Finding:
        return Finding(rule=rule, path=self.path, line=node.lineno,
                       col=node.col_offset, message=message, hint=hint,
                       snippet=self.snippet(node.lineno))

    def line_has_pragma(self, lineno: int, pragma: str) -> bool:
        return pragma in self.snippet(lineno)

    # -- jit reachability ---------------------------------------------------

    def _is_jit_entry(self, qn: str | None) -> bool:
        if qn is None:
            return False
        return qn in ("jax.jit", "jax.pjit") or qn.endswith(".pjit") \
            or qn.endswith(".shard_map") or qn.endswith("custom_vjp") \
            or qn.endswith("custom_jvp") or qn == f"{_PL}.pallas_call"

    def _decorator_is_jit(self, dec) -> bool:
        if self._is_jit_entry(self.qualname(dec)):
            return True
        if isinstance(dec, ast.Call):
            qn = self.qualname(dec.func)
            if self._is_jit_entry(qn):
                return True
            # functools.partial(jax.jit, ...) / partial(jax.custom_vjp, ...)
            if qn in ("functools.partial", "partial") and dec.args:
                return self._is_jit_entry(self.qualname(dec.args[0]))
        return False

    def _jit_reachable(self) -> set[ast.FunctionDef]:
        """Functions reachable from a jit/pjit/shard_map/pallas_call entry
        point, via decorators, wrap-calls (``jax.jit(f)``) and same-module
        calls by name (propagated to fixpoint)."""
        defs: dict[str, ast.FunctionDef] = {}
        all_defs: list[ast.FunctionDef] = []
        for node in ast.walk(self.tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                all_defs.append(node)
                defs.setdefault(node.name, node)

        seeds: set[ast.FunctionDef] = set()
        for fn in all_defs:
            if any(self._decorator_is_jit(d) for d in fn.decorator_list):
                seeds.add(fn)
        # f passed into jax.jit(f, ...) / pallas_call(f, ...) / partial(...)
        for node in ast.walk(self.tree):
            if not isinstance(node, ast.Call):
                continue
            qn = self.qualname(node.func)
            cands = []
            if self._is_jit_entry(qn):
                cands = node.args[:1]
            elif qn in ("functools.partial", "partial") and node.args:
                cands = node.args[:1]  # partial(kernel_fn, ...) fed to pallas
            for a in cands:
                if isinstance(a, ast.Name) and a.id in defs:
                    seeds.add(defs[a.id])

        # propagate through same-module calls by bare name
        reachable = set(seeds)
        changed = True
        while changed:
            changed = False
            for fn in list(reachable):
                for node in ast.walk(fn):
                    if isinstance(node, ast.Call) and \
                            isinstance(node.func, ast.Name):
                        callee = defs.get(node.func.id)
                        if callee is not None and callee not in reachable:
                            reachable.add(callee)
                            changed = True
        return reachable

    def enclosing_function(self, node) -> ast.FunctionDef | None:
        cur = self._parents.get(node)
        while cur is not None:
            if isinstance(cur, (ast.FunctionDef, ast.AsyncFunctionDef)):
                return cur
            cur = self._parents.get(cur)
        return None

    def in_jit_reachable(self, node) -> bool:
        fn = self.enclosing_function(node)
        while fn is not None:
            if fn in self.jit_reachable:
                return True
            fn = self.enclosing_function(fn)
        return False


def _is_jnp_call(mi: ModuleInfo, node) -> bool:
    if not isinstance(node, ast.Call):
        return False
    qn = mi.qualname(node.func)
    return qn is not None and qn.startswith(_JNP + ".")


def _contains_jnp_call(mi: ModuleInfo, node) -> ast.Call | None:
    for sub in ast.walk(node):
        if _is_jnp_call(mi, sub):
            return sub
    return None


# ---------------------------------------------------------------------------
# TRC — trace safety
# ---------------------------------------------------------------------------

def rule_trc001(mi: ModuleInfo) -> list[Finding]:
    """Python ``if``/``while`` on a jnp-valued test inside a jit-reachable
    function: under trace the test is a Tracer and raises
    ``TracerBoolConversionError`` (or silently specializes under
    ``static_argnums``)."""
    out = []
    for node in ast.walk(mi.tree):
        if not isinstance(node, (ast.If, ast.While)):
            continue
        if not mi.in_jit_reachable(node):
            continue
        hit = _contains_jnp_call(mi, node.test)
        if hit is not None:
            kind = "if" if isinstance(node, ast.If) else "while"
            out.append(mi.finding(
                "TRC001", node,
                f"Python `{kind}` on a traced value "
                f"(`{ast.unparse(hit)}`) inside a jit-reachable function",
                "branch with jnp.where / jax.lax.cond / jax.lax.select so "
                "the decision stays inside the compiled program"))
    return out


def rule_trc002(mi: ModuleInfo) -> list[Finding]:
    """Host-sync coercions — ``.item()`` / ``bool()`` / ``float()`` /
    ``int()`` over a jnp expression — inside a jit-reachable function."""
    out = []
    for node in ast.walk(mi.tree):
        if not isinstance(node, ast.Call):
            continue
        if not mi.in_jit_reachable(node):
            continue
        # x.item()
        if isinstance(node.func, ast.Attribute) and node.func.attr == "item" \
                and not node.args and not node.keywords:
            out.append(mi.finding(
                "TRC002", node,
                "`.item()` inside a jit-reachable function forces a host "
                "sync (and fails under trace)",
                "keep the value on device; reduce with jnp ops and read it "
                "out once, outside the jitted function"))
            continue
        qn = mi.qualname(node.func)
        if qn in ("bool", "float", "int") and len(node.args) == 1 and \
                _contains_jnp_call(mi, node.args[0]):
            out.append(mi.finding(
                "TRC002", node,
                f"`{qn}()` over a traced jnp expression inside a "
                "jit-reachable function",
                "keep the scalar as a jnp value (astype / jnp.where); "
                "coerce to Python only outside the compiled region"))
    return out


def rule_trc003(mi: ModuleInfo) -> list[Finding]:
    """Per-iteration host syncs in loops: ``.item()`` or
    ``jax.device_get`` inside a ``for``/``while`` body serializes the loop
    on device->host readback (the classic hidden hot-loop stall)."""
    out = []
    loops = [n for n in ast.walk(mi.tree)
             if isinstance(n, (ast.For, ast.While))]
    for loop in loops:
        for node in ast.walk(loop):
            if node is loop or not isinstance(node, ast.Call):
                continue
            is_item = isinstance(node.func, ast.Attribute) and \
                node.func.attr == "item" and not node.args
            qn = mi.qualname(node.func)
            is_get = qn == "jax.device_get"
            if not (is_item or is_get):
                continue
            if mi.in_jit_reachable(node):
                continue  # TRC002's jurisdiction
            what = ".item()" if is_item else "jax.device_get"
            out.append(mi.finding(
                "TRC003", node,
                f"`{what}` inside a loop body — a device->host sync every "
                "iteration",
                "accumulate on device and read back once after the loop, "
                "or log every N steps (see train_loop's log_every)"))
    return out


# ---------------------------------------------------------------------------
# RCP — recompile hazards
# ---------------------------------------------------------------------------

def rule_rcp001(mi: ModuleInfo) -> list[Finding]:
    """``jax.jit(...)`` called inside a loop body: every iteration builds a
    fresh jit wrapper with an empty cache — a guaranteed per-iteration
    recompile (the serve budget's nemesis)."""
    out = []
    for loop in ast.walk(mi.tree):
        if not isinstance(loop, (ast.For, ast.While)):
            continue
        for node in ast.walk(loop):
            if isinstance(node, ast.Call) and \
                    mi.qualname(node.func) in ("jax.jit", "jax.pjit"):
                out.append(mi.finding(
                    "RCP001", node,
                    "`jax.jit` constructed inside a loop — a fresh compile "
                    "cache (and a recompile) every iteration",
                    "hoist the jit call out of the loop; jit once, call "
                    "many times"))
    return out


def rule_rcp002(mi: ModuleInfo) -> list[Finding]:
    """A jitted inner function closing over an array built in its enclosing
    factory: the array is baked into the jaxpr as a constant, so every
    factory call compiles a distinct executable (step-factory recompile
    hazard) and the constant bypasses donation/sharding."""
    out = []
    for outer in ast.walk(mi.tree):
        if not isinstance(outer, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        # arrays assigned in the OUTER body (not inside nested defs)
        arrays: dict[str, ast.AST] = {}
        inner_defs = [n for n in ast.walk(outer)
                      if isinstance(n, (ast.FunctionDef,
                                        ast.AsyncFunctionDef)) and n is not outer]

        def _in_inner(node):
            return any(node in set(ast.walk(d)) for d in inner_defs)

        for node in ast.walk(outer):
            if isinstance(node, ast.Assign) and not _in_inner(node):
                val = node.value
                if isinstance(val, ast.Call):
                    qn = mi.qualname(val.func)
                    if qn and (qn.startswith(_JNP + ".")
                               or qn.startswith(_NP + ".")
                               or qn.startswith("jax.random.")):
                        for t in node.targets:
                            if isinstance(t, ast.Name):
                                arrays[t.id] = node
        if not arrays:
            continue
        for inner in inner_defs:
            jitted = any(mi._decorator_is_jit(d) for d in inner.decorator_list)
            if not jitted:
                # `step = jax.jit(inner)` in the same outer body
                for node in ast.walk(outer):
                    if isinstance(node, ast.Call) and \
                            mi._is_jit_entry(mi.qualname(node.func)) and \
                            node.args and isinstance(node.args[0], ast.Name) \
                            and node.args[0].id == inner.name:
                        jitted = True
            if not jitted:
                continue
            local = {a.arg for a in inner.args.args}
            local |= {n.id for n in ast.walk(inner)
                      if isinstance(n, ast.Name)
                      and isinstance(n.ctx, ast.Store)}
            for node in ast.walk(inner):
                if isinstance(node, ast.Name) and \
                        isinstance(node.ctx, ast.Load) and \
                        node.id in arrays and node.id not in local:
                    out.append(mi.finding(
                        "RCP002", node,
                        f"jitted `{inner.name}` closes over array "
                        f"`{node.id}` built in `{outer.name}` — baked in as "
                        "a constant, recompiled per factory call",
                        "pass the array as an argument to the jitted "
                        "function (or thread it through the train state)"))
    return out


def rule_rcp003(mi: ModuleInfo) -> list[Finding]:
    """Array- or container-valued STATIC args: a call site passing a jnp/np
    expression or list/dict/set literal for a parameter declared in
    ``static_argnames`` either fails (unhashable) or keys the jit cache on
    array *identity* — one compile per call."""
    out = []
    # name -> set of static argnames, for `f = jax.jit(g, static_argnames=..)`
    statics: dict[str, set[str]] = {}
    for node in ast.walk(mi.tree):
        if not isinstance(node, ast.Assign):
            continue
        val = node.value
        if not (isinstance(val, ast.Call)
                and mi.qualname(val.func) in ("jax.jit", "jax.pjit")):
            continue
        names: set[str] = set()
        for kw in val.keywords:
            if kw.arg == "static_argnames":
                for sub in ast.walk(kw.value):
                    if isinstance(sub, ast.Constant) and \
                            isinstance(sub.value, str):
                        names.add(sub.value)
        if names:
            for t in node.targets:
                if isinstance(t, ast.Name):
                    statics[t.id] = names
    if not statics:
        return out
    for node in ast.walk(mi.tree):
        if not (isinstance(node, ast.Call) and isinstance(node.func, ast.Name)
                and node.func.id in statics):
            continue
        for kw in node.keywords:
            if kw.arg not in statics[node.func.id]:
                continue
            bad = None
            if isinstance(kw.value, (ast.List, ast.Dict, ast.Set)):
                bad = "an unhashable container literal"
            elif isinstance(kw.value, ast.Call):
                qn = mi.qualname(kw.value.func)
                if qn and (qn.startswith(_JNP + ".")
                           or qn.startswith(_NP + ".")):
                    bad = "an array expression"
            if bad:
                out.append(mi.finding(
                    "RCP003", kw.value,
                    f"static arg `{kw.arg}` receives {bad} — unhashable or "
                    "identity-keyed, so the jit cache misses every call",
                    "pass a hashable scalar/tuple as the static, or make "
                    "the argument dynamic (drop it from static_argnames)"))
    return out


# ---------------------------------------------------------------------------
# DET — determinism
# ---------------------------------------------------------------------------

def rule_det001(mi: ModuleInfo) -> list[Finding]:
    """The legacy numpy global RNG (``np.random.<fn>``): process-global,
    unseedable per-stream, and invisible to the datapipe checkpoint
    sidecar — it breaks the bitwise batch-replay guarantee."""
    out = []
    for node in ast.walk(mi.tree):
        if not isinstance(node, ast.Call):
            continue
        qn = mi.qualname(node.func)
        if not qn or not qn.startswith(_NP + ".random."):
            continue
        fn = qn.rsplit(".", 1)[-1]
        if fn in _NP_RANDOM_OK:
            continue
        out.append(mi.finding(
            "DET001", node,
            f"legacy global numpy RNG `np.random.{fn}` — unseeded, "
            "process-global state outside the datapipe checkpoint",
            "use a held np.random.default_rng(seed) Generator (the repo "
            "convention; see repro.data.loader)"))
    return out


def rule_det002(mi: ModuleInfo) -> list[Finding]:
    """The Python stdlib ``random`` module's global functions — same
    process-global nondeterminism as DET001, same fix."""
    out = []
    for node in ast.walk(mi.tree):
        if not isinstance(node, ast.Call):
            continue
        qn = mi.qualname(node.func)
        if not qn or not qn.startswith("random."):
            continue
        fn = qn.split(".", 1)[1]
        if fn.split(".")[0] in ("Random", "SystemRandom"):
            continue  # an instance is held + seeded explicitly (or crypto)
        out.append(mi.finding(
            "DET002", node,
            f"stdlib global RNG `random.{fn}` — unseeded process-global "
            "state",
            "hold a random.Random(seed) instance, or use "
            "np.random.default_rng(seed)"))
    return out


def rule_det003(mi: ModuleInfo) -> list[Finding]:
    """``time.time()`` — non-monotonic (NTP steps it) so durations computed
    from it are wrong, and as a *value* in the replay-scoped packages it is
    nondeterministic input."""
    out = []
    scoped = any(s in mi.path for s in REPLAY_SCOPED)
    for node in ast.walk(mi.tree):
        if not isinstance(node, ast.Call):
            continue
        if mi.qualname(node.func) not in ("time.time", "time.time_ns"):
            continue
        where = "a bitwise-replay-scoped module" if scoped else \
            "a measured/timed path"
        out.append(mi.finding(
            "DET003", node,
            f"`time.time()` in {where} — non-monotonic wall clock",
            "time durations with time.perf_counter(); drive deadlines with "
            "time.monotonic(); replay-scoped code must not read clocks"))
    return out


# ---------------------------------------------------------------------------
# DON — donation discipline
# ---------------------------------------------------------------------------

def _donated_indices(call: ast.Call) -> tuple[int, ...]:
    for kw in call.keywords:
        if kw.arg in ("donate_argnums", "donate_argnames"):
            vals = []
            for sub in ast.walk(kw.value):
                if isinstance(sub, ast.Constant) and \
                        isinstance(sub.value, int):
                    vals.append(sub.value)
            return tuple(vals) or (0,)
    return ()


def rule_don001(mi: ModuleInfo) -> list[Finding]:
    """Use-after-donate: a buffer passed at a donated position of a jitted
    step is CONSUMED — XLA may alias its memory for the outputs, and
    reading it afterwards returns garbage (or errors on TPU)."""
    out = []

    def _enclosing_stmt(node):
        cur = node
        while cur is not None and not isinstance(cur, ast.stmt):
            cur = mi._parents.get(cur)
        return cur

    for fn in ast.walk(mi.tree):
        if not isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        # donating callables assigned in this function body
        donating: dict[str, tuple[int, ...]] = {}
        for node in ast.walk(fn):
            if isinstance(node, ast.Assign) and \
                    isinstance(node.value, ast.Call) and \
                    mi.qualname(node.value.func) in ("jax.jit", "jax.pjit"):
                idx = _donated_indices(node.value)
                if idx:
                    for t in node.targets:
                        if isinstance(t, ast.Name):
                            donating[t.id] = idx
        if not donating:
            continue
        # source-position-ordered event scan. Within one line, loads run
        # before stores before donations — so the canonical safe pattern
        # `state, out = step(state, batch)` (donate + rebind in one
        # statement) never taints `state`: the donation event checks its
        # enclosing statement for a rebind and skips tainting.
        events = []
        for node in ast.walk(fn):
            if isinstance(node, ast.Name):
                kind = 0 if isinstance(node.ctx, ast.Load) else 1
                events.append((node.lineno, kind, node.col_offset, node))
            elif isinstance(node, ast.Call) and \
                    isinstance(node.func, ast.Name) and \
                    node.func.id in donating:
                events.append((node.lineno, 2, node.col_offset, node))
        donated: dict[str, int] = {}  # name -> donation lineno
        for lineno, kind, _col, node in sorted(events, key=lambda e: e[:3]):
            if kind == 0 and node.id in donated:
                out.append(mi.finding(
                    "DON001", node,
                    f"`{node.id}` read after being donated on line "
                    f"{donated[node.id]} — its buffer may already be "
                    "aliased by the step's outputs",
                    "rebind the result (`state = step(state, ...)`) and "
                    "only use the returned value, or compile with "
                    "donate=False for debugging"))
                del donated[node.id]
            elif kind == 1 and node.id in donated:
                del donated[node.id]
            elif kind == 2:
                stmt = _enclosing_stmt(node)
                for i in donating[node.func.id]:
                    if i < len(node.args) and \
                            isinstance(node.args[i], ast.Name):
                        name = node.args[i].id
                        rebinds = stmt is not None and any(
                            isinstance(n, ast.Name) and n.id == name and
                            isinstance(n.ctx, ast.Store)
                            for n in ast.walk(stmt))
                        if not rebinds:
                            donated[name] = node.lineno
    return out


# ---------------------------------------------------------------------------
# PAL — Pallas contracts
# ---------------------------------------------------------------------------

def rule_pal001(mi: ModuleInfo) -> list[Finding]:
    """Bare int literals inside ``pl.load``/``pl.store`` index tuples — the
    exact PR 3 flash_decode bug: jax 0.4.x interpret-mode discharge probes
    ``.shape`` on every non-Slice index entry and chokes."""
    out = []
    for node in ast.walk(mi.tree):
        if not isinstance(node, ast.Call):
            continue
        qn = mi.qualname(node.func)
        if qn not in (f"{_PL}.load", f"{_PL}.store"):
            continue
        if len(node.args) < 2 or not isinstance(node.args[1], ast.Tuple):
            continue
        for el in node.args[1].elts:
            bad = isinstance(el, ast.Constant) and isinstance(el.value, int)
            bad = bad or (isinstance(el, ast.UnaryOp)
                          and isinstance(el.operand, ast.Constant)
                          and isinstance(el.operand.value, int))
            if bad:
                out.append(mi.finding(
                    "PAL001", el,
                    f"bare int `{ast.unparse(el)}` in a "
                    f"`{qn.rsplit('.', 1)[-1]}` index tuple",
                    "index unit dims with pl.dslice(i, 1) and squeeze "
                    "after the load (see flash_decode/kernel.py)"))
    return out


def rule_pal002(mi: ModuleInfo) -> list[Finding]:
    """Every ``pallas_call`` site must route its block sizes through a
    budget/planning helper (``egnn_edge.budget``-style) or carry an explicit
    ``# pallas: explicit-blocks`` override — unplanned tile sizes compile
    fine under the CPU interpreter and OOM VMEM on the first TPU run."""
    calls = [n for n in ast.walk(mi.tree)
             if isinstance(n, ast.Call)
             and mi.qualname(n.func) == f"{_PL}.pallas_call"]
    if not calls:
        return []
    if _PLANNING_RE.search(mi.src):
        return []
    out = []
    for node in calls:
        if mi.line_has_pragma(node.lineno, _EXPLICIT_BLOCKS_PRAGMA):
            continue
        out.append(mi.finding(
            "PAL002", node,
            "pallas_call with no block planning in the module — tile sizes "
            "never validated against a VMEM budget",
            "derive blocks via a plan/check helper (see "
            "repro.kernels.egnn_edge.budget) or annotate the call with "
            f"`# {_EXPLICIT_BLOCKS_PRAGMA}(<why the tiles are safe>)`"))
    return out


def rule_pal003(mi: ModuleInfo) -> list[Finding]:
    """Scratch accumulators must be f32: a bf16/f16 VMEM scratch used for
    cross-block reduction loses ~3 decimal digits per 1k accumulated terms
    (paper-shape E=768 edge blocks make that visible in gradients)."""
    out = []
    low = {f"{_JNP}.bfloat16", f"{_JNP}.float16"}
    for node in ast.walk(mi.tree):
        if not isinstance(node, ast.Call):
            continue
        if mi.qualname(node.func) != f"{_PL}.pallas_call":
            continue
        for kw in node.keywords:
            if kw.arg != "scratch_shapes":
                continue
            for sub in ast.walk(kw.value):
                if not (isinstance(sub, ast.Call)
                        and (mi.qualname(sub.func) or "").endswith(".VMEM")):
                    continue
                dtype_nodes = list(sub.args[1:2]) + \
                    [k.value for k in sub.keywords if k.arg == "dtype"]
                for dn in dtype_nodes:
                    if mi.qualname(dn) in low:
                        out.append(mi.finding(
                            "PAL003", dn,
                            f"VMEM scratch with dtype "
                            f"`{ast.unparse(dn)}` — reductions need an f32 "
                            "accumulator",
                            "accumulate in jnp.float32 scratch and cast on "
                            "the final flush (o_ref.dtype), as "
                            "segment_sum/_ss_kernel does"))
    return out


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Rule:
    id: str
    name: str
    doc: str
    fn: object

    def run(self, mi: ModuleInfo) -> list[Finding]:
        return self.fn(mi)


def _mk(id, name, fn):
    return Rule(id=id, name=name, doc=(fn.__doc__ or "").strip(), fn=fn)


RULES: list[Rule] = [
    _mk("TRC001", "trace-host-branch", rule_trc001),
    _mk("TRC002", "trace-host-sync", rule_trc002),
    _mk("TRC003", "hotloop-host-sync", rule_trc003),
    _mk("RCP001", "recompile-jit-in-loop", rule_rcp001),
    _mk("RCP002", "recompile-closure-array", rule_rcp002),
    _mk("RCP003", "recompile-array-static", rule_rcp003),
    _mk("DET001", "det-np-global-rng", rule_det001),
    _mk("DET002", "det-py-random", rule_det002),
    _mk("DET003", "det-wallclock", rule_det003),
    _mk("DON001", "donate-use-after", rule_don001),
    _mk("PAL001", "pallas-bare-int-index", rule_pal001),
    _mk("PAL002", "pallas-unplanned-blocks", rule_pal002),
    _mk("PAL003", "pallas-scratch-dtype", rule_pal003),
]


def rule_ids() -> list[str]:
    return [r.id for r in RULES]


_ALLOW_RE = re.compile(r"lint:\s*allow\(([A-Z0-9_,\s]+)\)")


def _inline_allowed(mi: ModuleInfo, f: Finding) -> bool:
    """``# lint: allow(RULEID): reason`` on the flagged line (or the line
    above) suppresses that rule there — for deliberate exceptions a
    baseline entry would misrepresent (e.g. one-jit-per-swept-config
    benchmark loops). DET*/PAL* findings cannot be inline-allowed: those
    must be fixed (same policy as ``baseline.NEVER_BASELINE``)."""
    if f.rule.startswith(("DET", "PAL")):
        return False
    for ln in (f.line, f.line - 1):
        m = _ALLOW_RE.search(mi.snippet(ln))
        if m and f.rule in {x.strip() for x in m.group(1).split(",")}:
            return True
    return False


def run_rules(path: str, src: str, *, rules=None) -> list[Finding]:
    """All findings for one module, deduplicated (nested AST walks can
    visit a node once per enclosing scope) and filtered through inline
    ``lint: allow(...)`` pragmas. ``rules``: optional filter by rule id or
    name."""
    mi = ModuleInfo(path, src)
    wanted = set(rules) if rules else None
    out: list[Finding] = []
    seen: set[tuple] = set()
    for rule in RULES:
        if wanted is not None and rule.id not in wanted \
                and rule.name not in wanted:
            continue
        for f in rule.run(mi):
            key = (f.rule, f.line, f.col)
            if key not in seen and not _inline_allowed(mi, f):
                seen.add(key)
                out.append(f)
    return out

"""Finding — one linter hit, with a drift-stable fingerprint.

A finding is keyed for baseline matching by ``(rule, path, fingerprint)``
where the fingerprint hashes the rule id, the *normalized source line text*
and an occurrence index among identical (rule, line-text) pairs in the same
file — NOT the line number. Inserting unrelated lines above a finding
therefore does not invalidate a baseline entry, while editing the flagged
line (presumably to fix it) does.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json


@dataclasses.dataclass(frozen=True)
class Finding:
    rule: str          # rule id, e.g. "DET003"
    path: str          # repo-relative posix path
    line: int          # 1-based line of the offending node
    col: int           # 0-based column
    message: str       # one-line statement of the defect
    hint: str          # fix recipe
    snippet: str       # stripped source line (fingerprint input)
    occurrence: int = 0  # index among identical (rule, snippet) in this file

    @property
    def fingerprint(self) -> str:
        norm = " ".join(self.snippet.split())
        raw = f"{self.rule}|{norm}|{self.occurrence}"
        return hashlib.sha1(raw.encode()).hexdigest()[:16]

    def format(self, *, show_hint: bool = True) -> str:
        out = f"{self.path}:{self.line}:{self.col + 1}: {self.rule} " \
              f"{self.message}"
        if show_hint and self.hint:
            out += f"\n    fix: {self.hint}"
        if self.snippet:
            out += f"\n    >>> {self.snippet}"
        return out

    def to_json(self) -> dict:
        d = dataclasses.asdict(self)
        d["fingerprint"] = self.fingerprint
        return d


def assign_occurrences(findings: list[Finding]) -> list[Finding]:
    """Disambiguate findings that share (path, rule, snippet) — e.g. the
    same offending expression repeated in a file — by a stable per-file
    occurrence index (source order)."""
    seen: dict[tuple, int] = {}
    out = []
    for f in sorted(findings, key=lambda f: (f.path, f.line, f.col, f.rule)):
        key = (f.path, f.rule, " ".join(f.snippet.split()))
        idx = seen.get(key, 0)
        seen[key] = idx + 1
        out.append(dataclasses.replace(f, occurrence=idx))
    return out


def dump_json(findings: list[Finding]) -> str:
    return json.dumps([f.to_json() for f in findings], indent=2)

"""The linter CLI: ``python -m repro.analysis.lint src benchmarks examples``.

Collects ``*.py`` under the given paths, runs every rule in
``repro.analysis.rules.RULES``, filters through the committed baseline
(``lint_baseline.json`` by default, when present) and exits non-zero when
NEW findings exist. Stdlib-only — no jax required, so the CI lint job is a
plain Python step.

Exit codes: 0 clean (or fully baselined), 1 new findings, 2 usage or
unparseable-source errors.
"""
from __future__ import annotations

import argparse
import pathlib
import sys

from .baseline import DEFAULT_NAME, Baseline, apply_baseline
from .findings import Finding, assign_occurrences, dump_json
from .rules import RULES, run_rules

EXCLUDED_PARTS = {"__pycache__", ".git", "fixtures"}


def collect_files(paths) -> list[pathlib.Path]:
    """``*.py`` files under the given files/dirs, sorted, minus caches and
    lint fixtures (fixtures are violations on purpose)."""
    out: set[pathlib.Path] = set()
    for p in paths:
        p = pathlib.Path(p)
        if p.is_file() and p.suffix == ".py":
            out.add(p)
        elif p.is_dir():
            for f in p.rglob("*.py"):
                if not EXCLUDED_PARTS & set(f.parts):
                    out.add(f)
        else:
            raise FileNotFoundError(f"lint path does not exist: {p}")
    return sorted(out)


def lint_paths(paths, *, rules=None, root: pathlib.Path | None = None):
    """Run the rule set over paths -> (findings, parse_errors). Paths in
    findings are relative to ``root`` (default: cwd) when possible, posix
    separators, so baselines are machine-independent."""
    root = pathlib.Path(root) if root is not None else pathlib.Path.cwd()
    findings: list[Finding] = []
    errors: list[str] = []
    for f in collect_files(paths):
        try:
            rel = f.resolve().relative_to(root.resolve())
        except ValueError:
            rel = f
        try:
            src = f.read_text()
            findings.extend(run_rules(rel.as_posix(), src, rules=rules))
        except SyntaxError as e:
            errors.append(f"{rel.as_posix()}:{e.lineno}: unparseable: "
                          f"{e.msg}")
    return assign_occurrences(findings), errors


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis.lint",
        description="repo-specific jax/Pallas static analysis "
                    "(docs/static_analysis.md)")
    ap.add_argument("paths", nargs="+", help="files or directories to lint")
    ap.add_argument("--baseline", default=None, metavar="FILE",
                    help=f"accepted-findings file (default: ./{DEFAULT_NAME} "
                         "when it exists)")
    ap.add_argument("--no-baseline", action="store_true",
                    help="ignore any baseline; every finding gates")
    ap.add_argument("--write-baseline", action="store_true",
                    help="record current findings as the new baseline "
                         "(refuses DET*/PAL* unless --allow-all)")
    ap.add_argument("--allow-all", action="store_true",
                    help="let --write-baseline record even fix-only "
                         "(DET*/PAL*) findings")
    ap.add_argument("--rules", default=None,
                    help="comma-separated rule ids/names to run")
    ap.add_argument("--format", choices=("text", "json"), default="text")
    ap.add_argument("--list-rules", action="store_true",
                    help="print the rule catalog and exit")
    args = ap.parse_args(argv)

    if args.list_rules:
        for r in RULES:
            first = r.doc.splitlines()[0] if r.doc else ""
            print(f"{r.id}  {r.name:<26} {first}")
        return 0

    rules = [s.strip() for s in args.rules.split(",")] if args.rules else None
    try:
        findings, errors = lint_paths(args.paths, rules=rules)
    except FileNotFoundError as e:
        print(f"error: {e}", file=sys.stderr)
        return 2
    for e in errors:
        print(f"error: {e}", file=sys.stderr)

    bl_path = pathlib.Path(args.baseline) if args.baseline else \
        pathlib.Path(DEFAULT_NAME)
    if args.write_baseline:
        Baseline.from_findings(findings, allow_all=args.allow_all) \
            .save(bl_path)
        print(f"wrote {len(findings)} finding(s) to {bl_path}")
        return 0

    baseline = None
    if not args.no_baseline and bl_path.exists():
        baseline = Baseline.load(bl_path)
    new, suppressed, stale = apply_baseline(findings, baseline)

    if args.format == "json":
        print(dump_json(new))
    else:
        for f in new:
            print(f.format())
        for e in stale:
            print(f"note: stale baseline entry {e['rule']} at {e['path']} "
                  f"(finding fixed?) — rewrite with --write-baseline")
        tail = f"{len(new)} finding(s)"
        if suppressed:
            tail += f", {len(suppressed)} baselined"
        if stale:
            tail += f", {len(stale)} stale baseline entr" + \
                ("y" if len(stale) == 1 else "ies")
        print(tail if new or suppressed or stale else "clean")
    if errors:
        return 2
    return 1 if new else 0


if __name__ == "__main__":
    sys.exit(main())

"""Bounded async request queue with per-request futures.

The serving sibling of ``repro.data.prefetch.Prefetcher``: one producer/
consumer handoff with a bounded ``queue.Queue``, the same responsive-put
discipline (short timeouts so shutdown never deadlocks a blocked caller)
and the same idempotent ``close()`` contract. The direction is reversed —
many caller threads produce *requests*, one engine worker consumes them —
so the per-item result channel is a ``concurrent.futures.Future`` resolved
by the worker after the batched forward.

Admission control happens here, at ``submit()`` time, not in the engine:
the request's real atom/edge counts (mask sums) are binned through
``BucketSpec.bucket_for`` immediately, so a structure that exceeds the
bucket grid's cap fails fast in the caller's thread with
``BucketOverflowError`` — it never occupies queue capacity, and the engine
only ever sees requests it has a compiled shape for.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
import time
from concurrent.futures import Future

import numpy as np

from repro.data.bucketing import BucketSpec

# the single-structure sample contract (unbatched; (A,)/(A,3)/(E,) arrays)
SAMPLE_KEYS = ("species", "pos", "edge_src", "edge_dst",
               "node_mask", "edge_mask")


class ServeClosedError(RuntimeError):
    """The queue/session is closed (shutdown, or the worker died). A
    RuntimeError whose message contains "closed", so callers matching the
    historical ``RuntimeError`` contract keep working."""


class DeadlineExceededError(RuntimeError):
    """The request's latency budget expired: either ``submit()`` could not
    find a queue slot within ``admission_timeout`` (raised in the caller's
    thread), or the request aged past ``max_queue_wait`` in the queue and
    the worker shed it (set on the request's future)."""


@dataclasses.dataclass
class Request:
    """One admitted property-prediction request.

    ``sample`` holds the validated single-structure arrays; ``bucket`` is
    the (A_pad, E_pad) bin assigned at admission; ``head`` names the
    per-source branch whose prediction was asked for. Timestamps (engine
    clock) drive the metrics stages: ``t_submit`` set here, ``t_dequeue`` /
    ``t_done`` stamped by the engine worker."""
    sample: dict
    head: int
    bucket: tuple
    n_atoms: int
    n_edges: int
    future: Future
    t_submit: float
    t_dequeue: float = 0.0
    t_done: float = 0.0
    # engine-clock instant after which the worker sheds this request
    # instead of computing it (None = no deadline)
    deadline: float | None = None


def _as_sample(sample: dict) -> tuple[dict, int, int]:
    """Validate + canonicalize one structure dict -> (sample, n_atoms,
    n_edges). Masks are derived when absent (species>0 / in-range edge
    endpoints), dtypes are normalized so every admitted sample hits the
    same compiled signature."""
    if "species" not in sample or "pos" not in sample:
        raise ValueError(f"sample needs at least species+pos; "
                         f"got keys {sorted(sample)}")
    species = np.asarray(sample["species"], np.int32)
    pos = np.asarray(sample["pos"], np.float32)
    if species.ndim != 1 or pos.shape != species.shape + (3,):
        raise ValueError(
            f"sample must be a SINGLE structure: species (A,), pos (A,3); "
            f"got species {species.shape}, pos {pos.shape}")
    A = species.shape[0]
    src = np.asarray(sample.get("edge_src", np.zeros(0)), np.int32)
    dst = np.asarray(sample.get("edge_dst", np.zeros(0)), np.int32)
    if src.shape != dst.shape or src.ndim != 1:
        raise ValueError(f"edge_src/edge_dst must be matching (E,) arrays; "
                         f"got {src.shape} vs {dst.shape}")
    nm = np.asarray(sample["node_mask"], bool) if "node_mask" in sample \
        else species > 0
    em = np.asarray(sample["edge_mask"], bool) if "edge_mask" in sample \
        else (src < A) & (dst < A)
    if nm.shape != species.shape or em.shape != src.shape:
        raise ValueError("mask shapes must match species/edge arrays")
    n_atoms, n_edges = int(nm.sum()), int(em.sum())
    # the repo-wide kernel contract: pad rows TRAILING. Enforced here so
    # batch assembly (which slices [:A_pad]) can never drop real content —
    # a scrambled sample is the CALLER's bug and fails in the caller's
    # thread, not the engine worker's
    if not (nm[:n_atoms].all() and em[:n_edges].all()):
        raise ValueError("sample masks must be front-packed "
                         "(real atoms/edges first, pad trailing)")
    out = {"species": species, "pos": pos, "edge_src": src, "edge_dst": dst,
           "node_mask": nm, "edge_mask": em}
    return out, n_atoms, n_edges


class RequestQueue:
    """Bounded admission queue feeding one engine worker.

    ``submit()`` is thread-safe and applies backpressure: when ``depth``
    requests are already queued it blocks (responsively — it keeps checking
    for shutdown) rather than growing without bound. ``close()`` stops
    admissions immediately and is an idempotent no-op on re-entry (the
    ``Prefetcher.close`` discipline); requests already queued stay queued so
    the engine can drain them."""

    def __init__(self, spec: BucketSpec, *, depth: int = 256,
                 n_heads: int = 1, clock=time.monotonic, metrics=None,
                 max_queue_wait: float | None = None,
                 admission_timeout: float | None = None):
        assert depth >= 1, f"queue depth must be >= 1, got {depth}"
        assert max_queue_wait is None or max_queue_wait > 0
        assert admission_timeout is None or admission_timeout > 0
        self.spec = spec
        self.n_heads = n_heads
        self._clock = clock
        self._metrics = metrics
        # per-request queue-wait budget (seconds): the worker sheds requests
        # that aged past it instead of computing stale answers under overload
        self.max_queue_wait = max_queue_wait
        # submit-side budget (seconds): bound how long a caller blocks on
        # backpressure before shedding in ITS thread
        self.admission_timeout = admission_timeout
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._closed = threading.Event()

    def submit(self, sample: dict, head: int = 0) -> Future:
        """Admit one structure for prediction by ``head``; returns a Future
        resolving to ``{"energy": float, "forces": (n_atoms, 3)}``.

        Raises ``BucketOverflowError`` (oversized structure), ``ValueError``
        (malformed sample / unknown head), ``ServeClosedError`` (queue
        closed) or ``DeadlineExceededError`` (no slot freed within
        ``admission_timeout``) — all in the caller's thread, before any
        queue slot is taken."""
        return self.put(self.make_request(sample, head)).future

    def make_request(self, sample: dict, head: int = 0) -> Request:
        """Validate + canonicalize + bin one structure into an admitted
        ``Request`` WITHOUT enqueuing it. Stamps ``t_submit``/``deadline``
        on the queue clock. The replica scheduler uses this to validate once
        in the caller's thread before routing the request to whichever
        replica's queue it picks (``put``)."""
        if self._closed.is_set():
            raise ServeClosedError("RequestQueue is closed")
        try:
            if not 0 <= head < self.n_heads:
                raise ValueError(f"head {head} out of range "
                                 f"(engine has {self.n_heads} heads)")
            canon, n_atoms, n_edges = _as_sample(sample)
            bucket = self.spec.bucket_for(n_atoms, n_edges)
        except ValueError:
            if self._metrics is not None:
                self._metrics.inc("rejected")
            raise
        t_submit = self._clock()
        return Request(sample=canon, head=head, bucket=bucket,
                       n_atoms=n_atoms, n_edges=n_edges, future=Future(),
                       t_submit=t_submit,
                       deadline=None if self.max_queue_wait is None
                       else t_submit + self.max_queue_wait)

    def put(self, req: Request) -> Request:
        """Enqueue an already-validated ``Request`` with backpressure.
        Raises ``ServeClosedError``/``DeadlineExceededError`` like
        ``submit``; admission-timeout is measured from ``req.t_submit`` so
        a rerouted request keeps its original budget."""
        while True:
            if self._closed.is_set():
                raise ServeClosedError("RequestQueue closed while waiting "
                                       "for a free slot")
            if self.admission_timeout is not None and \
                    self._clock() - req.t_submit > self.admission_timeout:
                if self._metrics is not None:
                    self._metrics.inc("shed_admission")
                raise DeadlineExceededError(
                    f"no queue slot freed within admission_timeout="
                    f"{self.admission_timeout}s — server is saturated")
            try:
                self._q.put(req, timeout=0.05)
                break
            except queue.Full:
                continue
        if self._metrics is not None:
            self._metrics.inc("submitted")
        return req

    def submit_many(self, samples, heads) -> list[Future]:
        """Vector ``submit``: heads may be one int for all samples or a
        per-sample sequence."""
        if isinstance(heads, (int, np.integer)):
            heads = [int(heads)] * len(samples)
        if len(heads) != len(samples):
            raise ValueError(f"{len(samples)} samples vs {len(heads)} heads")
        return [self.submit(s, h) for s, h in zip(samples, heads)]

    # -- consumer side (engine worker) --------------------------------------

    def get(self, timeout: float | None = None) -> Request | None:
        """Next queued request, or None on timeout."""
        try:
            return self._q.get(timeout=timeout)
        except queue.Empty:
            return None

    def drain(self) -> list[Request]:
        """Everything currently queued, without blocking."""
        out = []
        while True:
            try:
                out.append(self._q.get_nowait())
            except queue.Empty:
                return out

    def __len__(self) -> int:
        return self._q.qsize()

    # -- shutdown -----------------------------------------------------------

    @property
    def closed(self) -> bool:
        return self._closed.is_set()

    def close(self):
        """Stop admissions. Idempotent no-op on re-entry; already-queued
        requests remain for the engine to drain."""
        self._closed.set()

"""repro.serve — high-throughput property-prediction serving.

The inference-side counterpart of ``repro.engine``: a trained multi-head
GNN (any ``{"shared", "heads"}`` parameter tree) behind an async request
queue with continuous size-binned batching. Requests of similar atom/edge
counts coalesce — via the SAME ``BucketSpec`` grid training batches with —
into one padded batch per bucket, executed by a per-(bucket, head) compiled
cache whose recompile budget is the bucket grid. See docs/serving.md.

    from repro.serve import ServeSession
    with ServeSession(params, arch, spec=spec) as srv:
        fut = srv.submit({"species": z, "pos": x, ...}, head=2)
        print(fut.result()["energy"])

Scale-out (docs/serving.md#scaling-out): ``ServeSession(mesh=...)`` shards
each batch's rows over a device mesh; ``ReplicaServeSession`` runs one
engine per device behind a least-loaded ``ReplicaScheduler``; adaptive
release knobs via ``ServeSession(adaptive=True)`` / ``AdaptivePolicy``.
"""
from .batching import AdaptivePolicy, AssembledBatch, SizeBinnedBatcher, assemble
from .engine import ServeSession
from .metrics import Reservoir, ServeMetrics
from .queue import (
    DeadlineExceededError,
    Request,
    RequestQueue,
    ServeClosedError,
)
from .scaleout import ReplicaScheduler, ReplicaServeSession

__all__ = [
    "AdaptivePolicy", "AssembledBatch", "DeadlineExceededError", "Request",
    "ReplicaScheduler", "ReplicaServeSession", "RequestQueue", "Reservoir",
    "ServeClosedError", "ServeMetrics", "ServeSession", "SizeBinnedBatcher",
    "assemble",
]

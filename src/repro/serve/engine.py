"""ServeSession — the request-serving engine for trained multi-head GNNs.

Turns a trained ``{"shared", "heads"}`` parameter tree (the MultiTaskModel
layout every ``repro.engine`` training session produces) into a
property-prediction server:

  caller threads ── submit(sample, head) ──► RequestQueue (bounded, admits
                                             via BucketSpec.bucket_for)
                                                 │
                               worker thread ────┤ SizeBinnedBatcher
                                                 │   coalesce per (bucket,
                                                 │   head); flush on full
                                                 │   batch or max_wait
                                                 ▼
                          compiled forward (jit egnn_apply + branch_apply)
                                                 │
                     scatter rows back to request futures + ServeMetrics

The executable cache is keyed per (bucket-shape, head): every (bucket,
head) pair binds the head's parameter slice to ONE shared jitted forward,
so XLA compiles at most one variant per bucket shape — head slices have
identical shapes/dtypes and hit the jit cache. The recompile budget is
therefore the bucket grid, exactly as in training (``len(atom_buckets) x
len(edge_buckets)`` compilations, <= grid x n_heads cache entries;
asserted by tests/test_serve_engine.py). A multi-device session is one
more PLAN, not more shapes: ``mesh=`` shards the batched forward's rows
data-parallel over a 1-axis serving mesh (params replicated, the
``configs.sharding.serve_batch_spec`` rule), so the budget generalizes to
``distinct bucket shapes x plans`` — see ``repro.serve.scaleout`` for the
replica-per-device mode on top.

The time base is ONE injected ``clock`` (default ``time.monotonic``)
threaded through queue, batcher, and metrics: ``t_submit``/``deadline``/
``next_deadline`` arithmetic never mixes clock bases (perf_counter vs
monotonic skew is unbounded across hosts/suspends).

Shutdown follows the ``Prefetcher`` discipline: ``close()`` stops
admissions, drains everything already queued or binned through the compiled
path (every accepted future resolves), joins the worker, and is an
idempotent no-op on re-entry.
"""
from __future__ import annotations

import threading
import time

import jax
import numpy as np

from repro.data.bucketing import BucketSpec
from repro.models import gnn, heads as heads_mod

from .batching import AdaptivePolicy, AssembledBatch, SizeBinnedBatcher
from .metrics import ServeMetrics
from .queue import DeadlineExceededError, RequestQueue, ServeClosedError

# head-parameter keys that are training-only (loss weighting), never part
# of the serving forward
_NON_FORWARD_HEAD_KEYS = ("log_sigma2",)


def _head_slices(head_params, n_heads: int) -> list:
    """Stacked (n_heads, ...) head tree -> per-head parameter trees with
    training-only leaves dropped."""
    fwd = {k: v for k, v in head_params.items()
           if k not in _NON_FORWARD_HEAD_KEYS}
    return [jax.tree_util.tree_map(lambda v: v[t], fwd)
            for t in range(n_heads)]


class ServeSession:
    """High-throughput property-prediction serving for one trained model.

    params: ``{"shared": egnn params, "heads": stacked branch params}``
        (leading head/task dim on every heads leaf).
    arch:   the ``ArchConfig`` the params were trained with.
    spec:   the ``BucketSpec`` coalescing grid; None = one bucket at
        (arch.max_atoms, arch.max_edges) — correct but pays worst-case pad.
    max_batch:    rows per compiled batch (static leading dim).
    max_wait_ms:  partial-batch flush deadline (tail-latency bound).
    queue_depth:  admission backpressure bound.
    max_queue_wait_ms: per-request queue-wait budget — a request that aged
        past it is SHED (its future fails with ``DeadlineExceededError``)
        instead of computed, so overload degrades by dropping stale work
        rather than serving every request late. None = never shed.
    admission_timeout_ms: bound on how long ``submit()`` blocks on
        backpressure before raising ``DeadlineExceededError`` in the
        caller's thread. None = block until a slot frees.
    mesh: optional 1-axis serving mesh (``make_replica_meshes`` /
        ``make_group_meshes``): the batched forward's rows are sharded
        data-parallel over its devices with params replicated
        (``serve_batch_spec``); ``max_batch`` must tile evenly. None keeps
        the single-device plan. Row results stay BITWISE equal either way —
        the forward is per-row independent, sharding only moves rows.
    adaptive: adapt the release knobs per (bucket, head) from measured
        arrival rate/occupancy (``AdaptivePolicy``) instead of serving the
        fixed ``max_batch``/``max_wait_ms`` knee. Padded shapes (and so the
        compile budget) are unchanged.
    clock: the session's single time base (monotonic-like callable),
        threaded through queue, batcher, and metrics.
    """

    def __init__(self, params: dict, arch, *, spec: BucketSpec | None = None,
                 max_batch: int = 8, max_wait_ms: float = 5.0,
                 queue_depth: int = 256,
                 max_queue_wait_ms: float | None = None,
                 admission_timeout_ms: float | None = None,
                 mesh=None, adaptive: bool = False,
                 metrics: ServeMetrics | None = None,
                 clock=time.monotonic, seed: int = 0):
        if not (isinstance(params, dict) and
                {"shared", "heads"} <= set(params)):
            raise ValueError('params must be the MultiTaskModel layout '
                             '{"shared": ..., "heads": ...}')
        leaves = jax.tree_util.tree_leaves(
            {k: v for k, v in params["heads"].items()
             if k not in _NON_FORWARD_HEAD_KEYS})
        n_heads = int(leaves[0].shape[0])
        assert all(int(l.shape[0]) == n_heads for l in leaves), \
            "heads leaves disagree on the leading head dim"
        if spec is None:
            assert arch.max_atoms > 0 and arch.max_edges > 0, \
                "spec=None needs arch.max_atoms/max_edges to form a bucket"
            spec = BucketSpec((arch.max_atoms,), (arch.max_edges,))
        self.arch = arch
        self.spec = spec
        self.n_heads = n_heads
        self.max_batch = max_batch
        self.mesh = mesh
        self._clock = clock
        self._shared = params["shared"]
        self._heads = _head_slices(params["heads"], n_heads)
        self.metrics = metrics if metrics is not None else \
            ServeMetrics(seed=seed, clock=clock)
        # retained so restart_worker() can rebuild the queue/batcher pair
        self._queue_depth = queue_depth
        self._max_queue_wait = None if max_queue_wait_ms is None \
            else max_queue_wait_ms * 1e-3
        self._admission_timeout = None if admission_timeout_ms is None \
            else admission_timeout_ms * 1e-3
        self._max_wait = max_wait_ms * 1e-3
        # the policy is measurement state (like the jit cache): it survives
        # restart_worker(), only the batcher it advises is rebuilt
        self._policy = AdaptivePolicy(max_batch=max_batch,
                                      max_wait=self._max_wait) \
            if adaptive else None
        self.queue = self._make_queue()
        self.batcher = self._make_batcher()

        def forward(shared, head, batch):
            feats = gnn.egnn_apply(shared, batch, cfg=arch)
            return heads_mod.branch_apply(head, feats, batch["node_mask"],
                                          cfg=arch)

        # ONE jitted callable shared by every (bucket, head) cache entry:
        # head slices are shape/dtype-identical, so only a new BUCKET shape
        # actually compiles
        if mesh is None:
            self.plan_devices = 1
            self._predict = jax.jit(forward)
        else:
            self.plan_devices = int(np.prod(list(mesh.shape.values())))
            self._predict = self._sharded_predict(forward, mesh)
        self._exec: dict[tuple, object] = {}   # (bucket, head) -> callable
        self._shapes_compiled: set = set()
        self._closed = False
        self._worker_error: BaseException | None = None
        # requests dequeued but not yet filed into the batcher: on a worker
        # crash these are in NEITHER the queue nor the batcher, so the
        # fail-fast handler must fail their futures from here
        self._inflight: list = []
        self._closing = threading.Event()
        self._worker = threading.Thread(target=self._serve_loop,
                                        name="serve-worker", daemon=True)
        self._worker.start()

    def _make_queue(self) -> RequestQueue:
        return RequestQueue(self.spec, depth=self._queue_depth,
                            n_heads=self.n_heads, clock=self._clock,
                            metrics=self.metrics,
                            max_queue_wait=self._max_queue_wait,
                            admission_timeout=self._admission_timeout)

    def _make_batcher(self) -> SizeBinnedBatcher:
        return SizeBinnedBatcher(max_batch=self.max_batch,
                                 max_wait=self._max_wait,
                                 clock=self._clock, policy=self._policy)

    def _sharded_predict(self, forward, mesh):
        """jit the forward with rows data-parallel over the serving mesh and
        params replicated. Params are committed to the mesh once so every
        call reuses the on-device copies (no per-batch host transfer)."""
        from jax.sharding import NamedSharding, PartitionSpec as P

        from repro.configs.sharding import serve_batch_spec, tree_shardings

        ndev = self.plan_devices
        if self.max_batch % ndev != 0:
            raise ValueError(
                f"max_batch={self.max_batch} must tile evenly over the "
                f"{ndev}-device serving mesh (rows are data-parallel)")
        replicated = lambda path, leaf: P(*([None] * np.ndim(leaf)))  # noqa: E731
        shared_sh = tree_shardings(mesh, self._shared, replicated)
        head_sh = tree_shardings(mesh, self._heads[0], replicated)
        self._shared = jax.device_put(self._shared, shared_sh)
        self._heads = [jax.device_put(h, head_sh) for h in self._heads]
        # assembled-batch leaves are (max_batch, ...); ndim is fixed per key
        ndims = {"species": 2, "pos": 3, "edge_src": 2, "edge_dst": 2,
                 "node_mask": 2, "edge_mask": 2}
        batch_sh = {
            k: NamedSharding(mesh, serve_batch_spec(
                np.zeros((self.max_batch,) + (1,) * (nd - 1)), ndev))
            for k, nd in ndims.items()}
        out_sh = NamedSharding(mesh, P())   # tiny outputs: gather to all
        return jax.jit(forward, in_shardings=(shared_sh, head_sh, batch_sh),
                       out_shardings=out_sh)

    # -- construction helpers -----------------------------------------------

    @classmethod
    def from_checkpoint(cls, path: str, arch, *, model: str = "gfm-mtl",
                        n_heads: int | None = None, **kw) -> "ServeSession":
        """Load params written by ``Session``/``checkpoint.save`` (the
        ``{"params": ...}`` tree) and serve them. The template comes from
        the registry model's ``init`` under ``jax.eval_shape`` — zero
        allocation, restored leaves land as the checkpoint's values."""
        from repro.engine.registry import build_model
        from repro.train import checkpoint
        built = build_model(model, arch,
                            n_tasks=n_heads or arch.n_tasks or None)
        template = jax.eval_shape(built.init, jax.random.PRNGKey(0))
        params = checkpoint.restore(path, {"params": template})["params"]
        return cls(params, arch, **kw)

    # -- public API ----------------------------------------------------------

    def submit(self, sample: dict, head: int = 0):
        """Admit one structure; returns a Future resolving to
        ``{"energy": float, "forces": (n_atoms, 3) float32}``."""
        self._check_alive()
        return self.queue.submit(sample, head)

    def submit_many(self, samples, heads=0) -> list:
        self._check_alive()
        return self.queue.submit_many(samples, heads)

    def predict_one(self, sample: dict, head: int = 0) -> dict:
        """Synchronous single-request forward through the SAME executable a
        batched run uses (one real row, ``max_batch - 1`` inert pad rows) —
        the parity reference for the batched-and-scattered path, and a
        convenience for offline use. Bypasses the queue/worker."""
        from .queue import Request, _as_sample
        canon, n_atoms, n_edges = _as_sample(sample)
        bucket = self.spec.bucket_for(n_atoms, n_edges)
        req = Request(sample=canon, head=head, bucket=bucket,
                      n_atoms=n_atoms, n_edges=n_edges, future=None,
                      t_submit=self._clock())
        from .batching import assemble
        ab = assemble([req], bucket, self.max_batch)
        e, f = self._executable(bucket, head)(ab.batch)
        e, f = np.asarray(e), np.asarray(f)
        return {"energy": float(e[0]), "forces": f[0, :n_atoms]}

    def warmup(self, buckets=None) -> int:
        """Pre-compile executables (head 0) for the given buckets (default:
        the full grid) so first requests don't pay compile latency. Returns
        the number of compiled shapes afterwards."""
        if buckets is None:
            buckets = [(a, e) for a in self.spec.atom_buckets
                       for e in self.spec.edge_buckets]
        for bucket in buckets:
            a_pad, e_pad = bucket
            dummy = {"species": np.zeros((self.max_batch, a_pad), np.int32),
                     "pos": np.zeros((self.max_batch, a_pad, 3), np.float32),
                     "edge_src": np.full((self.max_batch, e_pad), a_pad,
                                         np.int32),
                     "edge_dst": np.full((self.max_batch, e_pad), a_pad,
                                         np.int32),
                     "node_mask": np.zeros((self.max_batch, a_pad), bool),
                     "edge_mask": np.zeros((self.max_batch, e_pad), bool)}
            e, f = self._executable(bucket, 0)(dummy)
            jax.block_until_ready((e, f))
        return len(self._shapes_compiled)

    def jit_functions(self):
        """The session's jitted callables — the probe seam for
        ``repro.analysis.RecompileSanitizer`` (tracks ``_predict``'s cache
        the same way ``tests/test_serve_engine.py`` asserts on it)."""
        return (self._predict,)

    def stats(self) -> dict:
        """Metrics snapshot + executable-cache occupancy (plain dict)."""
        out = self.metrics.snapshot()
        out["executable_cache"] = {
            "entries": len(self._exec),
            "compiled_shapes": len(self._shapes_compiled),
            "budget": self.spec.n_shapes * self.n_heads,
            # one plan (single jit cache) regardless of mesh width: XLA
            # compiles per distinct bucket shape, heads share the executable
            "compile_budget": self.spec.n_shapes,
        }
        out["plan"] = {"mode": "sharded" if self.plan_devices > 1
                       else "single", "devices": self.plan_devices}
        if self._policy is not None:
            out["adaptive"] = self._policy.snapshot()
        return out

    def close(self):
        """Graceful shutdown: stop admissions, drain every queued/binned
        request through the compiled path (all accepted futures resolve),
        join the worker. Idempotent no-op on re-entry."""
        if self._closed:
            return
        self._closed = True
        self.queue.close()
        self._closing.set()
        self._worker.join(timeout=60.0)
        if self._worker.is_alive():
            raise RuntimeError("serve worker did not drain within 60s")

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
        return False

    # -- worker ---------------------------------------------------------------

    def _check_alive(self):
        if self._closed:
            raise ServeClosedError("ServeSession is closed")
        if self._worker_error is not None:
            raise ServeClosedError(
                "serve worker died — session is closed to new work "
                "(restart_worker() recovers it)") from self._worker_error

    def _executable(self, bucket: tuple, head: int):
        """The per-(bucket, head) cache entry: the shared jitted forward
        with this head's parameter slice bound. Counts a compilation only
        when the bucket SHAPE is new — same-shape entries for other heads
        reuse the compiled executable."""
        key = (bucket, head)
        fn = self._exec.get(key)
        if fn is None:
            if bucket not in self._shapes_compiled:
                self._shapes_compiled.add(bucket)
                self.metrics.inc("compilations")
            hp = self._heads[head]
            shared = self._shared

            def fn(batch, _p=self._predict, _s=shared, _h=hp):
                return _p(_s, _h, batch)

            self._exec[key] = fn
        return fn

    def _execute(self, ab: AssembledBatch):
        """Run one assembled batch and scatter rows to futures."""
        t0 = self._clock()
        try:
            e, f = self._executable(ab.bucket, ab.head)(ab.batch)
            e, f = np.asarray(e), np.asarray(f)   # blocks until ready
        except BaseException as err:
            for r in ab.requests:
                r.future.set_exception(err)
            self.metrics.inc("failed", len(ab.requests))
            return
        t1 = self._clock()
        self.metrics.observe("compute", t1 - t0)
        self.metrics.inc("batches")
        self.metrics.inc("batch_slots", self.max_batch)
        self.metrics.inc("batch_real", ab.n_real)
        for i, r in enumerate(ab.requests):
            r.t_done = self._clock()
            r.future.set_result(
                {"energy": float(e[i]), "forces": f[i, :r.n_atoms]})
            self.metrics.observe("e2e", r.t_done - r.t_submit)
        self.metrics.inc("completed", ab.n_real)

    def _file(self, req) -> AssembledBatch | None:
        req.t_dequeue = self._clock()
        self.metrics.observe("queue_wait", req.t_dequeue - req.t_submit)
        if req.deadline is not None and req.t_dequeue > req.deadline:
            # stale request: under overload, computing it would only delay
            # every request behind it — shed instead (load shedding)
            req.future.set_exception(DeadlineExceededError(
                f"request waited {req.t_dequeue - req.t_submit:.3f}s in "
                f"queue, past its max_queue_wait deadline"))
            self.metrics.inc("shed_deadline")
            return None
        t0 = self._clock()
        ab = self.batcher.add(req)
        if ab is not None:
            self.metrics.observe("assembly", self._clock() - t0)
        return ab

    def _serve_loop(self):
        try:
            while not self._closing.is_set():
                now = self._clock()
                deadline = self.batcher.next_deadline(now)
                # poll timeout: wake for the earliest bin deadline, else a
                # coarse tick so close() is observed promptly
                timeout = 0.05 if deadline is None \
                    else min(max(deadline, 0.0), 0.05)
                req = self.queue.get(timeout=timeout)
                if req is not None:
                    # greedy drain: file the WHOLE backlog before computing.
                    # Under load, dequeued requests are usually already past
                    # their deadline (they aged in the queue), so filing one
                    # at a time would flush every bin one-deep; filing the
                    # backlog first lets bins reach max_batch occupancy.
                    self._inflight = [req] + self.queue.drain()
                    ready = []
                    while self._inflight:
                        ab = self._file(self._inflight[0])
                        self._inflight.pop(0)
                        if ab is not None:
                            ready.append(ab)
                    for ab in ready:
                        self._execute(ab)
                t0 = self._clock()
                expired = self.batcher.expired(self._clock())
                if expired:
                    dt = (self._clock() - t0) / len(expired)
                    for ab in expired:
                        self.metrics.observe("assembly", dt)
                        self._execute(ab)
            # graceful drain: admissions are closed, so the queue can only
            # shrink — run everything left through the compiled path
            for req in self.queue.drain():
                ab = self._file(req)
                if ab is not None:
                    self._execute(ab)
            for ab in self.batcher.flush():
                self._execute(ab)
        except BaseException as err:   # fail loudly, never hang futures
            self._worker_error = err
            # close admissions FIRST: a submit racing the drain below would
            # otherwise enqueue a request nobody will ever serve
            self.queue.close()
            self.metrics.inc("worker_failures")
            pending = (self._inflight + self.queue.drain() +
                       self.batcher.pending_requests())
            self._inflight = []
            for req in pending:
                req.future.set_exception(err)
            self.metrics.inc("failed", len(pending))

    # -- recovery -------------------------------------------------------------

    def restart_worker(self) -> bool:
        """Recover from a dead worker: clear the fail-fast state and stand
        up a fresh queue + batcher + worker thread. Compiled executables are
        retained, so recovery costs no recompilation. The crashed worker's
        pending futures were already failed — nothing is replayed. Returns
        True if a restart happened (False: worker was healthy)."""
        if self._closed:
            raise ServeClosedError("ServeSession is closed")
        if self._worker_error is None and self._worker.is_alive():
            return False
        self._worker.join(timeout=5.0)
        self._worker_error = None
        self._inflight = []
        self.queue = self._make_queue()
        self.batcher = self._make_batcher()
        self._closing = threading.Event()
        self._worker = threading.Thread(target=self._serve_loop,
                                        name="serve-worker", daemon=True)
        self._worker.start()
        self.metrics.inc("worker_restarts")
        return True

"""In-process serving metrics: counters + staged latency histograms.

No external metrics stack in the container, so this is the plain-dict
analogue of a Prometheus client: thread-safe counters and per-stage latency
reservoirs, snapshotted by benchmarks (``benchmarks/bench_serve.py``),
tests, and callers that want to scrape.

The request lifecycle is instrumented at four stages (docs/serving.md has
the lifecycle diagram):

  * ``queue_wait`` — submit() to the worker dequeuing the request;
  * ``assembly``   — host-side pad-and-stack of a bucket batch;
  * ``compute``    — the compiled forward, blocked until ready;
  * ``e2e``        — submit() to the request future resolving.

Percentiles come from a **deterministic reservoir**: fixed capacity,
Vitter's algorithm R driven by a seeded ``np.random.default_rng`` — two
runs over the same observation stream produce the same reservoir, so
benchmark JSON and test assertions are reproducible (no wall-clock or
global-RNG coupling). Up to ``capacity`` observations the reservoir is
exact; beyond it, a uniform sample.
"""
from __future__ import annotations

import threading
import time

import numpy as np

STAGES = ("queue_wait", "assembly", "compute", "e2e")


class Reservoir:
    """Deterministic fixed-size uniform sample of a float stream."""

    def __init__(self, capacity: int = 4096, seed: int = 0):
        assert capacity >= 1
        self.capacity = capacity
        self._rng = np.random.default_rng(seed)
        self._buf: list[float] = []
        self.count = 0          # observations offered (not just retained)
        self.total = 0.0
        self.max = 0.0

    def add(self, x: float):
        x = float(x)
        self.count += 1
        self.total += x
        self.max = max(self.max, x)
        if len(self._buf) < self.capacity:
            self._buf.append(x)
        else:
            # algorithm R: keep slot j with probability capacity/count
            j = int(self._rng.integers(0, self.count))
            if j < self.capacity:
                self._buf[j] = x

    def percentiles(self, qs=(50, 95, 99)) -> dict:
        if not self._buf:
            return {f"p{q}": 0.0 for q in qs}
        arr = np.asarray(self._buf)
        return {f"p{q}": float(np.percentile(arr, q)) for q in qs}

    def summary(self) -> dict:
        out = self.percentiles()
        out.update(count=self.count, max=self.max,
                   mean=self.total / self.count if self.count else 0.0)
        return out


class ServeMetrics:
    """Counters + per-stage latency reservoirs for one ``ServeSession``.

    Counter vocabulary (all monotonic):
      submitted / completed / failed / rejected — request outcomes
      shed_admission / shed_deadline            — load shedding (no queue
                                                  slot in time / aged past
                                                  the queue-wait budget)
      worker_failures / worker_restarts         — engine-worker crashes and
                                                  restart_worker() recoveries
      batches                                   — compiled executions run
      batch_slots / batch_real                  — padded vs occupied rows
      compilations                              — distinct compiled shapes
      routed / failovers                        — replica-scheduler decisions
                                                  (multi-device mode only)
    ``snapshot()`` returns a plain nested dict (JSON-serializable) with
    latencies in **milliseconds**.

    ``clock`` is the ONE serve time base (engine/queue/batcher share it, see
    docs/serving.md): rates in ``snapshot()`` are measured against it only —
    never mixed with another base.
    """

    def __init__(self, *, reservoir_capacity: int = 4096, seed: int = 0,
                 clock=time.monotonic):
        self._lock = threading.Lock()
        self._clock = clock
        self._t0 = clock()
        self.counters: dict[str, int] = {
            k: 0 for k in ("submitted", "completed", "failed", "rejected",
                           "shed_admission", "shed_deadline",
                           "worker_failures", "worker_restarts",
                           "batches", "batch_slots", "batch_real",
                           "compilations", "routed", "failovers")}
        # one seed per stage, derived deterministically from the base seed
        self.stages = {name: Reservoir(reservoir_capacity, seed=seed + i)
                       for i, name in enumerate(STAGES)}

    def inc(self, name: str, n: int = 1):
        with self._lock:
            self.counters[name] = self.counters.get(name, 0) + n

    def observe(self, stage: str, seconds: float):
        with self._lock:
            self.stages[stage].add(seconds * 1e3)   # stored as ms

    def snapshot(self) -> dict:
        with self._lock:
            counters = dict(self.counters)
            lat = {name: {f"{k}_ms" if k in ("p50", "p95", "p99", "max",
                                             "mean") else k: v
                          for k, v in r.summary().items()}
                   for name, r in self.stages.items()}
            elapsed = self._clock() - self._t0
        occ = (counters["batch_real"] / counters["batch_slots"]
               if counters["batch_slots"] else 0.0)
        # rates against the injected clock ONLY (same base as t_submit /
        # deadlines) — cross-base arithmetic is exactly the skew this
        # module's clock injection exists to rule out
        rates = {"elapsed_s": elapsed}
        if elapsed > 0:
            rates["submitted_per_s"] = counters["submitted"] / elapsed
            rates["completed_per_s"] = counters["completed"] / elapsed
        return {"counters": counters, "latency": lat,
                "batch_occupancy": occ, "rates": rates}

"""Continuous size-binned request batching.

Training already solved the padding-waste-vs-recompile tradeoff with
``BucketSpec`` (quantized pad-shape grids, ``repro.data.bucketing``); at
serving time the SAME grid becomes the coalescing rule: requests whose
(atom, edge) counts land in the same bucket are padded to one shared shape
and run as one batch, so the compiled-shape universe of the serving engine
is exactly the training bucket grid.

"Continuous" in the vLLM sense, adapted to fixed-shape XLA executables: the
binner never waits for an epoch or a fixed batch — as requests stream in it
holds at most one open bin per (bucket, head) and releases it the moment it
is **full** (``max_batch`` requests) or **expired** (its oldest request has
waited ``max_wait``). The deadline bounds tail latency under low arrival
rates: a lone request costs at most ``max_wait`` + one forward, it never
waits for a full batch that will not come.

The released batch is padded to a STATIC shape (``max_batch`` rows at the
bucket's (A_pad, E_pad)) with inert rows — all-pad structures whose node
masks are empty and whose edges point at the ``A_pad`` sentinel (the
``>= n_nodes`` kernel contract, see docs/kernels.md) — so partial flushes
reuse the full batch's executable instead of compiling a (k, ...) variant
per occupancy k.
"""
from __future__ import annotations

import dataclasses
import time

import numpy as np

from .queue import Request


@dataclasses.dataclass
class AssembledBatch:
    """One ready-to-run padded batch: ``batch`` is the (max_batch, A_pad,
    ...) dict the compiled forward takes; ``requests`` (length ``n_real``
    <= max_batch) maps row i back to the future to resolve."""
    batch: dict
    requests: list[Request]
    bucket: tuple
    head: int

    @property
    def n_real(self) -> int:
        return len(self.requests)


def assemble(requests: list[Request], bucket: tuple,
             max_batch: int) -> AssembledBatch:
    """Pad-and-stack admitted requests into one (max_batch, A_pad/E_pad)
    batch. Every request must already be binned into ``bucket`` (admission
    guarantees content fits); rows beyond ``len(requests)`` are inert pad
    structures. Edge endpoints of masked/pad edges are re-pointed at the
    ``A_pad`` sentinel — same contract as ``BucketingBatcher``."""
    assert 1 <= len(requests) <= max_batch, (len(requests), max_batch)
    a_pad, e_pad = bucket
    head = requests[0].head
    B = max_batch
    species = np.zeros((B, a_pad), np.int32)
    pos = np.zeros((B, a_pad, 3), np.float32)
    src = np.full((B, e_pad), a_pad, np.int32)
    dst = np.full((B, e_pad), a_pad, np.int32)
    nmask = np.zeros((B, a_pad), bool)
    emask = np.zeros((B, e_pad), bool)
    for i, r in enumerate(requests):
        assert r.bucket == bucket and r.head == head, \
            "batcher invariant: one (bucket, head) per assembled batch"
        s = r.sample
        nm, em = s["node_mask"], s["edge_mask"]
        # stored arrays may be longer than the bucket (a small structure
        # submitted in a big padded container): admission checked CONTENT
        # fits, so trailing storage beyond A_pad/E_pad is pad by contract
        na = min(nm.shape[0], a_pad)
        ne = min(em.shape[0], e_pad)
        # admission enforces front-packed masks and bucket_for sized the
        # bucket to the content, so the tail beyond the bucket is pure pad
        assert not (nm[na:].any() or em[ne:].any()), \
            "assemble invariant: real content beyond the assigned bucket"
        species[i, :na] = np.where(nm[:na], s["species"][:na], 0)
        pos[i, :na] = np.where(nm[:na, None], s["pos"][:na], 0.0)
        nmask[i, :na] = nm[:na]
        emask[i, :ne] = em[:ne]
        src[i, :ne] = np.where(em[:ne], s["edge_src"][:ne], a_pad)
        dst[i, :ne] = np.where(em[:ne], s["edge_dst"][:ne], a_pad)
    return AssembledBatch(
        batch={"species": species, "pos": pos, "edge_src": src,
               "edge_dst": dst, "node_mask": nmask, "edge_mask": emask},
        requests=list(requests), bucket=bucket, head=head)


class AdaptivePolicy:
    """Move the (max_batch, max_wait) knee per (bucket, head) bin from the
    measured arrival rate instead of serving fixed knobs.

    The PR 6 bench showed the knee shifts with model size and load, so a
    static (max_batch, max_wait) is only right at one operating point. The
    policy keeps, per bin key, an EWMA of the inter-arrival gap (from
    ``t_submit`` stamps — the shared engine clock) and of released-bin
    occupancy, and derives:

      * ``target_rows(key)`` — how many rows are worth waiting for: the
        arrivals expected inside the base window (capped at ``max_batch``).
        Under saturating load this is ``max_batch``; at low rates it decays
        to 1 so lone requests release immediately.
      * ``wait(key)`` — how long the oldest request may wait: just long
        enough for ``target_rows`` arrivals (``(rows-1) * gap``), floored at
        ``min_wait`` and capped at the configured ``max_wait``.

    Only RELEASE timing adapts — the assembled batch is always padded to the
    static ``max_batch`` rows, so the compiled-shape universe (and the
    compile budget) is untouched. All inputs come through injected clocks/
    stamps: under a fake clock the policy is fully deterministic.
    """

    def __init__(self, *, max_batch: int, max_wait: float,
                 min_wait: float = 2e-4, alpha: float = 0.2):
        assert max_batch >= 1 and max_wait >= 0.0
        assert 0.0 <= min_wait <= max(max_wait, min_wait)
        assert 0.0 < alpha <= 1.0
        self.max_batch = max_batch
        self.base_wait = max_wait
        self.min_wait = min(min_wait, max_wait) if max_wait > 0 else 0.0
        self.alpha = alpha
        self._gap: dict[tuple, float] = {}    # key -> EWMA inter-arrival (s)
        self._last: dict[tuple, float] = {}   # key -> last arrival stamp
        self._occ: dict[tuple, float] = {}    # key -> EWMA released rows

    def observe_arrival(self, key: tuple, t: float):
        last = self._last.get(key)
        self._last[key] = t
        if last is None:
            return
        gap = max(t - last, 1e-9)
        g = self._gap.get(key)
        self._gap[key] = gap if g is None \
            else (1.0 - self.alpha) * g + self.alpha * gap

    def observe_release(self, key: tuple, occupancy: int):
        o = self._occ.get(key)
        self._occ[key] = float(occupancy) if o is None \
            else (1.0 - self.alpha) * o + self.alpha * occupancy

    def target_rows(self, key: tuple) -> int:
        g = self._gap.get(key)
        if g is None:                 # no rate estimate yet: be patient
            return self.max_batch
        expect = int(self.base_wait / g) + 1
        return max(1, min(self.max_batch, expect))

    def wait(self, key: tuple) -> float:
        g = self._gap.get(key)
        if g is None:
            return self.base_wait
        if g > self.base_wait:        # nothing else is coming in the window
            return self.min_wait
        return min(self.base_wait,
                   max((self.target_rows(key) - 1) * g, self.min_wait))

    def snapshot(self) -> dict:
        """Per-key effective knobs (JSON-safe), for stats()/bench output."""
        keys = sorted(self._last)
        return {repr(k): {"gap_ms": self._gap.get(k, 0.0) * 1e3,
                          "wait_ms": self.wait(k) * 1e3,
                          "target_rows": self.target_rows(k),
                          "occupancy_ewma": self._occ.get(k, 0.0)}
                for k in keys}


class SizeBinnedBatcher:
    """Accumulate requests into per-(bucket, head) bins; release full or
    expired bins. Single-consumer (the engine worker owns it) — no locking.

    max_batch: rows per compiled batch (the static leading dim).
    max_wait:  seconds the OLDEST request of a bin may wait before the bin
               is flushed partially filled (the p99 bound at low rates).
    clock:     the shared engine clock; ``expired``/``next_deadline`` use it
               when the caller passes no ``now``, so bin-age math always
               lives on the same base as ``t_submit``.
    policy:    optional ``AdaptivePolicy`` — replaces the fixed release
               knobs with measured-rate per-bin ones (release shape is
               still the static ``max_batch``).
    """

    def __init__(self, *, max_batch: int = 8, max_wait: float = 0.005,
                 clock=time.monotonic, policy: AdaptivePolicy | None = None):
        assert max_batch >= 1 and max_wait >= 0.0
        if policy is not None:
            assert policy.max_batch == max_batch, \
                "policy and batcher must agree on the static batch shape"
        self.max_batch = max_batch
        self.max_wait = max_wait
        self._clock = clock
        self.policy = policy
        self._bins: dict[tuple, list[Request]] = {}   # (bucket, head) -> reqs

    # per-bin effective knobs: fixed, unless a policy is measuring
    def _wait(self, key: tuple) -> float:
        return self.max_wait if self.policy is None else self.policy.wait(key)

    def _target(self, key: tuple) -> int:
        return self.max_batch if self.policy is None \
            else self.policy.target_rows(key)

    def add(self, req: Request) -> AssembledBatch | None:
        """File one request; returns an AssembledBatch immediately when it
        fills its bin (to the policy's target under adaptation), else None
        (the bin keeps waiting)."""
        key = (req.bucket, req.head)
        if self.policy is not None:
            self.policy.observe_arrival(key, req.t_submit)
        bin_ = self._bins.setdefault(key, [])
        bin_.append(req)
        if len(bin_) >= self._target(key):
            del self._bins[key]
            return self._release(key, bin_)
        return None

    def _release(self, key: tuple, bin_: list[Request]) -> AssembledBatch:
        if self.policy is not None:
            self.policy.observe_release(key, len(bin_))
        return assemble(bin_, key[0], self.max_batch)

    def expired(self, now: float | None = None) -> list[AssembledBatch]:
        """Bins whose oldest request has waited past its wait budget,
        assembled (possibly partial). Deterministic order: by that oldest
        timestamp."""
        if now is None:
            now = self._clock()
        due = [(bin_[0].t_submit, key) for key, bin_ in self._bins.items()
               if now - bin_[0].t_submit >= self._wait(key)]
        return [self._release(key, self._bins.pop(key))
                for _, key in sorted(due)]

    def flush(self) -> list[AssembledBatch]:
        """Assemble every pending bin regardless of age (shutdown drain)."""
        out = [self._release(key, bin_)
               for key, bin_ in sorted(self._bins.items(),
                                       key=lambda kv: kv[1][0].t_submit)]
        self._bins.clear()
        return out

    def next_deadline(self, now: float | None = None) -> float | None:
        """Seconds until the earliest pending bin expires (<= 0: already
        due); None when no bins are waiting. The engine worker uses this as
        its queue-poll timeout so deadline flushes fire on time."""
        if now is None:
            now = self._clock()
        if not self._bins:
            return None
        due = min(bin_[0].t_submit + self._wait(key)
                  for key, bin_ in self._bins.items())
        return due - now

    @property
    def n_pending(self) -> int:
        return sum(len(b) for b in self._bins.values())

    def pending_requests(self) -> list[Request]:
        """The raw requests still binned, without assembling (failure-path
        cleanup: resolve their futures even when assembly itself is what
        broke)."""
        return [r for b in self._bins.values() for r in b]

"""Continuous size-binned request batching.

Training already solved the padding-waste-vs-recompile tradeoff with
``BucketSpec`` (quantized pad-shape grids, ``repro.data.bucketing``); at
serving time the SAME grid becomes the coalescing rule: requests whose
(atom, edge) counts land in the same bucket are padded to one shared shape
and run as one batch, so the compiled-shape universe of the serving engine
is exactly the training bucket grid.

"Continuous" in the vLLM sense, adapted to fixed-shape XLA executables: the
binner never waits for an epoch or a fixed batch — as requests stream in it
holds at most one open bin per (bucket, head) and releases it the moment it
is **full** (``max_batch`` requests) or **expired** (its oldest request has
waited ``max_wait``). The deadline bounds tail latency under low arrival
rates: a lone request costs at most ``max_wait`` + one forward, it never
waits for a full batch that will not come.

The released batch is padded to a STATIC shape (``max_batch`` rows at the
bucket's (A_pad, E_pad)) with inert rows — all-pad structures whose node
masks are empty and whose edges point at the ``A_pad`` sentinel (the
``>= n_nodes`` kernel contract, see docs/kernels.md) — so partial flushes
reuse the full batch's executable instead of compiling a (k, ...) variant
per occupancy k.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from .queue import Request


@dataclasses.dataclass
class AssembledBatch:
    """One ready-to-run padded batch: ``batch`` is the (max_batch, A_pad,
    ...) dict the compiled forward takes; ``requests`` (length ``n_real``
    <= max_batch) maps row i back to the future to resolve."""
    batch: dict
    requests: list[Request]
    bucket: tuple
    head: int

    @property
    def n_real(self) -> int:
        return len(self.requests)


def assemble(requests: list[Request], bucket: tuple,
             max_batch: int) -> AssembledBatch:
    """Pad-and-stack admitted requests into one (max_batch, A_pad/E_pad)
    batch. Every request must already be binned into ``bucket`` (admission
    guarantees content fits); rows beyond ``len(requests)`` are inert pad
    structures. Edge endpoints of masked/pad edges are re-pointed at the
    ``A_pad`` sentinel — same contract as ``BucketingBatcher``."""
    assert 1 <= len(requests) <= max_batch, (len(requests), max_batch)
    a_pad, e_pad = bucket
    head = requests[0].head
    B = max_batch
    species = np.zeros((B, a_pad), np.int32)
    pos = np.zeros((B, a_pad, 3), np.float32)
    src = np.full((B, e_pad), a_pad, np.int32)
    dst = np.full((B, e_pad), a_pad, np.int32)
    nmask = np.zeros((B, a_pad), bool)
    emask = np.zeros((B, e_pad), bool)
    for i, r in enumerate(requests):
        assert r.bucket == bucket and r.head == head, \
            "batcher invariant: one (bucket, head) per assembled batch"
        s = r.sample
        nm, em = s["node_mask"], s["edge_mask"]
        # stored arrays may be longer than the bucket (a small structure
        # submitted in a big padded container): admission checked CONTENT
        # fits, so trailing storage beyond A_pad/E_pad is pad by contract
        na = min(nm.shape[0], a_pad)
        ne = min(em.shape[0], e_pad)
        # admission enforces front-packed masks and bucket_for sized the
        # bucket to the content, so the tail beyond the bucket is pure pad
        assert not (nm[na:].any() or em[ne:].any()), \
            "assemble invariant: real content beyond the assigned bucket"
        species[i, :na] = np.where(nm[:na], s["species"][:na], 0)
        pos[i, :na] = np.where(nm[:na, None], s["pos"][:na], 0.0)
        nmask[i, :na] = nm[:na]
        emask[i, :ne] = em[:ne]
        src[i, :ne] = np.where(em[:ne], s["edge_src"][:ne], a_pad)
        dst[i, :ne] = np.where(em[:ne], s["edge_dst"][:ne], a_pad)
    return AssembledBatch(
        batch={"species": species, "pos": pos, "edge_src": src,
               "edge_dst": dst, "node_mask": nmask, "edge_mask": emask},
        requests=list(requests), bucket=bucket, head=head)


class SizeBinnedBatcher:
    """Accumulate requests into per-(bucket, head) bins; release full or
    expired bins. Single-consumer (the engine worker owns it) — no locking.

    max_batch: rows per compiled batch (the static leading dim).
    max_wait:  seconds the OLDEST request of a bin may wait before the bin
               is flushed partially filled (the p99 bound at low rates).
    """

    def __init__(self, *, max_batch: int = 8, max_wait: float = 0.005):
        assert max_batch >= 1 and max_wait >= 0.0
        self.max_batch = max_batch
        self.max_wait = max_wait
        self._bins: dict[tuple, list[Request]] = {}   # (bucket, head) -> reqs

    def add(self, req: Request) -> AssembledBatch | None:
        """File one request; returns an AssembledBatch immediately when it
        fills its bin, else None (the bin keeps waiting)."""
        key = (req.bucket, req.head)
        bin_ = self._bins.setdefault(key, [])
        bin_.append(req)
        if len(bin_) >= self.max_batch:
            del self._bins[key]
            return assemble(bin_, req.bucket, self.max_batch)
        return None

    def expired(self, now: float) -> list[AssembledBatch]:
        """Bins whose oldest request has waited past ``max_wait``, assembled
        (possibly partial). Deterministic order: by that oldest timestamp."""
        due = [(bin_[0].t_submit, key) for key, bin_ in self._bins.items()
               if now - bin_[0].t_submit >= self.max_wait]
        out = []
        for _, key in sorted(due):
            bin_ = self._bins.pop(key)
            out.append(assemble(bin_, key[0], self.max_batch))
        return out

    def flush(self) -> list[AssembledBatch]:
        """Assemble every pending bin regardless of age (shutdown drain)."""
        out = [assemble(bin_, key[0], self.max_batch)
               for key, bin_ in sorted(self._bins.items(),
                                       key=lambda kv: kv[1][0].t_submit)]
        self._bins.clear()
        return out

    def next_deadline(self, now: float) -> float | None:
        """Seconds until the earliest pending bin expires (<= 0: already
        due); None when no bins are waiting. The engine worker uses this as
        its queue-poll timeout so deadline flushes fire on time."""
        if not self._bins:
            return None
        oldest = min(bin_[0].t_submit for bin_ in self._bins.values())
        return (oldest + self.max_wait) - now

    @property
    def n_pending(self) -> int:
        return sum(len(b) for b in self._bins.values())

    def pending_requests(self) -> list[Request]:
        """The raw requests still binned, without assembling (failure-path
        cleanup: resolve their futures even when assembly itself is what
        broke)."""
        return [r for b in self._bins.values() for r in b]

"""Multi-device serving scale-out: replica workers behind one admission
scheduler.

``ServeSession(mesh=...)`` (engine.py) scales a SINGLE worker by sharding
each assembled bin's rows over a mesh — good when bins run full. This
module scales the other axis: ``ReplicaServeSession`` runs one complete
engine (queue + binner + worker + executables) per device sub-mesh from
``launch.mesh.make_replica_meshes``, fed by a size-aware
``ReplicaScheduler`` that routes each admitted request per (bucket, head)
to the least-loaded replica. It is the serving analogue of training's
hierarchical multi-task parallelism (PR 9): independent sub-meshes, no
cross-device collectives, coordination only at the host-side router.

Routing is STICKY per (bucket, head) while the chosen replica's bin is
filling: the scheduler re-picks the least-loaded replica only after
``max_batch`` rows have been routed under a key, so scale-out does not
shred coalescing (a round-robin router would split a would-be-full bin
into n_replicas partial flushes — the same pad-waste-vs-coalescing
tradeoff training's bucketing makes, applied to placement).

Failure semantics degrade instead of failing: a dead replica (its queue
closes when its worker crashes) is marked and its keys fail over to live
replicas (counted as ``failovers``); only when EVERY replica is dead does
``submit`` raise. Compile budget: each replica jit-compiles its own
executables (the jit cache is keyed per device set), so the session-wide
budget is ``distinct bucket shapes x n_replicas`` — plans, not heads.
"""
from __future__ import annotations

import threading
import time

from repro.data.bucketing import BucketSpec

from .engine import ServeSession
from .metrics import ServeMetrics
from .queue import ServeClosedError


class ReplicaScheduler:
    """Size-aware least-loaded router with sticky (bucket, head) bins.

    Thread-safe (callers submit from many threads). Load is the number of
    routed-but-unresolved requests per replica, maintained by the session's
    future done-callbacks. ``route``/``complete``/``fail`` are the whole
    protocol:

      r = sched.route(key)       # reserves one outstanding slot on r
      ... queue.put ok ...       # request delivered; slot rides the future
      sched.complete(r)          # future resolved (any outcome)
      sched.fail(r)              # put() failed: replica dead, slot released
    """

    def __init__(self, n_replicas: int, *, max_batch: int = 8):
        assert n_replicas >= 1 and max_batch >= 1
        self.n_replicas = n_replicas
        self.max_batch = max_batch
        self._lock = threading.Lock()
        self.outstanding = [0] * n_replicas
        self.dead: set[int] = set()
        # key -> [replica, rows routed into the replica's current bin]
        self._assign: dict[tuple, list] = {}

    def route(self, key: tuple) -> int:
        """Pick the replica for one request under ``key`` and reserve an
        outstanding slot on it. Raises ``ServeClosedError`` when every
        replica is dead."""
        with self._lock:
            cur = self._assign.get(key)
            if cur is not None and cur[0] not in self.dead \
                    and cur[1] < self.max_batch:
                cur[1] += 1
                self.outstanding[cur[0]] += 1
                return cur[0]
            live = [r for r in range(self.n_replicas) if r not in self.dead]
            if not live:
                raise ServeClosedError("every serving replica is dead")
            # least outstanding; ties broken by index for determinism
            r = min(live, key=lambda i: (self.outstanding[i], i))
            self._assign[key] = [r, 1]
            self.outstanding[r] += 1
            return r

    def complete(self, replica: int):
        with self._lock:
            self.outstanding[replica] -= 1

    def fail(self, replica: int):
        """The routed put() failed: release the reservation, mark the
        replica dead, and forget its sticky assignments so live replicas
        take over its keys."""
        with self._lock:
            self.outstanding[replica] -= 1
            self.dead.add(replica)
            for key in [k for k, v in self._assign.items()
                        if v[0] == replica]:
                del self._assign[key]

    def revive(self, replica: int):
        with self._lock:
            self.dead.discard(replica)

    def snapshot(self) -> dict:
        with self._lock:
            return {"outstanding": list(self.outstanding),
                    "dead": sorted(self.dead),
                    "sticky_keys": len(self._assign)}


class ReplicaServeSession:
    """N independent ``ServeSession`` replicas behind one scheduler.

    Mirrors the single-session public API (``submit``/``submit_many``/
    ``predict_one``/``warmup``/``stats``/``close``/context manager) so
    callers and benches swap it in unchanged. All replicas share ONE
    ``ServeMetrics`` (and one clock), so counters/latencies aggregate
    naturally; per-replica detail lives under ``stats()["scheduler"]``.

    meshes: one 1-axis mesh per replica (``make_replica_meshes``); a
        replica's session runs single-device when its mesh has one device,
        sharded-forward when it has several — the two scale-out modes
        compose.
    Remaining keyword arguments are forwarded to every ``ServeSession``.
    """

    def __init__(self, params: dict, arch, *, meshes,
                 spec: BucketSpec | None = None, max_batch: int = 8,
                 metrics: ServeMetrics | None = None,
                 clock=time.monotonic, seed: int = 0, **kw):
        assert len(meshes) >= 1, "need at least one replica mesh"
        self.metrics = metrics if metrics is not None else \
            ServeMetrics(seed=seed, clock=clock)
        # always pass the mesh, even 1-device: it COMMITS the replica's
        # params/compute to its own device (per-replica jit caches)
        self.replicas = [
            ServeSession(params, arch, spec=spec, max_batch=max_batch,
                         mesh=m, metrics=self.metrics, clock=clock,
                         seed=seed, **kw)
            for m in meshes]
        self.spec = self.replicas[0].spec
        self.n_heads = self.replicas[0].n_heads
        # admission-only queue (never enqueued, never closed): validation
        # must not depend on any particular replica being alive
        self._admission = self.replicas[0]._make_queue()
        self.max_batch = max_batch
        self.scheduler = ReplicaScheduler(len(self.replicas),
                                          max_batch=max_batch)
        self._closed = False

    @property
    def n_replicas(self) -> int:
        return len(self.replicas)

    # -- public API ----------------------------------------------------------

    def submit(self, sample: dict, head: int = 0):
        """Validate once (caller's thread), then route to the least-loaded
        live replica for this (bucket, head). Fails over past dead replicas;
        raises ``ServeClosedError`` only when none are left."""
        if self._closed:
            raise ServeClosedError("ReplicaServeSession is closed")
        req = self._admission.make_request(sample, head)
        key = (req.bucket, req.head)
        while True:
            r = self.scheduler.route(key)
            try:
                self.replicas[r].queue.put(req)
            except ServeClosedError:
                self.scheduler.fail(r)
                self.metrics.inc("failovers")
                continue
            break
        self.metrics.inc("routed")
        req.future.add_done_callback(
            lambda _f, _r=r: self.scheduler.complete(_r))
        return req.future

    def submit_many(self, samples, heads=0) -> list:
        import numpy as np
        if isinstance(heads, (int, np.integer)):
            heads = [int(heads)] * len(samples)
        if len(heads) != len(samples):
            raise ValueError(f"{len(samples)} samples vs {len(heads)} heads")
        return [self.submit(s, h) for s, h in zip(samples, heads)]

    def predict_one(self, sample: dict, head: int = 0) -> dict:
        """Synchronous single-request forward on the first LIVE replica —
        the bitwise parity reference every replica's batched rows are held
        to (tests/test_serve_scaleout.py)."""
        for r, srv in enumerate(self.replicas):
            if r not in self.scheduler.dead:
                return srv.predict_one(sample, head)
        raise ServeClosedError("every serving replica is dead")

    def warmup(self, buckets=None) -> int:
        """Pre-compile every replica's executables, concurrently (each
        replica owns its own jit cache — compilation is the per-plan cost
        scale-out pays once, so overlap it). Returns total compiled shapes
        across replicas."""
        threads = [threading.Thread(target=srv.warmup, args=(buckets,))
                   for srv in self.replicas]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        return sum(len(srv._shapes_compiled) for srv in self.replicas)

    def jit_functions(self):
        """Every replica's jitted forward — the RecompileSanitizer seam,
        matching ``ServeSession.jit_functions``."""
        return tuple(srv._predict for srv in self.replicas)

    def stats(self) -> dict:
        """Shared-metrics snapshot + aggregate cache occupancy. The compile
        budget scales with PLANS (one jit cache per replica device set), not
        heads: ``n_shapes x n_replicas`` compilations."""
        out = self.metrics.snapshot()
        out["executable_cache"] = {
            "entries": sum(len(s._exec) for s in self.replicas),
            "compiled_shapes": sum(len(s._shapes_compiled)
                                   for s in self.replicas),
            "budget": self.spec.n_shapes * self.n_heads * self.n_replicas,
            "compile_budget": self.spec.n_shapes * self.n_replicas,
        }
        out["plan"] = {"mode": "replica", "n_replicas": self.n_replicas,
                       "devices": sum(s.plan_devices for s in self.replicas)}
        out["scheduler"] = self.scheduler.snapshot()
        if self.replicas[0]._policy is not None:
            out["adaptive"] = {f"replica{r}": s._policy.snapshot()
                               for r, s in enumerate(self.replicas)}
        return out

    def restart_workers(self) -> int:
        """Recover dead replicas (``ServeSession.restart_worker`` each) and
        put them back in rotation. Returns how many restarted."""
        if self._closed:
            raise ServeClosedError("ReplicaServeSession is closed")
        n = 0
        for r, srv in enumerate(self.replicas):
            if srv.restart_worker():
                n += 1
            self.scheduler.revive(r)
        return n

    def close(self):
        """Stop admissions on every replica first (no request can land in a
        doomed queue mid-shutdown), then drain them all. Idempotent."""
        if self._closed:
            return
        self._closed = True
        for srv in self.replicas:
            srv.queue.close()
        for srv in self.replicas:
            srv.close()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
        return False

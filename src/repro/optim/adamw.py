"""AdamW in pure JAX (optax is not in the container).

State and updates are pytrees mirroring the params, so parameter shardings
propagate to optimizer state (ZeRO-style sharded moments fall out of FSDP
param shardings for free).
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jnp.ndarray
    m: Any
    v: Any


class Optimizer(NamedTuple):
    init: Callable
    update: Callable  # (grads, state, params) -> (new_params, new_state)


def adamw(lr, *, b1=0.9, b2=0.999, eps=1e-8, weight_decay=0.01,
          grad_clip=0.0, moment_dtype=jnp.float32) -> Optimizer:
    """lr: float or schedule fn(step)->float."""
    sched = lr if callable(lr) else (lambda _: lr)

    def init(params):
        zeros = lambda p: jnp.zeros(p.shape, moment_dtype)
        return AdamWState(step=jnp.zeros((), jnp.int32),
                          m=jax.tree_util.tree_map(zeros, params),
                          v=jax.tree_util.tree_map(zeros, params))

    def update(grads, state, params):
        step = state.step + 1
        if grad_clip > 0:
            gnorm = global_norm(grads)
            scale = jnp.minimum(1.0, grad_clip / jnp.maximum(gnorm, 1e-9))
            grads = jax.tree_util.tree_map(lambda g: g * scale, grads)
        lr_t = sched(step)
        bc1 = 1 - b1 ** step.astype(jnp.float32)
        bc2 = 1 - b2 ** step.astype(jnp.float32)

        def upd(p, g, m, v):
            g32 = g.astype(jnp.float32)
            m_new = b1 * m.astype(jnp.float32) + (1 - b1) * g32
            v_new = b2 * v.astype(jnp.float32) + (1 - b2) * jnp.square(g32)
            mhat = m_new / bc1
            vhat = v_new / bc2
            delta = mhat / (jnp.sqrt(vhat) + eps) + weight_decay * p.astype(jnp.float32)
            p_new = p.astype(jnp.float32) - lr_t * delta
            return (p_new.astype(p.dtype), m_new.astype(m.dtype),
                    v_new.astype(v.dtype))

        flat = jax.tree_util.tree_map(upd, params, grads, state.m, state.v)
        p_new = jax.tree_util.tree_map(lambda t: t[0], flat,
                                       is_leaf=lambda t: isinstance(t, tuple))
        m_new = jax.tree_util.tree_map(lambda t: t[1], flat,
                                       is_leaf=lambda t: isinstance(t, tuple))
        v_new = jax.tree_util.tree_map(lambda t: t[2], flat,
                                       is_leaf=lambda t: isinstance(t, tuple))
        return p_new, AdamWState(step=step, m=m_new, v=v_new)

    return Optimizer(init=init, update=update)


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in leaves))

from .adamw import AdamWState, Optimizer, adamw, global_norm  # noqa: F401
from .schedules import constant, warmup_cosine  # noqa: F401

"""Async double-buffered input pipeline.

``GroupBatcher``/``SingleBatcher`` assemble batches on the host (NumPy
indexing + stacking) and the training loop then pays ``shard_batch`` /
``device_put`` before every step — all serialized with the running step, so
the accelerator idles between steps. ``Prefetcher`` moves that whole chain
onto a background thread with a bounded queue (default depth 2 — classic
double buffering: one batch in flight to the device while the step consumes
the previous one). JAX dispatch is thread-safe and ``device_put`` is async,
so the H2D copy overlaps the running step's compute.

This is the generic, batcher-agnostic layer of the DDStore latency-hiding
role (``repro.data.store.PrefetchingBatcher`` is the shard-store-specific
sibling that prefetches filesystem reads).

Determinism: the producer thread is the only caller of
``batcher.next_batch``, so the batch stream is byte-identical to the
synchronous path (tests/test_prefetch.py asserts this) — prefetching changes
when batches are built, never which. One caveat: ``close()`` discards the
(up to ``depth``+1) batches the producer has already drawn, advancing the
wrapped batcher past what the consumer saw — so hold ONE Prefetcher for the
batcher's whole lifetime instead of re-wrapping per loop (``Session`` keeps
its prefetcher across ``run()`` calls for exactly this reason; queued
batches are simply consumed by the next run).

Checkpointing closes exactly that gap: when the wrapped batcher is
checkpointable (``state()``/``restore()``), the producer snapshots the
batcher state AFTER drawing each batch and ships it through the queue with
the batch, and ``Prefetcher.state()`` returns the snapshot of the last batch
the CONSUMER actually received — never crediting read-ahead the training
loop hasn't seen. ``restore(state)`` halts the producer, discards its
read-ahead, rewinds the batcher, and restarts — so a resumed run replays the
stream from the first unconsumed batch, byte-identically
(tests/test_datapipe_checkpoint.py).
"""
from __future__ import annotations

import queue
import threading


class Prefetcher:
    """Wrap any batcher (the ``next_batch()`` contract) with a depth-``depth``
    background producer.

    transform: optional callable applied to each batch ON THE PRODUCER
    THREAD — pass ``plan.shard_batch`` (or ``jax.device_put``) so host->
    device transfer overlaps the running step.

    Exceptions in the producer (including inside ``transform``) are captured
    and re-raised from ``next_batch()``. Use as a context manager or call
    ``close()`` to stop the producer; extra batches already in the queue are
    discarded."""

    _DONE = object()   # queued after a producer exception

    def __init__(self, batcher, *, transform=None, depth: int = 2):
        assert depth >= 1, f"prefetch depth must be >= 1, got {depth}"
        self.batcher = batcher
        self.transform = transform
        self.depth = depth
        # consumer-visible stream position: state as of the last batch
        # handed out by next_batch() (initially: before any batch).
        # Trackability is probed by CALLING state(), not hasattr — a
        # delegating wrapper (e.g. BucketingBatcher) always has the method
        # but raises when its inner batcher is not checkpointable
        try:
            self._consumed_state = batcher.state()
            self._trackable = True
        except (AttributeError, TypeError):
            self._consumed_state = None
            self._trackable = False
        self._err: BaseException | None = None
        self._fault: BaseException | None = None
        self._closed = False
        self._start()

    def _start(self):
        self._q: queue.Queue = queue.Queue(maxsize=self.depth)
        self._stop = threading.Event()
        # instrumentation seam (repro.analysis.tsan tests): producer
        # generations are SEQUENTIAL — a restore halts generation N before
        # generation N+1 draws, which is why the single-producer contract
        # is overlap-based, not thread-identity-based
        self.generation = getattr(self, "generation", 0) + 1
        self._thread = threading.Thread(
            target=self._produce, name=f"prefetcher-{self.generation}",
            daemon=True)
        self._thread.start()

    def _put(self, item) -> bool:
        """Blocking put that stays responsive to close(); False if stopped."""
        while not self._stop.is_set():
            try:
                self._q.put(item, timeout=0.1)
                return True
            except queue.Full:
                continue
        return False

    def _produce(self):
        try:
            while not self._stop.is_set():
                if self._fault is not None:
                    exc, self._fault = self._fault, None
                    raise exc
                b = self.batcher.next_batch()
                # snapshot BEFORE transform (transform is placement, not
                # stream position) and after the draw: restoring to this
                # snapshot replays the stream from the NEXT batch
                st = self.batcher.state() if self._trackable else None
                if self.transform is not None:
                    b = self.transform(b)
                self._put((b, st))
        except BaseException as e:  # propagate to the consumer
            if isinstance(e, StopIteration):
                # next_batch() is also __next__: re-raising a producer's
                # bare StopIteration there would SILENTLY end any for-loop
                # over the Prefetcher instead of surfacing the failure —
                # wrap it, keeping the original as __cause__ (traceback
                # included)
                wrapped = RuntimeError(
                    "prefetch producer raised StopIteration "
                    "(exhausted/broken source?)")
                wrapped.__cause__ = e
                e = wrapped
            self._err = e
            self._put((self._DONE, None))

    def inject_producer_fault(self, exc: BaseException):
        """Chaos hook (repro.resilience.faults): the producer raises ``exc``
        before its next draw, exactly as if it had crashed — the consumer
        sees it from ``next_batch()`` after draining already-queued batches,
        and ``restore(state())`` recovers the stream in place."""
        self._fault = exc

    def next_batch(self):
        if self._err is not None and self._q.empty():
            raise self._err          # producer already died; don't block
        if self._stop.is_set():      # closed: drain or raise, never hang
            try:
                item, st = self._q.get_nowait()
            except queue.Empty:
                raise RuntimeError("Prefetcher is closed") from self._err
        else:
            item, st = self._q.get()
        if item is self._DONE:
            self._stop.set()
            raise self._err
        if st is not None:
            self._consumed_state = st
        return item

    # iterator protocol, so a Prefetcher drops into train_loop(batches=...)
    def __iter__(self):
        return self

    def __next__(self):
        return self.next_batch()

    # -- checkpointing ------------------------------------------------------

    def state(self) -> dict:
        """Wrapped-batcher state as of the last batch the consumer received
        (producer read-ahead is NOT credited — it will be re-drawn after a
        restore)."""
        if not self._trackable:
            raise TypeError(
                f"{type(self.batcher).__name__} has no state()/restore(); "
                "wrap a checkpointable batcher to checkpoint the pipeline")
        return self._consumed_state

    def restore(self, state: dict):
        """Rewind the pipeline to a ``state()`` snapshot: halt the producer,
        discard its read-ahead, restore the batcher, restart. Also revives a
        closed Prefetcher."""
        if not self._trackable:
            raise TypeError(
                f"{type(self.batcher).__name__} has no state()/restore()")
        self._halt()
        if self._thread.is_alive():
            # a producer stuck past _halt's join timeout would race the new
            # producer on the same batcher and corrupt the rewound stream
            raise RuntimeError(
                "prefetch producer did not stop within the join timeout; "
                "cannot restore safely while it may still draw batches")
        self.batcher.restore(state)
        self._consumed_state = self.batcher.state()
        self._err = None
        self._closed = False
        self._start()

    # -- shutdown -----------------------------------------------------------

    def _halt(self):
        """Stop the producer and discard queued batches."""
        self._stop.set()
        # unblock a producer stuck in _put, then drain — twice: the first
        # drain can free a slot that the producer's in-flight put fills
        # before it observes _stop, so drain again after the join
        for _ in range(2):
            try:
                while True:
                    self._q.get_nowait()
            except queue.Empty:
                pass
            self._thread.join(timeout=5.0)

    def close(self):
        """Stop the producer and discard queued batches. Repeated shutdown
        is a strict no-op: the second ``close()`` (or a ``close()`` followed
        by context-manager ``__exit__``) returns immediately without
        re-draining or re-joining — a producer stuck past the join timeout
        previously made every extra ``close()`` block for the full timeout
        again. (``restore()`` revives a closed Prefetcher and re-arms
        ``close()``; ``next_batch()`` on a closed one raises.)"""
        if self._closed:
            return
        self._closed = True
        self._halt()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
        return False

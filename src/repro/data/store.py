"""Sharded sample store — the ADIOS + DDStore analogue.

The paper serialises every dataset into ADIOS bp files and serves training
batches through DDStore, an in-memory distributed cache with one-sided
remote fetches so "a process requests the next batch ... which transparently
obtains it from the memory of a remote process", never touching the
filesystem in the steady state.

This module reproduces that data path at container scale:

  * ``write_store``  — serialise one source into N ``.npz`` shards + a JSON
    manifest (the ADIOS file-set analogue);
  * ``ShardedSource`` — lazily maps shards, caches them in memory after
    first touch (the DDStore cache), and serves arbitrary sample indices by
    routing to the owning shard — reads from the "remote" shard hit the
    in-memory copy, not the filesystem;
  * ``PrefetchingBatcher`` — a GroupBatcher over ShardedSources with a
    one-batch-deep background prefetch thread (double buffering, DDStore's
    latency-hiding role).
"""
from __future__ import annotations

import json
import os

import numpy as np

from .loader import GroupBatcher
from .prefetch import Prefetcher


def write_store(path: str, arrays: dict[str, np.ndarray], *,
                shard_size: int = 256) -> dict:
    """arrays: dict of equal-length (dim 0) numpy arrays -> shard files +
    manifest. Returns the manifest."""
    os.makedirs(path, exist_ok=True)
    n = len(next(iter(arrays.values())))
    for k, v in arrays.items():
        assert len(v) == n, f"{k} length {len(v)} != {n}"
    shards = []
    for i, start in enumerate(range(0, n, shard_size)):
        stop = min(start + shard_size, n)
        fname = f"shard_{i:05d}.npz"
        np.savez(os.path.join(path, fname),
                 **{k: v[start:stop] for k, v in arrays.items()})
        shards.append({"file": fname, "start": start, "stop": stop})
    manifest = {"n_samples": n, "keys": sorted(arrays),
                "shard_size": shard_size, "shards": shards}
    # atomic MANIFEST publish: write to a temp file in the SAME directory,
    # then os.replace — an interrupted writer leaves either the old
    # manifest or none at all, never a truncated JSON that ShardedSource
    # crashes parsing. Scope: shard .npz files are NOT transactional — an
    # interrupted REwrite of an existing store can leave new shard bytes
    # under the old manifest; write to a fresh directory to replace a store
    final = os.path.join(path, "manifest.json")
    tmp = final + ".tmp"
    with open(tmp, "w") as f:
        json.dump(manifest, f, indent=1)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, final)
    return manifest


class ShardedSource:
    """Lazy, caching reader over one store directory (DDStore cache)."""

    def __init__(self, path: str):
        self.path = path
        with open(os.path.join(path, "manifest.json")) as f:
            self.manifest = json.load(f)
        self._cache: dict[int, dict] = {}
        self.fetches = 0          # filesystem reads (should plateau)
        self.hits = 0             # in-memory serves

    def __len__(self):
        return self.manifest["n_samples"]

    @property
    def keys(self):
        return self.manifest["keys"]

    def _shard(self, si: int) -> dict:
        if si not in self._cache:
            f = np.load(os.path.join(self.path,
                                     self.manifest["shards"][si]["file"]))
            self._cache[si] = {k: f[k] for k in self.keys}
            self.fetches += 1
        else:
            self.hits += 1
        return self._cache[si]

    def gather(self, idx: np.ndarray) -> dict:
        """Serve arbitrary sample indices, routing per owning shard."""
        ss = self.manifest["shard_size"]
        out = {k: [] for k in self.keys}
        order = np.argsort(idx // ss, kind="stable")
        inv = np.empty_like(order)
        inv[order] = np.arange(len(order))
        for si in np.unique(idx // ss):
            sh = self._shard(int(si))
            local = idx[idx // ss == si] - si * ss
            for k in self.keys:
                out[k].append(sh[k][local])
        res = {k: np.concatenate(v)[inv] for k, v in out.items()}
        return res


class PrefetchingBatcher(Prefetcher):
    """Group-aware batcher over ShardedSources with background prefetch:
    a ``GroupBatcher`` (which accepts gather-style sources) composed with
    the generic ``repro.data.prefetch.Prefetcher`` — one thread-lifecycle
    implementation, DDStore's latency-hiding role.

    Matches GroupBatcher's contract: ``next_batch()`` -> task-major numpy
    dict, row t drawn only from source t."""

    def __init__(self, sources: list[ShardedSource], batch_per_task: int,
                 *, seed: int = 0, depth: int = 1):
        self.sources = sources
        super().__init__(GroupBatcher(sources, batch_per_task, seed=seed),
                         depth=depth)

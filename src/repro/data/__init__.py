from . import lm_data, loader, prefetch, synthetic_atoms  # noqa: F401
from .loader import GroupBatcher  # noqa: F401
from .prefetch import Prefetcher  # noqa: F401

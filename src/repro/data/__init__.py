from . import (bucketing, lm_data, loader, mixing, prefetch,  # noqa: F401
               synthetic_atoms)
from .bucketing import BucketingBatcher, BucketSpec  # noqa: F401
from .loader import GroupBatcher, SingleBatcher  # noqa: F401
from .mixing import MixingBatcher, MixingConfig, mix_weights  # noqa: F401
from .prefetch import Prefetcher  # noqa: F401

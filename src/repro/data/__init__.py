from . import lm_data, loader, synthetic_atoms  # noqa: F401
from .loader import GroupBatcher  # noqa: F401

"""Synthetic token streams for the LM architectures.

Per-source streams with distinct token statistics (different Zipf exponents
and source-tag prefixes) so the multi-task LM setup has genuinely different
per-source distributions — the LM analogue of multi-fidelity data.
"""
from __future__ import annotations

import numpy as np


def zipf_tokens(rng, n, vocab, alpha=1.2, offset=0):
    ranks = np.arange(1, vocab + 1, dtype=np.float64)
    p = ranks ** (-alpha)
    p /= p.sum()
    return ((rng.choice(vocab, size=n, p=p) + offset) % vocab).astype(np.int32)


def make_lm_source(seed: int, n_seqs: int, seq_len: int, vocab: int,
                   alpha: float = 1.2, offset: int = 0) -> dict:
    rng = np.random.default_rng(seed)
    toks = zipf_tokens(rng, n_seqs * (seq_len + 1), vocab, alpha, offset)
    toks = toks.reshape(n_seqs, seq_len + 1)
    return {"tokens": toks[:, :-1].copy(), "labels": toks[:, 1:].copy()}


def make_lm_sources(n_tasks: int, n_seqs: int, seq_len: int, vocab: int,
                    seed: int = 0) -> list[dict]:
    return [make_lm_source(seed + t, n_seqs, seq_len, vocab,
                           alpha=1.05 + 0.15 * t, offset=t * (vocab // max(n_tasks, 1)))
            for t in range(n_tasks)]

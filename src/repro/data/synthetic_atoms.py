"""Synthetic multi-source, multi-fidelity atomistic datasets.

The container has no ANI1x/QM7-X/etc. files, so we synthesise five sources
that reproduce the *structure* of the paper's data problem:

  * a shared ground-truth potential (Morse-like pairwise + per-element site
    energies) defines E_true and F_true = -∇E_true (computed with jax.grad,
    so forces are exactly consistent with the energy surface);
  * each source draws from a DIFFERENT chemical domain (element sets and
    cluster geometries) — mirroring "different atomistic domains, not the
    same systems at different fidelity";
  * each source applies its own fidelity transform: per-element reference
    shifts, a global scale, and observation noise — mirroring different
    XC functionals / levels of theory. A single shared head cannot fit the
    conflicting labels; per-source heads can (Tables 1–2 phenomenology).

Five sources named after the paper's datasets, with element palettes taken
from the paper's §4.1 descriptions.
"""
from __future__ import annotations

import dataclasses
import zlib

import jax
import jax.numpy as jnp
import numpy as np

# element palettes (atomic numbers), per paper §4.1
SOURCES = {
    "ani1x": dict(elements=(1, 6, 7, 8), n_atoms=(8, 24), scale=1.00,
                  shift_mag=0.00, noise=0.002),
    "qm7x": dict(elements=(1, 6, 7, 8, 16, 17), n_atoms=(4, 16), scale=1.02,
                 shift_mag=0.8, noise=0.004),
    "transition1x": dict(elements=(1, 3, 6, 7, 8, 9, 11, 15, 16, 17),
                         n_atoms=(6, 20), scale=0.97, shift_mag=0.5, noise=0.006),
    "mptrj": dict(elements=tuple(range(3, 40, 2)), n_atoms=(12, 32),
                  scale=1.10, shift_mag=2.0, noise=0.010),
    "alexandria": dict(elements=tuple(range(4, 48, 3)), n_atoms=(10, 28),
                       scale=0.92, shift_mag=1.5, noise=0.008),
}
N_SPECIES = 64  # supported atomic numbers (0 = pad)


# ---------------------------------------------------------------------------
# Ground-truth potential (shared across sources)
# ---------------------------------------------------------------------------

def _element_params(n_species: int = N_SPECIES, seed: int = 7):
    rng = np.random.default_rng(seed)
    site = rng.normal(0.0, 1.0, n_species)          # per-element site energy
    depth = 0.2 + 0.8 * rng.random(n_species)        # Morse well depth factor
    radius = 0.9 + 0.6 * rng.random(n_species)       # equilibrium radius factor
    return jnp.array(site), jnp.array(depth), jnp.array(radius)


_SITE, _DEPTH, _RADIUS = _element_params()


def true_energy(species, pos):
    """species: (A,) int32 (0=pad); pos: (A,3). Smooth, bounded potential."""
    mask = species > 0
    site = _SITE[species] * mask
    d = pos[:, None, :] - pos[None, :, :]
    r2 = jnp.sum(d * d, -1) + 1e-6
    r = jnp.sqrt(r2)
    dep = jnp.sqrt(_DEPTH[species][:, None] * _DEPTH[species][None, :])
    r0 = 0.5 * (_RADIUS[species][:, None] + _RADIUS[species][None, :])
    a = 1.5
    morse = dep * (jnp.exp(-2 * a * (r - r0)) - 2 * jnp.exp(-a * (r - r0)))
    pair_mask = (mask[:, None] & mask[None, :] &
                 ~jnp.eye(species.shape[0], dtype=bool))
    cutoff = jnp.exp(-r2 / 16.0)                     # smooth locality
    e_pair = 0.5 * jnp.sum(jnp.where(pair_mask, morse * cutoff, 0.0))
    return jnp.sum(site) + e_pair


true_forces = jax.jit(jax.vmap(lambda s, p: -jax.grad(true_energy, argnums=1)(s, p)))
true_energy_batch = jax.jit(jax.vmap(true_energy))


# ---------------------------------------------------------------------------
# Structure + graph generation
# ---------------------------------------------------------------------------

def _radius_edges(pos: np.ndarray, mask: np.ndarray, cutoff: float,
                  max_edges: int):
    """Dense radius graph on one padded structure -> (src, dst, emask)."""
    A = pos.shape[0]
    d2 = ((pos[:, None] - pos[None, :]) ** 2).sum(-1)
    adj = (d2 < cutoff ** 2) & mask[:, None] & mask[None, :]
    np.fill_diagonal(adj, False)
    src, dst = np.nonzero(adj)
    n = min(len(src), max_edges)
    s = np.full(max_edges, A, np.int32)
    t = np.full(max_edges, A, np.int32)
    em = np.zeros(max_edges, bool)
    s[:n], t[:n], em[:n] = src[:n], dst[:n], True
    return s, t, em


@dataclasses.dataclass
class SourceData:
    name: str
    species: np.ndarray     # (N, A) int32
    pos: np.ndarray         # (N, A, 3) f32
    edge_src: np.ndarray    # (N, E)
    edge_dst: np.ndarray    # (N, E)
    node_mask: np.ndarray   # (N, A) bool
    edge_mask: np.ndarray   # (N, E) bool
    energy: np.ndarray      # (N,) f32 — per-atom, source-fidelity labels
    forces: np.ndarray      # (N, A, 3) f32
    e_true: np.ndarray      # (N,) f32 — per-atom ground truth (for eval)


def generate_source(name: str, n_samples: int, *, max_atoms=32, max_edges=256,
                    cutoff=2.5, seed=0) -> SourceData:
    spec = SOURCES[name]
    # crc32, not hash(): Python's str hash is salted per process, which made
    # the generated data (and comparative tests downstream) run-dependent
    rng = np.random.default_rng(seed + zlib.crc32(name.encode()) % 2 ** 16)
    lo, hi = spec["n_atoms"]
    hi = min(hi, max_atoms)
    lo = min(lo, hi)
    species = np.zeros((n_samples, max_atoms), np.int32)
    pos = np.zeros((n_samples, max_atoms, 3), np.float32)
    nmask = np.zeros((n_samples, max_atoms), bool)
    for i in range(n_samples):
        n = rng.integers(lo, hi + 1)
        species[i, :n] = rng.choice(spec["elements"], n)
        # compact cluster geometry with jitter
        p = rng.normal(0, 1.0, (n, 3)) * (n ** (1 / 3))
        pos[i, :n] = p * 0.8
        nmask[i, :n] = True

    e_true_total = np.asarray(true_energy_batch(jnp.array(species), jnp.array(pos)))
    f_true = np.asarray(true_forces(jnp.array(species), jnp.array(pos)))
    n_atoms = np.maximum(nmask.sum(1), 1)

    # fidelity transform: per-element shift + scale + noise
    shift = rng.normal(0, spec["shift_mag"], N_SPECIES)
    comp = np.zeros((n_samples, N_SPECIES))
    for z in np.unique(species):
        if z > 0:
            comp[:, z] = (species == z).sum(1)
    e_obs_total = (spec["scale"] * e_true_total + comp @ shift
                   + rng.normal(0, spec["noise"], n_samples) * n_atoms)
    f_obs = spec["scale"] * f_true + rng.normal(0, spec["noise"], f_true.shape)
    f_obs = f_obs * nmask[..., None]

    es = np.zeros((n_samples, max_edges), np.int32)
    ed = np.zeros((n_samples, max_edges), np.int32)
    em = np.zeros((n_samples, max_edges), bool)
    for i in range(n_samples):
        es[i], ed[i], em[i] = _radius_edges(pos[i], nmask[i], cutoff, max_edges)

    return SourceData(
        name=name, species=species, pos=pos, edge_src=es, edge_dst=ed,
        node_mask=nmask, edge_mask=em,
        energy=(e_obs_total / n_atoms).astype(np.float32),
        forces=f_obs.astype(np.float32),
        e_true=(e_true_total / n_atoms).astype(np.float32))


def generate_all(n_per_source: int, *, max_atoms=32, max_edges=256, seed=0,
                 sources=None) -> dict[str, SourceData]:
    return {name: generate_source(name, n_per_source, max_atoms=max_atoms,
                                  max_edges=max_edges, seed=seed)
            for name in (sources or SOURCES)}


# approximate RELATIVE sizes of the paper's five training sets (structure
# counts, §4.1 — ~24M total with a ~6x spread between the largest and
# smallest source). Only the ratios matter here: generate_mixture scales
# them down to a requested total while keeping the imbalance shape.
PAPER_REL_SIZES = {
    "ani1x": 4.9, "qm7x": 4.2, "transition1x": 9.7,
    "mptrj": 1.6, "alexandria": 3.1,
}


def generate_mixture(total: int, *, max_atoms=32, max_edges=256, seed=0,
                     rel_sizes=None) -> dict[str, SourceData]:
    """Five-source paper-shaped mixture: all SOURCES, with per-source sample
    counts proportional to the paper's dataset-size imbalance (largest-
    remainder apportionment of ``total``; every source gets >= 1 sample).
    This is the fixture the mixing/bucketing subsystem and
    ``benchmarks/bench_datapipe.py`` are exercised against."""
    rel = rel_sizes or PAPER_REL_SIZES
    names = list(rel)
    w = np.asarray([rel[n] for n in names], np.float64)
    w = w / w.sum()
    counts = np.maximum(np.floor(total * w).astype(int), 1)
    # largest remainder tops up to the exact total (deterministic)
    for i in np.argsort(-(total * w - counts), kind="stable"):
        if counts.sum() >= total:
            break
        counts[i] += 1
    return {name: generate_source(name, int(c), max_atoms=max_atoms,
                                  max_edges=max_edges, seed=seed)
            for name, c in zip(names, counts)}


def source_dicts(data: dict[str, SourceData], *, keys=(
        "species", "pos", "edge_src", "edge_dst", "node_mask", "edge_mask",
        "energy", "forces")) -> list[dict]:
    """SourceData objects -> the list-of-dicts shape Session/batchers take
    (one dict of numpy arrays per source, insertion order preserved)."""
    return [{k: getattr(sd, k) for k in keys} for sd in data.values()]


def to_batch_dict(sd: SourceData, idx: np.ndarray) -> dict:
    return {
        "species": jnp.array(sd.species[idx]),
        "pos": jnp.array(sd.pos[idx]),
        "edge_src": jnp.array(sd.edge_src[idx]),
        "edge_dst": jnp.array(sd.edge_dst[idx]),
        "node_mask": jnp.array(sd.node_mask[idx]),
        "edge_mask": jnp.array(sd.edge_mask[idx]),
        "energy": jnp.array(sd.energy[idx]),
        "forces": jnp.array(sd.forces[idx]),
    }

"""Group-aware batcher — the DDStore/ADIOS analogue.

The paper stores samples in ADIOS files and serves batches through DDStore,
an in-memory distributed cache: each DDP sub-group only ever receives batches
from ITS dataset. Here the same contract is an in-memory, task-major batcher:
``next_batch()`` returns a pytree whose every leaf is (n_tasks, B, ...), with
row t drawn only from source t — exactly what the task-sharded train step
expects (dim 0 -> task axis, dim 1 -> data axes).

Batch assembly is pure NumPy (host-side indexing + ``np.stack``): no JAX
dispatch, no host->device copies. Device placement belongs to the consumer
(``plan.shard_batch`` / ``device_put``), which lets ``repro.data.prefetch``
overlap the whole assemble+transfer chain with the running step instead of
paying a synchronous per-key ``jnp.stack`` on the critical path.

Epoch semantics: per-source shuffled cyclic iteration (sources of different
sizes wrap independently — matching the paper's weak-scaling setup where all
heads stay busy every step).
"""
from __future__ import annotations

import numpy as np


def _source_len(s) -> int:
    """Samples in a source: a dict of arrays, or any object with __len__
    and ``gather(idx) -> dict`` (e.g. ``repro.data.store.ShardedSource``)."""
    return len(s) if hasattr(s, "gather") else len(next(iter(s.values())))


class GroupBatcher:
    def __init__(self, sources: list, batch_per_task: int, *, seed=0,
                 drop_keys=()):
        """sources: one per task/source — dicts of equal-structure numpy
        arrays (dim 0 = sample dim) or gather-style readers (objects with
        ``__len__`` and ``gather(idx) -> dict``, e.g. ``ShardedSource``)."""
        self.sources = sources
        self.B = batch_per_task
        self.rngs = [np.random.default_rng(seed + i) for i in range(len(sources))]
        # _perm_rng[t] = rng state BEFORE the current permutation was drawn:
        # state() serializes that (O(1) per source) instead of the
        # permutation itself, and restore() regenerates the permutation
        self._perm_rng = [r.bit_generator.state for r in self.rngs]
        self.perm = [r.permutation(_source_len(s)) for r, s in
                     zip(self.rngs, sources)]
        self.cursor = [0] * len(sources)
        self.drop = set(drop_keys)

    def _take(self, t: int) -> np.ndarray:
        n = len(self.perm[t])
        idx = []
        c = self.cursor[t]
        while len(idx) < self.B:
            take = min(self.B - len(idx), n - c)
            idx.extend(self.perm[t][c: c + take])
            c += take
            if c >= n:
                self._perm_rng[t] = self.rngs[t].bit_generator.state
                self.perm[t] = self.rngs[t].permutation(n)
                c = 0
        self.cursor[t] = c
        return np.asarray(idx)

    def next_batch(self) -> dict:
        rows = []
        for t, s in enumerate(self.sources):
            idx = self._take(t)
            row = s.gather(idx) if hasattr(s, "gather") else \
                {k: v[idx] for k, v in s.items()}
            rows.append({k: v for k, v in row.items() if k not in self.drop})
        return {k: np.stack([np.asarray(r[k]) for r in rows], axis=0)
                for k in rows[0]}

    # -- checkpointing (JSON-serializable; see docs/data.md) ----------------

    def state(self) -> dict:
        """O(n_sources) snapshot — permutations are regenerated from the
        stored rng states on restore, never serialized."""
        return {"kind": "GroupBatcher",
                "perm_rng": list(self._perm_rng),
                "cursor": list(self.cursor)}

    def restore(self, state: dict):
        assert state.get("kind") == "GroupBatcher", state.get("kind")
        assert len(state["perm_rng"]) == len(self.rngs), (
            f"snapshot has {len(state['perm_rng'])} sources, batcher has "
            f"{len(self.rngs)} — restore into a matching construction")
        for t, st in enumerate(state["perm_rng"]):
            self.rngs[t].bit_generator.state = st
            self._perm_rng[t] = st
            self.perm[t] = self.rngs[t].permutation(len(self.perm[t]))
        self.cursor = list(state["cursor"])


class SingleBatcher:
    """Flat (no task dim) uniform-random batcher over one source dict —
    the single-task analogue of GroupBatcher for the engine's "lm" model."""

    def __init__(self, source: dict, batch: int, *, seed=0):
        self.source = source
        self.B = batch
        self.n = len(next(iter(source.values())))
        self.rng = np.random.default_rng(seed)

    def next_batch(self) -> dict:
        idx = self.rng.integers(0, self.n, self.B)
        return {k: np.asarray(v[idx]) for k, v in self.source.items()}

    def state(self) -> dict:
        return {"kind": "SingleBatcher", "rng": self.rng.bit_generator.state}

    def restore(self, state: dict):
        assert state.get("kind") == "SingleBatcher", state.get("kind")
        self.rng.bit_generator.state = state["rng"]

"""Group-aware batcher — the DDStore/ADIOS analogue.

The paper stores samples in ADIOS files and serves batches through DDStore,
an in-memory distributed cache: each DDP sub-group only ever receives batches
from ITS dataset. Here the same contract is an in-memory, task-major batcher:
``next_batch()`` returns a pytree whose every leaf is (n_tasks, B, ...), with
row t drawn only from source t — exactly what the task-sharded train step
expects (dim 0 -> task axis, dim 1 -> data axes).

Epoch semantics: per-source shuffled cyclic iteration (sources of different
sizes wrap independently — matching the paper's weak-scaling setup where all
heads stay busy every step).
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp


class GroupBatcher:
    def __init__(self, sources: list[dict], batch_per_task: int, *, seed=0,
                 drop_keys=()):
        """sources: list of dicts of equal-structure numpy arrays, one dict
        per task/source; every array's dim 0 is the sample dim."""
        self.sources = sources
        self.B = batch_per_task
        self.rngs = [np.random.default_rng(seed + i) for i in range(len(sources))]
        self.perm = [r.permutation(len(next(iter(s.values())))) for r, s in
                     zip(self.rngs, sources)]
        self.cursor = [0] * len(sources)
        self.drop = set(drop_keys)

    def _take(self, t: int) -> np.ndarray:
        n = len(self.perm[t])
        idx = []
        c = self.cursor[t]
        while len(idx) < self.B:
            take = min(self.B - len(idx), n - c)
            idx.extend(self.perm[t][c: c + take])
            c += take
            if c >= n:
                self.perm[t] = self.rngs[t].permutation(n)
                c = 0
        self.cursor[t] = c
        return np.asarray(idx)

    def next_batch(self) -> dict:
        rows = []
        for t, s in enumerate(self.sources):
            idx = self._take(t)
            rows.append({k: v[idx] for k, v in s.items() if k not in self.drop})
        out = {}
        for k in rows[0]:
            out[k] = jnp.stack([jnp.asarray(r[k]) for r in rows], axis=0)
        return out


class SingleBatcher:
    """Flat (no task dim) uniform-random batcher over one source dict —
    the single-task analogue of GroupBatcher for the engine's "lm" model."""

    def __init__(self, source: dict, batch: int, *, seed=0):
        self.source = source
        self.B = batch
        self.n = len(next(iter(source.values())))
        self.rng = np.random.default_rng(seed)

    def next_batch(self) -> dict:
        idx = self.rng.integers(0, self.n, self.B)
        return {k: jnp.asarray(v[idx]) for k, v in self.source.items()}

"""Size-bucketed dynamic batching — stop paying worst-case (A, E) padding.

Every graph batch in this repo is padded to ONE global shape
(``max_atoms``, ``max_edges``): the paper config pads every structure to
(64, 2048) even though most sources top out at ~32 atoms and a few hundred
radius-graph edges. The fused EGNN kernels do O(E) work on pad edges and
O(A) on pad nodes, so the pad fraction is wall-clock waste, not just memory
("Towards Training Billion Parameter Graph Neural Networks for Atomic
Simulations" makes size-aware batching the enabling trick for large graph
batches).

This module trims that waste while keeping the sample stream EXACT:

  * ``BucketSpec`` — a small grid of padded shapes (atom ceilings x edge
    ceilings). ``BucketSpec.from_sources`` plans the grid from the data's
    per-sample node/edge count quantiles.
  * ``BucketingBatcher`` — wraps ANY ``next_batch()`` batcher
    (``GroupBatcher`` task-major, ``MixingBatcher``/``SingleBatcher`` flat,
    ``PrefetchingBatcher``) and re-pads each emitted batch down to the
    smallest bucket shape that holds the batch's real content. The samples,
    their order, and their values are untouched — only trailing padding is
    dropped — so the stream is the single-shape stream minus pad, and every
    determinism/checkpoint property of the wrapped batcher carries over
    (``state()``/``restore()`` delegate).

Because shapes are quantized to the bucket grid, a jitted train step
compiles at most ``len(atom_buckets) * len(edge_buckets)`` variants (vs one
per distinct content size if batches were trimmed exactly), amortized over
the whole run — the classic bucketing compromise between pad waste and
recompilation.

Contract with the kernels: pad rows must be TRAILING (``node_mask`` /
``edge_mask`` front-packed, as every source in this repo emits) and masked
edges are re-pointed at the trimmed batch's pad sentinel ``A_pad`` — the
``>= n_nodes`` sentinel contract shared by ``segment_sum`` and the fused
``egnn_edge`` kernels (see ``docs/kernels.md``).
"""
from __future__ import annotations

import dataclasses

import numpy as np

ATOM_KEYS = ("species", "pos", "node_mask", "forces")
EDGE_KEYS = ("edge_src", "edge_dst", "edge_mask")


class BucketOverflowError(ValueError):
    """A sample's atom/edge count exceeds the grid's largest bucket.

    Raised by ``BucketSpec.bucket_for`` — at training time this means the
    planner did not cover the data (``from_sources`` always includes the
    stored cap, so it cannot happen there); at serving time it is the
    admission-control signal: the request cannot be padded to any compiled
    shape and must be rejected, not silently truncated."""


def _ceil_grid(counts: np.ndarray, n_buckets: int, cap: int,
               multiple: int) -> tuple:
    """Ascending pad ceilings covering ``counts``: quantile cut points
    rounded up to ``multiple``, deduplicated, capped by (and always
    including) ``cap`` so every sample has a bucket."""
    qs = np.quantile(counts, np.linspace(0, 1, n_buckets + 1)[1:])
    grid = sorted({min(int(-(-max(q, 1) // multiple) * multiple), cap)
                   for q in qs} | {cap})
    return tuple(grid)


@dataclasses.dataclass(frozen=True)
class BucketSpec:
    """A small grid of padded graph shapes.

    ``atom_buckets``/``edge_buckets``: ascending pad ceilings; the last
    entry must dominate every sample (``from_sources`` guarantees this by
    construction — it always includes the stored pad shape)."""
    atom_buckets: tuple
    edge_buckets: tuple

    def __post_init__(self):
        for name, g in (("atom", self.atom_buckets),
                        ("edge", self.edge_buckets)):
            assert len(g) >= 1 and list(g) == sorted(set(g)), \
                f"{name}_buckets must be ascending and unique, got {g}"

    @property
    def n_shapes(self) -> int:
        return len(self.atom_buckets) * len(self.edge_buckets)

    def bucket_for(self, n_atoms: int, n_edges: int) -> tuple:
        """Public single-sample lookup: the smallest (A_pad, E_pad) bucket
        shape covering the given content (ceilings are inclusive). Counts
        beyond the grid cap raise ``BucketOverflowError`` with the offending
        count and the cap — training planners must cover the data; serving
        admission uses the error to reject oversized requests up front."""
        if n_atoms < 0 or n_edges < 0:
            raise ValueError(f"negative content counts: "
                             f"({n_atoms} atoms, {n_edges} edges)")
        a = next((b for b in self.atom_buckets if b >= n_atoms), None)
        e = next((b for b in self.edge_buckets if b >= n_edges), None)
        if a is None:
            raise BucketOverflowError(
                f"{n_atoms} atoms exceeds the grid cap "
                f"{self.atom_buckets[-1]} (atom_buckets={self.atom_buckets})")
        if e is None:
            raise BucketOverflowError(
                f"{n_edges} edges exceeds the grid cap "
                f"{self.edge_buckets[-1]} (edge_buckets={self.edge_buckets})")
        return a, e

    def ceil(self, n_atoms: int, n_edges: int) -> tuple:
        """Alias of ``bucket_for`` (the original batch-path name)."""
        return self.bucket_for(n_atoms, n_edges)

    @classmethod
    def from_sources(cls, sources, *, n_atom_buckets: int = 4,
                     n_edge_buckets: int = 4, atom_multiple: int = 8,
                     edge_multiple: int = 64) -> "BucketSpec":
        """Plan the grid from per-sample node/edge counts (quantile cuts,
        rounded up to hardware-friendly multiples). sources: dicts with
        ``node_mask``/``edge_mask`` arrays, ``SourceData`` objects, or
        gather-style readers (``__len__`` + ``gather``, e.g.
        ``ShardedSource``). Planning touches every sample's MASKS once —
        gather-style sources are read in chunks and only the per-sample
        counts are kept, never the whole dataset (the reader's own shard
        cache warms as a side effect, same as training would)."""
        def mask_counts(s):
            """-> per-sample (n_atoms, n_edges, A_cap, E_cap) for one
            source, without materializing more than a chunk of it."""
            if hasattr(s, "gather"):
                a_counts, e_counts = [], []
                a_cap = e_cap = 0
                for start in range(0, len(s), 4096):
                    sub = s.gather(np.arange(start, min(start + 4096, len(s))))
                    nm, em = np.asarray(sub["node_mask"]), \
                        np.asarray(sub["edge_mask"])
                    a_counts.append(nm.sum(-1).ravel())
                    e_counts.append(em.sum(-1).ravel())
                    a_cap, e_cap = nm.shape[-1], em.shape[-1]
                return (np.concatenate(a_counts), np.concatenate(e_counts),
                        a_cap, e_cap)
            nm = np.asarray(s["node_mask"] if isinstance(s, dict)
                            else s.node_mask)
            em = np.asarray(s["edge_mask"] if isinstance(s, dict)
                            else s.edge_mask)
            return (nm.sum(-1).ravel(), em.sum(-1).ravel(),
                    nm.shape[-1], em.shape[-1])

        per_source = [mask_counts(s) for s in sources]
        atoms = np.concatenate([p[0] for p in per_source])
        edges = np.concatenate([p[1] for p in per_source])
        a_cap = per_source[0][2]
        e_cap = per_source[0][3]
        return cls(_ceil_grid(atoms, n_atom_buckets, a_cap, atom_multiple),
                   _ceil_grid(edges, n_edge_buckets, e_cap, edge_multiple))


def pad_fraction(batch: dict) -> dict:
    """Fraction of pad rows in one batch: ``{"atoms": ..., "edges": ...}``.
    This is the wall-clock-waste metric bench_datapipe.py tracks."""
    return {"atoms": 1.0 - float(np.mean(batch["node_mask"])),
            "edges": 1.0 - float(np.mean(batch["edge_mask"]))}


class BucketingBatcher:
    """Re-pad every batch of a wrapped batcher down to its bucket shape.

    Works on flat ``(B, A, ...)`` and task-major ``(T, B, A, ...)`` batches
    (the atom/edge axis is located from ``node_mask.ndim``). Keys outside
    ``ATOM_KEYS``/``EDGE_KEYS`` pass through untouched (e.g. ``energy``,
    ``source_id``).

    strict (default True): assert per batch that trimming dropped no real
    atom/edge (masks must be front-packed — the contract every store/
    generator in this repo satisfies). Costs two mask sums per batch; set
    False on hot paths once a pipeline is validated."""

    def __init__(self, batcher, spec: BucketSpec, *, strict: bool = True):
        self.batcher = batcher
        self.spec = spec
        self.strict = strict
        self.shapes_seen: set = set()   # distinct (A_pad, E_pad) emitted

    def next_batch(self) -> dict:
        b = self.batcher.next_batch()
        nm, em = np.asarray(b["node_mask"]), np.asarray(b["edge_mask"])
        axis = nm.ndim - 1               # atom/edge axis: 1 flat, 2 task-major
        a_pad, e_pad = self.spec.bucket_for(int(nm.sum(-1).max(initial=0)),
                                            int(em.sum(-1).max(initial=0)))
        self.shapes_seen.add((a_pad, e_pad))
        out = {}
        for k, v in b.items():
            v = np.asarray(v)
            if k in ATOM_KEYS:
                v = v[(slice(None),) * axis + (slice(0, a_pad),)]
            elif k in EDGE_KEYS:
                v = v[(slice(None),) * axis + (slice(0, e_pad),)]
            out[k] = v
        # masked edges -> the TRIMMED pad sentinel (>= n_nodes contract);
        # stored values point at the stored shape's A and would still be
        # "out of range", but re-pointing keeps the invariant explicit and
        # the gather clamps cheap
        em_t = out["edge_mask"]
        for k in ("edge_src", "edge_dst"):
            out[k] = np.where(em_t, out[k], a_pad).astype(out[k].dtype)
        if self.strict:
            assert out["node_mask"].sum() == nm.sum(), \
                "bucket trim dropped real atoms — node_mask not front-packed"
            assert em_t.sum() == em.sum(), \
                "bucket trim dropped real edges — edge_mask not front-packed"
        return out

    # -- delegation ---------------------------------------------------------

    def state(self) -> dict:
        # the bucketed STREAM is a pure function of the wrapped batcher, but
        # ``shapes_seen`` is real session state: it is the compiled-shape
        # surface RecompileSanitizer budget checks audit, and a resumed run
        # that dropped it would under-report until every shape recurred
        return {"kind": "BucketingBatcher",
                "shapes_seen": sorted(list(s) for s in self.shapes_seen),
                "inner": self.batcher.state()}

    def restore(self, state: dict):
        if isinstance(state, dict) and state.get("kind") == "BucketingBatcher":
            self.shapes_seen = {tuple(s) for s in state["shapes_seen"]}
            self.batcher.restore(state["inner"])
        else:
            # pre-scale-out snapshot: bare inner state, no shapes recorded
            self.batcher.restore(state)

    @property
    def sources(self):
        return self.batcher.sources

    def close(self):
        if hasattr(self.batcher, "close"):
            self.batcher.close()

"""Multi-source mixing sampler — imbalance-aware source weighting.

The paper pre-trains on five sources whose sizes differ by ~6x (Transition1x
alone is ~40% of the 24M+ structures). A fixed per-source round-robin
(``GroupBatcher``) keeps every head busy but gives small sources the same
gradient share as large ones only via the loss; for SINGLE-head models over
mixed data (the paper's GFM-Baseline-All) the batch composition itself is
the knob. This module owns that knob:

  * ``mix_weights`` — per-source sampling weights from source sizes:
    ``w_s ∝ n_s^(1/temperature)``, normalized. ``temperature=1`` is
    proportional sampling (an epoch of the pooled data), ``temperature→∞``
    is uniform, and values in between flatten the imbalance — the standard
    multilingual-pretraining temperature trick carried to multi-fidelity
    atomistic sources.
  * ``MixingBatcher`` — flat (no task dim) batcher over N sources whose
    batches are composed according to those weights by a DETERMINISTIC
    schedule (smooth weighted round-robin, not multinomial draws): after k
    batches, source s has contributed ``k*B*w_s`` samples to within
    ``len(sources)`` — so the realized mixture tracks the target weights
    exactly, not just in expectation. Within each source, samples follow
    the same shuffled-cyclic epoch semantics as ``GroupBatcher``.

Both speak the ``next_batch()`` contract, so ``Prefetcher`` and
``BucketingBatcher`` wrap a ``MixingBatcher`` unchanged, and its
``state()``/``restore()`` make the stream checkpointable (see
``docs/data.md``).

For MULTI-head (task-major) sessions every head must see its own source
every step, so batch composition is fixed; there the same weights apply as
per-task LOSS weights instead — ``Session`` wires ``SessionConfig.mixing``
to whichever lever fits the model flavour.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from .loader import _source_len


@dataclasses.dataclass(frozen=True)
class MixingConfig:
    """Declarative mixing policy. ``weights=None`` derives imbalance-aware
    defaults from the source sizes via ``mix_weights(sizes, temperature)``;
    explicit ``weights`` (any positive scale — they are normalized) win."""
    temperature: float = 1.0
    weights: tuple | None = None
    # emit a "source_id" (B,) int32 key in every batch (e.g. for per-source
    # metrics over a mixed stream); off by default so batch pytrees keep the
    # exact key set the model losses expect
    emit_source: bool = False

    def resolve(self, sizes) -> np.ndarray:
        return mix_weights(sizes, temperature=self.temperature,
                           weights=self.weights)


def mix_weights(sizes, *, temperature: float = 1.0,
                weights=None) -> np.ndarray:
    """Normalized per-source sampling weights.

    sizes: per-source sample counts. With ``weights=None``:
    ``w_s ∝ sizes[s] ** (1/temperature)`` — proportional at 1.0, uniform as
    temperature → ∞. Explicit ``weights`` override the size-derived rule and
    are only normalized."""
    if weights is not None:
        w = np.asarray(weights, np.float64)
        assert w.ndim == 1 and (w > 0).all(), \
            f"explicit mixing weights must be positive, got {w}"
    else:
        assert temperature > 0, f"temperature must be > 0, got {temperature}"
        n = np.asarray([float(s) for s in sizes], np.float64)
        assert (n > 0).all(), f"source sizes must be positive, got {n}"
        w = n ** (1.0 / temperature)
    return w / w.sum()


class MixingBatcher:
    """Weighted mixture batcher over N sources -> flat ``(B, ...)`` batches.

    sources: dicts of equal-structure numpy arrays (dim 0 = sample dim) or
    gather-style readers (``__len__`` + ``gather(idx) -> dict``, e.g.
    ``ShardedSource``). All sources must share a key set (drop per-source
    extras via ``drop_keys``).

    Schedule: each of the B slots goes to the source with the highest
    accumulated credit (``credit += w`` per slot, winner pays 1 — smooth
    weighted round-robin), then the composition order within the batch is a
    seeded shuffle — deterministic, counts are non-negative by
    construction, and realized proportions track the weights exactly.
    Per-source sample order is shuffled-cyclic (every sample of a source
    visited once per local epoch, reshuffled on wraparound).
    """

    def __init__(self, sources: list, batch: int, *,
                 mixing: MixingConfig | None = None, seed: int = 0,
                 drop_keys=(), task_major: bool = False):
        assert len(sources) >= 1, "MixingBatcher needs at least one source"
        self.sources = list(sources)
        self.B = batch
        self.mixing = mixing or MixingConfig()
        # task_major=True prepends a length-1 task dim to every leaf —
        # the batch shape a single-branch MultiTaskModel (gfm-baseline over
        # a mixture) expects from its task-major loss
        self.task_major = task_major
        self.sizes = [_source_len(s) for s in self.sources]
        self.weights = self.mixing.resolve(self.sizes)
        self.drop = set(drop_keys)
        # one rng for the batch-composition shuffle + one per source for the
        # epoch permutations (mirrors GroupBatcher's per-source streams).
        # _perm_rng[s] is each rng's state BEFORE its current permutation
        # was drawn — state() stores that instead of the O(source-size)
        # permutation itself, and restore() regenerates the permutation
        self.rng = np.random.default_rng(seed)
        self.rngs = [np.random.default_rng(seed + 1 + i)
                     for i in range(len(self.sources))]
        self._perm_rng = [r.bit_generator.state for r in self.rngs]
        self.perm = [r.permutation(n) for r, n in zip(self.rngs, self.sizes)]
        self.cursor = [0] * len(self.sources)
        self.credit = np.zeros(len(self.sources), np.float64)

    # -- deterministic schedule --------------------------------------------

    def _counts(self) -> np.ndarray:
        """Per-source sample counts for the next batch (sums to B, every
        count >= 0). Smooth weighted round-robin: the per-source credit
        drift stays bounded, so cumulative counts track ``k*B*w_s``. A
        zero-weight (quarantined) source gains no credit AND is masked out
        of the argmax — residual credit from before a ``set_weights`` call
        must not win it one last slot."""
        counts = np.zeros(len(self.weights), np.int64)
        live = self.weights > 0
        for _ in range(self.B):
            self.credit += self.weights
            pick = int(np.argmax(np.where(live, self.credit, -np.inf)))
            self.credit[pick] -= 1.0
            counts[pick] += 1
        return counts

    def set_weights(self, weights):
        """Replace the sampling weights in place (renormalized) — the
        quarantine lever: zero a bad source's weight and it stops appearing
        in batches from the NEXT draw on (already-prefetched batches may
        still contain it). At least one source must stay positive.

        A source coming BACK from quarantine (weight 0 -> positive) restarts
        with zero credit: its stale pre-quarantine credit would otherwise
        burst-win early slots and skew cumulative counts off the ``k*B*w_s``
        schedule the smooth round-robin guarantees."""
        w = np.asarray(weights, np.float64)
        assert w.shape == self.weights.shape, \
            f"{w.shape} weights for {self.weights.shape} sources"
        assert (w >= 0).all(), f"weights must be >= 0, got {w}"
        assert w.sum() > 0, "cannot zero every source's weight"
        reenabled = (self.weights <= 0) & (w > 0)
        self.weights = w / w.sum()
        self.credit[reenabled] = 0.0

    def _take(self, s: int, k: int) -> np.ndarray:
        """k sample indices from source s, shuffled-cyclic."""
        n = len(self.perm[s])
        idx = []
        c = self.cursor[s]
        while len(idx) < k:
            take = min(k - len(idx), n - c)
            idx.extend(self.perm[s][c: c + take])
            c += take
            if c >= n:
                self._perm_rng[s] = self.rngs[s].bit_generator.state
                self.perm[s] = self.rngs[s].permutation(n)
                c = 0
        self.cursor[s] = c
        return np.asarray(idx, np.int64)

    def next_batch(self) -> dict:
        counts = self._counts()
        rows, src_ids = [], []
        for s, k in enumerate(counts):
            if k == 0:
                continue
            idx = self._take(s, int(k))
            src = self.sources[s]
            row = src.gather(idx) if hasattr(src, "gather") else \
                {kk: v[idx] for kk, v in src.items()}
            rows.append({kk: np.asarray(v) for kk, v in row.items()
                         if kk not in self.drop})
            src_ids.append(np.full(int(k), s, np.int32))
        order = self.rng.permutation(self.B)
        batch = {k: np.concatenate([r[k] for r in rows], axis=0)[order]
                 for k in rows[0]}
        if self.mixing.emit_source:
            batch["source_id"] = np.concatenate(src_ids)[order]
        if self.task_major:
            batch = {k: v[None] for k, v in batch.items()}
        return batch

    # -- checkpointing ------------------------------------------------------

    def state(self) -> dict:
        """O(n_sources) snapshot (permutations are NOT serialized — only
        the rng state that generated them), cheap enough for the prefetch
        producer to capture per batch."""
        return {
            "kind": "MixingBatcher",
            "rng": self.rng.bit_generator.state,
            "perm_rng": list(self._perm_rng),
            "cursor": list(self.cursor),
            "credit": self.credit.tolist(),
            "weights": self.weights.tolist(),
        }

    def restore(self, state: dict):
        assert state.get("kind") == "MixingBatcher", state.get("kind")
        assert len(state["perm_rng"]) == len(self.rngs), (
            f"snapshot has {len(state['perm_rng'])} sources, batcher has "
            f"{len(self.rngs)} — restore into a matching construction")
        self.rng.bit_generator.state = state["rng"]
        for s, st in enumerate(state["perm_rng"]):
            self.rngs[s].bit_generator.state = st
            self._perm_rng[s] = st
            self.perm[s] = self.rngs[s].permutation(self.sizes[s])
        self.cursor = list(state["cursor"])
        self.credit = np.asarray(state["credit"], np.float64)
        if "weights" in state:   # absent in pre-resilience snapshots
            self.weights = np.asarray(state["weights"], np.float64)

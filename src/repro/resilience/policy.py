"""Preemption-safe checkpointing: policy, retention, signals, retried IO.

``CheckpointPolicy`` decides WHEN to checkpoint (every N steps, plus a
step-0 rollback anchor); ``CheckpointManager`` decides WHERE and HOW —
one ``ckpt-<step>`` triple (atomic ``.npz`` + ``.meta.json`` +
``.datapipe.json``, see ``repro.train.checkpoint``) per saved step inside
one directory, retention of the last K plus the best-metric checkpoint,
and every filesystem touch wrapped in ``repro.resilience.retry`` backoff.

``PreemptionHandler`` turns SIGTERM/SIGUSR1 (the two signals SLURM-class
schedulers deliver before reclaiming a node) into a cooperative flag the
training loop polls between steps: flush a final checkpoint, exit cleanly,
resume elsewhere — and ``trigger()`` lets the fault-injection harness
deliver the same preemption deterministically, without a real signal.
"""
from __future__ import annotations

import contextlib
import dataclasses
import glob
import json
import os
import signal as _signal
import threading
import time
from typing import Any

from repro.train import checkpoint

from .retry import with_retry


@dataclasses.dataclass(frozen=True)
class CheckpointPolicy:
    """every_steps: checkpoint cadence (0 = only the anchor + final flush).
    keep_last: retained trailing checkpoints (older ones are pruned).
    keep_best: additionally retain the best-``metric`` checkpoint ever
    written (smaller is better — it is the loss).
    save_initial: write a step-0 anchor before the first step, so rollback
    always has a target even if the very first steps trip."""
    every_steps: int = 50
    keep_last: int = 3
    keep_best: bool = True
    save_initial: bool = True

    def should_save(self, step: int) -> bool:
        return self.every_steps > 0 and step > 0 \
            and step % self.every_steps == 0


class CheckpointManager:
    """Retention + retried IO over ``repro.train.checkpoint`` in one dir.

    Every write goes through tmp-file + ``os.replace`` (checkpoint.py), so
    a manager directory only ever contains complete files; ``checkpoints()``
    therefore trusts the directory listing as its index — no separate index
    file that could itself desynchronize.

    ``fault_hook`` is the deterministic-fault-injection seam: when set, it
    is invoked at the START of every raw save attempt and may raise (the
    retry wrapper then backs off and re-attempts). ``arm_failures(n)`` is
    the canned hook used by ``FaultSchedule``: fail the next ``n`` attempts
    with ``CheckpointWriteError``, then succeed.
    """

    def __init__(self, directory: str, policy: CheckpointPolicy | None = None,
                 *, attempts: int = 3, base_delay: float = 0.05,
                 sleep=time.sleep):
        self.dir = directory
        self.policy = policy or CheckpointPolicy()
        self.io_retries = 0
        self.fault_hook = None
        self._armed = 0

        def _count(attempt, exc):
            self.io_retries += 1

        self._retry = with_retry(attempts=attempts, base_delay=base_delay,
                                 exceptions=(OSError, IOError),
                                 sleep=sleep, on_retry=_count)
        os.makedirs(directory, exist_ok=True)

    # -- fault injection seam ------------------------------------------------

    def arm_failures(self, n: int = 1):
        """The next ``n`` raw save attempts raise ``CheckpointWriteError``
        (an OSError, so the retry wrapper treats it as transient)."""
        self._armed += int(n)

    def _maybe_fail(self, stage: str):
        if self._armed > 0:
            self._armed -= 1
            raise CheckpointWriteError(
                f"injected checkpoint {stage} failure "
                f"({self._armed} more armed)")
        if self.fault_hook is not None:
            self.fault_hook(stage)

    # -- paths / listing -----------------------------------------------------

    def path_for(self, step: int) -> str:
        return os.path.join(self.dir, f"ckpt-{step:08d}")

    def checkpoints(self) -> list[tuple[int, str]]:
        """(step, path-without-.npz) pairs, ascending by step."""
        out = []
        for npz in glob.glob(os.path.join(self.dir, "ckpt-*.npz")):
            base = npz[:-len(".npz")]
            try:
                out.append((int(base.rsplit("-", 1)[1]), base))
            except ValueError:
                continue
        return sorted(out)

    def latest(self) -> str | None:
        cks = self.checkpoints()
        return cks[-1][1] if cks else None

    def latest_step(self) -> int | None:
        cks = self.checkpoints()
        return cks[-1][0] if cks else None

    def best(self) -> str | None:
        """Path of the smallest-metric checkpoint (None when no saved
        checkpoint carries a metric)."""
        best, best_m = None, None
        for _, path in self.checkpoints():
            try:
                m = checkpoint.load_metadata(path).get("metric")
            except (FileNotFoundError, json.JSONDecodeError):
                continue
            if m is not None and (best_m is None or m < best_m):
                best, best_m = path, m
        return best

    # -- save / load ---------------------------------------------------------

    def save(self, state: Any, *, datapipe: dict | None = None,
             metric: float | None = None, metadata: dict | None = None) -> str:
        """Write the full TrainState (params + optimizer + step + rng +
        guard) plus the datapipe sidecar for ``step = int(state.step)``,
        with retries, then prune per the policy. Returns the path."""
        step = int(state.step)
        path = self.path_for(step)
        meta = dict(metadata or {}, step=step)
        if metric is not None:
            meta["metric"] = float(metric)

        def _write():
            self._maybe_fail("save")
            checkpoint.save(path, {"state": state}, metadata=meta,
                            datapipe=datapipe)

        self._retry(_write)()
        self.prune()
        return path

    def load(self, path: str, template: Any) -> Any:
        """Restore a TrainState saved by ``save``; template supplies tree
        structure, dtypes and shardings (the session's live state works)."""
        return self._retry(
            lambda: checkpoint.restore(path, {"state": template}))()["state"]

    def load_latest(self, template: Any) -> tuple[str, Any]:
        path = self.latest()
        if path is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        return path, self.load(path, template)

    # -- retention -----------------------------------------------------------

    def prune(self):
        """Delete everything but the last ``keep_last`` checkpoints (and the
        best-metric one, when ``keep_best``)."""
        cks = self.checkpoints()
        keep = {p for _, p in cks[-max(self.policy.keep_last, 1):]}
        if self.policy.keep_best:
            b = self.best()
            if b is not None:
                keep.add(b)
        for _, path in cks:
            if path in keep:
                continue
            for suffix in (".npz", ".meta.json", ".datapipe.json"):
                with contextlib.suppress(FileNotFoundError):
                    os.remove(path + suffix)


class CheckpointWriteError(OSError):
    """Injected (or wrapped) checkpoint-write failure; an OSError so the
    retry layer classifies it as transient."""


class PreemptionHandler:
    """Cooperative preemption flag, settable by OS signal or by hand.

    install=True registers handlers for ``signals`` (default SIGTERM +
    SIGUSR1) that set the flag; the previous handlers are saved and
    restored by ``uninstall()`` / context-manager exit. Installation is
    skipped (installed == False) off the main thread, where CPython
    forbids ``signal.signal`` — the flag still works via ``trigger()``.
    """

    DEFAULT_SIGNALS = (_signal.SIGTERM, _signal.SIGUSR1)

    def __init__(self, install: bool = False, signals=None):
        self.signals = tuple(signals) if signals is not None \
            else self.DEFAULT_SIGNALS
        self._flag = threading.Event()
        self._prev: dict = {}
        self.installed = False
        self.received: int | None = None
        if install:
            self.install()

    def install(self) -> bool:
        try:
            for sig in self.signals:
                self._prev[sig] = _signal.signal(sig, self._on_signal)
            self.installed = True
        except ValueError:   # not the main thread
            self.installed = False
        return self.installed

    def uninstall(self):
        for sig, prev in self._prev.items():
            with contextlib.suppress(ValueError):
                _signal.signal(sig, prev)
        self._prev.clear()
        self.installed = False

    def _on_signal(self, signum, frame):
        self.received = signum
        self._flag.set()

    def trigger(self, signum: int | None = None):
        """Deliver a simulated preemption (the fault-injection path)."""
        self.received = signum
        self._flag.set()

    @property
    def triggered(self) -> bool:
        return self._flag.is_set()

    def clear(self):
        self._flag.clear()
        self.received = None

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.uninstall()
        return False

"""Guarded stepping: loss/grad finiteness + EMA spike checks, in-step.

At 24M+-structure multi-fidelity scale the occasional poisoned batch — a
corrupt record, an outlier geometry, a fidelity source whose labels go bad —
is routine, and one NaN gradient is enough to destroy a parameter tree
forever. The guard makes every optimizer update conditional:

    ok = isfinite(loss) & isfinite(|grads|) & (loss <= spike_factor * EMA)

The select lives INSIDE the jitted step (``make_guarded_step``), so it is
donation-safe: a tripped step returns the incoming state unchanged (params,
optimizer moments, step counter and all) without any host round-trip of the
parameter tree. The EMA, warmup counter and consecutive-trip counter travel
in ``TrainState.guard`` (a ``GuardState`` of scalars), so they are part of
every checkpoint and every rollback for free.

``StepGuard`` is the host-side half: it reads the one ``guard_ok`` scalar
per step (the only forced sync the guard adds), counts consecutive trips to
decide when the runner should roll back to the last good checkpoint, and
attributes trips to fidelity sources (via the non-finite / spiking entries
of ``per_task_loss``) so a persistently bad source can be quarantined —
its loss weight zeroed and its batch slice sanitized — instead of killing
the run. See ``repro.resilience.runner`` for the loop that acts on it and
docs/robustness.md for the lifecycle.
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.taskpar import MultiTaskModel
from repro.engine.state import StepOutput, TrainState
from repro.engine.step import make_grad_fn, with_grad_accum
from repro.optim.adamw import global_norm


@dataclasses.dataclass(frozen=True)
class GuardConfig:
    """Knobs for guarded stepping (docs/robustness.md has the full table).

    spike_factor: trip when ``loss > spike_factor * |EMA| + spike_slack``
        (only after ``warmup_steps`` accepted steps have seeded the EMA).
    ema_decay: EMA smoothing of the accepted-step loss. Tripped losses never
        enter the EMA, so one spike cannot drag the threshold up after it.
    warmup_steps: accepted steps before the spike check arms (finiteness is
        always checked, from step one).
    max_consecutive_trips: consecutive tripped steps before the runner rolls
        params + optimizer + datapipe back to the last good checkpoint.
    quarantine_after: per-source attributed trips before the runner zeroes
        that source's loss weight (0 = never quarantine).
    """
    spike_factor: float = 4.0
    spike_slack: float = 0.0
    ema_decay: float = 0.98
    warmup_steps: int = 10
    max_consecutive_trips: int = 3
    quarantine_after: int = 0


class GuardState(NamedTuple):
    """Device-resident guard scalars, threaded through ``TrainState.guard``:
    they ride every checkpoint/rollback with the params."""
    ema: jnp.ndarray     # () f32 — EMA of ACCEPTED losses
    good: jnp.ndarray    # () i32 — accepted steps seen (arms the spike check)
    trips: jnp.ndarray   # () i32 — consecutive tripped steps

    @classmethod
    def init(cls) -> "GuardState":
        return cls(ema=jnp.zeros((), jnp.float32),
                   good=jnp.zeros((), jnp.int32),
                   trips=jnp.zeros((), jnp.int32))


def make_guarded_train_step(grad_fn, optimizer, gcfg: GuardConfig):
    """Wrap a grad_fn + optimizer into a guarded TrainStep.

    Same signature as ``make_train_step``'s result, but the state must carry
    a ``GuardState`` (``TrainState.guard``) and a tripped step returns the
    incoming state unchanged — params, moments AND step counter (the runner
    advances by ``state.step``, so a skipped update is retried against the
    next batch, not silently dropped from the schedule)."""

    def step(state: TrainState, batch):
        g = state.guard
        loss, metrics, grads = grad_fn(state.params, batch)
        gnorm = global_norm(grads)
        # one non-finite anywhere in the grads makes the global norm
        # non-finite (inf/nan propagate through square+sum), so two scalar
        # checks cover the whole tree
        finite = jnp.isfinite(loss) & jnp.isfinite(gnorm)
        warm = g.good >= gcfg.warmup_steps
        threshold = jnp.where(
            warm, gcfg.spike_factor * jnp.abs(g.ema) + gcfg.spike_slack,
            jnp.inf).astype(jnp.float32)
        ok = finite & (loss <= threshold)

        new_params, new_opt = optimizer.update(grads, state.opt_state,
                                               state.params)
        sel = lambda new, old: jnp.where(ok, new, old)  # noqa: E731
        params = jax.tree_util.tree_map(sel, new_params, state.params)
        opt = jax.tree_util.tree_map(sel, new_opt, state.opt_state)
        # tripped losses never update the EMA; the first accepted loss
        # seeds it outright (no zero-bias from the init value)
        ema = jnp.where(
            ok, jnp.where(g.good > 0,
                          gcfg.ema_decay * g.ema +
                          (1.0 - gcfg.ema_decay) * loss.astype(jnp.float32),
                          loss.astype(jnp.float32)),
            g.ema)
        oki = ok.astype(jnp.int32)
        guard = GuardState(ema=ema, good=g.good + oki,
                           trips=jnp.where(ok, 0, g.trips + 1))
        new_state = TrainState(params=params, opt_state=opt,
                               step=state.step + oki, rng=state.rng,
                               guard=guard)
        metrics = dict(metrics, guard_ok=ok.astype(jnp.float32),
                       guard_trips=guard.trips.astype(jnp.float32),
                       guard_gnorm=gnorm, guard_threshold=threshold)
        return new_state, StepOutput(loss=loss, metrics=metrics)

    return step


def make_guarded_step(model, optimizer, plan=None, *, guard: GuardConfig,
                      accum: int = 1, task_weights=None):
    """``repro.engine.make_step`` with the guard threaded in: one call from
    model + optimizer (+ plan) to an uncompiled guarded TrainStep."""
    grad_fn = make_grad_fn(model, plan, task_weights=task_weights)
    axis = 1 if isinstance(model, MultiTaskModel) else 0
    grad_fn = with_grad_accum(grad_fn, accum, axis=axis)
    return make_guarded_train_step(grad_fn, optimizer, guard)


class StepGuard:
    """Host-side guard bookkeeping over a guarded step's metrics.

    ``observe(out)`` syncs exactly one scalar (``guard_ok``) per step; on a
    trip it additionally pulls ``per_task_loss`` to attribute the trip to a
    fidelity source: non-finite entries are charged directly, a finite
    spike is charged to the per-task-loss argmax. ``should_rollback()`` and
    ``quarantine_candidates()`` are the two decisions the resilient runner
    acts on."""

    def __init__(self, cfg: GuardConfig, n_sources: int = 0):
        self.cfg = cfg
        self.consecutive = 0
        self.trips_total = 0
        self.rollbacks = 0
        self.source_trips = np.zeros(max(n_sources, 0), np.int64)
        self.quarantined: set[int] = set()

    def observe(self, out: StepOutput) -> bool:
        """True if the step was accepted. Counts trips and attributes them
        to sources when ``per_task_loss`` is available."""
        m = out.metrics
        ok = bool(np.asarray(m["guard_ok"]))
        if ok:
            self.consecutive = 0
            return True
        self.consecutive += 1
        self.trips_total += 1
        pt = m.get("per_task_loss")
        if pt is not None and self.source_trips.size:
            pt = np.asarray(pt, np.float64)
            bad = ~np.isfinite(pt)
            if bad.any():
                self.source_trips[bad] += 1
            else:  # finite spike: charge the loudest source
                self.source_trips[int(np.argmax(pt))] += 1
        return False

    def should_rollback(self) -> bool:
        return self.consecutive >= self.cfg.max_consecutive_trips

    def on_rollback(self):
        """Rollback restored the last good state: the consecutive streak is
        over (per-source attribution is cumulative — it survives, so a
        persistently bad source still reaches quarantine through repeated
        rollback cycles)."""
        self.consecutive = 0
        self.rollbacks += 1

    def quarantine_candidates(self) -> list[int]:
        """Sources whose attributed trips crossed ``quarantine_after`` and
        that are not already quarantined (empty when the knob is off)."""
        if self.cfg.quarantine_after <= 0:
            return []
        hot = np.nonzero(self.source_trips >= self.cfg.quarantine_after)[0]
        return [int(s) for s in hot if int(s) not in self.quarantined]

    def mark_quarantined(self, sources):
        self.quarantined |= {int(s) for s in sources}

    def report(self) -> dict:
        return {"trips": self.trips_total, "rollbacks": self.rollbacks,
                "source_trips": self.source_trips.tolist(),
                "quarantined": sorted(self.quarantined)}


def zero_task_slices(batch, tasks) -> Any:
    """Sanitize a task-major batch: overwrite the given task slices with
    inert zeros (floats -> 0.0, ints -> 0, masks -> False). Zeroing the
    LOSS weight of a quarantined source is not enough on its own: a zero
    cotangent back-propagated through non-finite activations is still
    non-finite (0 * nan == nan), so the poisoned rows must never enter the
    forward at all."""
    tasks = sorted(int(t) for t in tasks)
    if not tasks:
        return batch

    def scrub(x):
        x = jnp.asarray(x)
        for t in tasks:
            x = x.at[t].set(jnp.zeros((), x.dtype))
        return x

    return jax.tree_util.tree_map(scrub, batch)

"""Retry-with-exponential-backoff for checkpoint/store IO.

On Perlmutter/Aurora/Frontier-class machines the parallel filesystem is a
shared, occasionally-flaky resource: a checkpoint write can fail transiently
(quota races, metadata-server hiccups, preemption of a sibling job) without
the run itself being unhealthy. ``with_retry`` wraps any callable so those
transient failures cost a bounded backoff instead of the whole run.

The sleeper is injectable so tests (and the deterministic fault-injection
harness, ``repro.resilience.faults``) never wall-clock sleep, and the delay
sequence is fully deterministic: ``base_delay * factor**attempt`` — no
jitter, because a single-process trainer has nothing to decorrelate from
and reproducible recovery timelines are worth more than thundering-herd
protection here.
"""
from __future__ import annotations

import functools
import time


class RetryError(RuntimeError):
    """All attempts failed; ``__cause__`` is the last underlying error."""

    def __init__(self, msg: str, attempts: int):
        super().__init__(msg)
        self.attempts = attempts


def with_retry(fn=None, *, attempts: int = 3, base_delay: float = 0.05,
               factor: float = 2.0, exceptions=(OSError,),
               sleep=time.sleep, on_retry=None):
    """Wrap ``fn`` so it is retried up to ``attempts`` times.

    attempts:   total tries (>= 1); the last failure raises ``RetryError``
                chained to the underlying exception.
    base_delay: seconds before the first retry; each further retry waits
                ``factor`` times longer.
    exceptions: exception types considered transient. Anything else
                propagates immediately (a ``ValueError`` from a corrupt
                argument is not cured by waiting).
    sleep:      injectable sleeper (tests pass a recorder).
    on_retry:   optional ``on_retry(attempt_index, exc)`` observer, called
                before each backoff sleep (e.g. to count IO retries).

    Usable directly (``with_retry(fn, ...)``) or as a decorator
    (``@with_retry(attempts=5)``).
    """
    assert attempts >= 1, f"attempts must be >= 1, got {attempts}"
    if fn is None:
        return functools.partial(with_retry, attempts=attempts,
                                 base_delay=base_delay, factor=factor,
                                 exceptions=exceptions, sleep=sleep,
                                 on_retry=on_retry)

    @functools.wraps(fn)
    def wrapped(*args, **kw):
        delay = base_delay
        for attempt in range(attempts):
            try:
                return fn(*args, **kw)
            except exceptions as e:
                if attempt == attempts - 1:
                    raise RetryError(
                        f"{getattr(fn, '__name__', 'call')} failed after "
                        f"{attempts} attempts: {e}", attempts) from e
                if on_retry is not None:
                    on_retry(attempt, e)
                sleep(delay)
                delay *= factor
    return wrapped

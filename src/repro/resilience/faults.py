"""Deterministic fault injection: seeded, tick-indexed, replayable.

Chaos testing a trainer is only useful if a failing run can be replayed
exactly, so every fault here is pinned to a *tick* — the resilient runner's
loop-iteration counter, which (unlike ``state.step``) increases even when a
step trips or rolls back, so a fault fires exactly once and a rollback can
never re-trigger it. A ``FaultSchedule`` is either written out explicitly
(``Fault(tick=5, kind="nan_grad")``) or drawn from a seeded RNG
(``FaultSchedule.random``) — both are bit-reproducible.

Fault classes (``Fault.kind``):

  * ``nan_grad``        — the drawn batch's float leaves become NaN, so the
                          loss and every gradient is non-finite (the guard's
                          finiteness trip);
  * ``corrupt_batch``   — float leaves scaled by ``magnitude`` (finite
                          garbage: the guard's EMA-spike trip);
  * ``kill_producer``   — the ``Prefetcher`` producer thread raises
                          ``ProducerKilled`` (synchronous sessions raise it
                          at the draw site instead);
  * ``ckpt_write_fail`` — the next ``repeats`` checkpoint-save attempts
                          fail with ``CheckpointWriteError`` (exercises the
                          retry/backoff path);
  * ``preempt``         — a simulated SIGTERM via
                          ``PreemptionHandler.trigger()``: flush-and-exit.

The injectors (``poison_nan`` / ``scale_floats``) operate on already-placed
batches (jnp ops), so injection composes with the async prefetch pipeline:
the clean batch was drawn and placed normally — the corruption is what the
step sees, exactly as a flipped bit in device memory would be.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

KINDS = ("nan_grad", "corrupt_batch", "kill_producer", "ckpt_write_fail",
         "preempt")


class InjectedFault(RuntimeError):
    """Base class for every injected failure."""


class ProducerKilled(InjectedFault):
    """Simulated death of the input-pipeline producer."""


@dataclasses.dataclass(frozen=True)
class Fault:
    """One scheduled fault. ``source`` limits batch corruption to a single
    task-major slice (None poisons the whole batch); ``magnitude`` scales
    ``corrupt_batch``; ``repeats`` is how many consecutive save attempts a
    ``ckpt_write_fail`` poisons (keep it below the manager's retry budget
    for a recoverable fault, at/above it for a fatal one)."""
    tick: int
    kind: str
    source: int | None = None
    magnitude: float = 1e4
    repeats: int = 1

    def __post_init__(self):
        assert self.kind in KINDS, \
            f"unknown fault kind '{self.kind}'; known: {KINDS}"
        assert self.tick >= 1, f"ticks are 1-based, got {self.tick}"


class FaultSchedule:
    """A set of tick-pinned faults; each fires exactly once.

    ``take(tick)`` pops and returns the faults pinned to that tick (tick
    order within one tick follows construction order). ``pending()`` counts
    what has not fired yet — a soak test asserts it reaches zero.
    """

    def __init__(self, faults=()):
        self._by_tick: dict[int, list[Fault]] = {}
        for f in faults:
            assert isinstance(f, Fault), f
            self._by_tick.setdefault(f.tick, []).append(f)
        self.fired: list[Fault] = []

    @classmethod
    def from_dict(cls, ticks: dict) -> "FaultSchedule":
        """{tick: kind} shorthand for single-fault ticks."""
        return cls([Fault(tick=t, kind=k) for t, k in sorted(ticks.items())])

    @classmethod
    def random(cls, seed: int, n_ticks: int,
               rates: dict | None = None) -> "FaultSchedule":
        """Seeded random schedule: each tick independently draws each fault
        kind with probability ``rates[kind]`` (default 0.01 per kind).
        Deterministic: same (seed, n_ticks, rates) -> same schedule."""
        rates = dict(rates or {})
        rng = np.random.default_rng(seed)
        faults = []
        for tick in range(1, n_ticks + 1):
            for kind in KINDS:
                if rng.random() < rates.get(kind, 0.01):
                    faults.append(Fault(tick=tick, kind=kind))
        return cls(faults)

    def take(self, tick: int) -> list[Fault]:
        out = self._by_tick.pop(tick, [])
        self.fired.extend(out)
        return out

    def pending(self) -> int:
        return sum(len(v) for v in self._by_tick.values())

    def __len__(self) -> int:
        return self.pending() + len(self.fired)

    def __bool__(self) -> bool:
        return len(self) > 0


# ---------------------------------------------------------------------------
# batch injectors
# ---------------------------------------------------------------------------

def _map_floats(batch, fn, source: int | None):
    """Apply ``fn`` to every float leaf (whole leaf, or task slice
    ``leaf[source]`` for task-major batches when ``source`` is given)."""

    def apply(x):
        x = jnp.asarray(x)
        if not jnp.issubdtype(x.dtype, jnp.floating):
            return x
        if source is None:
            return fn(x)
        assert x.ndim >= 1, "source-targeted corruption needs task-major leaves"
        return x.at[source].set(fn(x[source]))

    return jax.tree_util.tree_map(apply, batch)


def poison_nan(batch, source: int | None = None):
    """Every float value becomes NaN -> non-finite loss AND gradients."""
    return _map_floats(batch, lambda x: jnp.full_like(x, jnp.nan), source)


def scale_floats(batch, magnitude: float, source: int | None = None):
    """Finite corruption: float leaves scaled by ``magnitude`` (a huge but
    finite loss — the EMA-spike trip, not the finiteness trip)."""
    return _map_floats(batch, lambda x: x * jnp.asarray(magnitude, x.dtype),
                       source)


def corrupt_batch(batch, fault: Fault):
    """Dispatch one batch-corruption fault."""
    if fault.kind == "nan_grad":
        return poison_nan(batch, fault.source)
    if fault.kind == "corrupt_batch":
        return scale_floats(batch, fault.magnitude, fault.source)
    raise ValueError(f"'{fault.kind}' is not a batch-corruption fault")

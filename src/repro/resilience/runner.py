"""The resilient training loop: guard -> rollback -> quarantine -> survive.

``run_resilient(session)`` is what ``Session.run()`` dispatches to when
``SessionConfig.resilience`` is set. It differs from the plain
``train_loop`` in one structural way: progress is measured by
``state.step`` (which only advances on ACCEPTED steps), not by loop
iterations — so a tripped step retries against the next batch, a rollback
rewinds progress, and the loop still terminates exactly at
``cfg.steps`` accepted updates. Loop iterations are counted by a *tick*
(monotonic, never rewound), which is what ``FaultSchedule`` pins faults to.

Per tick:

  1. fire scheduled faults (arm checkpoint failures, kill the producer,
     trigger a simulated preemption, queue batch corruption);
  2. preemption flag set? -> flush a final checkpoint (params + optimizer +
     guard + datapipe position) and exit cleanly with ``preempted=True``;
  3. draw a batch; a dead producer is recovered in place — the prefetcher
     is rewound to the last CONSUMED position and restarted, so the stream
     continues byte-identically (bounded by ``max_pipeline_recoveries``);
  4. step through the guarded compiled step; on a trip: after
     ``max_consecutive_trips``, roll params + optimizer + guard + datapipe
     back to the last good checkpoint; a source crossing
     ``quarantine_after`` attributed trips is quarantined (loss weight
     zeroed + batch slice sanitized) instead of killing the run;
  5. on an accepted step: log/eval on the usual cadence and checkpoint per
     ``CheckpointPolicy`` (retried with exponential backoff).

Determinism contract (proven by tests/test_resilience_soak.py): for
rollback-covered faults the run's final params are bitwise-identical to a
never-faulted run — rollback restores the params, optimizer moments, guard
EMA and the byte-identical datapipe stream together, and replayed compute
is deterministic.
"""
from __future__ import annotations

import dataclasses
import json
import time

import jax
import numpy as np

from repro.train.loop import EarlyStopping, MetricLogger

from .faults import FaultSchedule, ProducerKilled, corrupt_batch
from .guard import GuardConfig, StepGuard, zero_task_slices
from .policy import CheckpointManager, CheckpointPolicy, PreemptionHandler


@dataclasses.dataclass(frozen=True)
class ResilienceConfig:
    """Everything ``SessionConfig.resilience`` needs (docs/robustness.md).

    ckpt_dir: directory the ``CheckpointManager`` owns — required, it is
    the rollback target and the preemption flush destination.
    guard: ``GuardConfig`` or None to disable guarded stepping (keeps the
    checkpoint/preemption/recovery machinery only).
    faults: a ``FaultSchedule`` for chaos runs (tests/benchmarks); None in
    production.
    handle_signals: install SIGTERM/SIGUSR1 handlers for the run (main
    thread only; simulated preemptions work regardless).
    max_ticks: hard bound on loop iterations (None = ``20 * steps + 100``)
    — a backstop so a pathological trip/rollback cycle raises instead of
    spinning forever.
    """
    ckpt_dir: str
    guard: GuardConfig | None = GuardConfig()
    policy: CheckpointPolicy = CheckpointPolicy()
    faults: FaultSchedule | None = None
    handle_signals: bool = False
    max_pipeline_recoveries: int = 3
    retry_attempts: int = 3
    retry_base_delay: float = 0.05
    max_ticks: int | None = None

    def replace(self, **kw) -> "ResilienceConfig":
        return dataclasses.replace(self, **kw)


def run_resilient(session) -> "SessionResult":  # noqa: F821
    from repro.engine.session import SessionResult
    from repro.train import checkpoint as ckpt_mod

    cfg = session.cfg
    res: ResilienceConfig = cfg.resilience
    assert res.ckpt_dir, "ResilienceConfig.ckpt_dir is required"
    mgr = CheckpointManager(res.ckpt_dir, res.policy,
                            attempts=res.retry_attempts,
                            base_delay=res.retry_base_delay)
    faults = res.faults if res.faults is not None else FaultSchedule()
    n_sources = getattr(session.model, "n_tasks", 0) or 0
    guard = StepGuard(res.guard, n_sources=n_sources) \
        if res.guard is not None else None
    preempt = PreemptionHandler(install=res.handle_signals)
    logger = MetricLogger()
    early = EarlyStopping(patience=cfg.patience, min_delta=cfg.min_delta) \
        if cfg.patience > 0 else None
    log_every = cfg.log_every or cfg.eval_every
    batches = session._batches()
    state = session.state
    events: list[dict] = []
    recoveries = 0
    saved = 0
    preempted = stopped = False
    out = None
    pending_corrupt = []
    sync_kill = []

    def save(metric=None):
        nonlocal saved
        mgr.save(state, datapipe=session.datapipe_state(), metric=metric)
        saved += 1

    # rollback anchor: without it the guard could trip on step 1 with
    # nothing to roll back to
    if res.policy.save_initial and mgr.latest_step() != int(state.step):
        save()

    step_h = int(state.step)          # host mirror of state.step
    tick = 0
    max_ticks = res.max_ticks if res.max_ticks is not None \
        else 20 * cfg.steps + 100
    try:
        while step_h < cfg.steps:
            tick += 1
            if tick > max_ticks:
                raise RuntimeError(
                    f"resilient loop exceeded {max_ticks} ticks at step "
                    f"{step_h}/{cfg.steps} — persistent faulting without "
                    "progress (see the resilience report events)")

            for f in faults.take(tick):
                if f.kind == "kill_producer":
                    if session._prefetcher is not None:
                        session._prefetcher.inject_producer_fault(
                            ProducerKilled(f"injected at tick {tick}"))
                    else:
                        sync_kill.append(f)
                elif f.kind == "ckpt_write_fail":
                    mgr.arm_failures(f.repeats)
                elif f.kind == "preempt":
                    preempt.trigger()
                else:
                    pending_corrupt.append(f)

            if preempt.triggered:
                t0 = time.perf_counter()
                save(metric=float(out.loss) if out is not None else None)
                events.append({"kind": "preempt_flush", "tick": tick,
                               "step": step_h,
                               "ms": (time.perf_counter() - t0) * 1e3})
                preempted = True
                break

            if sync_kill:
                # synchronous sessions have no producer thread to kill: the
                # fault surfaces as a failed draw, recovered by retrying
                # (the batcher itself did not advance)
                sync_kill.clear()
                recoveries += 1
                events.append({"kind": "pipeline_recovery", "tick": tick,
                               "error": "ProducerKilled", "ms": 0.0})
                continue

            try:
                batch = batches()
            except Exception as e:
                recoveries += 1
                if recoveries > res.max_pipeline_recoveries:
                    raise
                t0 = time.perf_counter()
                if session._prefetcher is not None:
                    # rewind to the last CONSUMED position (read-ahead and
                    # any partial draw of the dying producer are discarded)
                    # and restart the producer: the stream continues
                    # byte-identically, no state rollback needed
                    session._prefetcher.restore(session._prefetcher.state())
                events.append({"kind": "pipeline_recovery", "tick": tick,
                               "error": type(e).__name__,
                               "ms": (time.perf_counter() - t0) * 1e3})
                continue

            if session._quarantined and session._task_major_batches:
                batch = zero_task_slices(batch, session._quarantined)
            for f in pending_corrupt:
                batch = corrupt_batch(batch, f)
            pending_corrupt.clear()

            state, out = session.compiled_step(state, batch)
            ok = guard.observe(out) if guard is not None else True
            if ok:
                step_h += 1
            else:
                if guard.should_rollback():
                    t0 = time.perf_counter()
                    path, state = mgr.load_latest(template=state)
                    session.state = state
                    if ckpt_mod.has_datapipe(path):
                        session.restore_datapipe(path)
                    session._reapply_quarantine()
                    guard.on_rollback()
                    step_h = int(state.step)
                    events.append({"kind": "rollback", "tick": tick,
                                   "to_step": step_h,
                                   "ms": (time.perf_counter() - t0) * 1e3})
                q = guard.quarantine_candidates()
                if q:
                    session.quarantine_tasks(q)
                    guard.mark_quarantined(q)
                    events.append({"kind": "quarantine", "tick": tick,
                                   "sources": q})
                continue

            is_eval = step_h % cfg.eval_every == 0 or step_h == 1 \
                or step_h == cfg.steps
            is_log = step_h % log_every == 0 or step_h == 1 \
                or step_h == cfg.steps
            if is_eval or is_log:
                extras = session._metric_fn(out)
                row = logger.log(step_h, loss=out.loss, **extras)
                if session.eval_fn is not None and is_eval:
                    row.update({k: float(v) for k, v
                                in session.eval_fn(state.params).items()})
                if cfg.verbose:
                    print(json.dumps({k: round(v, 5)
                                      if isinstance(v, float) else v
                                      for k, v in row.items()}))
                if early is not None and is_eval:
                    criterion = row.get(cfg.val_metric, row["loss"])
                    if early.update(float(criterion)):
                        stopped = True
            if res.policy.should_save(step_h):
                save(metric=float(out.loss))
            if stopped:
                break
    finally:
        session.state = state
        if res.handle_signals:
            preempt.uninstall()

    if not preempted and mgr.latest_step() != step_h:
        # final flush: a completed (or early-stopped) run is resumable too
        save(metric=float(out.loss) if out is not None else None)

    report = {
        "ticks": tick, "steps": step_h, "preempted": preempted,
        "checkpoints_saved": saved, "io_retries": mgr.io_retries,
        "pipeline_recoveries": recoveries,
        "faults_fired": len(faults.fired), "faults_pending": faults.pending(),
        "events": events,
    }
    if guard is not None:
        report.update(guard.report())
    if cfg.ckpt_path:
        from repro.train import checkpoint
        checkpoint.save(cfg.ckpt_path, {"params": state.params},
                        metadata={"model": cfg.model, "arch": cfg.arch.name,
                                  "step": step_h,
                                  "final_loss": float(out.loss)
                                  if out is not None else None},
                        datapipe=session.datapipe_state())
    return SessionResult(
        state=state, logger=logger,
        final_loss=float(out.loss) if out is not None else float("nan"),
        last_metrics={} if out is None else
        jax.tree_util.tree_map(np.asarray, out.metrics),
        stopped_early=stopped, preempted=preempted, resilience=report)

"""repro.resilience — fault-tolerant pre-training.

Guarded stepping (loss-spike/NaN rollback), preemption-safe checkpointing,
retried IO, and a deterministic fault-injection harness. Wire it into a
training run via ``SessionConfig(resilience=ResilienceConfig(...))``; see
docs/robustness.md for the lifecycle and knobs.
"""
from .faults import (
    KINDS,
    Fault,
    FaultSchedule,
    InjectedFault,
    ProducerKilled,
    corrupt_batch,
    poison_nan,
    scale_floats,
)
from .guard import (
    GuardConfig,
    GuardState,
    StepGuard,
    make_guarded_step,
    make_guarded_train_step,
    zero_task_slices,
)
from .policy import (
    CheckpointManager,
    CheckpointPolicy,
    CheckpointWriteError,
    PreemptionHandler,
)
from .retry import RetryError, with_retry
from .runner import ResilienceConfig, run_resilient

__all__ = [
    "KINDS",
    "Fault",
    "FaultSchedule",
    "InjectedFault",
    "ProducerKilled",
    "corrupt_batch",
    "poison_nan",
    "scale_floats",
    "GuardConfig",
    "GuardState",
    "StepGuard",
    "make_guarded_step",
    "make_guarded_train_step",
    "zero_task_slices",
    "CheckpointManager",
    "CheckpointPolicy",
    "CheckpointWriteError",
    "PreemptionHandler",
    "RetryError",
    "with_retry",
    "ResilienceConfig",
    "run_resilient",
]

"""Mixture-of-Experts layer (TPU-idiomatic, capacity-based).

Dispatch is scatter-based (token->(expert,slot) indices built from a grouped
cumsum), NOT the GShard (T,E,C) one-hot einsum — at k=6..8 and E=40..160 the
one-hot dispatch tensor would dwarf the activations. The dispatched buffer is
laid out (groups, E, capacity, d) so the expert dim shards over the "model"
mesh axis (expert parallelism) and groups shard over "data".

Top-k routing with per-group capacity + dropped-token dump slot, Switch-style
load-balance aux loss, optional DeepSeek-style shared experts.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from .common import ACT, KeyGen, Params, normal_init


def moe_init(key, cfg) -> Params:
    kg = KeyGen(key)
    d, E, dff = cfg.d_model, cfg.n_experts, cfg.d_ff_expert or cfg.d_ff
    dt = cfg.param_dtype
    p = {
        "router": normal_init(kg(), (d, E), dt, 0.02),
        "w_gate": normal_init(kg(), (E, d, dff), dt, 1 / math.sqrt(d)),
        "w_up": normal_init(kg(), (E, d, dff), dt, 1 / math.sqrt(d)),
        "w_down": normal_init(kg(), (E, dff, d), dt, 0.02 / math.sqrt(2 * cfg.n_layers)),
    }
    if cfg.n_shared_experts:
        from .mlp import swiglu_init
        p["shared"] = swiglu_init(kg(), d, dff * cfg.n_shared_experts, dt, cfg.n_layers)
    return p


def _capacity(gs: int, top_k: int, n_experts: int, factor: float) -> int:
    c = int(math.ceil(gs * top_k / n_experts * factor))
    return max(8, -(-c // 8) * 8)  # pad to multiple of 8 lanes


def moe_apply(params: Params, x, *, cfg, group_size: int = 512):
    """x: (B, S, d) -> (y, aux_loss). Token order is preserved."""
    B, S, d = x.shape
    E, k = cfg.n_experts, cfg.top_k
    cd = cfg.compute_dtype
    T = B * S
    gs = min(group_size, T)
    G = T // gs
    assert G * gs == T, f"tokens {T} not divisible by group {gs}"
    C = _capacity(gs, k, E, cfg.capacity_factor)

    xf = x.reshape(G, gs, d)
    logits = jnp.einsum("gsd,de->gse", xf.astype(jnp.float32),
                        params["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)                     # (G,gs,E)
    gate, choice = jax.lax.top_k(probs, k)                      # (G,gs,k)
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)

    # ---- positions in each expert's per-group queue ----------------------
    cf = choice.reshape(G, gs * k)                              # token-major
    oh = jax.nn.one_hot(cf, E, dtype=jnp.int32)                 # (G,gs*k,E)
    pos = jnp.cumsum(oh, axis=1) * oh                           # 1-based where chosen
    pos = jnp.sum(pos, axis=-1) - 1                             # (G,gs*k)
    keep = pos < C
    slot = jnp.where(keep, cf * C + pos, E * C)                 # dump slot = E*C

    # ---- dispatch (scatter) ----------------------------------------------
    xr = jnp.broadcast_to(xf[:, :, None, :], (G, gs, k, d)).reshape(G, gs * k, d)
    buf = jnp.zeros((G, E * C + 1, d), cd)
    buf = jax.vmap(lambda b, i, v: b.at[i].add(v))(buf, slot, xr.astype(cd))
    ein = buf[:, : E * C].reshape(G, E, C, d)                   # (G,E,C,d)

    # ---- expert FFN (batched over expert dim; shards over "model") -------
    wg = params["w_gate"].astype(cd)
    wu = params["w_up"].astype(cd)
    wd = params["w_down"].astype(cd)
    h = ACT[cfg.act](jnp.einsum("gecd,edf->gecf", ein, wg)) * jnp.einsum(
        "gecd,edf->gecf", ein, wu)
    eout = jnp.einsum("gecf,efd->gecd", h, wd)                  # (G,E,C,d)

    # ---- combine (gather) -------------------------------------------------
    flat = jnp.concatenate([eout.reshape(G, E * C, d),
                            jnp.zeros((G, 1, d), cd)], axis=1)
    yk = jax.vmap(lambda f, i: f[i])(flat, slot)                # (G,gs*k,d)
    yk = yk * (gate.reshape(G, gs * k, 1).astype(cd) * keep[..., None])
    y = yk.reshape(G, gs, k, d).sum(axis=2).reshape(B, S, d)

    # ---- shared experts + aux loss ----------------------------------------
    if "shared" in params:
        from .mlp import swiglu_apply
        y = y + swiglu_apply(params["shared"], x, cfg.act, cd)

    # Switch-style load balance: E * sum_e fraction_e * mean_prob_e
    frac = jnp.mean(jax.nn.one_hot(choice, E, dtype=jnp.float32), axis=(0, 1, 2)) * k
    mp = jnp.mean(probs, axis=(0, 1))
    aux = E * jnp.sum(frac * mp)
    return y, aux

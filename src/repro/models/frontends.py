"""Modality frontends — STUBS per the brief.

The ViT / conv-codec themselves are out of scope: ``input_specs()`` supplies
precomputed patch/frame embeddings. What we *do* own is the learned projector
that maps those embeddings into the LM's d_model space (the standard
VLM/audio "adapter" layer), so the backbone consumes real parameters.
"""
from __future__ import annotations

import jax.numpy as jnp

from .common import KeyGen, Params, dense, dense_init, layernorm, layernorm_init

# embedding widths the stubs emit (typical ViT-L / w2v-BERT frame widths)
VISION_EMBED_DIM = 1024
AUDIO_EMBED_DIM = 1024


def projector_init(key, cfg) -> Params:
    kg = KeyGen(key)
    d_in = VISION_EMBED_DIM if cfg.modality == "vision_embed" else AUDIO_EMBED_DIM
    return {
        "ln": layernorm_init(d_in, cfg.param_dtype),
        "fc1": dense_init(kg(), d_in, cfg.d_model, cfg.param_dtype, bias=True),
        "fc2": dense_init(kg(), cfg.d_model, cfg.d_model, cfg.param_dtype, bias=True),
    }


def projector_apply(params: Params, media_embed, cfg):
    """media_embed: (B, n_media, d_in) -> (B, n_media, d_model)."""
    cd = cfg.compute_dtype
    x = layernorm(params["ln"], media_embed.astype(cd))
    x = dense(params["fc1"], x, cd)
    x = jnp.maximum(x, 0.0)  # simple ReLU projector (LLaVA-style 2-layer MLP)
    return dense(params["fc2"], x, cd)

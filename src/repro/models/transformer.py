"""Transformer assembly: decoder-only LMs and encoder-decoder models.

Layers are organised by the config's ``block_pattern`` unit; repetitions of
the unit are stacked and driven by ``lax.scan`` (small HLO, fast compile for
60-layer models), with any remainder layers unrolled. Per-layer remat via
``jax.checkpoint`` around each block when ``cfg.remat``.

Block types (pattern entries): attn | swa | mla | mamba2 | mlstm | slstm |
shared_attn (zamba-style shared-weight attention with per-application LoRA)
| enc_attn (bidirectional) | dec_attn (self+cross, enc-dec only).
"""
from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp

from .attention import (gqa_apply, gqa_cache_init, gqa_init, mla_apply,
                        mla_cache_init, mla_init, sdpa)
from .common import (KeyGen, Params, dense, dense_init, embed, embedding_init,
                     layernorm, normal_init, rmsnorm, unembed)
from .mlp import swiglu_apply, swiglu_init
from .moe import moe_apply, moe_init
from .ssm import (mamba2_apply, mamba2_init, mamba2_state_init, mamba2_step,
                  mlstm_apply, mlstm_init, mlstm_state_init, mlstm_step,
                  slstm_apply, slstm_init, slstm_state_init, slstm_step)

ATTN_TYPES = ("attn", "swa", "mla", "shared_attn", "enc_attn")
SSM_TYPES = ("mamba2", "mlstm", "slstm")
LORA_RANK = 64  # zamba2-style per-application adapters on the shared block


def _norm(cfg):
    return rmsnorm if cfg.norm == "rmsnorm" else layernorm


def _norm_init(cfg, d=None):
    d = d or cfg.d_model
    if cfg.norm == "rmsnorm":
        return {"scale": jnp.ones((d,), cfg.param_dtype)}
    return {"scale": jnp.ones((d,), cfg.param_dtype),
            "bias": jnp.zeros((d,), cfg.param_dtype)}


def _has_ffn(btype: str) -> bool:
    return btype in ("attn", "swa", "mla", "enc_attn", "dec_attn")


# ---------------------------------------------------------------------------
# Block init / apply
# ---------------------------------------------------------------------------

def block_init(key, cfg, btype: str) -> Params:
    kg = KeyGen(key)
    p: Params = {"ln1": _norm_init(cfg)}
    if btype in ("attn", "swa", "enc_attn"):
        p["attn"] = gqa_init(kg(), cfg)
    elif btype == "mla":
        p["attn"] = mla_init(kg(), cfg)
    elif btype == "shared_attn":
        d, H, K, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
        dt = cfg.param_dtype
        for nm, dout in (("q", H * hd), ("k", K * hd), ("v", K * hd)):
            p[f"lora_{nm}_a"] = normal_init(kg(), (d, LORA_RANK), dt, 0.02)
            p[f"lora_{nm}_b"] = jnp.zeros((LORA_RANK, dout), dt)
    elif btype == "mamba2":
        p["mixer"] = mamba2_init(kg(), cfg)
    elif btype == "mlstm":
        p["mixer"] = mlstm_init(kg(), cfg)
    elif btype == "slstm":
        p["mixer"] = slstm_init(kg(), cfg)
    if btype == "dec_attn":
        p["attn"] = gqa_init(kg(), cfg)
        p["ln_x"] = _norm_init(cfg)
        p["xattn"] = gqa_init(kg(), cfg)
    if _has_ffn(btype):
        p["ln2"] = _norm_init(cfg)
        if cfg.n_experts and btype != "enc_attn":
            p["ffn"] = moe_init(kg(), cfg)
        else:
            p["ffn"] = swiglu_init(kg(), cfg.d_model, cfg.d_ff, cfg.param_dtype,
                                   cfg.n_layers or 2)
    return p


def _shared_attn_params(shared: Params, bp: Params, cfg):
    """Merge shared base weights with this application's LoRA deltas."""
    cd = cfg.compute_dtype
    out = {}
    for nm, key in (("q", "wq"), ("k", "wk"), ("v", "wv")):
        w = shared[key]["w"].astype(cd) + (
            bp[f"lora_{nm}_a"].astype(cd) @ bp[f"lora_{nm}_b"].astype(cd))
        out[key] = {"w": w}
    out["wo"] = {"w": shared["wo"]["w"].astype(cd)}
    return out


def block_apply(bp: Params, x, *, btype, cfg, positions, cache=None,
                mode="train", shared=None, memory=None, impl="chunked"):
    """Returns (x, new_cache, aux). cache semantics:
    mode=="train": cache ignored/None;  "prefill": returns init'd cache;
    "decode": cache consumed and updated."""
    nrm = _norm(cfg)
    aux = jnp.zeros((), jnp.float32)
    h = nrm(bp["ln1"], x)
    new_cache = None

    if btype in ("attn", "swa", "shared_attn", "enc_attn", "dec_attn"):
        ap = _shared_attn_params(shared, bp, cfg) if btype == "shared_attn" else bp["attn"]
        window = cfg.window if btype in ("swa", "shared_attn") and cfg.window else 0
        if btype == "swa":
            window = cfg.window
        causal = btype != "enc_attn"
        if mode == "decode":
            sa = cache["self"] if btype == "dec_attn" else cache
            o, nc = gqa_apply(ap, h, cfg=cfg, positions=positions, window=window,
                              cache=sa, impl=impl)
        elif mode == "prefill":
            o, nc = gqa_apply(ap, h, cfg=cfg, positions=positions, window=window,
                              cache="init", impl=impl)
        else:
            o = gqa_apply(ap, h, cfg=cfg, positions=positions, window=window,
                          impl=impl) if causal else _bidir_attn(ap, h, cfg, positions, impl)
            nc = None
        x = x + o
        if btype == "dec_attn":
            hx = nrm(bp["ln_x"], x)
            xo = _cross_attn(bp["xattn"], hx, memory, cfg, impl)
            x = x + xo
            nc = {"self": nc} if nc is not None else None
        new_cache = nc
    elif btype == "mla":
        if mode == "decode":
            o, new_cache = mla_apply(bp["attn"], h, cfg=cfg, positions=positions,
                                     cache=cache, impl=impl)
        elif mode == "prefill":
            o, new_cache = mla_apply(bp["attn"], h, cfg=cfg, positions=positions,
                                     cache="init", impl=impl)
        else:
            o = mla_apply(bp["attn"], h, cfg=cfg, positions=positions, impl=impl)
        x = x + o
    elif btype == "mamba2":
        if mode == "decode":
            o, new_cache = mamba2_step(bp["mixer"], h, cache, cfg=cfg)
        elif mode == "prefill":
            o, new_cache = mamba2_apply(bp["mixer"], h, cfg=cfg, return_state=True)
        else:
            o = mamba2_apply(bp["mixer"], h, cfg=cfg)
        x = x + o
    elif btype in ("mlstm", "slstm"):
        fns = {"mlstm": (mlstm_apply, mlstm_step), "slstm": (slstm_apply, slstm_step)}[btype]
        if mode == "decode":
            o, new_cache = fns[1](bp["mixer"], h, cache, cfg=cfg)
        elif mode == "prefill":
            o, new_cache = fns[0](bp["mixer"], h, cfg=cfg, return_state=True)
        else:
            o = fns[0](bp["mixer"], h, cfg=cfg)
        x = x + o

    if _has_ffn(btype):
        h2 = nrm(bp["ln2"], x)
        if cfg.n_experts and btype != "enc_attn":
            f, aux = moe_apply(bp["ffn"], h2, cfg=cfg)
        else:
            f = swiglu_apply(bp["ffn"], h2, cfg.act, cfg.compute_dtype)
        x = x + f
    return x, new_cache, aux


def _bidir_attn(ap, h, cfg, positions, impl):
    from .attention import sdpa as _sdpa
    B, S, _ = h.shape
    H, K, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    cd = cfg.compute_dtype
    from .common import dense as _d
    from .attention import apply_rope
    q = _d(ap["wq"], h, cd).reshape(B, S, H, hd)
    k = _d(ap["wk"], h, cd).reshape(B, S, K, hd)
    v = _d(ap["wv"], h, cd).reshape(B, S, K, hd)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    o = _sdpa(q, k, v, q_pos=positions, k_pos=positions, causal=False, impl=impl)
    return _d(ap["wo"], o.reshape(B, S, H * hd), cd)


def _cross_attn(ap, h, memory, cfg, impl):
    """Decoder cross-attention to fixed encoder memory (no causal mask)."""
    B, S, _ = h.shape
    M = memory.shape[1]
    H, K, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    cd = cfg.compute_dtype
    q = dense(ap["wq"], h, cd).reshape(B, S, H, hd)
    k = dense(ap["wk"], memory, cd).reshape(B, M, K, hd)
    v = dense(ap["wv"], memory, cd).reshape(B, M, K, hd)
    o = sdpa(q, k, v, q_pos=jnp.zeros((S,), jnp.int32),
             k_pos=jnp.zeros((M,), jnp.int32), causal=False, impl=impl)
    return dense(ap["wo"], o.reshape(B, S, H * hd), cd)


def block_cache_init(cfg, btype, batch, cache_len):
    if btype in ("attn", "mla") and btype == "mla":
        pass
    if btype == "mla":
        return mla_cache_init(cfg, batch, cache_len)
    if btype in ("attn", "enc_attn"):
        return gqa_cache_init(cfg, batch, cache_len)
    if btype in ("swa", "shared_attn"):
        w = cfg.window or cache_len
        return gqa_cache_init(cfg, batch, min(w, cache_len))
    if btype == "dec_attn":
        return {"self": gqa_cache_init(cfg, batch, cache_len)}
    if btype == "mamba2":
        return mamba2_state_init(cfg, batch)
    if btype == "mlstm":
        return mlstm_state_init(cfg, batch)
    if btype == "slstm":
        return slstm_state_init(cfg, batch)
    raise ValueError(btype)


# ---------------------------------------------------------------------------
# LM (decoder-only) — trunk + head split for multi-task parallelism
# ---------------------------------------------------------------------------

def _pattern_split(cfg):
    unit = tuple(cfg.block_pattern)
    reps = cfg.n_layers // len(unit)
    rem = cfg.pattern[reps * len(unit):]
    return unit, reps, rem


def lm_init(key, cfg) -> Params:
    kg = KeyGen(key)
    unit, reps, rem = _pattern_split(cfg)
    p: Params = {"embed": embedding_init(kg(), cfg.padded_vocab, cfg.d_model, cfg.param_dtype)}
    if reps > 0:
        p["scan"] = {}
        for u, btype in enumerate(unit):
            keys = jax.random.split(kg(), reps)
            p["scan"][f"u{u}"] = jax.vmap(lambda k: block_init(k, cfg, btype))(keys)
    p["rem"] = {f"r{i}": block_init(kg(), cfg, bt) for i, bt in enumerate(rem)}
    if "shared_attn" in cfg.pattern:
        p["shared_attn"] = gqa_init(kg(), cfg)
    if cfg.modality in ("vision_embed", "audio_embed"):
        from .frontends import projector_init
        p["projector"] = projector_init(kg(), cfg)
    p["ln_f"] = _norm_init(cfg)
    if not cfg.tie_embeddings:
        p["lm_head"] = dense_init(kg(), cfg.d_model, cfg.padded_vocab, cfg.param_dtype)
    if cfg.n_tasks > 1:
        # the paper's technique: per-source decoding heads, task-shardable
        p["task_heads"] = {
            "w": normal_init(kg(), (cfg.n_tasks, cfg.d_model, cfg.padded_vocab),
                             cfg.param_dtype, 0.02)}
    if cfg.n_enc_layers:
        p["enc"] = {"blocks": {f"e{i}": block_init(kg(), cfg, "enc_attn")
                               for i in range(cfg.n_enc_layers)},
                    "ln_f": _norm_init(cfg)}
    return p


def _maybe_remat(fn, cfg, mode):
    if cfg.remat and mode == "train":
        return jax.checkpoint(fn, prevent_cse=False)
    return fn


def run_trunk(params: Params, x, *, cfg, positions, mode="train", caches=None,
              memory=None, impl="chunked"):
    """x: (B,S,d) embedded inputs -> (hidden, new_caches, aux)."""
    unit, reps, rem = _pattern_split(cfg)
    aux = jnp.zeros((), jnp.float32)
    shared = params.get("shared_attn")
    new_caches: Params = {}

    if reps > 0:
        def unit_body(x, bps, cs):
            a = jnp.zeros((), jnp.float32)
            ncs = []
            for u, btype in enumerate(unit):
                fn = partial(block_apply, btype=btype, cfg=cfg, positions=positions,
                             mode=mode, shared=shared, memory=memory, impl=impl)
                fn = _maybe_remat(fn, cfg, mode)
                x, nc, a_u = fn(bps[u], x, cache=cs[u] if cs is not None else None)
                ncs.append(nc)
                a = a + a_u
            return x, tuple(ncs), a

        stacked = tuple(params["scan"][f"u{u}"] for u in range(len(unit)))
        if mode == "train":
            def body(carry, bps):
                x, a = carry
                x, _, au = unit_body(x, bps, None)
                return (x, a + au), None
            (x, aux), _ = jax.lax.scan(body, (x, aux), stacked)
        elif mode == "prefill":
            # prefill inits caches inside block_apply; scan stacks them
            def body2(carry, bps):
                x, a = carry
                a_u = jnp.zeros((), jnp.float32)
                ncs = []
                xx = x
                for u, btype in enumerate(unit):
                    xx, nc, au = block_apply(bps[u], xx, btype=btype, cfg=cfg,
                                             positions=positions, mode="prefill",
                                             shared=shared, memory=memory, impl=impl)
                    ncs.append(nc)
                    a_u = a_u + au
                return (xx, a + a_u), tuple(ncs)
            (x, aux), scan_caches = jax.lax.scan(body2, (x, aux), stacked)
            new_caches["scan"] = scan_caches
        else:  # decode
            def body(carry, xs):
                x, a = carry
                bps, cs = xs
                x, ncs, au = unit_body(x, bps, cs)
                return (x, a + au), ncs
            (x, aux), scan_caches = jax.lax.scan(
                body, (x, aux), (stacked, caches["scan"]))
            new_caches["scan"] = scan_caches

    for i, btype in enumerate(rem):
        bp = params["rem"][f"r{i}"]
        c = caches["rem"][f"r{i}"] if (caches and "rem" in caches) else None
        fn = partial(block_apply, btype=btype, cfg=cfg, positions=positions,
                     mode=mode, shared=shared, memory=memory, impl=impl)
        fn = _maybe_remat(fn, cfg, mode)
        x, nc, au = fn(bp, x, cache=c)
        aux = aux + au
        if nc is not None:
            new_caches.setdefault("rem", {})[f"r{i}"] = nc

    x = _norm(cfg)(params["ln_f"], x)
    return x, (new_caches if new_caches else None), aux


def embed_inputs(params, tokens, cfg, media=None):
    """tokens: (B, S_text) int; media: raw frontend embeddings
    (B, n_media, d_frontend) or None -> (B, S, d_model)."""
    x = embed(params["embed"], tokens, cfg.compute_dtype)
    if media is not None:
        from .frontends import projector_apply
        media = projector_apply(params["projector"], media, cfg)
        x = jnp.concatenate([media.astype(x.dtype), x], axis=1)
    return x


def _mask_pad_vocab(logits, cfg):
    """Padded vocab slots get -inf so softmax/xent ignore them."""
    if cfg.padded_vocab > cfg.vocab:
        vid = jnp.arange(cfg.padded_vocab)
        return jnp.where(vid < cfg.vocab, logits, -1e30)
    return logits


def lm_logits(params, hidden, cfg, task: int | None = None):
    if cfg.n_tasks > 1:
        w = params["task_heads"]["w"].astype(hidden.dtype)
        if task is not None:
            w = w[task]
            out = jnp.einsum("...d,dv->...v", hidden, w,
                             preferred_element_type=jnp.float32)
        else:
            # hidden: (n_tasks, B, S, d) task-sharded layout
            out = jnp.einsum("tbsd,tdv->tbsv", hidden, w,
                             preferred_element_type=jnp.float32)
    elif "lm_head" in params:
        out = dense(params["lm_head"], hidden, cfg.compute_dtype).astype(jnp.float32)
    else:
        out = unembed(params["embed"], hidden)
    return _mask_pad_vocab(out, cfg)


def encode(params, src_embed, cfg, impl="chunked"):
    """Encoder for enc-dec models. src_embed: raw frontend frames
    (B, S_src, d_frontend) -> memory (B, S_src, d_model)."""
    if cfg.modality in ("vision_embed", "audio_embed"):
        from .frontends import projector_apply
        src_embed = projector_apply(params["projector"], src_embed, cfg)
    x = src_embed.astype(cfg.compute_dtype)
    S = x.shape[1]
    positions = jnp.arange(S)
    for i in range(cfg.n_enc_layers):
        bp = params["enc"]["blocks"][f"e{i}"]
        x, _, _ = block_apply(bp, x, btype="enc_attn", cfg=cfg, positions=positions,
                              mode="train", impl=impl)
    return _norm(cfg)(params["enc"]["ln_f"], x)


def lm_apply(params: Params, tokens, *, cfg, media=None, memory=None,
             mode="train", caches=None, positions=None, impl="chunked",
             task=None):
    """Full LM forward. Returns (logits, new_caches, aux)."""
    if mode == "decode":
        x = embed(params["embed"], tokens, cfg.compute_dtype)  # (B,1,d)
    else:
        x = embed_inputs(params, tokens, cfg, media)
    if positions is None:
        positions = jnp.arange(x.shape[1])
    if cfg.n_enc_layers and memory is None and mode != "decode":
        raise ValueError("enc-dec model needs encoder memory")
    h, ncaches, aux = run_trunk(params, x, cfg=cfg, positions=positions,
                                mode=mode, caches=caches, memory=memory, impl=impl)
    logits = lm_logits(params, h, cfg, task=task)
    return logits, ncaches, aux


def lm_cache_init(params, cfg, batch: int, cache_len: int) -> Params:
    unit, reps, rem = _pattern_split(cfg)
    caches: Params = {}
    if reps > 0:
        per_unit = []
        for btype in unit:
            one = block_cache_init(cfg, btype, batch, cache_len)
            stacked = jax.tree_util.tree_map(
                lambda a: jnp.broadcast_to(a, (reps,) + a.shape).copy(), one)
            per_unit.append(stacked)
        caches["scan"] = tuple(per_unit)
    if rem:
        caches["rem"] = {f"r{i}": block_cache_init(cfg, bt, batch, cache_len)
                         for i, bt in enumerate(rem)}
    return caches

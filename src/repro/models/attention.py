"""Attention: GQA/MHA (+QKV bias), sliding-window, and DeepSeek-V2 MLA.

Two compute paths:
  * ``impl="chunked"`` — pure-JAX blocked online-softmax (flash-style) used
    for dry-run lowering and CPU tests. Memory is O(q_chunk * k_chunk), never
    O(S^2), so 32k prefill lowers with a sane working set.
  * ``impl="pallas"`` — the Pallas TPU kernel in ``repro.kernels``
    (validated in interpret mode; TPU-only at runtime).
  * ``impl="naive"`` — full score matrix; oracle for tests.

Cache layout (GQA):  {"k","v": (B, C, K, hd), "pos": ()} where C is either
full seq_len or the rolling window size. Keys are stored *post-RoPE* at their
absolute positions so a rolling cache stays valid.
Cache layout (MLA):  {"ckv": (B, C, r), "krope": (B, C, dr), "pos": ()}.
"""
from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp

from .common import KeyGen, Params, apply_rope, dense, dense_init

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# Mask helper
# ---------------------------------------------------------------------------

def _mask(q_pos, k_pos, causal: bool, window: int):
    """q_pos: (..., Sq), k_pos: (..., Sk) -> bool (..., Sq, Sk); True=keep.
    Padded/invalid positions use large-negative sentinels; guard them
    explicitly (a -1e9 k_pos would otherwise pass the causal test)."""
    d = q_pos[..., :, None] - k_pos[..., None, :]
    m = (k_pos > -(10 ** 8))[..., None, :] & (q_pos > -(10 ** 8))[..., :, None]
    if causal:
        m &= d >= 0
    if window > 0:
        m &= d < window
    return m


# ---------------------------------------------------------------------------
# Scaled dot-product attention (grouped-query, no kv repeat)
# ---------------------------------------------------------------------------

def sdpa_naive(q, k, v, *, q_pos, k_pos, causal=True, window=0, scale=None):
    """q: (B,Sq,H,hd) k,v: (B,Sk,K,hd). Oracle path."""
    B, Sq, H, hd = q.shape
    K = k.shape[2]
    G = H // K
    scale = scale if scale is not None else 1.0 / math.sqrt(hd)
    qg = q.reshape(B, Sq, K, G, hd)
    s = jnp.einsum("bqkgh,bskh->bkgqs", qg.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    m = _mask(q_pos, k_pos, causal, window)  # (Sq,Sk) or (B,Sq,Sk)
    while m.ndim < s.ndim:
        m = m[..., None, :, :] if m.ndim >= 2 else m
    s = jnp.where(m, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqs,bskh->bqkgh", p, v.astype(jnp.float32))
    return o.reshape(B, Sq, H, hd).astype(q.dtype)


def sdpa_chunked(q, k, v, *, q_pos, k_pos, causal=True, window=0, scale=None,
                 q_chunk=512, k_chunk=1024):
    """Blocked online-softmax attention in pure JAX (lowering-friendly)."""
    B, Sq, H, hd = q.shape
    Sk, K = k.shape[1], k.shape[2]
    G = H // K
    scale = scale if scale is not None else 1.0 / math.sqrt(hd)
    qc = min(q_chunk, Sq)
    kc = min(k_chunk, Sk)
    nq, nk = -(-Sq // qc), -(-Sk // kc)
    # pad to multiples
    if nq * qc != Sq:
        pad = nq * qc - Sq
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
        q_pos = jnp.pad(q_pos, ((0, pad),), constant_values=-10 ** 9)
    if nk * kc != Sk:
        pad = nk * kc - Sk
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k_pos = jnp.pad(k_pos, ((0, pad),), constant_values=-10 ** 9)

    qb = q.reshape(B, nq, qc, K, G, hd).astype(jnp.float32)
    kb = k.reshape(B, nk, kc, K, hd).astype(jnp.float32)
    vb = v.reshape(B, nk, kc, K, hd).astype(jnp.float32)
    qpb = q_pos.reshape(nq, qc)
    kpb = k_pos.reshape(nk, kc)

    def q_block(args):
        qi, qp = args  # (B,qc,K,G,hd), (qc,)

        def kv_step(carry, kv):
            m_prev, l_prev, acc = carry
            ki, vi, kp = kv
            s = jnp.einsum("bqkgh,bskh->bkgqs", qi, ki) * scale
            msk = _mask(qp, kp, causal, window)
            s = jnp.where(msk[None, None, None], s, NEG_INF)
            m_cur = jnp.maximum(m_prev, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_cur[..., None])
            corr = jnp.exp(m_prev - m_cur)
            l_new = l_prev * corr + jnp.sum(p, axis=-1)
            acc = acc * corr[..., None] + jnp.einsum("bkgqs,bskh->bkgqh", p, vi)
            return (m_cur, l_new, acc), None

        m0 = jnp.full((B, K, G, qc), NEG_INF)
        l0 = jnp.zeros((B, K, G, qc))
        a0 = jnp.zeros((B, K, G, qc, hd))
        (m, l, acc), _ = jax.lax.scan(kv_step, (m0, l0, a0), (kb.swapaxes(0, 1), vb.swapaxes(0, 1), kpb))
        o = acc / jnp.maximum(l[..., None], 1e-30)
        return o.transpose(0, 3, 1, 2, 4)  # (B,qc,K,G,hd)

    out = jax.lax.map(q_block, (qb.swapaxes(0, 1), qpb))  # (nq,B,qc,K,G,hd)
    out = out.swapaxes(0, 1).reshape(B, nq * qc, H, hd)
    return out[:, :Sq].astype(q.dtype)


def sdpa(q, k, v, *, q_pos, k_pos, causal=True, window=0, scale=None,
         impl="chunked", **kw):
    if impl == "naive":
        return sdpa_naive(q, k, v, q_pos=q_pos, k_pos=k_pos, causal=causal,
                          window=window, scale=scale)
    if impl == "pallas":
        from repro.kernels.flash_attention import ops as fa_ops
        return fa_ops.flash_attention(q, k, v, q_pos=q_pos, k_pos=k_pos,
                                      causal=causal, window=window, scale=scale,
                                      interpret=kw.get("interpret", True))
    return sdpa_chunked(q, k, v, q_pos=q_pos, k_pos=k_pos, causal=causal,
                        window=window, scale=scale,
                        q_chunk=kw.get("q_chunk", 512), k_chunk=kw.get("k_chunk", 1024))


# ---------------------------------------------------------------------------
# GQA attention block
# ---------------------------------------------------------------------------

def gqa_init(key, cfg) -> Params:
    kg = KeyGen(key)
    d, H, K, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    dt = cfg.param_dtype
    return {
        "wq": dense_init(kg(), d, H * hd, dt, bias=cfg.qkv_bias),
        "wk": dense_init(kg(), d, K * hd, dt, bias=cfg.qkv_bias),
        "wv": dense_init(kg(), d, K * hd, dt, bias=cfg.qkv_bias),
        "wo": dense_init(kg(), H * hd, d, dt, stddev=0.02 / math.sqrt(2 * cfg.n_layers or 2)),
    }


def gqa_apply(params: Params, x, *, cfg, positions, window=0, cache=None,
              impl="chunked", cache_window=0):
    """x: (B,S,d). cache None => train/prefill (returns new cache if requested
    via cache == "init"); else decode step (S==1), returns (out, new_cache)."""
    B, S, d = x.shape
    H, K, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    cd = cfg.compute_dtype
    q = dense(params["wq"], x, cd).reshape(B, S, H, hd)
    k = dense(params["wk"], x, cd).reshape(B, S, K, hd)
    v = dense(params["wv"], x, cd).reshape(B, S, K, hd)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)

    if cache is None or cache == "init":
        o = sdpa(q, k, v, q_pos=positions, k_pos=positions, causal=True,
                 window=window, impl=impl)
        out = dense(params["wo"], o.reshape(B, S, H * hd), cd)
        if cache == "init":
            return out, {"k": k, "v": v, "pos": jnp.array(S, jnp.int32)}
        return out

    # ---- decode: S == 1, rolling or full cache --------------------------
    C = cache["k"].shape[1]
    pos = cache["pos"]  # absolute position of the new token
    slot = jnp.mod(pos, C)
    ck = jax.lax.dynamic_update_slice(cache["k"], k, (0, slot, 0, 0))
    cv = jax.lax.dynamic_update_slice(cache["v"], v, (0, slot, 0, 0))
    # absolute position held by each slot j after the write:
    j = jnp.arange(C)
    slot_pos = pos - jnp.mod(pos - j, C)  # <= pos, same residue as j
    valid = slot_pos >= 0
    if window > 0:
        valid &= slot_pos > pos - window
    k_pos = jnp.where(valid, slot_pos, -10 ** 9)
    if impl == "pallas":
        # window already folded into k_pos validity
        from repro.kernels.flash_decode import ops as fd_ops
        o = fd_ops.flash_decode(q, ck, cv, q_pos=pos,
                                k_pos=jnp.broadcast_to(k_pos[None], (B, C)))
    else:
        o = sdpa_naive(q, ck, cv, q_pos=positions, k_pos=k_pos, causal=True,
                       window=0)
    out = dense(params["wo"], o.reshape(B, 1, H * hd), cd)
    return out, {"k": ck, "v": cv, "pos": pos + 1}


def gqa_cache_init(cfg, batch: int, cache_len: int, dtype=None) -> Params:
    dt = dtype or cfg.compute_dtype
    K, hd = cfg.n_kv_heads, cfg.hd
    return {"k": jnp.zeros((batch, cache_len, K, hd), dt),
            "v": jnp.zeros((batch, cache_len, K, hd), dt),
            "pos": jnp.array(0, jnp.int32)}


# ---------------------------------------------------------------------------
# MLA (DeepSeek-V2): low-rank compressed KV, absorbed decode
# ---------------------------------------------------------------------------

def mla_init(key, cfg) -> Params:
    kg = KeyGen(key)
    d, H = cfg.d_model, cfg.n_heads
    r, rq = cfg.kv_lora, cfg.q_lora
    dn = cfg.hd                 # nope sub-dim per head
    dr = cfg.rope_dims
    dv = cfg.v_head_dim
    dt = cfg.param_dtype
    return {
        "wq_a": dense_init(kg(), d, rq, dt),
        "q_norm": {"scale": jnp.ones((rq,), dt)},
        "wq_b": dense_init(kg(), rq, H * (dn + dr), dt),
        "wkv_a": dense_init(kg(), d, r + dr, dt),
        "kv_norm": {"scale": jnp.ones((r,), dt)},
        "wk_b": dense_init(kg(), r, H * dn, dt),
        "wv_b": dense_init(kg(), r, H * dv, dt),
        "wo": dense_init(kg(), H * dv, d, dt, stddev=0.02 / math.sqrt(2 * cfg.n_layers)),
    }


def _mla_project_q(params, x, cfg, positions):
    from .common import rmsnorm
    B, S, _ = x.shape
    H, dn, dr = cfg.n_heads, cfg.hd, cfg.rope_dims
    cd = cfg.compute_dtype
    qa = rmsnorm(params["q_norm"], dense(params["wq_a"], x, cd))
    qb = dense(params["wq_b"], qa, cd).reshape(B, S, H, dn + dr)
    q_nope, q_rope = qb[..., :dn], qb[..., dn:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    return q_nope, q_rope


def mla_apply(params: Params, x, *, cfg, positions, cache=None, impl="chunked"):
    from .common import rmsnorm
    B, S, d = x.shape
    H, r, dn, dr, dv = cfg.n_heads, cfg.kv_lora, cfg.hd, cfg.rope_dims, cfg.v_head_dim
    cd = cfg.compute_dtype
    q_nope, q_rope = _mla_project_q(params, x, cfg, positions)

    kv = dense(params["wkv_a"], x, cd)
    ckv, k_rope = kv[..., :r], kv[..., r:]
    ckv = rmsnorm(params["kv_norm"], ckv)
    k_rope = apply_rope(k_rope[:, :, None, :], positions, cfg.rope_theta)[:, :, 0, :]

    if cache is None or cache == "init":
        # prefill/train: up-project and run standard MHA with split rope dims
        k_nope = dense(params["wk_b"], ckv, cd).reshape(B, S, H, dn)
        vv = dense(params["wv_b"], ckv, cd).reshape(B, S, H, dv)
        q = jnp.concatenate([q_nope, q_rope], -1)
        k = jnp.concatenate([k_nope, jnp.broadcast_to(k_rope[:, :, None, :], (B, S, H, dr))], -1)
        scale = 1.0 / math.sqrt(dn + dr)
        # pad v to q head_dim for the shared sdpa, then slice back
        o = sdpa(q, k, jnp.pad(vv, ((0, 0), (0, 0), (0, 0), (0, dn + dr - dv))),
                 q_pos=positions, k_pos=positions, causal=True, impl=impl, scale=scale)
        o = o[..., :dv]
        out = dense(params["wo"], o.reshape(B, S, H * dv), cd)
        if cache == "init":
            return out, {"ckv": ckv, "krope": k_rope, "pos": jnp.array(S, jnp.int32)}
        return out

    # ---- absorbed decode (S == 1): score/value in latent space ----------
    C = cache["ckv"].shape[1]
    pos = cache["pos"]
    cc = jax.lax.dynamic_update_slice(cache["ckv"], ckv, (0, pos, 0))
    cr = jax.lax.dynamic_update_slice(cache["krope"], k_rope, (0, pos, 0))
    # absorb W_uk into q:  q_lat[b,h,r'] = sum_dn q_nope[b,h,dn] * Wk_b[r',h,dn]
    wkb = params["wk_b"]["w"].reshape(r, H, dn).astype(cd)
    q_lat = jnp.einsum("bhd,rhd->bhr", q_nope[:, 0], wkb)
    scale = 1.0 / math.sqrt(dn + dr)
    s = (jnp.einsum("bhr,bsr->bhs", q_lat.astype(jnp.float32), cc.astype(jnp.float32))
         + jnp.einsum("bhd,bsd->bhs", q_rope[:, 0].astype(jnp.float32), cr.astype(jnp.float32))) * scale
    k_pos = jnp.arange(C)
    s = jnp.where((k_pos <= pos)[None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o_lat = jnp.einsum("bhs,bsr->bhr", p, cc.astype(jnp.float32))  # (B,H,r)
    wvb = params["wv_b"]["w"].reshape(r, H, dv).astype(cd)
    o = jnp.einsum("bhr,rhd->bhd", o_lat.astype(cd), wvb)
    out = dense(params["wo"], o.reshape(B, 1, H * dv), cd)
    return out, {"ckv": cc, "krope": cr, "pos": pos + 1}


def mla_cache_init(cfg, batch: int, cache_len: int, dtype=None) -> Params:
    dt = dtype or cfg.compute_dtype
    return {"ckv": jnp.zeros((batch, cache_len, cfg.kv_lora), dt),
            "krope": jnp.zeros((batch, cache_len, cfg.rope_dims), dt),
            "pos": jnp.array(0, jnp.int32)}

"""State-space / recurrent blocks: Mamba2 (SSD) and xLSTM (mLSTM + sLSTM).

Mamba2 uses the chunked SSD formulation (matmul-dominant — the TPU-native
adaptation of the CUDA selective-scan: intra-chunk quadratic attention-like
einsums feed the MXU, inter-chunk state carried by a short lax.scan).

Each block exposes:
  *_init(key, cfg)                  parameter pytree
  *_apply(params, x, cfg)           full-sequence (train/prefill) -> (y, state)
  *_step(params, x1, state, cfg)    single-token decode -> (y1, state)
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from .common import KeyGen, Params, dense, dense_init, normal_init, rmsnorm


# ===========================================================================
# Mamba2 (SSD)
# ===========================================================================

def _mamba_dims(cfg):
    d_inner = cfg.ssm_expand * cfg.d_model
    H = cfg.ssm_heads or max(1, d_inner // 64)
    P = d_inner // H
    N = cfg.ssm_state
    return d_inner, H, P, N


def mamba2_init(key, cfg) -> Params:
    kg = KeyGen(key)
    d = cfg.d_model
    d_inner, H, P, N = _mamba_dims(cfg)
    dt = cfg.param_dtype
    conv_ch = d_inner + 2 * N
    return {
        "w_in": dense_init(kg(), d, 2 * d_inner + 2 * N + H, dt),
        "conv_w": normal_init(kg(), (cfg.conv_kernel, conv_ch), dt, 0.1),
        "conv_b": jnp.zeros((conv_ch,), dt),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, H)).astype(dt),
        "D": jnp.ones((H,), dt),
        "dt_bias": jnp.zeros((H,), dt),
        "norm": {"scale": jnp.ones((d_inner,), dt)},
        "w_out": dense_init(kg(), d_inner, d, dt,
                            stddev=0.02 / math.sqrt(2 * max(cfg.n_layers, 1))),
    }


def _causal_conv(x, w, b):
    """x: (B,S,C) depthwise causal conv, kernel K."""
    K = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    y = sum(xp[:, i: i + x.shape[1]] * w[i] for i in range(K))
    return y + b


def _segsum(x):
    """x: (..., Q) -> (..., Q, Q) with out[i,j] = sum_{j<m<=i} x[m], -inf above diag."""
    Q = x.shape[-1]
    cs = jnp.cumsum(x, -1)
    d = cs[..., :, None] - cs[..., None, :]
    return jnp.where(jnp.tril(jnp.ones((Q, Q), bool)), d, -jnp.inf)


def ssd_chunked(xh, dtv, A, Bm, Cm, chunk: int):
    """Chunked SSD. xh:(B,S,H,P) dtv:(B,S,H) A:(H,) Bm,Cm:(B,S,N).
    Returns (y:(B,S,H,P), final_state:(B,H,P,N))."""
    B_, S, H, P = xh.shape
    N = Bm.shape[-1]
    Q = min(chunk, S)
    nc = -(-S // Q)
    if nc * Q != S:
        # pad with dt=0 steps: decay exp(0)=1 and contribution dt*x=0, so
        # the recurrence (and final state) are exactly preserved
        pad = nc * Q - S
        xh = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dtv = jnp.pad(dtv, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
    S_pad = nc * Q
    f32 = jnp.float32
    xc = xh.reshape(B_, nc, Q, H, P).astype(f32)
    dtc = dtv.reshape(B_, nc, Q, H).astype(f32)
    Bc = Bm.reshape(B_, nc, Q, N).astype(f32)
    Cc = Cm.reshape(B_, nc, Q, N).astype(f32)
    dA = dtc * A.astype(f32)                            # (B,nc,Q,H)  (negative)
    dAh = dA.transpose(0, 1, 3, 2)                      # (B,nc,H,Q)
    dA_cs = jnp.cumsum(dAh, -1)                         # (B,nc,H,Q)
    xd = xc * dtc[..., None]                            # dt-weighted input

    # intra-chunk (quadratic within chunk — MXU friendly)
    L = jnp.exp(_segsum(dAh))                           # (B,nc,H,Q,Q)
    y_diag = jnp.einsum("bcin,bcjn,bchij,bcjhp->bcihp", Cc, Bc, L, xd)

    # per-chunk input->state
    decay_states = jnp.exp(dA_cs[..., -1:] - dA_cs)     # (B,nc,H,Q)
    states = jnp.einsum("bcjn,bchj,bcjhp->bchpn", Bc, decay_states, xd)

    # inter-chunk recurrence
    chunk_decay = jnp.exp(dA_cs[..., -1])               # (B,nc,H)

    def step(h, inp):
        s_c, dec = inp
        h_new = h * dec[..., None, None] + s_c
        return h_new, h                                 # emit state BEFORE chunk

    h0 = jnp.zeros((B_, H, P, N), f32)
    hT, prev = jax.lax.scan(step, h0, (states.swapaxes(0, 1), chunk_decay.swapaxes(0, 1)))
    prev = prev.swapaxes(0, 1)                          # (B,nc,H,P,N)
    y_off = jnp.einsum("bcin,bchpn,bchi->bcihp", Cc, prev, jnp.exp(dA_cs))
    y = (y_diag + y_off).reshape(B_, S_pad, H, P)[:, :S]
    return y.astype(xh.dtype), hT


def mamba2_apply(params: Params, x, *, cfg, return_state=False):
    B, S, d = x.shape
    d_inner, H, P, N = _mamba_dims(cfg)
    cd = cfg.compute_dtype
    zxbcdt = dense(params["w_in"], x, cd)
    z = zxbcdt[..., :d_inner]
    xBC = zxbcdt[..., d_inner: 2 * d_inner + 2 * N]
    dtv = zxbcdt[..., 2 * d_inner + 2 * N:]
    xBC = jax.nn.silu(_causal_conv(xBC, params["conv_w"].astype(cd),
                                   params["conv_b"].astype(cd)))
    xh = xBC[..., :d_inner].reshape(B, S, H, P)
    Bm = xBC[..., d_inner: d_inner + N]
    Cm = xBC[..., d_inner + N:]
    dtv = jax.nn.softplus(dtv.astype(jnp.float32) + params["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(params["A_log"].astype(jnp.float32))
    y, hT = ssd_chunked(xh, dtv, A, Bm, Cm, cfg.ssm_chunk)
    y = y + params["D"].astype(cd)[None, None, :, None] * xh
    y = y.reshape(B, S, d_inner)
    y = rmsnorm(params["norm"], y) * jax.nn.silu(z)
    out = dense(params["w_out"], y, cd)
    if return_state:
        conv_tail = _conv_tail(x, zxbcdt, cfg)
        return out, {"ssm": hT, "conv": conv_tail}
    return out


def _conv_tail(x, zxbcdt, cfg):
    """Last (K-1) pre-conv xBC inputs, for decode cache continuity."""
    d_inner, H, P, N = _mamba_dims(cfg)
    K = cfg.conv_kernel
    xBC_raw = zxbcdt[..., d_inner: 2 * d_inner + 2 * N]
    tail = xBC_raw[:, -(K - 1):]
    pad = (K - 1) - tail.shape[1]
    if pad > 0:
        tail = jnp.pad(tail, ((0, 0), (pad, 0), (0, 0)))
    return tail


def mamba2_state_init(cfg, batch: int, dtype=jnp.float32) -> Params:
    d_inner, H, P, N = _mamba_dims(cfg)
    return {"ssm": jnp.zeros((batch, H, P, N), jnp.float32),
            "conv": jnp.zeros((batch, cfg.conv_kernel - 1, d_inner + 2 * N), dtype)}


def mamba2_step(params: Params, x1, state, *, cfg):
    """x1: (B,1,d) single-token decode."""
    B = x1.shape[0]
    d_inner, H, P, N = _mamba_dims(cfg)
    cd = cfg.compute_dtype
    zxbcdt = dense(params["w_in"], x1, cd)
    z = zxbcdt[..., :d_inner]
    xBC_raw = zxbcdt[:, 0, d_inner: 2 * d_inner + 2 * N]
    dtv = zxbcdt[:, 0, 2 * d_inner + 2 * N:]
    conv = jnp.concatenate([state["conv"], xBC_raw[:, None]], axis=1)  # (B,K,C)
    w = params["conv_w"].astype(cd)
    xBC = jax.nn.silu(jnp.einsum("bkc,kc->bc", conv, w) + params["conv_b"].astype(cd))
    xh = xBC[:, :d_inner].reshape(B, H, P)
    Bm = xBC[:, d_inner: d_inner + N]
    Cm = xBC[:, d_inner + N:]
    dtv = jax.nn.softplus(dtv.astype(jnp.float32) + params["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(params["A_log"].astype(jnp.float32))
    dA = jnp.exp(dtv * A)                                # (B,H)
    h = state["ssm"] * dA[..., None, None] + jnp.einsum(
        "bh,bhp,bn->bhpn", dtv, xh.astype(jnp.float32), Bm.astype(jnp.float32))
    y = jnp.einsum("bhpn,bn->bhp", h, Cm.astype(jnp.float32)).astype(cd)
    y = y + params["D"].astype(cd)[None, :, None] * xh
    y = y.reshape(B, 1, d_inner)
    y = rmsnorm(params["norm"], y) * jax.nn.silu(z)
    out = dense(params["w_out"], y, cd)
    return out, {"ssm": h, "conv": conv[:, 1:]}


# ===========================================================================
# xLSTM — mLSTM (matrix memory) and sLSTM (scalar memory)
# ===========================================================================

def mlstm_init(key, cfg) -> Params:
    kg = KeyGen(key)
    d = cfg.d_model
    H = cfg.n_heads
    d_inner = 2 * d
    dk = d_inner // H
    dt = cfg.param_dtype
    return {
        "w_up": dense_init(kg(), d, 2 * d_inner, dt),      # x_in, gate z
        "conv_w": normal_init(kg(), (4, d_inner), dt, 0.1),
        "conv_b": jnp.zeros((d_inner,), dt),
        "wq": dense_init(kg(), d_inner, d_inner, dt),
        "wk": dense_init(kg(), d_inner, d_inner, dt),
        "wv": dense_init(kg(), d_inner, d_inner, dt),
        "wi": dense_init(kg(), d_inner, H, dt, bias=True),
        "wf": dense_init(kg(), d_inner, H, dt, bias=True),
        "norm": {"scale": jnp.ones((d_inner,), dt)},
        "w_down": dense_init(kg(), d_inner, d, dt,
                             stddev=0.02 / math.sqrt(2 * max(cfg.n_layers, 1))),
    }


def _mlstm_cell(q, k, v, ig, fg, state):
    """One step. q,k,v: (B,H,dk|dv); ig,fg: (B,H) raw gates.
    state = (C:(B,H,dv,dk), n:(B,H,dk), m:(B,H))."""
    C, n, m = state
    logf = jax.nn.log_sigmoid(fg)
    m_new = jnp.maximum(logf + m, ig)
    fp = jnp.exp(logf + m - m_new)
    ip = jnp.exp(ig - m_new)
    C = C * fp[..., None, None] + ip[..., None, None] * (v[..., :, None] * k[..., None, :])
    n = n * fp[..., None] + ip[..., None] * k
    num = jnp.einsum("bhvk,bhk->bhv", C, q)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhk,bhk->bh", n, q)), 1.0)
    return num / den[..., None], (C, n, m_new)


def mlstm_chunkwise(q, k, v, ig, fg, chunk: int, state=None):
    """Chunkwise-parallel mLSTM, algebraically exact vs the step cell
    (tests/test_ssm.py::test_mlstm_chunkwise_vs_scan).

    The time-step scan keeps a (B,H,dv,dk) matrix state PER STEP alive for
    backward — ~S x dk^2 HBM traffic. This reformulation (the TPU-native
    adaptation, cf. SSD/GLA chunking) does intra-chunk work as masked (L,L)
    matmuls on the MXU and carries one stabilized state per chunk:
      scan length S -> S/L,  saved state volume / L.

    q,k,v: (B,S,H,dk) f32; ig,fg: (B,S,H) raw gates. Returns (y, (C,n,m))."""
    B, S, H, dk = q.shape
    L = min(chunk, S)
    nc = -(-S // L)
    pad = nc * L - S
    if pad:
        # pad with fg -> +inf (f=1, no decay) and ig -> -inf (no input):
        # the recurrence and final state pass through unchanged
        zpad = ((0, 0), (0, pad), (0, 0), (0, 0))
        q = jnp.pad(q, zpad)
        k = jnp.pad(k, zpad)
        v = jnp.pad(v, zpad)
        ig = jnp.pad(ig, ((0, 0), (0, pad), (0, 0)), constant_values=-1e30)
        fg = jnp.pad(fg, ((0, 0), (0, pad), (0, 0)), constant_values=40.0)

    def cks(x):  # (B,S,H,...) -> (nc, B, H, L, ...)
        x = x.reshape((B, nc, L) + x.shape[2:])
        return jnp.moveaxis(jnp.swapaxes(x, 2, 3), 1, 0) if x.ndim == 5 else \
            jnp.moveaxis(x.transpose(0, 1, 3, 2), 1, 0)

    qc = cks(q)   # (nc,B,H,L,dk)
    kc = cks(k)
    vc = cks(v)
    igc = cks(ig)  # (nc,B,H,L)
    fgc = cks(fg)

    if state is None:
        C0 = jnp.zeros((B, H, dk, dk))
        n0 = jnp.zeros((B, H, dk))
        m0 = jnp.full((B, H), -1e30)
    else:
        C0, n0, m0 = state

    mask = jnp.tril(jnp.ones((L, L), bool))

    def chunk_step(carry, inp):
        C, n, m = carry                      # C:(B,H,dv,dk) n:(B,H,dk) m:(B,H)
        qi, ki, vi, ai, fi = inp
        logf = jax.nn.log_sigmoid(fi)        # (B,H,L)
        b = jnp.cumsum(logf, axis=-1)        # local cumulative decay
        g = ai - b                           # (B,H,L)
        gmax = jax.lax.cummax(g, axis=g.ndim - 1)
        m_i = b + jnp.maximum(m[..., None], gmax)          # (B,H,L)
        # intra-chunk decay matrix D_ij = exp(b_i + g_j - m_i), j <= i.
        # mask BEFORE exp: for j > i the argument can be large-positive
        # (b_i - b_j > 0), and exp -> inf would poison the backward even
        # under a post-hoc where (inf * 0 = NaN in the VJP).
        arg = b[..., :, None] + g[..., None, :] - m_i[..., :, None]
        D = jnp.exp(jnp.where(mask, arg, -jnp.inf))
        s = jnp.einsum("bhik,bhjk->bhij", qi, ki)          # q.k
        w = D * s
        inter = jnp.exp(m[..., None] + b - m_i)            # (B,H,L)
        num = jnp.einsum("bhij,bhjv->bhiv", w, vi) + \
            inter[..., None] * jnp.einsum("bhvk,bhik->bhiv", C, qi)
        den = jnp.sum(w, axis=-1) + inter * jnp.einsum("bhk,bhik->bhi", n, qi)
        y = num / jnp.maximum(jnp.abs(den), 1.0)[..., None]
        # end-of-chunk state
        bL = b[..., -1:]                                   # (B,H,1)
        m_new = bL[..., 0] + jnp.maximum(m, gmax[..., -1])
        sc = jnp.exp(bL + g - m_new[..., None])            # (B,H,L)
        C_new = jnp.exp(m + bL[..., 0] - m_new)[..., None, None] * C + \
            jnp.einsum("bhj,bhjv,bhjk->bhvk", sc, vi, ki)
        n_new = jnp.exp(m + bL[..., 0] - m_new)[..., None] * n + \
            jnp.einsum("bhj,bhjk->bhk", sc, ki)
        return (C_new, n_new, m_new), y

    (C, n, m), ys = jax.lax.scan(chunk_step, (C0, n0, m0),
                                 (qc, kc, vc, igc, fgc))
    # ys: (nc,B,H,L,dk) -> (B,S,H,dk)
    y = jnp.moveaxis(ys, 0, 1).swapaxes(2, 3).reshape(B, nc * L, H, dk)[:, :S]
    return y, (C, n, m)


def mlstm_apply(params: Params, x, *, cfg, return_state=False,
                use_chunked=None):
    if use_chunked is None:
        use_chunked = getattr(cfg, "mlstm_chunked", True)
    B, S, d = x.shape
    H = cfg.n_heads
    d_inner = 2 * d
    dk = d_inner // H
    cd = cfg.compute_dtype
    up = dense(params["w_up"], x, cd)
    xin, z = up[..., :d_inner], up[..., d_inner:]
    xc = jax.nn.silu(_causal_conv(xin, params["conv_w"].astype(cd),
                                  params["conv_b"].astype(cd)))
    q = dense(params["wq"], xc, cd).reshape(B, S, H, dk)
    k = dense(params["wk"], xc, cd).reshape(B, S, H, dk) / math.sqrt(dk)
    v = dense(params["wv"], xin, cd).reshape(B, S, H, dk)
    ig = dense(params["wi"], xc, cd).astype(jnp.float32)
    fg = dense(params["wf"], xc, cd).astype(jnp.float32)

    if use_chunked:
        yq, stT = mlstm_chunkwise(q.astype(jnp.float32), k.astype(jnp.float32),
                                  v.astype(jnp.float32), ig, fg,
                                  cfg.ssm_chunk or 64)
        y = yq.reshape(B, S, d_inner).astype(cd)
        stT = {"C": stT[0], "n": stT[1], "m": stT[2]}
    else:
        def step(st, inp):
            qt, kt, vt, it, ft = inp
            yt, st = _mlstm_cell(qt.astype(jnp.float32), kt.astype(jnp.float32),
                                 vt.astype(jnp.float32), it, ft, st)
            return st, yt

        st0 = (jnp.zeros((B, H, dk, dk)), jnp.zeros((B, H, dk)),
               jnp.full((B, H), -1e30))
        st, ys = jax.lax.scan(step, st0, (q.swapaxes(0, 1), k.swapaxes(0, 1),
                                          v.swapaxes(0, 1), ig.swapaxes(0, 1),
                                          fg.swapaxes(0, 1)))
        y = ys.swapaxes(0, 1).reshape(B, S, d_inner).astype(cd)
        stT = {"C": st[0], "n": st[1], "m": st[2]}

    y = rmsnorm(params["norm"], y) * jax.nn.silu(z)
    out = dense(params["w_down"], y, cd)
    if return_state:
        conv_tail = xin[:, -3:]
        pad = 3 - conv_tail.shape[1]
        if pad > 0:
            conv_tail = jnp.pad(conv_tail, ((0, 0), (pad, 0), (0, 0)))
        return out, dict(stT, conv=conv_tail)
    return out


def mlstm_state_init(cfg, batch: int, dtype=None) -> Params:
    H = cfg.n_heads
    d_inner = 2 * cfg.d_model
    dk = d_inner // H
    return {"C": jnp.zeros((batch, H, dk, dk)), "n": jnp.zeros((batch, H, dk)),
            "m": jnp.full((batch, H), -1e30),
            "conv": jnp.zeros((batch, 3, d_inner), dtype or cfg.compute_dtype)}


def mlstm_step(params: Params, x1, state, *, cfg):
    B = x1.shape[0]
    d = cfg.d_model
    H = cfg.n_heads
    d_inner = 2 * d
    dk = d_inner // H
    cd = cfg.compute_dtype
    up = dense(params["w_up"], x1, cd)
    xin, z = up[:, 0, :d_inner], up[:, 0, d_inner:]
    conv = jnp.concatenate([state["conv"], xin[:, None]], axis=1)
    xc = jax.nn.silu(jnp.einsum("bkc,kc->bc", conv, params["conv_w"].astype(cd))
                     + params["conv_b"].astype(cd))
    q = dense(params["wq"], xc, cd).reshape(B, H, dk)
    k = dense(params["wk"], xc, cd).reshape(B, H, dk) / math.sqrt(dk)
    v = dense(params["wv"], xin, cd).reshape(B, H, dk)
    ig = dense(params["wi"], xc, cd).astype(jnp.float32)
    fg = dense(params["wf"], xc, cd).astype(jnp.float32)
    y, (C, n, m) = _mlstm_cell(q.astype(jnp.float32), k.astype(jnp.float32),
                               v.astype(jnp.float32), ig, fg,
                               (state["C"], state["n"], state["m"]))
    y = y.reshape(B, 1, d_inner).astype(cd)
    y = rmsnorm(params["norm"], y) * jax.nn.silu(z[:, None])
    out = dense(params["w_down"], y, cd)
    return out, {"C": C, "n": n, "m": m, "conv": conv[:, 1:]}


def slstm_init(key, cfg) -> Params:
    kg = KeyGen(key)
    d = cfg.d_model
    H = cfg.n_heads
    dh = d // H
    dt = cfg.param_dtype
    ff = 2 * d  # xLSTM sLSTM post-FFN (proj-factor deviation noted in DESIGN.md)
    return {
        "w_gates": dense_init(kg(), d, 4 * d, dt, bias=True),   # i,f,z,o from x
        "r_gates": normal_init(kg(), (H, dh, 4 * dh), dt, 1 / math.sqrt(dh)),
        "norm": {"scale": jnp.ones((d,), dt)},
        "w_ff_up": dense_init(kg(), d, ff, dt),
        "w_ff_down": dense_init(kg(), ff, d, dt,
                                stddev=0.02 / math.sqrt(2 * max(cfg.n_layers, 1))),
    }


def _slstm_cell(gx, h_prev, state, r, H, dh):
    """gx: (B,4d) input gate pre-acts; h_prev: (B,d); state=(c,n,m) each (B,d)."""
    c, n, m = state
    B = gx.shape[0]
    hr = h_prev.reshape(B, H, dh)
    gr = jnp.einsum("bhd,hdk->bhk", hr, r).reshape(B, 4 * H * dh)
    g = (gx + gr).reshape(B, 4, H * dh)
    ig, fg, zg, og = g[:, 0], g[:, 1], g[:, 2], g[:, 3]
    logf = jax.nn.log_sigmoid(fg)
    m_new = jnp.maximum(logf + m, ig)
    ip = jnp.exp(ig - m_new)
    fp = jnp.exp(logf + m - m_new)
    c = fp * c + ip * jnp.tanh(zg)
    n = fp * n + ip
    h = jax.nn.sigmoid(og) * c / jnp.maximum(n, 1.0)
    return h, (c, n, m_new)


def slstm_apply(params: Params, x, *, cfg, return_state=False):
    B, S, d = x.shape
    H = cfg.n_heads
    dh = d // H
    cd = cfg.compute_dtype
    gx = dense(params["w_gates"], x, cd).astype(jnp.float32)
    r = params["r_gates"].astype(jnp.float32)

    def step(carry, g):
        h_prev, st = carry
        h, st = _slstm_cell(g, h_prev, st, r, H, dh)
        return (h, st), h

    st0 = (jnp.zeros((B, d)), jnp.zeros((B, d)), jnp.full((B, d), -1e30))
    (hT, stT), hs = jax.lax.scan(step, (jnp.zeros((B, d)), st0), gx.swapaxes(0, 1))
    y = hs.swapaxes(0, 1).astype(cd)
    y = rmsnorm(params["norm"], y)
    ff = dense(params["w_ff_down"],
               jax.nn.gelu(dense(params["w_ff_up"], y, cd)), cd)
    out = ff
    if return_state:
        return out, {"h": hT, "c": stT[0], "n": stT[1], "m": stT[2]}
    return out


def slstm_state_init(cfg, batch: int, dtype=None) -> Params:
    d = cfg.d_model
    return {"h": jnp.zeros((batch, d)), "c": jnp.zeros((batch, d)),
            "n": jnp.zeros((batch, d)), "m": jnp.full((batch, d), -1e30)}


def slstm_step(params: Params, x1, state, *, cfg):
    B = x1.shape[0]
    d = cfg.d_model
    H = cfg.n_heads
    dh = d // H
    cd = cfg.compute_dtype
    gx = dense(params["w_gates"], x1, cd).astype(jnp.float32)[:, 0]
    r = params["r_gates"].astype(jnp.float32)
    h, (c, n, m) = _slstm_cell(gx, state["h"], (state["c"], state["n"], state["m"]),
                               r, H, dh)
    y = rmsnorm(params["norm"], h[:, None].astype(cd))
    out = dense(params["w_ff_down"], jax.nn.gelu(dense(params["w_ff_up"], y, cd)), cd)
    return out, {"h": h, "c": c, "n": n, "m": m}

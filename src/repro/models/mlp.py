"""Feed-forward blocks: SwiGLU (gated) and plain 2-layer MLP."""
from __future__ import annotations

import jax.numpy as jnp

from .common import ACT, KeyGen, Params, dense, dense_init


def swiglu_init(key, d: int, d_ff: int, dtype, n_layers: int = 2) -> Params:
    kg = KeyGen(key)
    import math
    return {
        "w_gate": dense_init(kg(), d, d_ff, dtype),
        "w_up": dense_init(kg(), d, d_ff, dtype),
        "w_down": dense_init(kg(), d_ff, d, dtype, stddev=0.02 / math.sqrt(2 * n_layers)),
    }


def swiglu_apply(params: Params, x, act="silu", compute_dtype=None):
    g = dense(params["w_gate"], x, compute_dtype)
    u = dense(params["w_up"], x, compute_dtype)
    return dense(params["w_down"], ACT[act](g) * u, compute_dtype)


def mlp_init(key, d_in: int, hidden: int, d_out: int, n_hidden: int, dtype,
             bias: bool = True) -> Params:
    """Plain MLP with n_hidden hidden layers (HydraGNN head style)."""
    kg = KeyGen(key)
    dims = [d_in] + [hidden] * n_hidden + [d_out]
    return {f"fc{i}": dense_init(kg(), dims[i], dims[i + 1], dtype, bias=bias)
            for i in range(len(dims) - 1)}


def mlp_apply(params: Params, x, act="relu", compute_dtype=None):
    n = len(params)
    for i in range(n):
        x = dense(params[f"fc{i}"], x, compute_dtype)
        if i < n - 1:
            x = ACT[act](x)
    return x

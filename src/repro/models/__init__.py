from . import attention, common, frontends, gnn, heads, mlp, moe, ssm, transformer  # noqa: F401

"""EGNN encoder (the paper's HydraGNN backbone: 4-layer EGNN, 866 hidden).

Operates on padded graph batches (atomistic structures are small graphs —
hundreds of nodes — so we batch many padded graphs, per the paper's workload
shape, rather than partitioning one monolithic graph):

  species:    (B, A)    int32   atomic numbers (0 = pad)
  pos:        (B, A, 3) float   coordinates
  edge_src:   (B, E)    int32   source node index (A = pad sentinel)
  edge_dst:   (B, E)    int32   destination node index
  node_mask:  (B, A)    bool
  edge_mask:  (B, E)    bool

Message aggregation is a segment-sum — the MPNN hot spot. Implementations
(selected per call or via ``cfg.segment_sum_impl``):

  * ``"scatter"`` (default) — ``zeros.at[b, dst].add(msg)``: one XLA
    scatter-add, O(E·F) work. Fastest lowering on CPU/GPU and what XLA:TPU
    rewrites into its own sorted-segment ops.
  * ``"jnp"``     — one-hot einsum per graph, O(E·A·F) work. The original
    reference formulation; kept as the parity oracle.
  * ``"pallas"``  — blocked mask-matmul MXU kernel
    (``repro.kernels.segment_sum``), batched grid over B.
  * ``"fused"``   — the full message hot path (gather -> d² -> φ_e MLP ->
    masked segment-sum) in one Pallas kernel (``repro.kernels.egnn_edge``),
    never materializing the (B,E,2H+1) concat in HBM.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from .common import KeyGen, Params, dense, embedding_init, embed
from .mlp import mlp_init, mlp_apply

SEGMENT_SUM_IMPLS = ("scatter", "jnp", "pallas", "fused")


def segment_sum_nodes(messages, dst, n_nodes, *, edge_mask, impl="scatter",
                      block_n=None, block_e=None):
    """messages: (B,E,F), dst: (B,E) -> (B,A,F) summing messages into nodes.

    ``impl``: "scatter" | "jnp" | "pallas" (see module docstring; "fused" is
    a whole-layer path and is dispatched in ``egnn_apply``, not here).
    ``block_n``/``block_e`` tile the Pallas kernel (None = autotune; only
    the "pallas" impl consumes them)."""
    if impl == "pallas":
        from repro.kernels.segment_sum import ops as ss_ops
        return ss_ops.segment_sum(messages, dst, n_nodes, edge_mask=edge_mask,
                                  block_n=block_n, block_e=block_e)
    if impl == "scatter":
        B = messages.shape[0]
        m = jnp.where(edge_mask[..., None], messages, 0.0)
        # masked / pad edges -> index n_nodes, out of range: dropped by the
        # scatter (mode="drop"), mirroring the Pallas sentinel contract
        d = jnp.where(edge_mask, dst, n_nodes)
        out = jnp.zeros((B, n_nodes) + messages.shape[2:], messages.dtype)
        return out.at[jnp.arange(B)[:, None], d].add(m, mode="drop")
    if impl != "jnp":
        raise ValueError(
            f"segment_sum impl '{impl}'; this op takes 'scatter' | 'jnp' | "
            "'pallas' ('fused' is a whole-layer path — select it via "
            "egnn_apply / cfg.segment_sum_impl)")
    m = jnp.where(edge_mask[..., None], messages, 0.0)
    oh = jax.nn.one_hot(dst, n_nodes, dtype=messages.dtype)       # (B,E,A)
    return jnp.einsum("bea,bef->baf", oh, m)


def egnn_init(key, cfg) -> Params:
    kg = KeyGen(key)
    hid = cfg.gnn_hidden
    dt = cfg.param_dtype
    p: Params = {"embed": embedding_init(kg(), cfg.n_species, hid, dt)}
    for i in range(cfg.gnn_layers):
        p[f"layer{i}"] = {
            "phi_e": mlp_init(kg(), 2 * hid + 1, hid, hid, 1, dt),
            "phi_h": mlp_init(kg(), 2 * hid, hid, hid, 1, dt),
        }
    return p


def egnn_apply(params: Params, batch: dict, *, cfg, impl=None) -> jnp.ndarray:
    """-> node features (B, A, hidden). Invariant (distance-based) features.
    impl selects the message-aggregation path ("scatter" | "jnp" | "pallas" |
    "fused"); None defers to ``cfg.segment_sum_impl`` (config-driven kernel
    selection)."""
    if impl is None:
        impl = getattr(cfg, "segment_sum_impl", "scatter") or "scatter"
    if impl not in SEGMENT_SUM_IMPLS:
        raise ValueError(f"segment_sum impl '{impl}'; "
                         f"known: {SEGMENT_SUM_IMPLS}")
    cd = cfg.compute_dtype
    # kernel tile override shared by the pallas + fused paths (0/absent =
    # autotune inside the kernel wrappers); block_h additionally tiles the
    # fused kernel's φ_e hidden axis (the H=866 VMEM enabler)
    bn = getattr(cfg, "kernel_block_n", 0) or None
    be = getattr(cfg, "kernel_block_e", 0) or None
    bh = getattr(cfg, "kernel_block_h", 0) or None
    species = batch["species"]
    pos = batch["pos"].astype(jnp.float32)
    src, dst = batch["edge_src"], batch["edge_dst"]
    nm, em = batch["node_mask"], batch["edge_mask"]
    B, A = species.shape
    h = embed(params["embed"], species, cd) * nm[..., None].astype(cd)

    def gather(x, idx):
        return jnp.take_along_axis(x, idx[..., None], axis=1)

    for i in range(cfg.gnn_layers):
        lp = params[f"layer{i}"]
        if impl == "fused":
            from repro.kernels.egnn_edge import ops as edge_ops
            agg = edge_ops.egnn_edge_agg(h, pos, src, dst, em, lp["phi_e"],
                                         compute_dtype=cd, block_e=be,
                                         block_h=bh)
        else:
            hi = gather(h, jnp.minimum(src, A - 1))
            hj = gather(h, jnp.minimum(dst, A - 1))
            xi = gather(pos, jnp.minimum(src, A - 1))
            xj = gather(pos, jnp.minimum(dst, A - 1))
            d2 = jnp.sum((xi - xj) ** 2, -1, keepdims=True).astype(cd)
            m = mlp_apply(lp["phi_e"], jnp.concatenate([hi, hj, d2], -1),
                          "silu", cd)
            agg = segment_sum_nodes(m, dst, A, edge_mask=em, impl=impl,
                                    block_n=bn, block_e=be)
        upd = mlp_apply(lp["phi_h"], jnp.concatenate([h, agg], -1), "silu", cd)
        h = (h + upd) * nm[..., None].astype(cd)
    return h

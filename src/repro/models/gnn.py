"""EGNN encoder (the paper's HydraGNN backbone: 4-layer EGNN, 866 hidden).

Operates on padded graph batches (atomistic structures are small graphs —
hundreds of nodes — so we batch many padded graphs, per the paper's workload
shape, rather than partitioning one monolithic graph):

  species:    (B, A)    int32   atomic numbers (0 = pad)
  pos:        (B, A, 3) float   coordinates
  edge_src:   (B, E)    int32   source node index (A = pad sentinel)
  edge_dst:   (B, E)    int32   destination node index
  node_mask:  (B, A)    bool
  edge_mask:  (B, E)    bool

Message aggregation is a segment-sum — the MPNN hot spot. The Pallas kernel
(`repro.kernels.segment_sum`) implements it as a blocked mask-matmul for the
MXU; the jnp path uses one-hot matmul per graph (identical math).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from .common import KeyGen, Params, dense, embedding_init, embed
from .mlp import mlp_init, mlp_apply


def segment_sum_nodes(messages, dst, n_nodes, *, edge_mask, impl="jnp"):
    """messages: (B,E,F), dst: (B,E) -> (B,A,F) summing messages into nodes."""
    if impl == "pallas":
        from repro.kernels.segment_sum import ops as ss_ops
        return ss_ops.segment_sum(messages, dst, n_nodes, edge_mask=edge_mask)
    m = jnp.where(edge_mask[..., None], messages, 0.0)
    oh = jax.nn.one_hot(dst, n_nodes, dtype=messages.dtype)       # (B,E,A)
    return jnp.einsum("bea,bef->baf", oh, m)


def egnn_init(key, cfg) -> Params:
    kg = KeyGen(key)
    hid = cfg.gnn_hidden
    dt = cfg.param_dtype
    p: Params = {"embed": embedding_init(kg(), cfg.n_species, hid, dt)}
    for i in range(cfg.gnn_layers):
        p[f"layer{i}"] = {
            "phi_e": mlp_init(kg(), 2 * hid + 1, hid, hid, 1, dt),
            "phi_h": mlp_init(kg(), 2 * hid, hid, hid, 1, dt),
        }
    return p


def egnn_apply(params: Params, batch: dict, *, cfg, impl=None) -> jnp.ndarray:
    """-> node features (B, A, hidden). Invariant (distance-based) features.
    impl selects the segment-sum kernel; None defers to
    ``cfg.segment_sum_impl`` (config-driven kernel selection)."""
    if impl is None:
        impl = getattr(cfg, "segment_sum_impl", "jnp") or "jnp"
    cd = cfg.compute_dtype
    species = batch["species"]
    pos = batch["pos"].astype(jnp.float32)
    src, dst = batch["edge_src"], batch["edge_dst"]
    nm, em = batch["node_mask"], batch["edge_mask"]
    B, A = species.shape
    h = embed(params["embed"], species, cd) * nm[..., None].astype(cd)

    def gather(x, idx):
        return jnp.take_along_axis(x, idx[..., None], axis=1)

    for i in range(cfg.gnn_layers):
        lp = params[f"layer{i}"]
        hi = gather(h, jnp.minimum(src, A - 1))
        hj = gather(h, jnp.minimum(dst, A - 1))
        xi = gather(pos, jnp.minimum(src, A - 1))
        xj = gather(pos, jnp.minimum(dst, A - 1))
        d2 = jnp.sum((xi - xj) ** 2, -1, keepdims=True).astype(cd)
        m = mlp_apply(lp["phi_e"], jnp.concatenate([hi, hj, d2], -1), "silu", cd)
        agg = segment_sum_nodes(m, dst, A, edge_mask=em, impl=impl)
        upd = mlp_apply(lp["phi_h"], jnp.concatenate([h, agg], -1), "silu", cd)
        h = (h + upd) * nm[..., None].astype(cd)
    return h

"""Decoding heads — including the paper's two-level hierarchical MTL heads.

Level 1: one branch per data source (task). Level 2: each branch owns an
energy head (graph-level scalar via masked mean-pool + MLP) and a force head
(node-level 3-vector via MLP). Heads are *stacked* along a leading task dim
so the multi-task-parallelism core can shard that dim over the mesh's task
axis (paper: each process owns one branch).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import KeyGen, Params
from .mlp import mlp_apply, mlp_init


def branch_init(key, cfg) -> Params:
    """One per-source branch: {energy, force} MLPs (paper: 3 FC x 889)."""
    kg = KeyGen(key)
    hid = cfg.gnn_hidden
    hh, hl = cfg.head_hidden, cfg.head_layers
    dt = cfg.param_dtype
    return {
        "energy": mlp_init(kg(), hid, hh, 1, hl, dt),
        "force": mlp_init(kg(), hid, hh, 3, hl, dt),
    }


def stacked_branches_init(key, cfg, n_tasks: int) -> Params:
    keys = jax.random.split(key, n_tasks)
    return jax.vmap(lambda k: branch_init(k, cfg))(keys)


def branch_apply(bp: Params, node_feats, node_mask, *, cfg):
    """node_feats: (B,A,hid) -> (energy_per_atom: (B,), forces: (B,A,3))."""
    cd = cfg.compute_dtype
    nm = node_mask[..., None].astype(cd)
    n = jnp.maximum(node_mask.sum(-1, keepdims=True).astype(jnp.float32), 1.0)
    pooled = (node_feats * nm).sum(1) / n.astype(cd)       # masked mean-pool
    e = mlp_apply(bp["energy"], pooled, "silu", cd)[..., 0]  # (B,)
    f = mlp_apply(bp["force"], node_feats, "silu", cd) * nm  # (B,A,3)
    return e.astype(jnp.float32), f.astype(jnp.float32)


def stacked_branches_apply(bp: Params, node_feats, node_mask, *, cfg):
    """Task-major inputs: node_feats (T,B,A,hid), node_mask (T,B,A).
    bp leaves have leading task dim (shardable over the task mesh axis)."""
    return jax.vmap(lambda p, h, m: branch_apply(p, h, m, cfg=cfg))(
        bp, node_feats, node_mask)

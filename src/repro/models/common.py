"""Common building blocks: norms, RoPE, initializers, dtype policy.

Everything is a pure function over pytree parameter dicts — no flax/haiku in
the container, and plain pytrees keep the sharding story explicit (the
config layer attaches a PartitionSpec to every leaf by name).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable

import jax
import jax.numpy as jnp

Params = dict  # nested {str: Params | jnp.ndarray}


@dataclasses.dataclass(frozen=True)
class Precision:
    """Dtype policy. TPU-native default: fp32 params, bf16 compute."""

    param_dtype: Any = jnp.float32
    compute_dtype: Any = jnp.bfloat16
    # dtype used for softmax / variance / loss reductions
    accum_dtype: Any = jnp.float32


DEFAULT_PRECISION = Precision()


def cast(x, dtype):
    return x.astype(dtype) if x.dtype != dtype else x


# ---------------------------------------------------------------------------
# Initializers (pure functions of a key; match common LLM init conventions)
# ---------------------------------------------------------------------------

def normal_init(key, shape, dtype, stddev=0.02):
    return (stddev * jax.random.normal(key, shape, jnp.float32)).astype(dtype)


def lecun_init(key, shape, dtype, fan_in=None):
    fan_in = fan_in if fan_in is not None else shape[0]
    return (jax.random.normal(key, shape, jnp.float32) / math.sqrt(max(fan_in, 1))).astype(dtype)


def zeros_init(_key, shape, dtype):
    return jnp.zeros(shape, dtype)


def ones_init(_key, shape, dtype):
    return jnp.ones(shape, dtype)


class KeyGen:
    """Split a PRNG key on demand: kg = KeyGen(key); w = init(kg(), ...)."""

    def __init__(self, key):
        self._key = key

    def __call__(self):
        self._key, sub = jax.random.split(self._key)
        return sub


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def rmsnorm_init(d: int, dtype=jnp.float32) -> Params:
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm(params: Params, x: jnp.ndarray, eps: float = 1e-6,
            upcast: bool = True) -> jnp.ndarray:
    dt = x.dtype
    if upcast:
        x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    return (y * params["scale"].astype(y.dtype)).astype(dt)


def layernorm_init(d: int, dtype=jnp.float32) -> Params:
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def layernorm(params: Params, x: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return (y * params["scale"] + params["bias"]).astype(dt)


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float = 10000.0) -> jnp.ndarray:
    """Inverse frequencies, shape (head_dim//2,)."""
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float = 10000.0) -> jnp.ndarray:
    """x: (..., S, H, hd); positions: broadcastable to (..., S)."""
    hd = x.shape[-1]
    inv = rope_freqs(hd, theta)  # (hd/2,)
    ang = positions[..., :, None].astype(jnp.float32) * inv  # (..., S, hd/2)
    cos = jnp.cos(ang)[..., :, None, :]  # (..., S, 1, hd/2)
    sin = jnp.sin(ang)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Dense layers as param dicts
# ---------------------------------------------------------------------------

def dense_init(key, d_in: int, d_out: int, dtype, bias: bool = False,
               stddev: float | None = None) -> Params:
    std = stddev if stddev is not None else 1.0 / math.sqrt(d_in)
    p = {"w": normal_init(key, (d_in, d_out), dtype, std)}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def dense(params: Params, x: jnp.ndarray, compute_dtype=None) -> jnp.ndarray:
    w = params["w"]
    if compute_dtype is not None:
        x = cast(x, compute_dtype)
        w = cast(w, compute_dtype)
    y = x @ w
    if "b" in params:
        y = y + cast(params["b"], y.dtype)
    return y


# ---------------------------------------------------------------------------
# Embedding
# ---------------------------------------------------------------------------

def embedding_init(key, vocab: int, d: int, dtype) -> Params:
    return {"table": normal_init(key, (vocab, d), dtype, 0.02)}


def embed(params: Params, ids: jnp.ndarray, compute_dtype=None) -> jnp.ndarray:
    t = params["table"]
    if compute_dtype is not None:
        t = cast(t, compute_dtype)
    return jnp.take(t, ids, axis=0)


def unembed(params: Params, x: jnp.ndarray) -> jnp.ndarray:
    """Tied unembedding: x @ table.T in fp32 accumulation."""
    t = params["table"].astype(x.dtype)
    return jnp.einsum("...d,vd->...v", x, t, preferred_element_type=jnp.float32)


# ---------------------------------------------------------------------------
# Misc
# ---------------------------------------------------------------------------

def count_params(params: Params) -> int:
    return sum(int(x.size) for x in jax.tree_util.tree_leaves(params))


def tree_bytes(params: Params) -> int:
    return sum(int(x.size) * x.dtype.itemsize for x in jax.tree_util.tree_leaves(params))


def gelu(x):
    return jax.nn.gelu(x, approximate=True)


def silu(x):
    return jax.nn.silu(x)


ACT: dict[str, Callable] = {"gelu": gelu, "silu": silu, "relu": jax.nn.relu,
                            "tanh": jnp.tanh}

"""Loop-aware analysis of compiled (post-SPMD, per-device) HLO text.

XLA's ``compiled.cost_analysis()`` counts while-loop bodies ONCE (verified in
tests/test_hlo_analysis.py), which under-reports FLOPs/bytes by the scan trip
count — fatal for models that lax.scan over layers. This module re-derives
loop-complete statistics directly from the HLO text:

  * computations are parsed into instruction lists with a symbol table
    (instruction name -> shape);
  * the call graph (fusion ``calls=``, ``to_apply=``, while ``body=`` /
    ``condition=``) propagates an execution-count multiplier; while trip
    counts are read from the loop-condition computation's bound constant;
  * FLOPs: 2 x |output| x |contracted dims| for every ``dot``;
  * HBM traffic: per scope-level instruction, output + operand bytes
    (fusions are XLA:CPU/TPU's codegen units, so computation-scope operands/
    results approximate materialised buffers);
  * collective bytes: output bytes of all-gather / all-reduce /
    reduce-scatter / all-to-all / collective-permute, times multiplier.

All numbers are PER-DEVICE (the SPMD program is per-device).
"""
from __future__ import annotations

import re
from collections import defaultdict
from dataclasses import dataclass, field

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s4": 1, "u4": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16,
}
COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")
_SKIP_TRAFFIC = ("parameter", "constant", "get-tuple-element", "tuple",
                 "bitcast", "while", "conditional", "call", "after-all",
                 "partition-id", "replica-id")

_SHAPE_TOK = re.compile(r"(\w+)\[([\d,]*)\]")
# one operand inside an instruction's argument list. Older XLA prints
# ``dot(%a, %b)``; this container's XLA prints typed operands
# ``dot(f32[128,128]{1,0} %a, ...)`` — the inline shape is captured as a
# fallback for names missing from the symbol table.
_OPERAND = re.compile(r"(?:([\w]+\[[\d,]*\](?:\{[^}]*\})?)\s+)?%([\w.\-]+)")
_INSTR = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*((?:\([^)]*\))|(?:[\w]+\[[\d,]*\]\S*))\s+([\w\-]+)\((.*)$")
_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\((.*)\)\s*->")


def _shape_elems_bytes(shape_str: str) -> tuple[int, int]:
    elems = bytes_ = 0
    for dt, dims in _SHAPE_TOK.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        elems += n
        bytes_ += n * _DTYPE_BYTES[dt]
    return elems, bytes_


def _shape_dims(shape_str: str) -> tuple[str, list[int]]:
    m = _SHAPE_TOK.search(shape_str)
    if not m:
        return "", []
    dims = [int(d) for d in m.group(2).split(",") if d]
    return m.group(1), dims


@dataclass
class Instr:
    name: str
    shape: str
    op: str
    rest: str


def _operands(ins: Instr) -> list[tuple[str, str | None]]:
    """(name, inline_shape_or_None) per operand of the instruction, robust
    to both bare (``%a``) and typed (``f32[..]{..} %a``) dump formats."""
    return [(m.group(2), m.group(1))
            for m in _OPERAND.finditer(ins.rest.split(")")[0])]


def _operand_shape(comp: "Computation", name: str, inline: str | None) -> str:
    return comp.symtab.get(name) or inline or ""


@dataclass
class Computation:
    name: str
    instrs: list = field(default_factory=list)
    symtab: dict = field(default_factory=dict)   # value name -> shape str
    calls: list = field(default_factory=list)    # (callee, kind) kind in {call, body, cond}


def _trip_count(comps, cond_name: str) -> int:
    """Bound constant in the loop condition computation (lax.scan canonical:
    induction var starts at 0, compared LT against the trip bound). Falls
    back to 1 (the cost_analysis behaviour) when no bound is found."""
    vals = []
    seen: set = set()
    stack = [cond_name]
    while stack:
        c = stack.pop()
        if c in seen or c not in comps:
            continue
        seen.add(c)
        for ins in comps[c].instrs:
            if ins.op == "constant" and "s32" in ins.shape:
                m = re.match(r"(\d+)\)", ins.rest)
                if m:
                    vals.append(int(m.group(1)))
        stack.extend(cal for cal, _ in comps[c].calls)
    return max(vals) if vals else 1


def parse_into(comps, text):
    cur = None
    for raw in text.splitlines():
        line = raw.rstrip()
        if line.endswith("{"):
            hdr = _COMP_HDR.match(line)
            if hdr:
                cur = Computation(hdr.group(1))
                comps[cur.name] = cur
                for pname, pshape in re.findall(
                        r"([\w.\-]+):\s*((?:\([^)]*\))|[\w\[\]{},]+)",
                        hdr.group(2)):
                    cur.symtab[pname] = pshape
                continue
        if cur is None:
            continue
        if line.strip() == "}":
            cur = None
            continue
        m = _INSTR.match(line)
        if not m:
            continue
        name, shape, op, rest = m.groups()
        cur.instrs.append(Instr(name, shape, op, rest))
        cur.symtab[name] = shape
        kind = "fusion" if op == "fusion" else "call"
        for callee in re.findall(r"(?:calls|to_apply)=%?([\w.\-]+)", rest):
            cur.calls.append((callee, kind))
        wb = re.search(r"condition=%?([\w.\-]+),\s*body=%?([\w.\-]+)", rest)
        if wb:
            cur.calls.append((wb.group(1), "cond"))
            cur.calls.append((wb.group(2), "body"))


def _entry_name(comps, text) -> str:
    m = re.search(r"^ENTRY\s+%?([\w.\-]+)", text, re.M)
    if m:
        return m.group(1)
    return next(iter(comps))


def _multipliers(comps, text) -> dict[str, float]:
    """Execution count per computation, propagated through fusions/whiles."""
    mult: dict[str, float] = defaultdict(float)
    entry = _entry_name(comps, text)

    def visit(cname: str, k: float, depth=0):
        if cname not in comps or depth > 64:
            return
        mult[cname] += k
        comp = comps[cname]
        # group while edges: body gets k * trip
        for ins in comp.instrs:
            if ins.op == "while":
                wb = re.search(r"condition=%?([\w.\-]+),\s*body=%?([\w.\-]+)",
                               ins.rest)
                if wb:
                    trip = _trip_count(comps, wb.group(1))
                    visit(wb.group(1), k * (trip + 1), depth + 1)
                    visit(wb.group(2), k * trip, depth + 1)
        for callee, kind in comp.calls:
            if kind in ("call", "fusion"):
                visit(callee, k, depth + 1)

    visit(entry, 1.0)
    return mult


def _dot_flops(comp: Computation, ins: Instr) -> float:
    _, out_dims = _shape_dims(ins.shape)
    out_elems = 1
    for d in out_dims:
        out_elems *= d
    mm = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", ins.rest)
    if not mm:
        return 2.0 * out_elems  # dot with no contraction info
    cdims = [int(x) for x in mm.group(1).split(",") if x]
    ops = _operands(ins)
    contract = 1
    if ops:
        _, ldims = _shape_dims(_operand_shape(comp, *ops[0]))
        for c in cdims:
            if c < len(ldims):
                contract *= ldims[c]
    return 2.0 * out_elems * contract


def _operand_bytes(comp: Computation, ins: Instr) -> list[int]:
    out = []
    for name, inline in _operands(ins):
        shape = _operand_shape(comp, name, inline)
        if shape:
            out.append(_shape_elems_bytes(shape)[1])
    return out


def _fusion_traffic(comps, comp: Computation, ins: Instr) -> float:
    """Traffic of a fusion = output + per-parameter actual reads, with two
    in-place patterns discounted:
      * a parameter consumed ONLY by slicing ops (lax.scan stacked-weight
        reads) moves just the slices, not the whole buffer;
      * a parameter that is ONLY the target of dynamic-update-slice (scan
        carry accumulators — saved activations) aliases in place: traffic is
        the update region, and the fusion's big output buffer likewise."""
    _, ob = _shape_elems_bytes(ins.shape)
    m = re.search(r"calls=%?([\w.\-]+)", ins.rest)
    if not m or m.group(1) not in comps:
        return ob + sum(_operand_bytes(comp, ins))
    callee = comps[m.group(1)]
    defined = {i.name for i in callee.instrs if i.op != "parameter"}
    params = [i.name for i in callee.instrs if i.op == "parameter"]
    params += [p for p in callee.symtab
               if p not in defined and p not in params]
    total = 0.0
    inplace_out = 0.0
    for p in params:
        pb = _shape_elems_bytes(callee.symtab[p])[1]
        uses = [i for i in callee.instrs
                if re.search(r"%" + re.escape(p) + r"\b", i.rest)]

        def first_opnd(u):
            ops = _operands(u)
            return ops[0][0] if ops else None

        if uses and all(u.op in ("dynamic-slice", "slice", "gather") and
                        first_opnd(u) == p for u in uses):
            total += sum(_shape_elems_bytes(u.shape)[1] for u in uses)
        elif uses and all(u.op == "dynamic-update-slice" and
                          first_opnd(u) == p for u in uses):
            # in-place accumulator: charge write of the update region(s)
            for u in uses:
                ops = _operands(u)
                upd = (_shape_elems_bytes(
                    _operand_shape(callee, *ops[1]))[1]
                       if len(ops) > 1 else 0)
                total += 2 * upd
                inplace_out += pb
        else:
            total += pb
    # if every output byte is an in-place-aliased accumulator, don't charge
    # the full output buffer again
    if inplace_out >= ob:
        return total
    return total + ob


def _instr_traffic(comp: Computation, ins: Instr) -> float:
    """HBM bytes moved by one scope-level instruction.

    Slicing/gather ops read only the slice (≈ output bytes), NOT the whole
    source buffer; in-place update ops move ~2x the update. Everything else
    reads its operands once and writes its output (the fusion contract)."""
    _, ob = _shape_elems_bytes(ins.shape)
    op = ins.op
    if op in ("dynamic-slice", "slice", "gather", "broadcast", "iota",
              "concatenate", "reshape", "transpose", "reverse"):
        return 2.0 * ob
    if op in ("dynamic-update-slice", "scatter"):
        opb = _operand_bytes(comp, ins)
        upd = opb[1] if len(opb) > 1 else ob
        return 2.0 * min(upd, ob)
    if op == "pad":
        return 2.0 * ob
    return ob + sum(_operand_bytes(comp, ins))


def _instr_traffic_full(comps, comp: Computation, ins: Instr) -> float:
    if ins.op == "fusion":
        return _fusion_traffic(comps, comp, ins)
    return _instr_traffic(comp, ins)


def xla_cost_analysis(compiled) -> dict:
    """jax-version-tolerant ``compiled.cost_analysis()``: newer jax returns
    the per-device dict directly, jax 0.4.x wraps it in a 1-element list."""
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return dict(ca)


def analyze_hlo(text: str) -> dict:
    comps: dict[str, Computation] = {}
    parse_into(comps, text)
    mult = _multipliers(comps, text)

    # computations reachable through a fusion edge are codegen bodies —
    # their internals don't touch HBM (no separate traffic accounting)
    fused: set = set()
    stack = [c for comp in comps.values()
             for c, kind in comp.calls if kind == "fusion"]
    while stack:
        c = stack.pop()
        if c in fused or c not in comps:
            continue
        fused.add(c)
        stack.extend(cal for cal, _ in comps[c].calls)

    flops = 0.0
    traffic = 0.0
    coll = defaultdict(lambda: {"count": 0.0, "bytes": 0.0})
    for cname, comp in comps.items():
        k = mult.get(cname, 0.0)
        if k == 0.0:
            continue
        for ins in comp.instrs:
            if ins.op == "dot":
                flops += k * _dot_flops(comp, ins)
            if ins.op not in _SKIP_TRAFFIC and cname not in fused:
                traffic += k * _instr_traffic_full(comps, comp, ins)
            for kind in COLLECTIVES:
                if ins.op == kind or (ins.op.startswith(kind) and
                                      not ins.op.endswith("-start")):
                    _, b = _shape_elems_bytes(ins.shape)
                    coll[kind]["count"] += k
                    coll[kind]["bytes"] += k * b
                    break

    total_coll = sum(v["bytes"] for v in coll.values())
    return {
        "flops": flops,
        "traffic_bytes": traffic,
        "collectives": {k: dict(v) for k, v in coll.items()},
        "collective_bytes": total_coll,
        "n_computations": len(comps),
    }

"""Production meshes. Functions, not module constants — importing this module
never touches jax device state."""
from __future__ import annotations

import numpy as np

import jax
from jax.sharding import Mesh


def _make_mesh(shape, axes) -> Mesh:
    """jax.make_mesh across jax versions: axis_types= (and AxisType) only
    exist on newer releases; fall back to the plain call."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        try:
            return jax.make_mesh(shape, axes,
                                 axis_types=(axis_type.Auto,) * len(axes))
        except TypeError:
            pass
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def make_alt_mesh(model: int = 8) -> Mesh:
    """Same 256-chip pod, reshaped so the TP degree divides awkward head
    counts (e.g. granite's 24 heads on model=8) — §Perf-2 mesh-reshape."""
    return _make_mesh((256 // model, model), ("data", "model"))


def make_gfm_paper_mesh(n_tasks: int = 5, dp: int = 100) -> Mesh:
    """The paper's process layout: N=5 head sub-groups x M data-parallel
    ranks (paper: 640 GPUs = 5 x 128 on Frontier; here 5 x 100 of the 512
    placeholder devices)."""
    devs = np.array(jax.devices()[: dp * n_tasks]).reshape(dp, n_tasks)
    return Mesh(devs, ("data", "model"))


def make_host_mesh(data: int, model: int) -> Mesh:
    """Small mesh over however many host devices exist (tests/examples)."""
    return _make_mesh((data, model), ("data", "model"))


def make_group_meshes(placement, *, devices=None) -> list[Mesh]:
    """Per-group sub-meshes for a hierarchical plan: the device pool is
    partitioned contiguously by ``placement.device_counts`` and each slice
    becomes a 1-axis ``("data",)`` mesh — within a group the batch is
    data-parallel and the group's head slice is replicated, so the group IS
    its heads' model shard (the paper's head sub-group).

    devices: explicit device list (length >= placement.n_devices); defaults
    to ``jax.devices()``. Raises if the pool is too small."""
    devs = list(devices) if devices is not None else jax.devices()
    need = placement.n_devices
    assert len(devs) >= need, (
        f"placement needs {need} devices, host has {len(devs)} — solve the "
        f"placement against the real device count")
    meshes, off = [], 0
    for c in placement.device_counts:
        meshes.append(Mesh(np.array(devs[off: off + c]), ("data",)))
        off += c
    return meshes


def make_replica_meshes(n_replicas: int, *, devices_per_replica: int = 1,
                        devices=None) -> list[Mesh]:
    """Serving scale-out meshes: partition the device pool into
    ``n_replicas`` disjoint 1-axis ``("data",)`` sub-meshes of
    ``devices_per_replica`` each. Built on the SAME ``make_group_meshes``
    machinery as training's hierarchical plan — each serving replica is a
    degenerate head group that owns EVERY head (replicated params, rows
    data-parallel within the replica), so ``ServeSession(mesh=...)`` /
    ``ReplicaServeSession`` reuse the training mesh contract unchanged."""
    from repro.core.taskpar import HeadPlacement
    assert n_replicas >= 1 and devices_per_replica >= 1
    placement = HeadPlacement(
        groups=tuple((g,) for g in range(n_replicas)),
        device_counts=(devices_per_replica,) * n_replicas)
    return make_group_meshes(placement, devices=devices)

"""Runnable trainer CLI — a thin argparse front-end over ``repro.engine``.

  # the paper's GFM, MTP x DDP over the host devices:
  PYTHONPATH=src python -m repro.launch.train --mode gfm --steps 200

  # any assigned LM arch at smoke scale:
  PYTHONPATH=src python -m repro.launch.train --mode lm --arch qwen1.5-0.5b --steps 50

  # multi-task LM (the paper's technique on an LLM trunk):
  PYTHONPATH=src python -m repro.launch.train --mode lm-mtl --arch qwen1.5-0.5b
"""
from __future__ import annotations

import argparse

from repro import configs
from repro.data.lm_data import make_lm_sources
from repro.data.synthetic_atoms import generate_all
from repro.engine import Session, SessionConfig


def session_for(args) -> Session:
    if args.mode == "gfm":
        cfg = configs.get_smoke("hydragnn-gfm") if args.smoke else \
            configs.get("hydragnn-gfm").replace(gnn_hidden=128, head_hidden=64)
        data = list(generate_all(args.samples, max_atoms=cfg.max_atoms,
                                 max_edges=cfg.max_edges).items())[:cfg.n_tasks]
        sources = [dict(species=s.species, pos=s.pos, edge_src=s.edge_src,
                        edge_dst=s.edge_dst, node_mask=s.node_mask,
                        edge_mask=s.edge_mask, energy=s.energy,
                        forces=s.forces) for _, s in data]
        # paper: AdamW, lr 1e-3, warmup-cosine, early stopping
        scfg = SessionConfig(model="gfm-mtl", arch=cfg, steps=args.steps,
                             batch_per_task=args.batch, lr=args.lr,
                             warmup=20, accum=args.accum, seed=args.seed,
                             log_every=args.log_every,
                             eval_every=args.log_every, patience=20,
                             ckpt_path=args.ckpt)
        return Session.from_config(scfg, sources=sources,
                                   task_names=[k for k, _ in data])

    cfg = configs.get_smoke(args.arch)
    if args.mode == "lm-mtl":
        cfg = cfg.replace(n_tasks=args.tasks)
        sources = make_lm_sources(cfg.n_tasks, 64, args.seq, cfg.vocab)
        scfg = SessionConfig(model="lm-mtl", arch=cfg, steps=args.steps,
                             batch_per_task=args.batch, lr=args.lr,
                             accum=args.accum, seed=args.seed,
                             log_every=args.log_every, ckpt_path=args.ckpt)
        return Session.from_config(scfg, sources=sources)

    source = make_lm_sources(1, 256, args.seq, cfg.vocab)[0]
    scfg = SessionConfig(model="lm", arch=cfg, steps=args.steps,
                         batch_per_task=args.batch, lr=args.lr,
                         accum=args.accum, seed=args.seed,
                         log_every=args.log_every, ckpt_path=args.ckpt)
    return Session.from_config(scfg, sources=source)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", default="gfm", choices=["gfm", "lm", "lm-mtl"])
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    ap.add_argument("--tasks", type=int, default=4)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--samples", type=int, default=256)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--ckpt", default=None)
    args = ap.parse_args()
    with session_for(args) as session:
        result = session.run()
    return result.final_loss


if __name__ == "__main__":
    main()

"""Runnable trainer CLI (CPU-scale; the full-scale path is the dry-run).

  # the paper's GFM, MTP x DDP over the host devices:
  PYTHONPATH=src python -m repro.launch.train --mode gfm --steps 200

  # any assigned LM arch at smoke scale:
  PYTHONPATH=src python -m repro.launch.train --mode lm --arch qwen1.5-0.5b --steps 50

  # multi-task LM (the paper's technique on an LLM trunk):
  PYTHONPATH=src python -m repro.launch.train --mode lm-mtl --arch qwen1.5-0.5b
"""
from __future__ import annotations

import argparse
import json

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.core import MTPConfig, make_gfm_mtl, make_lm_multitask, \
    make_mtp_train_step
from repro.data.lm_data import make_lm_sources
from repro.data.loader import GroupBatcher
from repro.data.synthetic_atoms import generate_all
from repro.optim import adamw, warmup_cosine
from repro.train import checkpoint
from repro.train.loop import EarlyStopping, MetricLogger, make_lm_train_step


def run_gfm(args):
    cfg = configs.get_smoke("hydragnn-gfm") if args.smoke else \
        configs.get("hydragnn-gfm").replace(gnn_hidden=128, head_hidden=64)
    n_tasks = cfg.n_tasks
    sources = list(generate_all(args.samples, max_atoms=cfg.max_atoms,
                                max_edges=cfg.max_edges).values())[:n_tasks]
    srcs = [dict(species=s.species, pos=s.pos, edge_src=s.edge_src,
                 edge_dst=s.edge_dst, node_mask=s.node_mask,
                 edge_mask=s.edge_mask, energy=s.energy, forces=s.forces)
            for s in sources]
    model = make_gfm_mtl(cfg, n_tasks)
    params = model.init(jax.random.PRNGKey(args.seed))
    # paper: AdamW, lr 1e-3, local batch 128
    opt = adamw(warmup_cosine(args.lr, 20, args.steps))
    st = opt.init(params)
    step = make_mtp_train_step(model, opt, MTPConfig(n_tasks=n_tasks))
    gb = GroupBatcher(srcs, args.batch, seed=args.seed)
    log, es = MetricLogger(), EarlyStopping(patience=20)
    for i in range(args.steps):
        params, st, loss, m = step(params, st, gb.next_batch())
        if i % args.log_every == 0 or i == args.steps - 1:
            row = log.log(i, loss=loss,
                          **{f"task{t}": m["per_task_loss"][t]
                             for t in range(n_tasks)})
            print(json.dumps(row))
            if es.update(float(loss)):
                print("# early stop")
                break
    if args.ckpt:
        checkpoint.save(args.ckpt, {"params": params},
                        metadata={"arch": "hydragnn-gfm", "step": i})
    return float(loss)


def run_lm(args, multitask=False):
    cfg = configs.get_smoke(args.arch)
    if multitask:
        cfg = cfg.replace(n_tasks=4)
        model = make_lm_multitask(cfg)
        sources = make_lm_sources(cfg.n_tasks, 64, args.seq, cfg.vocab)
        gb = GroupBatcher(sources, args.batch)
        params = model.init(jax.random.PRNGKey(args.seed))
        opt = adamw(args.lr)
        st = opt.init(params)
        step = make_mtp_train_step(model, opt, MTPConfig(n_tasks=cfg.n_tasks))
        log = MetricLogger()
        for i in range(args.steps):
            params, st, loss, _ = step(params, st, gb.next_batch())
            if i % args.log_every == 0:
                print(json.dumps(log.log(i, loss=loss)))
        return float(loss)

    from repro.models import transformer
    src = make_lm_sources(1, 256, args.seq, cfg.vocab)[0]
    params = transformer.lm_init(jax.random.PRNGKey(args.seed), cfg)
    opt = adamw(args.lr)
    st = opt.init(params)
    step = jax.jit(make_lm_train_step(cfg, opt))
    rng = np.random.default_rng(args.seed)
    log = MetricLogger()
    for i in range(args.steps):
        idx = rng.integers(0, src["tokens"].shape[0], args.batch)
        batch = {"tokens": jnp.asarray(src["tokens"][idx]),
                 "labels": jnp.asarray(src["labels"][idx])}
        params, st, loss = step(params, st, batch)
        if i % args.log_every == 0:
            print(json.dumps(log.log(i, loss=loss)))
    if args.ckpt:
        checkpoint.save(args.ckpt, {"params": params},
                        metadata={"arch": args.arch, "step": i})
    return float(loss)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", default="gfm", choices=["gfm", "lm", "lm-mtl"])
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--samples", type=int, default=256)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--ckpt", default=None)
    args = ap.parse_args()
    if args.mode == "gfm":
        run_gfm(args)
    else:
        run_lm(args, multitask=(args.mode == "lm-mtl"))


if __name__ == "__main__":
    main()

import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x input-shape x mesh).

Proves the distribution config is coherent without hardware: builds the
production mesh from 512 placeholder host devices, lowers the real step
function against ShapeDtypeStruct stand-ins (zero allocation — params and
optimizer state come from jax.eval_shape), compiles, and records
memory_analysis / cost_analysis / collective schedule for §Dry-run and
§Roofline.

  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen1.5-0.5b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both --out results/dryrun
"""
import argparse
import json
import math
import time
import traceback
from functools import partial

import jax
import jax.numpy as jnp

from repro import configs
from repro.configs import SHAPES
from repro.configs.sharding import make_spec_fn, tree_shardings
from repro.configs.specs import cache_specs, data_axes, input_specs
from repro.engine import ShardingPlan, build_model, make_step
from repro.launch.hlo_analysis import analyze_hlo, xla_cost_analysis
from repro.launch.hlo_stats import collective_stats, op_histogram
from repro.launch.mesh import make_gfm_paper_mesh, make_production_mesh
from repro.optim import adamw
from repro.train.serve import make_decode_step


def _sds_with_shardings(shapes_tree, shardings_tree):
    return jax.tree_util.tree_map(
        lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
        shapes_tree, shardings_tree)


def skip_reason(arch: str, shape_name: str) -> str | None:
    cfg = configs.get(arch)
    shape = SHAPES[shape_name]
    if cfg.family == "gnn" and shape.kind != "train":
        return "gnn: no LM serving shapes (paper arch trains only)"
    if shape.kind == "decode" and not cfg.supports_decode:
        return "no decode step for this arch"
    if shape_name == "long_500k":
        if arch == "seamless-m4t-medium":
            return "enc-dec speech model: 500k-token decode out of family scope (DESIGN.md)"
        if not cfg.long_context_ok and not cfg.swa_variant_window:
            return "pure full attention, no SWA variant configured"
    return None


def params_and_opt_specs(cfg, mesh, init_fn, moment_dtype=jnp.float32):
    """eval_shape the init + optimizer and attach rule-based shardings."""
    key_spec = jax.ShapeDtypeStruct((2,), jnp.uint32)
    p_shapes = jax.eval_shape(init_fn, key_spec)
    spec_fn = make_spec_fn(cfg, mesh)
    p_shard = tree_shardings(mesh, p_shapes, spec_fn)
    p_sds = _sds_with_shardings(p_shapes, p_shard)
    opt = adamw(1e-3, weight_decay=0.01, grad_clip=1.0,
                moment_dtype=moment_dtype)
    o_shapes = jax.eval_shape(opt.init, p_shapes)
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.optim import AdamWState
    o_shard = AdamWState(step=NamedSharding(mesh, P()), m=p_shard, v=p_shard)
    o_sds = _sds_with_shardings(o_shapes, o_shard)
    return p_sds, o_sds, opt


def build_lowered(arch: str, shape_name: str, mesh, impl="chunked",
                  accum: int = 1, cfg_override=None):
    """Returns (lowered, meta). Raises on structural failure."""
    cfg = cfg_override or configs.get(arch)
    shape = SHAPES[shape_name]

    if cfg.family == "gnn":
        if mesh is None:       # hierarchical: per-group sub-meshes, no mesh
            return _build_gfm_hier_lowered(cfg)
        return _build_gfm_lowered(cfg, mesh)

    if shape.kind == "train":
        model = build_model("lm", cfg, impl=impl)
        opt = adamw(1e-3, weight_decay=0.01, grad_clip=1.0,
                    moment_dtype=cfg.moment_dtype)
        plan = ShardingPlan(mesh=mesh, spec_fn=make_spec_fn(cfg, mesh))
        batch = input_specs(cfg, shape, mesh)
        if accum == 1:
            accum = cfg.train_accum
        step = make_step(model, opt, plan, accum=accum)
        lowered = plan.compile(step).lower(
            plan.state_template(model.init, opt), batch)
        return lowered, {"kind": "train", "accum": accum}

    if shape.kind == "prefill":
        from repro.models import transformer
        from repro.models.transformer import lm_init
        p_sds, _, _ = params_and_opt_specs(cfg, mesh, lambda k: lm_init(k, cfg))
        batch = input_specs(cfg, shape, mesh)

        def prefill(params, batch):
            memory = None
            if cfg.n_enc_layers:
                memory = transformer.encode(params, batch["src_embed"], cfg, impl)
            logits, caches, _ = transformer.lm_apply(
                params, batch["tokens"], cfg=cfg, media=batch.get("media"),
                memory=memory, mode="prefill", impl=impl)
            return logits[:, -1:], caches

        lowered = jax.jit(prefill).lower(p_sds, batch)
        return lowered, {"kind": "prefill"}

    # decode
    caches_sds, eff_cfg = cache_specs(cfg, shape, mesh)
    from repro.models.transformer import lm_init
    p_sds, _, _ = params_and_opt_specs(eff_cfg, mesh,
                                       lambda k: lm_init(k, eff_cfg))
    io = input_specs(eff_cfg, shape, mesh)
    dec = make_decode_step(eff_cfg, impl=impl)
    mem_sds = None
    if cfg.n_enc_layers:
        from jax.sharding import NamedSharding, PartitionSpec as P
        mem_sds = jax.ShapeDtypeStruct(
            (shape.global_batch, cfg.enc_memory_len, cfg.d_model),
            cfg.compute_dtype, sharding=NamedSharding(mesh, P()))

    def decode(params, token, caches, pos, memory=None):
        return dec(params, token, caches, pos, memory=memory)

    lowered = jax.jit(decode).lower(p_sds, io["token"], caches_sds, io["pos"],
                                    mem_sds)
    return lowered, {"kind": "decode",
                     "swa_variant": eff_cfg is not cfg and bool(cfg.swa_variant_window)}


def _gfm_batch_shapes(cfg, n_req: int = 1):
    """ShapeDtypeStruct task-major batch for the paper's model. The
    per-task batch must divide ``n_req`` (product of the axes its dim is
    sharded over); paper local batch is 128 per process."""
    B = 128 if 128 % n_req == 0 else n_req
    T, A, E = cfg.n_tasks, cfg.max_atoms, cfg.max_edges
    return {
        "species": jax.ShapeDtypeStruct((T, B, A), jnp.int32),
        "pos": jax.ShapeDtypeStruct((T, B, A, 3), jnp.float32),
        "edge_src": jax.ShapeDtypeStruct((T, B, E), jnp.int32),
        "edge_dst": jax.ShapeDtypeStruct((T, B, E), jnp.int32),
        "node_mask": jax.ShapeDtypeStruct((T, B, A), jnp.bool_),
        "edge_mask": jax.ShapeDtypeStruct((T, B, E), jnp.bool_),
        "energy": jax.ShapeDtypeStruct((T, B), jnp.float32),
        "forces": jax.ShapeDtypeStruct((T, B, A, 3), jnp.float32),
    }


def _build_gfm_lowered(cfg, mesh):
    """The paper's model: MTP x DDP train step on the task mesh."""
    from repro.core import MTPConfig, make_gfm_mtl
    model = make_gfm_mtl(cfg, cfg.n_tasks)
    # task-sharded heads need a "model" axis that n_tasks divides; meshes
    # without one (1-axis data meshes) and ragged task counts run the
    # paper's MTL-base mode (heads replicated, pure DDP) instead
    m_ax = dict(mesh.shape).get("model", 0)
    mode = "par" if m_ax and m_ax % cfg.n_tasks == 0 else "base"
    mtp = MTPConfig(n_tasks=cfg.n_tasks, mode=mode,
                    data_axes=data_axes(mesh))
    opt = adamw(1e-3)
    plan = ShardingPlan(mesh=mesh, mtp=mtp)
    state_sds = plan.state_template(model.init, opt)

    # paper: local batch 128 per process; the per-task global batch must
    # divide the axes its dim is sharded over ("data" in par mode, all axes
    # in base mode; the paper mesh has data=100)
    n_req = 1
    shard_axes = data_axes(mesh) if mode == "par" else \
        data_axes(mesh) + ("model",)
    for a in shard_axes:
        n_req *= dict(mesh.shape).get(a, 1)
    batch_shapes = _gfm_batch_shapes(cfg, n_req)
    b_sds = _sds_with_shardings(batch_shapes,
                                plan.data_batch_shardings(batch_shapes))

    step = make_step(model, opt, plan)
    lowered = plan.compile(step).lower(state_sds, b_sds)
    return lowered, {"kind": "gfm-train", "n_tasks": cfg.n_tasks,
                     "mtp_mode": mode}


def _build_gfm_hier_lowered(cfg, n_devices: int | None = None):
    """Hierarchical MTP dry-run: solve the imbalance-aware placement over
    the host device pool at the paper's source mix, lower every group, and
    report the per-group HBM model (hlo_stats.hier_group_memory). The
    returned lowering is the BOTTLENECK group's per-device program — its
    memory/cost numbers are the step's critical path."""
    from repro.core import make_gfm_mtl, solve_placement
    from repro.data.synthetic_atoms import PAPER_REL_SIZES
    from repro.launch.hlo_stats import hier_group_memory

    model = make_gfm_mtl(cfg, cfg.n_tasks)
    mix = list(PAPER_REL_SIZES.values())
    loads = [mix[t % len(mix)] for t in range(cfg.n_tasks)]
    n_dev = n_devices if n_devices is not None else len(jax.devices())
    placement = solve_placement(n_dev, loads)
    opt = adamw(1e-3)
    plan = ShardingPlan(placement=placement)
    state_sds = plan.state_template(model.init, opt)
    batch_shapes = _gfm_batch_shapes(cfg)

    compiled = plan.compile(make_step(model, opt, plan))
    lowers = compiled.lower_groups(state_sds, batch_shapes)

    # the §4.3 residency model: trunk replicated per group, head slices
    # resident only in their group
    def nbytes(tree):
        return sum(int(jnp.dtype(l.dtype).itemsize) * math.prod(l.shape)
                   for l in jax.tree_util.tree_leaves(tree))

    shared_bytes = nbytes(state_sds.params["shared"])
    head_bytes = nbytes(state_sds.params["heads"]) // cfg.n_tasks
    group_memory = hier_group_memory(placement, shared_bytes, head_bytes)

    gl = placement.group_loads()
    hot = gl.index(max(gl))
    meta = {"kind": "gfm-hier-train", "n_tasks": cfg.n_tasks,
            "placement": {"groups": [list(g) for g in placement.groups],
                          "device_counts": list(placement.device_counts),
                          "loads": list(placement.loads or ())},
            "group_memory": group_memory, "bottleneck_group": hot}
    return lowers[hot][1], meta


def analyze(lowered, compile_too=True) -> dict:
    res = {}
    t0 = time.perf_counter()
    res["lower_s"] = None
    hlo = None
    if compile_too:
        compiled = lowered.compile()
        res["compile_s"] = round(time.perf_counter() - t0, 2)
        try:
            ma = compiled.memory_analysis()
            res["memory"] = {
                k: int(getattr(ma, k))
                for k in ("argument_size_in_bytes", "output_size_in_bytes",
                          "temp_size_in_bytes", "generated_code_size_in_bytes")
                if hasattr(ma, k)}
        except Exception as e:  # pragma: no cover
            res["memory"] = {"error": str(e)}
        try:
            ca = xla_cost_analysis(compiled)
            res["cost"] = {k: float(v) for k, v in ca.items()
                           if k in ("flops", "bytes accessed", "transcendentals",
                                    "utilization operand 0 {}")
                           or k.startswith("bytes accessed")}
        except Exception as e:  # pragma: no cover
            res["cost"] = {"error": str(e)}
        hlo = compiled.as_text()
        # loop-aware per-device stats (XLA cost_analysis counts while bodies
        # once); only meaningful on compiled HLO — lowered.as_text() is
        # StableHLO, which the analyzer cannot parse
        res["hlo"] = analyze_hlo(hlo)
        res["collectives_once"] = collective_stats(hlo)
        res["top_ops"] = op_histogram(hlo, 12)
    else:
        res["hlo"] = {"skipped": "no-compile: StableHLO only"}
    return res


def run_one(arch: str, shape_name: str, mesh_kind: str, *, impl="chunked",
            accum: int = 1, compile_too=True, cfg_override=None,
            baseline=False) -> dict:
    entry = {"arch": arch, "shape": shape_name, "mesh": mesh_kind}
    if baseline and cfg_override is None and arch in configs.ARCHS:
        cfg_override = configs.get(arch).replace(mlstm_chunked=False,
                                                 naive_tp=True)
    reason = skip_reason(arch, shape_name)
    if reason:
        entry["status"] = "skip"
        entry["reason"] = reason
        return entry
    if mesh_kind == "paper":
        mesh = make_gfm_paper_mesh()
    elif mesh_kind == "hier":
        # hierarchical plan: no global mesh — per-group sub-meshes are
        # solved from the device pool (gnn family only)
        if configs.get(arch).family != "gnn":
            entry["status"] = "skip"
            entry["reason"] = "hier placement shards per-task heads (gnn only)"
            return entry
        mesh = None
    elif mesh_kind.startswith("pod32x8"):
        from repro.launch.mesh import make_alt_mesh
        mesh = make_alt_mesh(8)
    else:
        mesh = make_production_mesh(multi_pod=(mesh_kind == "multipod"))
    t0 = time.perf_counter()
    try:
        lowered, meta = build_lowered(arch, shape_name, mesh, impl=impl,
                                      accum=accum, cfg_override=cfg_override)
        entry.update(meta)
        entry.update(analyze(lowered, compile_too=compile_too))
        entry["status"] = "ok"
    except Exception as e:
        entry["status"] = "fail"
        entry["error"] = f"{type(e).__name__}: {e}"
        entry["trace"] = traceback.format_exc()[-2000:]
    entry["total_s"] = round(time.perf_counter() - t0, 2)
    return entry


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="pod",
                    choices=["pod", "multipod", "both", "paper", "pod32x8",
                             "hier"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--baseline", action="store_true",
                    help="pre-perf-iteration system (naive TP, scan mLSTM)")
    ap.add_argument("--no-compile", action="store_true")
    ap.add_argument("--out", default=None, help="JSON output path (appends)")
    args = ap.parse_args()

    archs = list(configs.ASSIGNED) if args.all or not args.arch else [args.arch]
    shapes = list(SHAPES) if args.all or not args.shape else [args.shape]
    meshes = ["pod", "multipod"] if args.mesh == "both" else [args.mesh]

    results = []
    if args.out and os.path.exists(args.out):
        with open(args.out) as f:
            results = json.load(f)
    done = {(r["arch"], r["shape"], r["mesh"]) for r in results
            if r.get("status") == "ok"}

    for arch in archs:
        for shape in shapes:
            for mk in meshes:
                if (arch, shape, mk) in done:
                    continue
                r = run_one(arch, shape, mk, accum=args.accum,
                            compile_too=not args.no_compile,
                            baseline=args.baseline)
                print(json.dumps({k: v for k, v in r.items()
                                  if k not in ("trace", "top_ops")}))
                results.append(r)
                if args.out:
                    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
                    with open(args.out, "w") as f:
                        json.dump(results, f, indent=1)

    n_ok = sum(1 for r in results if r["status"] == "ok")
    n_fail = sum(1 for r in results if r["status"] == "fail")
    n_skip = sum(1 for r in results if r["status"] == "skip")
    print(f"# dryrun done: ok={n_ok} fail={n_fail} skip={n_skip}")
    if n_fail:
        raise SystemExit(1)


if __name__ == "__main__":
    main()

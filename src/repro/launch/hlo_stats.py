"""Parse lowered/compiled HLO text for collective statistics.

cost_analysis() has no collective_bytes, so we sum the output-shape bytes of
every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute in the (post-SPMD, per-device) module text.
"""
from __future__ import annotations

import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
}
_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    """'bf16[8,128]{1,0}' or '(f32[2], f32[4])' -> total bytes."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_stats(hlo_text: str) -> dict:
    """-> {op_kind: {"count": int, "bytes": int}} + {"total_bytes": int}.
    Bytes are OUTPUT bytes of each collective in the per-device program."""
    out: dict = defaultdict(lambda: {"count": 0, "bytes": 0})
    for line in hlo_text.splitlines():
        ls = line.strip()
        m = re.match(r"(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(\([^)]*\)|[\w\[\]{},]+)\s+([\w-]+)",
                     ls)
        if not m:
            continue
        shape_str, op = m.group(1), m.group(2)
        for kind in _COLLECTIVES:
            if op == kind or op.startswith(kind + "-"):
                if op.endswith("-start"):   # avoid double count with -done
                    continue
                out[kind]["count"] += 1
                out[kind]["bytes"] += _shape_bytes(shape_str)
                break
    total = sum(v["bytes"] for v in out.values())
    res = dict(out)
    res["total_bytes"] = total
    return res


def param_bytes_per_device(tree) -> int:
    """Per-device resident bytes of a sharded template/array pytree: each
    leaf's byte size divided by the product of the mesh-axis sizes its
    PartitionSpec actually uses. Mesh-rank agnostic — flat ``(data,
    model)``, multi-pod 3-axis, and hierarchical 1-axis group meshes all
    work (the old estimate hard-coded the two flat axis names). Leaves
    without a sharding count as replicated."""
    import jax
    total = 0
    for leaf in jax.tree_util.tree_leaves(tree):
        n = 1
        for d in leaf.shape:
            n *= int(d)
        size = n * leaf.dtype.itemsize
        sharding = getattr(leaf, "sharding", None)
        spec = getattr(sharding, "spec", None)
        mesh = getattr(sharding, "mesh", None)
        denom = 1
        if spec is not None and mesh is not None:
            axsize = dict(mesh.shape)
            for entry in spec:
                if entry is None:
                    continue
                for ax in (entry if isinstance(entry, tuple) else (entry,)):
                    denom *= axsize.get(ax, 1)
        total += -(-size // denom)        # ceil: XLA pads ragged tiles
    return total


def hier_group_memory(placement, shared_bytes: int, head_bytes,
                      *, opt_factor: float = 3.0) -> list[dict]:
    """Modeled per-device HBM of each group in a hierarchical placement:
    the trunk is replicated into every group while a head's params live
    ONLY in its group — the paper's §4.3 ``P_s + Σ_{t∈g} P_h`` residency
    (one head per group reproduces ``P_s + P_h`` exactly).

    head_bytes: one int (uniform heads) or a per-head byte sequence.
    opt_factor: bytes per resident param byte across train state (3.0 =
    fp32 params + AdamW m/v moments). Returns one dict per group with the
    modeled ``param_bytes`` / ``hbm_bytes`` and the group's shape."""
    n_heads = placement.n_heads
    hb = [int(head_bytes)] * n_heads if isinstance(head_bytes, (int, float)) \
        else [int(b) for b in head_bytes]
    assert len(hb) == n_heads, f"{len(hb)} head_bytes for {n_heads} heads"
    out = []
    for g, (heads, n_dev) in enumerate(zip(placement.groups,
                                           placement.device_counts)):
        pb = int(shared_bytes) + sum(hb[t] for t in heads)
        out.append({"group": g, "heads": list(heads), "devices": int(n_dev),
                    "param_bytes": pb,
                    "hbm_bytes": int(round(opt_factor * pb))})
    return out


def op_histogram(hlo_text: str, top: int = 15) -> list[tuple[str, int]]:
    counts: dict = defaultdict(int)
    for line in hlo_text.splitlines():
        m = re.match(r"\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(?:\([^)]*\)|[\w\[\]{},]+)\s+([\w-]+)",
                     line)
        if m:
            counts[m.group(1)] += 1
    return sorted(counts.items(), key=lambda kv: -kv[1])[:top]

"""Parse lowered/compiled HLO text for collective statistics.

cost_analysis() has no collective_bytes, so we sum the output-shape bytes of
every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute in the (post-SPMD, per-device) module text.
"""
from __future__ import annotations

import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
}
_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    """'bf16[8,128]{1,0}' or '(f32[2], f32[4])' -> total bytes."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_stats(hlo_text: str) -> dict:
    """-> {op_kind: {"count": int, "bytes": int}} + {"total_bytes": int}.
    Bytes are OUTPUT bytes of each collective in the per-device program."""
    out: dict = defaultdict(lambda: {"count": 0, "bytes": 0})
    for line in hlo_text.splitlines():
        ls = line.strip()
        m = re.match(r"(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(\([^)]*\)|[\w\[\]{},]+)\s+([\w-]+)",
                     ls)
        if not m:
            continue
        shape_str, op = m.group(1), m.group(2)
        for kind in _COLLECTIVES:
            if op == kind or op.startswith(kind + "-"):
                if op.endswith("-start"):   # avoid double count with -done
                    continue
                out[kind]["count"] += 1
                out[kind]["bytes"] += _shape_bytes(shape_str)
                break
    total = sum(v["bytes"] for v in out.values())
    res = dict(out)
    res["total_bytes"] = total
    return res


def op_histogram(hlo_text: str, top: int = 15) -> list[tuple[str, int]]:
    counts: dict = defaultdict(int)
    for line in hlo_text.splitlines():
        m = re.match(r"\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(?:\([^)]*\)|[\w\[\]{},]+)\s+([\w-]+)",
                     line)
        if m:
            counts[m.group(1)] += 1
    return sorted(counts.items(), key=lambda kv: -kv[1])[:top]

"""Unified train-step construction.

The three divergent step factories of the old API (``make_lm_train_step``,
the ``make_mtp_train_step`` pjit path, ``mtp_value_and_grad_shardmap``) are
unified behind one pipeline:

    grad_fn = make_grad_fn(model, plan)          # backend-aware
    grad_fn = with_grad_accum(grad_fn, accum)    # works for ALL steps
    step    = make_train_step(grad_fn, optimizer)
    compiled = plan.compile(step)                # jit / pjit / shard_map

``make_step`` composes the pipeline in one call. A ``grad_fn`` has the
signature ``grad_fn(params, batch) -> (loss, metrics, grads)``; a step has
``step(state, batch) -> (state, StepOutput)``.

Models come in two flavours:

  * ``MultiTaskModel`` (repro.core.taskpar): params ``{"shared", "heads"}``,
    ``loss_fn(shared, heads, batch) -> (per_task_loss, metrics)`` over a
    task-major batch — the paper's technique;
  * ``SingleTaskModel``: flat params, scalar ``loss_fn(params, batch)`` —
    the standard LM path.
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.core.taskpar import MultiTaskModel, mtp_value_and_grad_shardmap
from .state import StepOutput, TrainState

# step(state, batch) -> (state, StepOutput)
TrainStep = Callable[[TrainState, Any], tuple[TrainState, StepOutput]]


class SingleTaskModel(NamedTuple):
    """init(key) -> params; loss_fn(params, batch) -> scalar loss."""
    init: Callable
    loss_fn: Callable
    name: str = "single"


class HierStepSpec(NamedTuple):
    """The ``make_step`` product for hierarchical plans (backend="hier"):
    not a callable — per-group executables depend on the plan's
    ``HeadPlacement``, so step construction is deferred to
    ``plan.compile()``, which builds a ``repro.engine.hier.HierCompiledStep``
    from this spec. Carries exactly the ingredients the flat pipeline would
    have consumed."""
    model: Any
    optimizer: Any
    accum: int = 1
    task_weights: Any = None


def normalized_task_weights(n_tasks: int, task_weights=None) -> jnp.ndarray:
    tw = jnp.ones((n_tasks,), jnp.float32) if task_weights is None else \
        jnp.asarray(task_weights, jnp.float32)
    return tw / tw.sum()


# ---------------------------------------------------------------------------
# grad_fn builders
# ---------------------------------------------------------------------------

def single_grad_fn(model: SingleTaskModel) -> Callable:
    def grad_fn(params, batch):
        l, grads = jax.value_and_grad(model.loss_fn)(params, batch)
        return l, {}, grads
    return grad_fn


def multitask_grad_fn(model: MultiTaskModel, n_tasks: int,
                      task_weights=None) -> Callable:
    tw = normalized_task_weights(n_tasks, task_weights)

    def grad_fn(params, batch):
        def loss(p):
            per_task, metrics = model.loss_fn(p["shared"], p["heads"], batch)
            # zero-weight (quarantined) tasks are excluded by select, not by
            # multiplication: 0 * non-finite is still non-finite, so a
            # quarantined source's NaN loss would otherwise poison the total
            return jnp.sum(jnp.where(tw > 0, per_task * tw, 0.0)), \
                (per_task, metrics)

        (l, (per_task, metrics)), grads = \
            jax.value_and_grad(loss, has_aux=True)(params)
        return l, dict(metrics, per_task_loss=per_task), grads

    return grad_fn


def shardmap_grad_fn(model: MultiTaskModel, mesh, mtp) -> Callable:
    """Explicit two-scope collective backend (paper-verbatim psum scopes).
    Same StepOutput contract as the pjit path: metrics carry per_task_loss."""
    vg = mtp_value_and_grad_shardmap(model, mesh, mtp)

    def grad_fn(params, batch):
        l, per_task, grads = vg(params, batch)
        return l, {"per_task_loss": per_task}, grads

    return grad_fn


def make_grad_fn(model, plan=None, *, task_weights=None) -> Callable:
    """Backend-aware grad_fn for either model flavour.

    plan: a ShardingPlan (or None for single-device). The shard_map backend
    requires uniform task weights (its sub-group psum carries an implicit
    1/n_tasks factor)."""
    from .plan import ShardingPlan
    plan = plan or ShardingPlan()
    if isinstance(model, MultiTaskModel):
        assert plan.mtp is not None, "multi-task model needs plan.mtp"
        if plan.resolved_backend == "shard_map":
            assert task_weights is None, \
                "shard_map backend supports uniform task weights only"
            return shardmap_grad_fn(model, plan.mesh, plan.mtp)
        return multitask_grad_fn(model, plan.mtp.n_tasks, task_weights)
    return single_grad_fn(model)


# ---------------------------------------------------------------------------
# gradient accumulation — one wrapper for every step
# ---------------------------------------------------------------------------

def with_grad_accum(grad_fn: Callable, accum: int, axis: int = 0) -> Callable:
    """Microbatch any grad_fn: splits the batch into ``accum`` slices along
    ``axis`` (0 for flat batches, 1 for task-major ``(T, B, ...)`` batches)
    and averages losses/metrics/grads over the slices with ``lax.scan``."""
    if accum <= 1:
        return grad_fn

    def split(x):
        if x.ndim <= axis:
            # leaf has no batch dim to slice (e.g. stacked per-task weights
            # (n_tasks,) in a task-major batch): same value every microbatch
            return jnp.broadcast_to(x[None], (accum,) + x.shape)
        b = x.shape[axis]
        assert b % accum == 0, f"batch dim {b} not divisible by accum={accum}"
        shape = x.shape[:axis] + (accum, b // accum) + x.shape[axis + 1:]
        return jnp.moveaxis(x.reshape(shape), axis, 0)

    def accum_fn(params, batch):
        micro_batches = jax.tree_util.tree_map(split, batch)

        def micro(carry, mb):
            acc_l, acc_g = carry
            l, metrics, g = grad_fn(params, mb)
            carry = (acc_l + l, jax.tree_util.tree_map(jnp.add, acc_g, g))
            return carry, metrics

        zeros = jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (l, grads), metrics = jax.lax.scan(
            micro, (jnp.zeros(()), zeros), micro_batches)
        metrics = jax.tree_util.tree_map(lambda m: jnp.mean(m, axis=0), metrics)
        grads = jax.tree_util.tree_map(lambda g: g / accum, grads)
        return l / accum, metrics, grads

    return accum_fn


# ---------------------------------------------------------------------------
# step assembly
# ---------------------------------------------------------------------------

def make_train_step(grad_fn: Callable, optimizer) -> TrainStep:
    """Wrap a grad_fn + optimizer into the unified TrainStep signature."""
    def step(state: TrainState, batch):
        loss, metrics, grads = grad_fn(state.params, batch)
        new_params, new_opt = optimizer.update(grads, state.opt_state,
                                               state.params)
        new_state = TrainState(params=new_params, opt_state=new_opt,
                               step=state.step + 1, rng=state.rng)
        return new_state, StepOutput(loss=loss, metrics=metrics)
    return step


def make_step(model, optimizer, plan=None, *, accum: int = 1,
              task_weights=None) -> TrainStep:
    """One call from model + optimizer (+ plan) to an uncompiled TrainStep.
    Compile it with ``plan.compile(step)``. Hierarchical plans (a
    ``HeadPlacement`` instead of a mesh) get a ``HierStepSpec`` — same
    ``plan.compile()`` call, per-group executables built there."""
    if plan is not None and plan.resolved_backend == "hier":
        assert isinstance(model, MultiTaskModel), \
            "backend='hier' shards per-task heads — needs a MultiTaskModel"
        return HierStepSpec(model=model, optimizer=optimizer, accum=accum,
                            task_weights=task_weights)
    grad_fn = make_grad_fn(model, plan, task_weights=task_weights)
    axis = 1 if isinstance(model, MultiTaskModel) else 0
    grad_fn = with_grad_accum(grad_fn, accum, axis=axis)
    return make_train_step(grad_fn, optimizer)

"""Hierarchical multi-task parallelism — data-parallel replicas x per-head
model shards (the paper's §4.3–4.4 process sub-groups, generalised to
UNEVEN head-to-device assignment per the Exascale follow-up).

A ``HeadPlacement`` (repro.core.taskpar) partitions the device pool into
per-group 1-axis ``("data",)`` sub-meshes (launch/mesh.make_group_meshes):
group g holds the trunk plus ONLY its heads' parameter slices, its batch
slice is data-parallel over the group's devices, and groups run
concurrently. The two collective scopes fall out structurally — head grads
all-reduce inside the group's sub-mesh (XLA SPMD over the group mesh) and
trunk partial-grads are summed ACROSS groups by the combine step, exactly
the paper's "local DDP for heads, global all-reduce for the trunk".

Numerics are the flat path's, by construction: each group's partial loss
uses the GLOBAL normalized task-weight slice (``w = tw[heads]``, NOT
re-normalized within the group), so

    Σ_g Σ_{t∈g} ŵ_t L_t  ==  Σ_t ŵ_t L_t   (summation order only)

and per-task losses / head grads are scattered back by head index. The
cross-plan parity suite (tests/test_parallel_parity.py) pins hier vs flat
pjit vs single-device jit to fp32 tolerance.

``HierCompiledStep`` is the ``plan.compile()`` product for
``backend="hier"``: one lazily-jitted executable per (heads, devices)
group plus one parameter-update executable, exposed via ``functions()`` /
``cache_size()`` for ``repro.analysis.RecompileSanitizer``. A placement
change (``update_placement``) re-jits exactly the groups whose (heads,
devices) key changed — untouched groups and the update step are reused.
"""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.sharding import hier_batch_spec
from repro.launch.mesh import make_group_meshes

from .state import StepOutput, TrainState
from .step import normalized_task_weights, with_grad_accum


def _take_heads(leaf, heads):
    """Slice a leading per-task dim at the group's head indices. Works on
    concrete arrays and on ShapeDtypeStruct templates (dry-run lowering)."""
    if isinstance(leaf, jax.ShapeDtypeStruct):
        return jax.ShapeDtypeStruct((len(heads),) + tuple(leaf.shape[1:]),
                                    leaf.dtype)
    return leaf[np.asarray(heads)]


def _slice_batch(batch, heads, n_tasks):
    """Group view of a task-major batch: leaves with a leading (n_tasks,)
    dim are sliced at the group's heads; anything else (flat side-channel
    leaves) is passed through whole."""
    def take(leaf):
        shape = tuple(leaf.shape)
        if len(shape) >= 1 and shape[0] == n_tasks:
            return _take_heads(leaf, heads)
        return leaf
    return jax.tree_util.tree_map(take, batch)


class HierCompiledStep:
    """Compiled step for hierarchical plans; see module docstring.

    Call signature matches ``CompiledStep``: ``(state, batch) -> (state,
    StepOutput)`` over the GLOBAL state and task-major batch — slicing,
    group dispatch, and the combine are internal. Parameters are re-placed
    onto each group mesh per call (host-mesh repro; a production port keeps
    them resident per group).
    """

    def __init__(self, plan, spec):
        from .step import HierStepSpec
        assert isinstance(spec, HierStepSpec), (
            "backend='hier' compiles the HierStepSpec returned by "
            f"make_step(model, optimizer, plan) — got {type(spec).__name__}")
        assert plan.placement is not None, "hier plan needs a placement"
        self.plan = plan
        self.spec = spec
        self.placement = plan.placement
        self.n_tasks = self.placement.n_heads
        model_tasks = getattr(spec.model, "n_tasks", 0)
        assert model_tasks in (0, self.n_tasks), (
            f"placement covers {self.n_tasks} heads but model "
            f"'{spec.model.name}' has {model_tasks}")
        self._tw = normalized_task_weights(self.n_tasks, spec.task_weights)
        self._groups = {}      # (heads, device_ids) -> jitted group grad fn
        self._update = None

    # -- executable builders (lazy, cached) ---------------------------------

    def _group_grad_fn(self, heads):
        """Jitted ``(params_g, batch_g) -> (partial_loss, metrics, grads_g)``
        for one group. The weight slice keeps the GLOBAL normalization so
        group partials sum to the flat loss exactly."""
        model, accum = self.spec.model, self.spec.accum
        w = self._tw[np.asarray(heads)]

        def grad_fn(params, batch):
            def loss(p):
                per_task, metrics = model.loss_fn(p["shared"], p["heads"],
                                                  batch)
                # quarantined (zero-weight) heads excluded by select, not
                # multiplication — 0 * nan is still nan (cf. step.py)
                return jnp.sum(jnp.where(w > 0, per_task * w, 0.0)), \
                    (per_task, metrics)

            (l, (per_task, metrics)), grads = \
                jax.value_and_grad(loss, has_aux=True)(params)
            return l, dict(metrics, per_task_loss=per_task), grads

        return jax.jit(with_grad_accum(grad_fn, accum, axis=1))

    def _get_group(self, heads, gmesh):
        key = (tuple(heads), tuple(d.id for d in gmesh.devices.flat))
        fn = self._groups.get(key)
        if fn is None:
            # old entries are kept: flipping a placement back reuses them,
            # and RecompileSanitizer.track_session holds every fn it saw
            fn = self._groups[key] = self._group_grad_fn(heads)
        return fn

    def _get_update(self):
        if self._update is None:
            optimizer = self.spec.optimizer
            donate = (0,) if self.plan.donate else ()

            def update(state, grads):
                new_params, new_opt = optimizer.update(
                    grads, state.opt_state, state.params)
                return TrainState(params=new_params, opt_state=new_opt,
                                  step=state.step + 1, rng=state.rng)

            self._update = jax.jit(update, donate_argnums=donate)
        return self._update

    # -- per-group placement -------------------------------------------------

    def _group_inputs(self, params, batch, heads, gmesh, n_dev):
        """(params_g, batch_g) placed on the group mesh: trunk + head slice
        replicated, batch B sharded over the group's data axis (replicated
        when ragged — hier_batch_spec)."""
        pg = {"shared": params["shared"],
              "heads": jax.tree_util.tree_map(
                  lambda l: _take_heads(l, heads), params["heads"])}
        bg = _slice_batch(batch, heads, self.n_tasks)
        rep = NamedSharding(gmesh, P())
        if any(isinstance(l, jax.ShapeDtypeStruct)
               for l in jax.tree_util.tree_leaves(pg)):
            # dry-run templates: attach shardings instead of placing
            pg = jax.tree_util.tree_map(
                lambda l: jax.ShapeDtypeStruct(l.shape, l.dtype, sharding=rep),
                pg)
            bg = jax.tree_util.tree_map(
                lambda l: jax.ShapeDtypeStruct(
                    l.shape, l.dtype,
                    sharding=NamedSharding(gmesh,
                                           hier_batch_spec(l, n_dev))), bg)
            return pg, bg
        pg = jax.device_put(pg, jax.tree_util.tree_map(lambda _: rep, pg))
        bg = jax.device_put(bg, jax.tree_util.tree_map(
            lambda l: NamedSharding(gmesh, hier_batch_spec(l, n_dev)), bg))
        return pg, bg

    # -- the step ------------------------------------------------------------

    def __call__(self, state, batch):
        placement = self.placement
        meshes = make_group_meshes(placement)
        params = state.params
        results = []
        for heads, gmesh, n_dev in zip(placement.groups, meshes,
                                       placement.device_counts):
            fn = self._get_group(heads, gmesh)
            pg, bg = self._group_inputs(params, batch, heads, gmesh, n_dev)
            results.append(fn(pg, bg))     # async dispatch — groups overlap
        outs = jax.device_get(results)     # one readback for all groups

        # combine: loss and trunk grads sum ACROSS groups (the global
        # all-reduce scope); per-head leaves scatter by head index
        loss = np.float32(sum(o[0] for o in outs))
        metrics = self._scatter_metrics([o[1] for o in outs],
                                        placement.groups)
        trunk = jax.tree_util.tree_map(
            lambda *ls: np.sum(np.stack([np.asarray(l) for l in ls]), axis=0),
            *[o[2]["shared"] for o in outs])
        head_grads = jax.tree_util.tree_map(
            lambda *ls: self._scatter_heads(ls, placement.groups),
            *[o[2]["heads"] for o in outs])
        new_state = self._get_update()(state,
                                       {"shared": trunk, "heads": head_grads})
        return new_state, StepOutput(loss=loss, metrics=metrics)

    def _scatter_heads(self, leaves, groups):
        """Per-group (k_g, ...) leaves -> one (n_tasks, ...) leaf."""
        l0 = np.asarray(leaves[0])
        out = np.zeros((self.n_tasks,) + l0.shape[1:], l0.dtype)
        for heads, leaf in zip(groups, leaves):
            out[np.asarray(heads)] = np.asarray(leaf)
        return out

    def _scatter_metrics(self, mets, groups):
        def combine(*leaves):
            per_task = all(
                np.asarray(l).ndim >= 1
                and np.asarray(l).shape[0] == len(g)
                for g, l in zip(groups, leaves))
            if per_task:
                return self._scatter_heads(leaves, groups)
            return np.mean(np.stack([np.asarray(l) for l in leaves]), axis=0)
        return jax.tree_util.tree_map(combine, *mets)

    # -- placement changes ---------------------------------------------------

    def update_placement(self, placement):
        """Swap the head->group assignment in place. Groups whose (heads,
        devices) key is unchanged keep their compiled executable; only the
        affected groups re-jit on next call. The update executable is
        untouched (global state layout is placement-independent)."""
        assert placement.n_heads == self.n_tasks, (
            f"new placement covers {placement.n_heads} heads, step has "
            f"{self.n_tasks}")
        self.placement = placement

    # -- probe seams (RecompileSanitizer / dryrun) ---------------------------

    def functions(self):
        """Every executable built so far (all placements seen) plus the
        update step — each exposes jit's ``_cache_size`` probe."""
        fns = tuple(self._groups.values())
        return fns + ((self._update,) if self._update is not None else ())

    def cache_size(self) -> int:
        """Total XLA compilations across group + update executables."""
        total = 0
        for fn in self.functions():
            probe = getattr(fn, "_cache_size", None)
            total += int(probe()) if callable(probe) else 0
        return total

    def lower_groups(self, state, batch):
        """Per-group lowerings for dry-run analysis: ``[(heads, lowered)]``
        from ShapeDtypeStruct (or concrete) templates of the GLOBAL state
        and task-major batch."""
        placement = self.placement
        meshes = make_group_meshes(placement)
        out = []
        for heads, gmesh, n_dev in zip(placement.groups, meshes,
                                       placement.device_counts):
            fn = self._get_group(heads, gmesh)
            pg, bg = self._group_inputs(state.params, batch, heads, gmesh,
                                        n_dev)
            out.append((tuple(heads), fn.lower(pg, bg)))
        return out

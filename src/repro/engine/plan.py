"""ShardingPlan — one object that owns the parallelism decisions.

A plan bundles the mesh, the multi-task-parallelism config (``MTPConfig``),
the param/opt/batch sharding rules and the compilation backend behind a
single ``plan.compile(step)`` call:

  * ``mesh=None``                         -> plain single-device ``jax.jit``
  * ``mesh=..., backend="pjit"``          -> jit with NamedSharding in/out
    specs (XLA SPMD emits the paper's two collective scopes from the
    shardings; covers ``mtp.mode="par"`` and ``mode="base"``)
  * ``mesh=..., backend="shard_map"``     -> explicit-collective formulation
    (the grad_fn built by ``make_grad_fn`` carries the two psum scopes)

This replaces the old dual-return ``make_mtp_train_step`` wart: there is
exactly one public way to build a compiled step, and single-device vs
sharded is a config difference, not a different call path.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.taskpar import (HeadPlacement, MTPConfig, batch_shardings,
                                param_shardings)
from .state import StepOutput, TrainState

BACKENDS = ("auto", "jit", "pjit", "shard_map", "hier")


def _is_multitask_params(params) -> bool:
    return isinstance(params, dict) and set(params) == {"shared", "heads"}


@dataclasses.dataclass(frozen=True)
class ShardingPlan:
    mesh: Mesh | None = None
    mtp: MTPConfig | None = None
    backend: str = "auto"              # auto | jit | pjit | shard_map | hier
    shared_spec_fn: Callable | None = None   # trunk params (multitask layout)
    spec_fn: Callable | None = None          # flat params (single-task layout)
    donate: bool = True
    # hierarchical backend: a HeadPlacement (heads -> uneven device groups,
    # repro.core.solve_placement) INSTEAD of a mesh — the plan partitions
    # the raw device pool into per-group sub-meshes itself
    placement: HeadPlacement | None = None

    def __post_init__(self):
        assert self.backend in BACKENDS, f"backend '{self.backend}'"
        if self.backend in ("pjit", "shard_map"):
            assert self.mesh is not None, \
                f"backend '{self.backend}' needs a mesh"
        if self.backend == "hier":
            assert self.placement is not None, \
                "backend='hier' needs a placement (see repro.core." \
                "solve_placement / round_robin_placement)"
        if self.placement is not None:
            assert self.mesh is None, \
                "placement and mesh are exclusive — a hierarchical plan " \
                "builds its own per-group sub-meshes from the device pool"
            assert self.backend in ("auto", "hier"), \
                f"placement needs backend 'auto' or 'hier', " \
                f"got '{self.backend}'"

    @property
    def resolved_backend(self) -> str:
        if self.backend != "auto":
            return self.backend
        if self.placement is not None:
            return "hier"
        return "jit" if self.mesh is None else "pjit"

    # -- sharding trees ----------------------------------------------------

    def params_shardings(self, params):
        assert self.mesh is not None
        if self.mtp is not None and _is_multitask_params(params):
            return param_shardings(self.mesh, params, self.mtp,
                                   self.shared_spec_fn)
        from repro.configs.sharding import tree_shardings
        fn = self.spec_fn or (lambda path, leaf: P())
        return tree_shardings(self.mesh, params, fn)

    def opt_shardings(self, opt_state, p_shard):
        """Optimizer moments mirror the params; scalars replicate."""
        rep = NamedSharding(self.mesh, P())
        from repro.optim import AdamWState
        if isinstance(opt_state, AdamWState):
            return AdamWState(step=rep, m=p_shard, v=p_shard)
        raise NotImplementedError(
            f"no sharding rule for optimizer state {type(opt_state).__name__}")

    def state_shardings(self, state: TrainState) -> TrainState:
        rep = NamedSharding(self.mesh, P())
        ps = self.params_shardings(state.params)
        os_ = self.opt_shardings(state.opt_state, ps)
        rng = None if state.rng is None else \
            jax.tree_util.tree_map(lambda _: rep, state.rng)
        guard = None if state.guard is None else \
            jax.tree_util.tree_map(lambda _: rep, state.guard)
        return TrainState(params=ps, opt_state=os_, step=rep, rng=rng,
                          guard=guard)

    def data_batch_shardings(self, batch):
        assert self.mesh is not None
        if self.mtp is not None:
            return batch_shardings(self.mesh, batch, self.mtp)
        # flat batch: dim 0 over every non-model axis (pure DDP)
        axes = tuple(a for a in self.mesh.axis_names if a != "model")

        def spec(leaf):
            s = P(axes) if leaf.ndim >= 1 else P()
            return NamedSharding(self.mesh, s)
        return jax.tree_util.tree_map(spec, batch)

    # -- placement helpers -------------------------------------------------

    def shard_state(self, state: TrainState) -> TrainState:
        if self.mesh is None:
            return state
        return jax.device_put(state, self.state_shardings(state))

    def shard_batch(self, batch):
        """Device placement for a host batch. With a mesh: NamedSharding
        placement. Without: plain default-device put — loaders return host
        NumPy, and an explicit put here (e.g. on the prefetch thread) keeps
        the H2D copy off the step's critical path."""
        if self.mesh is None:
            return jax.device_put(batch)
        return jax.device_put(batch, self.data_batch_shardings(batch))

    # -- dry-run templates -------------------------------------------------

    def state_template(self, init_fn, optimizer) -> TrainState:
        """TrainState of ShapeDtypeStructs (zero allocation — eval_shape
        only), with this plan's shardings attached when a mesh is set.
        Feed the result to ``plan.compile(step).lower(...)`` for dry-runs."""
        import jax.numpy as jnp
        key = jax.ShapeDtypeStruct((2,), jnp.uint32)
        p_shapes = jax.eval_shape(init_fn, key)
        o_shapes = jax.eval_shape(optimizer.init, p_shapes)
        shapes = TrainState(params=p_shapes, opt_state=o_shapes,
                            step=jax.ShapeDtypeStruct((), jnp.int32), rng=None)
        if self.mesh is None:
            return shapes
        return jax.tree_util.tree_map(
            lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
            shapes, self.state_shardings(shapes))

    # -- compilation -------------------------------------------------------

    def compile(self, step):
        """The one public way to build a compiled step. Works for concrete
        arrays and for ShapeDtypeStruct templates (``.lower`` for dry-runs).
        Hierarchical plans take the ``HierStepSpec`` from ``make_step`` and
        return a ``HierCompiledStep`` (same call signature)."""
        from .step import HierStepSpec
        if self.resolved_backend == "hier":
            from .hier import HierCompiledStep
            return HierCompiledStep(self, step)
        assert not isinstance(step, HierStepSpec), (
            f"a HierStepSpec can only be compiled by a hier plan "
            f"(this plan resolves to '{self.resolved_backend}')")
        return CompiledStep(self, step)


class CompiledStep:
    """Lazy jit wrapper: sharding specs are derived from the first
    (state, batch) it sees — concrete arrays or ShapeDtypeStructs."""

    def __init__(self, plan: ShardingPlan, step):
        self.plan = plan
        self.step = step
        self._jitted = None

    def _build(self, state, batch):
        plan = self.plan
        donate = (0,) if plan.donate else ()
        if plan.resolved_backend == "jit":
            return jax.jit(self.step, donate_argnums=donate)
        ss = plan.state_shardings(state)
        # ShapeDtypeStruct templates may carry hand-attached batch shardings
        # (e.g. input_specs' replicate-on-non-divisible fallback in dryruns);
        # honor those, fill the rest from the plan's rule
        bs = jax.tree_util.tree_map(
            lambda leaf, sh: leaf.sharding
            if (isinstance(leaf, jax.ShapeDtypeStruct)
                and leaf.sharding is not None) else sh,
            batch, plan.data_batch_shardings(batch))
        rep = NamedSharding(plan.mesh, P())
        out = (ss, StepOutput(loss=rep, metrics=None))
        return jax.jit(self.step, in_shardings=(ss, bs), out_shardings=out,
                       donate_argnums=donate)

    def _get(self, state, batch):
        if self._jitted is None:
            self._jitted = self._build(state, batch)
        return self._jitted

    def __call__(self, state, batch):
        return self._get(state, batch)(state, batch)

    def lower(self, state, batch):
        return self._get(state, batch).lower(state, batch)

    def cache_size(self) -> int:
        """Number of XLA compilations held by the underlying jit cache
        (0 before first call) — the probe seam for
        ``repro.analysis.RecompileSanitizer``."""
        if self._jitted is None:
            return 0
        probe = getattr(self._jitted, "_cache_size", None)
        return int(probe()) if callable(probe) else 0

"""repro.engine — the declarative training-session API.

One consistent surface for single-task, multi-task and task-parallel
pre-training:

    from repro.engine import Session, SessionConfig
    result = Session.from_config(
        SessionConfig(model="gfm-mtl", arch=cfg, steps=300),
        sources=sources).run()

Lower-level pieces (all public):

  * ``TrainState`` / ``StepOutput`` / ``TrainStep`` — the unified step
    protocol ``step(state, batch) -> (state, StepOutput)``;
  * ``make_step`` / ``make_grad_fn`` / ``with_grad_accum`` — step assembly
    (gradient accumulation works for every step, LM and multi-task alike);
  * ``ShardingPlan`` — mesh + MTPConfig + backend choice behind one
    ``plan.compile(step)`` call (jit / pjit / shard_map);
  * ``build_model`` / ``register_model`` — the model registry.

Performance knobs a session picks up from its configs:

  * ``ArchConfig.segment_sum_impl`` — GNN message-aggregation kernel:
    ``"scatter"`` (default) | ``"jnp"`` | ``"pallas"`` | ``"fused"``
    (see ``repro.models.gnn``);
  * ``SessionConfig.prefetch`` (default on) — async double-buffered input
    pipeline: batch assembly and device placement run on a background
    thread and overlap the running step (``repro.data.prefetch``);
  * ``SessionConfig.mixing`` — imbalance-aware multi-source mixing
    (``repro.data.mixing``): weighted batch composition for single-branch
    models, per-task loss weights for multi-head models;
  * ``SessionConfig.bucketing`` — size-bucketed dynamic batching
    (``repro.data.bucketing``): batches re-padded down to a small shape
    grid so the kernels stop paying worst-case (A, E) padding.

The input pipeline is checkpointable end to end: ``Session.run`` writes a
``.datapipe.json`` sidecar next to ``ckpt_path`` and
``Session.restore_datapipe`` resumes a byte-identical batch stream (see
docs/data.md).
"""
from .state import StepOutput, TrainState  # noqa: F401
from .step import (HierStepSpec, SingleTaskModel, TrainStep,  # noqa: F401
                   make_grad_fn, make_step, make_train_step,
                   multitask_grad_fn, normalized_task_weights,
                   shardmap_grad_fn, single_grad_fn, with_grad_accum)
from .plan import CompiledStep, ShardingPlan  # noqa: F401
from .hier import HierCompiledStep  # noqa: F401
from .registry import available_models, build_model, register_model  # noqa: F401
from .session import Session, SessionConfig, SessionResult  # noqa: F401

"""Session — the engine front door.

``Session.from_config(cfg, sources=...).run()`` composes everything one used
to hand-wire per entry point: model registry, ``GroupBatcher``/
``SingleBatcher`` data feeding with async double-buffered prefetch
(``SessionConfig.prefetch``, default on — batch assembly and H2D transfer
overlap the running step), AdamW + schedule, ``ShardingPlan`` (mesh / MTP
mode / backend), gradient accumulation, ``EarlyStopping``,
``MetricLogger``, eval and checkpointing — then runs the unified train loop
and returns a ``SessionResult``.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import numpy as np

from repro.core.taskpar import MTPConfig, MultiTaskModel
from repro.data.bucketing import BucketingBatcher, BucketSpec
from repro.data.loader import GroupBatcher, SingleBatcher, _source_len
from repro.data.mixing import MixingBatcher, MixingConfig
from repro.optim import adamw, warmup_cosine
from repro.train import checkpoint
from repro.train.loop import EarlyStopping, MetricLogger, train_loop

from .plan import ShardingPlan
from .registry import build_model
from .state import TrainState
from .step import make_step


@dataclasses.dataclass(frozen=True)
class SessionConfig:
    model: str                        # registry name (see engine.registry)
    arch: Any                         # ArchConfig
    steps: int = 100
    batch_per_task: int = 16          # per-task batch (== batch for "lm")
    # optimizer
    lr: float = 1e-3
    warmup: int = 0                   # >0 => warmup_cosine(lr, warmup, steps)
    weight_decay: float = 0.01
    grad_clip: float = 0.0
    accum: int = 1                    # gradient-accumulation microbatches
    # parallelism (mesh itself is passed to Session — it is runtime state)
    mode: str = "par"                 # MTP head sharding: "par" | "base"
    backend: str = "auto"             # auto | jit | pjit | shard_map
    # loop control
    log_every: int = 10
    eval_every: int = 50
    patience: int = 0                 # >0 => early stopping
    min_delta: float = 1e-4
    val_metric: str = "val_loss"      # row key EarlyStopping watches
    # input pipeline: assemble + device-place batches on a background
    # thread (repro.data.prefetch.Prefetcher, depth-2 double buffering) so
    # host-side batching and H2D transfer overlap the running step. The
    # batch STREAM is identical either way — prefetch changes when batches
    # are built, never which.
    prefetch: bool = True
    prefetch_depth: int = 2
    # multi-source mixing (repro.data.mixing): None = legacy behaviour
    # (fixed per-task round-robin / single source). A MixingConfig, a float
    # (shorthand for MixingConfig(temperature=...)) or a tuple of explicit
    # per-source weights. Single-task models over a LIST of sources get a
    # MixingBatcher (weighted batch composition); multi-task models keep
    # one-head-per-source batches and apply the same weights as per-task
    # LOSS weights instead (unless task_weights is set explicitly).
    mixing: Any = None
    # size-bucketed dynamic batching (repro.data.bucketing): None = one
    # global pad shape. A BucketSpec, or an int n (shorthand: plan an n x n
    # bucket grid from the session's sources) — batches are re-padded down
    # to the smallest bucket shape holding their content.
    bucketing: Any = None
    # hierarchical multi-task parallelism (docs/parallelism.md): assign
    # heads to UNEVEN device groups, load-balanced by the mixing weights
    # (the measured per-source batch mix) as the per-head load model.
    # None = flat plans (legacy). An int n = solve over n devices; "auto"
    # = solve over every host device; an explicit HeadPlacement is used
    # as-is. Exclusive with passing a mesh to Session.
    placement: Any = None
    # misc
    seed: int = 0
    task_weights: tuple | None = None
    ckpt_path: str | None = None
    verbose: bool = True
    # buffer donation: fastest, but the session's TrainState is CONSUMED by
    # each step — if run() raises mid-loop, session.state buffers are gone.
    # Set False to keep pre-run state recoverable after a failure.
    donate: bool = True
    # fault tolerance (repro.resilience): a ResilienceConfig switches run()
    # to the resilient runner — guarded stepping with loss-spike/NaN
    # rollback, policy-driven preemption-safe checkpointing, retried IO and
    # deterministic fault injection (docs/robustness.md). None = the plain
    # train_loop, byte-for-byte legacy behaviour.
    resilience: Any = None

    def replace(self, **kw) -> "SessionConfig":
        return dataclasses.replace(self, **kw)


def _as_mixing(mixing) -> MixingConfig | None:
    """SessionConfig.mixing shorthands -> MixingConfig."""
    if mixing is None or isinstance(mixing, MixingConfig):
        return mixing
    if isinstance(mixing, bool):   # bool IS int — reject the likely typo
        raise TypeError("cfg.mixing=True/False is ambiguous — pass a "
                        "MixingConfig, a float temperature, or None")
    if isinstance(mixing, (int, float)):
        return MixingConfig(temperature=float(mixing))
    if isinstance(mixing, (tuple, list)):
        return MixingConfig(weights=tuple(mixing))
    raise TypeError(f"cfg.mixing: expected MixingConfig | float temperature "
                    f"| weight tuple | None, got {type(mixing).__name__}")


def _resolve_placement(placement, n_tasks, loads, seed):
    """SessionConfig.placement shorthands -> HeadPlacement (int n / "auto"
    run the imbalance-aware solver over n / all host devices)."""
    from repro.core.balancing import solve_placement
    from repro.core.taskpar import HeadPlacement
    if isinstance(placement, HeadPlacement):
        assert placement.n_heads == n_tasks, (
            f"placement covers {placement.n_heads} heads, session has "
            f"{n_tasks} tasks")
        return placement
    if isinstance(placement, bool):   # bool IS int — reject the likely typo
        raise TypeError("cfg.placement=True/False is ambiguous — pass a "
                        "device count, \"auto\", or a HeadPlacement")
    if placement == "auto":
        return solve_placement(len(jax.devices()), loads, seed=seed)
    if isinstance(placement, int):
        return solve_placement(placement, loads, seed=seed)
    raise TypeError(f"cfg.placement: expected HeadPlacement | int device "
                    f"count | \"auto\" | None, got {type(placement).__name__}")


def _as_bucket_spec(bucketing, sources, batcher) -> BucketSpec:
    """SessionConfig.bucketing shorthands -> BucketSpec (an int plans an
    n x n grid from the session's sources)."""
    if isinstance(bucketing, BucketSpec):
        return bucketing
    if isinstance(bucketing, bool):   # bool IS int — reject the likely typo
        raise TypeError("cfg.bucketing=True/False is ambiguous — pass a "
                        "BucketSpec, an int grid size, or None")
    if isinstance(bucketing, int):
        srcs = sources if isinstance(sources, (list, tuple)) else \
            ([sources] if sources is not None
             else getattr(batcher, "sources", None))
        assert srcs is not None, \
            "cfg.bucketing=<int> needs sources to plan the grid from; " \
            "pass an explicit BucketSpec instead"
        return BucketSpec.from_sources(srcs, n_atom_buckets=bucketing,
                                       n_edge_buckets=bucketing)
    raise TypeError(f"cfg.bucketing: expected BucketSpec | int | None, "
                    f"got {type(bucketing).__name__}")


@dataclasses.dataclass
class SessionResult:
    state: TrainState
    logger: MetricLogger
    final_loss: float
    last_metrics: dict
    stopped_early: bool
    # resilient runs only: the run exited early on (real or simulated)
    # SIGTERM/SIGUSR1 after flushing a resumable checkpoint
    preempted: bool = False
    # resilient runs only: trip/rollback/recovery report (runner docstring)
    resilience: dict | None = None

    @property
    def params(self):
        return self.state.params


class Session:
    """One declarative training session; see module docstring.

    sources: list of per-task sample dicts (multi-task models) or a single
    sample dict (the "lm" single-task model). eval_fn(params) -> dict of
    scalar metrics, merged into logged rows (put cfg.val_metric in it to
    early-stop on validation, per paper §5.1)."""

    def __init__(self, cfg: SessionConfig, *, sources=None, batcher=None,
                 mesh=None, eval_fn: Callable | None = None,
                 task_names: list[str] | None = None, model=None,
                 model_kwargs: dict | None = None):
        assert cfg.steps >= 1, f"SessionConfig.steps must be >= 1, got {cfg.steps}"
        self.cfg = cfg
        self.eval_fn = eval_fn

        # task count comes from the data (one source per task)
        if batcher is not None:
            n_tasks = (len(batcher.sources)
                       if isinstance(batcher, GroupBatcher) else 1)
        else:
            assert sources is not None, "Session needs sources or a batcher"
            n_tasks = len(sources) if isinstance(sources, (list, tuple)) else 1
        self.model = model if model is not None else \
            build_model(cfg.model, cfg.arch, n_tasks=n_tasks,
                        **(model_kwargs or {}))
        # batching follows the BUILT model's flavour (works for any model
        # registered via @register_model, not just the built-in names)
        multitask = isinstance(self.model, MultiTaskModel)
        mixing = _as_mixing(cfg.mixing)
        task_weights = cfg.task_weights
        if batcher is None:
            if multitask:
                assert isinstance(sources, (list, tuple)), \
                    "multi-task session takes a list of per-task sources"
                heads = getattr(self.model, "n_tasks", 0) or n_tasks
                if heads == 1 and len(sources) > 1:
                    # single-branch model over several sources (the paper's
                    # GFM-Baseline-All): one task row drawn from the
                    # weighted MIXTURE of all sources
                    assert mixing is not None, (
                        f"model '{cfg.model}' has one branch but got "
                        f"{len(sources)} sources — set cfg.mixing to train "
                        "it on the mixture, or pool the sources yourself")
                    batcher = MixingBatcher(list(sources), cfg.batch_per_task,
                                            mixing=mixing, seed=cfg.seed,
                                            task_major=True)
                    n_tasks = 1
                else:
                    assert len(sources) == heads or heads == 0, (
                        f"model '{cfg.model}' has {heads} branches but got "
                        f"{len(sources)} sources")
                    batcher = GroupBatcher(list(sources), cfg.batch_per_task,
                                           seed=cfg.seed)
                    if mixing is not None and task_weights is None:
                        # every head must see ITS source every step, so
                        # batch composition is fixed — the mixing weights
                        # become per-task LOSS weights instead (same
                        # imbalance lever, applied where the model flavour
                        # allows)
                        sizes = [_source_len(s) for s in sources]
                        task_weights = tuple(float(w)
                                             for w in mixing.resolve(sizes))
            else:
                if mixing is not None and isinstance(sources, (list, tuple)) \
                        and len(sources) > 1:
                    # the paper's baseline shape: ONE head over mixed data —
                    # mixing composes each flat batch from all sources
                    batcher = MixingBatcher(list(sources), cfg.batch_per_task,
                                            mixing=mixing, seed=cfg.seed)
                else:
                    if isinstance(sources, (list, tuple)):
                        assert len(sources) == 1, (
                            f"single-task model '{cfg.model}' got "
                            f"{len(sources)} sources; use a multi-task model "
                            "(e.g. 'lm-mtl'), pass one source, or set "
                            "cfg.mixing to train one head on the mixture")
                        sources = sources[0]
                    batcher = SingleBatcher(sources, cfg.batch_per_task,
                                            seed=cfg.seed)
                n_tasks = 1
        if cfg.bucketing is not None:
            batcher = BucketingBatcher(
                batcher, _as_bucket_spec(cfg.bucketing, sources, batcher))
        self.batcher = batcher
        self.task_names = task_names or [f"task{t}" for t in range(n_tasks)]
        assert len(self.task_names) == n_tasks, \
            f"{len(self.task_names)} task_names for {n_tasks} tasks"

        mtp = None
        if multitask:
            # data axes follow the mesh: everything but the task axis (so a
            # multi-pod mesh's "pod" axis carries batch too)
            data_axes = tuple(a for a in mesh.axis_names if a != "model") \
                if mesh is not None else ("data",)
            mtp = MTPConfig(n_tasks=n_tasks, mode=cfg.mode,
                            data_axes=data_axes)
        placement = None
        if cfg.placement is not None:
            assert mesh is None, \
                "cfg.placement and an explicit mesh are exclusive — the " \
                "hierarchical plan partitions the device pool itself"
            assert multitask, \
                "cfg.placement shards per-task heads — needs a multi-task " \
                "model"
            if cfg.resilience is not None and \
                    getattr(cfg.resilience, "guard", None) is not None:
                raise NotImplementedError(
                    "guarded stepping (resilience.guard) is not supported "
                    "on the hierarchical backend yet — drop cfg.placement "
                    "or the guard")
            # the solver's load model: the measured per-source batch mix —
            # for multi-task sessions the mixing weights already landed in
            # task_weights above; uniform when neither is set
            loads = tuple(task_weights) if task_weights is not None \
                else (1.0,) * n_tasks
            placement = _resolve_placement(cfg.placement, n_tasks, loads,
                                           cfg.seed)
        self.plan = ShardingPlan(mesh=mesh, mtp=mtp, backend=cfg.backend,
                                 donate=cfg.donate, placement=placement)

        if task_weights is not None and \
                self.plan.resolved_backend == "shard_map":
            raise ValueError(
                "the shard_map backend supports uniform task weights only — "
                "drop cfg.mixing/task_weights or use backend='pjit'")
        self.task_weights = task_weights
        lr = warmup_cosine(cfg.lr, cfg.warmup, cfg.steps) if cfg.warmup \
            else cfg.lr
        self.optimizer = adamw(lr, weight_decay=cfg.weight_decay,
                               grad_clip=cfg.grad_clip)
        # quarantine bookkeeping (repro.resilience): loss-weight-quarantined
        # task indices (task-major sessions) and sampling-quarantined source
        # indices (MixingBatcher sessions)
        self._quarantined: set[int] = set()
        self._quarantined_sources: set[int] = set()
        self._task_major_batches = multitask
        self._rebuild_step()

        params = self.model.init(jax.random.PRNGKey(cfg.seed))
        guard0 = None
        if cfg.resilience is not None and \
                getattr(cfg.resilience, "guard", None) is not None:
            from repro.resilience.guard import GuardState
            guard0 = GuardState.init()
        state = TrainState.create(params, self.optimizer,
                                  rng=jax.random.PRNGKey(cfg.seed + 1),
                                  guard=guard0)
        self.state = self.plan.shard_state(state)
        # ONE prefetcher for the session's lifetime (created on first run):
        # closing it between runs would discard already-drawn batches and
        # silently shift the batcher's stream vs the synchronous path
        self._prefetcher = None
        # consumed-position snapshot taken when the prefetcher is closed —
        # after close() the underlying batcher sits PAST what the loop saw
        # (discarded read-ahead), so datapipe_state() must not read it
        self._dp_snapshot = None

    @classmethod
    def from_config(cls, cfg: SessionConfig, **kw) -> "Session":
        return cls(cfg, **kw)

    def _rebuild_step(self):
        """(Re)build + (re)compile the train step from the current model /
        optimizer / task_weights. Guarded (repro.resilience.guard) when the
        session carries a ResilienceConfig with a GuardConfig — the
        accept/reject select lives INSIDE the jitted step, so guarding stays
        donation-safe. Called at construction and after quarantine changes
        the task weights."""
        cfg = self.cfg
        gcfg = getattr(cfg.resilience, "guard", None) \
            if cfg.resilience is not None else None
        if gcfg is not None:
            from repro.resilience.guard import make_guarded_step
            step = make_guarded_step(self.model, self.optimizer, self.plan,
                                     guard=gcfg, accum=cfg.accum,
                                     task_weights=self.task_weights)
        else:
            step = make_step(self.model, self.optimizer, self.plan,
                             accum=cfg.accum,
                             task_weights=self.task_weights)
        self.compiled_step = self.plan.compile(step)

    def compiled_functions(self):
        """The session's compiled callables, re-read live — the probe seam
        for ``repro.analysis.RecompileSanitizer.track_session`` (a step
        rebuilt by quarantine replaces ``compiled_step``, so trackers must
        not cache the object). Hierarchical sessions surface the per-group
        executables + the update step individually."""
        fns = getattr(self.compiled_step, "functions", None)
        if callable(fns):
            return tuple(fns())
        return (self.compiled_step,)

    def set_placement(self, placement):
        """Swap a hierarchical session's head->device-group assignment in
        place (same shorthands as ``cfg.placement``). Only group
        executables whose (heads, devices) changed recompile — verified by
        the RecompileSanitizer regression in tests/test_sanitizers.py."""
        assert self.plan.resolved_backend == "hier", \
            "set_placement needs a hierarchical session (cfg.placement)"
        loads = tuple(self.task_weights) if self.task_weights is not None \
            else (1.0,) * len(self.task_names)
        placement = _resolve_placement(placement, len(self.task_names),
                                       loads, self.cfg.seed)
        self.plan = dataclasses.replace(self.plan, placement=placement)
        self.compiled_step.update_placement(placement)

    def n_params(self) -> int:
        return sum(int(x.size) for x in
                   jax.tree_util.tree_leaves(self.state.params))

    def close(self):
        """Stop the background prefetcher (if any). The session stays
        usable — the next run() recreates it — but batches the producer had
        already drawn are discarded, so only close when done with the
        session."""
        if self._prefetcher is not None:
            try:
                self._dp_snapshot = self._prefetcher.state()
            except TypeError:
                self._dp_snapshot = None
            self._prefetcher.close()
            self._prefetcher = None

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
        return False

    # -- input-pipeline checkpointing ---------------------------------------

    def datapipe_state(self) -> dict | None:
        """JSON-serializable state of the session's input pipeline, as of
        the last batch the TRAINING LOOP consumed (prefetcher read-ahead is
        not credited). None when the batcher isn't checkpointable (e.g. a
        hand-rolled batcher without state()/restore())."""
        if self._prefetcher is None and self._dp_snapshot is not None:
            # prefetcher was closed: the live batcher sits past the
            # consumed position (discarded read-ahead) — use the snapshot
            # taken at close time
            return self._dp_snapshot
        src = self._prefetcher if self._prefetcher is not None else \
            self.batcher
        try:
            return src.state()
        except (AttributeError, TypeError):
            return None

    def restore_datapipe(self, state):
        """Rewind the input pipeline to a ``datapipe_state()`` snapshot (or
        a checkpoint path whose ``.datapipe.json`` sidecar holds one): the
        next batch drawn is byte-identical to the one an uninterrupted run
        would have drawn."""
        if isinstance(state, str):
            path = state
            state = checkpoint.load_datapipe(path)
            # a crash between the npz write and the sidecar write leaves
            # the two describing different steps — refuse to resume a
            # stream position that doesn't match the params
            stamp = checkpoint.load_datapipe_step(path)
            try:
                meta_step = checkpoint.load_metadata(path).get("step")
            except FileNotFoundError:
                meta_step = None
            if stamp is not None and meta_step is not None \
                    and stamp != meta_step:
                raise RuntimeError(
                    f"checkpoint desync at {path}: params are at step "
                    f"{meta_step} but the datapipe sidecar was written at "
                    f"step {stamp} (crash between the two writes?) — "
                    "resuming would replay or skip batches")
        if self._prefetcher is not None:
            self._prefetcher.restore(state)
        else:
            self.batcher.restore(state)
        # any close-time snapshot describes the PRE-restore position —
        # stale now that the pipeline was rewound
        self._dp_snapshot = None

    # -- fault tolerance (repro.resilience) ---------------------------------

    def _inner_batcher(self):
        b = self.batcher
        return b.batcher if isinstance(b, BucketingBatcher) else b

    def quarantine_tasks(self, tasks):
        """Quarantine fidelity sources so they stop influencing the params.

        Multi-head (task-major) sessions zero the per-task LOSS weight and
        recompile the step (the resilient runner additionally sanitizes the
        quarantined batch slices — a zero loss weight alone is not enough,
        since 0 * nan == nan in the backward pass). MixingBatcher sessions
        zero the source's SAMPLING weight instead — no recompile needed.
        Idempotent; refuses to quarantine every source."""
        tasks = sorted({int(t) for t in tasks})
        if not tasks:
            return
        inner = self._inner_batcher()
        if isinstance(inner, MixingBatcher):
            w = np.asarray(inner.weights, np.float64).copy()
            for t in tasks:
                assert 0 <= t < w.size, f"source {t} out of range"
                w[t] = 0.0
            inner.set_weights(w)   # asserts at least one source survives
            self._quarantined_sources |= set(tasks)
            return
        assert isinstance(self.model, MultiTaskModel), \
            "quarantine_tasks needs per-task loss weights (multi-task " \
            "model) or a MixingBatcher session"
        if self.plan.resolved_backend == "shard_map":
            raise ValueError(
                "the shard_map backend supports uniform task weights only — "
                "cannot quarantine a source; use backend='pjit'")
        n = len(self.task_names)
        w = np.ones(n, np.float64) if self.task_weights is None else \
            np.asarray(self.task_weights, np.float64).copy()
        for t in tasks:
            assert 0 <= t < n, f"task {t} out of range for {n} tasks"
            w[t] = 0.0
        assert w.sum() > 0, "cannot quarantine every task"
        self.task_weights = tuple(float(x) for x in w)
        self._quarantined |= set(tasks)
        self._rebuild_step()

    def _reapply_quarantine(self):
        """Rollback restores a datapipe snapshot that may predate a
        sampling quarantine — restoring it would resurrect the quarantined
        source's weight, so re-zero it. The loss-weight path lives in the
        compiled step and survives rollback untouched."""
        if not self._quarantined_sources:
            return
        inner = self._inner_batcher()
        w = np.asarray(inner.weights, np.float64).copy()
        w[sorted(self._quarantined_sources)] = 0.0
        inner.set_weights(w)

    def resume(self, ckpt_dir: str | None = None) -> int:
        """Rewind this session to the latest checkpoint a resilient run
        wrote (full TrainState — params, optimizer moments, step, rng,
        guard — AND the datapipe position): the next ``run()`` continues
        from there to ``cfg.steps``, replaying the batch stream
        byte-identically. Returns the resumed step."""
        d = ckpt_dir if ckpt_dir is not None else \
            getattr(self.cfg.resilience, "ckpt_dir", None)
        assert d, "resume() needs cfg.resilience.ckpt_dir or an explicit dir"
        from repro.resilience.policy import CheckpointManager
        mgr = CheckpointManager(
            d, getattr(self.cfg.resilience, "policy", None))
        path, state = mgr.load_latest(template=self.state)
        self.state = state
        if checkpoint.has_datapipe(path):
            self.restore_datapipe(path)
        return int(state.step)

    def _metric_fn(self, out) -> dict:
        m = out.metrics
        extras = {}
        if "per_task_loss" in m:
            pt = np.asarray(m["per_task_loss"])
            extras.update({self.task_names[t]: float(pt[t])
                           for t in range(pt.shape[0])})
        return extras

    def _batches(self):
        """The batch-drawing callable run() loops over. Device placement
        runs with the batcher: on the prefetch thread it overlaps the
        running step (async input pipeline), synchronously it is simply the
        old ``shard_batch(next_batch())`` critical path."""
        place = self.plan.shard_batch
        if self.cfg.prefetch:
            if self._prefetcher is None:
                from repro.data.prefetch import Prefetcher
                self._prefetcher = Prefetcher(
                    self.batcher, transform=place,
                    depth=self.cfg.prefetch_depth)
            return self._prefetcher.next_batch
        return lambda: place(self.batcher.next_batch())

    def run(self) -> SessionResult:
        if self.cfg.resilience is not None:
            from repro.resilience.runner import run_resilient
            return run_resilient(self)
        cfg = self.cfg
        early = EarlyStopping(patience=cfg.patience,
                              min_delta=cfg.min_delta) \
            if cfg.patience > 0 else None
        batches = self._batches()
        state, logger, last_out = train_loop(
            self.compiled_step, self.state, batches,
            steps=cfg.steps, eval_fn=self.eval_fn,
            eval_every=cfg.eval_every, log_every=cfg.log_every,
            early_stop=early, val_metric=cfg.val_metric,
            metric_fn=self._metric_fn, verbose=cfg.verbose)
        self.state = state
        stopped = bool(early and early.bad >= early.patience)
        final_loss = float(last_out.loss)
        if cfg.ckpt_path:
            checkpoint.save(cfg.ckpt_path, {"params": state.params},
                            metadata={"model": cfg.model,
                                      "arch": cfg.arch.name,
                                      "step": int(state.step),
                                      "final_loss": final_loss},
                            datapipe=self.datapipe_state())
        return SessionResult(
            state=state, logger=logger, final_loss=final_loss,
            last_metrics=jax.tree_util.tree_map(np.asarray, last_out.metrics),
            stopped_early=stopped)

"""Session — the engine front door.

``Session.from_config(cfg, sources=...).run()`` composes everything one used
to hand-wire per entry point: model registry, ``GroupBatcher``/
``SingleBatcher`` data feeding with async double-buffered prefetch
(``SessionConfig.prefetch``, default on — batch assembly and H2D transfer
overlap the running step), AdamW + schedule, ``ShardingPlan`` (mesh / MTP
mode / backend), gradient accumulation, ``EarlyStopping``,
``MetricLogger``, eval and checkpointing — then runs the unified train loop
and returns a ``SessionResult``.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import numpy as np

from repro.core.taskpar import MTPConfig, MultiTaskModel
from repro.data.loader import GroupBatcher, SingleBatcher
from repro.optim import adamw, warmup_cosine
from repro.train import checkpoint
from repro.train.loop import EarlyStopping, MetricLogger, train_loop

from .plan import ShardingPlan
from .registry import build_model
from .state import TrainState
from .step import make_step


@dataclasses.dataclass(frozen=True)
class SessionConfig:
    model: str                        # registry name (see engine.registry)
    arch: Any                         # ArchConfig
    steps: int = 100
    batch_per_task: int = 16          # per-task batch (== batch for "lm")
    # optimizer
    lr: float = 1e-3
    warmup: int = 0                   # >0 => warmup_cosine(lr, warmup, steps)
    weight_decay: float = 0.01
    grad_clip: float = 0.0
    accum: int = 1                    # gradient-accumulation microbatches
    # parallelism (mesh itself is passed to Session — it is runtime state)
    mode: str = "par"                 # MTP head sharding: "par" | "base"
    backend: str = "auto"             # auto | jit | pjit | shard_map
    # loop control
    log_every: int = 10
    eval_every: int = 50
    patience: int = 0                 # >0 => early stopping
    min_delta: float = 1e-4
    val_metric: str = "val_loss"      # row key EarlyStopping watches
    # input pipeline: assemble + device-place batches on a background
    # thread (repro.data.prefetch.Prefetcher, depth-2 double buffering) so
    # host-side batching and H2D transfer overlap the running step. The
    # batch STREAM is identical either way — prefetch changes when batches
    # are built, never which.
    prefetch: bool = True
    prefetch_depth: int = 2
    # misc
    seed: int = 0
    task_weights: tuple | None = None
    ckpt_path: str | None = None
    verbose: bool = True
    # buffer donation: fastest, but the session's TrainState is CONSUMED by
    # each step — if run() raises mid-loop, session.state buffers are gone.
    # Set False to keep pre-run state recoverable after a failure.
    donate: bool = True

    def replace(self, **kw) -> "SessionConfig":
        return dataclasses.replace(self, **kw)


@dataclasses.dataclass
class SessionResult:
    state: TrainState
    logger: MetricLogger
    final_loss: float
    last_metrics: dict
    stopped_early: bool

    @property
    def params(self):
        return self.state.params


class Session:
    """One declarative training session; see module docstring.

    sources: list of per-task sample dicts (multi-task models) or a single
    sample dict (the "lm" single-task model). eval_fn(params) -> dict of
    scalar metrics, merged into logged rows (put cfg.val_metric in it to
    early-stop on validation, per paper §5.1)."""

    def __init__(self, cfg: SessionConfig, *, sources=None, batcher=None,
                 mesh=None, eval_fn: Callable | None = None,
                 task_names: list[str] | None = None, model=None,
                 model_kwargs: dict | None = None):
        assert cfg.steps >= 1, f"SessionConfig.steps must be >= 1, got {cfg.steps}"
        self.cfg = cfg
        self.eval_fn = eval_fn

        # task count comes from the data (one source per task)
        if batcher is not None:
            n_tasks = (len(batcher.sources)
                       if isinstance(batcher, GroupBatcher) else 1)
        else:
            assert sources is not None, "Session needs sources or a batcher"
            n_tasks = len(sources) if isinstance(sources, (list, tuple)) else 1
        self.model = model if model is not None else \
            build_model(cfg.model, cfg.arch, n_tasks=n_tasks,
                        **(model_kwargs or {}))
        # batching follows the BUILT model's flavour (works for any model
        # registered via @register_model, not just the built-in names)
        multitask = isinstance(self.model, MultiTaskModel)
        if batcher is None:
            if multitask:
                assert isinstance(sources, (list, tuple)), \
                    "multi-task session takes a list of per-task sources"
                batcher = GroupBatcher(list(sources), cfg.batch_per_task,
                                       seed=cfg.seed)
            else:
                if isinstance(sources, (list, tuple)):
                    assert len(sources) == 1, (
                        f"single-task model '{cfg.model}' got {len(sources)} "
                        "sources; use a multi-task model (e.g. 'lm-mtl') or "
                        "pass one source")
                    sources = sources[0]
                batcher = SingleBatcher(sources, cfg.batch_per_task,
                                        seed=cfg.seed)
                n_tasks = 1
        self.batcher = batcher
        self.task_names = task_names or [f"task{t}" for t in range(n_tasks)]
        assert len(self.task_names) == n_tasks, \
            f"{len(self.task_names)} task_names for {n_tasks} tasks"

        mtp = None
        if multitask:
            # data axes follow the mesh: everything but the task axis (so a
            # multi-pod mesh's "pod" axis carries batch too)
            data_axes = tuple(a for a in mesh.axis_names if a != "model") \
                if mesh is not None else ("data",)
            mtp = MTPConfig(n_tasks=n_tasks, mode=cfg.mode,
                            data_axes=data_axes)
        self.plan = ShardingPlan(mesh=mesh, mtp=mtp, backend=cfg.backend,
                                 donate=cfg.donate)

        lr = warmup_cosine(cfg.lr, cfg.warmup, cfg.steps) if cfg.warmup \
            else cfg.lr
        self.optimizer = adamw(lr, weight_decay=cfg.weight_decay,
                               grad_clip=cfg.grad_clip)
        step = make_step(self.model, self.optimizer, self.plan,
                         accum=cfg.accum, task_weights=cfg.task_weights)
        self.compiled_step = self.plan.compile(step)

        params = self.model.init(jax.random.PRNGKey(cfg.seed))
        state = TrainState.create(params, self.optimizer,
                                  rng=jax.random.PRNGKey(cfg.seed + 1))
        self.state = self.plan.shard_state(state)
        # ONE prefetcher for the session's lifetime (created on first run):
        # closing it between runs would discard already-drawn batches and
        # silently shift the batcher's stream vs the synchronous path
        self._prefetcher = None

    @classmethod
    def from_config(cls, cfg: SessionConfig, **kw) -> "Session":
        return cls(cfg, **kw)

    def n_params(self) -> int:
        return sum(int(x.size) for x in
                   jax.tree_util.tree_leaves(self.state.params))

    def close(self):
        """Stop the background prefetcher (if any). The session stays
        usable — the next run() recreates it — but batches the producer had
        already drawn are discarded, so only close when done with the
        session."""
        if self._prefetcher is not None:
            self._prefetcher.close()
            self._prefetcher = None

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
        return False

    def _metric_fn(self, out) -> dict:
        m = out.metrics
        extras = {}
        if "per_task_loss" in m:
            pt = np.asarray(m["per_task_loss"])
            extras.update({self.task_names[t]: float(pt[t])
                           for t in range(pt.shape[0])})
        return extras

    def run(self) -> SessionResult:
        cfg = self.cfg
        early = EarlyStopping(patience=cfg.patience,
                              min_delta=cfg.min_delta) \
            if cfg.patience > 0 else None
        # device placement runs with the batcher: on the prefetch thread it
        # overlaps the running step (async input pipeline), synchronously it
        # is simply the old ``shard_batch(next_batch())`` critical path
        place = self.plan.shard_batch
        if cfg.prefetch:
            if self._prefetcher is None:
                from repro.data.prefetch import Prefetcher
                self._prefetcher = Prefetcher(self.batcher, transform=place,
                                              depth=cfg.prefetch_depth)
            batches = self._prefetcher.next_batch
        else:
            batches = lambda: place(self.batcher.next_batch())  # noqa: E731
        state, logger, last_out = train_loop(
            self.compiled_step, self.state, batches,
            steps=cfg.steps, eval_fn=self.eval_fn,
            eval_every=cfg.eval_every, log_every=cfg.log_every,
            early_stop=early, val_metric=cfg.val_metric,
            metric_fn=self._metric_fn, verbose=cfg.verbose)
        self.state = state
        stopped = bool(early and early.bad >= early.patience)
        final_loss = float(last_out.loss)
        if cfg.ckpt_path:
            checkpoint.save(cfg.ckpt_path, {"params": state.params},
                            metadata={"model": cfg.model,
                                      "arch": cfg.arch.name,
                                      "step": int(state.step),
                                      "final_loss": final_loss})
        return SessionResult(
            state=state, logger=logger, final_loss=final_loss,
            last_metrics=jax.tree_util.tree_map(np.asarray, last_out.metrics),
            stopped_early=stopped)

"""Unified training state and step output pytrees.

Every train step in the repo — single-task LM, multi-task GFM/LM, pjit or
shard_map backend — has ONE signature:

    step(state: TrainState, batch) -> (TrainState, StepOutput)

``TrainState`` bundles params, optimizer state, a step counter and an
(optional) PRNG key into a single donat-able pytree; ``StepOutput`` carries
the scalar loss plus a dict of auxiliary metrics (e.g. ``per_task_loss``).
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax.numpy as jnp


class TrainState(NamedTuple):
    params: Any
    opt_state: Any
    step: jnp.ndarray          # () int32
    rng: Any = None            # optional PRNG key, threaded through steps
    # guarded stepping (repro.resilience): a GuardState pytree of scalars
    # (loss EMA + trip counters) threaded through the jitted step so the
    # guard's skip-the-update select lives INSIDE the compiled step and
    # survives buffer donation. None for unguarded sessions — plain steps
    # drop it and every existing construction keeps working.
    guard: Any = None

    @classmethod
    def create(cls, params, optimizer, rng=None, guard=None) -> "TrainState":
        """Initialise from params + an ``Optimizer`` (repro.optim)."""
        return cls(params=params, opt_state=optimizer.init(params),
                   step=jnp.zeros((), jnp.int32), rng=rng, guard=guard)


class StepOutput(NamedTuple):
    loss: jnp.ndarray          # () float
    metrics: dict              # auxiliary metric pytree (may be empty)

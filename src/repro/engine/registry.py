"""Model registry: build any trainable model by name.

Registered builders (``build(arch_cfg, *, n_tasks=None, **kw)``):

  * ``gfm-mtl``      — GFM-MTL-All: shared EGNN + per-source branches
  * ``gfm-baseline`` — GFM-Baseline-All: shared EGNN + ONE branch
  * ``lm-mtl``       — shared transformer trunk + per-source LM heads
  * ``lm``           — standard single-task LM (SingleTaskModel)
"""
from __future__ import annotations

from typing import Callable

from .step import SingleTaskModel

_REGISTRY: dict[str, Callable] = {}


def register_model(name: str):
    def deco(fn):
        _REGISTRY[name] = fn
        return fn
    return deco


def build_model(name: str, cfg, **kw):
    if name not in _REGISTRY:
        raise KeyError(f"unknown model '{name}'; known: {sorted(_REGISTRY)}")
    return _REGISTRY[name](cfg, **kw)


def available_models() -> tuple:
    return tuple(sorted(_REGISTRY))


@register_model("gfm-mtl")
def _gfm_mtl(cfg, *, n_tasks=None, **kw):
    from repro.core.mtl import make_gfm_mtl
    return make_gfm_mtl(cfg, n_tasks or cfg.n_tasks, **kw)


@register_model("gfm-baseline")
def _gfm_baseline(cfg, *, n_tasks=None, **kw):
    """GFM-Baseline-All: ONE branch regardless of how many sources feed it
    (over several sources, pair it with ``SessionConfig.mixing`` so the
    single head trains on a weighted mixture — the paper's baseline)."""
    from repro.core.mtl import make_gfm_mtl
    return make_gfm_mtl(cfg, 1, **kw)


@register_model("lm-mtl")
def _lm_mtl(cfg, *, n_tasks=None, impl="chunked"):
    from repro.core.mtl import make_lm_multitask
    assert n_tasks in (None, cfg.n_tasks), \
        f"lm-mtl head count is cfg.n_tasks={cfg.n_tasks}"
    return make_lm_multitask(cfg, impl)


@register_model("lm")
def _lm(cfg, *, n_tasks=None, impl="chunked"):
    from repro.models.transformer import lm_init
    from repro.train.loop import make_lm_loss
    return SingleTaskModel(init=lambda key: lm_init(key, cfg),
                           loss_fn=make_lm_loss(cfg, impl),
                           name=f"lm-{cfg.name}")

from . import kernel, ops, ref  # noqa: F401
from .ops import egnn_edge_agg  # noqa: F401

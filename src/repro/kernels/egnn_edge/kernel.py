"""Fused EGNN edge-message Pallas kernel.

One ``pallas_call`` computes, per edge block, the whole EGNN message hot
path that ``egnn_apply`` otherwise lowers as five separate HBM-bound ops:

    gather(h_i, h_j, x_i, x_j) -> d² -> φ_e MLP (2 dense + SiLU)
        -> masked segment-sum into node rows

Nothing edge-major ever round-trips to HBM: the ``(BE, 2H+1)`` concat input
of φ_e is never materialized (the first dense layer's weight is split into
its ``h_i`` / ``h_j`` / ``d²`` row blocks, so the concat-matmul becomes a sum
of three small matmuls), and the aggregation happens tile-by-tile in VMEM
via the membership-matmul trick of ``repro.kernels.segment_sum`` — no
``(B, E, A)`` one-hot tensor at the XLA level.

Grid: (B, num_edge_blocks) — edge blocks are the sequential inner dim; a
VMEM f32 scratch holds the whole (A, H) node accumulator per graph (A is
small in this workload: padded structures, not monolithic graphs) and is
flushed on the last edge block.

VMEM budget at A=128, H=866, BE=256 (f32): node features 433 KiB, messages
866 KiB, membership tile 128 KiB, accumulator 433 KiB, φ_e weights ≈5.9 MiB
(2·H·H + H rows) — ≈7.8 MiB resident, within the ~16 MiB/core budget. For
H beyond ~1k the first dense's weight blocks would need a K-grid dimension.

Masked/pad edges arrive with ``dst >= A`` (routed by ``ops.egnn_edge_agg``)
and are excluded from the membership tile; their gather indices are clamped
so the loads stay in bounds.

Backward (``egnn_edge_fused_bwd``) — residual-recompute contract:
the ``custom_vjp`` saves ONLY the primal inputs (h, pos, src, dst,
edge_mask, φ_e); no edge-major intermediate survives the forward. The
backward kernel re-gathers h_i/h_j/x_i/x_j, re-derives d² and re-runs the
φ_e fc0 + SiLU per edge tile in VMEM (z recomputed in the compute dtype —
bit-identical rounding to the forward — then the chain rule runs in f32),
and emits in one pass per tile:

  * ``d_h``   — masked scatter-transpose of dφ cotangents back to BOTH
    endpoint rows (membership matmuls shared with
    ``repro.kernels.segment_sum.accumulate_tile``);
  * ``d_x``   — the d² chain: ``±2(x_i - x_j) · dd²`` scattered likewise;
  * φ_e grads — (H,H)/(1,H) full reductions accumulated in f32 scratch
    across the entire sequential grid, flushed by the final program.

Masked/pad edges produce exact zeros in every cotangent because ``dm`` (the
gather of the upstream cotangent) is zeroed before anything multiplies it.

VMEM (backward) at A=128, H=256, BE=256 f32: node/cotangent tiles 3·128 KiB,
φ_e weights ≈0.75 MiB, weight-grad scratch 3·(H,H) ≈0.75 MiB, edge tiles
≈1 MiB — ≈2.9 MiB resident; H beyond ~700 needs a K-grid split, same as the
forward.

``interpret=None`` auto-detects the backend (compiled on TPU, interpreter
mode elsewhere — CPU CI validates numerics, not timing).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.segment_sum.kernel import accumulate_tile, resolve_interpret


def _edge_kernel(src_ref, dst_ref, h_ref, pos_ref, w0i_ref, w0j_ref, w0d_ref,
                 b0_ref, w1_ref, b1_ref, o_ref, acc_ref, *, ne):
    je = pl.program_id(1)   # edge block (sequential)

    @pl.when(je == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    src = src_ref[0]                      # (BE,) int32, >= A marks pad
    dst = dst_ref[0]
    h = h_ref[0]                          # (A, H) compute dtype
    pos = pos_ref[0].astype(jnp.float32)  # (A, 3)
    A = h.shape[0]
    cd = h.dtype

    # clamped gathers (pad edges load row A-1; masked out of the sum below)
    sc = jnp.minimum(src, A - 1)
    dc = jnp.minimum(dst, A - 1)
    hi = jnp.take(h, sc, axis=0)          # (BE, H)
    hj = jnp.take(h, dc, axis=0)
    xi = jnp.take(pos, sc, axis=0)        # (BE, 3)
    xj = jnp.take(pos, dc, axis=0)
    d2 = jnp.sum((xi - xj) ** 2, axis=-1, keepdims=True).astype(cd)  # (BE,1)

    # φ_e fc0 over the *virtual* concat [hi | hj | d2]: the weight arrives
    # pre-split into its three row blocks, so no (BE, 2H+1) tensor exists
    z = (hi @ w0i_ref[...] + hj @ w0j_ref[...]
         + d2 * w0d_ref[...] + b0_ref[...])
    m = jax.nn.silu(z) @ w1_ref[...] + b1_ref[...]        # (BE, H)

    # membership matmul (MXU): pad edges carry dst >= A, which matches no
    # node-id column (shared scatter-transpose tile with
    # repro.kernels.segment_sum)
    accumulate_tile(dst, m.astype(jnp.float32), acc_ref, ib=0, bn=A)

    @pl.when(je == ne - 1)
    def _flush():
        o_ref[0] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_e", "interpret"))
def egnn_edge_fused(h, pos, src, dst, w0i, w0j, w0d, b0, w1, b1, *,
                    block_e=256, interpret=None):
    """Fused forward. h: (B, A, H) compute-dtype node features; pos:
    (B, A, 3); src/dst: (B, E) int32 with >= A marking masked/pad edges
    (route them before calling — see ``ops.egnn_edge_agg``); φ_e fc0 weight
    pre-split into w0i (H,H), w0j (H,H), w0d (1,H), plus b0 (1,H), fc1
    w1 (H,H), b1 (1,H). Returns (B, A, H) aggregated messages."""
    B, A, H = h.shape
    E = src.shape[1]
    be = min(block_e, E)
    ne = -(-E // be)
    if ne * be != E:
        pe = ne * be - E
        # pad sentinel A: matches no node id, contributes nothing
        src = jnp.pad(src, ((0, 0), (0, pe)), constant_values=A)
        dst = jnp.pad(dst, ((0, 0), (0, pe)), constant_values=A)
    src = src.astype(jnp.int32)
    dst = dst.astype(jnp.int32)

    kern = functools.partial(_edge_kernel, ne=ne)
    full = lambda s: pl.BlockSpec(s, lambda b, je: (0,) * len(s))
    return pl.pallas_call(
        kern,
        grid=(B, ne),
        in_specs=[
            pl.BlockSpec((1, be), lambda b, je: (b, je)),      # src
            pl.BlockSpec((1, be), lambda b, je: (b, je)),      # dst
            pl.BlockSpec((1, A, H), lambda b, je: (b, 0, 0)),  # h
            pl.BlockSpec((1, A, 3), lambda b, je: (b, 0, 0)),  # pos
            full(w0i.shape), full(w0j.shape), full(w0d.shape),
            full(b0.shape), full(w1.shape), full(b1.shape),
        ],
        out_specs=pl.BlockSpec((1, A, H), lambda b, je: (b, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, A, H), h.dtype),
        scratch_shapes=[pltpu.VMEM((A, H), jnp.float32)],
        interpret=resolve_interpret(interpret),
    )(src, dst, h, pos, w0i, w0j, w0d, b0, w1, b1)


def _edge_bwd_kernel(src_ref, dst_ref, h_ref, pos_ref, g_ref,
                     w0i_ref, w0j_ref, w0d_ref, b0_ref, w1_ref,
                     dh_ref, dpos_ref, dw0i_ref, dw0j_ref, dw0d_ref,
                     db0_ref, dw1_ref, db1_ref,
                     acc_dh, acc_dpos, acc_w0i, acc_w0j, acc_w0d,
                     acc_b0, acc_w1, acc_b1, *, nb, ne):
    b = pl.program_id(0)    # graph (outer)
    je = pl.program_id(1)   # edge block (sequential inner)

    @pl.when(je == 0)
    def _init_batch():
        acc_dh[...] = jnp.zeros_like(acc_dh)
        acc_dpos[...] = jnp.zeros_like(acc_dpos)

    @pl.when((b == 0) & (je == 0))
    def _init_weights():
        acc_w0i[...] = jnp.zeros_like(acc_w0i)
        acc_w0j[...] = jnp.zeros_like(acc_w0j)
        acc_w0d[...] = jnp.zeros_like(acc_w0d)
        acc_b0[...] = jnp.zeros_like(acc_b0)
        acc_w1[...] = jnp.zeros_like(acc_w1)
        acc_b1[...] = jnp.zeros_like(acc_b1)

    src = src_ref[0]                      # (BE,) int32, >= A marks pad
    dst = dst_ref[0]
    h = h_ref[0]                          # (A, H) compute dtype
    pos = pos_ref[0].astype(jnp.float32)  # (A, 3)
    g = g_ref[0]                          # (A, H) upstream cotangent
    A = h.shape[0]
    cd = h.dtype

    # --- recompute the forward residuals for this edge tile (nothing was
    # saved edge-major in HBM; see the residual-recompute contract in the
    # module docstring). z is recomputed in the compute dtype — identical
    # rounding to the forward kernel — then the chain rule runs in f32.
    sc = jnp.minimum(src, A - 1)
    dc = jnp.minimum(dst, A - 1)
    hi = jnp.take(h, sc, axis=0)          # (BE, H)
    hj = jnp.take(h, dc, axis=0)
    xi = jnp.take(pos, sc, axis=0)        # (BE, 3) f32
    xj = jnp.take(pos, dc, axis=0)
    diff = xi - xj
    d2f = jnp.sum(diff ** 2, axis=-1, keepdims=True)          # (BE, 1) f32
    z = (hi @ w0i_ref[...] + hj @ w0j_ref[...]
         + d2f.astype(cd) * w0d_ref[...] + b0_ref[...])       # (BE, H) cd
    zf = z.astype(jnp.float32)
    sig = jax.nn.sigmoid(zf)
    s = zf * sig                                              # silu(z), f32

    # --- dm: gather of g at the destination, zeroed on masked/pad edges.
    # Every downstream cotangent is a product with dm (or dz), so masked
    # edges contribute exact zeros everywhere below.
    valid = dst < A
    gm = jnp.take(g, dc, axis=0).astype(jnp.float32)          # (BE, H)
    dm = jnp.where(valid[:, None], gm, 0.0)

    w1f = w1_ref[...].astype(jnp.float32)
    ds = jax.lax.dot_general(dm, w1f, (((1,), (1,)), ((), ())))  # dm @ w1ᵀ
    dz = ds * (sig * (1.0 + zf * (1.0 - sig)))                # silu'(z)

    # --- node cotangents, scattered via the shared membership-matmul tile
    # (clamped indices always hit a real row; masked rows are exact zeros)
    w0if = w0i_ref[...].astype(jnp.float32)
    w0jf = w0j_ref[...].astype(jnp.float32)
    w0df = w0d_ref[...].astype(jnp.float32)                   # (1, H)
    dhi = jax.lax.dot_general(dz, w0if, (((1,), (1,)), ((), ())))
    dhj = jax.lax.dot_general(dz, w0jf, (((1,), (1,)), ((), ())))
    dd2 = jnp.sum(dz * w0df, axis=-1, keepdims=True)          # (BE, 1)
    ddiff = 2.0 * diff * dd2                                  # (BE, 3) = d xi
    accumulate_tile(sc, dhi, acc_dh, ib=0, bn=A)
    accumulate_tile(dc, dhj, acc_dh, ib=0, bn=A)
    accumulate_tile(sc, ddiff, acc_dpos, ib=0, bn=A)
    accumulate_tile(dc, -ddiff, acc_dpos, ib=0, bn=A)

    # --- φ_e weight cotangents: full reduction over every (b, je) tile
    hif = hi.astype(jnp.float32)
    hjf = hj.astype(jnp.float32)
    acc_w0i[...] += jax.lax.dot_general(hif, dz, (((0,), (0,)), ((), ())))
    acc_w0j[...] += jax.lax.dot_general(hjf, dz, (((0,), (0,)), ((), ())))
    acc_w0d[...] += jnp.sum(dz * d2f, axis=0, keepdims=True)
    acc_b0[...] += jnp.sum(dz, axis=0, keepdims=True)
    acc_w1[...] += jax.lax.dot_general(s, dm, (((0,), (0,)), ((), ())))
    acc_b1[...] += jnp.sum(dm, axis=0, keepdims=True)

    @pl.when(je == ne - 1)
    def _flush_batch():
        dh_ref[0] = acc_dh[...].astype(dh_ref.dtype)
        dpos_ref[0] = acc_dpos[...].astype(dpos_ref.dtype)

    @pl.when((b == nb - 1) & (je == ne - 1))
    def _flush_weights():
        dw0i_ref[...] = acc_w0i[...]
        dw0j_ref[...] = acc_w0j[...]
        dw0d_ref[...] = acc_w0d[...]
        db0_ref[...] = acc_b0[...]
        dw1_ref[...] = acc_w1[...]
        db1_ref[...] = acc_b1[...]


@functools.partial(jax.jit, static_argnames=("block_e", "interpret"))
def egnn_edge_fused_bwd(g, h, pos, src, dst, w0i, w0j, w0d, b0, w1, *,
                        block_e=256, interpret=None):
    """Fused backward. Inputs mirror ``egnn_edge_fused`` (same routed
    src/dst with the >= A pad sentinel) plus ``g``, the (B, A, H) cotangent
    of the aggregated output. The forward's edge-major intermediates are
    recomputed tile-by-tile in VMEM — no (B, E, 2H+1) concat or (B, E, H)
    message tensor ever lands in HBM.

    Returns ``(dh, dpos, dw0i, dw0j, dw0d, db0, dw1, db1)``:
    dh (B, A, H) in h.dtype; dpos (B, A, 3) f32; the φ_e cotangents in f32
    (split row blocks, biases as (1, H) rows — ``ops._edge_agg_bwd``
    reassembles the param dict and casts to the param dtypes)."""
    B, A, H = h.shape
    E = src.shape[1]
    be = min(block_e, E)
    ne = -(-E // be)
    if ne * be != E:
        pe = ne * be - E
        src = jnp.pad(src, ((0, 0), (0, pe)), constant_values=A)
        dst = jnp.pad(dst, ((0, 0), (0, pe)), constant_values=A)
    src = src.astype(jnp.int32)
    dst = dst.astype(jnp.int32)

    kern = functools.partial(_edge_bwd_kernel, nb=B, ne=ne)
    full = lambda s: pl.BlockSpec(s, lambda b, je: (0,) * len(s))
    out_shape = [
        jax.ShapeDtypeStruct((B, A, H), h.dtype),          # dh
        jax.ShapeDtypeStruct((B, A, 3), jnp.float32),      # dpos
        jax.ShapeDtypeStruct((H, H), jnp.float32),         # dw0i
        jax.ShapeDtypeStruct((H, H), jnp.float32),         # dw0j
        jax.ShapeDtypeStruct((1, H), jnp.float32),         # dw0d
        jax.ShapeDtypeStruct((1, H), jnp.float32),         # db0
        jax.ShapeDtypeStruct((H, H), jnp.float32),         # dw1
        jax.ShapeDtypeStruct((1, H), jnp.float32),         # db1
    ]
    return pl.pallas_call(
        kern,
        grid=(B, ne),
        in_specs=[
            pl.BlockSpec((1, be), lambda b, je: (b, je)),      # src
            pl.BlockSpec((1, be), lambda b, je: (b, je)),      # dst
            pl.BlockSpec((1, A, H), lambda b, je: (b, 0, 0)),  # h
            pl.BlockSpec((1, A, 3), lambda b, je: (b, 0, 0)),  # pos
            pl.BlockSpec((1, A, H), lambda b, je: (b, 0, 0)),  # g
            full(w0i.shape), full(w0j.shape), full(w0d.shape),
            full(b0.shape), full(w1.shape),
        ],
        out_specs=[
            pl.BlockSpec((1, A, H), lambda b, je: (b, 0, 0)),
            pl.BlockSpec((1, A, 3), lambda b, je: (b, 0, 0)),
            full((H, H)), full((H, H)), full((1, H)),
            full((1, H)), full((H, H)), full((1, H)),
        ],
        out_shape=out_shape,
        scratch_shapes=[
            pltpu.VMEM((A, H), jnp.float32),   # acc_dh
            pltpu.VMEM((A, 3), jnp.float32),   # acc_dpos
            pltpu.VMEM((H, H), jnp.float32),   # acc_w0i
            pltpu.VMEM((H, H), jnp.float32),   # acc_w0j
            pltpu.VMEM((1, H), jnp.float32),   # acc_w0d
            pltpu.VMEM((1, H), jnp.float32),   # acc_b0
            pltpu.VMEM((H, H), jnp.float32),   # acc_w1
            pltpu.VMEM((1, H), jnp.float32),   # acc_b1
        ],
        interpret=resolve_interpret(interpret),
    )(src, dst, h, pos, g, w0i, w0j, w0d, b0, w1)

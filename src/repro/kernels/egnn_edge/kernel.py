"""Fused EGNN edge-message Pallas kernel, H-blocked for paper widths.

One ``pallas_call`` computes, per edge block, the whole EGNN message hot
path that ``egnn_apply`` otherwise lowers as five separate HBM-bound ops:

    gather(h_i, h_j, x_i, x_j) -> d² -> φ_e MLP (2 dense + SiLU)
        -> masked segment-sum into node rows

Nothing edge-major ever round-trips to HBM: the ``(BE, 2H+1)`` concat input
of φ_e is never materialized (the first dense layer's weight is split into
its ``h_i`` / ``h_j`` / ``d²`` row blocks, so the concat-matmul becomes a sum
of three small matmuls), and the aggregation happens tile-by-tile in VMEM
via the membership-matmul trick of ``repro.kernels.segment_sum`` — no
``(B, E, A)`` one-hot tensor at the XLA level.

H-blocking (the paper-width enabler, H=866). A ``block_h`` grid dimension
tiles the φ_e *inner* hidden axis — fc0's output columns, which are also
fc1's contraction (K) rows. Per H-block ``j`` the kernel computes the full
slice ``z_j = h_i @ w0i[:, j] + h_j @ w0j[:, j] + d²·w0d[:, j] + b0[:, j]``
(the contraction over the input-H runs whole inside one matmul, so no z
accumulator is needed and the backward stays single-pass) and folds it
straight into fc1's K-split: ``m += silu(z_j) @ w1[j, :]``. VMEM residency
is therefore bounded by ``block_h·H`` weight tiles plus ``A·H``/``block_e·H``
node-sided rows — never by an ``(H, H)`` matrix. Tiling fc0's *input*-K
instead would bound the same bytes but make the backward two-phase (the
SiLU chain rule needs a complete z before any cotangent flows), which is
why the inner axis is the one that gets the grid dimension.

Forward grid: (B, num_edge_blocks, num_h_blocks) — h-blocks innermost so
the (block_e, H) f32 message row finishes before its single membership
matmul; edge blocks sequential above it accumulate the (A, H) node scratch,
flushed on the batch's last step.

Masked/pad edges arrive with ``dst >= A`` (routed by ``ops.egnn_edge_agg``)
and are excluded from the membership tile; their gather indices are clamped
so the loads stay in bounds. Ragged ``E % block_e`` is padded with the
sentinel; ragged ``H % block_h`` is padded with ZERO weight columns/rows —
``silu(0) @ 0-rows`` contributes exactly nothing, and the pad columns of
the weight-grad outputs are sliced away by the wrapper.

Backward (``egnn_edge_fused_bwd``) — residual-recompute contract: the
``custom_vjp`` saves ONLY the primal inputs (h, pos, src, dst, edge_mask,
φ_e); no edge-major intermediate survives the forward. Grid
(B, num_h_blocks, num_edge_blocks): per (graph, h-block), the edge sweep
re-gathers h_i/h_j/x_i/x_j, re-derives d², recomputes the φ_e fc0 slice
``z_j`` + SiLU in the compute dtype (bit-identical rounding to the forward
— same dot shape, same inputs), then runs the chain rule in f32 and emits:

  * ``d_h`` / ``d_x`` — masked scatter-transposes of the per-block
    cotangents back to BOTH endpoint rows (membership matmuls shared with
    ``repro.kernels.segment_sum.accumulate_tile``), accumulated in (A, H) /
    (A, 3) f32 scratch across the whole (h-block × edge-block) sweep and
    flushed once per graph;
  * φ_e grads — PER-H-BLOCK f32 reductions: the ``(H, block_h)`` /
    ``(block_h, H)`` accumulators flush at the end of each (graph, h-block)
    edge sweep into per-graph partial outputs (summed over B by the
    wrapper — B-partials, not (H, H) scratch, is what keeps the grad path
    inside the ``block_h`` budget).

Masked/pad edges produce exact zeros in every cotangent because ``dm`` (the
gather of the upstream cotangent) is zeroed before anything multiplies it.

VMEM budgets are not estimated here — ``budget.py`` is the itemized,
unit-tested model (``tests/test_egnn_budget.py``), and ``ops.py`` plans or
validates every (block_e, block_h) against it before calling these.

``interpret=None`` auto-detects the backend (compiled on TPU, interpreter
mode elsewhere — CPU CI validates numerics, not timing).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.egnn_edge.budget import check_blocks
from repro.kernels.segment_sum.kernel import accumulate_tile, resolve_interpret


def _pad_h_blocks(nh, bh, H, w0i, w0j, w0d, b0, w1):
    """Zero-pad the h-block-tiled weight axes (fc0 output columns, fc1 rows)
    up to ``nh*bh``. Zero pad columns give z_pad = 0, silu(0) = 0, and the
    matching w1 pad rows are zero too — pad blocks contribute exactly
    nothing in either direction."""
    ph = nh * bh - H
    if ph == 0:
        return w0i, w0j, w0d, b0, w1
    col = ((0, 0), (0, ph))
    return (jnp.pad(w0i, col), jnp.pad(w0j, col), jnp.pad(w0d, col),
            jnp.pad(b0, col), jnp.pad(w1, ((0, ph), (0, 0))))


def _gather_edge_tile(src, dst, h, pos):
    """Clamped endpoint gathers for one edge tile (pad edges load row A-1;
    masked out of every sum by the ``>= A`` sentinel downstream)."""
    A = h.shape[0]
    sc = jnp.minimum(src, A - 1)
    dc = jnp.minimum(dst, A - 1)
    hi = jnp.take(h, sc, axis=0)              # (BE, H)
    hj = jnp.take(h, dc, axis=0)
    xi = jnp.take(pos, sc, axis=0)            # (BE, 3) f32
    xj = jnp.take(pos, dc, axis=0)
    return sc, dc, hi, hj, xi - xj


def _edge_kernel(src_ref, dst_ref, h_ref, pos_ref, w0i_ref, w0j_ref, w0d_ref,
                 b0_ref, w1_ref, b1_ref, o_ref, m_acc, acc_ref, *, ne, nh):
    je = pl.program_id(1)   # edge block (sequential)
    jh = pl.program_id(2)   # h-block (sequential inner)

    @pl.when((je == 0) & (jh == 0))
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    src = src_ref[0]                      # (BE,) int32, >= A marks pad
    dst = dst_ref[0]
    h = h_ref[0]                          # (A, H) compute dtype
    pos = pos_ref[0].astype(jnp.float32)  # (A, 3)
    A = h.shape[0]
    cd = h.dtype

    _, _, hi, hj, diff = _gather_edge_tile(src, dst, h, pos)
    d2 = jnp.sum(diff ** 2, axis=-1, keepdims=True).astype(cd)  # (BE, 1)

    @pl.when(jh == 0)
    def _init_row():
        m_acc[...] = jnp.broadcast_to(
            b1_ref[...].astype(jnp.float32), m_acc.shape)

    # φ_e fc0, H-block slice j of the *virtual* concat [hi | hj | d2]: the
    # weight arrives pre-split into its three row blocks (no (BE, 2H+1)
    # tensor) and pre-tiled into its output columns (no (H, H) tile). The
    # input-H contraction runs whole inside this one matmul.
    z = (hi @ w0i_ref[...] + hj @ w0j_ref[...]
         + d2 * w0d_ref[...] + b0_ref[...])                   # (BE, bh) cd
    # fc1 K-split: fold this h-block straight into the f32 message row
    m_acc[...] += (jax.nn.silu(z) @ w1_ref[...]).astype(jnp.float32)

    # membership matmul (MXU): pad edges carry dst >= A, which matches no
    # node-id column (shared scatter-transpose tile with
    # repro.kernels.segment_sum)
    @pl.when(jh == nh - 1)
    def _aggregate():
        accumulate_tile(dst, m_acc[...], acc_ref, ib=0, bn=A)

    @pl.when((je == ne - 1) & (jh == nh - 1))
    def _flush():
        o_ref[0] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_e", "block_h",
                                             "interpret"))
def egnn_edge_fused(h, pos, src, dst, w0i, w0j, w0d, b0, w1, b1, *,
                    block_e=256, block_h=256, interpret=None):
    """Fused forward. h: (B, A, H) compute-dtype node features; pos:
    (B, A, 3); src/dst: (B, E) int32 with >= A marking masked/pad edges
    (route them before calling — see ``ops.egnn_edge_agg``); φ_e fc0 weight
    pre-split into w0i (H,H), w0j (H,H), w0d (1,H), plus b0 (1,H), fc1
    w1 (H,H), b1 (1,H). ``block_h`` tiles the φ_e inner hidden axis (see
    module docstring) — ``ops.py`` plans it from the VMEM budget model.
    Returns (B, A, H) aggregated messages."""
    B, A, H = h.shape
    E = src.shape[1]
    be = min(block_e, E)
    ne = -(-E // be)
    bh = min(block_h, H)
    nh = -(-H // bh)
    # defense in depth: ops plans blocks, but a direct caller's override
    # must never compile over-budget (trace-time raise, shapes are static)
    check_blocks(A, E, H, be, bh, itemsize=h.dtype.itemsize)
    if ne * be != E:
        pe = ne * be - E
        # pad sentinel A: matches no node id, contributes nothing
        src = jnp.pad(src, ((0, 0), (0, pe)), constant_values=A)
        dst = jnp.pad(dst, ((0, 0), (0, pe)), constant_values=A)
    src = src.astype(jnp.int32)
    dst = dst.astype(jnp.int32)
    w0i, w0j, w0d, b0, w1 = _pad_h_blocks(nh, bh, H, w0i, w0j, w0d, b0, w1)

    kern = functools.partial(_edge_kernel, ne=ne, nh=nh)
    return pl.pallas_call(
        kern,
        grid=(B, ne, nh),
        in_specs=[
            pl.BlockSpec((1, be), lambda b, je, jh: (b, je)),      # src
            pl.BlockSpec((1, be), lambda b, je, jh: (b, je)),      # dst
            pl.BlockSpec((1, A, H), lambda b, je, jh: (b, 0, 0)),  # h
            pl.BlockSpec((1, A, 3), lambda b, je, jh: (b, 0, 0)),  # pos
            pl.BlockSpec((H, bh), lambda b, je, jh: (0, jh)),      # w0i
            pl.BlockSpec((H, bh), lambda b, je, jh: (0, jh)),      # w0j
            pl.BlockSpec((1, bh), lambda b, je, jh: (0, jh)),      # w0d
            pl.BlockSpec((1, bh), lambda b, je, jh: (0, jh)),      # b0
            pl.BlockSpec((bh, H), lambda b, je, jh: (jh, 0)),      # w1
            pl.BlockSpec((1, H), lambda b, je, jh: (0, 0)),        # b1
        ],
        out_specs=pl.BlockSpec((1, A, H), lambda b, je, jh: (b, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, A, H), h.dtype),
        scratch_shapes=[pltpu.VMEM((be, H), jnp.float32),   # m_acc
                        pltpu.VMEM((A, H), jnp.float32)],   # node acc
        interpret=resolve_interpret(interpret),
    )(src, dst, h, pos, w0i, w0j, w0d, b0, w1, b1)


def _edge_bwd_kernel(src_ref, dst_ref, h_ref, pos_ref, g_ref,
                     w0i_ref, w0j_ref, w0d_ref, b0_ref, w1_ref,
                     dh_ref, dpos_ref, dw0i_ref, dw0j_ref, dw0d_ref,
                     db0_ref, dw1_ref, db1_ref,
                     acc_dh, acc_dpos, acc_w0i, acc_w0j, acc_w0d,
                     acc_b0, acc_w1, acc_b1, *, nb, ne, nh):
    b = pl.program_id(0)    # graph (outer)
    jh = pl.program_id(1)   # h-block (sequential middle)
    je = pl.program_id(2)   # edge block (sequential inner)

    @pl.when((jh == 0) & (je == 0))
    def _init_batch():
        acc_dh[...] = jnp.zeros_like(acc_dh)
        acc_dpos[...] = jnp.zeros_like(acc_dpos)

    @pl.when(je == 0)
    def _init_block_grads():
        # per-(graph, h-block) weight-grad accumulators: (H, bh)/(bh, H),
        # flushed into per-graph partials after this edge sweep — the
        # whole-H (H, H) scratch of the un-blocked kernel is gone
        acc_w0i[...] = jnp.zeros_like(acc_w0i)
        acc_w0j[...] = jnp.zeros_like(acc_w0j)
        acc_w0d[...] = jnp.zeros_like(acc_w0d)
        acc_b0[...] = jnp.zeros_like(acc_b0)
        acc_w1[...] = jnp.zeros_like(acc_w1)

    @pl.when((b == 0) & (jh == 0) & (je == 0))
    def _init_b1():
        acc_b1[...] = jnp.zeros_like(acc_b1)

    src = src_ref[0]                      # (BE,) int32, >= A marks pad
    dst = dst_ref[0]
    h = h_ref[0]                          # (A, H) compute dtype
    pos = pos_ref[0].astype(jnp.float32)  # (A, 3)
    g = g_ref[0]                          # (A, H) upstream cotangent
    A = h.shape[0]
    cd = h.dtype

    # --- recompute this h-block's forward residuals for this edge tile
    # (nothing was saved edge-major in HBM; see the residual-recompute
    # contract in the module docstring). z_j is recomputed in the compute
    # dtype — identical dot shape and rounding to the forward kernel —
    # then the chain rule runs in f32.
    sc, dc, hi, hj, diff = _gather_edge_tile(src, dst, h, pos)
    d2f = jnp.sum(diff ** 2, axis=-1, keepdims=True)          # (BE, 1) f32
    z = (hi @ w0i_ref[...] + hj @ w0j_ref[...]
         + d2f.astype(cd) * w0d_ref[...] + b0_ref[...])       # (BE, bh) cd
    zf = z.astype(jnp.float32)
    sig = jax.nn.sigmoid(zf)
    s = zf * sig                                              # silu(z), f32

    # --- dm: gather of g at the destination, zeroed on masked/pad edges.
    # Every downstream cotangent is a product with dm (or dz), so masked
    # edges contribute exact zeros everywhere below.
    valid = dst < A
    gm = jnp.take(g, dc, axis=0).astype(jnp.float32)          # (BE, H)
    dm = jnp.where(valid[:, None], gm, 0.0)

    w1f = w1_ref[...].astype(jnp.float32)                     # (bh, H)
    ds = jax.lax.dot_general(dm, w1f, (((1,), (1,)), ((), ())))  # (BE, bh)
    dz = ds * (sig * (1.0 + zf * (1.0 - sig)))                # silu'(z)

    # --- node cotangents: this h-block's slice of the chain, scattered via
    # the shared membership-matmul tile (clamped indices always hit a real
    # row; masked rows are exact zeros) and accumulated across ALL h-blocks
    # in the per-graph (A, H)/(A, 3) scratch
    w0if = w0i_ref[...].astype(jnp.float32)                   # (H, bh)
    w0jf = w0j_ref[...].astype(jnp.float32)
    w0df = w0d_ref[...].astype(jnp.float32)                   # (1, bh)
    dhi = jax.lax.dot_general(dz, w0if, (((1,), (1,)), ((), ())))  # (BE, H)
    dhj = jax.lax.dot_general(dz, w0jf, (((1,), (1,)), ((), ())))
    dd2 = jnp.sum(dz * w0df, axis=-1, keepdims=True)          # (BE, 1)
    ddiff = 2.0 * diff * dd2                                  # (BE, 3) = d xi
    accumulate_tile(sc, dhi, acc_dh, ib=0, bn=A)
    accumulate_tile(dc, dhj, acc_dh, ib=0, bn=A)
    accumulate_tile(sc, ddiff, acc_dpos, ib=0, bn=A)
    accumulate_tile(dc, -ddiff, acc_dpos, ib=0, bn=A)

    # --- φ_e weight cotangents, H-block slice: reduce over this edge tile
    hif = hi.astype(jnp.float32)
    hjf = hj.astype(jnp.float32)
    acc_w0i[...] += jax.lax.dot_general(hif, dz, (((0,), (0,)), ((), ())))
    acc_w0j[...] += jax.lax.dot_general(hjf, dz, (((0,), (0,)), ((), ())))
    acc_w0d[...] += jnp.sum(dz * d2f, axis=0, keepdims=True)
    acc_b0[...] += jnp.sum(dz, axis=0, keepdims=True)
    acc_w1[...] += jax.lax.dot_general(s, dm, (((0,), (0,)), ((), ())))

    @pl.when(jh == 0)
    def _acc_b1():
        # db1 = Σ dm is h-block-independent: reduce it exactly once
        acc_b1[...] += jnp.sum(dm, axis=0, keepdims=True)

    @pl.when(je == ne - 1)
    def _flush_block_grads():
        dw0i_ref[0] = acc_w0i[...]
        dw0j_ref[0] = acc_w0j[...]
        dw0d_ref[0] = acc_w0d[...]
        db0_ref[0] = acc_b0[...]
        dw1_ref[0] = acc_w1[...]

    @pl.when((jh == nh - 1) & (je == ne - 1))
    def _flush_batch():
        dh_ref[0] = acc_dh[...].astype(dh_ref.dtype)
        dpos_ref[0] = acc_dpos[...].astype(dpos_ref.dtype)

    @pl.when((b == nb - 1) & (jh == nh - 1) & (je == ne - 1))
    def _flush_b1():
        db1_ref[...] = acc_b1[...]


@functools.partial(jax.jit, static_argnames=("block_e", "block_h",
                                             "interpret"))
def egnn_edge_fused_bwd(g, h, pos, src, dst, w0i, w0j, w0d, b0, w1, *,
                        block_e=256, block_h=256, interpret=None):
    """Fused backward. Inputs mirror ``egnn_edge_fused`` (same routed
    src/dst with the >= A pad sentinel) plus ``g``, the (B, A, H) cotangent
    of the aggregated output. The forward's edge-major intermediates are
    recomputed H-block-by-H-block in VMEM — no (B, E, 2H+1) concat, no
    (B, E, H) message tensor, and no (H, H) weight-grad scratch.

    Returns ``(dh, dpos, dw0i, dw0j, dw0d, db0, dw1, db1)``:
    dh (B, A, H) in h.dtype; dpos (B, A, 3) f32; the φ_e cotangents in f32
    (split row blocks, biases as (1, H) rows — ``ops._edge_agg_bwd``
    reassembles the param dict and casts to the param dtypes). The kernel
    emits the weight grads as per-graph H-block partials; the trailing
    ``sum(axis=0)`` over B here is the only out-of-kernel reduction."""
    B, A, H = h.shape
    E = src.shape[1]
    be = min(block_e, E)
    ne = -(-E // be)
    bh = min(block_h, H)
    nh = -(-H // bh)
    check_blocks(A, E, H, be, bh, itemsize=h.dtype.itemsize)
    Hp = nh * bh
    if ne * be != E:
        pe = ne * be - E
        src = jnp.pad(src, ((0, 0), (0, pe)), constant_values=A)
        dst = jnp.pad(dst, ((0, 0), (0, pe)), constant_values=A)
    src = src.astype(jnp.int32)
    dst = dst.astype(jnp.int32)
    w0i, w0j, w0d, b0, w1 = _pad_h_blocks(nh, bh, H, w0i, w0j, w0d, b0, w1)

    kern = functools.partial(_edge_bwd_kernel, nb=B, ne=ne, nh=nh)
    out_shape = [
        jax.ShapeDtypeStruct((B, A, H), h.dtype),          # dh
        jax.ShapeDtypeStruct((B, A, 3), jnp.float32),      # dpos
        jax.ShapeDtypeStruct((B, H, Hp), jnp.float32),     # dw0i partials
        jax.ShapeDtypeStruct((B, H, Hp), jnp.float32),     # dw0j partials
        jax.ShapeDtypeStruct((B, 1, Hp), jnp.float32),     # dw0d partials
        jax.ShapeDtypeStruct((B, 1, Hp), jnp.float32),     # db0 partials
        jax.ShapeDtypeStruct((B, Hp, H), jnp.float32),     # dw1 partials
        jax.ShapeDtypeStruct((1, H), jnp.float32),         # db1
    ]
    dh, dpos, dw0i_p, dw0j_p, dw0d_p, db0_p, dw1_p, db1 = pl.pallas_call(
        kern,
        grid=(B, nh, ne),
        in_specs=[
            pl.BlockSpec((1, be), lambda b, jh, je: (b, je)),      # src
            pl.BlockSpec((1, be), lambda b, jh, je: (b, je)),      # dst
            pl.BlockSpec((1, A, H), lambda b, jh, je: (b, 0, 0)),  # h
            pl.BlockSpec((1, A, 3), lambda b, jh, je: (b, 0, 0)),  # pos
            pl.BlockSpec((1, A, H), lambda b, jh, je: (b, 0, 0)),  # g
            pl.BlockSpec((H, bh), lambda b, jh, je: (0, jh)),      # w0i
            pl.BlockSpec((H, bh), lambda b, jh, je: (0, jh)),      # w0j
            pl.BlockSpec((1, bh), lambda b, jh, je: (0, jh)),      # w0d
            pl.BlockSpec((1, bh), lambda b, jh, je: (0, jh)),      # b0
            pl.BlockSpec((bh, H), lambda b, jh, je: (jh, 0)),      # w1
        ],
        out_specs=[
            pl.BlockSpec((1, A, H), lambda b, jh, je: (b, 0, 0)),
            pl.BlockSpec((1, A, 3), lambda b, jh, je: (b, 0, 0)),
            pl.BlockSpec((1, H, bh), lambda b, jh, je: (b, 0, jh)),
            pl.BlockSpec((1, H, bh), lambda b, jh, je: (b, 0, jh)),
            pl.BlockSpec((1, 1, bh), lambda b, jh, je: (b, 0, jh)),
            pl.BlockSpec((1, 1, bh), lambda b, jh, je: (b, 0, jh)),
            pl.BlockSpec((1, bh, H), lambda b, jh, je: (b, jh, 0)),
            pl.BlockSpec((1, H), lambda b, jh, je: (0, 0)),
        ],
        out_shape=out_shape,
        scratch_shapes=[
            pltpu.VMEM((A, H), jnp.float32),    # acc_dh
            pltpu.VMEM((A, 3), jnp.float32),    # acc_dpos
            pltpu.VMEM((H, bh), jnp.float32),   # acc_w0i (per h-block)
            pltpu.VMEM((H, bh), jnp.float32),   # acc_w0j (per h-block)
            pltpu.VMEM((1, bh), jnp.float32),   # acc_w0d (per h-block)
            pltpu.VMEM((1, bh), jnp.float32),   # acc_b0  (per h-block)
            pltpu.VMEM((bh, H), jnp.float32),   # acc_w1  (per h-block)
            pltpu.VMEM((1, H), jnp.float32),    # acc_b1
        ],
        interpret=resolve_interpret(interpret),
    )(src, dst, h, pos, g, w0i, w0j, w0d, b0, w1)
    # sum the per-graph partials and drop the zero-padded h-block columns —
    # the only reduction that happens outside the kernel
    return (dh, dpos,
            dw0i_p.sum(axis=0)[:, :H], dw0j_p.sum(axis=0)[:, :H],
            dw0d_p.sum(axis=0)[:, :H], db0_p.sum(axis=0)[:, :H],
            dw1_p.sum(axis=0)[:H], db1)

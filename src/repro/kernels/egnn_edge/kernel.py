"""Fused EGNN edge-message Pallas kernel.

One ``pallas_call`` computes, per edge block, the whole EGNN message hot
path that ``egnn_apply`` otherwise lowers as five separate HBM-bound ops:

    gather(h_i, h_j, x_i, x_j) -> d² -> φ_e MLP (2 dense + SiLU)
        -> masked segment-sum into node rows

Nothing edge-major ever round-trips to HBM: the ``(BE, 2H+1)`` concat input
of φ_e is never materialized (the first dense layer's weight is split into
its ``h_i`` / ``h_j`` / ``d²`` row blocks, so the concat-matmul becomes a sum
of three small matmuls), and the aggregation happens tile-by-tile in VMEM
via the membership-matmul trick of ``repro.kernels.segment_sum`` — no
``(B, E, A)`` one-hot tensor at the XLA level.

Grid: (B, num_edge_blocks) — edge blocks are the sequential inner dim; a
VMEM f32 scratch holds the whole (A, H) node accumulator per graph (A is
small in this workload: padded structures, not monolithic graphs) and is
flushed on the last edge block.

VMEM budget at A=128, H=866, BE=256 (f32): node features 433 KiB, messages
866 KiB, membership tile 128 KiB, accumulator 433 KiB, φ_e weights ≈5.9 MiB
(2·H·H + H rows) — ≈7.8 MiB resident, within the ~16 MiB/core budget. For
H beyond ~1k the first dense's weight blocks would need a K-grid dimension.

Masked/pad edges arrive with ``dst >= A`` (routed by ``ops.egnn_edge_agg``)
and are excluded from the membership tile; their gather indices are clamped
so the loads stay in bounds.

``interpret=None`` auto-detects the backend (compiled on TPU, interpreter
mode elsewhere — CPU CI validates numerics, not timing).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.segment_sum.kernel import resolve_interpret


def _edge_kernel(src_ref, dst_ref, h_ref, pos_ref, w0i_ref, w0j_ref, w0d_ref,
                 b0_ref, w1_ref, b1_ref, o_ref, acc_ref, *, ne):
    je = pl.program_id(1)   # edge block (sequential)

    @pl.when(je == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    src = src_ref[0]                      # (BE,) int32, >= A marks pad
    dst = dst_ref[0]
    h = h_ref[0]                          # (A, H) compute dtype
    pos = pos_ref[0].astype(jnp.float32)  # (A, 3)
    A = h.shape[0]
    cd = h.dtype

    # clamped gathers (pad edges load row A-1; masked out of the sum below)
    sc = jnp.minimum(src, A - 1)
    dc = jnp.minimum(dst, A - 1)
    hi = jnp.take(h, sc, axis=0)          # (BE, H)
    hj = jnp.take(h, dc, axis=0)
    xi = jnp.take(pos, sc, axis=0)        # (BE, 3)
    xj = jnp.take(pos, dc, axis=0)
    d2 = jnp.sum((xi - xj) ** 2, axis=-1, keepdims=True).astype(cd)  # (BE,1)

    # φ_e fc0 over the *virtual* concat [hi | hj | d2]: the weight arrives
    # pre-split into its three row blocks, so no (BE, 2H+1) tensor exists
    z = (hi @ w0i_ref[...] + hj @ w0j_ref[...]
         + d2 * w0d_ref[...] + b0_ref[...])
    m = jax.nn.silu(z) @ w1_ref[...] + b1_ref[...]        # (BE, H)

    # masked membership matmul (MXU): pad edges contribute zero columns
    valid = dst < A
    node_ids = jax.lax.broadcasted_iota(jnp.int32, (dst.shape[0], A), 1)
    onehot = jnp.where(valid[:, None],
                       (dst[:, None] == node_ids).astype(jnp.float32), 0.0)
    acc_ref[...] += jax.lax.dot_general(
        onehot, m.astype(jnp.float32), (((0,), (0,)), ((), ())))

    @pl.when(je == ne - 1)
    def _flush():
        o_ref[0] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_e", "interpret"))
def egnn_edge_fused(h, pos, src, dst, w0i, w0j, w0d, b0, w1, b1, *,
                    block_e=256, interpret=None):
    """Fused forward. h: (B, A, H) compute-dtype node features; pos:
    (B, A, 3); src/dst: (B, E) int32 with >= A marking masked/pad edges
    (route them before calling — see ``ops.egnn_edge_agg``); φ_e fc0 weight
    pre-split into w0i (H,H), w0j (H,H), w0d (1,H), plus b0 (1,H), fc1
    w1 (H,H), b1 (1,H). Returns (B, A, H) aggregated messages."""
    B, A, H = h.shape
    E = src.shape[1]
    be = min(block_e, E)
    ne = -(-E // be)
    if ne * be != E:
        pe = ne * be - E
        # pad sentinel A: matches no node id, contributes nothing
        src = jnp.pad(src, ((0, 0), (0, pe)), constant_values=A)
        dst = jnp.pad(dst, ((0, 0), (0, pe)), constant_values=A)
    src = src.astype(jnp.int32)
    dst = dst.astype(jnp.int32)

    kern = functools.partial(_edge_kernel, ne=ne)
    full = lambda s: pl.BlockSpec(s, lambda b, je: (0,) * len(s))
    return pl.pallas_call(
        kern,
        grid=(B, ne),
        in_specs=[
            pl.BlockSpec((1, be), lambda b, je: (b, je)),      # src
            pl.BlockSpec((1, be), lambda b, je: (b, je)),      # dst
            pl.BlockSpec((1, A, H), lambda b, je: (b, 0, 0)),  # h
            pl.BlockSpec((1, A, 3), lambda b, je: (b, 0, 0)),  # pos
            full(w0i.shape), full(w0j.shape), full(w0d.shape),
            full(b0.shape), full(w1.shape), full(b1.shape),
        ],
        out_specs=pl.BlockSpec((1, A, H), lambda b, je: (b, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, A, H), h.dtype),
        scratch_shapes=[pltpu.VMEM((A, H), jnp.float32)],
        interpret=resolve_interpret(interpret),
    )(src, dst, h, pos, w0i, w0j, w0d, b0, w1, b1)

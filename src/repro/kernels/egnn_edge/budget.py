"""Explicit VMEM budget model for the fused EGNN edge kernels.

The fused forward/backward kernels (``kernel.py``) are H-blocked: a
``block_h`` grid dimension tiles the φ_e *inner* hidden axis (fc0's output
columns == fc1's contraction rows), so every (H, H) weight tile, the f32
weight-grad scratches, and the per-step dense intermediates are bounded by
``block_h · H`` bytes instead of ``H²``. What still scales with full H is
only the *node-sided* state (``A·H`` features/accumulators and ``block_e·H``
edge rows) — small for this workload's padded-structure batches.

This module is the single source of truth for what fits: an itemized,
unit-tested byte model of the resident set (``fwd_vmem_items`` /
``bwd_vmem_items``), a planner (``plan_blocks``) that NEVER emits an
over-budget ``(block_e, block_h)``, and a validator (``check_blocks``) that
raises ``VmemBudgetError`` on over-budget explicit overrides instead of
letting them silently compile and OOM on device.

Accounting rules (deliberately conservative):

  * every ``pallas_call`` input/output block counts TWICE — the Mosaic
    pipeline double-buffers block DMA;
  * scratch (``pltpu.VMEM``) counts once;
  * the large *live* jnp intermediates of one kernel step (gathered edge
    rows, the masked cotangent gather, the per-block dense products) are
    itemized too — Mosaic keeps them in VMEM between ops;
  * f32 unless the buffer holds compute-dtype values (``itemsize``).

The default budget is 16 MiB/core of physical VMEM minus 4 MiB headroom
for Mosaic spills, semaphores, and accounting slack (``VMEM_BUDGET``).
``tests/test_egnn_budget.py`` pins the model: planned configs are within
budget at paper widths (H ∈ {256, 512, 866}, A ∈ {64, 128}) and
over-budget overrides raise.
"""
from __future__ import annotations

VMEM_BYTES = 16 << 20          # physical VMEM per TPU core
VMEM_HEADROOM = 4 << 20        # Mosaic spills / semaphores / model slack
VMEM_BUDGET = VMEM_BYTES - VMEM_HEADROOM

_MIN_BLOCK = 8                 # sublane floor shared with autotune_blocks


class VmemBudgetError(ValueError):
    """An explicit (block_e, block_h) override exceeds the VMEM budget."""


def _clamp(block, dim):
    return max(1, min(block, dim))


def fwd_vmem_items(A: int, block_e: int, block_h: int, H: int, *,
                   itemsize: int = 4) -> dict:
    """Itemized resident bytes of one forward kernel step (grid (B, ne, nh)).

    ``itemsize`` is the compute dtype's width (4 = f32, 2 = bf16); masks,
    indices, positions, and every accumulator stay f32/int32."""
    be, bh = _clamp(block_e, 10 ** 9), _clamp(block_h, H)
    return {
        # --- double-buffered input blocks (×2) -----------------------------
        "in.src_dst": 2 * 2 * be * 4,
        "in.h": 2 * A * H * itemsize,
        "in.pos": 2 * A * 3 * 4,
        "in.w0_blocks": 2 * 2 * H * bh * itemsize,       # w0i + w0j (H, bh)
        "in.w0d_b0": 2 * 2 * bh * itemsize,              # (1, bh) rows
        "in.w1_block": 2 * bh * H * itemsize,            # (bh, H)
        "in.b1": 2 * H * itemsize,
        # --- double-buffered output block (×2) -----------------------------
        "out.o": 2 * A * H * itemsize,
        # --- scratch (×1) --------------------------------------------------
        "scratch.m_acc": be * H * 4,                     # f32 message row acc
        "scratch.node_acc": A * H * 4,                   # f32 (A, H)
        # --- live step intermediates --------------------------------------
        "live.hi_hj": 2 * be * H * itemsize,             # gathered endpoints
        "live.xi_xj_diff": 3 * be * 3 * 4,
        "live.z_silu": 2 * be * bh * itemsize,           # z_j + silu(z_j)
        "live.partial_m": be * H * 4,                    # (silu @ w1_blk) f32
    }


def bwd_vmem_items(A: int, block_e: int, block_h: int, H: int, *,
                   itemsize: int = 4) -> dict:
    """Itemized resident bytes of one backward kernel step (grid
    (B, nh, ne)). The weight-grad accumulators are PER-BLOCK (H·bh f32),
    flushed at the end of each (b, h-block) edge sweep — the old whole-H
    (H, H) scratches are exactly what this model exists to forbid."""
    be, bh = _clamp(block_e, 10 ** 9), _clamp(block_h, H)
    return {
        # --- double-buffered input blocks (×2) -----------------------------
        "in.src_dst": 2 * 2 * be * 4,
        "in.h": 2 * A * H * itemsize,
        "in.g": 2 * A * H * 4,                           # upstream cotangent
        "in.pos": 2 * A * 3 * 4,
        "in.w0_blocks": 2 * 2 * H * bh * itemsize,
        "in.w0d_b0": 2 * 2 * bh * itemsize,
        "in.w1_block": 2 * bh * H * itemsize,
        # --- double-buffered output blocks (×2) ----------------------------
        "out.dh": 2 * A * H * itemsize,
        "out.dpos": 2 * A * 3 * 4,
        "out.dw0_blocks": 2 * 2 * H * bh * 4,            # per-(b, j) partials
        "out.dw1_block": 2 * bh * H * 4,
        "out.rows": 2 * (2 * bh + H) * 4,                # dw0d, db0, db1
        # --- scratch (×1) --------------------------------------------------
        "scratch.node_acc": A * (H + 3) * 4,             # acc_dh + acc_dpos
        "scratch.w0_grad": 2 * H * bh * 4,               # acc_w0i + acc_w0j
        "scratch.w1_grad": bh * H * 4,
        "scratch.rows": (2 * bh + H) * 4,
        # --- live step intermediates --------------------------------------
        "live.hi_hj": 2 * be * H * itemsize,
        "live.xi_xj_diff": 3 * be * 3 * 4,
        "live.dm": be * H * 4,                           # masked g gather
        "live.dhi_dhj": 2 * be * H * 4,                  # dz_j @ w0ᵀ rows
        "live.z_chain": 4 * be * bh * 4,                 # z/s/ds/dz f32
    }


def vmem_bytes(A: int, block_e: int, block_h: int, H: int, *,
               itemsize: int = 4) -> int:
    """Worst-direction resident bytes — the custom_vjp pins ONE
    (block_e, block_h) into both directions, so the plan must satisfy the
    larger (backward) set."""
    kw = dict(itemsize=itemsize)
    return max(sum(fwd_vmem_items(A, block_e, block_h, H, **kw).values()),
               sum(bwd_vmem_items(A, block_e, block_h, H, **kw).values()))


def check_blocks(A: int, E: int, H: int, block_e: int, block_h: int, *,
                 itemsize: int = 4, vmem_limit: int = VMEM_BUDGET) -> None:
    """Raise ``VmemBudgetError`` if an explicit (block_e, block_h) override
    exceeds the budget — never let an over-budget config silently compile."""
    be, bh = _clamp(block_e, E), _clamp(block_h, H)
    need = vmem_bytes(A, be, bh, H, itemsize=itemsize)
    if need > vmem_limit:
        raise VmemBudgetError(
            f"egnn_edge block override (block_e={block_e}, block_h={block_h}) "
            f"needs ≈{need / 2 ** 20:.1f} MiB of VMEM at (A={A}, E={E}, "
            f"H={H}, itemsize={itemsize}) — over the {vmem_limit / 2 ** 20:.1f}"
            f" MiB budget. Shrink the blocks (plan_blocks(A, E, H) suggests "
            f"{plan_blocks(A, E, H, itemsize=itemsize, vmem_limit=vmem_limit)}"
            f") or raise vmem_limit if the target core really has more VMEM.")


def plan_blocks(A: int, E: int, H: int, *, itemsize: int = 4,
                vmem_limit: int = VMEM_BUDGET) -> tuple[int, int]:
    """Plan ``(block_e, block_h)`` for the fused kernels: start from the
    MXU-native 256-row tiles (clamped to the problem) and halve — ``block_h``
    first, since the ``block_h·H`` weight tiles dominate at paper widths —
    until the modeled resident set fits. Never returns an over-budget
    config; raises ``VmemBudgetError`` if even the floor (8, 8) does not
    fit (then the problem needs an A/H split this kernel doesn't have)."""
    be = max(_MIN_BLOCK, min(256, E))
    bh = max(_MIN_BLOCK, min(256, H))
    while vmem_bytes(A, be, bh, H, itemsize=itemsize) > vmem_limit:
        if bh > _MIN_BLOCK and bh >= be:
            bh = max(_MIN_BLOCK, bh // 2)
        elif be > _MIN_BLOCK:
            be = max(_MIN_BLOCK, be // 2)
        else:
            raise VmemBudgetError(
                f"no (block_e, block_h) fits (A={A}, E={E}, H={H}, "
                f"itemsize={itemsize}) in {vmem_limit / 2 ** 20:.1f} MiB — "
                f"the A·H node state alone exceeds the budget; this shape "
                f"needs a node-dimension split.")
    return be, bh

"""Public entry for the fused EGNN edge kernel, forward and backward.

``egnn_edge_agg`` runs the fused Pallas forward (one kernel for gather ->
d² -> φ_e -> masked segment-sum) and carries a ``jax.custom_vjp`` whose
backward is the fused Pallas backward kernel (``kernel.egnn_edge_fused_bwd``):
it recomputes the edge-major residuals tile-by-tile from the saved INPUTS
(h, pos, src, dst, edge_mask) and emits d_h / d_x / φ_e weight cotangents
without materializing the (B, E, 2H+1) concat or the (B, E, H) message
tensor in HBM — so ``impl="fused"`` trains with the same memory profile it
infers with. The pure-jnp reference (``ref.py``) remains the parity oracle
for both directions (tests/test_hotpath.py, tests/test_egnn_paper_shape.py).

Block planning: every call resolves ``(block_e, block_h)`` against the
itemized VMEM budget model in ``budget.py`` — ``None`` means "plan it"
(``plan_blocks`` never emits an over-budget config, which is what lets the
fused path run at the paper width H=866), and explicit overrides are
validated (``VmemBudgetError`` instead of silently compiling a config that
cannot fit a TPU core's VMEM).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .budget import check_blocks, plan_blocks
from .kernel import egnn_edge_fused, egnn_edge_fused_bwd


def _split_phi_e(phi_e, H, cd):
    """fc0 weight (2H+1, H) -> its h_i / h_j / d² row blocks (biases to
    (1, H) rows for lane-aligned VMEM tiles)."""
    w0 = phi_e["fc0"]["w"].astype(cd)
    assert w0.shape[0] == 2 * H + 1, \
        f"phi_e fc0 expects (2H+1, H)={2 * H + 1}, got {w0.shape}"
    return (w0[:H], w0[H:2 * H], w0[2 * H:],
            phi_e["fc0"]["b"].astype(cd)[None, :],
            phi_e["fc1"]["w"].astype(cd),
            phi_e["fc1"]["b"].astype(cd)[None, :])


def _resolve_blocks(block_e, block_h, A, E, H):
    """Plan-or-validate ``(block_e, block_h)`` against the VMEM budget
    model. The resolved pair is pinned into the custom_vjp static for BOTH
    directions, so the model's worst-direction (backward) resident set is
    what gets budgeted (``budget.vmem_bytes``). Explicit overrides that
    exceed the budget raise ``VmemBudgetError`` — never silently compile."""
    if block_e and block_h:
        check_blocks(A, E, H, block_e, block_h)
        return block_e, block_h
    pe, ph = plan_blocks(A, E, H)
    be, bh = block_e or pe, block_h or ph
    if block_e or block_h:          # one side overridden: re-validate the mix
        check_blocks(A, E, H, be, bh)
    return be, bh


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _edge_agg(static, h, pos, src, dst, edge_mask, phi_e):
    compute_dtype, block_e, block_h, interpret = static
    cd = compute_dtype or h.dtype
    H = h.shape[-1]
    A = h.shape[1]
    w0i, w0j, w0d, b0, w1, b1 = _split_phi_e(phi_e, H, cd)
    # masked edges -> sentinel A (excluded from the membership tile)
    sr = jnp.where(edge_mask, src, A)
    dr = jnp.where(edge_mask, dst, A)
    return egnn_edge_fused(h.astype(cd), pos, sr, dr,
                           w0i, w0j, w0d, b0, w1, b1,
                           block_e=block_e, block_h=block_h,
                           interpret=interpret)


def _edge_agg_fwd(static, h, pos, src, dst, edge_mask, phi_e):
    out = _edge_agg(static, h, pos, src, dst, edge_mask, phi_e)
    # residuals are the primal INPUTS only — every edge-major intermediate
    # is recomputed inside the backward kernel (see module docstring)
    return out, (h, pos, src, dst, edge_mask, phi_e)


def _edge_agg_bwd(static, res, g):
    compute_dtype, block_e, block_h, interpret = static
    h, pos, src, dst, edge_mask, phi_e = res
    cd = compute_dtype or h.dtype
    H = h.shape[-1]
    A = h.shape[1]
    w0i, w0j, w0d, b0, w1, _ = _split_phi_e(phi_e, H, cd)
    sr = jnp.where(edge_mask, src, A)
    dr = jnp.where(edge_mask, dst, A)
    dh, dpos, dw0i, dw0j, dw0d, db0, dw1, db1 = egnn_edge_fused_bwd(
        g, h.astype(cd), pos, sr, dr, w0i, w0j, w0d, b0, w1,
        block_e=block_e, block_h=block_h, interpret=interpret)
    f0, f1 = phi_e["fc0"], phi_e["fc1"]
    dphi = {
        "fc0": {"w": jnp.concatenate([dw0i, dw0j, dw0d],
                                     axis=0).astype(f0["w"].dtype),
                "b": db0[0].astype(f0["b"].dtype)},
        "fc1": {"w": dw1.astype(f1["w"].dtype),
                "b": db1[0].astype(f1["b"].dtype)},
    }
    return dh.astype(h.dtype), dpos.astype(pos.dtype), None, None, None, dphi


_edge_agg.defvjp(_edge_agg_fwd, _edge_agg_bwd)


def egnn_edge_agg(h, pos, src, dst, edge_mask, phi_e, *, compute_dtype=None,
                  block_e=None, block_h=None, interpret=None):
    """Fused EGNN message + aggregation: (B, A, H) node features in,
    (B, A, H) aggregated messages out. Drop-in for the unfused
    gather/φ_e/segment-sum sequence in ``egnn_apply`` (numerics: ``ref.py``),
    differentiable end-to-end via the fused backward kernel.
    ``block_e``/``block_h``: None plans against the VMEM budget model
    (``cfg.kernel_block_e`` / ``cfg.kernel_block_h`` override via
    ``egnn_apply``; over-budget overrides raise ``budget.VmemBudgetError``);
    ``interpret=None`` auto-detects the backend."""
    block_e, block_h = _resolve_blocks(block_e, block_h, h.shape[1],
                                       src.shape[1], h.shape[-1])
    static = (compute_dtype, block_e, block_h, interpret)
    return _edge_agg(static, h, pos, src, dst, edge_mask, phi_e)

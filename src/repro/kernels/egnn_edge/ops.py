"""Public entry for the fused EGNN edge kernel, with a training-safe VJP.

``egnn_edge_agg`` runs the fused Pallas forward (one kernel for gather ->
d² -> φ_e -> masked segment-sum) and carries a ``jax.custom_vjp`` whose
backward differentiates the pure-jnp reference (``ref.py``) — the standard
fused-forward / recompute-backward pattern, so ``impl="fused"`` is usable
inside ``jax.grad`` train steps without a hand-written backward kernel.
(A fused backward kernel is the obvious follow-up once the forward is
profiled on real TPUs.)
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import egnn_edge_fused
from .ref import egnn_edge_agg_ref


def _split_phi_e(phi_e, H, cd):
    """fc0 weight (2H+1, H) -> its h_i / h_j / d² row blocks (biases to
    (1, H) rows for lane-aligned VMEM tiles)."""
    w0 = phi_e["fc0"]["w"].astype(cd)
    assert w0.shape[0] == 2 * H + 1, \
        f"phi_e fc0 expects (2H+1, H)={2 * H + 1}, got {w0.shape}"
    return (w0[:H], w0[H:2 * H], w0[2 * H:],
            phi_e["fc0"]["b"].astype(cd)[None, :],
            phi_e["fc1"]["w"].astype(cd),
            phi_e["fc1"]["b"].astype(cd)[None, :])


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _edge_agg(static, h, pos, src, dst, edge_mask, phi_e):
    compute_dtype, block_e, interpret = static
    cd = compute_dtype or h.dtype
    H = h.shape[-1]
    A = h.shape[1]
    w0i, w0j, w0d, b0, w1, b1 = _split_phi_e(phi_e, H, cd)
    # masked edges -> sentinel A (excluded from the membership tile)
    sr = jnp.where(edge_mask, src, A)
    dr = jnp.where(edge_mask, dst, A)
    return egnn_edge_fused(h.astype(cd), pos, sr, dr,
                           w0i, w0j, w0d, b0, w1, b1,
                           block_e=block_e, interpret=interpret)


def _edge_agg_fwd(static, h, pos, src, dst, edge_mask, phi_e):
    out = _edge_agg(static, h, pos, src, dst, edge_mask, phi_e)
    return out, (h, pos, src, dst, edge_mask, phi_e)


def _edge_agg_bwd(static, res, g):
    compute_dtype = static[0]
    h, pos, src, dst, edge_mask, phi_e = res
    _, vjp = jax.vjp(
        lambda hh, pp, ww: egnn_edge_agg_ref(
            hh, pp, src, dst, edge_mask, ww, compute_dtype=compute_dtype),
        h, pos, phi_e)
    dh, dpos, dphi = vjp(g)
    return dh, dpos, None, None, None, dphi


_edge_agg.defvjp(_edge_agg_fwd, _edge_agg_bwd)


def egnn_edge_agg(h, pos, src, dst, edge_mask, phi_e, *, compute_dtype=None,
                  block_e=256, interpret=None):
    """Fused EGNN message + aggregation: (B, A, H) node features in,
    (B, A, H) aggregated messages out. Drop-in for the unfused
    gather/φ_e/segment-sum sequence in ``egnn_apply`` (numerics: ``ref.py``).
    ``interpret=None`` auto-detects the backend."""
    static = (compute_dtype, block_e, interpret)
    return _edge_agg(static, h, pos, src, dst, edge_mask, phi_e)

"""Pure-jnp oracle for the fused EGNN edge kernel.

Exactly the unfused message hot path of ``repro.models.gnn.egnn_apply``
(gather -> d² -> φ_e via ``mlp_apply`` on the materialized concat ->
scatter segment-sum), so kernel-vs-ref parity is also kernel-vs-model
parity. ``jax.grad`` through this function is likewise the oracle for the
fused BACKWARD kernel (``kernel.egnn_edge_fused_bwd``): the custom_vjp in
``ops.py`` must match it within tolerance in every cotangent
(tests/test_hotpath.py paper-shape parity suite)."""
from __future__ import annotations

import jax.numpy as jnp

from repro.models.mlp import mlp_apply


def egnn_edge_agg_ref(h, pos, src, dst, edge_mask, phi_e, *,
                      compute_dtype=None):
    """h: (B, A, H); pos: (B, A, 3); src/dst: (B, E); edge_mask: (B, E);
    phi_e: 2-layer MLP params ({"fc0": {w,b}, "fc1": {w,b}}).
    Returns (B, A, H) aggregated messages."""
    cd = compute_dtype or h.dtype
    B, A, H = h.shape

    def gather(x, idx):
        return jnp.take_along_axis(x, idx[..., None], axis=1)

    sc = jnp.minimum(src, A - 1)
    dc = jnp.minimum(dst, A - 1)
    hi = gather(h, sc)
    hj = gather(h, dc)
    xi = gather(pos.astype(jnp.float32), sc)
    xj = gather(pos.astype(jnp.float32), dc)
    d2 = jnp.sum((xi - xj) ** 2, -1, keepdims=True).astype(cd)
    m = mlp_apply(phi_e, jnp.concatenate([hi, hj, d2], -1), "silu", cd)
    m = jnp.where(edge_mask[..., None], m, 0.0)
    d = jnp.where(edge_mask, dst, A)
    out = jnp.zeros((B, A, H), m.dtype)
    return out.at[jnp.arange(B)[:, None], d].add(m, mode="drop")

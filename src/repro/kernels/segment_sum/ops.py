"""jit'd public wrapper for batched graph segment-sum."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernel import segment_sum_2d


def segment_sum(messages, dst, n_nodes: int, *, edge_mask=None,
                block_n=128, block_e=256, interpret=True):
    """messages: (B,E,F); dst: (B,E) -> (B,n_nodes,F). Masked edges are
    routed to an out-of-range sentinel so they contribute nothing."""
    if edge_mask is not None:
        dst = jnp.where(edge_mask, dst, n_nodes + 1)
    fn = lambda m, d: segment_sum_2d(m, d, n_nodes, block_n=block_n,
                                     block_e=block_e, interpret=interpret)
    return jax.vmap(fn)(messages, dst)

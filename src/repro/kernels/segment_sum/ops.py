"""jit'd public wrapper for graph segment-sum (batched or single-graph).

Batched ``(B, E, F)`` input goes through ``segment_sum_batched`` (B as a
leading grid dimension); unbatched ``(E, F)`` input through
``segment_sum_2d``. Masked edges are routed to an out-of-range destination
sentinel so they contribute nothing (the kernel's pad-sentinel contract —
see ``kernel.py``). ``interpret=None`` auto-detects the backend: compiled on
TPU, interpreter mode elsewhere.
"""
from __future__ import annotations

import jax.numpy as jnp

from .kernel import autotune_blocks, segment_sum_2d, segment_sum_batched


def segment_sum(messages, dst, n_nodes: int, *, edge_mask=None,
                block_n=None, block_e=None, interpret=None):
    """messages: (B,E,F) or (E,F); dst: (B,E) or (E,) -> (B,n_nodes,F) or
    (n_nodes,F). ``block_n``/``block_e`` default to the ``autotune_blocks``
    heuristic; pass explicit values (e.g. the ``kernel_block_*`` config
    knobs) to override."""
    if messages.ndim not in (2, 3):
        raise ValueError(f"messages must be (E,F) or (B,E,F), got "
                         f"ndim={messages.ndim}")
    E, F = messages.shape[-2], messages.shape[-1]
    auto_n, auto_e = autotune_blocks(n_nodes, E, F)
    block_n = block_n or auto_n
    block_e = block_e or auto_e
    if edge_mask is not None:
        # n_nodes is >= every valid id and lands on a discarded padded row
        # (or matches nothing) inside the kernel — see sentinel contract
        dst = jnp.where(edge_mask, dst, n_nodes)
    if messages.ndim == 3:
        return segment_sum_batched(messages, dst, n_nodes, block_n=block_n,
                                   block_e=block_e, interpret=interpret)
    return segment_sum_2d(messages, dst, n_nodes, block_n=block_n,
                          block_e=block_e, interpret=interpret)

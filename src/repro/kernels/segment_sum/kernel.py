"""Segment-sum Pallas TPU kernel — the MPNN aggregation hot spot.

GPU frameworks implement scatter-add with atomics; TPU has none, so the
operation is re-thought for the MXU (the DESIGN.md "adapt, don't port" item):
tile (edges x nodes), build the one-hot membership tile in VMEM from the
destination-index block, and accumulate ``one_hotᵀ @ messages`` as a matmul.

Two entry points:

  * ``segment_sum_2d``      — one graph: (E, F) messages -> (n_nodes, F);
  * ``segment_sum_batched`` — padded graph batches: (B, E, F) -> (B, A, F)
    with the batch as the leading (parallel) grid dimension. This is what
    ``repro.models.gnn.segment_sum_nodes`` feeds; it replaces the old
    ``vmap(segment_sum_2d)`` lowering, which re-traced the kernel under the
    batching rule instead of expressing B as a grid axis.

Grid: (num_node_blocks, num_edge_blocks) — edge blocks are the sequential
inner dim; a VMEM f32 scratch accumulates the (BN, F) node tile and is
flushed on the last edge block. The batched kernel prepends B to the grid.

Pad-edge sentinel contract: edges whose destination must not contribute
(ragged-E padding added here, or masked edges routed by ``ops.segment_sum``)
carry a ``dst`` value ``>= n_nodes``. The kernel compares ``dst`` against
node ids ``0 .. num_node_blocks*BN - 1``; because the output is padded up to
``num_node_blocks*BN >= n_nodes`` rows and then sliced back to ``n_nodes``,
any ``dst`` in ``[n_nodes, num_node_blocks*BN)`` lands on a padded row that
is discarded, and any ``dst >= num_node_blocks*BN`` matches no row at all.
The internal ragged-E pad sentinel is ``num_node_blocks*BN + 1`` — strictly
above every node id a tile can generate (asserted below, not assumed).

VMEM budget at BN=128, BE=256, F=896: membership tile (256x128 f32) 128 KiB,
message tile (256x896 f32) 896 KiB, accumulator (128x896 f32) 448 KiB —
≈1.5 MiB resident.

``interpret=None`` (the default) auto-detects: the kernel runs compiled on
TPU backends and falls back to interpreter mode everywhere else (CPU CI).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def resolve_interpret(interpret) -> bool:
    """None -> interpret only off-TPU (compiled Mosaic path on TPU)."""
    if interpret is None:
        return jax.default_backend() != "tpu"
    return bool(interpret)


def _block_geometry(n_nodes: int, E: int, block_n: int, block_e: int):
    """Clamp block sizes to the problem (explicitly — a ``block_n`` larger
    than ``n_nodes`` would otherwise pad every node tile with dead rows, and
    a ``block_e`` larger than ``E`` would pad every edge tile) and derive
    block counts + the ragged-E pad sentinel."""
    if block_n < 1 or block_e < 1:
        raise ValueError(f"block sizes must be >= 1, got block_n={block_n}, "
                         f"block_e={block_e}")
    bn = min(block_n, n_nodes)
    be = min(block_e, E)
    nb, ne = -(-n_nodes // bn), -(-E // be)
    sentinel = nb * bn + 1
    # the one-hot tile compares dst against node ids 0 .. nb*bn - 1; the
    # sentinel must exceed ALL of them or a pad edge would alias a real node
    assert sentinel > nb * bn - 1 and nb * bn >= n_nodes, \
        (sentinel, nb, bn, n_nodes)
    return bn, be, nb, ne, sentinel


def accumulate_tile(dst, msg, acc_ref, *, ib, bn):
    """One (edge-block x node-block) scatter-transpose tile: membership
    one-hot as an MXU matmul (``one_hotᵀ @ msg``), accumulated into the f32
    scratch. This is the shared TPU replacement for scatter-add — used by
    both segment-sum entry points here and by the fused EGNN edge kernel's
    forward aggregation and backward ``d_h``/``d_x`` scatters
    (``repro.kernels.egnn_edge``). Masking is by index, per the sentinel
    contract: any ``dst`` outside this tile's ``ib*bn .. ib*bn+bn-1`` id
    range matches no one-hot column and contributes nothing."""
    node_ids = ib * bn + jax.lax.broadcasted_iota(
        jnp.int32, (dst.shape[0], bn), 1)
    onehot = (dst[:, None] == node_ids).astype(jnp.float32)   # (BE, BN)
    acc_ref[...] += jax.lax.dot_general(
        onehot, msg, (((0,), (0,)), ((), ())))


_accumulate_tile = accumulate_tile  # back-compat alias


def autotune_blocks(n_nodes: int, E: int, F: int, *, extra_bytes: int = 0,
                    vmem_limit: int = 8 << 20) -> tuple[int, int]:
    """Heuristic (block_n, block_e) for the membership-matmul kernels: start
    from the MXU-native 128x256 tile and halve ``block_e`` until the resident
    f32 working set (node accumulator + message tile + membership tile, plus
    ``extra_bytes`` for caller-resident buffers such as the fused kernel's
    φ_e weights) fits the VMEM budget. Callers override via the
    ``kernel_block_n`` / ``kernel_block_e`` config knobs
    (``repro.configs.base.ArchConfig``)."""
    bn = max(8, min(128, n_nodes))
    be = max(8, min(256, E))

    def resident():
        return extra_bytes + 4 * (bn * F + be * F + be * bn)

    while be > 8 and resident() > vmem_limit:
        be //= 2
    # never emit an over-budget config: once the edge tile hits the sublane
    # floor, keep shrinking the node tile (wide-F problems otherwise sail
    # past the budget with be pinned at 8)
    while bn > 8 and resident() > vmem_limit:
        bn //= 2
    return bn, be


def _ss_kernel(dst_ref, msg_ref, o_ref, acc_ref, *, bn, ne):
    ib = pl.program_id(0)   # node block
    je = pl.program_id(1)   # edge block (sequential)

    @pl.when(je == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    _accumulate_tile(dst_ref[...], msg_ref[...].astype(jnp.float32),
                     acc_ref, ib=ib, bn=bn)

    @pl.when(je == ne - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("n_nodes", "block_n", "block_e",
                                             "interpret"))
def segment_sum_2d(messages, dst, n_nodes: int, *, block_n=128, block_e=256,
                   interpret=None):
    """messages: (E, F); dst: (E,) int32 in [0, n_nodes) or >= n_nodes for
    masked/pad edges (see the sentinel contract in the module docstring).
    Returns (n_nodes, F)."""
    E, F = messages.shape
    bn, be, nb, ne, sentinel = _block_geometry(n_nodes, E, block_n, block_e)
    if ne * be != E:
        pe = ne * be - E
        messages = jnp.pad(messages, ((0, pe), (0, 0)))
        dst = jnp.pad(dst, (0, pe), constant_values=sentinel)
    dst = dst.astype(jnp.int32)

    kern = functools.partial(_ss_kernel, bn=bn, ne=ne)
    out = pl.pallas_call(
        kern,
        grid=(nb, ne),
        in_specs=[
            pl.BlockSpec((be,), lambda ib, je: (je,)),
            pl.BlockSpec((be, F), lambda ib, je: (je, 0)),
        ],
        out_specs=pl.BlockSpec((bn, F), lambda ib, je: (ib, 0)),
        out_shape=jax.ShapeDtypeStruct((nb * bn, F), messages.dtype),
        scratch_shapes=[pltpu.VMEM((bn, F), jnp.float32)],
        interpret=resolve_interpret(interpret),
    )(dst, messages)
    return out[:n_nodes]


def _ss_batched_kernel(dst_ref, msg_ref, o_ref, acc_ref, *, bn, ne):
    ib = pl.program_id(1)   # node block
    je = pl.program_id(2)   # edge block (sequential inner dim)

    @pl.when(je == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    _accumulate_tile(dst_ref[0], msg_ref[0].astype(jnp.float32),
                     acc_ref, ib=ib, bn=bn)

    @pl.when(je == ne - 1)
    def _flush():
        o_ref[0] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("n_nodes", "block_n", "block_e",
                                             "interpret"))
def segment_sum_batched(messages, dst, n_nodes: int, *, block_n=128,
                        block_e=256, interpret=None):
    """messages: (B, E, F); dst: (B, E) int32 in [0, n_nodes) or >= n_nodes
    for masked/pad edges. Returns (B, n_nodes, F). B is the leading
    (parallel) grid dimension — each graph reuses the same node/edge tiling
    as ``segment_sum_2d``."""
    B, E, F = messages.shape
    bn, be, nb, ne, sentinel = _block_geometry(n_nodes, E, block_n, block_e)
    if ne * be != E:
        pe = ne * be - E
        messages = jnp.pad(messages, ((0, 0), (0, pe), (0, 0)))
        dst = jnp.pad(dst, ((0, 0), (0, pe)), constant_values=sentinel)
    dst = dst.astype(jnp.int32)

    kern = functools.partial(_ss_batched_kernel, bn=bn, ne=ne)
    out = pl.pallas_call(
        kern,
        grid=(B, nb, ne),
        in_specs=[
            pl.BlockSpec((1, be), lambda b, ib, je: (b, je)),
            pl.BlockSpec((1, be, F), lambda b, ib, je: (b, je, 0)),
        ],
        out_specs=pl.BlockSpec((1, bn, F), lambda b, ib, je: (b, ib, 0)),
        out_shape=jax.ShapeDtypeStruct((B, nb * bn, F), messages.dtype),
        scratch_shapes=[pltpu.VMEM((bn, F), jnp.float32)],
        interpret=resolve_interpret(interpret),
    )(dst, messages)
    return out[:, :n_nodes]

"""Segment-sum Pallas TPU kernel — the MPNN aggregation hot spot.

GPU frameworks implement scatter-add with atomics; TPU has none, so the
operation is re-thought for the MXU (the DESIGN.md "adapt, don't port" item):
tile (edges x nodes), build the one-hot membership tile in VMEM from the
destination-index block, and accumulate ``one_hotᵀ @ messages`` as a matmul.

Grid: (num_node_blocks, num_edge_blocks) — edge blocks are the sequential
inner dim; a VMEM f32 scratch accumulates the (BN, F) node tile and is
flushed on the last edge block.

VMEM budget at BN=128, BE=256, F=896: membership tile (256x128 f32) 128 KiB,
message tile (256x896 f32) 896 KiB, accumulator (128x896 f32) 448 KiB —
≈1.5 MiB resident.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ss_kernel(dst_ref, msg_ref, o_ref, acc_ref, *, bn, ne):
    ib = pl.program_id(0)   # node block
    je = pl.program_id(1)   # edge block (sequential)

    @pl.when(je == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    dst = dst_ref[...]                                   # (BE,) int32
    msg = msg_ref[...].astype(jnp.float32)               # (BE, F)
    node_ids = ib * bn + jax.lax.broadcasted_iota(jnp.int32, (dst.shape[0], bn), 1)
    onehot = (dst[:, None] == node_ids).astype(jnp.float32)   # (BE, BN)
    acc_ref[...] += jax.lax.dot_general(onehot, msg, (((0,), (0,)), ((), ())))

    @pl.when(je == ne - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("n_nodes", "block_n", "block_e",
                                             "interpret"))
def segment_sum_2d(messages, dst, n_nodes: int, *, block_n=128, block_e=256,
                   interpret=True):
    """messages: (E, F); dst: (E,) int32 in [0, n_nodes) or >= n_nodes for
    masked/pad edges. Returns (n_nodes, F)."""
    E, F = messages.shape
    bn = min(block_n, n_nodes)
    be = min(block_e, E)
    nb, ne = -(-n_nodes // bn), -(-E // be)
    if ne * be != E:
        pe = ne * be - E
        messages = jnp.pad(messages, ((0, pe), (0, 0)))
        dst = jnp.pad(dst, (0, pe), constant_values=nb * bn + 1)
    dst = dst.astype(jnp.int32)

    kern = functools.partial(_ss_kernel, bn=bn, ne=ne)
    out = pl.pallas_call(
        kern,
        grid=(nb, ne),
        in_specs=[
            pl.BlockSpec((be,), lambda ib, je: (je,)),
            pl.BlockSpec((be, F), lambda ib, je: (je, 0)),
        ],
        out_specs=pl.BlockSpec((bn, F), lambda ib, je: (ib, 0)),
        out_shape=jax.ShapeDtypeStruct((nb * bn, F), messages.dtype),
        scratch_shapes=[pltpu.VMEM((bn, F), jnp.float32)],
        interpret=interpret,
    )(dst, messages)
    return out[:n_nodes]

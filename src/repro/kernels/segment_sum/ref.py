"""Pure-jnp oracle for segment_sum (jax.ops.segment_sum)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def segment_sum_ref(messages, dst, n_nodes: int):
    """messages: (E,F); dst: (E,); out-of-range dst are dropped."""
    valid = dst < n_nodes
    m = jnp.where(valid[:, None], messages, 0.0)
    d = jnp.where(valid, dst, 0)
    out = jax.ops.segment_sum(m.astype(jnp.float32), d, num_segments=n_nodes)
    # drop contributions routed to node 0 from invalid edges
    corr = jax.ops.segment_sum(
        jnp.where(valid[:, None], 0.0, 0.0).astype(jnp.float32), d,
        num_segments=n_nodes)
    return (out - corr).astype(messages.dtype)

"""Pure-jnp oracle for the flash-attention kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def attention_ref(q, k, v, q_pos, k_pos, *, causal=True, window=0, scale=None):
    """q: (B,H,Sq,D); k,v: (B,K,Sk,D). Full-softmax reference in f32."""
    B, H, Sq, D = q.shape
    K = k.shape[1]
    G = H // K
    scale = scale if scale is not None else D ** -0.5
    kk = jnp.repeat(k, G, axis=1)
    vv = jnp.repeat(v, G, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   kk.astype(jnp.float32)) * scale
    dpos = q_pos[:, None] - k_pos[None, :]
    mask = k_pos[None, :] > -(10 ** 8)
    if causal:
        mask &= dpos >= 0
    if window > 0:
        mask &= dpos < window
    s = jnp.where(mask[None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhqk,bhkd->bhqd", p, vv.astype(jnp.float32))
    return o.astype(q.dtype)

"""jit'd public wrapper: (B,S,H,D) layout + GQA, dispatching to the kernel."""
from __future__ import annotations

import jax.numpy as jnp

from .kernel import flash_attention_bhsd


def flash_attention(q, k, v, *, q_pos, k_pos, causal=True, window=0,
                    scale=None, block_q=128, block_k=128, interpret=True):
    """q: (B,Sq,H,D); k,v: (B,Sk,K,D) -> (B,Sq,H,D)."""
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    o = flash_attention_bhsd(qt, kt, vt, q_pos, k_pos, causal=causal,
                             window=window, scale=scale, block_q=block_q,
                             block_k=block_k, interpret=interpret)
    return o.transpose(0, 2, 1, 3)

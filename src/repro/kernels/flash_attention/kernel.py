"""Flash-attention Pallas TPU kernel (causal + sliding-window, GQA-aware).

Grid: (batch, q_heads, num_q_blocks, num_kv_blocks) — the last grid dim is
sequential on TPU, so online-softmax state (m, l, acc) lives in VMEM scratch
carried across kv blocks; the output tile is written on the final kv block.

BlockSpec tiling (VMEM working set, MXU-aligned):
  q:   (1, 1, BQ, D)  indexed (b, h, iq, ·)
  k/v: (1, 1, BK, D)  indexed (b, h // G, ·, ik)  — GQA without kv repeat
  pos: (BQ,) / (BK,)  int32 streams, so padded / rolling-window caches mask
       correctly (pad sentinel = -1e9).

Defaults BQ=BK=128: for D=256 the resident set (q,k,v tiles + f32 score tile
+ f32 accumulator) is ~0.7 MiB — far under the ~16 MiB VMEM budget, leaving
room for double-buffered pipelining.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30

VMEM_BUDGET = (16 << 20) - (4 << 20)   # physical VMEM minus Mosaic headroom


def vmem_bytes(D: int, block_q: int, block_k: int, *,
               itemsize: int = 4) -> int:
    """Modeled resident VMEM of one kernel step: double-buffered block DMA
    (×2) for q/k/v/out tiles and the pos streams, f32 online-softmax scratch
    (m, l, acc), plus the live f32 casts and the (BQ, BK) score/prob tiles.
    ``itemsize`` is the in/out dtype width; all kernel math is f32."""
    bq, bk = block_q, block_k
    return (2 * (bq + bk) * 4                  # q_pos / k_pos int32 streams
            + 2 * bq * D * itemsize            # q tile (double-buffered)
            + 2 * 2 * bk * D * itemsize        # k + v tiles
            + 2 * bq * D * itemsize            # out tile
            + (2 * bq + bq * D) * 4            # m, l, acc scratch
            + (bq + 2 * bk) * D * 4            # live f32 casts of q, k, v
            + 2 * bq * bk * 4)                 # live s and p score tiles


def check_blocks(D: int, block_q: int, block_k: int, *, itemsize: int = 4,
                 vmem_limit: int = VMEM_BUDGET) -> None:
    """Raise if an explicit (block_q, block_k) override exceeds the VMEM
    budget — over-budget configs must fail at trace time, not OOM on core."""
    need = vmem_bytes(D, block_q, block_k, itemsize=itemsize)
    if need > vmem_limit:
        raise ValueError(
            f"flash_attention blocks (block_q={block_q}, block_k={block_k}) "
            f"need ≈{need / 2 ** 20:.1f} MiB of VMEM at D={D} — over the "
            f"{vmem_limit / 2 ** 20:.1f} MiB budget; halve the blocks "
            f"(the 128/128 defaults fit every supported head dim).")


def _fa_kernel(qp_ref, kp_ref, q_ref, k_ref, v_ref, o_ref,
               m_ref, l_ref, acc_ref, *, scale, causal, window, nk):
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)          # (BQ, D)
    k = k_ref[0, 0].astype(jnp.float32)          # (BK, D)
    v = v_ref[0, 0].astype(jnp.float32)
    qp = qp_ref[...]                             # (BQ,) int32
    kp = kp_ref[...]                             # (BK,)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale  # (BQ,BK)
    dpos = qp[:, None] - kp[None, :]
    mask = kp[None, :] > -(10 ** 8)              # padded keys out
    if causal:
        mask &= dpos >= 0
    if window > 0:
        mask &= dpos < window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]
    m_cur = jnp.maximum(m_prev, jnp.max(s, axis=1))
    corr = jnp.exp(m_prev - m_cur)
    p = jnp.exp(s - m_cur[:, None])
    l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=1)
    acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())))
    m_ref[...] = m_cur

    @pl.when(ik == nk - 1)
    def _flush():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "window", "scale",
                                             "block_q", "block_k", "interpret"))
def flash_attention_bhsd(q, k, v, q_pos, k_pos, *, causal=True, window=0,
                         scale=None, block_q=128, block_k=128, interpret=True):
    """q: (B,H,Sq,D); k,v: (B,K,Sk,D); H % K == 0. Returns (B,H,Sq,D)."""
    B, H, Sq, D = q.shape
    K, Sk = k.shape[1], k.shape[2]
    G = H // K
    scale = scale if scale is not None else D ** -0.5
    bq = min(block_q, Sq)
    bk = min(block_k, Sk)
    nq, nk = -(-Sq // bq), -(-Sk // bk)
    check_blocks(D, bq, bk, itemsize=q.dtype.itemsize)
    q_pos = q_pos.astype(jnp.int32)
    k_pos = k_pos.astype(jnp.int32)
    if nq * bq != Sq:
        pq = nq * bq - Sq
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pq), (0, 0)))
        q_pos = jnp.pad(q_pos, (0, pq), constant_values=-(10 ** 9))
    if nk * bk != Sk:
        pk = nk * bk - Sk
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pk), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pk), (0, 0)))
        k_pos = jnp.pad(k_pos, (0, pk), constant_values=-(10 ** 9))

    kern = functools.partial(_fa_kernel, scale=scale, causal=causal,
                             window=window, nk=nk)
    out = pl.pallas_call(
        kern,
        grid=(B, H, nq, nk),
        in_specs=[
            pl.BlockSpec((bq,), lambda b, h, iq, ik: (iq,)),
            pl.BlockSpec((bk,), lambda b, h, iq, ik: (ik,)),
            pl.BlockSpec((1, 1, bq, D), lambda b, h, iq, ik: (b, h, iq, 0)),
            pl.BlockSpec((1, 1, bk, D), lambda b, h, iq, ik: (b, h // G, ik, 0)),
            pl.BlockSpec((1, 1, bk, D), lambda b, h, iq, ik: (b, h // G, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, D), lambda b, h, iq, ik: (b, h, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, nq * bq, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq, D), jnp.float32),
        ],
        interpret=interpret,
    )(q_pos, k_pos, q, k, v)
    return out[:, :, :Sq]

"""jit'd wrapper: (B,1,H,D) query + (B,S,K,D) cache -> (B,1,H,D)."""
from __future__ import annotations

import jax.numpy as jnp

from .kernel import combine_partials, flash_decode_partials


def flash_decode(q, k, v, *, q_pos, k_pos, window=0, scale=None,
                 n_splits=8, block_k=512, interpret=True):
    """q: (B,1,H,D); k,v: (B,S,K,D); q_pos: (B,) or scalar; k_pos: (B,S) or
    (S,). Returns (B,1,H,D)."""
    B, _, H, D = q.shape
    S, K = k.shape[1], k.shape[2]
    if k_pos.ndim == 1:
        k_pos = jnp.broadcast_to(k_pos[None], (B, S))
    q_pos = jnp.broadcast_to(jnp.asarray(q_pos).reshape(-1), (B,))
    m, l, acc = flash_decode_partials(
        q[:, 0].transpose(0, 1, 2), k.transpose(0, 2, 1, 3),
        v.transpose(0, 2, 1, 3), q_pos, k_pos, window=window, scale=scale,
        n_splits=n_splits, block_k=block_k, interpret=interpret)
    o = combine_partials(m, l, acc)                 # (B,K,G,D)
    return o.reshape(B, 1, H, D).astype(q.dtype)

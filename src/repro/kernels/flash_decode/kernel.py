"""Flash-decode Pallas TPU kernel: one query token vs a long KV cache.

Decode attention is bandwidth-bound on cache reads and, unlike prefill,
offers no query-block parallelism. The standard adaptation (flash-decoding)
splits the KV length across the grid so every split streams its cache slice
at full HBM bandwidth, emitting PARTIAL online-softmax states (m, l, acc);
a cheap second phase combines the partials exactly.

Grid: (batch, kv_heads, n_splits). Each program handles all G = H/K query
heads of its kv head (GQA without repeat), reading a (BK, D) cache tile per
inner step via ``pl.when``-guarded accumulation over its split's blocks.

Outputs (partials, combined on the host side of the op in ops.py):
  m_part:   (B, K, G, n_splits)
  l_part:   (B, K, G, n_splits)
  acc_part: (B, K, G, n_splits, D)

VMEM per program at BK=512, D=256, G=8: k/v tiles 2x512x256x4 = 1 MiB,
q (8,256) + acc (8,256) negligible.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30

VMEM_BUDGET = (16 << 20) - (4 << 20)   # physical VMEM minus Mosaic headroom


def vmem_bytes(D: int, G: int, per_split: int, block_k: int, *,
               itemsize: int = 4) -> int:
    """Modeled resident VMEM of one (batch, kv_head, split) program. The
    dominant term is the SPLIT slice, not ``block_k``: the k/v BlockSpecs
    carve ``(1, 1, per_split, D)``, so the whole slice is DMA'd (double-
    buffered) and the fori_loop sub-tiles it in-VMEM with ``pl.dslice``."""
    return (2 * 2 * per_split * D * itemsize   # k + v split slices (×2 DMA)
            + 2 * per_split * 4                # k_pos int32 stream
            + 2 * G * D * itemsize             # q block
            + 2 * (2 * G + G * D) * 4          # m/l/acc partial outputs
            + 2 * block_k * D * 4              # live f32 casts of k, v tiles
            + 2 * G * D * 4                    # live f32 q cast + acc carry
            + 2 * G * block_k * 4)             # live s and p score tiles


def check_blocks(S: int, D: int, G: int, n_splits: int, block_k: int, *,
                 itemsize: int = 4, vmem_limit: int = VMEM_BUDGET) -> None:
    """Raise if an (n_splits, block_k) config exceeds the VMEM budget for a
    cache of length S — fail at trace time instead of OOMing on core. Longer
    caches need MORE splits (per_split shrinks), not bigger blocks."""
    bk = min(block_k, S)
    per_split = -(-S // (n_splits * bk)) * bk
    need = vmem_bytes(D, G, per_split, bk, itemsize=itemsize)
    if need > vmem_limit:
        raise ValueError(
            f"flash_decode config (n_splits={n_splits}, block_k={block_k}) "
            f"puts a per-split slice of {per_split} kv rows ≈"
            f"{need / 2 ** 20:.1f} MiB in VMEM at (S={S}, D={D}, G={G}) — "
            f"over the {vmem_limit / 2 ** 20:.1f} MiB budget; raise n_splits "
            f"or shrink block_k.")


def _fd_kernel(qpos_ref, kp_ref, q_ref, k_ref, v_ref,
               m_out, l_out, acc_out, *, scale, window, blocks_per_split, bk):
    """One (batch, kv_head, split). Inner loop over this split's kv blocks."""
    q = q_ref[0, 0].astype(jnp.float32)            # (G, D)
    qpos = qpos_ref[0]                              # scalar int32

    def body(i, carry):
        m, l, acc = carry
        # full-Slice index tuples only: jax 0.4.37's interpret-mode discharge
        # rule chokes on bare ints inside pl.load indices (it probes
        # ``.shape`` on every non-Slice entry), so the unit leading dims are
        # loaded as dslice(0, 1) and squeezed after the load
        k = pl.load(k_ref, (pl.dslice(0, 1), pl.dslice(0, 1),
                            pl.dslice(i * bk, bk), slice(None))
                    )[0, 0].astype(jnp.float32)     # (BK, D)
        v = pl.load(v_ref, (pl.dslice(0, 1), pl.dslice(0, 1),
                            pl.dslice(i * bk, bk), slice(None))
                    )[0, 0].astype(jnp.float32)
        kp = pl.load(kp_ref, (pl.dslice(0, 1),
                              pl.dslice(i * bk, bk)))[0]  # (BK,)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale  # (G,BK)
        dpos = qpos - kp
        mask = (kp > -(10 ** 8)) & (dpos >= 0)
        if window > 0:
            mask &= dpos < window
        s = jnp.where(mask[None, :], s, NEG_INF)
        m_cur = jnp.maximum(m, jnp.max(s, axis=1))
        corr = jnp.exp(m - m_cur)
        p = jnp.exp(s - m_cur[:, None])
        l_new = l * corr + jnp.sum(p, axis=1)
        acc_new = acc * corr[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())))
        return m_cur, l_new, acc_new

    G, D = q.shape
    m0 = jnp.full((G,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((G,), jnp.float32)
    a0 = jnp.zeros((G, D), jnp.float32)
    m, l, acc = jax.lax.fori_loop(0, blocks_per_split, body, (m0, l0, a0))
    m_out[0, 0, :, 0] = m
    l_out[0, 0, :, 0] = l
    acc_out[0, 0, :, 0] = acc


@functools.partial(jax.jit, static_argnames=("window", "scale", "n_splits",
                                             "block_k", "interpret"))
def flash_decode_partials(q, k, v, q_pos, k_pos, *, window=0, scale=None,
                          n_splits=8, block_k=512, interpret=True):
    """q: (B,H,D) one token per sequence; k,v: (B,K,S,D); k_pos: (B,S).
    Returns partials (m, l, acc) with a trailing split dim."""
    B, H, D = q.shape
    K, S = k.shape[1], k.shape[2]
    G = H // K
    scale = scale if scale is not None else D ** -0.5
    bk = min(block_k, S)
    check_blocks(S, D, G, n_splits, block_k, itemsize=q.dtype.itemsize)
    # pad S to n_splits * blocks_per_split * bk
    per_split = -(-S // (n_splits * bk)) * bk
    S_pad = per_split * n_splits
    if S_pad != S:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, S_pad - S), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, S_pad - S), (0, 0)))
        k_pos = jnp.pad(k_pos, ((0, 0), (0, S_pad - S)),
                        constant_values=-(10 ** 9))
    blocks_per_split = per_split // bk
    qg = q.reshape(B, K, G, D)

    kern = functools.partial(_fd_kernel, scale=scale, window=window,
                             blocks_per_split=blocks_per_split, bk=bk)
    out_shape = [
        jax.ShapeDtypeStruct((B, K, G, n_splits), jnp.float32),
        jax.ShapeDtypeStruct((B, K, G, n_splits), jnp.float32),
        jax.ShapeDtypeStruct((B, K, G, n_splits, D), jnp.float32),
    ]
    m, l, acc = pl.pallas_call(
        kern,
        grid=(B, K, n_splits),
        in_specs=[
            pl.BlockSpec((1, 1), lambda b, h, s: (b, 0)),      # q_pos (B,1)
            pl.BlockSpec((1, per_split), lambda b, h, s: (b, s)),
            pl.BlockSpec((1, 1, G, D), lambda b, h, s: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, per_split, D), lambda b, h, s: (b, h, s, 0)),
            pl.BlockSpec((1, 1, per_split, D), lambda b, h, s: (b, h, s, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, G, 1), lambda b, h, s: (b, h, 0, s)),
            pl.BlockSpec((1, 1, G, 1), lambda b, h, s: (b, h, 0, s)),
            pl.BlockSpec((1, 1, G, 1, D), lambda b, h, s: (b, h, 0, s, 0)),
        ],
        out_shape=out_shape,
        interpret=interpret,
    )(q_pos.reshape(B, 1).astype(jnp.int32), k_pos.astype(jnp.int32),
      qg, k, v)
    return m, l, acc


def combine_partials(m, l, acc):
    """Exact combine of per-split online-softmax partials -> (B,K,G,D)."""
    m_max = jnp.max(m, axis=-1, keepdims=True)              # (B,K,G,1)
    w = jnp.exp(m - m_max)                                  # (B,K,G,S)
    l_tot = jnp.sum(l * w, axis=-1)                         # (B,K,G)
    acc_tot = jnp.sum(acc * w[..., None], axis=-2)          # (B,K,G,D)
    return acc_tot / jnp.maximum(l_tot, 1e-30)[..., None]

"""Oracle: naive decode attention over the full cache."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def decode_ref(q, k, v, *, q_pos, k_pos, window=0, scale=None):
    """q: (B,1,H,D); k,v: (B,S,K,D); k_pos: (B,S). -> (B,1,H,D)."""
    B, _, H, D = q.shape
    S, K = k.shape[1], k.shape[2]
    G = H // K
    scale = scale if scale is not None else D ** -0.5
    qg = q[:, 0].reshape(B, K, G, D).astype(jnp.float32)
    s = jnp.einsum("bkgd,bskd->bkgs", qg, k.astype(jnp.float32)) * scale
    q_pos = jnp.broadcast_to(jnp.asarray(q_pos).reshape(-1), (B,))
    dpos = q_pos[:, None] - k_pos
    mask = (k_pos > -(10 ** 8)) & (dpos >= 0)
    if window > 0:
        mask &= dpos < window
    s = jnp.where(mask[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgs,bskd->bkgd", p, v.astype(jnp.float32))
    return o.reshape(B, 1, H, D).astype(q.dtype)

"""Parameter sharding rules: leaf path -> PartitionSpec.

The mesh contract (launch/mesh.py): axes ("data", "model") single-pod or
("pod", "data", "model") multi-pod. "model" carries tensor-parallel,
expert-parallel and task-parallel dims; "data" carries batch + FSDP; "pod"
is pure data-parallel.

Rules are (substring-match on the '/'-joined tree path) -> spec builder.
Stacked scan params carry a leading (reps,) dim which is auto-detected (rule
spec is for the unstacked block) and padded with None.
"""
from __future__ import annotations

import re

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

MODEL = "model"


def _rules(cfg, model_size: int = 16):
    F = "data" if cfg.fsdp else None  # FSDP axis
    E_div = cfg.n_experts and cfg.n_experts % model_size == 0  # EP if divisible
    # head-ALIGNED tensor parallelism only: sharding H*hd over the model axis
    # when n_heads % model_size != 0 splits heads fractionally — XLA then
    # all-reduces score tensors INSIDE the attention kv-loop (measured
    # 1.3e13 B/dev on granite prefill_32k — §Perf-2). Same for kv heads.
    QH = MODEL if cfg.n_heads and cfg.n_heads % model_size == 0 else None
    KH = MODEL if cfg.n_kv_heads and cfg.n_kv_heads % model_size == 0 else None
    if cfg.naive_tp:  # baseline (pre-§Perf-2) behaviour for the perf log
        QH = KH = MODEL
    r = []
    # embeddings / heads
    r.append((r"embed/table$", lambda s: P(MODEL, F)))
    r.append((r"lm_head/w$", lambda s: P(F, MODEL)))
    r.append((r"task_heads/w$", lambda s: P(MODEL, None, None)))
    # attention (gqa + mla)
    r.append((r"attn/wq/w$", lambda s: P(F, QH)))
    r.append((r"attn/w[kv]/w$", lambda s: P(F, KH)))
    r.append((r"attn/wq/b$", lambda s: P(QH)))
    r.append((r"attn/w[kv]/b$", lambda s: P(KH)))
    r.append((r"attn/wo/w$", lambda s: P(QH, F)))
    r.append((r"attn/wq_a/w$", lambda s: P(F, None)))
    r.append((r"attn/wq_b/w$", lambda s: P(None, MODEL)))
    r.append((r"attn/wkv_a/w$", lambda s: P(F, None)))
    r.append((r"attn/w[kv]_b/w$", lambda s: P(None, MODEL)))
    # xattn (enc-dec) same as attn
    r.append((r"xattn/wq/w$", lambda s: P(F, QH)))
    r.append((r"xattn/w[kv]/w$", lambda s: P(F, KH)))
    r.append((r"xattn/wo/w$", lambda s: P(QH, F)))
    # dense mlp
    r.append((r"ffn/w_gate/w$", lambda s: P(F, MODEL)))
    r.append((r"ffn/w_up/w$", lambda s: P(F, MODEL)))
    r.append((r"ffn/w_down/w$", lambda s: P(MODEL, F)))
    # moe: expert-parallel if E divides the axis, else TP over expert hidden
    if E_div:
        r.append((r"ffn/w_gate$", lambda s: P(MODEL, F, None)))
        r.append((r"ffn/w_up$", lambda s: P(MODEL, F, None)))
        r.append((r"ffn/w_down$", lambda s: P(MODEL, None, F)))
    else:
        r.append((r"ffn/w_gate$", lambda s: P(None, F, MODEL)))
        r.append((r"ffn/w_up$", lambda s: P(None, F, MODEL)))
        r.append((r"ffn/w_down$", lambda s: P(None, MODEL, F)))
    r.append((r"ffn/router$", lambda s: P(F, None)))
    r.append((r"ffn/shared/w_gate/w$", lambda s: P(F, MODEL)))
    r.append((r"ffn/shared/w_up/w$", lambda s: P(F, MODEL)))
    r.append((r"ffn/shared/w_down/w$", lambda s: P(MODEL, F)))
    # mamba2
    r.append((r"mixer/w_in/w$", lambda s: P(F, MODEL)))
    r.append((r"mixer/w_out/w$", lambda s: P(MODEL, F)))
    r.append((r"mixer/conv_w$", lambda s: P(None, MODEL)))
    r.append((r"mixer/conv_b$", lambda s: P(MODEL)))
    # xlstm
    r.append((r"mixer/w_up/w$", lambda s: P(F, MODEL)))
    r.append((r"mixer/w[qkv]/w$", lambda s: P(F, MODEL)))
    r.append((r"mixer/w_down/w$", lambda s: P(MODEL, F)))
    r.append((r"mixer/w_ff_up/w$", lambda s: P(F, MODEL)))
    r.append((r"mixer/w_ff_down/w$", lambda s: P(MODEL, F)))
    return r


def path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def make_spec_fn(cfg, mesh: Mesh | None = None):
    axsize = dict(mesh.shape) if mesh is not None else {}
    rules = _rules(cfg, model_size=axsize.get(MODEL, 16))

    def _fit(spec: P, shape) -> P:
        """Drop mesh axes from dims they don't evenly divide (e.g. odd
        vocabs): jit in_shardings require even tiling."""
        out = []
        for dim, entry in zip(shape, spec):
            if entry is None:
                out.append(None)
                continue
            axes = entry if isinstance(entry, tuple) else (entry,)
            n = 1
            for a in axes:
                n *= axsize.get(a, 1)
            out.append(entry if n and dim % n == 0 else None)
        return P(*out)

    def spec_fn(path, leaf) -> P:
        ps = path_str(path) if not isinstance(path, str) else path
        for pat, build in rules:
            if re.search(pat, ps):
                spec = build(leaf.shape)
                nd = leaf.ndim
                k = len(spec)
                if nd == k:
                    return _fit(spec, leaf.shape)
                if nd == k + 1:          # stacked scan block: leading reps dim
                    return _fit(P(None, *spec), leaf.shape)
                # mismatch (e.g. bias matched weight rule): replicate
                return P(*([None] * nd))
        return P(*([None] * leaf.ndim))

    return spec_fn


def hier_batch_spec(leaf, n_devices: int, axis: str = "data") -> P:
    """Spec for one leaf of a GROUP's batch slice (k_g, B, ...) on a 1-axis
    group mesh: the per-group head dim stays replicated, B shards over the
    group's data axis — replicate entirely when B doesn't tile evenly (ragged
    per-head batches; jit in_shardings require even tiling)."""
    nd = leaf.ndim
    if nd < 2 or leaf.shape[1] % max(n_devices, 1) != 0:
        return P(*([None] * nd))
    return P(None, axis, *([None] * (nd - 2)))


def serve_batch_spec(leaf, n_devices: int, axis: str = "data") -> P:
    """Spec for one leaf of an assembled SERVING batch (max_batch, ...) on a
    1-axis serving mesh (``launch.mesh.make_replica_meshes`` /
    ``ServeSession(mesh=...)``): rows are data-parallel over the axis —
    replicate when the static row count doesn't tile evenly (jit
    in_shardings require even tiling). The serving analogue of
    ``hier_batch_spec``: head params stay replicated, only rows shard."""
    nd = leaf.ndim
    if nd < 1 or leaf.shape[0] % max(n_devices, 1) != 0:
        return P(*([None] * nd))
    return P(axis, *([None] * (nd - 1)))


def tree_shardings(mesh: Mesh, tree, spec_fn):
    """NamedSharding pytree for a params pytree / eval_shape tree."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = [NamedSharding(mesh, spec_fn(p, l)) for p, l in flat]
    return jax.tree_util.tree_unflatten(treedef, out)


def check_divisibility(cfg, mesh: Mesh) -> list[str]:
    """Sanity report: which sharded dims don't divide the axis (XLA pads
    these — legal but wasteful; surfaced for the roofline notes)."""
    issues = []
    ax = dict(mesh.shape)
    m = ax.get(MODEL, 1)
    for nm, dim in (("n_heads", cfg.n_heads), ("vocab", cfg.vocab),
                    ("d_ff", cfg.d_ff)):
        if dim and dim % m:
            issues.append(f"{nm}={dim} % model={m} != 0")
    return issues

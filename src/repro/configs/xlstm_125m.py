"""xlstm-125m [ssm] — 12L d=768 4H, alternating mLSTM (matrix memory) and
sLSTM (scalar memory) blocks, d_ff=0 (blocks own their projections),
vocab=50304. [arXiv:2405.04517]"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="xlstm-125m", family="ssm", citation="arXiv:2405.04517",
    n_layers=12, d_model=768, n_heads=4, n_kv_heads=4, d_ff=0,
    vocab=50304, head_dim=192,
    block_pattern=("mlstm", "slstm"),
    long_context_ok=True,      # O(1) recurrent state
)


def smoke() -> ArchConfig:
    return CONFIG.replace(n_layers=2, d_model=128, n_heads=4, n_kv_heads=4,
                          head_dim=32, vocab=512, remat=False)

"""h2o-danube-1.8b [dense] — 24L d=2560 32H (GQA kv=8) d_ff=6912 vocab=32000;
llama+mistral mix with sliding-window attention (window 4096).
[arXiv:2401.16818]"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="h2o-danube-1.8b", family="dense", citation="arXiv:2401.16818",
    n_layers=24, d_model=2560, n_heads=32, n_kv_heads=8, d_ff=6912,
    vocab=32000, head_dim=80,
    block_pattern=("swa",), window=4096,
    long_context_ok=True,       # native SWA => bounded decode cache
)


def smoke() -> ArchConfig:
    return CONFIG.replace(n_layers=2, d_model=128, n_heads=4, n_kv_heads=2,
                          head_dim=32, d_ff=256, vocab=512, window=32,
                          remat=False)

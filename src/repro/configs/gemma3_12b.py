"""gemma3-12b [dense] — 48L d=3840 16H (GQA kv=8) d_ff=15360 vocab=262144;
5:1 local:global attention (local window 1024), 128k context.
[hf:google/gemma-3-1b-pt family, 12b scaling]"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="gemma3-12b", family="dense", citation="hf:google/gemma-3-1b-pt",
    n_layers=48, d_model=3840, n_heads=16, n_kv_heads=8, d_ff=15360,
    vocab=262144, head_dim=256,
    block_pattern=("swa", "swa", "swa", "swa", "swa", "attn"), window=1024,
    rope_theta=1_000_000.0,
    fsdp=True,
    train_accum=4,
    long_context_ok=True,      # 5/6 layers windowed; global layers O(S) decode
)


def smoke() -> ArchConfig:
    return CONFIG.replace(n_layers=6, d_model=128, n_heads=4, n_kv_heads=2,
                          head_dim=32, d_ff=256, vocab=512, window=32,
                          fsdp=False, remat=False)

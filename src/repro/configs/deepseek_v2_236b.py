"""deepseek-v2-236b [moe] — 60L d=5120 128H, MLA (kv_lora=512, q_lora=1536,
rope 64 + nope 128 per head, v_head 128), MoE 160 routed top-6 + 2 shared
experts, d_ff_expert=1536, vocab=102400. [arXiv:2405.04434]

bf16 params (fp32 moments) — the fp32-param variant does not fit 16 GB/chip
even fully sharded; recorded in EXPERIMENTS.md §Roofline. Real DS-V2 keeps
the first layer dense-FFN; we use MoE in every layer for scan homogeneity
(noted deviation)."""
import jax.numpy as jnp

from .base import ArchConfig

CONFIG = ArchConfig(
    name="deepseek-v2-236b", family="moe", citation="arXiv:2405.04434",
    n_layers=60, d_model=5120, n_heads=128, n_kv_heads=128, d_ff=12288,
    vocab=102400,
    head_dim=128,              # nope sub-dim per head
    kv_lora=512, q_lora=1536, rope_dims=64, v_head_dim=128,
    n_experts=160, top_k=6, n_shared_experts=2, d_ff_expert=1536,
    block_pattern=("mla",),
    param_dtype=jnp.bfloat16,
    moment_dtype=jnp.bfloat16,  # §Perf-3: args 10.9 -> 6.5 GB/device
    fsdp=True,
    train_accum=64,             # §Perf-3: temp 45.9 -> 20.1 GB/device

    long_context_ok=True,      # MLA latent cache (576 B/token/layer) + absorbed decode
)


def smoke() -> ArchConfig:
    return CONFIG.replace(n_layers=2, d_model=128, n_heads=4, n_kv_heads=4,
                          head_dim=32, kv_lora=32, q_lora=48, rope_dims=16,
                          v_head_dim=32, n_experts=4, top_k=2,
                          n_shared_experts=1, d_ff_expert=64, d_ff=256,
                          vocab=512, param_dtype=jnp.float32, fsdp=False,
                          remat=False)

"""qwen1.5-0.5b [dense] — 24L d=1024 16H (kv=16, MHA) d_ff=2816 vocab=151936,
QKV bias. [hf:Qwen/Qwen1.5-0.5B]"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="qwen1.5-0.5b", family="dense", citation="hf:Qwen/Qwen1.5-0.5B",
    n_layers=24, d_model=1024, n_heads=16, n_kv_heads=16, d_ff=2816,
    vocab=151936, head_dim=64, qkv_bias=True,
    block_pattern=("attn",),
    swa_variant_window=4096,
)


def smoke() -> ArchConfig:
    return CONFIG.replace(n_layers=2, d_model=128, n_heads=4, n_kv_heads=4,
                          head_dim=32, d_ff=256, vocab=512, remat=False)

"""internvl2-1b [vlm] — 24L d=896 14H (GQA kv=2) d_ff=4864 vocab=151655;
InternViT vision encoder is a STUB (input_specs provides patch embeddings),
we own the projector + Qwen2-0.5B-style language backbone. [arXiv:2404.16821]"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="internvl2-1b", family="vlm", citation="arXiv:2404.16821",
    n_layers=24, d_model=896, n_heads=14, n_kv_heads=2, d_ff=4864,
    vocab=151655, head_dim=64, qkv_bias=True,
    block_pattern=("attn",),
    modality="vision_embed", n_media_tokens=256,
    naive_tp=True,  # 14 heads % 16 != 0 — see granite note / §Perf-2
    swa_variant_window=4096,
)


def smoke() -> ArchConfig:
    return CONFIG.replace(n_layers=2, d_model=128, n_heads=4, n_kv_heads=2,
                          head_dim=32, d_ff=256, vocab=512, n_media_tokens=8,
                          remat=False)

"""Architecture + input-shape config dataclasses.

Every assigned architecture is expressed as an ``ArchConfig``. Model builders
(``repro.models.transformer`` / ``repro.models.gnn``) consume these; the
launcher resolves them by id via ``repro.configs.registry``.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    # identity ------------------------------------------------------------
    name: str = ""
    family: str = "dense"          # dense | moe | vlm | audio | hybrid | ssm | gnn
    citation: str = ""             # source paper / model card
    # trunk ---------------------------------------------------------------
    n_layers: int = 0
    d_model: int = 0
    n_heads: int = 0
    n_kv_heads: int = 0
    d_ff: int = 0
    vocab: int = 0
    head_dim: int = 0              # 0 => d_model // n_heads
    qkv_bias: bool = False
    act: str = "silu"
    norm: str = "rmsnorm"          # rmsnorm | layernorm
    rope_theta: float = 10000.0
    tie_embeddings: bool = True
    # attention pattern ----------------------------------------------------
    window: int = 0                # 0 = full attention; >0 = sliding window
    # per-layer pattern unit, repeated to n_layers. entries:
    #   "attn"        full attention
    #   "swa"         sliding-window attention (cfg.window)
    #   "mamba2"      Mamba2 SSD block
    #   "mlstm"/"slstm" xLSTM blocks
    #   "shared_attn" zamba-style shared-weight attention block (+LoRA/app)
    block_pattern: tuple = ("attn",)
    # MoE -------------------------------------------------------------------
    n_experts: int = 0
    top_k: int = 0
    n_shared_experts: int = 0
    d_ff_expert: int = 0           # per-expert hidden size
    router_aux_coef: float = 0.01
    capacity_factor: float = 1.25
    # MLA (DeepSeek-V2) ------------------------------------------------------
    kv_lora: int = 0               # latent rank for compressed KV (0 => GQA path)
    q_lora: int = 0
    rope_dims: int = 0             # per-head rotary sub-dim
    v_head_dim: int = 0
    # SSM -------------------------------------------------------------------
    ssm_state: int = 0
    ssm_heads: int = 0
    ssm_expand: int = 2
    ssm_chunk: int = 256
    conv_kernel: int = 4
    mlstm_chunked: bool = True     # chunkwise-parallel mLSTM (perf log: §Perf-1)
    naive_tp: bool = False         # pre-§Perf-2 sharding (head-fractional TP)
    moment_dtype: Any = jnp.float32  # AdamW m/v dtype (bf16: §Perf-3)
    # encoder-decoder ---------------------------------------------------------
    n_enc_layers: int = 0          # >0 => encoder-decoder (seamless)
    enc_memory_len: int = 4096     # stub encoder-memory length for serving
    # modality frontends (stubs) ----------------------------------------------
    modality: str = "text"         # text | vision_embed | audio_embed
    n_media_tokens: int = 0        # prepended embedding tokens for vlm/audio
    # multi-task (the paper's technique) ----------------------------------------
    n_tasks: int = 1               # >1 => per-source LM heads, task-shardable
    # GNN (hydragnn-gfm) ----------------------------------------------------
    gnn_hidden: int = 0
    gnn_layers: int = 0
    head_hidden: int = 0           # MTL head FC width (paper: 889)
    head_layers: int = 3
    max_atoms: int = 0
    max_edges: int = 0
    n_species: int = 0
    # message-aggregation kernel, plumbed through egnn_apply so the MTL
    # model builders pick it up without call-site edits:
    #   "scatter" (default) — XLA scatter-add, O(E·F); fastest lowering
    #   "jnp"               — one-hot einsum, O(E·A·F); parity oracle
    #   "pallas"            — blocked mask-matmul MXU kernel (batched grid)
    #   "fused"             — whole message hot path (gather → d² → φ_e →
    #                         segment-sum) in one Pallas kernel
    segment_sum_impl: str = "scatter"
    # Pallas block-size override shared by the segment-sum kernel and the
    # fused egnn_edge kernel, forward AND backward (0 = autotune from the
    # problem shape: repro.kernels.segment_sum.kernel.autotune_blocks for
    # the segment-sum kernel, the VMEM budget planner
    # repro.kernels.egnn_edge.budget.plan_blocks for the fused kernel —
    # over-budget explicit overrides raise there instead of compiling):
    kernel_block_n: int = 0        # node-tile rows
    kernel_block_e: int = 0        # edge-tile rows
    # fused-kernel H-block: tiles the φ_e inner hidden axis so VMEM
    # residency is bounded by block_h·H, not H² — the paper-width (H=866)
    # enabler (0 = plan from the budget model; egnn_edge only)
    kernel_block_h: int = 0
    # precision / memory ---------------------------------------------------
    param_dtype: Any = jnp.float32
    compute_dtype: Any = jnp.bfloat16
    remat: bool = True
    train_accum: int = 1           # gradient-accumulation microbatches
    # sharding -------------------------------------------------------------
    fsdp: bool = False             # ZeRO-3-style param sharding over "data"
    # serving ----------------------------------------------------------------
    supports_decode: bool = True
    long_context_ok: bool = False  # native sub-quadratic path for long_500k
    swa_variant_window: int = 0    # >0: brief-allowed SWA serve variant for long_500k

    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // max(self.n_heads, 1))

    @property
    def padded_vocab(self) -> int:
        """Vocab padded to a multiple of 256 so the vocab dim shards evenly
        over the model axis (odd vocabs otherwise force replicated fp32
        logits — measured +39 GB/device on internvl2 train_4k)."""
        if self.vocab == 0:
            return 0
        return -(-self.vocab // 256) * 256

    @property
    def pattern(self) -> tuple:
        """Full per-layer pattern of length n_layers."""
        unit = self.block_pattern
        reps = -(-self.n_layers // len(unit))
        return (unit * reps)[: self.n_layers]

    def replace(self, **kw) -> "ArchConfig":
        return dataclasses.replace(self, **kw)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                      # "train" | "prefill" | "decode"


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}

"""input_specs(): ShapeDtypeStruct stand-ins for every model input.

Weak-type-correct, shardable, zero allocation — consumed by
``launch/dryrun.py`` (.lower() on specs) and by the smoke tests (which
materialise real arrays from the same shapes at reduced scale).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.frontends import AUDIO_EMBED_DIM, VISION_EMBED_DIM
from .base import ArchConfig, ShapeConfig


def data_axes(mesh: Mesh | None) -> tuple:
    if mesh is None:
        return ()
    return tuple(a for a in ("pod", "data") if a in mesh.shape)


def _sds(shape, dtype, mesh=None, spec=None):
    if mesh is None:
        return jax.ShapeDtypeStruct(shape, dtype)
    return jax.ShapeDtypeStruct(shape, dtype,
                                sharding=NamedSharding(mesh, spec or P()))


def _batch_spec(mesh, B, extra_dims):
    """P over the batch dim if it divides the data axes; else replicate."""
    if mesh is None:
        return None
    dp = data_axes(mesh)
    n = 1
    for a in dp:
        n *= mesh.shape[a]
    if B % n == 0 and B >= n:
        return P(dp, *([None] * extra_dims))
    return P(*([None] * (extra_dims + 1)))


def input_specs(cfg: ArchConfig, shape: ShapeConfig, mesh: Mesh | None = None,
                reduced: bool = False) -> dict:
    """Batch pytree of ShapeDtypeStructs for the given (arch, shape).

    train/prefill: {"tokens", "labels"?, "media"?, "src_embed"?}
    decode:        {"token", "pos"}  (caches are built by the step factory)
    """
    B, S = shape.global_batch, shape.seq_len
    if reduced:
        B, S = min(B, 4), min(S, 128)
    i32 = jnp.int32

    if shape.kind == "decode":
        return {
            "token": _sds((B, 1), i32, mesh, _batch_spec(mesh, B, 1)),
            "pos": _sds((), i32, mesh, P()),
        }

    out = {}
    s_text = S
    if cfg.modality == "vision_embed" and cfg.n_media_tokens:
        nm = cfg.n_media_tokens if not reduced else 8
        s_text = S - nm
        out["media"] = _sds((B, nm, VISION_EMBED_DIM), jnp.float32, mesh,
                            _batch_spec(mesh, B, 2))
    if cfg.modality == "audio_embed":
        M = cfg.enc_memory_len if not reduced else 32
        out["src_embed"] = _sds((B, M, AUDIO_EMBED_DIM), jnp.float32, mesh,
                                _batch_spec(mesh, B, 2))
    out["tokens"] = _sds((B, s_text), i32, mesh, _batch_spec(mesh, B, 1))
    if shape.kind == "train":
        out["labels"] = _sds((B, s_text), i32, mesh, _batch_spec(mesh, B, 1))
    return out


def cache_specs(cfg: ArchConfig, shape: ShapeConfig, mesh: Mesh | None = None,
                reduced: bool = False):
    """ShapeDtypeStructs (sharded) for decode caches at full capacity."""
    from repro.models.transformer import lm_cache_init
    B = shape.global_batch if not reduced else min(shape.global_batch, 4)
    C = shape.seq_len if not reduced else min(shape.seq_len, 128)
    # SWA serve variant for pure full-attention archs on long_500k
    eff_cfg = cfg
    if shape.name == "long_500k" and not cfg.long_context_ok and cfg.swa_variant_window:
        eff_cfg = cfg.replace(window=cfg.swa_variant_window,
                              block_pattern=tuple(
                                  "swa" if b == "attn" else b
                                  for b in cfg.block_pattern))
    shapes = jax.eval_shape(lambda: lm_cache_init(None, eff_cfg, B, C))
    if mesh is None:
        return shapes, eff_cfg

    dp = data_axes(mesh)
    n_dp = 1
    for a in dp:
        n_dp *= mesh.shape[a]
    shard_batch = B % n_dp == 0 and B >= n_dp

    def spec(leaf):
        nd = leaf.ndim
        # identify axes by rank pattern; leading (reps,) stack possible.
        # attention caches: (B,C,K,hd) / (B,C,r); states: various.
        s = [None] * nd
        shp = leaf.shape
        # find the batch axis: first axis equal to B (after optional reps dim)
        bax = 0 if shp and shp[0] == B else (1 if nd > 1 and shp[1] == B else None)
        if bax is not None and shard_batch:
            s[bax] = dp
        elif bax is not None and bax + 1 < nd and shp[bax + 1] >= n_dp and \
                shp[bax + 1] % max(n_dp, 1) == 0 and shp[bax + 1] > 1024:
            s[bax + 1] = dp  # long-context: shard cache length over data
        return jax.ShapeDtypeStruct(leaf.shape, leaf.dtype,
                                    sharding=NamedSharding(mesh, P(*s)))

    return jax.tree_util.tree_map(spec, shapes), eff_cfg

"""granite-moe-3b-a800m [moe] — 32L d=1536 24H (GQA kv=8) d_ff=512/expert,
vocab=49155, MoE 40 experts top-8. [hf:ibm-granite/granite-3.0-1b-a400m-base
family, 3b-a800m scaling]"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="granite-moe-3b-a800m", family="moe",
    citation="hf:ibm-granite/granite-3.0-1b-a400m-base",
    n_layers=32, d_model=1536, n_heads=24, n_kv_heads=8, d_ff=512,
    vocab=49155, head_dim=64,
    n_experts=40, top_k=8, d_ff_expert=512,
    block_pattern=("attn",),
    fsdp=True,
    train_accum=2,
    naive_tp=True,  # 24 heads % 16 != 0: fractional TP is the best 16x16 option;
                    # the real fix is the 32x8 mesh reshape (EXPERIMENTS.md §Perf-2)
    swa_variant_window=4096,   # brief-allowed SWA serve variant for long_500k
)


def smoke() -> ArchConfig:
    return CONFIG.replace(n_layers=2, d_model=128, n_heads=4, n_kv_heads=2,
                          head_dim=32, n_experts=4, top_k=2, d_ff_expert=64,
                          d_ff=64, vocab=512, fsdp=False, remat=False)

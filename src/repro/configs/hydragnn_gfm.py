"""hydragnn-gfm — the paper's own architecture (§5): 4-layer EGNN encoder,
866 hidden units per message-passing layer; one branch per dataset (5), each
branch = {energy head, force head} of 3 FC layers x 889 units.
[this paper; HydraGNN v3.0, doi:10.11578/dc.20240131.1]"""
import jax.numpy as jnp

from .base import ArchConfig

CONFIG = ArchConfig(
    name="hydragnn-gfm", family="gnn", citation="this paper / HydraGNN v3.0",
    gnn_hidden=866, gnn_layers=4, head_hidden=889, head_layers=3,
    n_tasks=5, n_species=64, max_atoms=64, max_edges=2048,
    compute_dtype=jnp.float32,   # paper trains fp32; GNN heads are small
    supports_decode=False,
)


def smoke() -> ArchConfig:
    return CONFIG.replace(gnn_hidden=64, gnn_layers=2, head_hidden=32,
                          head_layers=2, max_atoms=16, max_edges=64,
                          n_tasks=3, remat=False)


def datapipe_defaults(sources) -> dict:
    """Paper-shaped input-pipeline knobs for a Session over these sources:
    temperature-2 imbalance-aware mixing (flattens the ~6x source-size
    spread without going fully uniform) and a 4x4 size-bucket grid planned
    from the data. Splat into SessionConfig:

        SessionConfig(model="gfm-mtl", arch=CONFIG,
                      **datapipe_defaults(sources), ...)
    """
    from repro.data.bucketing import BucketSpec
    from repro.data.mixing import MixingConfig
    return {"mixing": MixingConfig(temperature=2.0),
            "bucketing": BucketSpec.from_sources(sources)}

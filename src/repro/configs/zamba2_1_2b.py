"""zamba2-1.2b [hybrid] — 38L d=2048, Mamba2 backbone (ssm_state=64) with a
SHARED attention block (32H, MHA) applied every 6th layer through
per-application LoRA adapters (rank 64). [arXiv:2411.15242]

The shared block's serve cache is windowed (4096) so long_500k decodes with
bounded attention state (deviation from full-context shared attn; noted)."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="zamba2-1.2b", family="hybrid", citation="arXiv:2411.15242",
    n_layers=38, d_model=2048, n_heads=32, n_kv_heads=32, d_ff=8192,
    vocab=32000, head_dim=64,
    block_pattern=("mamba2", "mamba2", "mamba2", "mamba2", "mamba2",
                   "shared_attn"),
    window=4096,
    ssm_state=64, ssm_heads=64, ssm_expand=2, ssm_chunk=256,
    long_context_ok=True,
)


def smoke() -> ArchConfig:
    return CONFIG.replace(n_layers=8, d_model=128, n_heads=4, n_kv_heads=4,
                          head_dim=32, d_ff=256, vocab=512, window=32,
                          ssm_state=16, ssm_heads=4, ssm_chunk=16,
                          remat=False)

"""seamless-m4t-medium [audio] — encoder-decoder, 12L enc + 12L dec, d=1024
16H (kv=16) d_ff=4096 vocab=256206. The conformer speech frontend is a STUB:
input_specs supplies w2v-BERT-style frame embeddings; we own the projector,
the transformer encoder and the decoder. [arXiv:2308.11596]

long_500k is SKIPPED for this arch (enc-dec speech translation never decodes
500k tokens; see DESIGN.md §Shape-skips)."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="seamless-m4t-medium", family="audio", citation="arXiv:2308.11596",
    n_layers=12, d_model=1024, n_heads=16, n_kv_heads=16, d_ff=4096,
    vocab=256206, head_dim=64, norm="layernorm",
    block_pattern=("dec_attn",),
    n_enc_layers=12, enc_memory_len=4096,
    modality="audio_embed",
)


def smoke() -> ArchConfig:
    return CONFIG.replace(n_layers=2, n_enc_layers=2, d_model=128, n_heads=4,
                          n_kv_heads=4, head_dim=32, d_ff=256, vocab=512,
                          enc_memory_len=32, remat=False)

"""Config registry: ``get(name)`` / ``get_smoke(name)`` / ``ARCHS``."""
from __future__ import annotations

import importlib

from .base import SHAPES, ArchConfig, ShapeConfig  # noqa: F401

_MODULES = {
    "granite-moe-3b-a800m": "granite_moe_3b_a800m",
    "internvl2-1b": "internvl2_1b",
    "h2o-danube-1.8b": "h2o_danube_1_8b",
    "deepseek-v2-236b": "deepseek_v2_236b",
    "gemma3-12b": "gemma3_12b",
    "zamba2-1.2b": "zamba2_1_2b",
    "stablelm-12b": "stablelm_12b",
    "qwen1.5-0.5b": "qwen1_5_0_5b",
    "seamless-m4t-medium": "seamless_m4t_medium",
    "xlstm-125m": "xlstm_125m",
    "hydragnn-gfm": "hydragnn_gfm",
}
ARCHS = tuple(_MODULES)
ASSIGNED = tuple(a for a in ARCHS if a != "hydragnn-gfm")


def _mod(name: str):
    if name not in _MODULES:
        raise KeyError(f"unknown arch '{name}'; known: {list(_MODULES)}")
    return importlib.import_module(f"repro.configs.{_MODULES[name]}")


def get(name: str) -> ArchConfig:
    return _mod(name).CONFIG


def get_smoke(name: str) -> ArchConfig:
    return _mod(name).smoke()

"""stablelm-12b [dense] — 40L d=5120 32H (GQA kv=8) d_ff=13824 vocab=100352.
[hf:stabilityai/stablelm-2-1_6b family, 12b scaling]"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="stablelm-12b", family="dense",
    citation="hf:stabilityai/stablelm-2-1_6b",
    n_layers=40, d_model=5120, n_heads=32, n_kv_heads=8, d_ff=13824,
    vocab=100352, head_dim=160,
    block_pattern=("attn",),
    fsdp=True,
    train_accum=4,
    swa_variant_window=4096,
)


def smoke() -> ArchConfig:
    return CONFIG.replace(n_layers=2, d_model=128, n_heads=4, n_kv_heads=2,
                          head_dim=32, d_ff=256, vocab=512, fsdp=False,
                          remat=False)

"""Resilience benchmarks -> BENCH_resilience.json (repo root).

Measures what the ISSUE-7 ``repro.resilience`` subsystem costs when nothing
is failing and how fast it recovers when something is:

  * ``guard``: median step latency of the guarded train step (in-step
    finiteness + spike check + accept/reject select) vs the plain
    ``make_step`` on the SAME pre-built batch stream. The acceptance bar is
    guard overhead < 5% of the median step — the guard must be cheap enough
    to leave on for every pre-training run.
  * ``recovery``: latencies of the three recovery primitives — a policy
    checkpoint save (atomic npz + sidecars), a rollback (load_latest +
    datapipe rewind), and an in-place pipeline recovery
    (``Prefetcher.restore(state())``).
  * ``soak``: one short faulted run (NaN gradient -> rollback, producer
    kill -> pipeline recovery, checkpoint-write failure -> retried IO)
    against a clean run of the same schedule: wall-clock overhead plus the
    bitwise-identity verdict on the final params.

Run:  python benchmarks/bench_resilience.py [--smoke] [--out PATH]

``--smoke`` shrinks the model/steps and asserts the emitted JSON is
well-formed — the CI chaos-soak job's entry point (see docs/benchmarks.md
for the schema).
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np

REPO_ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))

FULL = dict(total=48, max_atoms=16, max_edges=64, hidden=32, layers=2,
            head_hidden=16, batch=16, timed_steps=60, warmup=8,
            soak_steps=16)
SMOKE = dict(total=24, max_atoms=8, max_edges=24, hidden=16, layers=2,
             head_hidden=8, batch=8, timed_steps=40, warmup=8,
             soak_steps=12)


def _arch(p):
    import jax.numpy as jnp
    from repro.configs.base import ArchConfig
    return ArchConfig(name="bench-res", family="gnn", gnn_hidden=p["hidden"],
                      gnn_layers=p["layers"], n_species=64,
                      head_hidden=p["head_hidden"], head_layers=2,
                      remat=False, compute_dtype=jnp.float32)


def _sources(p, n_tasks=3):
    from repro.data.synthetic_atoms import generate_all
    data = generate_all(p["total"], max_atoms=p["max_atoms"],
                        max_edges=p["max_edges"],
                        sources=["ani1x", "qm7x", "mptrj"][:n_tasks])
    return [dict(species=s.species, pos=s.pos, edge_src=s.edge_src,
                 edge_dst=s.edge_dst, node_mask=s.node_mask,
                 edge_mask=s.edge_mask, energy=s.energy, forces=s.forces)
            for s in data.values()]


# ---------------------------------------------------------------------------
# guard overhead: guarded vs plain step on an identical batch stream
# ---------------------------------------------------------------------------

def bench_guard(p):
    from repro.core import MTPConfig, make_gfm_mtl
    from repro.data.loader import GroupBatcher
    from repro.engine import ShardingPlan, TrainState, make_step
    from repro.optim import adamw
    from repro.resilience import GuardConfig, GuardState, make_guarded_step

    arch = _arch(p)
    sources = _sources(p)
    model = make_gfm_mtl(arch, len(sources))
    opt = adamw(1e-3)
    plan = ShardingPlan(mtp=MTPConfig(n_tasks=len(sources)), donate=False)
    plain = plan.compile(make_step(model, opt, plan))
    guarded = plan.compile(make_guarded_step(model, opt, plan,
                                             guard=GuardConfig()))
    params = model.init(jax.random.PRNGKey(0))
    # one pre-built stream so batch assembly is outside both timing loops
    b = GroupBatcher(sources, p["batch"], seed=0)
    batches = [b.next_batch() for _ in range(p["timed_steps"] + p["warmup"])]

    def one(step, state, batch):
        t0 = time.perf_counter()
        state, _ = step(state, batch)
        jax.block_until_ready(state.params)
        return time.perf_counter() - t0, state

    ps = TrainState.create(params, opt)
    gs = TrainState.create(params, opt, guard=GuardState.init())
    for batch in batches[:p["warmup"]]:
        _, ps = one(plain, ps, batch)
        _, gs = one(guarded, gs, batch)
    # INTERLEAVED timing, order alternating per batch: clock drift and
    # background load hit both variants equally, so the overhead delta is
    # the guard, not the weather
    plat, glat = [], []
    for i, batch in enumerate(batches[p["warmup"]:]):
        pair = [(plain, plat), (guarded, glat)]
        for step, lat in (pair if i % 2 == 0 else pair[::-1]):
            dt, st = one(step, ps if step is plain else gs, batch)
            lat.append(dt)
            if step is plain:
                ps = st
            else:
                gs = st
    assert int(gs.guard.trips) == 0, "clean stream must not trip"
    # medians are reported, but the OVERHEAD verdict uses minima: the min
    # over many reps is the classic noise-robust estimate of intrinsic step
    # cost (scheduler contention only ever ADDS latency, and it does not
    # add it to both variants equally in any one rep)
    p50 = (1e3 * np.median(plat), 1e3 * np.median(glat))
    lo = (1e3 * np.min(plat), 1e3 * np.min(glat))
    return {
        "timed_steps": p["timed_steps"],
        "plain_step_ms_p50": float(p50[0]),
        "guarded_step_ms_p50": float(p50[1]),
        "plain_step_ms_min": float(lo[0]),
        "guarded_step_ms_min": float(lo[1]),
        "overhead_pct": float(100.0 * (lo[1] - lo[0]) / lo[0]),
    }


# ---------------------------------------------------------------------------
# recovery primitives
# ---------------------------------------------------------------------------

def bench_recovery(p, tmp):
    from repro.core import make_gfm_mtl
    from repro.data.loader import GroupBatcher
    from repro.data.prefetch import Prefetcher
    from repro.engine import TrainState
    from repro.optim import adamw
    from repro.resilience import CheckpointManager, GuardState

    arch = _arch(p)
    sources = _sources(p)
    model = make_gfm_mtl(arch, len(sources))
    state = TrainState.create(model.init(jax.random.PRNGKey(0)), adamw(1e-3),
                              guard=GuardState.init())
    batcher = GroupBatcher(sources, p["batch"], seed=0)
    mgr = CheckpointManager(os.path.join(tmp, "bench-ckpt"))

    t0 = time.perf_counter()
    mgr.save(state, metric=1.0, datapipe=batcher.state())
    save_ms = 1e3 * (time.perf_counter() - t0)

    t0 = time.perf_counter()
    _, restored = mgr.load_latest(template=state)
    rollback_ms = 1e3 * (time.perf_counter() - t0)
    jax.block_until_ready(restored.params)

    pf = Prefetcher(GroupBatcher(sources, p["batch"], seed=1), depth=2)
    try:
        for _ in range(3):
            pf.next_batch()
        t0 = time.perf_counter()
        pf.restore(pf.state())
        pipeline_ms = 1e3 * (time.perf_counter() - t0)
        pf.next_batch()               # stream is live again
    finally:
        pf.close()
    return {"checkpoint_save_ms": float(save_ms),
            "rollback_load_ms": float(rollback_ms),
            "pipeline_recovery_ms": float(pipeline_ms)}


# ---------------------------------------------------------------------------
# faulted vs clean soak
# ---------------------------------------------------------------------------

def bench_soak(p, tmp):
    from repro.engine import Session, SessionConfig
    from repro.resilience import (CheckpointPolicy, Fault, FaultSchedule,
                                  GuardConfig, ResilienceConfig)

    arch = _arch(p)

    def run(name, faults):
        res = ResilienceConfig(
            ckpt_dir=os.path.join(tmp, name),
            guard=GuardConfig(warmup_steps=3, spike_factor=50.0,
                              max_consecutive_trips=1),
            policy=CheckpointPolicy(every_steps=4, keep_last=2),
            faults=faults, retry_base_delay=0.0)
        cfg = SessionConfig(model="gfm-mtl", arch=arch,
                            steps=p["soak_steps"], batch_per_task=p["batch"],
                            eval_every=10_000, log_every=10_000,
                            verbose=False, resilience=res)
        sess = Session.from_config(cfg, sources=_sources(p))
        try:
            t0 = time.perf_counter()
            out = sess.run()
            return out, time.perf_counter() - t0
        finally:
            sess.close()

    faults = FaultSchedule([Fault(tick=5, kind="nan_grad"),
                            Fault(tick=9, kind="kill_producer"),
                            Fault(tick=12, kind="ckpt_write_fail")])
    faulted, wall_f = run("faulted", faults)
    clean, wall_c = run("clean", None)
    same = all(np.array_equal(np.asarray(a), np.asarray(b))
               for a, b in zip(jax.tree_util.tree_leaves(faulted.state.params),
                               jax.tree_util.tree_leaves(clean.state.params)))
    rep = faulted.resilience
    return {
        "steps": p["soak_steps"],
        "faults_fired": rep["faults_fired"],
        "rollbacks": rep["rollbacks"],
        "pipeline_recoveries": rep["pipeline_recoveries"],
        "io_retries": rep["io_retries"],
        "wall_clean_s": float(wall_c),
        "wall_faulted_s": float(wall_f),
        "fault_overhead_pct": float(100.0 * (wall_f - wall_c) / wall_c),
        "bitwise_equal_to_clean": bool(same),
    }


# ---------------------------------------------------------------------------


def run(p, smoke):
    import tempfile
    with tempfile.TemporaryDirectory() as tmp:
        return {
            "meta": {
                "benchmark": "bench_resilience",
                "backend": jax.default_backend(),
                "jax": jax.__version__,
                "smoke": smoke,
                "model": {k: p[k] for k in ("hidden", "layers",
                                            "head_hidden", "batch")},
            },
            "guard": bench_guard(p),
            "recovery": bench_recovery(p, tmp),
            "soak": bench_soak(p, tmp),
        }


def validate(result: dict):
    """Guard overhead under the ISSUE-7 5% bar, recovery latencies finite
    and positive, and the faulted soak bitwise-identical to the clean run
    (the whole point of the subsystem). The smoke config's steps are
    sub-2ms on CPU, so the guard's fixed O(params) cost is deliberately
    UNDER-amortized there — smoke checks sanity at a looser bar; the
    committed BENCH_resilience.json comes from the full config."""
    g = result["guard"]
    bar = 15.0 if result["meta"]["smoke"] else 5.0
    assert g["plain_step_ms_p50"] > 0 and g["guarded_step_ms_p50"] > 0
    assert g["overhead_pct"] < bar, \
        f"StepGuard overhead must be < {bar}%; got {g['overhead_pct']:.2f}%"
    for k, v in result["recovery"].items():
        assert np.isfinite(v) and v > 0, (k, v)
    s = result["soak"]
    assert s["bitwise_equal_to_clean"] is True, s
    assert s["faults_fired"] == 3 and s["rollbacks"] >= 1
    assert s["pipeline_recoveries"] >= 1 and s["io_retries"] >= 1
    json.dumps(result)   # serializable


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny model + short runs; assert valid JSON")
    ap.add_argument("--out", default=os.path.join(REPO_ROOT,
                                                  "BENCH_resilience.json"))
    args = ap.parse_args(argv)
    p = SMOKE if args.smoke else FULL
    result = run(p, args.smoke)
    validate(result)
    with open(args.out, "w") as f:
        json.dump(result, f, indent=1)

    print("name,value")
    print(f"guard_overhead_pct,{result['guard']['overhead_pct']:.3f}")
    print(f"ckpt_save_ms,{result['recovery']['checkpoint_save_ms']:.3f}")
    print(f"rollback_load_ms,{result['recovery']['rollback_load_ms']:.3f}")
    print("pipeline_recovery_ms,"
          f"{result['recovery']['pipeline_recovery_ms']:.3f}")
    print(f"soak_bitwise,{result['soak']['bitwise_equal_to_clean']}")
    print(f"wrote {args.out}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())

"""Serving benchmarks -> BENCH_serve.json (repo root).

Measures the ISSUE-6 ``repro.serve`` subsystem — continuous size-binned
batching over the training bucket grid — against a naive per-request
baseline on an open-loop, paper-proportioned request stream:

  * baseline ``NaiveServer``: the SAME admission (bucket_for binning, same
    padded bucket shapes, warm jit) but B=1 — one forward per request, no
    coalescing. The only variable is continuous batching itself.
  * load: seeded exponential inter-arrivals (open loop — arrivals do not
    wait for completions) over ``generate_mixture``'s five sources, each
    request asking the head of its source. Rates are calibrated to the
    measured naive service rate mu: below saturation (0.5x), at the knee
    (2x) and well past it (6x), so the JSON shows where coalescing starts
    to matter and how far it carries.
  * metrics per (server, rate): throughput (completed / wall) and e2e
    latency p50/p95/p99 measured uniformly by the generator (future done
    callbacks), plus the engine's own stage histograms and the compiled-
    shape count vs the bucket-grid recompile budget.

The ``multi_device`` section (ISSUE 10) measures serving scale-out on 8
forced host devices at SATURATING load (whole pool submitted as a burst,
drain timed): single-device engine vs ``ReplicaServeSession`` (one engine
per device) vs the sharded-forward mesh mode, each checked bitwise against
the single-device ``predict_one`` and against the ``shapes x plans``
compile budget. The ``adaptive`` section compares fixed vs measured-rate
release knobs at low load (the knee the PR 6 bench showed moving).

Run:  python benchmarks/bench_serve.py [--smoke] [--out PATH]

``--smoke`` runs a tiny model + short streams and asserts the emitted JSON
is well-formed — the CI serve-smoke job's entry point (see
docs/benchmarks.md for the schema).
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import threading
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
# BEFORE jax import (the bench_scaling pattern): the scale-out section needs
# a multi-device host; 8 forced host CPU devices unless the caller set more
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax
import numpy as np

REPO_ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))

# CPU-sized serving rig: the paper-palette mixture (structures <= 32 atoms)
# on a small trunk — this benchmarks the BATCHING, not the kernels. The
# bucket grid quantizes the mixture's size spread; max_batch bounds how much
# coalescing can win (ceiling ~ max_batch x when forwards are overhead-bound).
FULL = dict(total=400, max_atoms=32, max_edges=320, hidden=32, layers=2,
            head_hidden=16, max_batch=8, max_wait_ms=6.0,
            n_requests=400, rate_factors=(0.5, 2.0, 6.0), calib=40,
            sat_repeats=4)
SMOKE = dict(total=60, max_atoms=16, max_edges=96, hidden=16, layers=1,
             head_hidden=8, max_batch=8, max_wait_ms=2.0,
             n_requests=90, rate_factors=(0.5, 2.0, 8.0), calib=15,
             sat_repeats=1)


def _build(p):
    """(params, arch, spec, sources): one tiny trained-shape GFM + the
    five-source request pool + the shared bucket grid."""
    import jax.numpy as jnp
    from repro.configs.base import ArchConfig
    from repro.core.mtl import make_gfm_mtl
    from repro.data.bucketing import BucketSpec
    from repro.data.synthetic_atoms import generate_mixture, source_dicts
    sources = source_dicts(generate_mixture(
        p["total"], max_atoms=p["max_atoms"], max_edges=p["max_edges"],
        seed=0))
    arch = ArchConfig(name="bench-serve", family="gnn",
                      gnn_hidden=p["hidden"], gnn_layers=p["layers"],
                      n_species=64, head_hidden=p["head_hidden"],
                      head_layers=2, remat=False,
                      compute_dtype=jnp.float32)
    model = make_gfm_mtl(arch, len(sources))
    params = model.init(jax.random.PRNGKey(0))
    # serving wants a COARSER grid than training: with per-(bucket, head)
    # bins, every extra bucket multiplies the bin count (x n_heads) and
    # starves coalescing — a 2x2 grid keeps pad waste modest while letting
    # bins actually fill (see docs/serving.md, "grid granularity")
    spec = BucketSpec.from_sources(sources, n_atom_buckets=2,
                                   n_edge_buckets=2)
    return params, arch, spec, sources


def _request_pool(sources, n, seed):
    """n (sample, head) pairs drawn paper-proportionally: source i appears
    with probability |source_i| / total, each request asks its own head."""
    rng = np.random.default_rng(seed)
    sizes = np.array([s["species"].shape[0] for s in sources], float)
    keys = ("species", "pos", "edge_src", "edge_dst", "node_mask",
            "edge_mask")
    pool = []
    for t in rng.choice(len(sources), size=n, p=sizes / sizes.sum()):
        i = rng.integers(sources[t]["species"].shape[0])
        pool.append(({k: sources[t][k][i] for k in keys}, int(t)))
    return pool


# ---------------------------------------------------------------------------
# the baseline: same admission, same shapes, no coalescing
# ---------------------------------------------------------------------------

class NaiveServer:
    """Per-request serving: each request runs as its own B=1 padded forward
    through a warm jit at its bucket shape. Shares ``RequestQueue`` and
    ``assemble`` with the real engine so admission, padding and the compiled
    shapes are identical — continuous batching is the ONLY difference."""

    def __init__(self, params, arch, spec, n_heads):
        from repro.models import gnn, heads
        from repro.serve.batching import assemble
        from repro.serve.engine import _head_slices
        from repro.serve.queue import RequestQueue
        self.queue = RequestQueue(spec, depth=100_000, n_heads=n_heads)
        self._assemble = assemble
        self._shared = params["shared"]
        self._heads = _head_slices(params["heads"], n_heads)
        self.spec = spec

        def forward(shared, head, batch):
            feats = gnn.egnn_apply(shared, batch, cfg=arch)
            return heads.branch_apply(head, feats, batch["node_mask"],
                                      cfg=arch)

        self._predict = jax.jit(forward)
        self._worker = threading.Thread(target=self._loop, daemon=True,
                                        name="naive-serve")
        self._closing = threading.Event()
        self._worker.start()

    def submit(self, sample, head=0):
        return self.queue.submit(sample, head)

    def _run_one(self, req):
        ab = self._assemble([req], req.bucket, 1)
        batch = {k: jax.numpy.asarray(v) for k, v in ab.batch.items()}
        e, f = self._predict(self._shared, self._heads[req.head], batch)
        e, f = np.asarray(e), np.asarray(f)
        req.future.set_result({"energy": float(e[0]),
                               "forces": f[0, :req.n_atoms]})

    def _loop(self):
        while not self._closing.is_set():
            req = self.queue.get(timeout=0.05)
            if req is not None:
                self._run_one(req)
        for req in self.queue.drain():
            self._run_one(req)

    def warmup(self):
        from concurrent.futures import Future
        from repro.serve.queue import Request, _as_sample
        sm, na, ne = _as_sample({"species": np.zeros(1, np.int32),
                                 "pos": np.zeros((1, 3), np.float32)})
        for a in self.spec.atom_buckets:
            for e in self.spec.edge_buckets:
                self._run_one(Request(sample=sm, head=0, bucket=(a, e),
                                      n_atoms=na, n_edges=ne,
                                      future=Future(), t_submit=0.0))

    def close(self):
        self.queue.close()
        self._closing.set()
        self._worker.join(timeout=60)


# ---------------------------------------------------------------------------
# open-loop generator
# ---------------------------------------------------------------------------

def _drive(server, pool, rate, seed):
    """Submit the pool open-loop at ``rate`` req/s (seeded exponential
    inter-arrivals), wait for everything, return throughput + e2e latency.
    Latency is measured OUTSIDE the server — submit call to future-done
    callback — so both servers are scored by the same clock."""
    rng = np.random.default_rng(seed)
    gaps = rng.exponential(1.0 / rate, size=len(pool))
    done_at = [None] * len(pool)
    submit_at = [None] * len(pool)
    futs = []
    ev = threading.Event()
    n_done = [0]

    def _mark(i):
        def cb(_fut):
            done_at[i] = time.monotonic()
            n_done[0] += 1
            if n_done[0] == len(pool):
                ev.set()
        return cb

    t0 = time.monotonic()
    next_t = t0
    for i, (sample, head) in enumerate(pool):
        next_t += gaps[i]
        while True:                      # hybrid sleep/spin to hold the rate
            dt = next_t - time.monotonic()
            if dt <= 0:
                break
            time.sleep(min(dt, 1e-3))
        submit_at[i] = time.monotonic()
        fut = server.submit(sample, head=head)
        fut.add_done_callback(_mark(i))
        futs.append(fut)
    assert ev.wait(timeout=300), "load run did not drain in 300s"
    wall = max(done_at) - t0
    lat_ms = 1e3 * (np.array(done_at) - np.array(submit_at))
    for f in futs:
        f.result(timeout=0)              # surface any per-request failure
    p50, p95, p99 = np.percentile(lat_ms, (50, 95, 99))
    return {
        "offered_rate_per_s": rate,
        "n_requests": len(pool),
        "wall_s": wall,
        "throughput_per_s": len(pool) / wall,
        "latency_ms": {"p50": float(p50), "p95": float(p95),
                       "p99": float(p99), "mean": float(lat_ms.mean()),
                       "max": float(lat_ms.max())},
    }


def _calibrate_mu(naive, pool, n):
    """Warm sequential B=1 rate (req/s) of the naive server — the rate axis
    every load point is expressed against."""
    for sample, head in pool[:3]:
        naive.submit(sample, head=head).result(timeout=60)
    t0 = time.monotonic()
    for sample, head in pool[:n]:
        naive.submit(sample, head=head).result(timeout=60)
    return n / (time.monotonic() - t0)


# ---------------------------------------------------------------------------
# scale-out: saturating-load drain on the forced multi-device host
# ---------------------------------------------------------------------------

def _saturate(server, pool, repeats=1):
    """Closed burst: submit ``repeats`` copies of the whole pool at once and
    time the drain — the throughput-ceiling question ("how fast can it go"),
    complementary to the open-loop latency runs above. At burst load every
    bin fills to max_batch, so this measures engine pipelining, not waiting."""
    reqs = pool * repeats
    t0, c0 = time.monotonic(), time.process_time()
    futs = [server.submit(sample, head=head) for sample, head in reqs]
    for f in futs:
        f.result(timeout=600)
    wall = time.monotonic() - t0
    cpu = time.process_time() - c0
    return {"n_requests": len(reqs), "wall_s": wall,
            "throughput_per_s": len(reqs) / wall,
            "cpu_utilization": cpu / wall}


def _parity(session, pool, refs):
    """Bitwise check of served rows against precomputed predict_one refs."""
    futs = [session.submit(sm, head=h) for (sm, h), _ in zip(pool, refs)]
    ok = True
    for f, r in zip(futs, refs):
        out = f.result(timeout=600)
        ok &= (out["energy"] == r["energy"]
               and np.array_equal(out["forces"], r["forces"]))
    return bool(ok)


def run_multi_device(p, smoke, params, arch, spec, sources, pool):
    """Single-device engine vs the two ISSUE-10 scale-out modes on every
    host device, all at saturating load; rows must stay bitwise equal to the
    single-device ``predict_one`` and compiles within ``shapes x plans``.

    The >= 1.5x speedup bar is a PARALLELISM claim, so it is only enforced
    where parallelism physically exists: forced host devices multiplex the
    machine's real cores, and on a 1-CPU host every mode time-slices the
    same core (the single engine already runs it at ~100% utilization —
    measured, not assumed). The JSON records the schedulable-CPU count and
    whether the bar was armed, so a regression on real multicore hardware
    (CI, dev boxes) still fails loudly."""
    from repro.launch.mesh import make_replica_meshes
    from repro.serve import ReplicaServeSession, ServeSession
    n_dev = jax.device_count()
    n_cpu = len(os.sched_getaffinity(0))
    out = {"n_host_devices": n_dev, "schedulable_cpus": n_cpu}
    if n_dev < 2:
        out["skipped"] = "single-device host (XLA_FLAGS was preset)"
        return out
    out["speedup_bar"] = {
        "target": 1.5,
        "enforced": n_cpu >= 2 and not smoke,
        "reason": ("armed" if n_cpu >= 2 else
                   f"{n_cpu} schedulable CPU(s): host devices time-slice one "
                   f"core, parallel speedup is physically unavailable; "
                   f"throughputs recorded for regression tracking"),
    }
    kw = dict(spec=spec, max_batch=p["max_batch"],
              max_wait_ms=p["max_wait_ms"], queue_depth=100_000, seed=0)
    reps = p.get("sat_repeats", 1)
    probe = pool[:min(32, len(pool))]

    single = ServeSession(params, arch, **kw)
    single.warmup()
    refs = [single.predict_one(sm, head=h) for sm, h in probe]
    out["single"] = _saturate(single, pool, reps)
    single.close()

    # replica-worker mode: one engine per device, least-loaded routing
    rep = ReplicaServeSession(params, arch,
                              meshes=make_replica_meshes(n_dev), **kw)
    rep.warmup()
    out["replica"] = {"n_replicas": n_dev, **_saturate(rep, pool, reps)}
    out["replica"]["bitwise_equal_vs_single"] = _parity(rep, probe, refs)
    st = rep.stats()
    out["replica"]["compilations"] = st["counters"]["compilations"]
    out["replica"]["compile_budget"] = \
        st["executable_cache"]["compile_budget"]
    rep.close()

    # sharded-forward mode: one engine, rows data-parallel across the mesh;
    # the static batch must tile the mesh, so round max_batch up
    mbs = -(-p["max_batch"] // n_dev) * n_dev
    sh = ServeSession(params, arch,
                      mesh=make_replica_meshes(
                          1, devices_per_replica=n_dev)[0],
                      **dict(kw, max_batch=mbs))
    sh.warmup()
    out["sharded"] = {"mesh_devices": n_dev, "max_batch": mbs,
                      **_saturate(sh, pool, reps)}
    out["sharded"]["bitwise_equal_vs_single"] = _parity(sh, probe, refs)
    st = sh.stats()
    out["sharded"]["compilations"] = st["counters"]["compilations"]
    out["sharded"]["compile_budget"] = \
        st["executable_cache"]["compile_budget"]
    sh.close()

    base = out["single"]["throughput_per_s"]
    out["speedup_replica"] = out["replica"]["throughput_per_s"] / base
    out["speedup_sharded"] = out["sharded"]["throughput_per_s"] / base
    out["speedup_best"] = max(out["speedup_replica"], out["speedup_sharded"])
    return out


def run_adaptive(p, params, arch, spec, pool, mu):
    """Fixed vs adaptive release knobs at LOW load (0.5x mu): with sparse
    arrivals the fixed batcher holds every lone request the full max_wait;
    the adaptive policy measures the arrival gap and releases near min_wait,
    trading no throughput for a visible latency cut."""
    from repro.serve import ServeSession
    kw = dict(spec=spec, max_batch=p["max_batch"],
              max_wait_ms=p["max_wait_ms"], queue_depth=100_000, seed=0)
    out = {}
    for name, extra in (("fixed", {}), ("adaptive", {"adaptive": True})):
        s = ServeSession(params, arch, **kw, **extra)
        s.warmup()
        out[name] = _drive(s, pool, 0.5 * mu, seed=77)
        if name == "adaptive":
            out["policy"] = s.stats()["adaptive"]
        s.close()
    out["p50_reduction_ms"] = (out["fixed"]["latency_ms"]["p50"]
                               - out["adaptive"]["latency_ms"]["p50"])
    return out


# ---------------------------------------------------------------------------


def run(p, smoke):
    from repro.serve import ServeSession
    params, arch, spec, sources = _build(p)
    pool = _request_pool(sources, p["n_requests"], seed=1)

    naive = NaiveServer(params, arch, spec, n_heads=len(sources))
    naive.warmup()
    mu = _calibrate_mu(naive, pool, p["calib"])
    rates = [f * mu for f in p["rate_factors"]]

    cont = ServeSession(params, arch, spec=spec, max_batch=p["max_batch"],
                        max_wait_ms=p["max_wait_ms"],
                        queue_depth=100_000, seed=0)
    cont.warmup()

    runs = []
    for k, rate in enumerate(rates):
        row = {"rate_factor_vs_mu": p["rate_factors"][k]}
        row["naive"] = _drive(naive, pool, rate, seed=10 + k)
        row["continuous"] = _drive(cont, pool, rate, seed=10 + k)
        row["throughput_ratio"] = (row["continuous"]["throughput_per_s"]
                                   / row["naive"]["throughput_per_s"])
        runs.append(row)

    stats = cont.stats()
    cont.close()
    naive.close()
    out = {
        "meta": {
            "benchmark": "bench_serve",
            "backend": jax.default_backend(),
            "jax": jax.__version__,
            "smoke": smoke,
            "model": {k: p[k] for k in ("hidden", "layers", "head_hidden")},
            "serve": {"max_batch": p["max_batch"],
                      "max_wait_ms": p["max_wait_ms"]},
            "bucket_grid": {"atoms": list(spec.atom_buckets),
                            "edges": list(spec.edge_buckets)},
            "n_heads": len(sources),
            "naive_service_rate_per_s": mu,
        },
        "runs": runs,
        "engine": {
            "counters": stats["counters"],
            "executable_cache": stats["executable_cache"],
            "stage_latency_ms": stats["latency"],
            "batch_occupancy": stats["batch_occupancy"],
        },
    }
    out["adaptive_release"] = run_adaptive(p, params, arch, spec, pool, mu)
    out["multi_device"] = run_multi_device(p, smoke, params, arch, spec,
                                           sources, pool)
    return out


def validate(result: dict):
    """Smoke contract: >= 3 rates with full percentile rows, compilations
    within the bucket-grid budget, and continuous batching >= 2x naive
    throughput at the highest offered rate (the ISSUE-6 acceptance bar)."""
    runs = result["runs"]
    assert len(runs) >= 3, f"need >= 3 arrival rates, got {len(runs)}"
    for row in runs:
        for server in ("naive", "continuous"):
            lm = row[server]["latency_ms"]
            for q in ("p50", "p95", "p99"):
                assert np.isfinite(lm[q]) and lm[q] >= 0, (server, lm)
            assert row[server]["throughput_per_s"] > 0
    eng = result["engine"]
    assert eng["counters"]["compilations"] <= \
        eng["executable_cache"]["budget"], eng
    assert eng["counters"]["failed"] == 0, eng
    top = runs[-1]
    assert top["throughput_ratio"] >= 2.0, \
        (f"continuous batching must be >= 2x naive at the highest rate; "
         f"got {top['throughput_ratio']:.2f}x")
    ad = result["adaptive_release"]
    for mode in ("fixed", "adaptive"):
        assert ad[mode]["throughput_per_s"] > 0, ad
    md = result["multi_device"]
    if "skipped" not in md:
        for mode in ("replica", "sharded"):
            assert md[mode]["bitwise_equal_vs_single"], \
                f"{mode} rows diverged bitwise from single-device predict_one"
            assert md[mode]["compilations"] <= md[mode]["compile_budget"], \
                (mode, md[mode])
        bar = md["speedup_bar"]
        if bar["enforced"]:
            # the ISSUE-10 acceptance bar — armed wherever the host has the
            # cores to make a parallelism claim meaningful
            assert md["speedup_best"] >= bar["target"], \
                (f"scale-out must reach >= {bar['target']}x single-device "
                 f"at saturating load; got {md['speedup_best']:.2f}x")
    json.dumps(result)   # serializable


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny model + short streams; assert valid JSON")
    ap.add_argument("--out", default=os.path.join(REPO_ROOT,
                                                  "BENCH_serve.json"))
    args = ap.parse_args(argv)
    p = SMOKE if args.smoke else FULL
    result = run(p, args.smoke)
    validate(result)
    with open(args.out, "w") as f:
        json.dump(result, f, indent=1)

    print("name,value,derived")
    mu = result["meta"]["naive_service_rate_per_s"]
    print(f"serve_mu/naive_per_s,{mu:.0f},warm B=1")
    for row in result["runs"]:
        fac = row["rate_factor_vs_mu"]
        for server in ("naive", "continuous"):
            r = row[server]
            print(f"serve_thr_{fac}x/{server},"
                  f"{r['throughput_per_s']:.0f},"
                  f"p50={r['latency_ms']['p50']:.1f}ms "
                  f"p99={r['latency_ms']['p99']:.1f}ms")
    ad = result["adaptive_release"]
    print(f"serve_adaptive_p50_cut_ms,{ad['p50_reduction_ms']:.2f},"
          f"fixed p50={ad['fixed']['latency_ms']['p50']:.1f}ms "
          f"adaptive p50={ad['adaptive']['latency_ms']['p50']:.1f}ms")
    md = result["multi_device"]
    if "skipped" not in md:
        for mode in ("single", "replica", "sharded"):
            print(f"serve_sat_thr/{mode},"
                  f"{md[mode]['throughput_per_s']:.0f},burst drain")
        print(f"serve_scaleout_best,{md['speedup_best']:.2f},"
              f"replica={md['speedup_replica']:.2f}x "
              f"sharded={md['speedup_sharded']:.2f}x on "
              f"{md['n_host_devices']} devices / "
              f"{md['schedulable_cpus']} cpus "
              f"(bar {'armed' if md['speedup_bar']['enforced'] else 'off'})")
    top = result["runs"][-1]
    eng = result["engine"]
    print(f"# continuous {top['throughput_ratio']:.2f}x naive at "
          f"{top['rate_factor_vs_mu']}x mu; "
          f"{eng['counters']['compilations']} compilations / budget "
          f"{eng['executable_cache']['budget']}; "
          f"occupancy {eng['batch_occupancy']:.2f}; wrote {args.out}")


if __name__ == "__main__":
    main()

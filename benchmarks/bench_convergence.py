"""Tables 1 & 2 analogue: cross-source MAE matrices for the seven models.

Trains, at CPU-reduced scale on synthetic 5-source multi-fidelity data:
  * Model-<source> x 5  — single-dataset models
  * GFM-Baseline-All    — all sources mixed through ONE branch
  * GFM-MTL-All         — shared encoder + per-source branches (the paper's)
then evaluates energy-per-atom MAE and force MAE of every model on every
source's held-out split.

Expected phenomenology (paper §5.1): single-source models are diagonal-good /
off-diagonal-bad; Baseline-All is uniformly mediocre; MTL-All is uniformly
good."""
from __future__ import annotations

import json
import sys
import time

import numpy as np

import jax


def run(n_samples=192, steps=250, batch=16, hidden=48, seed=0, verbose=False):
    from repro.configs import get_smoke
    from repro.core import gfm_eval_fn
    from repro.data.synthetic_atoms import SOURCES, generate_all, to_batch_dict
    from repro.engine import Session, SessionConfig

    names = list(SOURCES)
    cfg = get_smoke("hydragnn-gfm").replace(gnn_hidden=hidden, head_hidden=32,
                                            n_tasks=5)
    data = generate_all(n_samples, max_atoms=cfg.max_atoms,
                        max_edges=cfg.max_edges, seed=seed)
    n_tr = int(n_samples * 0.8)
    train = [dict(species=s.species[:n_tr], pos=s.pos[:n_tr],
                  edge_src=s.edge_src[:n_tr], edge_dst=s.edge_dst[:n_tr],
                  node_mask=s.node_mask[:n_tr], edge_mask=s.edge_mask[:n_tr],
                  energy=s.energy[:n_tr], forces=s.forces[:n_tr])
             for s in data.values()]
    test = {k: to_batch_dict(s, np.arange(n_tr, n_samples))
            for k, s in data.items()}
    ev = gfm_eval_fn(cfg)

    def train_model(sources, seed=0, steps=steps):
        # task count == len(sources) (Session derives it)
        scfg = SessionConfig(model="gfm-mtl", arch=cfg, steps=steps,
                             batch_per_task=batch, lr=3e-3, seed=seed,
                             log_every=max(steps // 4, 1), verbose=False)
        return Session.from_config(scfg, sources=sources).run().params

    results = {"energy": {}, "force": {}}

    def evaluate(tag, shared, head):
        e_row, f_row = {}, {}
        for k in names:
            e, f = ev(shared, head, test[k])
            e_row[k], f_row[k] = float(e), float(f)
        results["energy"][tag] = e_row
        results["force"][tag] = f_row
        if verbose:
            print(tag, {k: round(v, 4) for k, v in e_row.items()})

    t0 = time.perf_counter()
    # 5 single-source models
    for t, k in enumerate(names):
        p = train_model([train[t]], seed=t)
        evaluate(f"Model-{k}", p["shared"],
                 jax.tree_util.tree_map(lambda x: x[0], p["heads"]))
    # GFM-Baseline-All: one branch, mixed data
    mixed = {kk: np.concatenate([s[kk] for s in train]) for kk in train[0]}
    p = train_model([mixed], seed=7)
    evaluate("GFM-Baseline-All", p["shared"],
             jax.tree_util.tree_map(lambda x: x[0], p["heads"]))
    # GFM-MTL-All: the paper's model (per-source heads; evaluated per head)
    p = train_model(train, seed=9)
    e_row, f_row = {}, {}
    for t, k in enumerate(names):
        head_t = jax.tree_util.tree_map(lambda x: x[t], p["heads"])
        e, f = ev(p["shared"], head_t, test[k])
        e_row[k], f_row[k] = float(e), float(f)
    results["energy"]["GFM-MTL-All"] = e_row
    results["force"]["GFM-MTL-All"] = f_row
    results["wall_s"] = time.perf_counter() - t0
    return results


def check_claims(results) -> dict:
    """The paper's three claims, as pass/fail derived metrics."""
    names = list(results["energy"]["GFM-MTL-All"])
    e = results["energy"]
    # 1. single-source models transfer badly (off-diagonal >> diagonal)
    off_over_diag = np.mean([
        np.mean([e[f"Model-{a}"][b] for b in names if b != a]) /
        max(e[f"Model-{a}"][a], 1e-6) for a in names])
    # 2. MTL beats Baseline on (almost) every source
    mtl_wins = sum(e["GFM-MTL-All"][k] < e["GFM-Baseline-All"][k]
                   for k in names)
    # 3. MTL is uniformly decent: worst-source MAE within ~10x of best model
    worst_mtl = max(e["GFM-MTL-All"].values())
    return {"offdiag_over_diag": float(off_over_diag),
            "mtl_wins_of_5": int(mtl_wins),
            "worst_mtl_energy_mae": float(worst_mtl)}


def main():
    res = run(verbose=True)
    claims = check_claims(res)
    json.dump({"results": res, "claims": claims},
              open("results/convergence.json", "w"), indent=1)
    print("name,us_per_call,derived")
    print(f"table1_energy_mae,{res['wall_s'] * 1e6:.0f},"
          f"mtl_wins={claims['mtl_wins_of_5']}/5;"
          f"offdiag_ratio={claims['offdiag_over_diag']:.1f}")
    print(f"table2_force_mae,{res['wall_s'] * 1e6:.0f},"
          f"worst_mtl_E={claims['worst_mtl_energy_mae']:.4f}")


if __name__ == "__main__":
    sys.path.insert(0, "src")
    main()

"""Roofline analysis from the compiled dry-run (EXPERIMENTS.md §Roofline).

Per (arch x shape) on the single-pod mesh:
    compute term    = HLO_FLOPs_per_device / peak_FLOP/s        [s]
    memory term     = HLO_traffic_per_device / HBM_bw           [s]
    collective term = collective_bytes_per_device / link_bw     [s]
(the dry-run HLO is the per-device SPMD program, so per-device numbers over
per-chip rates are the pod-synchronous step-time estimates).

Also reports MODEL_FLOPS (6ND train / 2ND prefill / 2ND decode, N_active for
MoE) and the usefulness ratio MODEL_FLOPS / (HLO_FLOPs x chips).
"""
from __future__ import annotations

import json
import math
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from benchmarks.hw import CHIPS_POD, HBM_BW, ICI_BW, PEAK_FLOPS_BF16


def model_flops(arch: str, shape_name: str) -> tuple[float, float]:
    """(MODEL_FLOPS, N_used). Uses eval_shape param counts; MoE counts only
    active experts (top_k + shared) per token."""
    import jax
    import jax.numpy as jnp
    from repro import configs
    cfg = configs.get(arch)
    from repro.configs import SHAPES
    shape = SHAPES[shape_name]

    if cfg.family == "gnn":
        # EGNN: messages/updates per edge/node; report 6*N*B_graphs as proxy
        from repro.core import make_gfm_mtl
        model = make_gfm_mtl(cfg, cfg.n_tasks)
        shapes = jax.eval_shape(model.init, jax.ShapeDtypeStruct((2,), jnp.uint32))
        n = sum(math.prod(x.shape) for x in jax.tree_util.tree_leaves(shapes))
        return 6.0 * n * 128 * cfg.n_tasks, n

    from repro.models.transformer import lm_init
    shapes = jax.eval_shape(lambda k: lm_init(k, cfg),
                            jax.ShapeDtypeStruct((2,), jnp.uint32))
    flat = jax.tree_util.tree_flatten_with_path(shapes)[0]
    n_total = n_expert = 0
    for path, leaf in flat:
        ps = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        sz = math.prod(leaf.shape)
        n_total += sz
        if "ffn/w_" in ps and leaf.ndim >= 3 and cfg.n_experts:
            n_expert += sz
    active_frac = ((cfg.top_k / cfg.n_experts) if cfg.n_experts else 1.0)
    n_active = n_total - n_expert + n_expert * active_frac

    if shape.kind == "train":
        D = shape.global_batch * shape.seq_len
        return 6.0 * n_active * D, n_active
    if shape.kind == "prefill":
        D = shape.global_batch * shape.seq_len
        return 2.0 * n_active * D, n_active
    D = shape.global_batch  # decode: one token per sequence
    return 2.0 * n_active * D, n_active


def bottleneck_row(entry: dict) -> dict | None:
    if entry.get("status") != "ok" or "hlo" not in entry:
        return None
    h = entry["hlo"]
    if "flops" not in h:   # --no-compile entries carry only a skip marker
        return None
    ct = h["flops"] / PEAK_FLOPS_BF16
    mt = h["traffic_bytes"] / HBM_BW
    lt = h["collective_bytes"] / ICI_BW
    dom = max(("compute", ct), ("memory", mt), ("collective", lt),
              key=lambda kv: kv[1])
    try:
        mf, n_used = model_flops(entry["arch"], entry["shape"])
        n_chips = CHIPS_POD * (2 if entry["mesh"] == "multipod" else 1)
        ratio = mf / max(h["flops"] * n_chips, 1.0)
    except Exception:
        mf, ratio = float("nan"), float("nan")
    return {
        "arch": entry["arch"], "shape": entry["shape"], "mesh": entry["mesh"],
        "compute_s": ct, "memory_s": mt, "collective_s": lt,
        "dominant": dom[0], "model_flops": mf, "useful_ratio": ratio,
        "temp_gb": entry.get("memory", {}).get("temp_size_in_bytes", 0) / 2 ** 30,
        "kind": entry.get("kind"), "swa_variant": entry.get("swa_variant", False),
    }


def table(path="results/dryrun.json", mesh="pod") -> list[dict]:
    with open(path) as f:
        entries = json.load(f)
    rows = []
    for e in entries:
        if e.get("mesh") != mesh:
            continue
        r = bottleneck_row(e)
        if r:
            rows.append(r)
    return rows


def lever(r) -> str:
    """One sentence: what moves the dominant term down (per the brief)."""
    arch, shape, dom = r["arch"], r["shape"], r["dominant"]
    moe = arch.startswith(("granite", "deepseek"))
    if dom == "collective":
        if arch in ("granite-moe-3b-a800m", "internvl2-1b"):
            return "head-aligned TP via 32x8 mesh reshape (done, §Perf-2)"
        if shape == "train_4k":
            return "reduce-scatter + bf16 gradient all-reduces"
        return "keep 262k-vocab logits sharded (gather only the sampled row)"
    if dom == "compute":
        return "causal block skipping in the flash kernel"
    # memory-dominant
    if shape == "train_4k" and arch == "xlstm-125m":
        return "chunkwise mLSTM (done, §Perf-1)"
    if shape in ("train_4k", "prefill_32k"):
        s = "Pallas flash attention keeps score blocks in VMEM"
        if moe:
            s += " + sorted expert dispatch"
        return s
    if shape == "decode_32k":
        return "int8-quantised KV cache halves cache-read bytes"
    return "latency-bound at B=1; batch concurrent long-context requests"


def render_markdown(rows) -> str:
    hdr = ("| arch | shape | compute s | memory s | collective s | dominant "
           "| useful ratio | temp GB | lever for dominant term |\n"
           "|---|---|---|---|---|---|---|---|---|")
    out = [hdr]
    for r in rows:
        out.append(
            f"| {r['arch']} | {r['shape']}{' (swa)' if r['swa_variant'] else ''} "
            f"| {r['compute_s']:.3e} | {r['memory_s']:.3e} "
            f"| {r['collective_s']:.3e} | **{r['dominant']}** "
            f"| {r['useful_ratio']:.2f} | {r['temp_gb']:.1f} "
            f"| {lever(r)} |")
    return "\n".join(out)


def main():
    mesh = sys.argv[1] if len(sys.argv) > 1 else "pod"
    rows = table(mesh=mesh)
    print(render_markdown(rows))
    # per-table csv for benchmarks.run
    print("\nname,us_per_call,derived")
    for r in rows:
        step = max(r["compute_s"], r["memory_s"], r["collective_s"])
        print(f"roofline/{r['arch']}/{r['shape']},{step * 1e6:.1f},"
              f"dominant={r['dominant']};useful={r['useful_ratio']:.2f}")


if __name__ == "__main__":
    main()
